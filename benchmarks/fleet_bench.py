"""Fleet-layer benchmark: replica scaling, delta streaming, 2-d mesh steps.

Three measurements of the sharded serving fleet (``repro.fleet``), written
machine-readably to ``BENCH_fleet.json`` next to the other bench artifacts:

  * **replica scaling** — req/s and p50/p95 latency vs replica count, served
    through the router's per-lane workers with the ``proc`` transport (one
    OS process per replica, the configuration whose lanes actually run in
    parallel). The acceptance bar tracked across PRs: >= 1.5x req/s at 3
    replicas vs 1 on the 2-core CPU container.
  * **delta streaming** — wire bytes of the incremental snapshot deltas the
    writer broadcasts each sync vs what full-snapshot streaming would cost
    (measured on the same pickled payloads the process transport sends).
  * **2-d mesh** — steady-state ensemble step time under the
    chains x data 2-d mesh vs the 1-d chain mesh vs unsharded, at 4 forced
    host devices (run in a subprocess: JAX pins the device count at first
    init).

Reproduction guide: docs/BENCHMARKS.md.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from .multichain_bench import bench_json_path

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# One serving shape for every scaling point: enough draws x rows per query
# that the replica-side evaluation dominates parent-side dispatch (pickle,
# concat, GIL wakeups), so lane parallelism is measurable.
_SCALE_KW = dict(n_train=2000, d=16, batch_size=100)
_CHAINS, _WINDOW, _ROWS = 8, 64, 512


def _build_fleet(replicas: int, transport: str):
    import jax

    from repro.fleet import Fleet, FleetConfig
    from repro.serving import FreshnessPolicy, ServingConfig

    config = FleetConfig(
        replicas=replicas,
        shards=1,
        transport=transport,
        serving=ServingConfig(
            num_chains=_CHAINS,
            refresh_steps=32,
            window=_WINDOW,
            micro_batch=_ROWS,
            max_batch=8,
            freshness=FreshnessPolicy(
                max_staleness_s=1e9, min_draws=_CHAINS * _WINDOW
            ),
            default_deadline_s=10.0,
            seed=0,
        ),
    )
    fleet = Fleet(config)
    fleet.add_workload("bayeslr", **_SCALE_KW)
    fleet.warm()
    # Warm every replica's evaluator outside the measured window.
    spec = fleet.workload("bayeslr").query_specs["predictive"]
    for shard in fleet.shards("bayeslr"):
        for replica in shard.replicas:
            replica.serve(spec, "predictive",
                          spec.make_queries(jax.random.key(0), _ROWS))
    return fleet, spec


def _measure_point(fleet, spec, replicas: int, num_queries: int) -> dict:
    """One serving pass restricted to the shard's first ``replicas`` lanes."""
    import jax

    from repro.fleet import FleetRouter

    router = FleetRouter(fleet, max_batch=8, default_deadline_s=10.0,
                         lanes_per_shard=replicas)
    key = jax.random.key(1)
    queries = []
    for _ in range(num_queries):
        key, sub = jax.random.split(key)
        queries.append(spec.make_queries(sub, _ROWS))
    router.start_workers(max_wait_s=0.0)
    t0 = time.perf_counter()
    reqs = [router.submit("bayeslr", "predictive", xs) for xs in queries]
    for req in reqs:
        req.result(timeout_s=120.0)
    wall = time.perf_counter() - t0
    router.stop_workers()
    entry = router.slo_report()["classes"]["bayeslr.predictive"]
    return {"qps": num_queries / max(wall, 1e-12),
            "p50_ms": entry["p50_ms"], "p95_ms": entry["p95_ms"], "wall_s": wall}


def bench_scaling(replica_counts, num_queries: int, repeats: int = 3,
                  transport: str = "proc") -> list[dict]:
    """Replica-scaling sweep over ONE warmed fleet.

    The container's effective CPU allocation fluctuates (shared host), so a
    single pass per point is unreliable: the sweep interleaves the replica
    counts ``repeats`` times over the same warmed fleet (round-robin, so a
    slow phase of the box taxes every point) and keeps each point's best
    pass — the closest observable to the quiet-box capacity.
    """
    max_r = max(replica_counts)
    fleet, spec = _build_fleet(max_r, transport)
    best: dict[int, dict] = {}
    # Shorter GIL switch interval while driving many lane threads: a lane
    # waking from its pipe recv otherwise waits up to the default 5 ms for
    # the interpreter, which serializes the lanes at high RPC rates.
    prev_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.001)
    try:
        for _ in range(repeats):
            for r in replica_counts:
                res = _measure_point(fleet, spec, r, num_queries)
                if r not in best or res["qps"] > best[r]["qps"]:
                    best[r] = res
    finally:
        sys.setswitchinterval(prev_switch)
        fleet.close()
    return [
        {
            "kind": "scaling",
            "transport": transport,
            "replicas": r,
            "queries": num_queries,
            "rows_per_query": _ROWS,
            "repeats": repeats,
            **best[r],
        }
        for r in replica_counts
    ]


def bench_delta_stream(pumps: int) -> dict:
    """Measure incremental-delta vs full-snapshot wire bytes over a run of
    refresh+broadcast rounds (warm full sync excluded: steady state)."""
    fleet, _ = _build_fleet(1, "inproc")
    try:
        base = dict(fleet.sync_stats)  # includes the warm full resync
        for _ in range(pumps):
            fleet.pump("bayeslr")
        stats = fleet.sync_stats
        syncs = stats["syncs"] - base["syncs"]
        delta = stats["delta_wire_bytes"] - base["delta_wire_bytes"]
        full = stats["full_wire_bytes"] - base["full_wire_bytes"]
        return {
            "kind": "delta_stream",
            "syncs": syncs,
            "delta_wire_bytes": delta,
            "full_wire_bytes": full,
            "delta_bytes_per_sync": delta / max(syncs, 1),
            "full_bytes_per_sync": full / max(syncs, 1),
            "ratio": delta / max(full, 1),
            "window": _WINDOW,
            "refresh_steps": 32,
        }
    finally:
        fleet.close()


_MESH_SCRIPT = r"""
import json
import jax, jax.numpy as jnp
from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig
from repro.core.target_builder import build_target

n, d, K, steps = 4000, 8, 8, %(steps)d
kx, ky = jax.random.split(jax.random.key(0))
x = jax.random.normal(kx, (n, d))
y = jnp.where(jax.random.bernoulli(ky, 0.5, (n,)), 1.0, -1.0)
target = build_target("logit", (x, y), n,
                      prior_logpdf=lambda w: -0.5 * jnp.sum(w**2))
cfg = SubsampledMHConfig(batch_size=200, epsilon=0.05)
out = {"n_devices": len(jax.devices())}
for name, shard in (("unsharded", False), ("mesh_1d", True),
                    ("mesh_2d", {"chains": 2, "data": 2})):
    ens = ChainEnsemble(target, RandomWalk(0.05), K, config=cfg, shard=shard)
    state = ens.init(jnp.zeros(d))
    # steady state: run_timed warms per-block compiles before timing
    _, timed = ens.run_timed(jax.random.key(1), state, steps, block_every=steps)
    out[name] = timed["transitions_per_sec"]
print(json.dumps(out))
"""


def bench_mesh_2d(steps: int) -> dict:
    """2-d vs 1-d vs unsharded step throughput at 4 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT % {"steps": steps}],
        capture_output=True, text=True, env=env, cwd=_REPO, timeout=900,
    )
    if out.returncode != 0:
        raise RuntimeError(f"mesh subprocess failed:\n{out.stderr[-2000:]}")
    res = json.loads(out.stdout.strip().splitlines()[-1])
    return {
        "kind": "mesh_2d",
        "steps": steps,
        "n_devices": res["n_devices"],
        "tps_unsharded": res["unsharded"],
        "tps_mesh_1d": res["mesh_1d"],
        "tps_mesh_2d": res["mesh_2d"],
    }


def main(fast: bool = True):
    if fast:
        num_queries, pumps, mesh_steps, repeats = 120, 6, 120, 3
        replica_counts = (1, 2, 3)
    else:
        num_queries, pumps, mesh_steps, repeats = 360, 12, 400, 4
        replica_counts = (1, 2, 3, 4)

    rows_out, records = [], []
    scaling = bench_scaling(replica_counts, num_queries, repeats=repeats)
    base_qps = scaling[0]["qps"]
    for rec in scaling:
        records.append(rec)
        rows_out.append((
            f"fleet_scaling_r{rec['replicas']}",
            1e6 / rec["qps"],
            f"qps={rec['qps']:.0f}_p95_ms={rec['p95_ms']:.2f}"
            f"_speedup={rec['qps'] / base_qps:.2f}x",
        ))
    delta = bench_delta_stream(pumps)
    records.append(delta)
    rows_out.append((
        "fleet_delta_stream",
        delta["delta_bytes_per_sync"],
        f"delta_per_sync={delta['delta_bytes_per_sync']:.0f}B"
        f"_full_per_sync={delta['full_bytes_per_sync']:.0f}B"
        f"_ratio={delta['ratio']:.2f}",
    ))
    mesh = bench_mesh_2d(mesh_steps)
    records.append(mesh)
    rows_out.append((
        "fleet_mesh_2d",
        1e6 / mesh["tps_mesh_2d"],
        f"tps_2d={mesh['tps_mesh_2d']:.0f}_tps_1d={mesh['tps_mesh_1d']:.0f}"
        f"_tps_unsharded={mesh['tps_unsharded']:.0f}",
    ))

    path = bench_json_path("fleet")
    with open(path, "w") as f:
        json.dump({"bench": "fleet", "records": records}, f, indent=1)
    rows_out.append((f"fleet_json:{path}", 0.0, "machine-readable output"))
    return rows_out, records


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
