"""Paper Fig. 6d: JointDPM prediction accuracy vs running time,
exact-MH weights vs subsampled-MH weights."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.experiments import jointdpm


def run(n=4000, n_test=800, cycles=40, epsilon=0.3, batch=100, seed=0,
        eval_every=5):
    cfg = jointdpm.JDPMConfig()
    data = jointdpm.synth(jax.random.key(seed), n=n, n_test=n_test)
    out = {}
    for name, exact in [("subsampled", False), ("exact", True)]:
        state = jointdpm.init_state(jax.random.key(seed + 1), data, cfg)
        gz = jax.jit(lambda k, s, p: jointdpm.gibbs_z_steps(k, s, data, cfg, p))
        mw = jax.jit(
            lambda k, s: jointdpm.subsampled_mh_w(
                k, s, data, cfg, batch_size=batch,
                epsilon=epsilon, sigma_prop=0.3, exact=exact,
            )
        )
        # warm up compile outside the clock
        _ = mw(jax.random.key(0), state)
        _ = gz(jax.random.key(0), state, jnp.arange(min(n // 2, n)))
        times, accs, n_evals = [], [], []
        t0 = time.perf_counter()
        for it in range(cycles):
            kk = jax.random.fold_in(jax.random.key(seed + 2), it)
            pts = jax.random.permutation(kk, n)[: n // 2]
            state = gz(kk, state, pts)
            state = jointdpm.mh_alpha(jax.random.fold_in(jax.random.key(3), it), state, cfg)
            for j in range(10):
                state, info = mw(jax.random.fold_in(jax.random.key(4), 31 * it + j), state)
                n_evals.append(int(info.n_evaluated))
            if it % eval_every == 0 or it == cycles - 1:
                jax.block_until_ready(state.w)
                prob = jointdpm.predict_proba(state, data.x_test, cfg)
                accs.append(jointdpm.accuracy(np.asarray(prob), np.asarray(data.y_test)))
                times.append(time.perf_counter() - t0)
        out[name] = {
            "times": times, "accs": accs,
            "mean_evaluated": float(np.mean(n_evals)),
            "clusters": int(jnp.sum(state.stats.n > 0.5)),
        }
    return out


def main(fast: bool = True):
    res = run(n=2000 if fast else 10_000, cycles=20 if fast else 60)
    rows = []
    for name, r in res.items():
        us = 1e6 * r["times"][-1] / max(len(r["accs"]), 1)
        rows.append((
            f"fig6_{name}", us,
            f"acc={r['accs'][-1]:.3f}_meanNk={r['mean_evaluated']:.0f}"
            f"_clusters={r['clusters']}_t={r['times'][-1]:.1f}s",
        ))
    return rows, res


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
