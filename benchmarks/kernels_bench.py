"""Kernel benchmarks: fused-CE traffic model + wall time of the jnp paths.

On this CPU container the Pallas kernels run in interpret mode (not
representative of TPU latency), so we report (a) wall time of the pure-jnp
reference (the CPU-executable path), and (b) the derived HBM-traffic ratio
naive/fused — the quantity the kernel actually optimizes on TPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import fused_ce_ref, logit_delta_ref


def _time(f, *args, n=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main(fast: bool = True):
    rows = []
    cases = [(512, 512, 32_000), (512, 1024, 152_064)] if fast else [
        (512, 512, 32_000), (1024, 1024, 152_064), (2048, 1024, 262_144)]
    for t, d, v in cases:
        h = jax.random.normal(jax.random.key(0), (t, d), jnp.bfloat16)
        tab = jax.random.normal(jax.random.key(1), (v, d), jnp.bfloat16)
        tgt = jax.random.randint(jax.random.key(2), (t,), 0, v)
        f = jax.jit(fused_ce_ref)
        us = _time(f, h, tab, tgt) * 1e6
        naive_bytes = t * v * 4 + t * d * 2 + v * d * 2  # logits materialized
        fused_bytes = t * d * 2 + v * d * 2 + t * 4  # streamed tiles
        rows.append((
            f"kernel_ce_T{t}_V{v}", us,
            f"traffic_ratio_naive/fused={naive_bytes / fused_bytes:.1f}x",
        ))
    for n, d in [(12214, 50), (100_000, 50)]:
        x = jax.random.normal(jax.random.key(0), (n, d))
        y = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (n,)), 1.0, -1.0)
        w1 = jax.random.normal(jax.random.key(2), (d,))
        w2 = jax.random.normal(jax.random.key(3), (d,))
        f = jax.jit(logit_delta_ref)
        us = _time(f, x, y, w1, w2) * 1e6
        # pair-fused kernel reads x once instead of twice
        rows.append((f"kernel_logitdelta_N{n}", us, "x_reads_fused=2->1"))
    return rows, None


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
