"""Kernel benchmarks: fused-CE traffic model + wall time of the jnp paths.

On this CPU container the Pallas kernels run in interpret mode (not
representative of TPU latency), so we report (a) wall time of the pure-jnp
reference (the CPU-executable path), (b) the derived HBM-traffic ratio
naive/fused — the quantity the kernel actually optimizes on TPU — and
(c) the steady-state stochvol pgibbs+MH cycle at each sweep dispatch mode
(``opaque`` legacy vmap, ``compat`` fused scan with the legacy RNG stream,
``fused`` fast-RNG scan), whose fused/opaque speedup is the headline the
perf issue gates on.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import fused_ce_ref, logit_delta_ref


def _time(f, *args, n=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main(fast: bool = True):
    rows = []
    cases = [(512, 512, 32_000), (512, 1024, 152_064)] if fast else [
        (512, 512, 32_000), (1024, 1024, 152_064), (2048, 1024, 262_144)]
    for t, d, v in cases:
        h = jax.random.normal(jax.random.key(0), (t, d), jnp.bfloat16)
        tab = jax.random.normal(jax.random.key(1), (v, d), jnp.bfloat16)
        tgt = jax.random.randint(jax.random.key(2), (t,), 0, v)
        f = jax.jit(fused_ce_ref)
        us = _time(f, h, tab, tgt) * 1e6
        naive_bytes = t * v * 4 + t * d * 2 + v * d * 2  # logits materialized
        fused_bytes = t * d * 2 + v * d * 2 + t * 4  # streamed tiles
        rows.append((
            f"kernel_ce_T{t}_V{v}", us,
            f"traffic_ratio_naive/fused={naive_bytes / fused_bytes:.1f}x",
        ))
    for n, d in [(12214, 50), (100_000, 50)]:
        x = jax.random.normal(jax.random.key(0), (n, d))
        y = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (n,)), 1.0, -1.0)
        w1 = jax.random.normal(jax.random.key(2), (d,))
        w2 = jax.random.normal(jax.random.key(3), (d,))
        f = jax.jit(logit_delta_ref)
        us = _time(f, x, y, w1, w2) * 1e6
        # pair-fused kernel reads x once instead of twice
        rows.append((f"kernel_logitdelta_N{n}", us, "x_reads_fused=2->1"))
    rows.extend(_bench_sv_cycle(fast))
    return rows, None


def _bench_sv_cycle(fast: bool = True):
    """Steady-state stochvol pgibbs+MH cycle per sweep dispatch mode.

    Warm-up run compiles and settles the caches; the timed window then
    measures `steps` full cycles (particle-Gibbs sweep + the two
    subsampled-MH parameter moves) across the K-chain ensemble. The
    `sweep_speedup_fused_vs_opaque` row is the acceptance headline."""
    from repro.core.ensemble import ChainEnsemble
    from repro.experiments import stochvol

    s, t, p, k, steps = (200, 10, 25, 4, 20) if fast else (400, 20, 50, 8, 40)
    data = stochvol.synth(jax.random.key(0), num_series=s, length=t)
    theta0 = stochvol.init_theta(data.obs)
    rows, ms = [], {}
    for sweep in ("opaque", "compat", "fused"):
        cyc = stochvol.make_inference_cycle(
            data.obs, num_particles=p, sweep=sweep
        )
        ens = ChainEnsemble(
            num_chains=k, transition=cyc, collect=stochvol._collect_params
        )
        state = ens.init(theta0)
        state, _, _ = ens.run(jax.random.key(1), state, 2)  # compile + warm
        jax.block_until_ready(state.theta)
        t0 = time.perf_counter()
        state, _, _ = ens.run(jax.random.key(2), state, steps)
        jax.block_until_ready(state.theta)
        ms[sweep] = (time.perf_counter() - t0) / steps * 1e3
        rows.append((
            f"sv_cycle_{sweep}_S{s}_T{t}_P{p}_K{k}", ms[sweep] * 1e3,
            f"ms_per_cycle={ms[sweep]:.1f}",
        ))
    rows.append((
        "sweep_speedup_fused_vs_opaque", ms["opaque"] / ms["fused"],
        f"speedup={ms['opaque'] / ms['fused']:.2f}x"
        f"_compat={ms['opaque'] / ms['compat']:.2f}x",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
