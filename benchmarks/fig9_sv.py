"""Paper Fig. 9: stochastic volatility — posterior histograms of (phi, sigma)
and ESS/second, exact vs subsampled MH (joint with particle Gibbs states)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SubsampledMHConfig,
    effective_sample_size,
    make_sampler,
    mh_step,
    subsampled_mh_step,
)
from repro.experiments import stochvol


def run(num_series=200, length=5, iters=300, epsilon=1e-3, batch=100, seed=0,
        pgibbs_every=1, particles=25):
    data = stochvol.synth(jax.random.key(seed), num_series, length, phi=0.95, sigma=0.1)
    out = {}
    for name in ("exact", "subsampled"):
        theta = {"phi": jnp.asarray(0.7), "sigma2": jnp.asarray(0.03)}
        h = jnp.zeros_like(data.obs)
        pg = jax.jit(
            lambda k, h, t: stochvol.pgibbs_sweep(
                k, data.obs, h, stochvol.SVParams(t["phi"], t["sigma2"]), particles
            )
        )
        cfg = SubsampledMHConfig(batch_size=batch, epsilon=epsilon)
        pkey = jax.random.key(1234)
        target0 = stochvol.make_param_target(h, "phi", permute_key=pkey)
        s0, reset, draw = make_sampler("stream", target0.num_sections)

        def make_step(leaf, sig):
            if name == "subsampled":
                def f(k, th, hh):
                    t = stochvol.make_param_target(hh, leaf, permute_key=pkey)
                    return subsampled_mh_step(
                        k, th, s0, t, stochvol.SingleLeafRW(leaf, sig), cfg, reset, draw
                    )[0]
            else:
                def f(k, th, hh):
                    t = stochvol.make_param_target(hh, leaf)
                    return mh_step(k, th, t, stochvol.SingleLeafRW(leaf, sig))[0]
            return jax.jit(f)

        phi_step = make_step("phi", 0.02)
        sig_step = make_step("sigma2", 0.003)
        # compile
        theta = phi_step(jax.random.key(0), theta, h)
        theta = sig_step(jax.random.key(0), theta, h)
        h = pg(jax.random.key(0), h, theta)
        jax.block_until_ready(h)

        phis, sig2s = [], []
        t0 = time.perf_counter()
        key = jax.random.key(seed + 1)
        for it in range(iters):
            key, k1, k2, k3 = jax.random.split(key, 4)
            if it % pgibbs_every == 0:
                h = pg(k1, h, theta)
            # 10x more compute to states (paper Sec 4.3); here: params cheap
            theta = phi_step(k2, theta, h)
            theta = sig_step(k3, theta, h)
            phis.append(float(theta["phi"]))
            sig2s.append(float(theta["sigma2"]))
        wall = time.perf_counter() - t0
        burn = iters // 3
        phi_arr = np.asarray(phis[burn:])
        sig_arr = np.sqrt(np.asarray(sig2s[burn:]))
        out[name] = {
            "wall_s": wall,
            "phi_mean": float(phi_arr.mean()), "phi_std": float(phi_arr.std()),
            "sigma_mean": float(sig_arr.mean()), "sigma_std": float(sig_arr.std()),
            "ess_phi_per_s": effective_sample_size(phi_arr) / wall,
            "ess_sigma_per_s": effective_sample_size(sig_arr) / wall,
            "iters": iters,
        }
    return out


def main(fast: bool = True):
    res = run(num_series=100 if fast else 200, iters=150 if fast else 600)
    rows = []
    for name, r in res.items():
        us = 1e6 * r["wall_s"] / r["iters"]
        rows.append((
            f"fig9_{name}", us,
            f"phi={r['phi_mean']:.3f}±{r['phi_std']:.3f}"
            f"_sigma={r['sigma_mean']:.3f}±{r['sigma_std']:.3f}"
            f"_essphi/s={r['ess_phi_per_s']:.2f}",
        ))
    return rows, res


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
