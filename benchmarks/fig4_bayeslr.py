"""Paper Fig. 4: risk of the predictive mean vs wall time, BayesLR.

MNIST-scale synthetic (12214 train / 2037 test / 50 PCA-like dims). The
reference predictive mean comes from a long exact-MH run; risk(t) is the MSE
of each chain's running predictive mean against it. The paper's claim: the
subsampled chain reaches a given risk many times faster.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RandomWalk, SubsampledMHConfig, run_chain_timed
from repro.experiments import bayeslr


def run(budget_steps_exact=400, budget_steps_sub=1200, epsilon=0.05, batch=500,
        n_train=12214, n_test=2037, d=50, seed=0, sigma=0.03):
    data = bayeslr.synth_mnist_like(jax.random.key(seed), n_train, n_test, d)
    target = bayeslr.make_target(data.x_train, data.y_train)
    w0 = jnp.zeros(d)

    runs = {}
    for name, kernel, cfg, steps in [
        ("exact", "exact", None, budget_steps_exact),
        ("subsampled", "subsampled",
         SubsampledMHConfig(batch_size=batch, epsilon=epsilon, sampler="stream"), budget_steps_sub),
    ]:
        runs[name] = run_chain_timed(
            jax.random.key(seed + 1), w0, target, RandomWalk(sigma), steps,
            kernel=kernel, config=cfg, chunk_size=4096,
        )

    # reference: tail of the exact chain's running predictive mean
    x_test = np.asarray(data.x_test)
    ref_samples = np.asarray(runs["exact"]["samples"])[len(runs["exact"]["samples"]) // 2:]
    ref = bayeslr.predictive_mean_prob(ref_samples, x_test)[-1]

    out = {}
    for name, r in runs.items():
        w = np.asarray(r["samples"])
        pred = bayeslr.predictive_mean_prob(w, x_test)
        risk = bayeslr.risk_vs_reference(pred, ref)
        n_eval = np.asarray([i["n_evaluated"] for i in r["infos"]])
        out[name] = {
            "times": r["times"],
            "risk": risk,
            "mean_evaluated": float(n_eval.mean()),
            "steps": len(w),
            "test_err_final": bayeslr.test_error(w[len(w) // 2:].mean(0),
                                                 x_test, np.asarray(data.y_test)),
        }
    return out


def main(fast: bool = True):
    res = run(budget_steps_exact=150 if fast else 600,
              budget_steps_sub=450 if fast else 2500)
    rows = []
    for name, r in res.items():
        total_t = r["times"][-1] if len(r["times"]) else 0.0
        us = 1e6 * total_t / max(r["steps"], 1)
        # time to reach 2x the exact chain's final risk
        final_risk_exact = res["exact"]["risk"][-1]
        thresh = max(2.0 * final_risk_exact, 1e-6)
        reach = np.argmax(r["risk"] < thresh) if (r["risk"] < thresh).any() else -1
        t_reach = r["times"][reach] if reach >= 0 else float("nan")
        rows.append((
            f"fig4_{name}", us,
            f"steps={r['steps']}_meanN={r['mean_evaluated']:.0f}"
            f"_risk={r['risk'][-1]:.2e}_t2x={t_reach:.1f}s_testerr={r['test_err_final']:.3f}",
        ))
    return rows, res


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
