"""Per-kernel roofline harness over the repro.kernels dispatch surface.

For each production kernel (the five ``repro.kernels.ops`` entry points:
pair-fused logit delta, its ensemble-batched form, the batched AR(1)
transition delta, fused CE, and its ensemble-batched form) this times the
``mode="auto"`` dispatch path — exactly what the samplers execute: the
Pallas kernel on TPU, the jnp reference elsewhere — and pairs the measured
wall time with the kernel's analytic operation/byte model:

  * ``flops``            analytic FLOPs per call
  * ``bytes_min``        compulsory HBM traffic (each operand read once,
                         the output written once) — the fused kernels'
                         design point
  * ``intensity``        flops / bytes_min (arithmetic intensity)
  * ``gflops`` /``gbs``  achieved rates from the measured wall time
  * ``tpu_bound``        which side of the TPU-v5e roofline the analytic
                         model puts the kernel on (compute vs memory), with
                         the corresponding ideal per-call seconds
  * ``achieved_frac_peak``  measured FLOP rate over the roofline-limited
                         rate ``min(PEAK_FLOPS, intensity * HBM_BW)`` — the
                         headline "fraction of attainable peak" per kernel

Each bytes-bound family also runs a ``*_bf16`` variant (the
``precision="bf16"`` data path: gathered slabs and matmul operands in
bfloat16, fp32 accumulation) whose analytic ``bytes_min`` reflects the
halved slab traffic, and the fused particle-Gibbs sweep
(``repro.kernels.pgibbs``) is modeled as one time-major scan over the
(K, S, P) particle block.

The machine-readable result lands in ``BENCH_roofline.json`` (see
``multichain_bench.bench_json_path``) next to the other bench artifacts so
``benchmarks/gate.py`` can diff per-kernel throughput run-over-run.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .multichain_bench import bench_json_path

# TPU v5e single-chip peaks — the roofline the kernels were designed
# against; on CPU the measured rates land far below, but the analytic
# bound classification is machine-independent.
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9       # B/s


def _time(f, *args, n: int = 5) -> float:
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _nbytes(*arrays) -> int:
    return sum(a.size * a.dtype.itemsize for a in arrays)


def _case_logit_delta(n: int, d: int):
    x = jax.random.normal(jax.random.key(0), (n, d))
    y = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (n,)), 1.0, -1.0)
    w1 = jax.random.normal(jax.random.key(2), (d,))
    w2 = jax.random.normal(jax.random.key(3), (d,))
    args = (x, y, w1, w2)
    out_b = n * 4
    return {
        "name": f"logit_delta_N{n}_D{d}",
        "fn": ops.logit_delta,
        "args": args,
        # two matvecs (2ND each) + ~8 elementwise ops per row
        "flops": 2 * 2.0 * n * d + 8.0 * n,
        "bytes_min": _nbytes(*args) + out_b,
        "shape": f"N={n} D={d}",
    }


def _case_batched_logit_delta(k: int, m: int, d: int, precision: str = "fp32"):
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float32
    xg = jax.random.normal(jax.random.key(0), (k, m, d), dt)
    yg = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (k, m)), 1.0, -1.0)
    w1 = jax.random.normal(jax.random.key(2), (k, d), dt)
    w2 = jax.random.normal(jax.random.key(3), (k, d), dt)
    args = (xg, yg, w1, w2)
    suffix = "_bf16" if precision == "bf16" else ""
    return {
        "name": f"batched_logit_delta_K{k}_m{m}_D{d}{suffix}",
        "fn": ops.batched_logit_delta,
        "args": args,
        "kw": {"mode": "auto", "precision": precision},
        "precision": precision,
        "flops": 2 * 2.0 * k * m * d + 8.0 * k * m,
        "bytes_min": _nbytes(*args) + k * m * 4,
        "shape": f"K={k} m={m} D={d}",
    }


def _case_ar1_delta(k: int, m: int, precision: str = "fp32"):
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float32
    keys = jax.random.split(jax.random.key(0), 6)
    xt = jax.random.normal(keys[0], (k, m), dt)
    xp = jax.random.normal(keys[1], (k, m), dt)
    phi1 = 0.9 * jnp.tanh(jax.random.normal(keys[2], (k,)))
    phi2 = 0.9 * jnp.tanh(jax.random.normal(keys[3], (k,)))
    s21 = jnp.exp(jax.random.normal(keys[4], (k,)))
    s22 = jnp.exp(jax.random.normal(keys[5], (k,)))
    args = (xt, xp, phi1, s21, phi2, s22)
    suffix = "_bf16" if precision == "bf16" else ""
    return {
        "name": f"ar1_delta_K{k}_m{m}{suffix}",
        "fn": ops.batched_gaussian_ar1_delta,
        "args": args,
        "kw": {"mode": "auto", "precision": precision},
        "precision": precision,
        # per (k, m) element: two gaussian logpdfs, ~10 flops each
        "flops": 20.0 * k * m,
        "bytes_min": _nbytes(*args) + k * m * 4,
        "shape": f"K={k} m={m}",
    }


def _case_fused_ce(t: int, d: int, v: int, precision: str = "bf16"):
    dt = jnp.bfloat16 if precision == "bf16" else jnp.float32
    h = jax.random.normal(jax.random.key(0), (t, d), dt)
    tab = jax.random.normal(jax.random.key(1), (v, d), dt)
    tgt = jax.random.randint(jax.random.key(2), (t,), 0, v)
    args = (h, tab, tgt)
    suffix = "_fp32" if precision == "fp32" else ""
    return {
        "name": f"fused_ce_T{t}_D{d}_V{v}{suffix}",
        "fn": ops.fused_ce,
        "args": args,
        "kw": {"mode": "auto", "precision": precision},
        "precision": precision,
        # logits matmul + logsumexp over V per token
        "flops": 2.0 * t * d * v + 3.0 * t * v,
        "bytes_min": _nbytes(*args) + t * 4,
        "shape": f"T={t} D={d} V={v}",
        # what the fused kernel avoids: materializing (T, V) f32 logits
        "naive_bytes": _nbytes(*args) + t * 4 + 2 * t * v * 4,
    }


def _case_pgibbs_sweep(k: int, s: int, t: int, p: int):
    from repro.kernels.pgibbs import batched_pgibbs_sweep

    keys = jax.random.split(jax.random.key(0), k)
    obs = jax.random.normal(jax.random.key(1), (s, t))
    h = jax.random.normal(jax.random.key(2), (k, s, t)) * 0.1
    phi = jnp.full((k,), 0.95)
    s2 = jnp.full((k,), 0.02)
    args = (keys, obs, h, phi, s2)
    # per (chain, series, particle, step): AR(1) propagate (~4 flops incl.
    # the normal draw's transform), obs logpdf (~10 with the exp), softmax+
    # cumsum amortized (~3), inverse-CDF resample (~log2 P)
    import math

    flops = k * s * p * t * (4 + 10 + 3 + math.log2(max(p, 2)))
    # compulsory traffic: obs read, reference paths read, trajectory written;
    # the per-step particle block lives on chip inside the scan
    bytes_min = (s * t + 2 * k * s * t) * 4
    return {
        "name": f"pgibbs_sweep_K{k}_S{s}_T{t}_P{p}",
        "fn": batched_pgibbs_sweep,
        "args": args,
        "kw": {"num_particles": p, "mode": "fast"},
        "path": "fused-scan",
        "flops": flops,
        "bytes_min": bytes_min,
        "shape": f"K={k} S={s} T={t} P={p}",
    }


def _case_batched_fused_ce(k: int, t: int, d: int, v: int):
    h = jax.random.normal(jax.random.key(0), (k, t, d), jnp.bfloat16)
    tab = jax.random.normal(jax.random.key(1), (v, d), jnp.bfloat16)
    tgt = jax.random.randint(jax.random.key(2), (k, t), 0, v)
    args = (h, tab, tgt)
    return {
        "name": f"batched_fused_ce_K{k}_T{t}_V{v}",
        "fn": ops.batched_fused_ce,
        "args": args,
        "kw": {"mode": "auto", "precision": "bf16"},
        "precision": "bf16",
        "flops": 2.0 * k * t * d * v + 3.0 * k * t * v,
        "bytes_min": _nbytes(*args) + k * t * 4,
        "shape": f"K={k} T={t} D={d} V={v}",
        "naive_bytes": _nbytes(*args) + k * t * 4 + 2 * k * t * v * 4,
    }


def cases(fast: bool = True) -> list[dict]:
    if fast:
        return [
            _case_logit_delta(12214, 50),
            _case_batched_logit_delta(8, 256, 50),
            _case_batched_logit_delta(8, 256, 50, precision="bf16"),
            _case_ar1_delta(8, 512),
            _case_ar1_delta(8, 512, precision="bf16"),
            _case_fused_ce(256, 512, 32_000),
            _case_fused_ce(256, 512, 32_000, precision="fp32"),
            _case_batched_fused_ce(4, 128, 512, 32_000),
            _case_pgibbs_sweep(4, 64, 16, 25),
        ]
    return [
        _case_logit_delta(100_000, 50),
        _case_batched_logit_delta(32, 1024, 50),
        _case_batched_logit_delta(32, 1024, 50, precision="bf16"),
        _case_ar1_delta(32, 2048),
        _case_ar1_delta(32, 2048, precision="bf16"),
        _case_fused_ce(512, 1024, 152_064),
        _case_fused_ce(512, 1024, 152_064, precision="fp32"),
        _case_batched_fused_ce(8, 256, 1024, 152_064),
        _case_pgibbs_sweep(8, 200, 50, 50),
    ]


def measure(case: dict) -> dict:
    kw = case.get("kw", {"mode": "auto"})
    path = case.get("path") or ("pallas" if ops.use_kernel("auto") else "ref")
    fn = jax.jit(lambda *a: case["fn"](*a, **kw))
    sec = _time(fn, *case["args"])
    flops, bmin = case["flops"], case["bytes_min"]
    tpu_compute_s = flops / PEAK_FLOPS
    tpu_memory_s = bmin / HBM_BW
    # the attainable FLOP rate at this arithmetic intensity — the roofline
    roof_flops = min(PEAK_FLOPS, (flops / bmin) * HBM_BW)
    rec = {
        "kind": "roofline",
        "name": case["name"],
        "path": path,
        "backend": jax.default_backend(),
        "shape": case["shape"],
        "precision": case.get("precision", "fp32"),
        "us_per_call": sec * 1e6,
        "flops": flops,
        "bytes_min": bmin,
        "intensity_flops_per_byte": flops / bmin,
        "gflops": flops / sec / 1e9,
        "gbs": bmin / sec / 1e9,
        "tpu_bound": "compute" if tpu_compute_s >= tpu_memory_s else "memory",
        "tpu_ideal_us": max(tpu_compute_s, tpu_memory_s) * 1e6,
        "achieved_frac_peak": (flops / sec) / roof_flops,
    }
    if "naive_bytes" in case:
        rec["traffic_ratio_naive_over_fused"] = case["naive_bytes"] / bmin
    return rec


def main(fast: bool = True):
    records = [measure(c) for c in cases(fast)]
    payload = {"bench": "roofline", "fast": fast,
               "backend": jax.default_backend(), "records": records}
    path = bench_json_path("roofline")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    rows = [(
        f"roofline_{r['name']}",
        r["us_per_call"],
        f"path={r['path']}_ai={r['intensity_flops_per_byte']:.1f}"
        f"_gflops={r['gflops']:.1f}_tpu_bound={r['tpu_bound']}",
    ) for r in records]
    return rows, records


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
