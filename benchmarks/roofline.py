"""Roofline analysis (deliverable g): three derived terms per (arch × shape)
cell from the dry-run artifacts + an analytic TPU-target model.

Terms (per v5e chip, single-pod 256-chip mesh):
    compute_s    = FLOPs / (197e12 FLOP/s bf16)
    memory_s     = HBM bytes / (819e9 B/s)
    collective_s = collective wire bytes / (50e9 B/s per ICI link)

Measurement caveats (DESIGN.md §8, established empirically during the
dry-run):
  * ``compiled.cost_analysis()`` counts scan/while bodies ONCE — a 64-layer
    scanned transformer reports ~1/64 of its true FLOPs. We therefore derive
    compute/memory terms ANALYTICALLY from the architecture config and shape
    (formulas below), and report the raw cost_analysis number alongside.
  * XLA:CPU materializes f32 copies of bf16 buffers around dots and hoists
    them out of loops; memory_analysis() is reported raw plus a TPU-adjusted
    analytic params+cache+activation budget.
  * Collective bytes are parsed from post-SPMD HLO (per-device shard shapes);
    collectives inside scanned layer bodies are counted once per body and
    scaled by the trip count recorded in the artifact metadata.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link
CHIPS_SINGLE = 256


def _cfg(arch: str):
    from repro.configs import ARCHS

    return ARCHS[arch]


def per_token_matmul_flops(cfg) -> float:
    """Forward matmul FLOPs per token, excluding attention's quadratic term
    and the unembedding (= 2 x active non-embedding params)."""
    embed = cfg.vocab * cfg.d_model
    return 2.0 * max(cfg.active_param_count() - embed, 0)


def attn_quadratic_flops(cfg, kv_avg: float) -> float:
    """Per-token score+value FLOPs summed over attention layers."""
    if cfg.family == "ssm":
        return 0.0
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_period
    per_layer = 2 * 2 * cfg.n_heads * cfg.hd * kv_avg  # qk^T and pv
    extra = 0.0
    if cfg.family == "audio":
        # cross-attention against the (stubbed) encoder output
        extra = cfg.n_layers * 2 * 2 * cfg.n_heads * cfg.hd * cfg.n_audio_frames
    return n_attn * per_layer + extra


def unembed_flops(cfg) -> float:
    return 2.0 * cfg.d_model * cfg.vocab


def kv_avg_for(cfg, spec) -> float:
    s = spec.seq_len
    win = cfg.window or (cfg.local_window if cfg.global_every else None)
    if spec.kind == "decode":
        full = min(s, cfg.window) if cfg.window else s
        return float(full)
    causal_avg = s / 2.0
    if cfg.window:
        return float(min(causal_avg, cfg.window))
    if cfg.global_every and cfg.local_window:
        # 1/global_every layers see s/2, the rest see the local window
        g = 1.0 / cfg.global_every
        return float(g * causal_avg + (1 - g) * min(causal_avg, cfg.local_window))
    return float(causal_avg)


def analytic_cell(arch: str, spec, rec: dict) -> dict:
    """FLOPs / HBM bytes / collective seconds for one cell (per chip)."""
    cfg = _cfg(arch)
    chips = rec.get("n_chips", CHIPS_SINGLE)
    p_bytes = cfg.param_count() * 2  # bf16
    kv_avg = kv_avg_for(cfg, spec)
    tok_f = per_token_matmul_flops(cfg) + attn_quadratic_flops(cfg, kv_avg)

    kvb = 1 if rec.get("kv_dtype") == "fp8" else 2
    if spec.kind == "train":
        rb = rec.get("train_round_batch") or max(spec.global_batch // 4, 1)
        tokens = rb * (spec.seq_len - 1)
        # one test round = TWO forwards (theta, theta') incl. unembed loglik
        flops = 2 * tokens * (tok_f + unembed_flops(cfg))
        hbm = 2 * 2 * p_bytes + tokens * cfg.d_model * 2 * 8  # 2 fwd x (w read) + prop rw + acts
        rounds_note = f"per test round (round_batch={rb}); E[rounds] <= {spec.global_batch // rb}"
    elif spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        flops = tokens * tok_f + spec.global_batch * unembed_flops(cfg)
        cache_len = min(spec.seq_len, cfg.window) if cfg.window else spec.seq_len
        kv_bytes = _kv_cache_bytes(cfg, spec.global_batch, cache_len, kvb)
        hbm = p_bytes + tokens * cfg.d_model * 2 * 8 + kv_bytes
        rounds_note = "single forward"
    else:  # decode
        tokens = spec.global_batch
        flops = tokens * (tok_f + unembed_flops(cfg))
        cache_len = min(spec.seq_len, cfg.window) if cfg.window else spec.seq_len
        kv_bytes = _kv_cache_bytes(cfg, spec.global_batch, cache_len, kvb)
        hbm = cfg.active_param_count() * 2 + kv_bytes  # weights + full cache read
        rounds_note = "per decoded token"

    compute_s = flops / chips / PEAK_FLOPS
    memory_s = hbm / chips / HBM_BW
    # Two collective accountings bracket the truth (DESIGN.md §8): the raw
    # HLO parse counts scan-body collectives once (lower bound); scaling all
    # non-entry collectives by the layer-scan trip count over-scales the
    # per-round ones (upper bound). Primary = lower bound.
    coll_bytes = rec.get("collective_wire_bytes_unscaled",
                         rec.get("collective_wire_bytes_per_device", 0.0))
    coll_bytes_hi = rec.get("collective_wire_bytes_per_device", coll_bytes)
    collective_s = coll_bytes / ICI_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    frac = compute_s / max(max(terms.values()), 1e-30)

    model_flops_6nd = 6.0 * cfg.active_param_count() * (
        tokens if spec.kind == "train" else tokens
    )
    # MH is forward-only over two parameter sets: useful fwd flops = 4ND per
    # round vs the 6ND training convention
    ratio = model_flops_6nd / max(flops * chips / max(chips, 1), 1e-30) if False else (
        model_flops_6nd / max(flops, 1e-30)
    )

    advice = {
        "compute_s": "compute-bound: increase arithmetic efficiency (fused CE, "
                     "larger round_batch to amortize, bf16 end-to-end)",
        "memory_s": "memory-bound: cut bytes (int8 KV cache, windowed cache, "
                    "weight reuse across theta/theta' via delta evaluation)",
        "collective_s": "collective-bound: reshard to cut all-gathers "
                        "(replicate small weights, 1D-shard attention io)",
    }[bottleneck]

    return {
        "arch": arch,
        "shape": spec.name,
        "mesh": rec.get("mesh", "single"),
        "status": rec.get("status"),
        **{k: float(v) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "roofline_fraction": float(frac),
        "analytic_flops_global": float(flops),
        "costan_flops_per_dev": rec.get("flops_per_device"),
        "collective_bytes_per_dev": float(coll_bytes),
        "collective_s_upper": float(coll_bytes_hi / ICI_BW),
        "model_flops_6nd": float(model_flops_6nd),
        "useful_ratio_6nd": float(ratio),
        "temp_gib_cpu": rec.get("memory", {}).get("temp_bytes", 0) / 2**30,
        "note": rounds_note,
        "advice": advice,
    }


def _kv_cache_bytes(cfg, batch: int, cache_len: int, kv_bytes_per: int = 2) -> float:
    if cfg.family == "ssm":
        pairs = cfg.n_layers // 2
        dh = cfg.d_model // cfg.n_heads
        per = cfg.n_heads * (dh * dh + 2 * dh + 1) * 4  # mLSTM C,n,m f32
        per += cfg.n_heads * 4 * dh * 4  # sLSTM h,c,n,m
        return float(pairs * batch * per)
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_period
        mamba = (cfg.n_layers - n_attn) * batch * (
            cfg.d_inner * cfg.mamba_d_state * 4 + (cfg.mamba_d_conv - 1) * cfg.d_inner * 2
        )
    else:
        mamba = 0.0
    kv = n_attn * batch * cache_len * cfg.n_kv * cfg.hd * 2 * kv_bytes_per  # k+v
    return float(kv + mamba)


def load_artifacts(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def build_table(art_dir: str = "artifacts/dryrun", mesh: str = "single",
                include_variants: bool = False) -> list[dict]:
    from repro.configs import SHAPES

    rows = []
    for rec in load_artifacts(art_dir):
        if rec.get("mesh") != mesh:
            continue
        if not include_variants and rec.get("tag"):
            continue  # hillclimb variants are reported in §Perf, not the table
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec["status"],
                         "note": rec.get("reason", rec.get("error", ""))[:90]})
            continue
        rows.append(analytic_cell(rec["arch"], SHAPES[rec["shape"]], rec))
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | bound | "
           "roofline frac | 6ND ratio |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['bottleneck']} | "
            f"{r['roofline_fraction']:.2f} | {r['useful_ratio_6nd']:.2f} |"
        )
    return "\n".join(lines)


def main(fast: bool = True):
    rows = build_table()
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    with open("artifacts/roofline.md", "w") as f:
        f.write(to_markdown(rows) + "\n")
    out = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append((
            f"roofline_{r['arch']}_{r['shape']}",
            dom * 1e6,
            f"bound={r['bottleneck']}_frac={r['roofline_fraction']:.2f}",
        ))
    return out, rows


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
