"""Paper Fig. 5: sublinear per-transition scaling.

Synthetic 2-feature logistic regression; fixed (theta, theta') across dataset
sizes; measures (a) evaluated local sections per transition (empirical +
theoretical via the Korattikara Eq.-19-style walk), (b) wall time per
transition, against the O(N) exact baseline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChainEnsemble,
    RandomWalk,
    SubsampledMHConfig,
    expected_batches_theoretical,
    make_kernel,
    mh_step,
)
from repro.experiments import bayeslr


def run(sizes=(1000, 3000, 10_000, 30_000, 100_000), iters: int = 60,
        epsilon: float = 0.01, batch: int = 100, seed: int = 0,
        ensemble_chains: int = 8) -> list[dict]:
    rows = []
    theta = jnp.asarray([1.6, -1.6])  # near the posterior mode of w_true
    for n in sizes:
        data = bayeslr.synth_2d(jax.random.key(seed), n=n)
        target = bayeslr.make_target(data.x_train, data.y_train)
        # stream sampler: the pool is iid-generated (pre-permuted by
        # construction), so contiguous slices are exact without-replacement
        # draws with O(1) indexing — this is the TPU-native path (DESIGN §3)
        cfg = SubsampledMHConfig(batch_size=batch, epsilon=epsilon, sampler="stream")
        state0, step_fn = make_kernel(target, RandomWalk(0.1), cfg)
        jstep = jax.jit(step_fn)
        # warmup/compile
        th, st, info = jstep(jax.random.key(1), theta, state0)
        jax.block_until_ready(th)
        n_evals, times = [], []
        st = state0
        th = theta
        for i in range(iters):
            t0 = time.perf_counter()
            th2, st, info = jstep(jax.random.key(100 + i), th, st)
            jax.block_until_ready(th2)
            times.append(time.perf_counter() - t0)
            n_evals.append(int(info.n_evaluated))
            # keep theta fixed: per-iteration stats at a controlled point
        # exact baseline timing
        jexact = jax.jit(lambda k, t: mh_step(k, t, target, RandomWalk(0.1),
                                              chunk_size=min(n, 50_000)))
        t_ex, _ = jexact(jax.random.key(2), theta)
        jax.block_until_ready(t_ex)
        t0 = time.perf_counter()
        for i in range(5):
            out, _ = jexact(jax.random.key(200 + i), theta)
            jax.block_until_ready(out)
        exact_time = (time.perf_counter() - t0) / 5

        # theoretical expectation at this (theta, theta'): average the
        # Eq.-19-style walk over proposal and u draws
        rng = np.random.default_rng(0)
        theos = []
        for rep in range(20):
            th_p, _ = RandomWalk(0.1)(jax.random.key(300 + rep), theta)
            l = np.asarray(target.log_local(theta, th_p, jnp.arange(n, dtype=jnp.int32)))
            gl = float(target.log_global(theta, th_p))
            mu0 = (np.log(rng.uniform()) - gl) / n
            theos.append(expected_batches_theoretical(l, mu0, batch, epsilon))
        theo = float(np.mean(theos))
        # ensemble-amortized cost: K vmapped chains sharing one program —
        # the per-transition figure the multi-chain serving path actually pays
        ens = ChainEnsemble(target, RandomWalk(0.1), ensemble_chains, config=cfg)
        est = ens.init(theta)
        _, timed = ens.run_timed(jax.random.key(4), est, iters, block_every=iters)
        ens_us = 1e6 / timed["transitions_per_sec"]

        rows.append({
            "N": n,
            "mean_evaluated": float(np.mean(n_evals)),
            "theoretical_evaluated": theo,
            "subsampled_us": float(np.mean(times) * 1e6),
            "exact_us": float(exact_time * 1e6),
            "ensemble_chains": ensemble_chains,
            "ensemble_amortized_us": ens_us,
        })
    return rows


def main(fast: bool = True):
    sizes = (1000, 3000, 10_000, 30_000) if fast else (1000, 3000, 10_000, 30_000, 100_000, 300_000)
    rows = run(sizes=sizes, iters=30 if fast else 100)
    out = []
    for r in rows:
        frac = r["mean_evaluated"] / r["N"]
        out.append((f"fig5_subsampled_N{r['N']}", r["subsampled_us"],
                    f"evaluated={r['mean_evaluated']:.0f}({frac:.1%})_theo={r['theoretical_evaluated']:.0f}"))
        out.append((f"fig5_exact_N{r['N']}", r["exact_us"], f"evaluated={r['N']}"))
        out.append((f"fig5_ensembleK{r['ensemble_chains']}_N{r['N']}",
                    r["ensemble_amortized_us"], "amortized_per_transition"))
    return out, rows


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
