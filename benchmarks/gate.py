"""CI perf-regression gate over the machine-readable bench artifacts.

Diffs this run's ``BENCH_<name>.json`` files (multichain, serving, fleet,
roofline — see ``multichain_bench.bench_json_path``) against the previous
CI run's artifact directory and fails on any metric that regressed by more
than the threshold (default 15%, ``--threshold`` / ``$REPRO_GATE_THRESHOLD``):
req/s down, latency tails up, steady-state transition throughput down.

    python -m benchmarks.gate --previous prev-artifacts --current bench-artifacts

Records are matched run-over-run on their identifying fields (bench name +
``kind``/``engine``/shape fields); metrics are compared per direction —
``qps``/``tps_*`` must not drop, ``p95_ms``/``us_per_call`` must not rise.
A machine-readable verdict lands in ``<current>/GATE_verdict.json``; the
process exits nonzero iff any comparison regressed. A missing previous
artifact passes with ``status: "no_baseline"`` (first run, expired cache)
unless ``--fail-on-missing`` is set.

``--trend`` replaces the single-run diff with the historical store
(:class:`repro.obs.history.HistoryStore`): the baseline for each metric is
the **median of the last K runs** (``--trend-window``, robust to one noisy
CI host), and a second detector flags **monotone drift** — a metric that
worsened on every one of the last ``--trend-window`` runs and lost more
than the threshold cumulatively, even though no single step tripped the
gate. On a passing (or no-baseline) verdict the current artifacts are
appended to the store, so the history maintains itself run-over-run:

    python -m benchmarks.gate --trend --history bench-history \
        --current bench-artifacts
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

BENCHES = ("multichain", "serving", "fleet", "roofline", "subposterior")

# Metric -> direction. HIGHER: a drop beyond the threshold regresses.
# LOWER: a rise beyond the threshold regresses. Anything not listed is
# informational and never gates.
HIGHER, LOWER = "higher", "lower"
METRIC_DIRECTIONS = {
    "qps": HIGHER,
    "tps_e2e": HIGHER,
    "tps_steady": HIGHER,
    "transitions_per_sec": HIGHER,
    "tps_mesh_2d": HIGHER,
    "gflops": HIGHER,
    "achieved_frac_peak": HIGHER,
    "p50_ms": LOWER,
    "p95_ms": LOWER,
    "p99_ms": LOWER,
    "us_per_call": LOWER,
    "ratio": LOWER,  # delta-stream wire bytes vs full-snapshot bytes
}

# Fields that identify a record across runs (never compared as metrics).
ID_FIELDS = ("kind", "engine", "name", "kernel", "workload", "transport",
             "path", "backend", "shape", "N", "K", "steps", "replicas",
             "queries", "rows_per_query", "max_batch", "window", "mode",
             "P", "method", "precision")


def record_key(bench: str, rec: dict) -> str:
    parts = [bench] + [
        f"{f}={rec[f]}" for f in ID_FIELDS if rec.get(f) is not None
    ]
    return "/".join(parts)


def load_records(art_dir: str, bench: str) -> dict[str, dict] | None:
    """``{record_key: record}`` from one artifact file, or None when the
    file is absent (bench not run / first CI run)."""
    path = os.path.join(art_dir, f"BENCH_{bench}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    out: dict[str, dict] = {}
    for rec in payload.get("records", []):
        key = record_key(bench, rec)
        if key in out:  # duplicate id fields: keep first, flag neither
            continue
        out[key] = rec
    return out


def compare(prev: dict, cur: dict, key: str, threshold: float) -> list[dict]:
    """Per-metric comparisons for one matched record pair."""
    rows = []
    for metric, direction in METRIC_DIRECTIONS.items():
        p, c = prev.get(metric), cur.get(metric)
        if not isinstance(p, (int, float)) or not isinstance(c, (int, float)):
            continue
        if abs(p) < 1e-12:  # degenerate baseline: nothing meaningful to diff
            continue
        if direction == HIGHER:
            change = (p - c) / abs(p)  # fraction LOST
        else:
            change = (c - p) / abs(p)  # fraction GAINED (latency up = bad)
        rows.append({
            "record": key,
            "metric": metric,
            "direction": direction,
            "previous": p,
            "current": c,
            "regression": change,
            "regressed": change > threshold,
        })
    return rows


def run_gate(previous_dir: str, current_dir: str, *,
             threshold: float = 0.15,
             benches: tuple[str, ...] = BENCHES,
             fail_on_missing: bool = False) -> dict:
    """The full verdict dict (``status`` in pass/fail/no_baseline)."""
    comparisons: list[dict] = []
    missing: list[dict] = []
    seen_baseline = False
    for bench in benches:
        cur = load_records(current_dir, bench)
        prev = load_records(previous_dir, bench)
        if cur is None:
            missing.append({"bench": bench, "side": "current"})
            continue
        if prev is None:
            missing.append({"bench": bench, "side": "previous"})
            continue
        seen_baseline = True
        for key, cur_rec in cur.items():
            prev_rec = prev.get(key)
            if prev_rec is None:
                missing.append({"bench": bench, "side": "previous",
                                "record": key})
                continue
            comparisons.extend(compare(prev_rec, cur_rec, key, threshold))
    regressions = [c for c in comparisons if c["regressed"]]
    if regressions:
        status = "fail"
    elif not seen_baseline:
        status = "fail" if fail_on_missing else "no_baseline"
    else:
        status = "fail" if (fail_on_missing and missing) else "pass"
    return {
        "status": status,
        "threshold": threshold,
        "benches": list(benches),
        "checked": len(comparisons),
        "regressions": regressions,
        "missing": missing,
    }


# ---------------------------------------------------------------------------
# Historical trend gating (--trend, over repro.obs.history.HistoryStore)
# ---------------------------------------------------------------------------


def _metric_series(history_records: list[dict[str, dict] | None],
                   key: str, metric: str) -> list[float]:
    """The metric's value in each historical run that has the record
    (oldest first)."""
    series = []
    for recs in history_records:
        if recs is None:
            continue
        rec = recs.get(key)
        if rec is None:
            continue
        v = rec.get(metric)
        if isinstance(v, (int, float)):
            series.append(float(v))
    return series


def _drift_row(series: list[float], current: float, key: str, metric: str,
               direction: str, threshold: float, window: int) -> dict | None:
    """Monotone-drift detector: every step over the trailing window moved
    the wrong way AND the cumulative move exceeds the threshold. Needs at
    least 3 historical points (4 values with the current run) so two noisy
    runs can't fake a trend."""
    values = series[-window:] + [current]
    if len(values) < 4:
        return None
    worse = (lambda a, b: b < a) if direction == HIGHER else (lambda a, b: b > a)
    if not all(worse(a, b) for a, b in zip(values, values[1:])):
        return None
    first = values[0]
    if abs(first) < 1e-12:
        return None
    if direction == HIGHER:
        change = (first - current) / abs(first)
    else:
        change = (current - first) / abs(first)
    if change <= threshold:
        return None
    return {
        "record": key,
        "metric": metric,
        "direction": direction,
        "kind": "drift",
        "previous": first,
        "current": current,
        "steps": len(values) - 1,
        "regression": change,
        "regressed": True,
    }


def run_trend_gate(history_dir: str, current_dir: str, *,
                   threshold: float = 0.15,
                   benches: tuple[str, ...] = BENCHES,
                   window: int = 5,
                   fail_on_missing: bool = False) -> dict:
    """Gate the current artifacts against the run history.

    Per matched metric, two detectors:

    * **median baseline** — the single-run ``compare`` formula against the
      median of the last ``window`` runs' values (robust to one outlier
      baseline run, unlike the previous-run-only diff);
    * **monotone drift** — see :func:`_drift_row` (slow regressions that
      never individually trip the threshold).

    On pass / no_baseline the current run is appended to the store, so the
    history is self-maintaining. Returns the verdict dict (adds
    ``mode: "trend"``, ``history_runs``, ``appended_run``).
    """
    from repro.obs.history import HistoryStore

    store = HistoryStore(history_dir)
    run_dirs = [store.run_dir(r["id"]) for r in store.last(window)]
    comparisons: list[dict] = []
    missing: list[dict] = []
    seen_baseline = False
    for bench in benches:
        cur = load_records(current_dir, bench)
        if cur is None:
            missing.append({"bench": bench, "side": "current"})
            continue
        history_records = [load_records(d, bench) for d in run_dirs]
        if not any(r is not None for r in history_records):
            missing.append({"bench": bench, "side": "history"})
            continue
        seen_baseline = True
        for key, cur_rec in cur.items():
            matched = False
            for metric, direction in METRIC_DIRECTIONS.items():
                c = cur_rec.get(metric)
                if not isinstance(c, (int, float)):
                    continue
                series = _metric_series(history_records, key, metric)
                if not series:
                    continue
                matched = True
                baseline = statistics.median(series)
                rows = compare({metric: baseline}, {metric: c}, key, threshold)
                for row in rows:
                    row["baseline_runs"] = len(series)
                comparisons.extend(rows)
                drift = _drift_row(series, float(c), key, metric,
                                   direction, threshold, window)
                if drift is not None:
                    comparisons.append(drift)
            if not matched:
                missing.append({"bench": bench, "side": "history",
                                "record": key})
    regressions = [c for c in comparisons if c["regressed"]]
    if regressions:
        status = "fail"
    elif not seen_baseline:
        status = "fail" if fail_on_missing else "no_baseline"
    else:
        status = "fail" if (fail_on_missing and missing) else "pass"
    verdict = {
        "status": status,
        "mode": "trend",
        "threshold": threshold,
        "window": window,
        "history_runs": len(store),
        "benches": list(benches),
        "checked": len(comparisons),
        "regressions": regressions,
        "missing": missing,
        "appended_run": None,
    }
    return verdict


def _append_history(history_dir: str, current_dir: str) -> str | None:
    """Fold the current artifacts into the store (post-verdict); a current
    dir with no BENCH artifacts appends nothing."""
    from repro.obs.history import HistoryStore

    try:
        return HistoryStore(history_dir).append(current_dir)
    except FileNotFoundError:
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--previous", default=None,
                    help="previous run's bench artifact directory "
                         "(single-run diff mode)")
    ap.add_argument("--current", default=os.environ.get("REPRO_BENCH_DIR", "."),
                    help="this run's bench artifact directory "
                         "(default: $REPRO_BENCH_DIR, else cwd)")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("REPRO_GATE_THRESHOLD", 0.15)),
                    help="regression fraction that fails the gate "
                         "(default 0.15 = 15%%)")
    ap.add_argument("--benches", default=",".join(BENCHES),
                    help="comma list of bench artifacts to diff")
    ap.add_argument("--out", default=None,
                    help="verdict JSON path (default <current>/GATE_verdict.json)")
    ap.add_argument("--fail-on-missing", action="store_true",
                    help="also fail when a baseline artifact or record is "
                         "absent (default: pass with status no_baseline)")
    ap.add_argument("--trend", action="store_true",
                    help="gate against the run-history store instead of a "
                         "single previous run: median-of-last-K baseline + "
                         "monotone-drift detection; appends this run to the "
                         "store on pass")
    ap.add_argument("--history", default="bench-history",
                    help="HistoryStore root for --trend (default "
                         "bench-history; CI backs it with actions/cache)")
    ap.add_argument("--trend-window", type=int, default=5,
                    help="K: history runs in the median baseline / drift "
                         "window (default 5)")
    args = ap.parse_args(argv)

    benches = tuple(b for b in args.benches.split(",") if b)
    if args.trend:
        verdict = run_trend_gate(
            args.history, args.current,
            threshold=args.threshold,
            benches=benches,
            window=args.trend_window,
            fail_on_missing=args.fail_on_missing,
        )
    else:
        if args.previous is None:
            ap.error("--previous is required without --trend")
        verdict = run_gate(
            args.previous, args.current,
            threshold=args.threshold,
            benches=benches,
            fail_on_missing=args.fail_on_missing,
        )
    out = args.out or os.path.join(args.current, "GATE_verdict.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(verdict, f, indent=1)
    if args.trend and verdict["status"] in ("pass", "no_baseline"):
        # The verdict is written first so the stored run carries its own
        # GATE_verdict.json; a failing run is NOT appended (a regressed
        # run must not drag the median baseline down with it).
        verdict["appended_run"] = _append_history(args.history, args.current)
        with open(out, "w") as f:
            json.dump(verdict, f, indent=1)

    worst = sorted(verdict["regressions"],
                   key=lambda c: -c["regression"])[:10]
    for c in worst:
        kind = " (monotone drift)" if c.get("kind") == "drift" else ""
        print(f"GATE REGRESSION {c['record']} {c['metric']}: "
              f"{c['previous']:.4g} -> {c['current']:.4g} "
              f"({c['regression']:+.1%}, {c['direction']}-is-better){kind}")
    for m in verdict["missing"][:10]:
        print(f"gate: missing {m['side']} "
              f"{m.get('record', 'artifact for ' + m['bench'])}")
    trend_info = ""
    if args.trend:
        trend_info = (f" mode=trend history_runs={verdict['history_runs']} "
                      f"window={verdict['window']} "
                      f"appended={verdict['appended_run']}")
    print(f"GATE_{verdict['status'].upper()} checked={verdict['checked']} "
          f"regressions={len(verdict['regressions'])} "
          f"threshold={verdict['threshold']:.0%} verdict={out}{trend_info}")
    return 1 if verdict["status"] == "fail" else 0


if __name__ == "__main__":
    sys.exit(main())
