"""CI perf-regression gate over the machine-readable bench artifacts.

Diffs this run's ``BENCH_<name>.json`` files (multichain, serving, fleet,
roofline — see ``multichain_bench.bench_json_path``) against the previous
CI run's artifact directory and fails on any metric that regressed by more
than the threshold (default 15%, ``--threshold`` / ``$REPRO_GATE_THRESHOLD``):
req/s down, latency tails up, steady-state transition throughput down.

    python -m benchmarks.gate --previous prev-artifacts --current bench-artifacts

Records are matched run-over-run on their identifying fields (bench name +
``kind``/``engine``/shape fields); metrics are compared per direction —
``qps``/``tps_*`` must not drop, ``p95_ms``/``us_per_call`` must not rise.
A machine-readable verdict lands in ``<current>/GATE_verdict.json``; the
process exits nonzero iff any comparison regressed. A missing previous
artifact passes with ``status: "no_baseline"`` (first run, expired cache)
unless ``--fail-on-missing`` is set.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BENCHES = ("multichain", "serving", "fleet", "roofline", "subposterior")

# Metric -> direction. HIGHER: a drop beyond the threshold regresses.
# LOWER: a rise beyond the threshold regresses. Anything not listed is
# informational and never gates.
HIGHER, LOWER = "higher", "lower"
METRIC_DIRECTIONS = {
    "qps": HIGHER,
    "tps_e2e": HIGHER,
    "tps_steady": HIGHER,
    "transitions_per_sec": HIGHER,
    "tps_mesh_2d": HIGHER,
    "gflops": HIGHER,
    "achieved_frac_peak": HIGHER,
    "p50_ms": LOWER,
    "p95_ms": LOWER,
    "p99_ms": LOWER,
    "us_per_call": LOWER,
    "ratio": LOWER,  # delta-stream wire bytes vs full-snapshot bytes
}

# Fields that identify a record across runs (never compared as metrics).
ID_FIELDS = ("kind", "engine", "name", "kernel", "workload", "transport",
             "path", "backend", "shape", "N", "K", "steps", "replicas",
             "queries", "rows_per_query", "max_batch", "window", "mode",
             "P", "method", "precision")


def record_key(bench: str, rec: dict) -> str:
    parts = [bench] + [
        f"{f}={rec[f]}" for f in ID_FIELDS if rec.get(f) is not None
    ]
    return "/".join(parts)


def load_records(art_dir: str, bench: str) -> dict[str, dict] | None:
    """``{record_key: record}`` from one artifact file, or None when the
    file is absent (bench not run / first CI run)."""
    path = os.path.join(art_dir, f"BENCH_{bench}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        payload = json.load(f)
    out: dict[str, dict] = {}
    for rec in payload.get("records", []):
        key = record_key(bench, rec)
        if key in out:  # duplicate id fields: keep first, flag neither
            continue
        out[key] = rec
    return out


def compare(prev: dict, cur: dict, key: str, threshold: float) -> list[dict]:
    """Per-metric comparisons for one matched record pair."""
    rows = []
    for metric, direction in METRIC_DIRECTIONS.items():
        p, c = prev.get(metric), cur.get(metric)
        if not isinstance(p, (int, float)) or not isinstance(c, (int, float)):
            continue
        if abs(p) < 1e-12:  # degenerate baseline: nothing meaningful to diff
            continue
        if direction == HIGHER:
            change = (p - c) / abs(p)  # fraction LOST
        else:
            change = (c - p) / abs(p)  # fraction GAINED (latency up = bad)
        rows.append({
            "record": key,
            "metric": metric,
            "direction": direction,
            "previous": p,
            "current": c,
            "regression": change,
            "regressed": change > threshold,
        })
    return rows


def run_gate(previous_dir: str, current_dir: str, *,
             threshold: float = 0.15,
             benches: tuple[str, ...] = BENCHES,
             fail_on_missing: bool = False) -> dict:
    """The full verdict dict (``status`` in pass/fail/no_baseline)."""
    comparisons: list[dict] = []
    missing: list[dict] = []
    seen_baseline = False
    for bench in benches:
        cur = load_records(current_dir, bench)
        prev = load_records(previous_dir, bench)
        if cur is None:
            missing.append({"bench": bench, "side": "current"})
            continue
        if prev is None:
            missing.append({"bench": bench, "side": "previous"})
            continue
        seen_baseline = True
        for key, cur_rec in cur.items():
            prev_rec = prev.get(key)
            if prev_rec is None:
                missing.append({"bench": bench, "side": "previous",
                                "record": key})
                continue
            comparisons.extend(compare(prev_rec, cur_rec, key, threshold))
    regressions = [c for c in comparisons if c["regressed"]]
    if regressions:
        status = "fail"
    elif not seen_baseline:
        status = "fail" if fail_on_missing else "no_baseline"
    else:
        status = "fail" if (fail_on_missing and missing) else "pass"
    return {
        "status": status,
        "threshold": threshold,
        "benches": list(benches),
        "checked": len(comparisons),
        "regressions": regressions,
        "missing": missing,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--previous", required=True,
                    help="previous run's bench artifact directory")
    ap.add_argument("--current", default=os.environ.get("REPRO_BENCH_DIR", "."),
                    help="this run's bench artifact directory "
                         "(default: $REPRO_BENCH_DIR, else cwd)")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("REPRO_GATE_THRESHOLD", 0.15)),
                    help="regression fraction that fails the gate "
                         "(default 0.15 = 15%%)")
    ap.add_argument("--benches", default=",".join(BENCHES),
                    help="comma list of bench artifacts to diff")
    ap.add_argument("--out", default=None,
                    help="verdict JSON path (default <current>/GATE_verdict.json)")
    ap.add_argument("--fail-on-missing", action="store_true",
                    help="also fail when a baseline artifact or record is "
                         "absent (default: pass with status no_baseline)")
    args = ap.parse_args(argv)

    verdict = run_gate(
        args.previous, args.current,
        threshold=args.threshold,
        benches=tuple(b for b in args.benches.split(",") if b),
        fail_on_missing=args.fail_on_missing,
    )
    out = args.out or os.path.join(args.current, "GATE_verdict.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(verdict, f, indent=1)

    worst = sorted(verdict["regressions"],
                   key=lambda c: -c["regression"])[:10]
    for c in worst:
        print(f"GATE REGRESSION {c['record']} {c['metric']}: "
              f"{c['previous']:.4g} -> {c['current']:.4g} "
              f"({c['regression']:+.1%}, {c['direction']}-is-better)")
    for m in verdict["missing"][:10]:
        print(f"gate: missing {m['side']} "
              f"{m.get('record', 'artifact for ' + m['bench'])}")
    print(f"GATE_{verdict['status'].upper()} checked={verdict['checked']} "
          f"regressions={len(verdict['regressions'])} "
          f"threshold={verdict['threshold']:.0%} verdict={out}")
    return 1 if verdict["status"] == "fail" else 0


if __name__ == "__main__":
    sys.exit(main())
