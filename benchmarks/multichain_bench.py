"""Multi-chain throughput: sequential chains vs the ensemble engines, for
all three paper workloads.

The number that matters for the ROADMAP north star is aggregate
transitions/sec across an ensemble. The BayesLR section runs K subsampled-MH
chains on the Fig-5 target four ways:

  sequential — K independent ``run_chain_timed`` host loops (one jitted
               step, python dispatch per transition: the pre-ensemble idiom),
  lockstep   — one ``ChainEnsemble.run`` program, chains advance in
               lock-step (the batched while_loop runs every sequential-test
               round until the SLOWEST chain's test stops: per-transition
               row cost is max_k rounds_k),
  masked     — the masked-continuation superstep: a chain whose test stops
               early commits its transition and starts the next proposal
               inside the same compiled loop, so total row count drops from
               sum_t max_k rounds to max_k sum_t rounds,
  adaptive   — masked + the per-chain controller of ``repro.core.schedule``
               tuning batch-size buckets and epsilon from each chain's
               trailing rounds / n_evaluated stream.

Per engine we report end-to-end (including one-time compiles — what a cold
posterior query costs) and steady-state (compile-excluded) transitions/sec,
plus a tail-latency histogram of per-transition sequential-test rounds —
the lock-step row pays the tail's max, the masked modes only its mean.

The ``stochvol-sig/phi`` and ``jointdpm-w`` sections run the other two
paper workloads' full composite cycles (particle Gibbs + per-variable
subsampled MH; alpha-MH + Gibbs-z + dynamic-pool w-moves) as K-chain
ensembles vs K sequential single-chain scans, at K in {4, 16} — the
K-scaling acceptance row for the composite engine.

Reproduction guide and reference CPU numbers: docs/BENCHMARKS.md.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ChainEnsemble,
    RandomWalk,
    ScheduleConfig,
    SubsampledMHConfig,
    run_chain_timed,
    tail_latency_summary,
)
from repro.experiments import bayeslr

ENGINES = ("lockstep", "masked", "adaptive")


def _ensemble(target, prop, cfg, num_chains: int, engine: str) -> ChainEnsemble:
    kw = {}
    if engine == "masked":
        kw = dict(stepping="masked")
    elif engine == "adaptive":
        kw = dict(stepping="masked", schedule=ScheduleConfig())
    return ChainEnsemble(target, prop, num_chains, config=cfg, **kw)


def run(n: int = 5000, num_chains: int = 16, steps: int = 100,
        batch: int = 100, epsilon: float = 0.05, seed: int = 0,
        sequential_baseline: bool = True) -> dict:
    data = bayeslr.synth_2d(jax.random.key(seed), n=n)
    target = bayeslr.make_target(data.x_train, data.y_train)
    prop = RandomWalk(0.1)
    cfg = SubsampledMHConfig(batch_size=batch, epsilon=epsilon, sampler="stream")
    theta0 = jnp.zeros(2)
    keys = jax.random.split(jax.random.key(seed + 1), num_chains)
    out = {"N": n, "K": num_chains, "steps": steps}

    # --- sequential baseline: K host-driven chains ------------------------
    if sequential_baseline:
        t0 = time.perf_counter()
        seq_samples, seq_sample_secs = [], 0.0
        for k in range(num_chains):
            o = run_chain_timed(keys[k], theta0, target, prop, steps,
                                kernel="subsampled", config=cfg)
            seq_samples.append(np.asarray(o["samples"]))
            seq_sample_secs += float(o["times"][-1])  # compile-excluded
        seq_wall = time.perf_counter() - t0
        out["sequential_tps_e2e"] = num_chains * steps / seq_wall
        out["sequential_tps_steady"] = num_chains * steps / max(seq_sample_secs, 1e-12)
        out["seq_samples"] = np.stack(seq_samples)

    # --- the three ensemble engines --------------------------------------
    for engine in ENGINES:
        ens = _ensemble(target, prop, cfg, num_chains, engine)
        # Cold pass: exactly compile + one run, matching what the sequential
        # side pays per chain (run_timed's internal warm-up would double-count
        # sampling work in an end-to-end window).
        t0 = time.perf_counter()
        state = ens.init(theta0)
        state, _, _ = ens.run(keys, state, steps)
        jax.block_until_ready(state.theta)
        out[f"{engine}_tps_e2e"] = num_chains * steps / (time.perf_counter() - t0)
        # Steady state: program warm, run_timed's warm-up is a cache hit.
        state, timed = ens.run_timed(keys, state, steps, block_every=steps)
        out[f"{engine}_tps_steady"] = timed["transitions_per_sec"]
        out[f"{engine}_rounds_tail"] = tail_latency_summary(timed["infos"].rounds)
        out[f"{engine}_mean_n_evaluated"] = float(
            np.asarray(timed["infos"].n_evaluated, np.float64).mean()
        )
        if engine == "lockstep":
            out["ensemble_samples"] = timed["samples"]
    for engine in ("masked", "adaptive"):
        out[f"{engine}_vs_lockstep_steady"] = (
            out[f"{engine}_tps_steady"] / out["lockstep_tps_steady"]
        )
    return out


def _bench_cycle(cyc, theta0, num_chains: int, steps: int, seed: int, collect):
    """Steady-state throughput of a composite cycle: K sequential single-chain
    scans (one shared compile, per-chain dispatch) vs one composite
    ChainEnsemble program. Compile time excluded on both sides."""
    from repro.core import ChainEnsemble
    from repro.core.composite import run_cycle_sequential

    keys = jax.random.split(jax.random.key(seed), num_chains)
    seq = jax.jit(lambda k: run_cycle_sequential(k, theta0, cyc, steps, collect)[1])
    jax.block_until_ready(seq(keys[0]))  # compile
    t0 = time.perf_counter()
    for c in range(num_chains):
        jax.block_until_ready(seq(keys[c]))
    seq_wall = time.perf_counter() - t0

    ens = ChainEnsemble(num_chains=num_chains, transition=cyc, collect=collect)
    state = ens.init(theta0)
    warm, _, _ = ens.run(keys, state, steps)  # compile
    jax.block_until_ready(warm.theta)
    t0 = time.perf_counter()
    state, _, _ = ens.run(keys, state, steps)
    jax.block_until_ready(state.theta)
    ens_wall = time.perf_counter() - t0

    total = num_chains * steps
    return {
        "sequential_tps_steady": total / max(seq_wall, 1e-12),
        "ensemble_tps_steady": total / max(ens_wall, 1e-12),
        "ensemble_vs_sequential_steady": seq_wall / max(ens_wall, 1e-12),
        "ensemble_us_per_transition": 1e6 * ens_wall / total,
    }


def run_stochvol(num_chains: int, steps: int = 40, series: int = 100,
                 length: int = 5, seed: int = 0) -> dict:
    """The Sec-4.3 cycle (pgibbs + subsampled-MH sig/phi) at ensemble scale."""
    from repro.experiments import stochvol

    data = stochvol.synth(jax.random.key(seed), num_series=series, length=length)
    cyc = stochvol.make_inference_cycle(data.obs, batch_size=100, epsilon=0.05,
                                        num_particles=15)
    out = _bench_cycle(cyc, stochvol.init_theta(data.obs), num_chains, steps,
                       seed + 1, lambda th: th["phi"])
    out.update(N=series * length, K=num_chains, steps=steps)
    return out


def run_jointdpm(num_chains: int, cycles: int = 5, n: int = 1000,
                 w_moves: int = 5, seed: int = 0) -> dict:
    """The Sec-4.2 cycle (alpha-MH + Gibbs-z + dynamic-pool subsampled-MH w)
    over K replicas. Transitions counted as w-moves (the austerity kernel)."""
    from repro.experiments import jointdpm

    cfg = jointdpm.JDPMConfig()
    data = jointdpm.synth(jax.random.key(seed), n=n, n_test=10)
    cyc = jointdpm.make_inference_cycle(data, cfg, batch_size=100, epsilon=0.3,
                                        w_moves=w_moves, gibbs_frac=0.25)
    state0 = jointdpm.init_state(jax.random.key(seed + 1), data, cfg)
    out = _bench_cycle(cyc, state0, num_chains, cycles, seed + 2,
                       lambda s: s.alpha)
    # report per w-move (the subsampled kernel the paper scales)
    scale = 1.0 / w_moves
    out["ensemble_us_per_transition"] *= scale
    out["sequential_tps_steady"] /= scale
    out["ensemble_tps_steady"] /= scale
    out.update(N=n, K=num_chains, steps=cycles * w_moves)
    return out


WORKLOADS = {"stochvol": run_stochvol, "jointdpm": run_jointdpm}


def bench_json_path(name: str) -> str:
    """Where the machine-readable result lands (`BENCH_<name>.json` under
    ``$REPRO_BENCH_DIR`` or the working directory); CI uploads these as
    artifacts so the perf trajectory is tracked across PRs."""
    return os.path.join(os.environ.get("REPRO_BENCH_DIR", os.getcwd()),
                        f"BENCH_{name}.json")


def _write_multichain_json(raws, workload_raws) -> str:
    records = []
    for r in raws:
        for engine in ("sequential",) + ENGINES:
            if f"{engine}_tps_steady" not in r and engine == "sequential":
                continue
            rec = {
                "engine": engine,
                "N": r["N"],
                "K": r["K"],
                "steps": r["steps"],
                "tps_e2e": r.get(f"{engine}_tps_e2e"),
                "tps_steady": r.get(f"{engine}_tps_steady"),
            }
            tail = r.get(f"{engine}_rounds_tail")
            if tail is not None:
                rec["rounds_tail"] = {
                    k: (v.tolist() if isinstance(v, np.ndarray) else v)
                    for k, v in tail.items()
                }
            records.append(rec)
    for name, w in workload_raws:
        records.append({
            "engine": f"composite_{name}",
            "N": w["N"],
            "K": w["K"],
            "steps": w["steps"],
            "tps_steady": w["ensemble_tps_steady"],
            "sequential_tps_steady": w["sequential_tps_steady"],
            "ensemble_vs_sequential_steady": w["ensemble_vs_sequential_steady"],
        })
    path = bench_json_path("multichain")
    with open(path, "w") as f:
        json.dump({"bench": "multichain", "records": records}, f, indent=1)
    return path


def main(fast: bool = True):
    if fast:
        configs, steps = [(5000, 4), (5000, 16)], 100
        workload_ks = (4, 16)
    else:
        configs, steps = [(50_000, 4), (50_000, 16), (50_000, 64)], 400
        workload_ks = (4, 16)
    rows, raws = [], []
    for n, k in configs:
        r = run(n=n, num_chains=k, steps=steps)
        raws.append(r)
        rows.append((
            f"multichain_seq_N{n}_K{k}",
            1e6 / r["sequential_tps_e2e"],
            f"tps_e2e={r['sequential_tps_e2e']:.0f}_steady={r['sequential_tps_steady']:.0f}",
        ))
        for engine in ENGINES:
            tail = r[f"{engine}_rounds_tail"]
            extra = ""
            if engine != "lockstep":
                extra = f"_vs_lockstep={r[f'{engine}_vs_lockstep_steady']:.1f}x"
            rows.append((
                f"multichain_{engine}_N{n}_K{k}",
                1e6 / r[f"{engine}_tps_e2e"],
                f"tps_e2e={r[f'{engine}_tps_e2e']:.0f}"
                f"_steady={r[f'{engine}_tps_steady']:.0f}"
                f"_rounds_p50={tail['p50']:.0f}_p99={tail['p99']:.0f}_max={tail['max']:.0f}"
                + extra,
            ))
    workload_raws = []
    for wl_name, wl_fn in WORKLOADS.items():
        for k in workload_ks:
            w = wl_fn(k)
            workload_raws.append((wl_name, w))
            rows.append((
                f"multichain_{wl_name}_N{w['N']}_K{w['K']}",
                w["ensemble_us_per_transition"],
                f"seq_steady={w['sequential_tps_steady']:.0f}"
                f"_ens_steady={w['ensemble_tps_steady']:.0f}"
                f"_ens_vs_seq={w['ensemble_vs_sequential_steady']:.1f}x",
            ))
    path = _write_multichain_json(raws, workload_raws)
    rows.append((f"multichain_json:{path}", 0.0, "machine-readable output"))
    return rows, raws


def print_tail_histograms(raws) -> None:
    """ASCII tail-latency histograms of per-transition rounds per engine."""
    for r in raws:
        print(f"\nN={r['N']} K={r['K']}: per-transition sequential-test rounds")
        for engine in ENGINES:
            t = r[f"{engine}_rounds_tail"]
            print(f"  {engine:9s} mean={t['mean']:.2f} p50={t['p50']:.0f} "
                  f"p90={t['p90']:.0f} p99={t['p99']:.0f} max={t['max']:.0f}")
            total = max(int(t["hist"].sum()), 1)
            for e, h in zip(t["edges"], t["hist"]):
                if h:
                    bar = "#" * max(1, int(40 * h / total))
                    print(f"    {int(e):4d} rounds | {bar} {h}")


if __name__ == "__main__":
    rows, raws = main()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print_tail_histograms(raws)
