"""Multi-chain throughput: vmapped ensemble vs sequential single chains.

The number that matters for the ROADMAP north star is aggregate
transitions/sec across an ensemble. This bench runs K subsampled-MH chains
on the Fig-5 BayesLR target two ways:

  sequential — K independent ``run_chain_timed`` host loops (one jitted
               step, python dispatch per transition: the pre-ensemble idiom),
  ensemble   — one ``ChainEnsemble.run`` program (vmapped step inside one
               scan: one dispatch for the whole K x T block).

Two numbers per side, because they answer different questions:

  end-to-end     — total wall clock including one-time jit compiles. The
                   sequential idiom pays K compiles (run_chain_timed jits a
                   fresh closure per chain); the ensemble pays one. This is
                   what a cold posterior query actually costs.
  steady-state   — compile-excluded sampling throughput (run_chain_timed's
                   own times[-1] for the baseline, warm run_timed for the
                   ensemble). This is the long-chain amortized rate.

On this CPU at K=16 the ensemble wins ~4x end-to-end and ~1.6-2x steady
state (XLA's CPU backend extracts limited parallelism from the chain axis,
and the lock-step vmap runs every round until the slowest chain's test
stops); on accelerators the gap widens (per-step host dispatch is constant,
the batched (K, m) work parallelizes). See ROADMAP "async/adaptive chain
scheduling" for the lock-step follow-on.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig, run_chain_timed
from repro.experiments import bayeslr


def run(n: int = 5000, num_chains: int = 16, steps: int = 100,
        batch: int = 100, epsilon: float = 0.05, seed: int = 0) -> dict:
    data = bayeslr.synth_2d(jax.random.key(seed), n=n)
    target = bayeslr.make_target(data.x_train, data.y_train)
    prop = RandomWalk(0.1)
    cfg = SubsampledMHConfig(batch_size=batch, epsilon=epsilon, sampler="stream")
    theta0 = jnp.zeros(2)
    keys = jax.random.split(jax.random.key(seed + 1), num_chains)

    # --- sequential baseline: K host-driven chains ------------------------
    t0 = time.perf_counter()
    seq_samples, seq_sample_secs = [], 0.0
    for k in range(num_chains):
        out = run_chain_timed(keys[k], theta0, target, prop, steps,
                              kernel="subsampled", config=cfg)
        seq_samples.append(np.asarray(out["samples"]))
        seq_sample_secs += float(out["times"][-1])  # compile-excluded
    seq_wall = time.perf_counter() - t0
    seq_tps_e2e = num_chains * steps / seq_wall
    seq_tps_steady = num_chains * steps / max(seq_sample_secs, 1e-12)

    # --- vmapped ensemble --------------------------------------------------
    # Cold pass first: exactly compile + one run, matching what the sequential
    # side pays per chain (run_timed's internal warm-up would double-count
    # sampling work in an end-to-end window).
    ens = ChainEnsemble(target, prop, num_chains, config=cfg)
    t0 = time.perf_counter()
    state = ens.init(theta0)
    state, _, _ = ens.run(keys, state, steps)
    jax.block_until_ready(state.theta)
    ens_wall = time.perf_counter() - t0
    ens_tps_e2e = num_chains * steps / ens_wall
    # Steady state: the program is warm now, run_timed's warm-up is a cache hit.
    state, timed = ens.run_timed(keys, state, steps, block_every=steps)
    ens_tps_steady = timed["transitions_per_sec"]

    return {
        "N": n,
        "K": num_chains,
        "steps": steps,
        "sequential_tps_e2e": seq_tps_e2e,
        "sequential_tps_steady": seq_tps_steady,
        "ensemble_tps_e2e": ens_tps_e2e,
        "ensemble_tps_steady": ens_tps_steady,
        "speedup_e2e": ens_tps_e2e / seq_tps_e2e,
        "speedup_steady": ens_tps_steady / seq_tps_steady,
        "ensemble_samples": timed["samples"],
        "seq_samples": np.stack(seq_samples),
    }


def main(fast: bool = True):
    configs = [(5000, 4), (5000, 16)] if fast else [(50_000, 4), (50_000, 16), (50_000, 64)]
    steps = 100 if fast else 400
    rows, raws = [], []
    for n, k in configs:
        r = run(n=n, num_chains=k, steps=steps)
        raws.append(r)
        rows.append((
            f"multichain_seq_N{n}_K{k}",
            1e6 / r["sequential_tps_e2e"],
            f"tps_e2e={r['sequential_tps_e2e']:.0f}_steady={r['sequential_tps_steady']:.0f}",
        ))
        rows.append((
            f"multichain_ens_N{n}_K{k}",
            1e6 / r["ensemble_tps_e2e"],
            f"tps_e2e={r['ensemble_tps_e2e']:.0f}_steady={r['ensemble_tps_steady']:.0f}"
            f"_speedup_e2e={r['speedup_e2e']:.1f}x_steady={r['speedup_steady']:.1f}x",
        ))
    return rows, raws


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
