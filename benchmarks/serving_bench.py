"""Serving-layer benchmark: request latency tails and refresh throughput.

What the ROADMAP north star ("serve heavy traffic") is made of, measured on
the real serving stack (``repro.serving``): a resident BayesLR ensemble
kept warm by its refresh loop, and request classes served through the
batching queue. Reported per batching level:

  * p50/p95/p99 request latency and requests/sec — the queue coalesces up
    to ``max_batch`` requests into one posterior-functional evaluation, so
    tail latency vs throughput is exactly the batching trade;
  * steady-state refresh throughput (transitions/sec) of the resident
    ensemble — what bounds snapshot staleness under continuous refresh.

Writes ``BENCH_serving.json`` (machine-readable; see ``bench_json_path``)
next to ``BENCH_multichain.json`` so CI tracks the serving perf trajectory
across PRs. Reproduction guide: docs/BENCHMARKS.md.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import slo_summary

from .multichain_bench import bench_json_path


def _build_pool(num_chains: int, refresh_steps: int, window: int, smoke: bool):
    from repro.serving import EnsemblePool, FreshnessPolicy, ServingConfig

    config = ServingConfig(
        num_chains=num_chains,
        refresh_steps=refresh_steps,
        window=window,
        micro_batch=64,
        freshness=FreshnessPolicy(
            max_staleness_s=1e9,  # staleness is not the measured variable
            min_draws=num_chains * window // 2,
        ),
        seed=0,
    )
    pool = EnsemblePool(config)
    pool.add_workload("bayeslr", smoke=smoke)
    pool.warm()
    # compile the evaluator outside every measured window
    wl = pool.workload("bayeslr")
    spec = wl.query_specs["predictive"]
    pool.query("bayeslr", "predictive", spec.make_queries(jax.random.key(0), 8))
    return pool, wl


def bench_queries(pool, wl, max_batch: int, num_queries: int, rows: int) -> dict:
    from repro.serving import RequestQueue

    queue = RequestQueue(pool, max_batch=max_batch, default_deadline_s=1.0)
    spec = wl.query_specs["predictive"]
    key = jax.random.key(1)
    t0 = time.perf_counter()
    for i in range(0, num_queries, max_batch):
        for _ in range(min(max_batch, num_queries - i)):
            key, sub = jax.random.split(key)
            queue.submit("bayeslr", "predictive", spec.make_queries(sub, rows))
        queue.drain()
    wall = time.perf_counter() - t0
    done = queue.completed
    out = slo_summary([r.latency_s for r in done],
                      deadlines_s=[r.deadline_s for r in done])
    out["qps"] = len(done) / max(wall, 1e-12)
    out["max_batch"] = max_batch
    out["rows_per_query"] = rows
    return out


def bench_refresh(pool, steps: int) -> dict:
    resident = pool.resident("bayeslr")
    ens = resident.ensemble
    state, timed = ens.run_timed(
        jax.random.key(2), resident.state, steps, block_every=steps,
        start_step=resident.steps_done,
    )
    return {
        "transitions_per_sec": timed["transitions_per_sec"],
        "K": ens.num_chains,
        "steps": steps,
    }


def main(fast: bool = True):
    if fast:
        num_chains, refresh_steps, window = 4, 16, 32
        num_queries, rows, refresh_bench_steps = 120, 8, 100
        batches = (1, 8, 32)
    else:
        num_chains, refresh_steps, window = 16, 64, 128
        num_queries, rows, refresh_bench_steps = 600, 16, 400
        batches = (1, 8, 32, 128)
    pool, wl = _build_pool(num_chains, refresh_steps, window, smoke=fast)

    rows_out, records = [], []
    refresh = bench_refresh(pool, refresh_bench_steps)
    records.append({"kind": "refresh", **refresh})
    rows_out.append((
        f"serving_refresh_K{refresh['K']}",
        1e6 / refresh["transitions_per_sec"],
        f"steady_tps={refresh['transitions_per_sec']:.0f}",
    ))
    for max_batch in batches:
        r = bench_queries(pool, wl, max_batch, num_queries, rows)
        records.append({"kind": "queries", "K": num_chains, **r})
        rows_out.append((
            f"serving_query_b{max_batch}",
            1e3 * r["p50_ms"],
            f"p50_ms={r['p50_ms']:.2f}_p95_ms={r['p95_ms']:.2f}"
            f"_p99_ms={r['p99_ms']:.2f}_qps={r['qps']:.0f}",
        ))
    path = bench_json_path("serving")
    with open(path, "w") as f:
        json.dump({"bench": "serving", "records": records}, f, indent=1)
    rows_out.append((f"serving_json:{path}", 0.0, "machine-readable output"))
    return rows_out, records


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
