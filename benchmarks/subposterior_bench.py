"""Subposterior MCMC benchmark: per-partition throughput + combination error.

Measures the data-parallel subposterior pipeline (:mod:`repro.partition`)
on the conjugate Gaussian-mean model — the one workload with a closed-form
posterior, so combination *accuracy* is a measurable alongside throughput:

  * **per-partition throughput** — steady-state transitions/s of one
    partition's subsampled-MH chain ensemble at P in {1, 2, 4}. Each
    partition holds N/P observations under the ``p(theta)^(1/P)`` tempered
    prior; aggregate fleet throughput is P x the per-partition figure
    (partitions are independent writers).
  * **combination error** — distance between the recombined draws and the
    exact conjugate posterior ``N(n xbar/(n+1), I/(n+1))``, for both rules
    (consensus weighted averaging and Gaussian density-product):
    ``err_mean_sigma`` = mean error in posterior-std units,
    ``err_cov_rel`` = worst relative error of the covariance diagonal.
    These are informational for the perf gate (only ``tps_steady`` gates)
    but tracked run-over-run in ``BENCH_subposterior.json``. At P=1 both
    rules are the identity on the single partition's draws, so one
    ``method="passthrough"`` record stands in for the redundant pair.

Reproduction guide: docs/BENCHMARKS.md. Statistical correctness bars live
in ``tests/test_subposterior.py`` (this bench reuses its model shape).
"""
from __future__ import annotations

import json
import time

import numpy as np

from .multichain_bench import bench_json_path

_D = 2  # parameter dimension (closed-form posterior is per-coordinate)


def _build_full_target(n: int, seed: int):
    import jax
    import jax.numpy as jnp

    from repro.core import build_target

    theta_true = jnp.asarray([0.7, -0.4])
    x = theta_true + jax.random.normal(jax.random.key(seed), (n, _D))
    target = build_target(
        "gaussian_mean", x, n,
        prior_logpdf=lambda th: -0.5 * jnp.sum(th ** 2, axis=-1),
    )
    xbar = np.asarray(jnp.mean(x, axis=0), np.float64)
    post_mean = n * xbar / (n + 1.0)
    post_var = 1.0 / (n + 1.0)
    return target, post_mean, post_var


def _run_partition(target, num_partitions: int, chains: int, burn: int,
                   keep: int, seed: int, part_index: int):
    """Burn + timed draw collection for ONE partition's chain ensemble;
    returns ((K, keep, D) draws, steady transitions/s)."""
    import jax
    import jax.numpy as jnp

    from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig

    n_total = target.num_sections * num_partitions
    cfg = SubsampledMHConfig(
        batch_size=min(256, target.num_sections), epsilon=0.01,
        sampler="stream",
    )
    # Subposterior std ~ sqrt(P/(n+1)): scale the RW proposal with the
    # tempered posterior's width so acceptance stays in the useful band
    # at every P.
    sigma = 1.7 * float(np.sqrt(num_partitions / (n_total + 1.0)))
    ens = ChainEnsemble(target, RandomWalk(sigma), chains, config=cfg)
    state = ens.init(jnp.zeros(_D))
    key = jax.random.fold_in(jax.random.key(seed + 1), part_index)
    state, _, _ = ens.run(None, state, burn,
                          step_keys=ens.step_keys(key, 0, burn))
    jax.block_until_ready(state.theta)
    t0 = time.perf_counter()
    state, samples, _ = ens.run(None, state, keep,
                                step_keys=ens.step_keys(key, burn, keep))
    jax.block_until_ready(state.theta)
    wall = time.perf_counter() - t0
    return np.asarray(samples), chains * keep / max(wall, 1e-12)


def bench_subposterior(n: int, chains: int, burn: int, keep: int,
                       partition_counts=(1, 2, 4), seed: int = 0):
    """The sweep: per-partition tps at each P, plus both combination rules'
    error against the exact conjugate posterior."""
    from repro.partition import combine_draws, partition_target

    full_target, post_mean, post_var = _build_full_target(n, seed)
    post_std = float(np.sqrt(post_var))
    records = []
    for num_p in partition_counts:
        targets = partition_target(full_target, num_p)
        draws, tps = [], []
        for p, t in enumerate(targets):
            d, rate = _run_partition(t, num_p, chains, burn, keep, seed, p)
            draws.append(d)
            tps.append(rate)
        records.append({
            "kind": "subposterior_run",
            "P": num_p,
            "N": n,
            "K": chains,
            "steps": keep,
            "sections_per_partition": n // num_p,
            "tps_steady": float(np.mean(tps)),
            "tps_min": float(np.min(tps)),
            "tps_aggregate": float(np.sum(tps)),
        })
        # P=1: both rules degenerate to returning the single partition's
        # draws unchanged — one "passthrough" record instead of two
        # duplicate combine runs.
        methods = ("passthrough",) if num_p == 1 else ("consensus", "product")
        for method in methods:
            combined = combine_draws(
                draws, "consensus" if method == "passthrough" else method,
                seed=seed,
            )
            flat = np.asarray(combined, np.float64).reshape(-1, _D)
            err_mean = float(
                np.max(np.abs(flat.mean(axis=0) - post_mean)) / post_std
            )
            err_cov = float(
                np.max(np.abs(flat.var(axis=0, ddof=1) / post_var - 1.0))
            )
            records.append({
                "kind": "combine",
                "P": num_p,
                "N": n,
                "K": chains,
                "method": method,
                "num_draws": int(flat.shape[0]),
                "err_mean_sigma": err_mean,
                "err_cov_rel": err_cov,
            })
    return records


def main(fast: bool = True):
    if fast:
        n, chains, burn, keep = 2048, 4, 300, 400
    else:
        n, chains, burn, keep = 8192, 8, 600, 800

    records = bench_subposterior(n, chains, burn, keep)
    rows_out = []
    for rec in records:
        if rec["kind"] == "subposterior_run":
            rows_out.append((
                f"subposterior_P{rec['P']}",
                1e6 / rec["tps_steady"],
                f"tps={rec['tps_steady']:.0f}"
                f"_aggregate={rec['tps_aggregate']:.0f}"
                f"_n_p={rec['sections_per_partition']}",
            ))
        else:
            rows_out.append((
                f"subposterior_combine_{rec['method']}_P{rec['P']}",
                rec["err_mean_sigma"],
                f"err_mean={rec['err_mean_sigma']:.3f}sigma"
                f"_err_cov={rec['err_cov_rel']:.3f}"
                f"_draws={rec['num_draws']}",
            ))

    path = bench_json_path("subposterior")
    with open(path, "w") as f:
        json.dump({"bench": "subposterior", "records": records}, f, indent=1)
    rows_out.append((f"subposterior_json:{path}", 0.0, "machine-readable output"))
    return rows_out, records


if __name__ == "__main__":
    for name, us, derived in main()[0]:
        print(f"{name},{us:.1f},{derived}")
