"""Benchmark entry point: one bench per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Default is the fast (CPU-minutes)
configuration; ``--full`` runs the paper-scale versions.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig5,fig6,fig9,kernels,roofline,"
                         "multichain,serving,fleet,subposterior")
    args = ap.parse_args()
    fast = not args.full

    from . import fig4_bayeslr, fig5_sublinear, fig6_jointdpm, fig9_sv
    from . import fleet_bench, kernels_bench, multichain_bench, roofline
    from . import serving_bench, subposterior_bench

    benches = {
        "fig5": fig5_sublinear,
        "fig4": fig4_bayeslr,
        "fig6": fig6_jointdpm,
        "fig9": fig9_sv,
        "kernels": kernels_bench,
        "roofline": roofline,
        "multichain": multichain_bench,
        "serving": serving_bench,
        "fleet": fleet_bench,
        "subposterior": subposterior_bench,
    }
    selected = args.only.split(",") if args.only else list(benches)

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        mod = benches[name]
        try:
            rows, _ = mod.main(fast=fast)
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark(s) failed")


if __name__ == "__main__":
    main()
