"""The ops dispatch matrix: ref vs Pallas(interpret) across kernel
families × precision modes × autotune on/off, plus the fp32 bit-for-bit
regression, the deprecated-alias warning path, and the bf16
sequential-test decision-flip bound."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ops, ref


def _mk_fused_ce():
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    h = 0.5 * jax.random.normal(k1, (12, 8))
    tab = 0.5 * jax.random.normal(k2, (40, 8))
    tgt = jax.random.randint(k3, (12,), 0, 40)
    return (h, tab, tgt)


def _mk_batched_fused_ce():
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    h = 0.5 * jax.random.normal(k1, (2, 8, 8))
    tab = 0.5 * jax.random.normal(k2, (40, 8))
    tgt = jax.random.randint(k3, (2, 8), 0, 40)
    return (h, tab, tgt)


def _mk_logit_delta():
    k1, k2, k3, k4 = jax.random.split(jax.random.key(2), 4)
    x = jax.random.normal(k1, (33, 5))
    y = jnp.where(jax.random.bernoulli(k2, 0.5, (33,)), 1.0, -1.0)
    return (x, y, jax.random.normal(k3, (5,)), jax.random.normal(k4, (5,)))


def _mk_batched_logit_delta():
    k1, k2, k3, k4 = jax.random.split(jax.random.key(3), 4)
    xg = jax.random.normal(k1, (3, 20, 5))
    yg = jnp.where(jax.random.bernoulli(k2, 0.5, (3, 20)), 1.0, -1.0)
    return (xg, yg, jax.random.normal(k3, (3, 5)), jax.random.normal(k4, (3, 5)))


def _mk_ar1():
    k1, k2 = jax.random.split(jax.random.key(4))
    xt = jax.random.normal(k1, (3, 20))
    xp = jax.random.normal(k2, (3, 20))
    phi = jnp.asarray([0.9, 0.5, -0.3])
    s2 = jnp.asarray([0.02, 0.5, 1.1])
    return (xt, xp, phi, s2, phi * 0.95, s2 * 1.05)


FAMILIES = {
    "fused_ce": (ops.fused_ce, _mk_fused_ce),
    "batched_fused_ce": (ops.batched_fused_ce, _mk_batched_fused_ce),
    "logit_delta": (ops.logit_delta, _mk_logit_delta),
    "batched_loglik": (ops.batched_logit_delta, _mk_batched_logit_delta),
    "gaussian_ar1": (ops.batched_gaussian_ar1_delta, _mk_ar1),
}


@pytest.fixture(scope="module")
def tune_dir(tmp_path_factory):
    # one shared on-disk cache for the whole matrix: later cases exercise
    # the disk-cache hit path, not just the first-measure path
    return str(tmp_path_factory.mktemp("autotune"))


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("precision", ["fp32", "bf16"])
@pytest.mark.parametrize("tuned", [False, True])
def test_dispatch_parity_matrix(family, precision, tuned, tune_dir, monkeypatch):
    if tuned:
        monkeypatch.setenv(autotune.ENV_VAR, "1")
        monkeypatch.setenv(autotune.DIR_ENV_VAR, tune_dir)
    else:
        monkeypatch.setenv(autotune.ENV_VAR, "0")
    fn, mk = FAMILIES[family]
    args = mk()
    got = fn(*args, mode="always", precision=precision)  # interpret on CPU
    want = fn(*args, mode="never", precision=precision)
    assert got.dtype == jnp.float32  # fp32 accumulation on every path
    tol = 1e-5 if precision == "fp32" else 1e-1
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_autotune_cache_written_and_reused(tune_dir, monkeypatch):
    monkeypatch.setenv(autotune.ENV_VAR, "1")
    monkeypatch.setenv(autotune.DIR_ENV_VAR, tune_dir)
    tiles = autotune.tiles_for("gaussian_ar1", (3, 20))
    assert "tile_m" in tiles
    # second consult must come from cache (identical result)
    assert autotune.tiles_for("gaussian_ar1", (3, 20)) == tiles
    import json
    import os

    path = os.path.join(tune_dir, f"{jax.default_backend()}.json")
    assert os.path.exists(path)
    with open(path) as f:
        disk = json.load(f)
    key = autotune.cache_key("gaussian_ar1", (3, 20), jax.default_backend())
    assert disk[key]["tiles"] == tiles


def test_autotune_disabled_returns_defaults(monkeypatch):
    monkeypatch.setenv(autotune.ENV_VAR, "0")
    assert autotune.tiles_for("logit_delta", (100, 8)) == \
        autotune.DEFAULT_TILES["logit_delta"]
    with pytest.raises(KeyError):
        autotune.tiles_for("nope", (8,))


def test_fp32_default_is_bitwise_ref_and_kernel(monkeypatch):
    # precision="auto" with no env must be the exact pre-precision fp32
    # behaviour on both dispatch paths
    monkeypatch.delenv(ops.PRECISION_ENV_VAR, raising=False)
    monkeypatch.setenv(autotune.ENV_VAR, "0")
    xg, yg, w1, w2 = _mk_batched_logit_delta()
    got_ref = ops.batched_logit_delta(xg, yg, w1, w2, mode="never")
    want_ref = ref.batched_logit_delta_ref(xg, yg, w1, w2)
    assert np.array_equal(np.asarray(got_ref), np.asarray(want_ref))

    from repro.kernels.batched_loglik import batched_logit_delta as kern

    got_k = ops.batched_logit_delta(xg, yg, w1, w2, mode="always")
    want_k = kern(xg, yg, w1, w2, interpret=True)
    assert np.array_equal(np.asarray(got_k), np.asarray(want_k))


def test_deprecated_alias_warns():
    args = _mk_ar1()
    with pytest.warns(DeprecationWarning, match="deprecated"):
        out = ops.batched_gaussian_ar1_delta(*args, mode="ref")
    want = ref.batched_gaussian_ar1_delta_ref(*args)
    assert np.array_equal(np.asarray(out), np.asarray(want))
    with pytest.warns(DeprecationWarning):
        assert ops.normalize_mode("kernel") == "always"


def test_resolve_precision_validation(monkeypatch):
    assert ops.resolve_precision("fp32") == "fp32"
    assert ops.resolve_precision("bf16") == "bf16"
    monkeypatch.setenv(ops.PRECISION_ENV_VAR, "bf16")
    assert ops.resolve_precision("auto") == "bf16"
    monkeypatch.setenv(ops.PRECISION_ENV_VAR, "fp16")
    with pytest.raises(ValueError):
        ops.resolve_precision("auto")
    with pytest.raises(ValueError):
        ops.resolve_precision("double")


def test_dispatch_summary_smoke():
    line = ops.dispatch_summary()
    assert "dispatch=" in line and "precision=" in line and "autotune=" in line


def test_bf16_decision_flip_rate_bounded():
    # the mixed-precision acceptance bar: across many sequential-test-style
    # accept/reject rounds on the AR(1) delta, the bf16 data path may flip
    # only a small fraction of decisions relative to exact fp32
    k, m, rounds = 8, 256, 50
    rng = np.random.default_rng(0)
    flips = total = 0
    for r in range(rounds):
        xt = jnp.asarray(rng.standard_normal((k, m)) * 0.3, jnp.float32)
        xp = jnp.asarray(rng.standard_normal((k, m)) * 0.3, jnp.float32)
        phi = jnp.asarray(rng.uniform(0.5, 0.99, k), jnp.float32)
        s2 = jnp.asarray(rng.uniform(0.01, 0.2, k), jnp.float32)
        phi_p = phi + jnp.asarray(rng.normal(0, 0.02, k), jnp.float32)
        s2_p = s2 * jnp.asarray(rng.uniform(0.9, 1.1, k), jnp.float32)
        logu = jnp.asarray(np.log(rng.uniform(size=k)), jnp.float32)
        d32 = ops.batched_gaussian_ar1_delta(
            xt, xp, phi, s2, phi_p, s2_p, precision="fp32")
        d16 = ops.batched_gaussian_ar1_delta(
            xt, xp, phi, s2, phi_p, s2_p, precision="bf16")
        acc32 = np.asarray(jnp.sum(d32, axis=1) > logu)
        acc16 = np.asarray(jnp.sum(d16, axis=1) > logu)
        flips += int((acc32 != acc16).sum())
        total += k
    assert flips / total <= 0.05
