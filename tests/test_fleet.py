"""Fleet subsystem: delta streaming, replicas, routing/admission, 2-d mesh.

Single-device tests cover the host-side fleet semantics (delta algebra,
replica parity, router priority and shedding, warm restore). The
multi-device contracts — 2-d chains x data sharding bit-for-bit, sharded
fleet checkpoint round-trips — run in subprocesses under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (JAX pins the
device count at first init), marked slow like the other multi-device
cases.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

import jax
import jax.numpy as jnp

from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig
from repro.fleet import (
    AdmissionConfig,
    Fleet,
    FleetConfig,
    FleetRouter,
    ReplicaEnsemble,
    SnapshotDelta,
    apply_delta,
    make_delta,
    payload_nbytes,
    wire_bytes,
)
from repro.serving import FreshnessPolicy, ServingConfig
from repro.serving.resident import Snapshot

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fleet_config(replicas=2, shards=1, window=16, refresh_steps=8,
                  num_chains=2, transport="inproc", mesh="auto"):
    return FleetConfig(
        replicas=replicas,
        shards=shards,
        transport=transport,
        mesh=mesh,
        serving=ServingConfig(
            num_chains=num_chains,
            refresh_steps=refresh_steps,
            window=window,
            micro_batch=8,
            max_batch=4,
            freshness=FreshnessPolicy(max_staleness_s=1e9, min_draws=num_chains * 4),
            seed=0,
        ),
    )


def _tiny_fleet(**kw) -> Fleet:
    fleet = Fleet(_fleet_config(**kw))
    fleet.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    return fleet


@pytest.fixture(scope="module")
def warm_fleet():
    fleet = _tiny_fleet()
    fleet.warm()
    return fleet


# ---------------------------------------------------------------------------
# Delta algebra
# ---------------------------------------------------------------------------


def _snap(draws, steps):
    return Snapshot(draws=draws, num_draws=int(np.prod(draws.shape[:2])),
                    steps_done=steps, staleness_s=0.1, summary={}, created_at=0.0)


def test_make_delta_incremental_reconstructs_window():
    window = 6
    full = np.arange(2 * 10, dtype=np.float32).reshape(2, 10)
    # writer at v=8 (window holds draws 2..8), replica synced at v=5
    writer = full[:, 8 - window:8]
    delta = make_delta(_snap(writer, 8), base_version=5, window=window)
    assert not delta.full and delta.base_version == 5 and delta.version == 8
    assert delta.draws.shape == (2, 3)  # exactly the 3 new columns
    replica = full[:, max(5 - window, 0):5]  # replica's (still-filling) window at v=5
    np.testing.assert_array_equal(apply_delta(replica, delta), writer)


def test_make_delta_falls_back_to_full_resync():
    window = 4
    writer = np.arange(8, dtype=np.float32).reshape(2, 4)
    # gap >= window width: only a full window can reconcile
    delta = make_delta(_snap(writer, 20), base_version=2, window=window)
    assert delta.full and delta.base_version == 0
    np.testing.assert_array_equal(apply_delta(None, delta), writer)
    # replica ahead of writer (restore to older checkpoint): full again
    assert make_delta(_snap(writer, 20), base_version=30, window=window).full


def test_make_delta_zero_gap_is_empty():
    writer = np.ones((2, 4), np.float32)
    delta = make_delta(_snap(writer, 7), base_version=7, window=4)
    assert delta.draws is None and payload_nbytes(delta.draws) == 0
    np.testing.assert_array_equal(apply_delta(writer, delta), writer)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=4),    # chains K
    st.integers(min_value=2, max_value=12),   # window depth
    st.integers(min_value=0, max_value=64),   # replica version b
    st.integers(min_value=0, max_value=64),   # writer advance beyond b
)
def test_delta_roundtrip_property(k, window, base, advance):
    """apply(make(replica@b -> writer@v)) == writer window, bit for bit, for
    ANY (K, window, versions) — including cold replicas, still-filling
    windows, and replicas ahead of the writer (checkpoint restore)."""
    version = base + advance
    if version == 0:
        return  # writer has produced nothing: no snapshot to stream
    # One global draw sequence; a window at version v is its last columns.
    seq = np.arange(k * 80, dtype=np.float32).reshape(k, 80)
    win_at = lambda v: seq[:, max(v - window, 0):v] if v else None
    writer = win_at(version)
    delta = make_delta(_snap(writer, version), base, window)
    result = apply_delta(win_at(base), delta)
    np.testing.assert_array_equal(result, writer)
    assert delta.version == version


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=2, max_value=12),
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=0, max_value=64),
)
def test_delta_full_resync_iff_gap_reaches_window(window, base, advance):
    """The delta degrades to a full-window resync exactly when the gap can't
    be bridged: replica cold (b=0), replica ahead, or gap >= the writer
    window's actual width (min(version, window) — still-filling windows
    included)."""
    version = base + advance
    seq = np.arange(80, dtype=np.float32).reshape(1, 80)
    writer = seq[:, max(version - window, 0):version]
    delta = make_delta(_snap(writer, version), base, window)
    width = writer.shape[1]
    assert delta.full == (base == 0 or version - base >= width)
    if delta.full:
        assert delta.base_version == 0  # applies to any replica state


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=2, max_value=12),
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=0, max_value=64),
)
def test_delta_payload_accounting_invariants(k, window, base, advance):
    """Byte accounting the fleet bench reports: an empty delta costs zero
    payload, an incremental delta carries exactly the new tail columns and
    never more than the full window, and the pickled wire size bounds the
    raw payload from above."""
    version = base + advance
    seq = np.arange(k * 80, dtype=np.float32).reshape(k, 80)
    writer = seq[:, max(version - window, 0):version]
    delta = make_delta(_snap(writer, version), base, window)
    payload = payload_nbytes(delta.draws)
    full_payload = payload_nbytes(writer)
    if delta.draws is None:
        assert payload == 0
        assert version == base  # only a zero gap streams nothing
    elif delta.full:
        assert payload == full_payload
    else:
        gap = version - base
        assert payload == k * gap * 4  # exactly the new f32 tail
        assert payload < full_payload
    assert wire_bytes(delta) >= payload  # pickle overhead, never compression


def test_replica_rejects_mismatched_incremental():
    rep = ReplicaEnsemble("r", micro_batch=4)
    writer = np.ones((2, 4), np.float32)
    full = make_delta(_snap(writer, 4), 0, 4)
    rep.apply_delta(full)
    bad = SnapshotDelta("", base_version=99, version=101,
                        draws=np.ones((2, 2), np.float32), window=4,
                        summary={}, staleness_s=0.0, full=False)
    with pytest.raises(ValueError, match="full resync required"):
        rep.apply_delta(bad)


# ---------------------------------------------------------------------------
# Fleet sync: replicas mirror writers bit for bit, deltas beat full windows
# ---------------------------------------------------------------------------


def test_replica_window_matches_writer_bit_for_bit(warm_fleet):
    fleet = warm_fleet
    for _ in range(3):
        fleet.pump("bayeslr")
    for shard in fleet.shards("bayeslr"):
        wsnap = shard.writer.snapshot()
        for replica in shard.replicas:
            rsnap = replica.snapshot()
            assert rsnap.steps_done == wsnap.steps_done
            np.testing.assert_array_equal(
                np.asarray(jax.tree.leaves(wsnap.draws)[0]),
                np.asarray(jax.tree.leaves(rsnap.draws)[0]),
            )
    stats = fleet.sync_stats
    assert stats["delta_wire_bytes"] < stats["full_wire_bytes"]
    assert stats["delta_payload_bytes"] < stats["full_payload_bytes"]


def test_replica_serves_bit_for_bit_what_writer_would(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    shard = fleet.shards("bayeslr")[0]
    spec = fleet.spec("bayeslr", "predictive")
    xs = spec.make_queries(jax.random.key(3), 8)
    w_vals, _ = shard.writer.query(spec, xs)
    r_vals, staleness = shard.replicas[0].serve(spec, "predictive", xs)
    np.testing.assert_array_equal(np.asarray(w_vals), np.asarray(r_vals))
    assert np.isfinite(staleness)


def test_replica_staleness_compounds_writer_staleness():
    rep = ReplicaEnsemble("r", micro_batch=4)
    assert rep.snapshot().staleness_s == float("inf")
    delta = make_delta(_snap(np.ones((2, 4), np.float32), 4), 0, 4)
    delta = delta._replace(staleness_s=1.5)
    rep.apply_delta(delta)
    snap = rep.snapshot()
    assert snap.staleness_s >= 1.5  # never younger than the writer's stamp


def test_two_shards_have_independent_chains():
    fleet = _tiny_fleet(shards=2)
    fleet.warm()
    s0, s1 = fleet.shards("bayeslr")
    a = np.asarray(jax.tree.leaves(s0.writer.snapshot().draws)[0])
    b = np.asarray(jax.tree.leaves(s1.writer.snapshot().draws)[0])
    assert a.shape == b.shape
    assert not np.array_equal(a, b)  # fold_in(seed, shard) keys differ


# ---------------------------------------------------------------------------
# Router: load spreading, priority, admission control
# ---------------------------------------------------------------------------


def test_router_batch_result_transparent(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(fleet, max_batch=4, default_deadline_s=30.0)
    spec = fleet.spec("bayeslr", "predictive")
    xs_list = [spec.make_queries(jax.random.key(i), 3) for i in range(6)]
    reqs = [router.submit("bayeslr", "predictive", xs) for xs in xs_list]
    router.drain()
    shard = fleet.shards("bayeslr")[0]
    for req, xs in zip(reqs, xs_list):
        solo, _ = shard.writer.query(spec, xs)
        np.testing.assert_array_equal(np.asarray(req.result(1.0)), np.asarray(solo))
    report = router.slo_report()
    entry = report["classes"]["bayeslr.predictive"]
    assert entry["admitted"] == 6 and entry["shed"] == 0
    assert report["shed"] == 0 and report["errors"] == 0


def test_router_spreads_load_across_lanes(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(fleet, max_batch=2, default_deadline_s=30.0)
    spec = fleet.spec("bayeslr", "predictive")
    for i in range(8):
        router.submit("bayeslr", "predictive", spec.make_queries(jax.random.key(i), 2))
    lanes = router._lanes["bayeslr"]
    depths = [len(l.pending) for l in lanes]
    assert max(depths) - min(depths) <= 1  # least-loaded placement
    router.drain()


def test_router_serves_high_priority_first(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(fleet, priorities={"predictive": 2, "vote": 0},
                         max_batch=8, default_deadline_s=30.0)
    spec_p = fleet.spec("bayeslr", "predictive")
    spec_v = fleet.spec("bayeslr", "vote")
    low = [router.submit("bayeslr", "vote", spec_v.make_queries(jax.random.key(i), 2))
           for i in range(3)]
    high = [router.submit("bayeslr", "predictive",
                          spec_p.make_queries(jax.random.key(10 + i), 2))
            for i in range(3)]
    served = router.drain()
    # Within each lane the high-priority batch went first; verify globally by
    # completion order: every high request precedes any low request served on
    # the same lane. Cheap proxy: first completions are all high-priority.
    first_classes = [r.query_class for r in served[:len(high)]]
    assert all(c == "predictive" for c in first_classes)
    assert all(r.done.is_set() for r in low + high)


def test_admission_sheds_lowest_class_first(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(
        fleet, priorities={"predictive": 1, "vote": 0},
        admission=AdmissionConfig(max_depth=6, min_observations=10**9),
        max_batch=4, default_deadline_s=30.0,
    )
    spec = fleet.spec("bayeslr", "predictive")
    reqs = []
    for i in range(24):
        cls = "predictive" if i % 2 else "vote"
        reqs.append(router.submit("bayeslr", cls, spec.make_queries(jax.random.key(i), 2)))
    router.drain()
    report = router.slo_report()
    assert report["classes"]["bayeslr.vote"]["shed"] > 0
    assert report["classes"]["bayeslr.predictive"]["shed"] == 0
    assert report["shed"] == report["classes"]["bayeslr.vote"]["shed"]
    shed_req = next(r for r in reqs if (r.error or "").startswith("shed"))
    with pytest.raises(RuntimeError, match="shed"):
        shed_req.result(timeout_s=1.0)


def test_admission_trips_on_predicted_miss_rate(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(
        fleet, priorities={"predictive": 1, "vote": 0},
        admission=AdmissionConfig(max_depth=10**6, max_miss_rate=0.5,
                                  miss_window=8, min_observations=4),
        max_batch=4, default_deadline_s=30.0,
    )
    spec = fleet.spec("bayeslr", "predictive")
    # Deadline 0 => every completion is a miss; the predictor trips.
    for i in range(6):
        router.submit("bayeslr", "predictive",
                      spec.make_queries(jax.random.key(i), 2), deadline_s=0.0)
    router.drain()
    assert router.predicted_miss_rate() > 0.5
    low = router.submit("bayeslr", "vote", spec.make_queries(jax.random.key(99), 2))
    high = router.submit("bayeslr", "predictive",
                         spec.make_queries(jax.random.key(100), 2))
    assert (low.error or "").startswith("shed")
    assert high.error is None
    router.drain()
    report = router.slo_report()
    assert report["admission"]["shed_floor"] == 1
    assert report["classes"]["bayeslr.vote"]["shed"] == 1


def test_single_class_is_never_shed(warm_fleet):
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(
        fleet, priorities={"predictive": 0, "vote": 0},
        admission=AdmissionConfig(max_depth=2, min_observations=10**9),
        max_batch=4, default_deadline_s=30.0,
    )
    spec = fleet.spec("bayeslr", "predictive")
    for i in range(10):  # equal priorities: no lower class to shed first
        router.submit("bayeslr", "predictive", spec.make_queries(jax.random.key(i), 2))
    router.drain()
    assert router.slo_report()["shed"] == 0


def test_admission_floor_steps_at_max_depth_multiples(warm_fleet):
    """Hysteresis of the depth-driven shed floor across three priority
    levels: each ``max_depth`` multiple of backlog raises the floor one
    level (never past the top class), and draining drops it back to None."""
    fleet = warm_fleet
    fleet.sync_all()
    depth = 4
    # Three levels: "bulk" exists only in the priority map (submissions for
    # it queue like any class) so the floor has two steps to climb.
    router = FleetRouter(
        fleet, priorities={"predictive": 2, "vote": 1, "bulk": 0},
        admission=AdmissionConfig(max_depth=depth, min_observations=10**9),
        max_batch=4, default_deadline_s=30.0,
    )
    spec = fleet.spec("bayeslr", "predictive")
    qs = lambda i: spec.make_queries(jax.random.key(i), 2)

    assert router.slo_report()["admission"]["shed_floor"] is None
    assert router.submit("bayeslr", "bulk", qs(0)).error is None  # admitted

    # Build backlog (no workers running) out of top-class requests only —
    # they are always admitted, so the depth is exactly controllable.
    floors = {}
    for i in range(1, 2 * depth + 1):
        router.submit("bayeslr", "predictive", qs(i))
        floors[router.pending_count] = (
            router.slo_report()["admission"]["shed_floor"]
        )
    # below max_depth: everything admitted; the first multiple cuts
    # priority-0; the second cuts priority-1 as well; never priority-2.
    assert floors[depth - 1] is None
    assert floors[depth] == 1
    assert floors[2 * depth] == 2

    low = router.submit("bayeslr", "bulk", qs(100))
    mid = router.submit("bayeslr", "vote", qs(101))
    top = router.submit("bayeslr", "predictive", qs(102))
    assert (low.error or "").startswith("shed")
    assert (mid.error or "").startswith("shed")
    assert top.error is None

    # The one pre-floor bulk request fails at serve time (no such spec) —
    # that must fail the request, not the drain.
    router.drain()
    report = router.slo_report()
    assert report["admission"]["shed_floor"] is None  # backlog gone: recovered
    assert report["classes"]["bayeslr.bulk"]["shed"] == 1
    assert report["classes"]["bayeslr.vote"]["shed"] == 1
    assert report["classes"]["bayeslr.predictive"]["shed"] == 0
    admit = router.submit("bayeslr", "vote", qs(103))
    assert admit.error is None  # floor lifted: low classes admitted again


# ---------------------------------------------------------------------------
# Warm checkpoint round-trip through the fleet
# ---------------------------------------------------------------------------


def test_fleet_checkpoint_roundtrip_resumes_key_schedule(tmp_path):
    fleet1 = _tiny_fleet()
    fleet1.warm()
    fleet1.save(str(tmp_path))

    fleet2 = _tiny_fleet()
    step = fleet2.restore(str(tmp_path))
    s1 = fleet1.shards("bayeslr")[0]
    s2 = fleet2.shards("bayeslr")[0]
    assert step == s1.writer.steps_done == s2.writer.steps_done
    # restored replicas already mirror the restored writer window
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(s1.replicas[0].snapshot().draws)[0]),
        np.asarray(jax.tree.leaves(s2.replicas[0].snapshot().draws)[0]),
    )
    # the restored fleet's next refresh+broadcast continues the exact key
    # schedule: writer windows AND replica copies stay bit-for-bit equal
    fleet1.pump("bayeslr")
    fleet2.pump("bayeslr")
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(s1.writer.snapshot().draws)[0]),
        np.asarray(jax.tree.leaves(s2.writer.snapshot().draws)[0]),
    )
    for r1, r2 in zip(s1.replicas, s2.replicas):
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(r1.snapshot().draws)[0]),
            np.asarray(jax.tree.leaves(r2.snapshot().draws)[0]),
        )


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="replicas and shards"):
        FleetConfig(replicas=0)
    with pytest.raises(ValueError, match="unknown transport"):
        FleetConfig(transport="carrier-pigeon")
    with pytest.raises(ValueError, match="max_depth"):
        AdmissionConfig(max_depth=0)
    with pytest.raises(ValueError, match="max_miss_rate"):
        AdmissionConfig(max_miss_rate=0.0)


def test_ensemble_2d_shard_validation(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=100, seed=0)
    cfg = SubsampledMHConfig(batch_size=20, epsilon=0.05)
    with pytest.raises(ValueError, match="must name the mesh axes"):
        ChainEnsemble(target, RandomWalk(0.1), 4, config=cfg, shard=("rows", "cols"))
    with pytest.raises(ValueError, match="subset"):
        ChainEnsemble(target, RandomWalk(0.1), 4, config=cfg,
                      shard={"chains": 2, "batch": 2})
    with pytest.raises(ValueError, match="subsampled kernel"):
        ChainEnsemble(target, RandomWalk(0.1), 4, kernel="exact",
                      shard=("chains", "data"))
    with pytest.raises(ValueError, match="'auto', True, False"):
        ChainEnsemble(target, RandomWalk(0.1), 4, config=cfg, shard="yes")


def test_ensemble_2d_single_device_matches_default(gaussian_target_factory):
    """On one device the 2-d request runs the batched-transition scan —
    still bit-for-bit the default vmapped engine."""
    target, _, _ = gaussian_target_factory(n=200, seed=1)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
    keys = jax.random.split(jax.random.key(2), 4)
    ens2d = ChainEnsemble(target, RandomWalk(0.1), 4, config=cfg,
                          shard=("chains", "data"))
    plain = ChainEnsemble(target, RandomWalk(0.1), 4, config=cfg, shard=False)
    _, s2, i2 = ens2d.run(keys, ens2d.init(jnp.zeros(())), 30)
    _, sp, ip = plain.run(keys, plain.init(jnp.zeros(())), 30)
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(sp))
    np.testing.assert_array_equal(np.asarray(i2.n_evaluated),
                                  np.asarray(ip.n_evaluated))


# ---------------------------------------------------------------------------
# Multi-device contracts (subprocess: JAX pins device count at first init)
# ---------------------------------------------------------------------------


def _run_forced_devices(script: str, devices: int = 4) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=_REPO, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_2d_sharded_run_bit_for_bit_vs_unsharded():
    """Lock-step AND masked 2-d-sharded runs == unsharded at 4 devices."""
    script = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig, from_iid_loglik

n = 400
x = 0.7 + jnp.asarray(jax.random.normal(jax.random.key(1), (n,)))
target = from_iid_loglik(lambda th: -0.5 * jnp.sum(th**2),
                         lambda th, idx: -0.5 * (x[idx] - th) ** 2, None, n)
cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
keys = jax.random.split(jax.random.key(5), 8)

out = {"n_devices": len(jax.devices())}
for stepping in ("lockstep", "masked"):
    runs = {}
    for name, shard in (("2d", ("chains", "data")),
                        ("2d_dict", {"chains": 2, "data": 2}),
                        ("off", False)):
        ens = ChainEnsemble(target, RandomWalk(0.05), 8, config=cfg,
                            shard=shard, stepping=stepping)
        _, s, i = ens.run(keys, ens.init(jnp.zeros(())), 60)
        runs[name] = (np.asarray(s), np.asarray(i.n_evaluated))
    out[stepping] = bool(
        np.array_equal(runs["2d"][0], runs["off"][0])
        and np.array_equal(runs["2d"][1], runs["off"][1])
        and np.array_equal(runs["2d_dict"][0], runs["off"][0])
    )
print(json.dumps(out))
"""
    res = _run_forced_devices(script)
    assert res["n_devices"] == 4
    assert res["lockstep"] is True
    assert res["masked"] is True


@pytest.mark.slow
def test_2d_sharded_fused_family_bit_for_bit():
    """The registry-threaded fused route under the 2-d mesh == its
    unsharded self (and allclose to the unfused reference)."""
    script = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig
from repro.core.target_builder import build_target

n, d = 256, 3
kx, ky = jax.random.split(jax.random.key(0))
x = jax.random.normal(kx, (n, d))
y = jnp.where(jax.random.bernoulli(ky, 0.5, (n,)), 1.0, -1.0)
target = build_target("logit", (x, y), n,
                      prior_logpdf=lambda w: -0.5 * jnp.sum(w**2))
cfg = SubsampledMHConfig(batch_size=64, epsilon=0.05)
keys = jax.random.split(jax.random.key(3), 8)
outs = {}
for name, kw in (("fused_2d", dict(shard=("chains", "data"), fused_kernels="always")),
                 ("fused_off", dict(shard=False, fused_kernels="always")),
                 ("plain", dict(shard=False, fused_kernels="never"))):
    ens = ChainEnsemble(target, RandomWalk(0.1), 8, config=cfg, **kw)
    _, s, _ = ens.run(keys, ens.init(jnp.zeros(d)), 40)
    outs[name] = np.asarray(s)
print(json.dumps({
    "n_devices": len(jax.devices()),
    "bitexact": bool(np.array_equal(outs["fused_2d"], outs["fused_off"])),
    "allclose": bool(np.allclose(outs["fused_2d"], outs["plain"], rtol=2e-4, atol=2e-5)),
}))
"""
    res = _run_forced_devices(script)
    assert res["bitexact"] is True and res["allclose"] is True


@pytest.mark.slow
def test_sharded_fleet_checkpoint_roundtrip_at_4_devices(tmp_path):
    """A fleet whose writers run the 2-d mesh checkpoints and restores
    warm: the restored key schedule continues bit for bit and the replicas
    mirror it."""
    script = r"""
import json, tempfile
import jax, numpy as np
from repro.fleet import Fleet, FleetConfig
from repro.serving import FreshnessPolicy, ServingConfig

def build():
    cfg = FleetConfig(
        replicas=2, shards=1, mesh=("chains", "data"),
        serving=ServingConfig(num_chains=4, refresh_steps=8, window=16,
                              micro_batch=8,
                              freshness=FreshnessPolicy(max_staleness_s=1e9,
                                                        min_draws=8),
                              seed=0),
    )
    fleet = Fleet(cfg)
    fleet.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    return fleet

ckpt = tempfile.mkdtemp()
f1 = build(); f1.warm(); f1.save(ckpt)
f2 = build(); step = f2.restore(ckpt)
f1.pump(); f2.pump()
s1, s2 = f1.shards("bayeslr")[0], f2.shards("bayeslr")[0]
w1 = np.asarray(jax.tree.leaves(s1.writer.snapshot().draws)[0])
w2 = np.asarray(jax.tree.leaves(s2.writer.snapshot().draws)[0])
r1 = np.asarray(jax.tree.leaves(s1.replicas[1].snapshot().draws)[0])
r2 = np.asarray(jax.tree.leaves(s2.replicas[1].snapshot().draws)[0])
print(json.dumps({
    "n_devices": len(jax.devices()),
    "step": step,
    "writers_equal": bool(np.array_equal(w1, w2)),
    "replicas_equal": bool(np.array_equal(r1, r2)),
    "replica_mirrors_writer": bool(np.array_equal(w2, r2)),
}))
"""
    res = _run_forced_devices(script)
    assert res["n_devices"] == 4
    assert res["writers_equal"] and res["replicas_equal"]
    assert res["replica_mirrors_writer"]


@pytest.mark.slow
def test_proc_transport_replica_parity():
    """Process-group replicas (spawned workers) serve bit-for-bit what the
    writer serves, fed only by pickled deltas over the pipe."""
    script = r"""
import json
import jax, numpy as np
from repro.fleet import Fleet, FleetConfig
from repro.serving import FreshnessPolicy, ServingConfig

def main():
    cfg = FleetConfig(
        replicas=1, shards=1, transport="proc",
        serving=ServingConfig(num_chains=2, refresh_steps=8, window=16,
                              micro_batch=8,
                              freshness=FreshnessPolicy(max_staleness_s=1e9,
                                                        min_draws=8),
                              seed=0),
    )
    fleet = Fleet(cfg)
    fleet.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    fleet.warm()
    fleet.pump()
    shard = fleet.shards("bayeslr")[0]
    spec = fleet.spec("bayeslr", "predictive")
    xs = spec.make_queries(jax.random.key(9), 8)
    w_vals, _ = shard.writer.query(spec, xs)
    r_vals, _ = shard.replicas[0].serve(spec, "predictive", xs)
    stats = shard.replicas[0].stats()
    fleet.close()
    print(json.dumps({
        "equal": bool(np.array_equal(np.asarray(w_vals), np.asarray(r_vals))),
        "deltas_applied": stats["deltas_applied"],
        "bytes_received": stats["bytes_received"],
    }))

if __name__ == "__main__":
    main()
"""
    res = _run_forced_devices(script, devices=1)
    assert res["equal"] is True
    assert res["deltas_applied"] >= 2 and res["bytes_received"] > 0


def test_router_workers_serve_mixed_classes_correctly(warm_fleet):
    """Background lane workers with interleaved classes: every request must
    be answered with ITS class's functional (a merged cross-class batch
    would silently serve the wrong spec) and none may be dropped."""
    fleet = warm_fleet
    fleet.sync_all()
    router = FleetRouter(fleet, priorities={"predictive": 1, "vote": 0},
                         max_batch=4, default_deadline_s=30.0)
    spec_p = fleet.spec("bayeslr", "predictive")
    spec_v = fleet.spec("bayeslr", "vote")
    shard = fleet.shards("bayeslr")[0]
    router.start_workers(max_wait_s=0.001)
    try:
        reqs = []
        for i in range(16):
            cls = "predictive" if i % 2 else "vote"
            xs = (spec_p if cls == "predictive" else spec_v).make_queries(
                jax.random.key(i), 3)
            reqs.append((cls, xs, router.submit("bayeslr", cls, xs)))
        for cls, xs, req in reqs:
            got = req.result(timeout_s=30.0)  # hangs = dropped request
            spec = spec_p if cls == "predictive" else spec_v
            want, _ = shard.writer.query(spec, xs)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    finally:
        router.stop_workers()


# ---------------------------------------------------------------------------
# Runtime scaling — add_replica / remove_replica + attach_lane / detach_lane
# ---------------------------------------------------------------------------


def test_add_replica_joins_bit_exact_with_fresh_name():
    fleet = _tiny_fleet()
    fleet.warm()
    try:
        assert fleet.replica_count("bayeslr") == 2
        shard_before = fleet.shards("bayeslr")[0]
        shard, replica = fleet.add_replica("bayeslr")
        assert fleet.replica_count("bayeslr") == 3
        # the shard entry was swapped, not mutated: the new tuple is the
        # old one plus the newcomer, and the live list holds the new entry
        assert fleet.shards("bayeslr")[0] is shard
        assert shard.replicas[:-1] == shard_before.replicas
        assert replica is shard.replicas[-1]
        assert replica.name == f"{shard.name}#r2"
        # the join resync seeded the full window: bit-exact immediately
        assert replica.version == shard.writer.steps_done
        spec = fleet.spec("bayeslr", "predictive")
        xs = spec.make_queries(jax.random.key(0), 8)
        want, _ = shard.writer.query(spec, xs)
        got, _ = replica.serve(spec, "predictive", xs)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
        # retire + re-add: the #rN sequence never reuses a name
        fleet.remove_replica("bayeslr", replica_name=replica.name)
        _, again = fleet.add_replica("bayeslr")
        assert again.name == f"{shard.name}#r3"
    finally:
        fleet.close()


def test_remove_replica_retires_newest_and_guards_the_last():
    fleet = _tiny_fleet()  # 2 launch replicas
    fleet.warm()
    try:
        _, added = fleet.add_replica("bayeslr")
        assert fleet.remove_replica("bayeslr", replica_name=added.name) \
            == added.name
        shard = fleet.shards("bayeslr")[0]
        assert added not in shard.replicas
        assert fleet.replica_count("bayeslr") == 2
        with pytest.raises(KeyError):
            fleet.remove_replica("bayeslr", replica_name=added.name)
        # no name: the newest goes first
        newest = shard.replicas[-1].name
        assert fleet.remove_replica("bayeslr") == newest
        assert fleet.replica_count("bayeslr") == 1
        with pytest.raises(ValueError, match="last replica"):
            fleet.remove_replica("bayeslr")
        assert fleet.replica_count("bayeslr") == 1
    finally:
        fleet.close()


def test_attach_lane_serves_and_detach_reroutes_cleanly():
    fleet = _tiny_fleet(replicas=1)
    fleet.warm()
    try:
        spec = fleet.spec("bayeslr", "predictive")
        router = FleetRouter(fleet, priorities={"predictive": 0},
                             max_batch=4, default_deadline_s=30.0)
        shard, replica = fleet.add_replica("bayeslr")
        router.attach_lane(shard, replica)
        reqs = []
        for i in range(12):
            xs = spec.make_queries(jax.random.key(i), 2)
            reqs.append((xs, router.submit("bayeslr", "predictive", xs)))
        router.drain()
        for xs, req in reqs:
            want, _ = shard.writer.query(spec, xs)
            np.testing.assert_array_equal(
                np.asarray(req.result()), np.asarray(want))
        lanes = router._lanes["bayeslr"]
        assert len(lanes) == 2
        assert all(l.served > 0 for l in lanes)  # least-loaded used both
        # detach with a backlog queued: the pending work reroutes, nothing
        # is dropped, and the surviving lane keeps serving
        tail = []
        for i in range(6):
            xs = spec.make_queries(jax.random.key(100 + i), 2)
            tail.append((xs, router.submit("bayeslr", "predictive", xs)))
        assert router.detach_lane("bayeslr", replica.name) is True
        fleet.remove_replica("bayeslr", replica_name=replica.name)
        router.drain()
        for xs, req in tail:
            want, _ = shard.writer.query(spec, xs)
            np.testing.assert_array_equal(
                np.asarray(req.result()), np.asarray(want))
        assert router.slo_report()["errors"] == 0
    finally:
        fleet.close()
