"""Chaos/fault-tolerance: replica death mid-load, router recovery, resync.

The fast tier kills in-process replicas (``ReplicaEnsemble.kill`` raises
``ReplicaDeadError`` from every subsequent RPC, exactly like a dead
process-group pipe) and asserts the router's recovery contract: the dead
lane's in-flight batch and backlog reroute to live lanes, nothing is
dropped, and after ``restart()`` + a full-resync the revived replica serves
bit-for-bit what the writer serves. The slow tier drives the same sequence
through ``serve --fleet --soak`` with one-OS-process-per-replica transport
and a real SIGKILL (the CI chaos smoke greps the same ``SOAK_OK`` line).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.fleet import Fleet, FleetConfig, FleetRouter, ReplicaDeadError
from repro.serving import FreshnessPolicy, ServingConfig

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tiny_fleet(replicas=2, shards=1) -> Fleet:
    fleet = Fleet(FleetConfig(
        replicas=replicas,
        shards=shards,
        transport="inproc",
        serving=ServingConfig(
            num_chains=2, refresh_steps=8, window=16, micro_batch=8,
            max_batch=4,
            freshness=FreshnessPolicy(max_staleness_s=1e9, min_draws=8),
            seed=0,
        ),
    ))
    fleet.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    fleet.warm()
    return fleet


def test_kill_mid_load_reroutes_without_dropping_requests():
    """A replica dies with requests queued on its lane: the router marks the
    lane dead once, moves the stranded batch + backlog to the live lane, and
    every request still completes (correctly) without an error."""
    fleet = _tiny_fleet()
    try:
        shard = fleet.shards("bayeslr")[0]
        victim = shard.replicas[1]
        spec = fleet.spec("bayeslr", "predictive")
        router = FleetRouter(fleet, priorities={"predictive": 1, "vote": 0},
                             max_batch=4, default_deadline_s=30.0)
        reqs, queries = [], []
        for i in range(12):
            xs = spec.make_queries(jax.random.key(i), 3)
            queries.append(xs)
            reqs.append(router.submit("bayeslr", "predictive", xs))
        victim.kill()  # both lanes hold pending work at this point
        served = router.drain()
        assert len(served) == len(reqs)
        report = router.slo_report()
        assert report["errors"] == 0
        assert report["recovery"]["lane_deaths"] == 1
        assert report["recovery"]["rerouted"] >= 1
        assert report["recovery"]["dead_lanes"] == 1
        assert router.dead_lanes == 1
        # rerouted answers are the same bits the writer would serve
        for xs, req in zip(queries, reqs):
            want, _ = shard.writer.query(spec, xs)
            np.testing.assert_array_equal(
                np.asarray(req.result()), np.asarray(want))
    finally:
        fleet.close()


def test_restart_resyncs_bit_exact_and_revives_lane():
    fleet = _tiny_fleet()
    try:
        shard = fleet.shards("bayeslr")[0]
        victim = shard.replicas[1]
        spec = fleet.spec("bayeslr", "predictive")
        router = FleetRouter(fleet, priorities={"predictive": 0},
                             max_batch=4, default_deadline_s=30.0)
        victim.kill()
        assert not victim.alive and not victim.ping()
        with pytest.raises(ReplicaDeadError):
            victim.serve(spec, "predictive", spec.make_queries(jax.random.key(0), 2))
        for i in range(4):  # land work on both lanes (least-loaded routing)
            router.submit("bayeslr", "predictive",
                          spec.make_queries(jax.random.key(1 + i), 2))
        router.drain()  # lane death observed here
        assert router.dead_lanes == 1
        assert router.revive() == 0  # still dead: ping fails, stays dead

        full_before = fleet.sync_stats["full_deltas"]
        victim.restart()
        assert victim.alive and victim.version == 0  # empty, needs resync
        fleet.sync_shard(shard)
        assert fleet.sync_stats["full_deltas"] == full_before + 1
        assert victim.version == shard.writer.steps_done
        assert router.revive() == 1 and router.dead_lanes == 0

        xs = spec.make_queries(jax.random.key(2), 8)
        want, _ = shard.writer.query(spec, xs)
        got, _ = victim.serve(spec, "predictive", xs)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    finally:
        fleet.close()


def test_submit_fails_fast_when_every_lane_is_dead():
    fleet = _tiny_fleet(replicas=1)
    try:
        shard = fleet.shards("bayeslr")[0]
        spec = fleet.spec("bayeslr", "predictive")
        router = FleetRouter(fleet, priorities={"predictive": 0},
                             max_batch=4, default_deadline_s=30.0)
        shard.replicas[0].kill()
        first = router.submit("bayeslr", "predictive",
                              spec.make_queries(jax.random.key(0), 2))
        router.drain()  # death observed; no live lane left to reroute to
        assert first.done.is_set() and "ReplicaDeadError" in first.error
        # subsequent submissions fail at intake, not after a timeout
        second = router.submit("bayeslr", "predictive",
                               spec.make_queries(jax.random.key(1), 2))
        assert second.done.is_set() and "no live replica lanes" in second.error
        report = router.slo_report()
        assert report["errors"] == 2
        with pytest.raises(RuntimeError, match="ReplicaDeadError"):
            first.result()
    finally:
        fleet.close()


def test_fleet_sync_skips_dead_replica_and_recovers():
    """A dead replica must not wedge the shard's delta stream: sync skips it
    (recording the error), keeps the live replica fresh, and heals after a
    restart."""
    fleet = _tiny_fleet()
    try:
        shard = fleet.shards("bayeslr")[0]
        live, victim = shard.replicas
        victim.kill()
        fleet.pump("bayeslr")
        assert fleet.sync_stats["skipped_dead"] >= 1
        assert live.version == shard.writer.steps_done  # live lane kept fresh
        errors = fleet.report()["errors"]
        assert any("#r1" in k for k in errors)
        stats = fleet.report()["shards"]["bayeslr@0"]["replicas"]
        assert any(s.get("alive") is False for s in stats)

        victim.restart()
        fleet.pump("bayeslr")
        assert victim.version == shard.writer.steps_done
        assert fleet.report()["errors"] == {}
    finally:
        fleet.close()


def test_worker_threads_route_around_death_under_live_load():
    """Background lane workers (the serve --fleet path): kill a replica while
    workers are actively serving; no request may hang or error."""
    fleet = _tiny_fleet()
    try:
        shard = fleet.shards("bayeslr")[0]
        spec = fleet.spec("bayeslr", "predictive")
        router = FleetRouter(fleet, priorities={"predictive": 1, "vote": 0},
                             max_batch=4, default_deadline_s=30.0)
        router.start_workers(max_wait_s=0.001)
        try:
            reqs = []
            for i in range(30):
                xs = spec.make_queries(jax.random.key(i), 2)
                reqs.append(router.submit("bayeslr", "predictive", xs))
                if i == 10:
                    shard.replicas[1].kill()
            for req in reqs:
                req.result(timeout_s=60.0)  # raises on error, hangs if dropped
        finally:
            router.stop_workers()
        report = router.slo_report()
        assert report["errors"] == 0
        assert report["recovery"]["lane_deaths"] == 1
        assert report["classes"]["bayeslr.predictive"]["count"] == 30
    finally:
        fleet.close()


@pytest.mark.slow
def test_soak_sigkills_replica_process_and_recovers():
    """End-to-end chaos soak over one-OS-process-per-replica transport: a
    live ReplicaProcess is SIGKILLed mid-load, the router reroutes, the
    respawned worker full-resyncs, and the run ends SOAK_OK with bit-exact
    writer parity — the same line the CI chaos smoke greps."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--fleet", "--soak",
         "--smoke", "--workload", "bayeslr", "--soak-seconds", "8",
         "--replica-transport", "proc", "--stats-addr", "127.0.0.1:0"],
        capture_output=True, text=True, timeout=900,
        cwd=_REPO, env={**os.environ, "PYTHONPATH": "src"},
    )
    out = proc.stdout
    assert proc.returncode == 0, f"stdout:\n{out}\nstderr:\n{proc.stderr[-4000:]}"
    soak_line = next(l for l in out.splitlines() if l.startswith("SOAK_OK"))
    assert "kills=1" in soak_line and "recovered=1" in soak_line
    assert "top_class_errors=0" in soak_line
    assert "parity=ok(bitexact)" in soak_line
    assert "resyncs=0" not in soak_line
    assert "STATS_OK" in out  # live endpoint answered under load


def test_closed_loop_overload_scales_up_then_quiesce_scales_down(tmp_path):
    """The full observability loop in-proc: an admission overload recorded
    on the slo stream fires ``admission_overload``, the autoscaler traces
    that alert into a scale-up whose replica serves bit-exact, and after
    the queue drains the quiesce path retires exactly the replica it added
    — with the whole decision history on the ``autoscale`` stream."""
    from repro.fleet import AdmissionConfig, AutoScaleConfig, AutoScaler
    from repro.obs import AlertEngine, Recorder, SLOSampler, default_rules

    fleet = _tiny_fleet(replicas=1)
    rec = Recorder(str(tmp_path), run_id="loop")
    try:
        router = FleetRouter(fleet, priorities={"predictive": 1, "vote": 0},
                             max_batch=4, default_deadline_s=30.0,
                             admission=AdmissionConfig(max_depth=8))
        sampler = SLOSampler(rec, router)
        engine = AlertEngine(rec, default_rules("bayeslr", "predictive",
                                                max_depth=8))
        scaler = AutoScaler(
            fleet, router, "bayeslr",
            AutoScaleConfig(min_replicas=1, max_replicas=2, scale_up_depth=8,
                            scale_down_depth=2, quiesce_ticks=2,
                            cooldown_s=0.0),
            recorder=rec, engine=engine)
        spec_v = fleet.spec("bayeslr", "vote")

        # Overload: flood the low class until the shed floor rises.
        shed = 0
        for i in range(32):
            req = router.submit("bayeslr", "vote",
                                spec_v.make_queries(jax.random.key(i), 2))
            if req.error and req.error.startswith("shed"):
                shed += 1
        assert shed >= 1
        sampler.sample()
        engine.evaluate()
        assert "admission_overload" in engine.firing()

        # The alert becomes the scale-up, and the newcomer is bit-exact.
        decision = scaler.tick()
        assert decision["action"] == "scale_up"
        assert decision["reason"] == "alert:admission_overload"
        assert fleet.replica_count("bayeslr") == 2
        shard = fleet.shards("bayeslr")[0]
        newcomer = shard.replicas[-1]
        spec_p = fleet.spec("bayeslr", "predictive")
        xs = spec_p.make_queries(jax.random.key(99), 4)
        want, _ = shard.writer.query(spec_p, xs)
        got, _ = newcomer.serve(spec_p, "predictive", xs)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

        # Drain through both lanes, then quiesce: the alert resolves and
        # two calm ticks retire exactly the replica the scaler added.
        router.drain()
        sampler.sample()
        engine.evaluate()
        assert "admission_overload" not in engine.firing()
        assert scaler.tick()["action"] == "hold"  # calm 1 of 2
        down = scaler.tick()
        assert down["action"] == "scale_down"
        assert down["replica"] == newcomer.name
        assert fleet.replica_count("bayeslr") == 1
        assert scaler.events == {"scale_up": 1, "scale_down": 1, "blocked": 0}

        rec.close()
        alerts = rec.read_stream("alerts")
        assert any(e["rule"] == "admission_overload" and e["to"] == "firing"
                   for e in alerts)
        decisions = rec.read_stream("autoscale")
        assert [d["action"] for d in decisions] == ["scale_up", "scale_down"]
        assert decisions[0]["alerts_firing"] != ""
    finally:
        rec.close()
        fleet.close()
