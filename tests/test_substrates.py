"""Substrate tests: data determinism, checkpoint/restart, fault tolerance,
optimizers, distributed sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.configs import ARCHS, reduce_config
from repro.data import DataConfig, MarkovStream, TokenStream
from repro.distributed.sharding import DEFAULT_RULES, resolve_spec
from repro.optim import adam_init, adam_step, lm_loss_fn, sgd_step
from repro.runtime import InjectedFailure, LoopConfig, run_loop


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_token_stream_deterministic_and_step_dependent():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b1, b2 = s1.batch(5), s2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = s1.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_markov_stream_has_learnable_structure():
    cfg = DataConfig(vocab=16, seq_len=64, global_batch=32, seed=0)
    stream = MarkovStream(cfg, concentration=0.15)
    tok = np.asarray(stream.batch(0)["tokens"])
    # empirical bigram distribution should be far from uniform
    joint = np.zeros((16, 16))
    for row in tok:
        for a, b in zip(row[:-1], row[1:]):
            joint[a, b] += 1
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    assert cond.max(axis=1).mean() > 2.5 / 16, "transitions should be peaked"


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (4, 8), jnp.bfloat16),
            "b": jnp.arange(3, dtype=jnp.float32),
        },
        "step_stats": (jnp.asarray(2), jnp.asarray(0.5)),
    }


def test_checkpoint_roundtrip_bf16(tmp_path):
    state = _state()
    ckpt.save(str(tmp_path), 7, state)
    step, restored = ckpt.restore(str(tmp_path), target=state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_checkpoint_latest_and_cleanup(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_0000000004", "step_0000000005"]


def test_checkpoint_async(tmp_path):
    t = ckpt.save_async(str(tmp_path), 1, _state())
    t.join(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    ckpt.save(str(tmp_path), 1, _state())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# Fault tolerance: crash + resume reproduces the uninterrupted trajectory
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mh_loop_setup():
    """(params, jitted step, stream) shared by the fault-tolerance tests —
    one train-step compile for the whole module."""
    from repro.bayes import TrainConfig, make_train_step

    rc = reduce_config(ARCHS["chatglm3-6b"])
    tc = TrainConfig(round_batch=2, max_rounds=2, epsilon=0.3, sigma=5e-3)
    from repro.models import init_params

    params = init_params(jax.random.key(0), rc)
    step = jax.jit(make_train_step(rc, tc))
    data = DataConfig(vocab=rc.vocab, seq_len=16, global_batch=4, seed=1)
    stream = TokenStream(data)
    return params, step, stream


def test_crash_restart_resumes_identically(tmp_path, mh_loop_setup):
    params, step, stream = mh_loop_setup
    d_clean, d_crash = str(tmp_path / "clean"), str(tmp_path / "crash")

    clean = run_loop(step, params, stream.batch,
                     LoopConfig(num_steps=6, ckpt_dir=d_clean, ckpt_every=2, seed=9))

    with pytest.raises(InjectedFailure):
        run_loop(step, params, stream.batch,
                 LoopConfig(num_steps=6, ckpt_dir=d_crash, ckpt_every=2, seed=9,
                            fail_at_step=4))
    resumed = run_loop(step, params, stream.batch,
                       LoopConfig(num_steps=6, ckpt_dir=d_crash, ckpt_every=2, seed=9))
    for a, b in zip(jax.tree.leaves(clean["params"]), jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_preemption_flag_checkpoints_and_raises(tmp_path, mh_loop_setup):
    from repro.runtime import PreemptionRequested

    params, step, stream = mh_loop_setup
    flag = str(tmp_path / "preempt")
    d = str(tmp_path / "ck")
    run_loop(step, params, stream.batch,
             LoopConfig(num_steps=3, ckpt_dir=d, ckpt_every=1, seed=9))
    open(flag, "w").close()
    with pytest.raises(PreemptionRequested):
        run_loop(step, params, stream.batch,
                 LoopConfig(num_steps=6, ckpt_dir=d, ckpt_every=1, seed=9,
                            preempt_flag=flag))
    assert ckpt.latest_step(d) is not None


# ---------------------------------------------------------------------------
# Optimizers (the SGD/Adam substrate for hybrid inference)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_adam_reduces_lm_loss():
    rc = reduce_config(ARCHS["chatglm3-6b"])
    from repro.models import init_params

    params = init_params(jax.random.key(0), rc)
    data = DataConfig(vocab=rc.vocab, seq_len=32, global_batch=8, seed=0)
    stream = MarkovStream(data, concentration=0.15)
    loss_fn = lm_loss_fn(rc)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    state = adam_init(params)
    first = None
    for i in range(80):
        loss, grads = vg(params, stream.batch(i))
        params, state = adam_step(grads, state, params, lr=5e-3)
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.1, f"{first} -> {float(loss)}"


def test_sgd_step_moves_params():
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.ones((3,))}
    out = sgd_step(g, p, lr=0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_resolve_spec_divisibility_fallback():
    # fake mesh-shape view via a tiny namespace
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = resolve_spec((40, 128), ("q_heads", None), FakeMesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec()  # 40 % 16 != 0 -> replicated
    spec = resolve_spec((48, 128), ("q_heads", None), FakeMesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec("model")
    # uniqueness: two dims cannot claim the same axis
    spec = resolve_spec((16, 16), ("experts", "expert_mlp"), FakeMesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec("model")


def test_resolve_spec_kv_seq_prefers_model_then_data():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    # batch dim of 1 can't shard; kv_seq grabs model+data jointly
    spec = resolve_spec((1, 524288, 8, 128), ("batch", "kv_seq", "kv_heads", None),
                        FakeMesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec(None, ("model", "data"))
    # batch 128 takes pod+data; kv_seq falls back to model alone
    spec = resolve_spec((128, 32768, 8, 128), ("batch", "kv_seq", "kv_heads", None),
                        FakeMesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), "model")
