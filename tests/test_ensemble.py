"""Multi-chain ensemble engine: vmap-vs-sequential equivalence, cross-chain
diagnostics, batched sampler properties, multi-device fan-out."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core import (
    ChainEnsemble,
    RandomWalk,
    SubsampledMHConfig,
    ensemble_summary,
    fy_draw,
    fy_init,
    fy_reset,
    multichain_ess,
    run_chain,
    split_rhat,
)

# ---------------------------------------------------------------------------
# K vmapped chains == K sequential run_chain calls, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", ["subsampled", "exact"])
def test_ensemble_matches_sequential_chains_bit_for_bit(kernel, gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
    K, T = 3, 100
    ens = ChainEnsemble(target, RandomWalk(0.05), K, kernel=kernel, config=cfg)
    state = ens.init(jnp.zeros(()))
    keys = jax.random.split(jax.random.key(7), K)
    state, samples, infos = ens.run(keys, state, T)
    assert samples.shape == (K, T)
    for k in range(K):
        _, s_seq, i_seq = run_chain(
            keys[k], jnp.zeros(()), target, RandomWalk(0.05), T, kernel=kernel, config=cfg
        )
        np.testing.assert_array_equal(np.asarray(samples[k]), np.asarray(s_seq))
        np.testing.assert_array_equal(np.asarray(infos.accepted[k]), np.asarray(i_seq.accepted))
        np.testing.assert_array_equal(
            np.asarray(infos.n_evaluated[k]), np.asarray(i_seq.n_evaluated)
        )


def test_ensemble_chains_are_distinct(gaussian_target_factory):
    """Different per-chain keys must yield different trajectories."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    ens = ChainEnsemble(target, RandomWalk(0.05), 3,
                        config=SubsampledMHConfig(batch_size=50, epsilon=0.05))
    state, samples, _ = ens.run(jax.random.key(0), ens.init(jnp.zeros(())), 100)
    s = np.asarray(samples)
    assert not np.array_equal(s[0], s[1])
    assert not np.array_equal(s[1], s[2])


# ---------------------------------------------------------------------------
# Cross-chain diagnostics on a conjugate Gaussian target
# ---------------------------------------------------------------------------


def test_ensemble_rhat_near_one_on_conjugate_gaussian(gaussian_target_factory):
    target, pm, ps = gaussian_target_factory(n=400, seed=1)
    K, T = 4, 600
    ens = ChainEnsemble(target, RandomWalk(0.08), K,
                        config=SubsampledMHConfig(batch_size=200, epsilon=0.05))
    # overdispersed starts around the posterior, per-chain
    theta0 = jnp.asarray([-1.0, -0.3, 0.3, 1.0]) + pm
    state = ens.init(theta0, batched=True)
    state, samples, infos = ens.run(jax.random.key(2), state, T)
    w = np.asarray(samples)[:, T // 2:]
    rhat = split_rhat(w)
    assert rhat < 1.1, f"chains did not mix: rhat={rhat}"
    assert abs(w.mean() - pm) < 6 * ps
    assert multichain_ess(w) > 4 * 10  # at least ~10 effective draws per chain
    summ = ensemble_summary(infos)
    assert summ["accept_rate"].shape == (K,)
    assert 0.0 < summ["accept_rate_overall"] < 1.0
    assert summ["mean_n_evaluated_overall"] < target.num_sections


def test_split_rhat_flags_disjoint_chains():
    rng = np.random.default_rng(0)
    good = rng.normal(0.0, 1.0, size=(4, 400))
    bad = good + np.asarray([0.0, 0.0, 5.0, 5.0])[:, None]
    assert split_rhat(good) < 1.05
    assert split_rhat(bad) > 1.5
    # vectorized over trailing param dims
    stacked = np.stack([good, bad], axis=-1)
    r = split_rhat(stacked)
    assert r.shape == (2,)
    assert r[0] < 1.05 < r[1]


# ---------------------------------------------------------------------------
# Batched Fisher–Yates: per-chain draws stay distinct and in range
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([2, 4]), st.sampled_from([10, 37]), st.sampled_from([8, 16]),
       st.integers(0, 2**31 - 1))
def test_batched_fy_draws_distinct_and_in_range_per_chain(k_chains, n, m, seed):
    state = jax.vmap(lambda _: fy_reset(fy_init(n)))(jnp.arange(k_chains))
    keys = jax.random.split(jax.random.key(seed), k_chains)
    vdraw = jax.jit(jax.vmap(fy_draw, in_axes=(0, 0, None)), static_argnums=2)
    drawn = [[] for _ in range(k_chains)]
    rounds = -(-n // m)
    for r in range(rounds):
        keys = jax.vmap(lambda kk: jax.random.split(kk)[0])(keys)
        subs = jax.vmap(lambda kk: jax.random.split(kk)[1])(keys)
        state, idx, valid = vdraw(subs, state, m)
        for c in range(k_chains):
            drawn[c].extend(np.asarray(idx[c])[np.asarray(valid[c])].tolist())
    for c in range(k_chains):
        assert len(drawn[c]) == n
        assert set(drawn[c]) == set(range(n)), "per-chain exhaustive draw must be a permutation"


def test_batched_fy_chains_use_independent_randomness():
    n, m, k_chains = 50, 10, 4
    state = jax.vmap(lambda _: fy_reset(fy_init(n)))(jnp.arange(k_chains))
    keys = jax.random.split(jax.random.key(3), k_chains)
    _, idx, _ = jax.vmap(fy_draw, in_axes=(0, 0, None))(keys, state, m)
    rows = [tuple(np.asarray(idx[c]).tolist()) for c in range(k_chains)]
    assert len(set(rows)) > 1, "chains drew identical mini-batches"


# ---------------------------------------------------------------------------
# Engine plumbing
# ---------------------------------------------------------------------------


def test_ensemble_state_persists_across_runs(gaussian_target_factory):
    """The carried EnsembleState fully determines the continuation: same
    (state, key) -> identical trajectories; different carried state ->
    different trajectories."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
    ens = ChainEnsemble(target, RandomWalk(0.05), 2, config=cfg)
    keys = jax.random.split(jax.random.key(11), 2)
    st_a, s_a, _ = ens.run(keys, ens.init(jnp.zeros(())), 60)
    # purity: continuing twice from the same state with the same key is
    # bit-identical (state is consumed, never mutated in place)
    _, s_c1, _ = ens.run(jax.random.key(12), st_a, 10)
    _, s_c2, _ = ens.run(jax.random.key(12), st_a, 10)
    np.testing.assert_array_equal(np.asarray(s_c1), np.asarray(s_c2))
    # the carried state matters: same key from a fresh init diverges
    _, s_fresh, _ = ens.run(jax.random.key(12), ens.init(jnp.zeros(())), 10)
    assert not np.array_equal(np.asarray(s_c1), np.asarray(s_fresh))
    # and the continuation picks up where the first run left off
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(st_a.theta)[0]), np.asarray(s_a[:, -1])
    )


def test_ensemble_collect_and_pytree_theta(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
    ens = ChainEnsemble(
        target, RandomWalk(0.05), 3, config=cfg, collect=lambda th: th * 2.0
    )
    state, samples, _ = ens.run(jax.random.key(0), ens.init(jnp.zeros(())), 20)
    assert samples.shape == (3, 20)


def test_ensemble_rejects_bad_kernel_and_shape(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, kernel="nope")
    ens = ChainEnsemble(target, RandomWalk(0.05), 4)
    with pytest.raises(ValueError):
        ens.init(jnp.zeros((3,)), batched=True)  # 3 != num_chains 4


@pytest.mark.slow
def test_ensemble_shard_map_matches_single_device(gaussian_target_factory):
    """Chains sharded over 4 forced host devices == unsharded ensemble."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig, from_iid_loglik

n = 400
x = 0.7 + jnp.asarray(jax.random.normal(jax.random.key(1), (n,)))
target = from_iid_loglik(lambda th: -0.5 * jnp.sum(th**2),
                         lambda th, idx: -0.5 * (x[idx] - th) ** 2, None, n)
cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
keys = jax.random.split(jax.random.key(5), 8)

sharded = ChainEnsemble(target, RandomWalk(0.05), 8, config=cfg, shard=True)
local = ChainEnsemble(target, RandomWalk(0.05), 8, config=cfg, shard=False)
_, s_sh, _ = sharded.run(keys, sharded.init(jnp.zeros(())), 60)
_, s_lo, _ = local.run(keys, local.init(jnp.zeros(())), 60)
print(json.dumps({
    "n_devices": len(jax.devices()),
    "max_diff": float(np.max(np.abs(np.asarray(s_sh) - np.asarray(s_lo)))),
}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=repo, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    import json

    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_devices"] == 4
    assert res["max_diff"] < 1e-5, res
