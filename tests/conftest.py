"""Shared fixtures: seeded PRNGs and session-cached expensive setups.

The `slow` marker is registered in pyproject.toml (and defensively here);
the default run excludes it via `addopts = "-m 'not slow'"` so the tier-1
command stays CPU-minutes cheap. Run `pytest -m slow` (or override with
`-m ''`) for the full-size chains and subprocess multi-device cases.
"""
import os

import numpy as np
import pytest

# Persistent XLA compilation cache: the tier-1 suite is dominated by jit
# compiles of the MH-in-while_loop graphs, which are identical run to run.
# Warm runs cut compile time ~5x. Safe to enable unconditionally (the dir is
# created lazily; unsupported backends just ignore it).
try:
    import jax

    jax.config.update(
        "jax_compilation_cache_dir",
        os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), ".jax_cache"),
    )
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
except Exception:  # pragma: no cover - very old jax
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: minute-plus cases excluded from the default run"
    )


@pytest.fixture
def rng(request):
    """Per-test numpy Generator seeded from the test id (stable across runs)."""
    import zlib

    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


@pytest.fixture
def key(request):
    """Per-test jax PRNG key seeded from the test id."""
    import zlib

    import jax

    return jax.random.key(zlib.crc32(request.node.nodeid.encode()))


# ---------------------------------------------------------------------------
# Session-scoped caches for expensive jitted setups. Building the reduced LM
# (params + first jitted step) and the conjugate-Gaussian target dominates
# several modules' runtime; sharing them collapses that to one compile each.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="session")
def lm_setup():
    """(reduced_config, params, 8x24 token batch) for the chatglm3-6b LM —
    the `_setup()` tuple test_bayes builds per test, built once per session."""
    import jax

    from repro.configs import ARCHS, reduce_config
    from repro.data import DataConfig, TokenStream
    from repro.models import init_params

    rc = reduce_config(ARCHS["chatglm3-6b"])
    params = init_params(jax.random.key(0), rc)
    batch = TokenStream(
        DataConfig(vocab=rc.vocab, seq_len=24, global_batch=8, seed=0)
    ).batch(0)
    return rc, params, batch


@pytest.fixture(scope="session")
def conjugate_posterior():
    """The subposterior ground-truth harness: a D=2 conjugate Gaussian-mean
    model (prior N(0, I), x_i ~ N(theta, I)) whose exact posterior is
    ``N(n xbar / (n+1), I/(n+1))``, plus a memoized ``run(P)`` that returns
    the P per-partition subsampled-MH windows (each (K, W, D)) sampled
    against the stride-partitioned, prior-tempered slice targets.

    Session-scoped and lazy: each P's chains run once, shared by every
    statistical test. ``run(1)`` is the unpartitioned reference chain.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import (
        ChainEnsemble,
        RandomWalk,
        SubsampledMHConfig,
        build_target,
    )
    from repro.partition import partition_target

    n, d, chains, burn, keep, seed = 768, 2, 4, 250, 350, 3
    theta_true = jnp.asarray([0.6, -0.3])
    x = theta_true + jax.random.normal(jax.random.key(seed), (n, d))
    target = build_target(
        "gaussian_mean", x, n,
        prior_logpdf=lambda th: -0.5 * jnp.sum(th ** 2, axis=-1),
    )
    xbar = np.asarray(jnp.mean(x, axis=0), np.float64)
    cache = {}

    def run(num_partitions):
        if num_partitions not in cache:
            draws = []
            for p, t in enumerate(partition_target(target, num_partitions)):
                cfg = SubsampledMHConfig(
                    batch_size=min(128, t.num_sections), epsilon=0.005,
                    sampler="stream",
                )
                # proposal scaled to the subposterior width sqrt(P/(n+1))
                sigma = 1.7 * float(np.sqrt(num_partitions / (n + 1.0)))
                ens = ChainEnsemble(t, RandomWalk(sigma), chains, config=cfg)
                state = ens.init(jnp.zeros(d))
                key = jax.random.fold_in(jax.random.key(seed + 1), p)
                state, _, _ = ens.run(
                    None, state, burn, step_keys=ens.step_keys(key, 0, burn)
                )
                state, samples, _ = ens.run(
                    None, state, keep, step_keys=ens.step_keys(key, burn, keep)
                )
                draws.append(np.asarray(samples))
            cache[num_partitions] = draws
        return cache[num_partitions]

    return {
        "n": n,
        "d": d,
        "chains": chains,
        "target": target,
        "data": x,
        "post_mean": n * xbar / (n + 1.0),
        "post_var": 1.0 / (n + 1.0),
        "run": run,
    }


@pytest.fixture(scope="session")
def gaussian_target_factory():
    """Memoized conjugate-Gaussian targets keyed by (n, seed): returns
    (PartitionedTarget, posterior_mean, posterior_std)."""
    import jax
    import jax.numpy as jnp

    from repro.core import from_iid_loglik

    cache = {}

    def build(n=1500, seed=1):
        if (n, seed) not in cache:
            x = 0.7 + jnp.asarray(jax.random.normal(jax.random.key(seed), (n,)))
            prior = lambda th: -0.5 * jnp.sum(th**2)
            loglik = lambda th, idx: -0.5 * (x[idx] - th) ** 2
            post_mean = float(x.sum() / (n + 1))
            post_std = float(np.sqrt(1.0 / (n + 1)))
            cache[(n, seed)] = (from_iid_loglik(prior, loglik, None, n), post_mean, post_std)
        return cache[(n, seed)]

    return build
