"""Architecture smoke + correctness tests (reduced configs, CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, reduce_config, shape_applicable
from repro.models import (
    abstract_cache,
    decode_step,
    forward_hidden,
    forward_loglik,
    init_params,
    param_specs,
    prefill,
)
from repro.models.layers import ParamSpec, _attend_dense, _attend_flash, moe_mlp

ARCH_NAMES = list(ARCHS)

# One representative per family runs in the fast tier; the full matrix runs
# under `-m slow` (and in the weekly CI job). Compile time per arch is the
# whole cost here, so the fast tier keeps one dense, one MoE, one SSM.
FAST_ARCHS = {"chatglm3-6b", "mixtral-8x22b", "xlstm-350m"}


def _tiered(names):
    return [
        n if n in FAST_ARCHS else pytest.param(n, marks=pytest.mark.slow)
        for n in names
    ]


def _make_batch(rc, b=2, s=32, seed=2):
    tokens = jax.random.randint(jax.random.key(seed), (b, s), 0, rc.vocab)
    batch = {"tokens": tokens, "mask": jnp.ones((b, s), jnp.int32)}
    if rc.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.key(seed + 1), (b, rc.n_audio_frames, rc.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("name", _tiered(ARCH_NAMES))
def test_arch_smoke_forward_loglik(name):
    rc = reduce_config(ARCHS[name])
    params = init_params(jax.random.key(1), rc)
    batch = _make_batch(rc)
    ll = jax.jit(lambda p, b: forward_loglik(p, b, rc))(params, batch)
    assert ll.shape == (2,)
    assert bool(jnp.isfinite(ll).all()), f"{name}: non-finite loglik"
    assert float(ll.max()) < 0.0, "loglik must be negative"


@pytest.mark.parametrize("name", _tiered(ARCH_NAMES))
def test_arch_smoke_train_step(name):
    """One subsampled-MH train step on the reduced config (CPU)."""
    from repro.bayes import TrainConfig, make_train_step

    rc = reduce_config(ARCHS[name])
    tc = TrainConfig(round_batch=2, max_rounds=2, epsilon=0.5, sigma=1e-4)
    params = init_params(jax.random.key(1), rc)
    batch = _make_batch(rc, b=4)
    step = jax.jit(make_train_step(rc, tc))
    new_params, info = step(jax.random.key(2), params, batch)
    leaves = jax.tree.leaves(new_params)
    assert all(bool(jnp.isfinite(l.astype(jnp.float32)).all()) for l in leaves), name
    assert info.rounds.dtype == jnp.int32


@pytest.mark.parametrize("name", [
    "xlstm-350m",  # fast-tier representative (cheapest compile)
    pytest.param("qwen1.5-32b", marks=pytest.mark.slow),
    pytest.param("mixtral-8x22b", marks=pytest.mark.slow),
    pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow),
    pytest.param("whisper-base", marks=pytest.mark.slow),
])
def test_decode_matches_teacher_forcing(name):
    """prefill + decode_step logits == full-forward logits at each position."""
    rc = reduce_config(ARCHS[name])
    params = init_params(jax.random.key(1), rc)
    b, s = 2, 24
    batch = _make_batch(rc, b=b, s=s)
    tokens = batch["tokens"]
    extra = {"frames": batch["frames"]} if rc.family == "audio" else None

    h = forward_hidden(params, tokens, rc, extra)
    from repro.models.layers import rms_norm  # noqa: F401 (final norm applied inside)

    full_logits = jnp.einsum(
        "bsd,vd->bsv", h, params["embed"]["table"]
    ).astype(jnp.float32)

    n_pre = s // 2
    cache, lg = prefill(params, tokens[:, :n_pre], rc, max_len=64, extra=extra)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, n_pre - 1]), rtol=0.15, atol=0.15
    )
    for t in range(n_pre, min(n_pre + 4, s)):
        cache, lg = decode_step(params, cache, tokens[:, t : t + 1], rc)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]), rtol=0.2, atol=0.2
        )


def test_flash_attention_matches_dense():
    key = jax.random.key(0)
    b, s, n_kv, group, hd = 2, 64, 2, 3, 16
    qg = jax.random.normal(key, (b, s, n_kv, group, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (b, s, n_kv, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (b, s, n_kv, hd), jnp.float32)
    pos = jnp.arange(s)
    for window in (1 << 30, 16):
        for causal in (True, False):
            dense = _attend_dense(qg, k, v, pos, pos, window, causal, hd**-0.5)
            flash = _attend_flash(
                qg, k, v, pos, pos, window, causal, hd**-0.5, chunk_q=16, chunk_kv=24
            )
            np.testing.assert_allclose(
                np.asarray(dense), np.asarray(flash), rtol=2e-3, atol=2e-3
            )


def test_moe_matches_dense_reference():
    """Capacity-bounded dispatch == explicit per-expert loop when capacity
    is large enough to drop nothing."""
    key = jax.random.key(0)
    b, s, d, f, e, k = 2, 8, 16, 32, 4, 2
    x = jax.random.normal(key, (b, s, d), jnp.float32)
    p = {
        "router": jax.random.normal(jax.random.key(1), (d, e)) * 0.1,
        "wi_gate": jax.random.normal(jax.random.key(2), (e, d, f)) * 0.1,
        "wi_up": jax.random.normal(jax.random.key(3), (e, d, f)) * 0.1,
        "wo": jax.random.normal(jax.random.key(4), (e, f, d)) * 0.1,
    }
    got = moe_mlp(x, p, top_k=k, capacity_factor=float(e))  # no drops

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    gate_all = jax.nn.softmax(logits, -1)
    gate, sel = jax.lax.top_k(gate_all, k)
    gate = gate / gate.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for ei in range(e):
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"][ei]))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"][ei])
        y = jnp.einsum("bsf,fd->bsd", g * u, p["wo"][ei])
        w = ((sel == ei) * gate).sum(-1)
        want = want + w[..., None] * y
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_sliding_window_ring_cache_matches_full_history():
    """Windowed decode with an O(window) ring == decode with a full cache."""
    import dataclasses

    rc = dataclasses.replace(reduce_config(ARCHS["mixtral-8x22b"]), window=8)
    params = init_params(jax.random.key(1), rc)
    tokens = jax.random.randint(jax.random.key(2), (1, 30), 0, rc.vocab)
    # ring cache (cache_len = window = 8)
    cache_r, _ = prefill(params, tokens[:, :20], rc, max_len=512)
    assert cache_r["k"].shape[2] == 8
    # full-history reference: window mask still applies, cache holds everything
    rc_full = dataclasses.replace(rc, window=None, local_window=8, global_every=None)
    # emulate: full cache but same window mask via explicit config is complex;
    # instead compare against teacher forcing directly
    h = forward_hidden(params, tokens, rc)
    full_logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"]).astype(jnp.float32)
    cache, lg = cache_r, None
    for t in range(20, 26):
        cache, lg = decode_step(params, cache, tokens[:, t : t + 1], rc)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, t]), rtol=0.2, atol=0.2
        )


@pytest.mark.parametrize("name", _tiered(ARCH_NAMES))
def test_param_specs_match_init(name):
    rc = reduce_config(ARCHS[name])
    specs = param_specs(rc)
    params = init_params(jax.random.key(0), rc)
    # jax.tree.leaves_with_path only exists in newer jax; use tree_util
    flat_s = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    assert len(flat_s) == len(flat_p)
    key_fn = lambda kv: str(kv[0])  # noqa: E731
    for (ps, spec), (pp, leaf) in zip(sorted(flat_s, key=key_fn), sorted(flat_p, key=key_fn)):
        assert ps == pp
        assert tuple(spec.shape) == tuple(leaf.shape), (ps, spec.shape, leaf.shape)
        assert len(spec.shape) == len(spec.logical), f"{ps}: logical axes rank mismatch"


def test_shape_applicability_matrix():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if shape_applicable(*c)[0]]
    skipped = [c for c in cells if not shape_applicable(*c)[0]]
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "qwen1.5-32b", "gemma3-4b", "internlm2-20b", "chatglm3-6b",
        "whisper-base", "chameleon-34b", "phi3.5-moe-42b-a6.6b",
    }
    assert len(runnable) == 33
