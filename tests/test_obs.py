"""Observability subsystem: Recorder streams/rollups, source adapters, the
HTTP stats endpoint, and the bench perf-regression gate.

The recorder tests run memory-only or against tmp_path; the gate tests
drive ``benchmarks/gate.py`` both ways on synthetic fixtures (unchanged
baseline must pass, a >15% p95 regression must fail) — the contract the CI
gate job relies on.
"""
import json
import os
import sys
import urllib.request

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs import (
    Recorder,
    SLOSampler,
    StatsServer,
    make_on_block,
    record_adaptation,
    record_fleet_sync,
    record_snapshot,
)
from repro.serving.resident import Snapshot

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)  # benchmarks/ is a repo-root package, not in src/

from benchmarks.gate import run_gate  # noqa: E402
from benchmarks import gate  # noqa: E402


# ---------------------------------------------------------------------------
# Recorder
# ---------------------------------------------------------------------------


def test_recorder_streams_roundtrip(tmp_path):
    with Recorder(str(tmp_path), run_id="r1", meta={"workload": "t"}) as rec:
        rec.record("slo", {"count": 1, "p95_ms": 10.0})
        rec.record("slo", count=3, p95_ms=30.0)
        rec.record("snapshot", {"staleness_s": 0.5})
        roll = rec.rollup()
    assert roll["run_id"] == "r1" and roll["meta"] == {"workload": "t"}
    slo = roll["streams"]["slo"]
    assert slo["count"] == 2 and slo["last"]["count"] == 3
    agg = slo["fields"]["p95_ms"]
    # The pre-tail-quantile keys stay byte-compatible...
    assert {k: agg[k] for k in ("count", "mean", "min", "max", "last")} == {
        "count": 2, "mean": 20.0, "min": 10.0, "max": 30.0, "last": 30.0}
    # ...and the streaming tails ride alongside (exact below 5 samples).
    assert agg["p50"] == 20.0 and agg["p95"] == pytest.approx(29.0)
    # JSONL round-trips and carries both time stamps
    back = rec.read_stream("slo")
    assert [r["count"] for r in back] == [1, 3]
    assert all("t" in r and "rel_s" in r for r in back)
    # meta.json at start, summary.json at close
    run_dir = tmp_path / "r1"
    assert json.loads((run_dir / "meta.json").read_text())["run_id"] == "r1"
    summary = json.loads((run_dir / "summary.json").read_text())
    assert summary["streams"]["snapshot"]["count"] == 1


def test_recorder_memory_only_and_numpy_safety():
    rec = Recorder()  # no root_dir: nothing touches disk
    rec.record("s", {"arr": np.arange(3), "np_int": np.int64(7),
                     "np_float": np.float32(1.5), "flag": True,
                     "label": "text", "nan": float("nan")})
    roll = rec.rollup()
    fields = roll["streams"]["s"]["fields"]
    assert fields["np_int"]["last"] == 7.0
    assert fields["np_float"]["last"] == 1.5
    assert fields["flag"]["last"] == 1.0  # bools aggregate as rates
    assert "label" not in fields and "arr" not in fields
    assert "nan" not in fields  # non-finite values don't poison aggregates
    assert rec.stream_path("s") is None and rec.read_stream("s") == []
    assert rec.write_summary() is None
    rec.close()
    with pytest.raises(RuntimeError, match="closed"):
        rec.record("s", {"x": 1})


def test_stats_server_serves_live_rollup():
    rec = Recorder()
    rec.record("slo", {"req_per_s": 12.0, "arr": np.ones(2)})
    server = StatsServer(rec, "127.0.0.1:0")
    try:
        with urllib.request.urlopen(server.url, timeout=10) as resp:
            assert resp.headers["Content-Type"] == "application/json"
            roll = json.loads(resp.read())
        assert roll["streams"]["slo"]["last"]["req_per_s"] == 12.0
        assert roll["streams"]["slo"]["last"]["arr"] == [1.0, 1.0]
        # live: a later record shows up on the next GET
        rec.record("slo", {"req_per_s": 24.0})
        with urllib.request.urlopen(server.url, timeout=10) as resp:
            roll = json.loads(resp.read())
        assert roll["streams"]["slo"]["last"]["req_per_s"] == 24.0
    finally:
        server.close()
        rec.close()


# ---------------------------------------------------------------------------
# Source adapters
# ---------------------------------------------------------------------------


class _FakeSource:
    """Minimal slo_report() source: two classes, mutable counters."""

    def __init__(self):
        self.count = 0
        self.floor = None

    def slo_report(self):
        return {
            "count": self.count,
            "errors": 0,
            "shed": 2,
            "admission": {"depth": 5, "predicted_miss_rate": 0.1,
                          "shed_floor": self.floor},
            "recovery": {"lane_deaths": 1, "rerouted": 3, "dead_lanes": 0},
            "classes": {
                "w.fast": {"count": self.count, "errors": 0, "admitted": 9,
                           "shed": 0, "priority": 1, "p50_ms": 1.0,
                           "p95_ms": 4.0, "p99_ms": 5.0,
                           "deadline_hit_rate": 1.0, "mean_batch_size": 2.0,
                           "staleness_mean_s": 0.25},
                "w.slow": {"count": 0, "errors": 0, "admitted": 0, "shed": 2,
                           "priority": 0, "p50_ms": None, "p95_ms": 9.0,
                           "p99_ms": None, "deadline_hit_rate": 0.0,
                           "mean_batch_size": None, "staleness_mean_s": None},
            },
        }


def test_slo_sampler_derives_rates_and_worst_class():
    rec = Recorder()
    src = _FakeSource()
    sampler = SLOSampler(rec, src)
    src.count = 10
    first = sampler.sample()
    assert "req_per_s" not in first  # no interval yet
    src.count = 20
    second = sampler.sample()
    assert second["req_per_s"] > 0
    assert second["p95_ms"] == 9.0  # worst class lifted to top level
    assert second["staleness_mean_s"] == 0.25
    assert second["w.fast.count"] == 20 and second["w.slow.shed"] == 2
    assert "w.slow.p50_ms" not in second  # None fields stay absent
    rec.close()


def test_slo_sampler_records_admission_transitions_only():
    rec = Recorder()
    src = _FakeSource()
    sampler = SLOSampler(rec, src)
    sampler.sample()          # initial floor None: establishes state, no event
    sampler.sample()          # unchanged: still no event
    src.floor = 1
    sampler.sample()          # None -> 1: one transition
    sampler.sample()          # unchanged
    src.floor = None
    sampler.sample()          # 1 -> None: second transition
    roll = rec.rollup()
    admission = roll["streams"]["admission"]
    assert admission["count"] == 2
    assert admission["last"]["shed_floor"] == -1  # None encoded as -1
    rec.close()


def _synthetic_snapshot(k=3, w=8):
    draws = np.cumsum(
        np.random.default_rng(0).normal(size=(k, w)), axis=1
    ).astype(np.float32)
    return Snapshot(draws=draws, num_draws=k * w, steps_done=64,
                    staleness_s=0.5, summary={}, created_at=0.0)


def test_record_snapshot_emits_freshness_diagnostics():
    rec = Recorder()
    out = record_snapshot(rec, "bayeslr", _synthetic_snapshot())
    assert out["workload"] == "bayeslr"
    assert out["staleness_s"] == 0.5 and out["steps_done"] == 64
    assert np.isfinite(out["rhat"]) and out["ess"] > 0
    # too-shallow window: diagnostics are omitted, not fabricated
    shallow = record_snapshot(rec, "b", _synthetic_snapshot(w=2))
    assert "rhat" not in shallow
    rec.close()


def test_record_adaptation_flattens_summary():
    rec = Recorder()
    summary = {
        "accept_rate": np.array([0.2, 0.4]),      # per-chain -> mean
        "mean_batch_frac": 0.125,                  # scalar -> direct
        "schedule": {"epsilon": 0.01},             # nested -> dotted
        "edges": {"hist": np.arange(5)},           # nested array -> dropped
    }
    out = record_adaptation(rec, "sv", summary)
    assert out["accept_rate_mean"] == pytest.approx(0.3)
    assert out["mean_batch_frac"] == 0.125
    assert out["schedule.epsilon"] == 0.01
    assert not any(k.startswith("edges") for k in out)
    assert record_adaptation(rec, "sv", {}) is None
    assert record_adaptation(rec, "sv", {"note": "text"}) is None
    rec.close()


def test_make_on_block_records_refresh_throughput(gaussian_target_factory):
    from repro.core import ChainEnsemble, RandomWalk

    target, _, _ = gaussian_target_factory(n=400, seed=5)
    ens = ChainEnsemble(target, RandomWalk(0.1), num_chains=2)
    rec = Recorder()
    _, out = ens.run_timed(jax.random.key(0), ens.init(jnp.zeros(())),
                           num_steps=6, block_every=2,
                           on_block=make_on_block(rec, "gauss"))
    assert out["next_step"] == 6
    refresh = rec.rollup()["streams"]["refresh"]
    assert refresh["count"] == 3  # one record per block
    assert refresh["last"]["steps_done"] == 6
    assert refresh["last"]["workload"] == "gauss"
    # the first block has no prior clock; later blocks report throughput
    assert refresh["fields"]["transitions_per_sec"]["count"] == 2
    assert refresh["fields"]["transitions_per_sec"]["min"] > 0
    assert 0.0 <= refresh["last"]["accept_rate"] <= 1.0
    rec.close()


def test_record_fleet_sync_accounts_delta_bytes():
    class _FakeFleet:
        sync_stats = {"syncs": 4, "full_deltas": 1, "skipped_dead": 0,
                      "delta_wire_bytes": 100, "full_wire_bytes": 400,
                      "delta_payload_bytes": 80, "full_payload_bytes": 300}

        def report(self):
            return {"shards": {"b@0": {"writer_steps": 64,
                                       "replica_versions": [64, 48]}},
                    "errors": {}}

    rec = Recorder()
    out = record_fleet_sync(rec, _FakeFleet())
    assert out["delta_ratio"] == 0.25
    assert out["b@0.writer_steps"] == 64
    assert out["b@0.min_replica_version"] == 48
    assert out["sync_errors"] == 0 and out["full_deltas"] == 1
    rec.close()


# ---------------------------------------------------------------------------
# benchmarks/gate.py — the CI perf-regression gate
# ---------------------------------------------------------------------------


def _write_bench(dirpath, p95=20.0, qps=1000.0, tps=5000.0):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, "BENCH_serving.json"), "w") as f:
        json.dump({"bench": "serving", "records": [
            {"kind": "queries", "K": 4, "max_batch": 16,
             "qps": qps, "p50_ms": 5.0, "p95_ms": p95, "p99_ms": 2 * p95},
        ]}, f)
    with open(os.path.join(dirpath, "BENCH_multichain.json"), "w") as f:
        json.dump({"bench": "multichain", "records": [
            {"engine": "ensemble", "N": 2000, "K": 8, "steps": 64,
             "tps_e2e": tps * 0.9, "tps_steady": tps},
        ]}, f)


def test_gate_passes_on_unchanged_fixture(tmp_path):
    _write_bench(tmp_path / "prev")
    _write_bench(tmp_path / "cur")
    code = gate.main(["--previous", str(tmp_path / "prev"),
                      "--current", str(tmp_path / "cur"),
                      "--benches", "serving,multichain"])
    assert code == 0
    verdict = json.loads((tmp_path / "cur" / "GATE_verdict.json").read_text())
    assert verdict["status"] == "pass"
    assert verdict["checked"] > 0 and verdict["regressions"] == []


def test_gate_fails_on_p95_regression(tmp_path):
    _write_bench(tmp_path / "prev", p95=20.0)
    _write_bench(tmp_path / "cur", p95=25.0)  # +25% > 15% threshold
    code = gate.main(["--previous", str(tmp_path / "prev"),
                      "--current", str(tmp_path / "cur"),
                      "--benches", "serving,multichain"])
    assert code == 1
    verdict = json.loads((tmp_path / "cur" / "GATE_verdict.json").read_text())
    assert verdict["status"] == "fail"
    regressed = {(r["record"].split("/")[0], r["metric"])
                 for r in verdict["regressions"]}
    assert regressed == {("serving", "p95_ms"), ("serving", "p99_ms")}


def test_gate_fails_on_throughput_drop_but_tolerates_small_noise(tmp_path):
    verdict = run_gate(str(tmp_path / "prev"), str(tmp_path / "cur"))
    # throughput down 50%: fail; 10% noise on qps: within threshold
    _write_bench(tmp_path / "prev", qps=1000.0, tps=5000.0)
    _write_bench(tmp_path / "cur", qps=900.0, tps=2500.0)
    verdict = run_gate(str(tmp_path / "prev"), str(tmp_path / "cur"),
                       benches=("serving", "multichain"))
    assert verdict["status"] == "fail"
    metrics = {r["metric"] for r in verdict["regressions"]}
    assert metrics == {"tps_e2e", "tps_steady"}  # the 10% qps dip passes


def test_gate_no_baseline_passes_unless_strict(tmp_path):
    _write_bench(tmp_path / "cur")
    verdict = run_gate(str(tmp_path / "nope"), str(tmp_path / "cur"),
                       benches=("serving",))
    assert verdict["status"] == "no_baseline"
    strict = run_gate(str(tmp_path / "nope"), str(tmp_path / "cur"),
                      benches=("serving",), fail_on_missing=True)
    assert strict["status"] == "fail"


def test_gate_new_record_without_baseline_is_reported_not_failed(tmp_path):
    _write_bench(tmp_path / "prev")
    _write_bench(tmp_path / "cur")
    # current grows a record the baseline never measured (new K config)
    path = tmp_path / "cur" / "BENCH_serving.json"
    payload = json.loads(path.read_text())
    payload["records"].append({"kind": "queries", "K": 8, "max_batch": 16,
                               "qps": 1.0, "p95_ms": 1e9})
    path.write_text(json.dumps(payload))
    verdict = run_gate(str(tmp_path / "prev"), str(tmp_path / "cur"),
                       benches=("serving",))
    assert verdict["status"] == "pass"
    assert any("K=8" in m.get("record", "") for m in verdict["missing"])


# ---------------------------------------------------------------------------
# Streaming tail quantiles (P^2) in the rollup field aggregates
# ---------------------------------------------------------------------------


def test_p2_quantiles_track_numpy_percentiles():
    rec = Recorder()
    xs = np.random.default_rng(3).normal(loc=5.0, scale=2.0, size=4000)
    for x in xs:
        rec.record("lat", {"ms": float(x)})
    agg = rec.rollup()["streams"]["lat"]["fields"]["ms"]
    # Streaming estimates stay within a few percent of the exact tails
    # while the aggregator holds O(1) state (5 markers per quantile).
    assert agg["p50"] == pytest.approx(np.percentile(xs, 50), abs=0.15)
    assert agg["p95"] == pytest.approx(np.percentile(xs, 95), abs=0.25)
    assert agg["count"] == len(xs)
    rec.close()


def test_p2_quantiles_exact_below_five_samples():
    rec = Recorder()
    for v in (3.0, 1.0, 2.0):
        rec.record("s", {"v": v})
    agg = rec.rollup()["streams"]["s"]["fields"]["v"]
    assert agg["p50"] == 2.0  # exact sorted-buffer interpolation
    assert agg["p95"] == pytest.approx(np.percentile([1.0, 2.0, 3.0], 95))
    rec.close()


# ---------------------------------------------------------------------------
# SLOSampler counter-reset handling
# ---------------------------------------------------------------------------


def test_slo_sampler_clamps_negative_rate_on_counter_reset():
    rec = Recorder()
    src = _FakeSource()
    sampler = SLOSampler(rec, src)
    src.count = 100
    sampler.sample()
    src.count = 150
    assert sampler.sample()["req_per_s"] > 0
    # The source restarts (fleet failover): its completed counter resets.
    src.count = 10
    reset_rec = sampler.sample()
    assert reset_rec["req_per_s"] == 0.0  # clamped, never negative
    fields = rec.rollup()["streams"]["slo"]["fields"]
    assert fields["counter_reset"]["count"] == 1  # exactly one marker record
    assert fields["count_before"]["last"] == 150.0
    assert fields["count_after"]["last"] == 10.0
    # The very next interval reports a sane positive rate again.
    src.count = 30
    assert sampler.sample()["req_per_s"] > 0
    rec.close()


def test_slo_sampler_counter_reset_marker_lands_on_stream(tmp_path):
    rec = Recorder(str(tmp_path), run_id="reset")
    src = _FakeSource()
    sampler = SLOSampler(rec, src)
    src.count = 50
    sampler.sample()
    src.count = 5  # reset
    out = sampler.sample()
    assert out["req_per_s"] == 0.0
    records = rec.read_stream("slo")
    resets = [r for r in records if r.get("counter_reset")]
    assert len(resets) == 1
    assert resets[0]["count_before"] == 50 and resets[0]["count_after"] == 5
    rec.close()


# ---------------------------------------------------------------------------
# Sublinear-evidence telemetry (transition_cost stream)
# ---------------------------------------------------------------------------


def test_record_transition_cost_single_op():
    from repro.obs import record_transition_cost

    rec = Recorder()
    summary = {"accept_rate_overall": 0.4, "mean_n_evaluated_overall": 12.5,
               "mean_rounds_overall": 2.0}
    out = record_transition_cost(rec, "bayeslr", summary, num_sections=100)
    assert out["frac_data_touched"] == pytest.approx(0.125)
    assert out["frac_data_touched"] < 1.0  # the sublinear evidence
    assert out["mean_n_evaluated"] == 12.5
    assert out["num_sections"] == 100
    last = rec.rollup()["streams"]["transition_cost"]["last"]
    assert last["workload"] == "bayeslr"
    rec.close()


def test_record_transition_cost_composite_per_op_breakdown():
    from repro.obs import record_transition_cost

    rec = Recorder()
    summary = {
        "theta": {"mean_n_evaluated_overall": 10.0, "mean_rounds_overall": 1.5},
        "z": {"mean_n_evaluated_overall": 40.0},
        "sweep": {"accept_rate_overall": 1.0},  # no subsampling info
    }
    out = record_transition_cost(
        rec, "jointdpm", summary, num_sections={"theta": 100, "z": 80}
    )
    assert out["theta.frac_data_touched"] == pytest.approx(0.1)
    assert out["z.frac_data_touched"] == pytest.approx(0.5)
    assert out["frac_data_touched"] == pytest.approx(0.3)  # mean over ops
    assert "sweep.frac_data_touched" not in out
    rec.close()


def test_record_transition_cost_skips_unsubsampled_summary():
    from repro.obs import record_transition_cost

    rec = Recorder()
    assert record_transition_cost(rec, "w", {"accept_rate_overall": 1.0}) is None
    assert record_transition_cost(rec, "w", {}) is None
    assert "transition_cost" not in rec.rollup()["streams"]
    rec.close()


# ---------------------------------------------------------------------------
# StatsServer paths: /spans, /stages, /sublinear
# ---------------------------------------------------------------------------


def test_stats_server_spans_stages_and_sublinear_paths():
    from repro.obs import Tracer, record_transition_cost

    rec = Recorder()
    tracer = Tracer(recorder=rec)
    root = tracer.new_trace("request:w.q", workload="w")
    child = tracer.start(root["trace_id"], "queue_wait", "queue_wait",
                         parent_id=root["span_id"])
    tracer.finish(child)
    tracer.finish(root)
    record_transition_cost(rec, "w", {"mean_n_evaluated_overall": 5.0},
                           num_sections=50)
    server = StatsServer(rec, "127.0.0.1:0", tracer=tracer)
    try:
        base = server.url.rstrip("/")
        with urllib.request.urlopen(base + "/spans", timeout=10) as resp:
            spans = json.loads(resp.read())
        assert spans["count"] == 2 and spans["dropped"] == 0
        assert {s["stage"] for s in spans["spans"]} == {"request", "queue_wait"}
        with urllib.request.urlopen(base + "/stages", timeout=10) as resp:
            stages = json.loads(resp.read())
        assert set(stages["stages"]) == {"request", "queue_wait"}
        assert stages["trace_count"] == 1
        assert stages["stages"]["request"]["mean_ms"] >= \
            stages["stages"]["queue_wait"]["mean_ms"]
        with urllib.request.urlopen(base + "/sublinear", timeout=10) as resp:
            sub = json.loads(resp.read())
        assert sub["available"] is True
        assert sub["frac_data_touched"]["mean"] == pytest.approx(0.1)
        assert sub["frac_data_touched"]["mean"] < 1.0
        with urllib.request.urlopen(base + "/", timeout=10) as resp:
            roll = json.loads(resp.read())
        assert "streams" in roll
    finally:
        server.close()
        rec.close()


def test_stats_server_unknown_path_is_json_404_listing_routes():
    import urllib.error

    rec = Recorder()
    server = StatsServer(rec, "127.0.0.1:0")
    try:
        with pytest.raises(urllib.error.HTTPError) as exc_info:
            urllib.request.urlopen(server.url.rstrip("/") + "/nope",
                                   timeout=10)
        err = exc_info.value
        assert err.code == 404
        body = json.loads(err.read())
        assert "unknown path" in body["error"]
        assert {"/", "/alerts", "/health", "/healthz"} <= set(body["routes"])
    finally:
        server.close()
        rec.close()


def test_stats_server_healthz_alerts_and_health_paths():
    from repro.obs import AlertEngine, AlertRule

    rec = Recorder(run_id="probe")
    server = StatsServer(rec, "127.0.0.1:0")
    try:
        base = server.url.rstrip("/")
        with urllib.request.urlopen(base + "/healthz", timeout=10) as resp:
            hz = json.loads(resp.read())
        assert hz == {"ok": True, "run_id": "probe"}
        # no engine attached yet: /alerts degrades, /health still grades
        with urllib.request.urlopen(base + "/alerts", timeout=10) as resp:
            assert json.loads(resp.read()) == {"available": False}
        with urllib.request.urlopen(base + "/health", timeout=10) as resp:
            health = json.loads(resp.read())
        assert health["status"] == "ok" and health["score"] == 1.0
        # the serve front-end attaches the engine after the server is up
        rule = AlertRule(name="hot", stream="slo", field="p95_ms",
                         op=">", threshold=10.0, for_samples=1,
                         clear_samples=1, severity="page")
        engine = AlertEngine(rec, [rule])
        server.alerts = engine
        rec.record("slo", p95_ms=99.0)
        engine.evaluate()
        with urllib.request.urlopen(base + "/alerts", timeout=10) as resp:
            status = json.loads(resp.read())
        assert status["available"] is True and status["firing"] == ["hot"]
        assert status["rules"]["hot"]["state"] == "firing"
        # a firing page alert drags /health to critical
        with urllib.request.urlopen(base + "/health", timeout=10) as resp:
            health = json.loads(resp.read())
        assert health["status"] == "critical" and health["firing"] == ["hot"]
        assert set(health["components"]) == {
            "queue", "router", "replicas", "writer", "sublinear"}
    finally:
        server.close()
        rec.close()


def test_alert_and_health_modules_load_lazily():
    """Every serve path imports repro.obs (via trace/recorder); a flags-off
    run must not even *load* the alerting layer. PEP 562 lazy exports keep
    the names importable while deferring the modules."""
    import subprocess

    code = (
        "import sys, repro.obs, repro.obs.trace, repro.obs.server\n"
        "assert 'repro.obs.alerts' not in sys.modules, 'alerts eager'\n"
        "assert 'repro.obs.health' not in sys.modules, 'health eager'\n"
        "from repro.obs import AlertEngine, health_report\n"
        "assert 'repro.obs.alerts' in sys.modules\n"
        "assert 'repro.obs.health' in sys.modules\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


# ---------------------------------------------------------------------------
# repro.obs.dash — one-shot terminal summary of a recorded run dir
# ---------------------------------------------------------------------------


def _dash_run_dir(tmp_path, close=True):
    from repro.obs import record_transition_cost

    rec = Recorder(str(tmp_path), run_id="dashrun")
    rec.record("slo", {"count": 4, "req_per_s": 120.0, "p95_ms": 8.0,
                       "shed": 2, "errors": 0, "dead_lanes": 0})
    record_transition_cost(rec, "w", {"mean_n_evaluated_overall": 5.0},
                           num_sections=50)
    rec.record("alerts", {"rule": "hot", "from": "pending", "to": "firing",
                          "severity": "page", "value": 99.0})
    rec.record("autoscale", {"action": "scale_up", "replica": "w@0#r1",
                             "replicas_before": 1, "replicas_after": 2,
                             "reason": "alert:hot"})
    run_dir = rec.dir
    if close:
        rec.close()
    else:
        rec._closed = True  # simulate a crash: streams flushed, no summary
        for f in rec._files.values():
            f.close()
    return run_dir


def test_dash_renders_summary_alerts_and_autoscale(tmp_path):
    import io

    from repro.obs import dash

    out = io.StringIO()
    assert dash.main([_dash_run_dir(tmp_path)], out=out) == 0
    text = out.getvalue()
    assert "run dashrun" in text
    assert "frac_data_touched mean=0.1000" in text
    assert "hot" in text and "fired x1" in text
    assert "STILL FIRING at exit: hot" in text
    assert "scale_up w@0#r1 replicas 1->2 (alert:hot)" in text


def test_dash_rebuilds_rollup_when_run_crashed_before_summary(tmp_path):
    import io

    from repro.obs import dash

    run_dir = _dash_run_dir(tmp_path, close=False)
    assert not os.path.exists(os.path.join(run_dir, "summary.json"))
    out = io.StringIO()
    assert dash.main([run_dir], out=out) == 0
    assert "run dashrun" in out.getvalue()  # rebuilt from raw streams


def test_dash_exits_2_on_missing_or_empty_run_dir(tmp_path):
    from repro.obs import dash

    assert dash.main([str(tmp_path / "nope")]) == 2
    empty = tmp_path / "empty"
    empty.mkdir()
    assert dash.main([str(empty)]) == 2


def test_stats_server_sublinear_unavailable_without_stream():
    rec = Recorder()
    server = StatsServer(rec, "127.0.0.1:0")
    try:
        with urllib.request.urlopen(server.url.rstrip("/") + "/sublinear",
                                    timeout=10) as resp:
            sub = json.loads(resp.read())
        assert sub["available"] is False
        with urllib.request.urlopen(server.url.rstrip("/") + "/stages",
                                    timeout=10) as resp:
            stages = json.loads(resp.read())
        assert stages["span_count"] == 0  # no tracer attached: empty view
    finally:
        server.close()
        rec.close()


# ---------------------------------------------------------------------------
# HistoryStore — the append-only run ring benchmarks/gate.py --trend reads
# ---------------------------------------------------------------------------


def test_history_store_appends_and_prunes_ring(tmp_path):
    from repro.obs import HistoryStore

    store = HistoryStore(str(tmp_path / "hist"), capacity=3)
    for i in range(5):
        art = tmp_path / f"run{i}"
        _write_bench(art, qps=1000.0 + i)
        (art / "GATE_verdict.json").write_text(json.dumps({"status": "pass"}))
        store.append(str(art), run_id=f"r{i}")
    assert len(store) == 3  # ring pruned to capacity
    ids = [r["id"] for r in store.runs()]
    assert all(any(f"r{i}" in rid for i in (2, 3, 4)) for rid in ids)
    # stored artifacts round-trip through gate.load_records
    newest = store.last(1)[0]
    recs = gate.load_records(store.run_dir(newest["id"]), "serving")
    assert recs and any(r["qps"] == 1004.0 for r in recs.values())
    assert os.path.exists(
        os.path.join(store.run_dir(newest["id"]), "GATE_verdict.json"))


def test_history_store_refuses_empty_and_rebuilds_index(tmp_path):
    from repro.obs import HistoryStore

    store = HistoryStore(str(tmp_path / "hist"))
    with pytest.raises(FileNotFoundError):
        store.append(str(tmp_path / "empty"))
    art = tmp_path / "run"
    _write_bench(art)
    store.append(str(art), run_id="only")
    # corrupt index: the store rebuilds from the run directories on disk
    (tmp_path / "hist" / "index.json").write_text("{not json")
    rebuilt = HistoryStore(str(tmp_path / "hist"))
    assert len(rebuilt) == 1
    assert "only" in rebuilt.runs()[0]["id"]
    rebuilt.append(str(art), run_id="second")  # next_seq survived the rebuild
    assert len(rebuilt) == 2


def test_history_store_accepts_bench_artifacts_without_verdict(tmp_path):
    """A run that crashed before (or never ran) the gate still joins the
    trend baseline: BENCH_*.json alone is enough, GATE_verdict.json is
    optional."""
    from repro.obs import HistoryStore

    art = tmp_path / "run"
    _write_bench(art, qps=1234.0)  # no GATE_verdict.json written
    store = HistoryStore(str(tmp_path / "hist"))
    run_id = store.append(str(art), run_id="noverdict")
    entry = store.runs()[0]
    assert entry["artifacts"] == ["BENCH_multichain.json",
                                  "BENCH_serving.json"]
    assert not os.path.exists(
        os.path.join(store.run_dir(run_id), "GATE_verdict.json"))
    # the trend gate consumes a verdict-less history entry like any other
    recs = gate.load_records(store.run_dir(run_id), "serving")
    assert any(r["qps"] == 1234.0 for r in recs.values())
    code = gate.main(["--trend", "--history", str(tmp_path / "hist"),
                      "--current", str(art), "--benches", "serving"])
    assert code == 0


def test_history_store_interleaved_appends_from_two_stores(tmp_path):
    """Two writers (e.g. racing CI jobs restoring the same cache) each hold
    a cached index: neither crashes nor clobbers the other's artifacts —
    the last index write wins, and an index rebuild recovers both runs
    with a collision-free next_seq."""
    from repro.obs import HistoryStore

    art = tmp_path / "run"
    _write_bench(art)
    root = tmp_path / "hist"
    store_a = HistoryStore(str(root))
    store_b = HistoryStore(str(root))  # cached next_seq=0, same as a's
    id_a = store_a.append(str(art), run_id="a")
    id_b = store_b.append(str(art), run_id="b")
    assert id_a == "000000-a" and id_b == "000000-b"  # same seq, two dirs
    assert os.path.isdir(store_a.run_dir(id_a))
    assert os.path.isdir(store_b.run_dir(id_b))
    # b wrote the index last: a fresh reader sees only b's entry...
    assert [r["id"] for r in HistoryStore(str(root)).runs()] == [id_b]
    # ...but a rebuild (corrupt/missing index) recovers both from disk,
    # and the next append lands past the collision.
    (root / "index.json").unlink()
    rebuilt = HistoryStore(str(root))
    assert [r["id"] for r in rebuilt.runs()] == [id_a, id_b]
    assert rebuilt.append(str(art), run_id="c") == "000001-c"
    assert len(rebuilt) == 3


# ---------------------------------------------------------------------------
# benchmarks/gate.py --trend — history-backed median + drift gating
# ---------------------------------------------------------------------------


def _trend_run(tmp_path, hist, name, **bench_kw):
    cur = tmp_path / name
    _write_bench(cur, **bench_kw)
    code = gate.main(["--trend", "--history", str(hist), "--current", str(cur),
                      "--benches", "serving,multichain"])
    verdict = json.loads((cur / "GATE_verdict.json").read_text())
    return code, verdict


def test_trend_gate_no_baseline_then_passes_against_history(tmp_path):
    hist = tmp_path / "hist"
    code, verdict = _trend_run(tmp_path, hist, "r0")
    assert code == 0 and verdict["status"] == "no_baseline"
    assert verdict["appended_run"] is not None  # first run seeds the store
    for i, (qps, p95) in enumerate([(1010.0, 19.8), (995.0, 20.1),
                                    (1005.0, 20.0)], start=1):
        code, verdict = _trend_run(tmp_path, hist, f"r{i}", qps=qps, p95=p95)
        assert code == 0 and verdict["status"] == "pass"
    # >= 3-run history now: the pass was judged against a real median
    assert verdict["history_runs"] >= 3
    assert verdict["checked"] > 0


def test_trend_gate_fails_on_median_regression_and_keeps_history_clean(tmp_path):
    hist = tmp_path / "hist"
    for i, qps in enumerate([1000.0, 1005.0, 995.0]):
        code, _ = _trend_run(tmp_path, hist, f"r{i}", qps=qps)
        assert code == 0
    code, verdict = _trend_run(tmp_path, hist, "bad", qps=600.0)  # -40%
    assert code == 1 and verdict["status"] == "fail"
    assert any(r["metric"] == "qps" for r in verdict["regressions"])
    assert verdict["appended_run"] is None  # failures never join the baseline
    from repro.obs import HistoryStore

    assert len(HistoryStore(str(hist))) == 3


def test_trend_gate_catches_monotone_drift_below_single_run_threshold(tmp_path):
    hist = tmp_path / "hist"
    # each step ~ -5%: never trips the 15% single-run gate...
    for i, qps in enumerate([1000.0, 950.0, 900.0, 860.0]):
        code, _ = _trend_run(tmp_path, hist, f"r{i}", qps=qps)
        assert code == 0
    # ...but the cumulative monotone slide does.
    code, verdict = _trend_run(tmp_path, hist, "slide", qps=820.0)
    assert code == 1
    drifts = [r for r in verdict["regressions"] if r.get("kind") == "drift"]
    assert drifts and drifts[0]["metric"] == "qps"
    assert drifts[0]["regression"] > 0.15
