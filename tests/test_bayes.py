"""Bayes-bridge tests: the LM-scale transition operator.

The (config, params, batch) tuple comes from the session-scoped ``lm_setup``
fixture (tests/conftest.py) — building the reduced LM once per session
instead of once per test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bayes import (
    LogLikCache,
    TrainConfig,
    make_cached_train_step,
    make_exact_step,
    make_train_step,
)
from repro.configs import ARCHS, reduce_config
from repro.data import DataConfig, TokenStream
from repro.models import init_params


def _setup(pool=8, seq=24, arch="chatglm3-6b"):
    rc = reduce_config(ARCHS[arch])
    params = init_params(jax.random.key(0), rc)
    batch = TokenStream(DataConfig(vocab=rc.vocab, seq_len=seq, global_batch=pool, seed=0)).batch(0)
    return rc, params, batch


def test_cached_step_matches_uncached_decisions(lm_setup):
    """The lazy loglik cache is a pure optimization: identical keys must give
    identical accept decisions and identical parameter trajectories."""
    rc, params, batch = lm_setup
    tc = TrainConfig(round_batch=2, epsilon=0.2, sigma=1e-3)
    base = jax.jit(make_train_step(rc, tc))
    cach = jax.jit(make_cached_train_step(rc, tc))
    th_b, th_c = params, params
    cache = LogLikCache.empty(8)
    for i in range(6):
        k = jax.random.fold_in(jax.random.key(5), i)
        th_b, info_b = base(k, th_b, batch)
        th_c, cache, info_c = cach(k, th_c, batch, cache)
        assert bool(info_b.accepted) == bool(info_c.accepted), f"step {i}"
        assert int(info_b.rounds) == int(info_c.rounds), f"step {i}"
    for a, b in zip(jax.tree.leaves(th_b), jax.tree.leaves(th_c)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2
        )


def test_cache_goes_stale_on_accept_and_warm_on_reject(lm_setup):
    rc, params, batch = lm_setup
    # force accept: huge epsilon makes the test decide after round 1; sigma=0
    # means theta'=theta, so mu_hat=0 and acceptance depends on mu0 only
    tc = TrainConfig(round_batch=4, epsilon=0.9, sigma=0.0)
    cach = jax.jit(make_cached_train_step(rc, tc))
    cache = LogLikCache.empty(8)
    _, cache, info = cach(jax.random.key(0), params, batch, cache)
    v = np.asarray(cache.valid)
    if bool(info.accepted):
        # only evaluated sections are valid after an accept (lazy staleness)
        assert v.sum() == int(info.n_evaluated)
    else:
        assert v.sum() >= int(info.n_evaluated)


def test_exact_step_is_deterministic_full_scan(lm_setup):
    rc, params, batch = lm_setup
    tc = TrainConfig(round_batch=4, sigma=1e-3)
    ex = jax.jit(make_exact_step(rc, tc))
    _, info1 = ex(jax.random.key(1), params, batch)
    _, info2 = ex(jax.random.key(1), params, batch)
    assert int(info1.n_evaluated) == 8  # full pool, always
    assert bool(info1.accepted) == bool(info2.accepted)


@pytest.mark.slow
def test_mala_proposal_step_runs():
    rc, params, batch = _setup(pool=4)
    tc = TrainConfig(round_batch=2, epsilon=0.3, proposal="mala", mala_step=1e-8)
    step = jax.jit(make_train_step(rc, tc))
    new_params, info = step(jax.random.key(2), params, batch)
    assert all(
        bool(jnp.isfinite(l.astype(jnp.float32)).all())
        for l in jax.tree.leaves(new_params)
    )


def test_propose_paths_freezes_other_leaves():
    rc, params, batch = _setup(pool=4)
    tc = TrainConfig(round_batch=2, epsilon=0.9, sigma=0.5,
                     propose_paths=("final_norm",))
    step = jax.jit(make_train_step(rc, tc))
    new_params, info = step(jax.random.key(3), params, batch)
    if bool(info.accepted):
        # embed table must be untouched; final_norm must have moved
        np.testing.assert_array_equal(
            np.asarray(params["embed"]["table"]), np.asarray(new_params["embed"]["table"])
        )
        assert not np.array_equal(
            np.asarray(params["final_norm"], dtype=np.float32),
            np.asarray(new_params["final_norm"], dtype=np.float32),
        )
