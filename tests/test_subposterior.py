"""Statistical ground-truth harness for data-parallel subposterior MCMC.

The conjugate Gaussian-mean model gives a closed-form posterior, so the
partition -> temper -> sample -> combine pipeline (:mod:`repro.partition`)
is tested against *exact* answers, not a reference chain:

  * partitioning covers/disjoints the pool; P=1 is the same object;
  * the tempered subposterior log-densities sum to the full posterior's;
  * consensus and density-product combination recover the exact posterior
    mean and covariance at P in {1, 2, 4};
  * combination is invariant under permuting the partitions;
  * fleet wiring: P=1 is bit-for-bit the unpartitioned serving path, P=2
    serves finite, deterministic combined answers through the router;
  * streaming append: any chunking equals a full rebuild on the
    concatenated pool (property-tested), the empty append is a no-op, and
    the freshness policy refuses pre-append windows (staleness reset
    regression).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import append_observations, build_target, spec_of
from repro.partition import (
    combine_draws,
    combine_snapshots,
    consensus_combine,
    flatten_draws,
    partition_append_indices,
    partition_indices,
    partition_target,
    product_moments,
    take_sections,
    trim_windows,
    unflatten_draws,
)

from _hypothesis_compat import HealthCheck, given, settings
from _hypothesis_compat import strategies as st

# ---------------------------------------------------------------------------
# Partitioner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ["stride", "block"])
@pytest.mark.parametrize("n,num_p", [(10, 1), (10, 3), (7, 7), (64, 4)])
def test_partition_indices_cover_and_disjoint(n, num_p, scheme):
    parts = partition_indices(n, num_p, scheme)
    assert len(parts) == num_p
    merged = np.concatenate(parts)
    assert sorted(merged.tolist()) == list(range(n))
    assert all(len(p) >= 1 for p in parts)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1  # balanced to within one row


def test_partition_indices_rejects_bad_shapes():
    with pytest.raises(ValueError):
        partition_indices(3, 4)
    with pytest.raises(ValueError):
        partition_indices(8, 0)
    with pytest.raises(ValueError):
        partition_indices(8, 2, "zigzag")


@pytest.mark.parametrize("n_before,n_new,num_p", [(10, 7, 3), (8, 1, 4), (5, 0, 2)])
def test_partition_append_indices_extend_stride_partition(n_before, n_new, num_p):
    """Appending chunk[idx_p] to shard p == stride-partitioning the concat."""
    parts_before = partition_indices(n_before, num_p)
    parts_after = partition_indices(n_before + n_new, num_p) if n_new else parts_before
    appended = partition_append_indices(n_before, n_new, num_p)
    for p in range(num_p):
        grown = np.concatenate([parts_before[p], appended[p] + n_before])
        np.testing.assert_array_equal(grown, parts_after[p])


def test_partition_append_indices_require_stride():
    with pytest.raises(ValueError):
        partition_append_indices(8, 4, 2, scheme="block")


def test_partition_p1_is_same_object(conjugate_posterior):
    target = conjugate_posterior["target"]
    parts = partition_target(target, 1)
    assert len(parts) == 1 and parts[0] is target


def test_tempered_subposteriors_sum_to_full_posterior(conjugate_posterior):
    """sum_p [ (1/P) log prior + local loglik ] == full log posterior."""
    target = conjugate_posterior["target"]
    theta = jnp.asarray([0.25, -0.8])
    full = float(target.log_density(theta))
    for num_p in (2, 4):
        parts = partition_target(target, num_p)
        assert all(p.spec.prior_scale == pytest.approx(1.0 / num_p) for p in parts)
        total = sum(float(p.log_density(theta)) for p in parts)
        assert total == pytest.approx(full, rel=1e-5, abs=1e-3)


# ---------------------------------------------------------------------------
# Combination math
# ---------------------------------------------------------------------------


def test_flatten_unflatten_roundtrip(rng):
    draws = {
        "a": rng.normal(size=(3, 5, 2)).astype(np.float32),
        "b": rng.normal(size=(3, 5)).astype(np.float32),
    }
    flat = flatten_draws(draws)
    assert flat.shape == (15, 3)
    back = unflatten_draws(flat, draws)
    for k in draws:
        np.testing.assert_array_equal(back[k], draws[k])


def test_trim_windows_keeps_trailing_draws(rng):
    a = rng.normal(size=(2, 10, 3))
    b = rng.normal(size=(2, 6, 3))
    ta, tb = trim_windows([a, b])
    np.testing.assert_array_equal(ta, a[:, -6:])
    np.testing.assert_array_equal(tb, b)
    with pytest.raises(ValueError):
        trim_windows([a, rng.normal(size=(3, 6, 3))])  # chain-count mismatch


def test_single_partition_combination_is_passthrough(rng):
    draws = rng.normal(size=(2, 8, 3))
    for method in ("consensus", "product"):
        assert combine_draws([draws], method) is draws


@pytest.mark.parametrize("num_p", [1, 2, 4])
@pytest.mark.parametrize("method", ["consensus", "product"])
def test_combination_recovers_conjugate_posterior(
    conjugate_posterior, num_p, method
):
    """The headline ground-truth bar: recombined subposterior MCMC draws
    match the closed-form posterior N(n xbar/(n+1), I/(n+1))."""
    cp = conjugate_posterior
    draws = cp["run"](num_p)
    combined = np.asarray(
        combine_draws(draws, method, seed=17), np.float64
    ).reshape(-1, cp["d"])
    post_std = np.sqrt(cp["post_var"])
    err_mean = np.max(np.abs(combined.mean(axis=0) - cp["post_mean"])) / post_std
    assert err_mean < 0.5, (
        f"P={num_p} {method}: combined mean off by {err_mean:.2f} "
        f"posterior std"
    )
    var_ratio = combined.var(axis=0, ddof=1) / cp["post_var"]
    assert np.all(var_ratio > 0.45) and np.all(var_ratio < 2.2), (
        f"P={num_p} {method}: variance ratio {var_ratio} outside [0.45, 2.2]"
    )


def test_p1_combination_matches_unpartitioned_chain(conjugate_posterior):
    """P=1 'combination' must be the unpartitioned window itself, bit for
    bit — there is nothing to combine."""
    draws = conjugate_posterior["run"](1)
    for method in ("consensus", "product"):
        out = combine_draws(draws, method)
        assert out is draws[0]


def test_combination_invariant_under_partition_permutation(conjugate_posterior):
    draws = conjugate_posterior["run"](4)
    perm = [2, 0, 3, 1]
    base = np.asarray(combine_draws(draws, "consensus"))
    permuted = np.asarray(combine_draws([draws[i] for i in perm], "consensus"))
    np.testing.assert_allclose(permuted, base, rtol=1e-8, atol=1e-10)
    flats = [flatten_draws(d) for d in draws]
    m0, c0 = product_moments(flats)
    m1, c1 = product_moments([flats[i] for i in perm])
    np.testing.assert_allclose(m1, m0, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(c1, c0, rtol=1e-10, atol=1e-12)


def test_consensus_requires_aligned_shapes(rng):
    with pytest.raises(ValueError):
        consensus_combine([rng.normal(size=(10, 2)), rng.normal(size=(8, 2))])


def test_combine_snapshots_versions_and_staleness(rng):
    from repro.serving.resident import Snapshot

    def snap(version, staleness):
        return Snapshot(
            draws=rng.normal(size=(2, 6, 2)),
            num_draws=12, steps_done=version, staleness_s=staleness,
            summary={}, created_at=0.0,
        )

    combined = combine_snapshots([snap(32, 0.5), snap(48, 2.5)], "consensus")
    assert combined.steps_done == 80  # version sum: the generation key
    assert combined.staleness_s == 2.5  # only as fresh as the stalest input
    assert combined.num_draws == 12
    assert combined.summary["combine"] == {
        "method": "consensus", "partitions": 2,
    }
    with pytest.raises(RuntimeError, match="no window"):
        combine_snapshots(
            [snap(1, 0.0), snap(2, 0.0)._replace(draws=None)], "consensus"
        )


# ---------------------------------------------------------------------------
# Streaming append: target rebuild properties
# ---------------------------------------------------------------------------


def _toy_target(x):
    return build_target(
        "gaussian_mean", jnp.asarray(x), int(np.shape(x)[0]),
        prior_logpdf=lambda th: -0.5 * jnp.sum(th ** 2, axis=-1),
    )


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.integers(min_value=1, max_value=7), min_size=0, max_size=4))
def test_append_chunking_matches_full_rebuild(chunk_sizes):
    """Any append order/chunking == one build on the concatenated pool:
    same spec data bitwise, same log density bitwise."""
    rng = np.random.default_rng(11)
    base = rng.normal(size=(9, 2)).astype(np.float32)
    extra = rng.normal(size=(sum(chunk_sizes), 2)).astype(np.float32)
    target = _toy_target(base)
    offset = 0
    for size in chunk_sizes:
        target = append_observations(target, extra[offset:offset + size])
        offset += size
    rebuilt = _toy_target(np.concatenate([base, extra], axis=0))
    assert target.num_sections == rebuilt.num_sections
    np.testing.assert_array_equal(
        np.asarray(spec_of(target).data), np.asarray(spec_of(rebuilt).data)
    )
    theta = jnp.asarray([0.3, -0.2])
    assert float(target.log_density(theta)) == float(rebuilt.log_density(theta))


def test_empty_append_is_identity():
    target = _toy_target(np.zeros((5, 2), np.float32))
    out = append_observations(target, np.zeros((0, 2), np.float32))
    assert out is target


# ---------------------------------------------------------------------------
# Streaming append: resident fold-in + freshness regression
# ---------------------------------------------------------------------------


def _make_resident(x, *, key, window=8, refresh_steps=4):
    from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig
    from repro.serving.resident import ResidentEnsemble

    target = _toy_target(x)
    cfg = SubsampledMHConfig(
        batch_size=min(16, target.num_sections), epsilon=0.01,
        sampler="stream",
    )
    ens = ChainEnsemble(target, RandomWalk(0.15), 2, config=cfg)
    return ResidentEnsemble(
        ens, jnp.zeros(2), key=key, window=window, refresh_steps=refresh_steps,
        name="stream-test",
    )


def test_resident_append_then_refresh_matches_concat_build(rng, key):
    """Appending before the first refresh == building on the concatenated
    pool: identical step-key schedule from the same base key, so the first
    window is bit-for-bit equal."""
    base = rng.normal(size=(20, 2)).astype(np.float32)
    extra = rng.normal(size=(12, 2)).astype(np.float32)
    streamed = _make_resident(base, key=key)
    added = streamed.append(extra)
    assert added == 12
    assert streamed.ensemble.target.num_sections == 32
    rebuilt = _make_resident(np.concatenate([base, extra]), key=key)
    streamed.refresh()
    rebuilt.refresh()
    np.testing.assert_array_equal(
        np.asarray(streamed.snapshot().draws), np.asarray(rebuilt.snapshot().draws)
    )


def test_resident_append_continues_running_chains(rng, key):
    """Mid-run append: steps_done and theta carry over (no restart), the
    window survives, and the next refresh advances the grown target."""
    base = rng.normal(size=(20, 2)).astype(np.float32)
    extra = rng.normal(size=(8, 2)).astype(np.float32)
    res = _make_resident(base, key=key)
    res.refresh()
    res.refresh()
    theta_before = np.asarray(res.state.theta)
    draws_before = np.asarray(res.snapshot().draws)
    assert res.steps_done == 8
    added = res.append(extra)
    assert added == 8
    assert res.steps_done == 8  # schedule position preserved
    np.testing.assert_array_equal(np.asarray(res.state.theta), theta_before)
    np.testing.assert_array_equal(np.asarray(res.snapshot().draws), draws_before)
    res.refresh()
    assert res.steps_done == 12
    assert res.ensemble.target.num_sections == 28


def test_resident_empty_append_is_bitwise_noop(rng, key):
    res = _make_resident(rng.normal(size=(10, 2)).astype(np.float32), key=key)
    res.refresh()
    target_before = res.ensemble.target
    state_before = res._state
    stale_before = res.snapshot().staleness_s
    assert res.append(np.zeros((0, 2), np.float32)) == 0
    assert res.ensemble.target is target_before
    assert res._state is state_before
    assert np.isfinite(stale_before)
    assert np.isfinite(res.snapshot().staleness_s)  # clock NOT reset


def test_append_resets_freshness_staleness(rng, key):
    """Regression: the max_staleness_s gate must refuse pre-append windows.
    Before the fix, staleness only tracked the last draw-refresh, so a
    just-refreshed resident kept serving the pre-append posterior as
    fresh after new observations arrived."""
    from repro.serving import FreshnessPolicy

    res = _make_resident(rng.normal(size=(16, 2)).astype(np.float32), key=key)
    policy = FreshnessPolicy(max_staleness_s=3600.0, min_draws=4)
    res.refresh()
    snap = res.snapshot()
    assert policy.is_fresh(snap), policy.stale_reason(snap)
    res.append(rng.normal(size=(4, 2)).astype(np.float32))
    snap = res.snapshot()
    assert snap.staleness_s == float("inf")
    reason = policy.stale_reason(snap)
    assert reason is not None and "stale" in reason
    # one refresh folds the appended data in and the gate re-admits
    res.refresh()
    assert policy.is_fresh(res.snapshot())


# ---------------------------------------------------------------------------
# Fleet wiring
# ---------------------------------------------------------------------------


_FLEET_KW = dict(n_train=96, d=3, batch_size=32)


def _fleet_serving_config():
    from repro.serving import FreshnessPolicy, ServingConfig

    return ServingConfig(
        num_chains=2, refresh_steps=4, window=8, micro_batch=16, max_batch=4,
        freshness=FreshnessPolicy(max_staleness_s=3600.0, min_draws=4),
        seed=0,
    )


def test_fleet_p1_bitexact_vs_unpartitioned_serving(key):
    """The P=1 fleet configuration IS the unpartitioned path: same shard
    names, same chain keys, and bit-for-bit the same windows as a plain
    resident built the way the pre-partition fleet built it."""
    from repro.fleet import Fleet, FleetConfig
    from repro.serving.resident import ResidentEnsemble
    from repro.serving.workloads import build_serving_workload

    scfg = _fleet_serving_config()
    fleet = Fleet(FleetConfig(replicas=1, subposterior=1, serving=scfg))
    (shard,) = fleet.add_workload("bayeslr", **_FLEET_KW)
    assert shard.name == "bayeslr@0" and shard.partition == 0
    assert fleet.num_partitions("bayeslr") == 1

    wl = build_serving_workload("bayeslr", num_chains=2, seed=0, **_FLEET_KW)
    reference = ResidentEnsemble(
        wl.ensemble, wl.theta0,
        key=jax.random.fold_in(jax.random.key(0), 0),
        window=scfg.window, refresh_steps=scfg.refresh_steps,
        micro_batch=scfg.micro_batch, name="reference",
    )
    for _ in range(3):
        shard.writer.refresh()
        reference.refresh()
    np.testing.assert_array_equal(
        np.asarray(shard.writer.snapshot().draws),
        np.asarray(reference.snapshot().draws),
    )
    fleet.close()


def test_fleet_p2_partitions_data_and_keys():
    from repro.fleet import Fleet, FleetConfig

    fleet = Fleet(
        FleetConfig(replicas=1, subposterior=2, serving=_fleet_serving_config())
    )
    shards = fleet.add_workload("bayeslr", **_FLEET_KW)
    assert [s.name for s in shards] == ["bayeslr@p0@0", "bayeslr@p1@0"]
    assert [s.partition for s in shards] == [0, 1]
    sections = [s.writer.ensemble.target.num_sections for s in shards]
    assert sum(sections) == _FLEET_KW["n_train"]
    specs = [spec_of(s.writer.ensemble.target) for s in shards]
    assert all(sp.prior_scale == pytest.approx(0.5) for sp in specs)
    fleet.close()


def test_fleet_p2_combined_serving_is_deterministic():
    """Router combine-at-query: P=2 queries complete with finite values,
    identical on repeat against unchanged windows, and report the max of
    the partitions' staleness."""
    from repro.fleet import Fleet, FleetConfig, FleetRouter

    fleet = Fleet(
        FleetConfig(replicas=2, subposterior=2, combine="consensus",
                    serving=_fleet_serving_config())
    )
    fleet.add_workload("bayeslr", **_FLEET_KW)
    fleet.warm()
    router = FleetRouter(fleet)
    wl = fleet.workload("bayeslr")
    cls = wl.default_class
    xs = wl.query_specs[cls].make_queries(jax.random.key(5), 8)

    def ask():
        req = router.submit("bayeslr", cls, xs)
        router.drain()
        assert req.error is None, req.error
        return np.asarray(req.values), req.staleness_s

    v1, stale1 = ask()
    v2, _ = ask()
    assert v1.shape == (8,) and np.all(np.isfinite(v1))
    np.testing.assert_array_equal(v1, v2)  # same windows -> same combine
    assert stale1 >= 0.0  # max over the partitions' window staleness
    # after a pump the combined window changes and queries still serve
    fleet.pump("bayeslr")
    v3, _ = ask()
    assert np.all(np.isfinite(v3))
    fleet.close()


def test_fleet_append_routes_rows_to_partitions(rng):
    from repro.fleet import Fleet, FleetConfig

    fleet = Fleet(
        FleetConfig(replicas=1, subposterior=2, serving=_fleet_serving_config())
    )
    shards = fleet.add_workload("bayeslr", **_FLEET_KW)
    n = _FLEET_KW["n_train"]
    before = [s.writer.ensemble.target.num_sections for s in shards]
    tspec = spec_of(fleet.workload("bayeslr").ensemble.target)
    idx = rng.integers(0, n, size=7)
    chunk = jax.tree.map(lambda a: np.asarray(a)[idx], tspec.data)
    added = fleet.append_observations("bayeslr", chunk)
    assert added == 7
    after = [s.writer.ensemble.target.num_sections for s in shards]
    expected = [
        len(p) for p in partition_append_indices(n, 7, 2)
    ]
    assert [a - b for a, b in zip(after, before)] == expected
    # per-partition slices match a from-scratch stride partition of concat
    merged = jax.tree.map(
        lambda a, b: np.concatenate([np.asarray(a), np.asarray(b)]),
        tspec.data, chunk,
    )
    for shard in shards:
        want = take_sections(merged, partition_indices(n + 7, 2)[shard.partition])
        got = spec_of(shard.writer.ensemble.target).data
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    fleet.close()


def test_replica_window_rpc_version_gate():
    from repro.fleet.replica import ReplicaEnsemble
    from repro.fleet.delta import make_delta
    from repro.serving.resident import Snapshot

    replica = ReplicaEnsemble("w0#r0")
    version, snap = replica.window()
    assert version == 0 and snap.draws is None
    rng = np.random.default_rng(0)
    source = Snapshot(
        draws=rng.normal(size=(2, 4, 3)), num_draws=8, steps_done=16,
        staleness_s=0.1, summary={}, created_at=0.0,
    )
    replica.apply_delta(make_delta(source, 0, 4, "w0"))
    version, snap = replica.window(-1)
    assert version == 16 and snap is not None
    np.testing.assert_array_equal(np.asarray(snap.draws), source.draws)
    version, snap = replica.window(16)  # caller already current
    assert version == 16 and snap is None
