"""Fused particle-Gibbs sweep: bit-for-bit compat mode, fast-mode
statistics, and full-cycle ensemble integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.experiments import stochvol
from repro.kernels.pgibbs import batched_pgibbs_sweep, pgibbs_sweep_fused


def _setup(k=3, s=40, t=6, seed=0):
    data = stochvol.synth(jax.random.key(seed), num_series=s, length=t)
    keys = jax.random.split(jax.random.key(seed + 1), k)
    h = 0.1 * jax.random.normal(jax.random.key(seed + 2), (k, s, t))
    phi = jnp.full((k,), 0.95)
    s2 = jnp.full((k,), 0.01)
    return data, keys, h, phi, s2


def test_compat_mode_bitwise_matches_opaque_vmap():
    # the bit-for-bit compatibility mode: the fused time-major scan with
    # the legacy per-(chain, series, step) RNG stream must reproduce the
    # original sequential-sweep vmap exactly
    data, keys, h, phi, s2 = _setup()
    params = stochvol.SVParams(phi[0], s2[0])
    want = jax.vmap(
        lambda k_, h_: stochvol.pgibbs_sweep(
            k_, data.obs, h_, params, num_particles=12
        )
    )(keys, h)
    got = batched_pgibbs_sweep(
        keys, data.obs, h, phi, s2, num_particles=12, mode="compat"
    )
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_single_chain_wrapper_bitwise_matches_legacy():
    data, keys, h, phi, s2 = _setup(k=1)
    params = stochvol.SVParams(phi[0], s2[0])
    want = stochvol.pgibbs_sweep(keys[0], data.obs, h[0], params, num_particles=8)
    got = pgibbs_sweep_fused(
        keys[0], data.obs, h[0], phi[0], s2[0], num_particles=8, mode="compat"
    )
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["fast", "compat"])
def test_sweep_output_shape_and_finite(mode):
    data, keys, h, phi, s2 = _setup(k=2, s=10, t=5)
    out = batched_pgibbs_sweep(
        keys, data.obs, h, phi, s2, num_particles=6, mode=mode
    )
    assert out.shape == h.shape
    assert np.isfinite(np.asarray(out)).all()


def test_fast_mode_tracks_latent_path():
    # fast mode uses a different (slab-granular) RNG stream and inverse-CDF
    # resampling: distributionally equivalent, numerically different — it
    # must still be a correct cSMC kernel that tracks the latent scale
    data = stochvol.synth(jax.random.key(0), num_series=30, length=5)
    h = jnp.zeros((1,) + data.obs.shape)
    phi = jnp.asarray([0.95])
    s2 = jnp.asarray([0.01])
    for i in range(10):
        h = batched_pgibbs_sweep(
            jax.random.split(jax.random.key(i), 1), data.obs, h, phi, s2,
            num_particles=40, mode="fast",
        )
    assert np.isfinite(np.asarray(h)).all()
    assert float(jnp.abs(h).mean()) < 5.0


def test_fast_and_compat_agree_in_distribution():
    # same invariant kernel: cross-sweep posterior means of the latent
    # magnitude must agree between the two RNG schemes to sampling noise
    data = stochvol.synth(jax.random.key(3), num_series=50, length=6)
    k = 16
    h0 = jnp.zeros((k,) + data.obs.shape)
    phi = jnp.full((k,), 0.95)
    s2 = jnp.full((k,), 0.01)
    means = {}
    for mode in ("fast", "compat"):
        h = h0
        acc = []
        for i in range(6):
            h = batched_pgibbs_sweep(
                jax.random.split(jax.random.key(100 + i), k), data.obs, h,
                phi, s2, num_particles=24, mode=mode,
            )
            if i >= 2:
                acc.append(np.asarray(h))
        means[mode] = float(np.mean(np.abs(np.stack(acc))))
    assert means["fast"] == pytest.approx(means["compat"], rel=0.25)


def test_cycle_compat_sweep_bitwise_matches_opaque_cycle():
    # the full composite cycle (sweep + two MH moves) with the fused compat
    # sweep must equal the legacy opaque-vmap cycle bit for bit across a
    # K-chain ensemble run
    from repro.core.ensemble import ChainEnsemble

    data = stochvol.synth(jax.random.key(5), num_series=20, length=4)
    theta0 = stochvol.init_theta(data.obs)
    runs = {}
    for sweep in ("opaque", "compat"):
        cyc = stochvol.make_inference_cycle(
            data.obs, num_particles=8, sweep=sweep
        )
        ens = ChainEnsemble(num_chains=3, transition=cyc,
                            collect=lambda th: th)
        _, samples, _ = ens.run(jax.random.key(6), ens.init(theta0), 5)
        runs[sweep] = jax.tree.map(np.asarray, samples)
    for leaf_a, leaf_b in zip(
        jax.tree.leaves(runs["opaque"]), jax.tree.leaves(runs["compat"])
    ):
        assert np.array_equal(leaf_a, leaf_b)


def test_resolve_sweep_env_and_validation(monkeypatch):
    assert stochvol.resolve_sweep("compat") == "compat"
    monkeypatch.setenv(stochvol.SWEEP_ENV_VAR, "opaque")
    assert stochvol.resolve_sweep("auto") == "opaque"
    monkeypatch.delenv(stochvol.SWEEP_ENV_VAR)
    assert stochvol.resolve_sweep("auto") == "fused"
    with pytest.raises(ValueError):
        stochvol.resolve_sweep("nope")
