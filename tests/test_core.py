"""Core algorithm tests: sequential test, samplers, exact + subsampled MH."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings
from _hypothesis_compat import strategies as st

from repro.core import (
    RandomWalk,
    SubsampledMHConfig,
    Welford,
    from_iid_loglik,
    fy_draw,
    fy_from_buffer,
    fy_init,
    fy_reset,
    mh_step,
    run_chain,
    sequential_test,
    student_t_sf,
    trial_run_report,
)


# ---------------------------------------------------------------------------
# Student-t survival function vs scipy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,df", [(0.0, 3), (0.5, 1), (1.3, 5), (2.1, 99), (4.5, 12), (10.0, 2)])
def test_student_t_sf_matches_scipy(t, df):
    from scipy import stats as ss

    np.testing.assert_allclose(float(student_t_sf(t, df)), ss.t.sf(t, df), atol=2e-5)


# ---------------------------------------------------------------------------
# Welford streaming statistics == batch statistics (property test)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.floats(-100, 100), min_size=4, max_size=60),
    st.integers(min_value=1, max_value=7),
)
def test_welford_streaming_equals_batch(values, chunk):
    arr = np.asarray(values, np.float32)
    w = Welford.empty()
    for i in range(0, len(arr), chunk):
        w = w.merge_batch(jnp.asarray(arr[i : i + chunk]))
    np.testing.assert_allclose(float(w.mean), arr.mean(), rtol=1e-4, atol=1e-4)
    if len(arr) > 1 and arr.std() > 1e-6:
        np.testing.assert_allclose(
            float(w.std), arr.std(ddof=1), rtol=2e-3, atol=1e-3
        )


def test_welford_mask():
    w = Welford.empty()
    vals = jnp.asarray([1.0, 2.0, 3.0, 99.0])
    w = w.merge_batch(vals, mask=jnp.asarray([True, True, True, False]))
    assert float(w.count) == 3
    np.testing.assert_allclose(float(w.mean), 2.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Fisher–Yates without-replacement sampler
# ---------------------------------------------------------------------------


# m and n come from small fixed menus so the jitted draw compiles a handful
# of times instead of once per random example (fy_draw's batch size is a
# static argument; free-ranging integers forced a retrace every example).
_FY_JIT = jax.jit(fy_draw, static_argnums=2)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([10, 33, 128, 200]), st.sampled_from([1, 7, 40]),
       st.integers(0, 2**31 - 1))
def test_fy_draws_are_distinct_and_in_range(n, m, seed):
    state = fy_reset(fy_init(200))._replace(size=jnp.asarray(n, jnp.int32))
    key = jax.random.key(seed)
    drawn = []
    while True:
        key, sub = jax.random.split(key)
        state, idx, valid = _FY_JIT(sub, state, m)
        drawn.extend(np.asarray(idx)[np.asarray(valid)].tolist())
        if not bool(np.asarray(valid).all()) or len(drawn) >= n:
            break
    assert len(drawn) == len(set(drawn)), "without-replacement violated"
    assert all(0 <= d < n for d in drawn)
    if len(drawn) == n:
        assert set(drawn) == set(range(n)), "exhaustive draw must be a permutation"


def test_fy_is_uniform():
    # empirical check: first drawn element uniform over [0, n)
    n, trials = 8, 1500
    counts = np.zeros(n)
    state0 = fy_init(n)
    draw = jax.jit(lambda k, s: fy_draw(k, s, 2))
    for t in range(trials):
        _, idx, _ = draw(jax.random.key(t), fy_reset(state0))
        counts[int(idx[0])] += 1
    freq = counts / trials
    assert np.all(np.abs(freq - 1 / n) < 4 * np.sqrt((1 / n) * (1 - 1 / n) / trials) + 0.01)


def test_fy_dynamic_pool_size():
    # logical pool smaller than the buffer: draws stay within the prefix
    buf = jnp.arange(100, dtype=jnp.int32)
    state = fy_from_buffer(buf, 7)
    key = jax.random.key(0)
    state, idx, valid = fy_draw(key, fy_reset(state), 10)
    got = np.asarray(idx)[np.asarray(valid)]
    assert len(got) == 7 and set(got.tolist()) == set(range(7))


# ---------------------------------------------------------------------------
# Sequential test: agrees with the exact decision when epsilon is tiny,
# evaluates fewer sections when the decision is easy
# ---------------------------------------------------------------------------


import functools


@functools.lru_cache(maxsize=None)
def _jitted_seq_test(n, m, eps):
    """One compile per (n, m, eps); l_values/mu0 stay traced so the property
    test's examples all hit the same executable."""

    def f(key, l_values, mu0):
        return sequential_test(
            key=key,
            mu0=mu0,
            draw_fn=fy_draw,
            eval_fn=lambda idx: l_values[idx],
            sampler_state=fy_reset(fy_init(n)),
            num_sections=n,
            batch_size=m,
            epsilon=eps,
        )

    return jax.jit(f)


def _run_test(l_values, mu0, m=20, eps=0.05, seed=0):
    l_values = jnp.asarray(l_values, jnp.float32)
    n = l_values.shape[0]
    return _jitted_seq_test(n, m, eps)(
        jax.random.key(seed), l_values, jnp.asarray(mu0, jnp.float32)
    )


def test_sequential_test_easy_decision_is_sublinear():
    rng = np.random.default_rng(0)
    l = rng.normal(5.0, 1.0, size=5000)  # mean >> mu0=0: trivially accept
    res = _run_test(l, mu0=0.0, m=50, eps=0.05)
    assert bool(res.decision)
    assert int(res.n_evaluated) <= 200, "easy decision should stop early"


def test_sequential_test_exhaustion_gives_exact_decision():
    rng = np.random.default_rng(1)
    l = rng.normal(0.0, 1.0, size=300)
    mu0 = float(l.mean()) - 1e-4  # decision within noise: must exhaust
    res = _run_test(l, mu0=mu0, m=50, eps=1e-6)
    assert int(res.n_evaluated) == 300
    assert bool(res.decision) == bool(l.mean() > mu0)


def test_sequential_test_zero_variance_guard():
    l = np.full(200, 2.0)  # s_l = 0 everywhere: must exhaust, then exact
    res = _run_test(l, mu0=1.0, m=20, eps=0.05)
    assert bool(res.decision)
    assert int(res.n_evaluated) == 200


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_sequential_test_error_rate_bounded(seed):
    """Property: with well-separated decisions the test matches the exact
    rule (the paper's claim that errors concentrate on hard decisions)."""
    rng = np.random.default_rng(seed)
    mu_true = rng.choice([-1.0, 1.0]) * rng.uniform(0.5, 2.0)
    l = rng.normal(mu_true, 1.0, size=2000)
    res = _run_test(l, mu0=0.0, m=100, eps=0.01, seed=seed)
    assert bool(res.decision) == (l.mean() > 0.0)


# ---------------------------------------------------------------------------
# MH correctness on a conjugate Gaussian (exact posterior known). Targets come
# from the session-cached gaussian_target_factory fixture (tests/conftest.py).
# ---------------------------------------------------------------------------


def test_exact_mh_recovers_conjugate_posterior(gaussian_target_factory):
    target, pm, ps = gaussian_target_factory(n=800)
    _, samples, infos = run_chain(
        jax.random.key(0), jnp.zeros(()), target, RandomWalk(0.07), 2000, kernel="exact"
    )
    w = np.asarray(samples)[500:]
    assert abs(w.mean() - pm) < 4 * ps
    np.testing.assert_allclose(w.std(), ps, rtol=0.35)


def test_subsampled_mh_recovers_conjugate_posterior_and_subsamples(gaussian_target_factory):
    target, pm, ps = gaussian_target_factory(n=800)
    cfg = SubsampledMHConfig(batch_size=200, epsilon=0.05)
    _, samples, infos = run_chain(
        jax.random.key(0), jnp.zeros(()), target, RandomWalk(0.07), 1500,
        kernel="subsampled", config=cfg,
    )
    w = np.asarray(samples)[400:]
    assert abs(w.mean() - pm) < 5 * ps
    np.testing.assert_allclose(w.std(), ps, rtol=0.5)
    assert np.mean(np.asarray(infos.n_evaluated)) < target.num_sections


def test_exact_mh_chunked_equals_unchunked(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=500)
    th1, s1, i1 = run_chain(jax.random.key(3), jnp.zeros(()), target, RandomWalk(0.1), 50, kernel="exact")
    th2, s2, i2 = run_chain(
        jax.random.key(3), jnp.zeros(()), target, RandomWalk(0.1), 50, kernel="exact", chunk_size=64
    )
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Sec 3.3 safeguard
# ---------------------------------------------------------------------------


def test_trial_run_report_flags_clean_problem_as_safe(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=800)
    rep = trial_run_report(
        jax.random.key(0), jnp.zeros(()), target, RandomWalk(0.05),
        batch_size=50, epsilon=0.05, num_trials=6,
    )
    assert rep.num_trials == 6
    assert 0.0 <= rep.mean_fraction_evaluated <= 1.0
    assert rep.decision_error_rate <= 0.3
