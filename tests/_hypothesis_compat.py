"""Drop-in subset of `hypothesis` so property tests run without the package.

`pip install -e .[dev]` brings in the real hypothesis (declared in
pyproject.toml) and this module simply re-exports it. In environments where
it is missing, a small deterministic fallback supplies the same decorator
API: each `@given` test is replayed over `max_examples` pseudo-random
examples drawn from a fixed-seed generator. No shrinking, no database — the
point is that the properties still get exercised (and the module still
collects) on a bare scientific-python install.

Only the strategy surface this repo uses is implemented:
`st.integers`, `st.floats`, `st.lists`, `st.sampled_from`, `st.tuples`.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import functools
    import types

    import numpy as _np

    HAVE_HYPOTHESIS = False
    HealthCheck = types.SimpleNamespace(too_slow="too_slow", data_too_large="data_too_large")

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value=-1e6, max_value=1e6, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _lists(elements, min_size=0, max_size=20):
        def sample(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(size)]

        return _Strategy(sample)

    def _sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))

    st = types.SimpleNamespace(
        integers=_integers,
        floats=_floats,
        lists=_lists,
        sampled_from=_sampled_from,
        tuples=_tuples,
    )

    def settings(max_examples=20, deadline=None, suppress_health_check=(), **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    import os

    # The fallback has no shrinking or example database, so large example
    # counts buy little; cap them to keep the fast tier fast. Real hypothesis
    # (CI) runs the full declared max_examples.
    _EXAMPLE_CAP = int(os.environ.get("HYPOTHESIS_COMPAT_MAX_EXAMPLES", "8"))

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", None) or getattr(
                    fn, "_compat_max_examples", 20
                )
                n = min(n, _EXAMPLE_CAP)
                # Seed from the test name so every property has its own
                # reproducible example stream (crc32: stable across runs,
                # unlike str hash under PYTHONHASHSEED randomization).
                import zlib

                seed = zlib.crc32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    example = [s.sample(rng) for s in strategies]
                    fn(*args, *example, **kwargs)

            # pytest must see a zero-arg test, not the generated params
            # (functools.wraps copies __wrapped__, whose signature pytest
            # would otherwise resolve as fixture requests).
            import inspect

            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

# both import spellings work: `import strategies as st` and plain `st`
strategies = st
