"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.batched_loglik import batched_logit_delta, gather_and_delta
from repro.kernels.fused_ce import batched_fused_ce, fused_ce
from repro.kernels.gaussian_ar1 import batched_gaussian_ar1_delta
from repro.kernels.logit_loglik import logit_delta
from repro.kernels.ref import (
    batched_fused_ce_ref,
    batched_gaussian_ar1_delta_ref,
    batched_logit_delta_ref,
    fused_ce_ref,
    logit_delta_ref,
)


@pytest.mark.parametrize("t,d,v", [(8, 32, 64), (16, 64, 128),
                                   pytest.param(100, 48, 300, marks=pytest.mark.slow),
                                   pytest.param(256, 128, 1000, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ce_matches_ref(t, d, v, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    h = (0.5 * jax.random.normal(k1, (t, d))).astype(dtype)
    table = (0.5 * jax.random.normal(k2, (v, d))).astype(dtype)
    targets = jax.random.randint(k3, (t,), 0, v)
    got = fused_ce(h, table, targets, tile_t=32, tile_v=64, interpret=True)
    want = fused_ce_ref(h, table, targets)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_fused_ce_ragged_tiles():
    # shapes deliberately not multiples of the tiles: padding path
    t, d, v = 37, 16, 129
    h = jax.random.normal(jax.random.key(1), (t, d))
    table = jax.random.normal(jax.random.key(2), (v, d))
    targets = jax.random.randint(jax.random.key(3), (t,), 0, v)
    got = fused_ce(h, table, targets, tile_t=16, tile_v=32, interpret=True)
    want = fused_ce_ref(h, table, targets)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_ce_extreme_logits_stable():
    # online logsumexp must survive large-magnitude logits
    t, d, v = 16, 8, 64
    h = 30.0 * jax.random.normal(jax.random.key(4), (t, d))
    table = 30.0 * jax.random.normal(jax.random.key(5), (v, d))
    targets = jax.random.randint(jax.random.key(6), (t,), 0, v)
    got = fused_ce(h, table, targets, tile_t=8, tile_v=16, interpret=True)
    want = fused_ce_ref(h, table, targets)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d", [(8, 4), (100, 50), (512, 64), (1000, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logit_delta_matches_ref(n, d, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(k1, (n, d)).astype(dtype)
    y = jnp.where(jax.random.bernoulli(k2, 0.5, (n,)), 1.0, -1.0)
    w_c = jax.random.normal(k3, (d,)).astype(dtype)
    w_p = jax.random.normal(k4, (d,)).astype(dtype)
    got = logit_delta(x, y, w_c, w_p, tile_n=64, interpret=True)
    want = logit_delta_ref(x, y, w_c, w_p)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Ensemble-batched (K, m) logit delta: interpret-mode parity vs the ref twin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "k,m,d,tile",
    [
        (1, 8, 4, 8),       # single chain degenerates to logit_delta
        (4, 100, 50, 32),   # ragged tail: 100 % 32 != 0
        (16, 37, 3, 16),    # K=16 acceptance-bar shape, ragged
        (3, 256, 64, 256),  # one full tile per chain
        (7, 5, 2, 8),       # m smaller than the tile
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_logit_delta_matches_ref(k, m, d, tile, dtype):
    ks = jax.random.split(jax.random.key(k * 1000 + m), 4)
    xg = jax.random.normal(ks[0], (k, m, d)).astype(dtype)
    yg = jnp.where(jax.random.bernoulli(ks[1], 0.5, (k, m)), 1.0, -1.0)
    w_c = jax.random.normal(ks[2], (k, d)).astype(dtype)
    w_p = jax.random.normal(ks[3], (k, d)).astype(dtype)
    got = batched_logit_delta(xg, yg, w_c, w_p, tile_m=tile, interpret=True)
    want = batched_logit_delta_ref(xg, yg, w_c, w_p)
    assert got.shape == (k, m)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_batched_logit_delta_rows_match_single_chain_kernel():
    """Each chain's row must equal the single-chain logit_delta on its batch."""
    k, m, d = 5, 64, 8
    ks = jax.random.split(jax.random.key(9), 4)
    xg = jax.random.normal(ks[0], (k, m, d))
    yg = jnp.where(jax.random.bernoulli(ks[1], 0.5, (k, m)), 1.0, -1.0)
    w_c = jax.random.normal(ks[2], (k, d))
    w_p = jax.random.normal(ks[3], (k, d))
    got = batched_logit_delta(xg, yg, w_c, w_p, tile_m=32, interpret=True)
    for c in range(k):
        row = logit_delta(xg[c], yg[c], w_c[c], w_p[c], tile_n=32, interpret=True)
        np.testing.assert_allclose(np.asarray(got[c]), np.asarray(row), rtol=1e-5, atol=1e-5)


def test_gather_and_delta_matches_gather_then_ref():
    n, d, k, m = 500, 10, 3, 40
    x = jax.random.normal(jax.random.key(0), (n, d))
    y = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (n,)), 1.0, -1.0)
    idx = jax.random.randint(jax.random.key(2), (k, m), 0, n)
    w_c = jax.random.normal(jax.random.key(3), (k, d))
    w_p = jax.random.normal(jax.random.key(4), (k, d))
    got = gather_and_delta(x, y, idx, w_c, w_p, tile_m=16, interpret=True)
    want = batched_logit_delta_ref(x[idx], y[idx], w_c, w_p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ops_batched_dispatch_matches_kernel():
    from repro.kernels import ops

    k, m, d = 2, 24, 6
    xg = jax.random.normal(jax.random.key(0), (k, m, d))
    yg = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (k, m)), 1.0, -1.0)
    w_c = jax.random.normal(jax.random.key(2), (k, d))
    w_p = jax.random.normal(jax.random.key(3), (k, d))
    out_auto = ops.batched_logit_delta(xg, yg, w_c, w_p)
    out_kernel = ops.batched_logit_delta(xg, yg, w_c, w_p, mode="always", tile_m=8)
    np.testing.assert_allclose(np.asarray(out_auto), np.asarray(out_kernel),
                               rtol=1e-5, atol=1e-5)


def test_ops_auto_dispatch_runs_on_cpu():
    from repro.kernels import ops

    h = jax.random.normal(jax.random.key(0), (8, 16))
    table = jax.random.normal(jax.random.key(1), (32, 16))
    targets = jax.random.randint(jax.random.key(2), (8,), 0, 32)
    out_auto = ops.fused_ce(h, table, targets)
    out_kernel = ops.fused_ce(h, table, targets, mode="always", tile_t=8, tile_v=16)
    np.testing.assert_allclose(np.asarray(out_auto), np.asarray(out_kernel), rtol=1e-5, atol=1e-5)


def test_ops_mode_vocabulary_and_aliases():
    """One dispatch vocabulary (auto|always|never); the legacy kernel/ref
    spellings keep working as deprecated aliases and REPRO_FUSED pins auto."""
    import os
    import warnings

    from repro.kernels import ops

    x = jax.random.normal(jax.random.key(0), (8, 4))
    y = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (8,)), 1.0, -1.0)
    w_c = jax.random.normal(jax.random.key(2), (4,))
    w_p = jax.random.normal(jax.random.key(3), (4,))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = ops.logit_delta(x, y, w_c, w_p, mode="ref")
        assert any(issubclass(r.category, DeprecationWarning) for r in rec)
    new = ops.logit_delta(x, y, w_c, w_p, mode="never")
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    with pytest.raises(ValueError):
        ops.logit_delta(x, y, w_c, w_p, mode="maybe")
    assert ops.use_kernel("always") is True
    assert ops.use_kernel("never") is False
    before = os.environ.get(ops.ENV_VAR)
    try:
        os.environ[ops.ENV_VAR] = "always"
        assert ops.use_kernel("auto") is True
        os.environ[ops.ENV_VAR] = "never"
        assert ops.use_kernel("auto") is False
    finally:
        if before is None:
            os.environ.pop(ops.ENV_VAR, None)
        else:
            os.environ[ops.ENV_VAR] = before


# ---------------------------------------------------------------------------
# Ensemble-batched AR(1) delta (stochvol sections): interpret vs ref twin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "k,m,tile",
    [(1, 8, 8), (4, 100, 32), (16, 37, 16), (3, 256, 256), (7, 5, 8)],
)
def test_batched_gaussian_ar1_delta_matches_ref(k, m, tile):
    ks = jax.random.split(jax.random.key(k * 100 + m), 4)
    xt = jax.random.normal(ks[0], (k, m))
    xp = jax.random.normal(ks[1], (k, m))
    phi = jax.random.uniform(ks[2], (k,), minval=0.3, maxval=0.99)
    s2 = jax.random.uniform(ks[3], (k,), minval=1e-3, maxval=0.2)
    phi_p = phi + 0.05
    s2_p = s2 * 1.3
    got = batched_gaussian_ar1_delta(xt, xp, phi, s2, phi_p, s2_p,
                                     tile_m=tile, interpret=True)
    want = batched_gaussian_ar1_delta_ref(xt, xp, phi, s2, phi_p, s2_p)
    assert got.shape == (k, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_batched_gaussian_ar1_delta_out_of_support_is_finite():
    """Negative sigma^2 proposals are rejected by the -inf prior, but the
    local evaluations the test already drew must stay finite (clip guard)."""
    k, m = 2, 16
    xt = jax.random.normal(jax.random.key(0), (k, m))
    xp = jax.random.normal(jax.random.key(1), (k, m))
    phi = jnp.full((k,), 0.9)
    s2 = jnp.full((k,), 0.05)
    s2_bad = jnp.asarray([-0.01, 0.0])
    got = batched_gaussian_ar1_delta(xt, xp, phi, s2, phi, s2_bad,
                                     tile_m=8, interpret=True)
    want = batched_gaussian_ar1_delta_ref(xt, xp, phi, s2, phi, s2_bad)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Ensemble-batched fused CE: interpret vs ref twin, shared and per-chain tables
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,t,d,v", [(1, 8, 16, 32), (3, 19, 16, 50), (4, 16, 8, 33)])
@pytest.mark.parametrize("per_chain_table", [False, True])
def test_batched_fused_ce_matches_ref(k, t, d, v, per_chain_table):
    ks = jax.random.split(jax.random.key(k * 10 + t), 3)
    h = 0.4 * jax.random.normal(ks[0], (k, t, d))
    shape = (k, v, d) if per_chain_table else (v, d)
    table = 0.4 * jax.random.normal(ks[1], shape)
    targets = jax.random.randint(ks[2], (k, t), 0, v)
    got = batched_fused_ce(h, table, targets, tile_t=8, tile_v=16, interpret=True)
    want = batched_fused_ce_ref(h, table, targets)
    assert got.shape == (k, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_batched_fused_ce_rows_match_single_chain_kernel():
    """Each chain's row must equal the single-chain fused_ce on its slice."""
    k, t, d, v = 3, 12, 8, 40
    h = 0.3 * jax.random.normal(jax.random.key(0), (k, t, d))
    table = 0.3 * jax.random.normal(jax.random.key(1), (v, d))
    targets = jax.random.randint(jax.random.key(2), (k, t), 0, v)
    got = batched_fused_ce(h, table, targets, tile_t=8, tile_v=16, interpret=True)
    for c in range(k):
        row = fused_ce(h[c], table, targets[c], tile_t=8, tile_v=16, interpret=True)
        np.testing.assert_allclose(np.asarray(got[c]), np.asarray(row),
                                   rtol=1e-5, atol=1e-5)


def test_kernel_used_by_model_loglik_semantics():
    """unembed_loglik (chunked jnp path) == summing fused_ce per token."""
    from repro.models.layers import unembed_loglik

    b, s, d, v = 2, 12, 16, 40
    h = 0.3 * jax.random.normal(jax.random.key(0), (b, s, d))
    table = 0.3 * jax.random.normal(jax.random.key(1), (v, d))
    targets = jax.random.randint(jax.random.key(2), (b, s), 0, v)
    mask = jnp.ones((b, s))
    got = unembed_loglik(h, table, targets, mask, chunk=5)
    per_tok = fused_ce(h.reshape(-1, d), table, targets.reshape(-1),
                       tile_t=8, tile_v=16, interpret=True).reshape(b, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(per_tok.sum(-1)),
                               rtol=1e-4, atol=1e-4)
