"""Multi-device tests (subprocess: jax locks device count at first init).

These actually EXECUTE sharded steps on 8 forced host devices — complementing
the dry-run, which only lowers+compiles on 512.
"""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=_REPO, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Subsampled-MH train step on a (2,4) mesh == single-device result."""
    script = r"""
import json
import jax, jax.numpy as jnp, numpy as np
from repro.bayes import TrainConfig, make_train_step
from repro.configs import ARCHS, reduce_config
from repro.data import DataConfig, TokenStream
from repro.distributed.sharding import logical_axis_rules, named_sharding
from repro.models import init_params

rc = reduce_config(ARCHS["chatglm3-6b"])
tc = TrainConfig(round_batch=4, max_rounds=2, epsilon=0.3, sigma=1e-3)
params = init_params(jax.random.key(0), rc)
batch = TokenStream(DataConfig(vocab=rc.vocab, seq_len=32, global_batch=8, seed=0)).batch(0)
step = make_train_step(rc, tc)

# single device reference
ref, ref_info = jax.jit(step)(jax.random.key(7), params, batch)
ref_leaf = np.asarray(jax.tree.leaves(ref)[0], dtype=np.float32)

def make_mesh(shape, names):
    try:  # AxisType only exists in newer jax
        return jax.make_mesh(shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    except AttributeError:
        return jax.make_mesh(shape, names)

mesh = make_mesh((2, 4), ("data", "model"))
with logical_axis_rules(mesh), mesh:
    from repro.launch.steps import spec_tree_to_shardings
    from repro.models import param_specs
    psh = spec_tree_to_shardings(param_specs(rc), mesh)
    bsh = {k: named_sharding(mesh, v.shape, ("batch",) + (None,) * (v.ndim - 1))
           for k, v in batch.items()}
    params_s = jax.device_put(params, psh)
    batch_s = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
    out, info = jax.jit(step, in_shardings=(None, psh, bsh),
                        out_shardings=(psh, None))(jax.random.key(7), params_s, batch_s)
    out_leaf = np.asarray(jax.tree.leaves(out)[0], dtype=np.float32)

print(json.dumps({
    "accept_match": bool(info.accepted) == bool(ref_info.accepted),
    "max_diff": float(np.max(np.abs(out_leaf - ref_leaf))),
    "n_devices": len(jax.devices()),
}))
"""
    res = _run(script)
    assert res["n_devices"] == 8
    assert res["accept_match"]
    assert res["max_diff"] < 2e-2, res


@pytest.mark.slow
def test_elastic_checkpoint_reshard_across_meshes():
    """Save params sharded on a (4,2) mesh, restore onto (2,4): values equal."""
    script = r"""
import json, tempfile
import jax, numpy as np
from repro.checkpoint import manager as ckpt
from repro.configs import ARCHS, reduce_config
from repro.distributed.sharding import logical_axis_rules
from repro.launch.steps import spec_tree_to_shardings
from repro.models import init_params, param_specs

rc = reduce_config(ARCHS["xlstm-350m"])
params = init_params(jax.random.key(0), rc)
d = tempfile.mkdtemp()

def make_mesh(shape, names):
    try:  # AxisType only exists in newer jax
        return jax.make_mesh(shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    except AttributeError:
        return jax.make_mesh(shape, names)

mesh_a = make_mesh((4, 2), ("data", "model"))
sh_a = spec_tree_to_shardings(param_specs(rc), mesh_a)
ckpt.save(d, 3, jax.device_put(params, sh_a))

mesh_b = make_mesh((2, 4), ("data", "model"))
sh_b = spec_tree_to_shardings(param_specs(rc), mesh_b)
step, restored = ckpt.restore(d, target=params, shardings=sh_b)
ok = all(
    np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored))
)
shards_ok = all(
    l.sharding.is_equivalent_to(s, l.ndim)
    for l, s in zip(jax.tree.leaves(restored), jax.tree.leaves(sh_b))
)
print(json.dumps({"step": int(step), "values_equal": ok, "resharded": shards_ok}))
"""
    res = _run(script)
    assert res["step"] == 3
    assert res["values_equal"]
    assert res["resharded"]
