"""Alerting engine, component health model, and the autoscaler control loop.

All synthetic: rules are evaluated against hand-built rollups (the engine
never requires live serving), the health model against rollup + fleet
report fixtures, and the AutoScaler against stub fleet/router objects so
every decision branch (pressure kinds, cooldown, bounds, quiesce, LIFO
retirement) is exercised without spinning up chains.
"""
import pytest

from repro.core.stats import EwmaState, burn_rate, ewma_update, ewma_zscore
from repro.fleet.autoscale import AutoScaleConfig, AutoScaler
from repro.obs import Recorder
from repro.obs.alerts import AlertEngine, AlertRule, default_rules
from repro.obs.health import health_report


def _rollup(**fields):
    """A rollup with one 'slo' stream whose field aggregates all equal the
    given value — `fields` for the alert engine, `last` for the health
    model (both are what Recorder.rollup() maintains)."""
    return {"streams": {"slo": {
        "count": 1,
        "last": dict(fields),
        "fields": {k: {"last": v, "mean": v, "min": v, "max": v,
                       "p50": v, "p95": v, "count": 1}
                   for k, v in fields.items()},
    }}}


def _threshold_rule(**kw):
    base = dict(name="hot", stream="slo", field="p95_ms", kind="threshold",
                op=">", threshold=100.0, for_samples=2, clear_samples=2)
    base.update(kw)
    return AlertRule(**base)


# ---------------------------------------------------------------------------
# EWMA / burn-rate statistics (repro.core.stats)
# ---------------------------------------------------------------------------


def test_ewma_tracks_mean_and_flags_outliers():
    st = EwmaState(0, 0.0, 0.0)
    for _ in range(50):
        st = ewma_update(st, 10.0, alpha=0.3)
    assert st.mean == pytest.approx(10.0)
    assert abs(ewma_zscore(st, 10.0)) < 1e-6
    # a constant series has ~zero variance: any deviation is a huge z
    assert abs(ewma_zscore(st, 11.0)) > 100.0
    # noisy series: z is scaled by the learned sigma
    st = EwmaState(0, 0.0, 0.0)
    for i in range(200):
        st = ewma_update(st, 10.0 + (1.0 if i % 2 else -1.0), alpha=0.1)
    assert abs(ewma_zscore(st, 10.0)) < 1.5
    assert ewma_zscore(st, 50.0) > 10.0


def test_burn_rate_is_bad_fraction_over_budget():
    assert burn_rate(0.2, 0.1) == pytest.approx(2.0)
    assert burn_rate(0.0, 0.1) == 0.0
    assert burn_rate(1.0, 0.0) > 1e9  # zero budget never divides by zero


# ---------------------------------------------------------------------------
# AlertRule validation
# ---------------------------------------------------------------------------


def test_alert_rule_validates_kind_op_and_windows():
    with pytest.raises(ValueError):
        _threshold_rule(kind="vibes")
    with pytest.raises(ValueError):
        _threshold_rule(op="~")
    with pytest.raises(ValueError):
        _threshold_rule(source="p99")  # not a rollup aggregate
    with pytest.raises(ValueError):
        AlertRule(name="b", stream="slo", field="x", kind="burn_rate",
                  objective=0.9, short_window=10, long_window=5)
    with pytest.raises(ValueError):
        AlertRule(name="a", stream="slo", field="x", kind="anomaly",
                  direction="sideways")
    with pytest.raises(ValueError):
        AlertEngine(None, [_threshold_rule(), _threshold_rule()])  # dup names


# ---------------------------------------------------------------------------
# Threshold rules: the pending -> firing -> resolved -> ok state machine
# ---------------------------------------------------------------------------


def test_threshold_state_machine_full_cycle():
    eng = AlertEngine(None, [_threshold_rule()])
    hot, cool = _rollup(p95_ms=500.0), _rollup(p95_ms=10.0)
    assert [e["to"] for e in eng.evaluate(hot)] == ["pending"]
    assert [e["to"] for e in eng.evaluate(hot)] == ["firing"]
    assert eng.firing() == ["hot"]
    assert eng.evaluate(hot) == []  # steady-state firing: no new events
    assert eng.evaluate(cool) == []  # clear_samples=2: one clear holds
    assert [e["to"] for e in eng.evaluate(cool)] == ["resolved"]
    # resolved is visible for exactly one evaluation, then back to ok
    assert [e["to"] for e in eng.evaluate(cool)] == ["ok"]
    assert eng.firing() == []
    assert eng.fired_total == 1 and eng.resolved_total == 1


def test_threshold_pending_clears_without_firing_on_blip():
    eng = AlertEngine(None, [_threshold_rule(for_samples=3)])
    eng.evaluate(_rollup(p95_ms=500.0))  # pending
    events = eng.evaluate(_rollup(p95_ms=10.0))  # breach streak broken
    assert [e["to"] for e in events] == ["ok"]
    assert eng.fired_total == 0
    # the breach counter reset: two more breaches still only reach pending
    eng.evaluate(_rollup(p95_ms=500.0))
    assert eng.state("hot") == "pending"


def test_cooldown_suppresses_reentry_with_injected_clock():
    now = [0.0]
    eng = AlertEngine(
        None,
        [_threshold_rule(for_samples=1, clear_samples=1, cooldown_s=60.0)],
        clock=lambda: now[0],
    )
    hot, cool = _rollup(p95_ms=500.0), _rollup(p95_ms=10.0)
    eng.evaluate(hot)  # pending -> firing (for_samples=1 fires same pass)
    assert eng.state("hot") == "firing"
    eng.evaluate(cool)  # resolved
    eng.evaluate(cool)  # ok
    now[0] = 30.0  # inside cooldown: a fresh breach is suppressed
    assert eng.evaluate(hot) == []
    assert eng.state("hot") == "ok"
    now[0] = 61.0  # cooldown expired: normal re-entry
    events = eng.evaluate(hot)
    assert [e["to"] for e in events] == ["pending", "firing"]


def test_missing_stream_or_field_leaves_state_untouched():
    eng = AlertEngine(None, [_threshold_rule()])
    eng.evaluate(_rollup(p95_ms=500.0))
    assert eng.state("hot") == "pending"
    assert eng.evaluate({"streams": {}}) == []  # no slo stream this pass
    assert eng.state("hot") == "pending"  # neither breach nor clear


# ---------------------------------------------------------------------------
# Burn-rate rules: multi-window SLO error-budget burn
# ---------------------------------------------------------------------------


def _burn_engine():
    rule = AlertRule(
        name="burn", stream="slo", field="hit_rate", kind="burn_rate",
        objective=0.9, max_burn=2.0, short_window=3, long_window=6,
        good_metric=True, for_samples=1, clear_samples=1,
    )
    return AlertEngine(None, [rule])


def test_burn_rate_fires_on_sustained_budget_burn_and_resolves():
    eng = _burn_engine()
    # budget = 1 - 0.9 = 0.1; hit_rate 0.6 -> bad 0.4 -> burn 4x > 2x
    for _ in range(2):
        eng.evaluate(_rollup(hit_rate=0.6))
    assert eng.state("burn") == "ok"  # < short_window samples: no verdict
    eng.evaluate(_rollup(hit_rate=0.6))
    assert eng.state("burn") == "firing"
    # recovery: good samples dilute both windows below max_burn
    for _ in range(6):
        eng.evaluate(_rollup(hit_rate=1.0))
    assert eng.state("burn") in ("resolved", "ok")


def test_burn_rate_ignores_short_spike_the_long_window_absorbs():
    eng = _burn_engine()
    for _ in range(6):
        eng.evaluate(_rollup(hit_rate=1.0))  # long window full of good
    eng.evaluate(_rollup(hit_rate=0.0))  # one catastrophic sample
    # short burn is huge but the long window still averages under 2x
    assert eng.state("burn") == "ok"


# ---------------------------------------------------------------------------
# Anomaly rules: EWMA z-score with a baseline that regressions don't teach
# ---------------------------------------------------------------------------


def test_anomaly_fires_below_baseline_and_keeps_baseline_unpoisoned():
    rule = AlertRule(
        name="rate", stream="slo", field="req_per_s", kind="anomaly",
        z_threshold=4.0, min_samples=8, direction="below",
        for_samples=2, clear_samples=2,
    )
    eng = AlertEngine(None, [rule])
    for i in range(20):
        eng.evaluate(_rollup(req_per_s=1000.0 + (i % 2)))
    assert eng.state("rate") == "ok"
    eng.evaluate(_rollup(req_per_s=5.0))  # collapse: pending
    eng.evaluate(_rollup(req_per_s=5.0))  # still collapsed: firing
    assert eng.state("rate") == "firing"
    # the collapsed samples were NOT folded into the EWMA, so the baseline
    # still reads ~1000 and recovery resolves the alert
    for _ in range(2):
        eng.evaluate(_rollup(req_per_s=1001.0))
    assert eng.state("rate") == "resolved"
    eng.evaluate(_rollup(req_per_s=1001.0))
    assert eng.state("rate") == "ok"
    # direction='below' never fires on an upward spike
    eng.evaluate(_rollup(req_per_s=50000.0))
    assert eng.state("rate") == "ok"


# ---------------------------------------------------------------------------
# Engine bookkeeping: the alerts stream, status(), default rules
# ---------------------------------------------------------------------------


def test_transitions_land_on_the_alerts_stream(tmp_path):
    rec = Recorder(str(tmp_path), run_id="r")
    eng = AlertEngine(rec, [_threshold_rule(severity="page")])
    eng.evaluate(_rollup(p95_ms=500.0))
    eng.evaluate(_rollup(p95_ms=500.0))
    eng.evaluate(_rollup(p95_ms=1.0))
    eng.evaluate(_rollup(p95_ms=1.0))
    rec.close()
    events = rec.read_stream("alerts")
    assert [(e["from"], e["to"]) for e in events] == [
        ("ok", "pending"), ("pending", "firing"), ("firing", "resolved")]
    assert all(e["rule"] == "hot" and e["severity"] == "page"
               and e["stream"] == "slo" and "value" in e for e in events)


def test_status_payload_shape_and_counters():
    eng = AlertEngine(None, [_threshold_rule(for_samples=1)])
    eng.evaluate(_rollup(p95_ms=500.0))
    st = eng.status()
    assert st["available"] is True and st["firing"] == ["hot"]
    assert st["evaluations"] == 1 and st["fired_total"] == 1
    rule = st["rules"]["hot"]
    assert rule["state"] == "firing" and rule["kind"] == "threshold"
    assert rule["value"] == 500.0 and rule["severity"] == "warning"


def test_default_rules_cover_the_standard_streams_and_fire_sanely():
    rules = default_rules("bayeslr", "predictive",
                          deadline_ms=100.0, max_depth=32)
    names = {r.name for r in rules}
    assert {"p95_over_budget", "admission_overload", "queue_depth_high",
            "deadline_burn", "req_rate_anomaly", "sublinear_regression",
            "rhat_regression", "ess_anomaly"} <= names
    eng = AlertEngine(None, list(rules))
    # an active shed floor fires admission_overload within one evaluation
    eng.evaluate(_rollup(admission_shed_floor=1.0, admission_depth=5.0))
    assert "admission_overload" in eng.firing()
    # floor back to the -1 sentinel: resolves on the next pass
    eng.evaluate(_rollup(admission_shed_floor=-1.0, admission_depth=0.0))
    assert "admission_overload" not in eng.firing()


# ---------------------------------------------------------------------------
# Component health model
# ---------------------------------------------------------------------------


def test_health_report_healthy_when_signals_are_clean():
    roll = _rollup(admission_depth=3.0, admission_shed_floor=-1.0,
                   dead_lanes=0.0)
    rep = health_report(roll, max_depth=64)
    assert rep["status"] == "ok" and rep["score"] >= 0.9
    assert set(rep["components"]) >= {"queue", "router"}


def test_health_report_degrades_on_shed_floor_and_dead_lanes():
    roll = _rollup(admission_depth=80.0, admission_shed_floor=1.0,
                   dead_lanes=1.0)
    rep = health_report(roll, max_depth=64)
    assert rep["score"] <= 0.5
    assert rep["components"]["queue"]["score"] <= 0.5
    assert rep["components"]["router"]["score"] <= 0.5
    assert rep["status"] in ("degraded", "critical")


def test_health_report_page_alert_caps_score():
    roll = _rollup(admission_depth=0.0, admission_shed_floor=-1.0)
    status = {"available": True, "firing": ["p95_over_budget"],
              "rules": {"p95_over_budget": {"state": "firing",
                                            "severity": "page"}}}
    rep = health_report(roll, alert_status=status, max_depth=64)
    assert rep["score"] <= 0.4 and rep["status"] == "critical"
    assert rep["firing"] == ["p95_over_budget"]


def test_health_report_replica_and_writer_components():
    roll = {"streams": {"snapshot": {
        "count": 2, "last": {"rhat": 1.6, "num_draws": 64}}}}
    fleet_report = {
        "sync": {"syncs": 10},
        "errors": {"s/r1": "ReplicaDeadError: down"},
        "shards": {"s": {"writer_steps": 100,
                         "replica_versions": [100, 40],
                         "replicas": [{"alive": True}, {"alive": False}]}},
    }
    rep = health_report(roll, fleet_report=fleet_report)
    assert rep["components"]["replicas"]["score"] < 0.8
    assert rep["components"]["writer"]["score"] <= 0.4  # rhat 1.6 diverging
    assert rep["status"] == "critical"


# ---------------------------------------------------------------------------
# AutoScaler control loop (stub fleet/router: every branch, no chains)
# ---------------------------------------------------------------------------


class _StubReplica:
    def __init__(self, name):
        self.name = name


class _StubFleet:
    def __init__(self, n=1):
        self.replicas = [_StubReplica(f"w@0#r{i}") for i in range(n)]
        self._seq = n
        self.added, self.removed = [], []

    def replica_count(self, workload):
        return len(self.replicas)

    def add_replica(self, workload, shard_index=0):
        rep = _StubReplica(f"w@0#r{self._seq}")
        self._seq += 1
        self.replicas.append(rep)
        self.added.append(rep.name)
        return ("shard-stub", rep)

    def remove_replica(self, workload, replica_name=None):
        rep = next(r for r in self.replicas if r.name == replica_name)
        self.replicas.remove(rep)
        self.removed.append(rep.name)
        return rep.name


class _StubRouter:
    def __init__(self):
        self.depth = 0
        self.shed = 0
        self.shed_floor = None
        self.p95_ms = None
        self.attached, self.detached = [], []

    def slo_report(self):
        return {
            "shed": self.shed,
            "admission": {"depth": self.depth, "shed_floor": self.shed_floor,
                          "predicted_miss_rate": 0.0},
            "classes": {"w.q": {"p95_ms": self.p95_ms}},
        }

    def attach_lane(self, shard, replica):
        self.attached.append(replica.name)

    def detach_lane(self, workload, name, timeout_s=30.0):
        self.detached.append(name)
        return True


def _scaler(fleet, router, clock, **cfg_kw):
    cfg = dict(min_replicas=1, max_replicas=3, scale_up_depth=10,
               scale_down_depth=2, quiesce_ticks=2, cooldown_s=5.0)
    cfg.update(cfg_kw)
    return AutoScaler(fleet, router, "w", AutoScaleConfig(**cfg),
                      clock=lambda: clock[0])


def test_autoscale_config_validates_bounds():
    with pytest.raises(ValueError):
        AutoScaleConfig(min_replicas=0)
    with pytest.raises(ValueError):
        AutoScaleConfig(min_replicas=3, max_replicas=2)
    with pytest.raises(ValueError):
        AutoScaleConfig(quiesce_ticks=0)


def test_scale_up_on_depth_pressure_actuates_fleet_and_router():
    fleet, router, clock = _StubFleet(), _StubRouter(), [0.0]
    scaler = _scaler(fleet, router, clock)
    router.depth = 50
    d = scaler.tick()
    assert d["action"] == "scale_up" and d["replicas_after"] == 2
    assert fleet.added == ["w@0#r1"] and router.attached == ["w@0#r1"]
    assert scaler.outstanding == 1


def test_cooldown_and_max_bound_block_and_are_recorded(tmp_path):
    rec = Recorder(str(tmp_path), run_id="r")
    fleet, router, clock = _StubFleet(), _StubRouter(), [0.0]
    scaler = _scaler(fleet, router, clock)
    scaler.recorder = rec
    router.depth = 50
    assert scaler.tick()["action"] == "scale_up"
    clock[0] = 1.0  # inside cooldown
    d = scaler.tick()
    assert d["action"] == "hold" and "cooldown" in d["reason"]
    clock[0] = 6.0
    assert scaler.tick()["action"] == "scale_up"  # 3 replicas now (max)
    clock[0] = 12.0
    d = scaler.tick()
    assert d["action"] == "hold" and "max_replicas" in d["reason"]
    assert scaler.events == {"scale_up": 2, "scale_down": 0, "blocked": 2}
    rec.close()
    # every actuation AND every blocked intent landed on the stream
    assert [e["action"] for e in rec.read_stream("autoscale")] == [
        "scale_up", "hold", "scale_up", "hold"]


def test_scale_down_needs_consecutive_calm_and_retires_lifo_only_own():
    fleet, router, clock = _StubFleet(), _StubRouter(), [0.0]
    scaler = _scaler(fleet, router, clock, cooldown_s=0.0)
    router.depth = 50
    scaler.tick()
    scaler.tick()  # 3 replicas: r1, r2 added by the scaler
    router.depth = 0
    scaler.tick()  # calm 1
    assert fleet.removed == []
    d = scaler.tick()  # calm 2 -> retire newest own replica
    assert d["action"] == "scale_down"
    assert router.detached == ["w@0#r2"] and fleet.removed == ["w@0#r2"]
    scaler.tick()
    scaler.tick()  # quiesce again -> r1
    assert fleet.removed == ["w@0#r2", "w@0#r1"]
    # back at the floor with nothing of its own left: calm holds forever
    for _ in range(5):
        assert scaler.tick()["action"] == "hold"
    assert fleet.replica_count("w") == 1  # launch replica never touched


def test_pressure_reasons_alert_shed_and_p95():
    fleet, router, clock = _StubFleet(), _StubRouter(), [0.0]

    class _Eng:
        def firing(self):
            return ["admission_overload", "rhat_regression"]

    scaler = _scaler(fleet, router, clock, cooldown_s=0.0)
    scaler.engine = _Eng()
    d = scaler.tick()  # alert wins even with depth 0
    assert d["action"] == "scale_up" and d["reason"] == "alert:admission_overload"
    scaler.engine = None
    router.shed = 7  # fresh sheds since the last tick
    d = scaler.tick()
    assert d["action"] == "scale_up" and "shed_delta=7" in d["reason"]
    d = scaler.tick()  # same cumulative counter: no new sheds, calm
    assert d["action"] == "hold" and d["reason"] == "calm"
    # p95 pressure only when configured
    fleet2, router2 = _StubFleet(), _StubRouter()
    router2.p95_ms = 900.0
    assert _scaler(fleet2, router2, clock).tick()["action"] == "hold"
    s = _scaler(fleet2, router2, clock, scale_up_p95_ms=500.0)
    assert s.tick()["action"] == "scale_up"


def test_observe_absorbs_shed_baseline_without_acting():
    fleet, router, clock = _StubFleet(), _StubRouter(), [0.0]
    scaler = _scaler(fleet, router, clock, cooldown_s=0.0)
    router.shed = 100
    scaler.observe()  # burst already handled elsewhere
    assert scaler.tick()["action"] == "hold"  # no stale pressure
    assert fleet.added == []


def test_default_overload_alerts_exclude_cumulative_latency_rules():
    cfg = AutoScaleConfig()
    assert "p95_over_budget" not in cfg.overload_alerts
    assert "admission_overload" in cfg.overload_alerts
