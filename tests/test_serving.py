"""Serving subsystem: resident refresh semantics, batching transparency,
freshness enforcement, and warm checkpoint round-trips."""
import dataclasses
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    ChainEnsemble,
    RandomWalk,
    ScheduleConfig,
    SubsampledMHConfig,
)
from repro.serving import (
    EnsemblePool,
    FreshnessPolicy,
    QuerySpec,
    RequestQueue,
    ResidentEnsemble,
    ServingConfig,
    ServingWorkload,
    build_serving_workload,
    serving_workloads,
)


def _tiny_pool(max_batch=4, min_draws=16, max_staleness_s=60.0, window=16,
               refresh_steps=8, num_chains=2, **freshness_kw):
    cfg = ServingConfig(
        num_chains=num_chains,
        refresh_steps=refresh_steps,
        window=window,
        micro_batch=8,
        max_batch=max_batch,
        freshness=FreshnessPolicy(
            max_staleness_s=max_staleness_s, min_draws=min_draws, **freshness_kw
        ),
        seed=0,
    )
    pool = EnsemblePool(cfg)
    pool.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    return pool


@pytest.fixture(scope="module")
def warm_pool():
    pool = _tiny_pool()
    pool.warm()
    return pool


# ---------------------------------------------------------------------------
# Resident refresh == offline run (the resumable step-key contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    {},
    {"stepping": "masked"},
    {"stepping": "masked", "schedule": ScheduleConfig()},
])
def test_resident_refresh_matches_offline_run(kw):
    x = 0.5 + jax.random.normal(jax.random.key(0), (200,))
    from repro.core import from_iid_loglik

    target = from_iid_loglik(lambda th: -0.5 * th**2,
                             lambda th, idx: -0.5 * (x[idx] - th) ** 2, None, 200)
    ens = ChainEnsemble(target, RandomWalk(0.1), 3,
                        config=SubsampledMHConfig(batch_size=50, epsilon=0.05), **kw)
    key = jax.random.key(7)
    resident = ResidentEnsemble(ens, jnp.zeros(()), key=key, window=32,
                                refresh_steps=5)
    resident.refresh()       # 5
    resident.refresh(4)      # 9
    resident.refresh(3)      # 12
    offline_state, offline_samples, _ = ens.run(
        None, ens.init(jnp.zeros(())), 12, step_keys=ens.step_keys(key, 0, 12)
    )
    snap = resident.snapshot()
    np.testing.assert_array_equal(np.asarray(snap.draws),
                                  np.asarray(offline_samples))
    np.testing.assert_array_equal(np.asarray(resident.state.theta),
                                  np.asarray(offline_state.theta))
    assert snap.steps_done == 12 and snap.num_draws == 36


def test_run_timed_resumption_matches_one_shot():
    x = jax.random.normal(jax.random.key(1), (150,))
    from repro.core import from_iid_loglik

    target = from_iid_loglik(lambda th: -0.5 * th**2,
                             lambda th, idx: -0.5 * (x[idx] - th) ** 2, None, 150)
    ens = ChainEnsemble(target, RandomWalk(0.1), 2,
                        config=SubsampledMHConfig(batch_size=30, epsilon=0.05))
    key = jax.random.key(3)
    s0 = ens.init(jnp.zeros(()))
    _, one_shot, _ = ens.run(None, s0, 10, step_keys=ens.step_keys(key, 0, 10))
    state, out1 = ens.run_timed(key, s0, 6, block_every=4)
    assert out1["next_step"] == 6
    _, out2 = ens.run_timed(key, state, 4, block_every=4,
                            start_step=out1["next_step"])
    np.testing.assert_array_equal(
        np.concatenate([out1["samples"], out2["samples"]], axis=1),
        np.asarray(one_shot),
    )


def test_run_timed_on_block_hook_streams_every_block():
    x = jax.random.normal(jax.random.key(2), (100,))
    from repro.core import from_iid_loglik

    target = from_iid_loglik(lambda th: -0.5 * th**2,
                             lambda th, idx: -0.5 * (x[idx] - th) ** 2, None, 100)
    ens = ChainEnsemble(target, RandomWalk(0.1), 2,
                        config=SubsampledMHConfig(batch_size=25, epsilon=0.05))
    seen = []
    ens.run_timed(jax.random.key(4), ens.init(jnp.zeros(())), 7, block_every=3,
                  on_block=lambda state, samples, infos, done: seen.append(
                      (done, np.asarray(samples).shape[1])))
    assert seen == [(3, 3), (6, 3), (7, 1)]


# ---------------------------------------------------------------------------
# Queue batching is result-transparent
# ---------------------------------------------------------------------------


def test_queue_batching_preserves_per_request_results(warm_pool):
    wl = warm_pool.workload("bayeslr")
    spec = wl.query_specs["predictive"]
    requests_xs = [spec.make_queries(jax.random.key(i), 3 + i) for i in range(5)]

    queue = RequestQueue(warm_pool, max_batch=5)
    reqs = [queue.submit("bayeslr", "predictive", xs) for xs in requests_xs]
    queue.drain()
    assert all(r.batch_size == 5 for r in reqs)

    snap = warm_pool.resident("bayeslr").snapshot()
    for req, xs in zip(reqs, requests_xs):
        solo, _ = warm_pool.query("bayeslr", "predictive", xs, snapshot=snap)
        np.testing.assert_allclose(req.values, solo, rtol=0, atol=0)
        assert req.deadline_met is not None and req.latency_s >= 0.0


def test_queue_groups_by_request_class(warm_pool):
    queue = RequestQueue(warm_pool, max_batch=8)
    wl = warm_pool.workload("bayeslr")
    for i in range(4):
        cls = "predictive" if i % 2 == 0 else "vote"
        queue.submit("bayeslr", cls,
                     wl.query_specs[cls].make_queries(jax.random.key(i), 2))
    served = queue.drain()
    assert len(served) == 4
    # same-class requests rode together; classes were not mixed in a batch
    assert all(r.batch_size == 2 for r in served)
    report = queue.slo_report()
    assert set(report["classes"]) == {"bayeslr.predictive", "bayeslr.vote"}
    for entry in report["classes"].values():
        assert {"p50_ms", "p95_ms", "p99_ms", "deadline_hit_rate"} <= set(entry)


def test_queue_worker_thread_serves(warm_pool):
    queue = RequestQueue(warm_pool, max_batch=4)
    queue.start_worker(max_wait_s=0.0)
    try:
        wl = warm_pool.workload("bayeslr")
        req = queue.submit(
            "bayeslr", "predictive",
            wl.query_specs["predictive"].make_queries(jax.random.key(0), 4),
        )
        values = req.result(timeout_s=30.0)
        assert values.shape == (4,)
    finally:
        queue.stop_worker()


# ---------------------------------------------------------------------------
# Unified SLO report schema: edge cases
# ---------------------------------------------------------------------------


def _fake_request(workload="w", query_class="fast", *, latency_s=None,
                  error=None, deadline_met=None, staleness_s=None,
                  batch_size=None):
    from repro.serving.queue import Request

    req = Request(workload=workload, query_class=query_class,
                  xs=np.zeros(1), deadline_s=1.0, submitted_at=0.0)
    req.latency_s = latency_s
    req.error = error
    req.deadline_met = deadline_met
    req.staleness_s = staleness_s
    req.batch_size = batch_size
    return req


def test_slo_report_empty_window():
    """No completed requests: totals are zero, classes is empty, and every
    schema key is still present (consumers never probe for keys)."""
    from repro.core.stats import build_slo_report, slo_summary

    report = build_slo_report([]).to_dict()
    assert report["count"] == 0 and report["errors"] == 0
    assert report["shed"] == 0 and report["classes"] == {}
    assert report["admission"] is None and report["recovery"] is None
    # the raw percentile helper still refuses an empty sample (pinned: an
    # accidental empty slice should be loud, not silently None)
    with pytest.raises(ValueError, match="at least one request"):
        slo_summary([])
    # a fresh queue reports the same empty-but-complete schema
    queue = RequestQueue(_tiny_pool(), max_batch=2)
    assert queue.slo_report()["count"] == 0


def test_slo_report_all_requests_shed():
    """Shed requests complete (count them) but are neither errors nor
    latency samples: percentiles stay None, deadline_hit_rate stays 0."""
    from repro.core.stats import build_slo_report

    reqs = [_fake_request(latency_s=0.001, error="shed: overload",
                          deadline_met=False) for _ in range(5)]
    report = build_slo_report(reqs).to_dict()
    assert report["count"] == 5          # they completed, just answerless
    assert report["errors"] == 0 and report["shed"] == 5
    entry = report["classes"]["w.fast"]
    assert entry["count"] == 0 and entry["shed"] == 5
    assert entry["p50_ms"] is None and entry["p95_ms"] is None
    assert entry["deadline_hit_rate"] == 0.0


def test_slo_report_single_sample_percentiles():
    """One successful request: every percentile collapses to that sample
    (no interpolation artifacts, no NaNs)."""
    from repro.core.stats import build_slo_report

    report = build_slo_report([_fake_request(
        latency_s=0.012, deadline_met=True, staleness_s=0.5, batch_size=1,
    )]).to_dict()
    entry = report["classes"]["w.fast"]
    assert entry["p50_ms"] == entry["p95_ms"] == entry["p99_ms"]
    assert entry["p50_ms"] == pytest.approx(12.0)
    assert entry["mean_ms"] == entry["max_ms"] == pytest.approx(12.0)
    assert entry["deadline_hit_rate"] == 1.0
    assert entry["staleness_mean_s"] == entry["staleness_max_s"] == 0.5


def test_slo_report_counters_override_and_errors_split():
    """Router-style submit-time counters override completion-derived
    admitted/shed, and a counters-only class still gets a row."""
    from repro.core.stats import build_slo_report

    reqs = [
        _fake_request(latency_s=0.010, deadline_met=True, batch_size=2),
        _fake_request(latency_s=0.030, error="RuntimeError: boom",
                      deadline_met=False),
    ]
    report = build_slo_report(
        reqs,
        priorities={"fast": 2, "bulk": 0},
        class_counters={("w", "fast"): {"admitted": 7, "shed": 3},
                        ("w", "bulk"): {"admitted": 0, "shed": 4}},
    ).to_dict()
    fast = report["classes"]["w.fast"]
    assert fast["count"] == 1 and fast["errors"] == 1
    assert fast["admitted"] == 7 and fast["shed"] == 3
    assert fast["priority"] == 2
    assert fast["deadline_hit_rate"] == 0.5  # failure counts as a miss
    assert fast["p95_ms"] == pytest.approx(10.0)  # error not a latency sample
    bulk = report["classes"]["w.bulk"]  # everything shed, nothing completed
    assert bulk["count"] == 0 and bulk["shed"] == 4
    assert report["errors"] == 1 and report["shed"] == 7


def test_slo_report_deprecated_total_requests_alias():
    """The pre-unification ``total_requests`` spelling still answers — with
    a DeprecationWarning — but is not a real key: iteration, ``in``, and
    JSON serialization see only the canonical schema."""
    import json

    from repro.core.stats import build_slo_report

    report = build_slo_report([_fake_request(latency_s=0.01,
                                             deadline_met=True)]).to_dict()
    with pytest.warns(DeprecationWarning, match="total_requests"):
        assert report["total_requests"] == report["count"] == 1
    with pytest.warns(DeprecationWarning):
        assert report.get("total_requests") == 1
    assert "total_requests" not in report
    assert "total_requests" not in json.dumps(report)
    # unknown keys are still plain KeyErrors / get-defaults, no warning
    with pytest.raises(KeyError):
        report["no_such_key"]
    assert report.get("no_such_key", "fallback") == "fallback"


# ---------------------------------------------------------------------------
# Freshness policy
# ---------------------------------------------------------------------------


def test_freshness_min_draws_forces_initial_refreshes():
    pool = _tiny_pool(min_draws=32, refresh_steps=4, window=16)
    resident = pool.resident("bayeslr")
    assert resident.steps_done == 0
    snap = pool.ensure_fresh("bayeslr")
    # 2 chains * 16-draw window: needs >= 16 steps of 4-step refreshes
    assert snap.num_draws >= 32 and resident.steps_done >= 16


def test_freshness_staleness_triggers_refresh():
    pool = _tiny_pool(min_draws=8, max_staleness_s=0.2)
    pool.resident("bayeslr").refresh()
    before = pool.resident("bayeslr").steps_done
    time.sleep(0.5)  # let the snapshot age past the staleness bound
    pool.query("bayeslr", "predictive",
               pool.workload("bayeslr").query_specs["predictive"].make_queries(
                   jax.random.key(0), 2))
    assert pool.resident("bayeslr").steps_done > before


def test_freshness_unreachable_raises():
    pool = _tiny_pool(min_draws=10**9)
    # tiny refresh bound so the test is fast
    pool.config = dataclasses.replace(pool.config, max_refreshes_per_query=2)
    with pytest.raises(RuntimeError, match="freshness unreachable"):
        pool.ensure_fresh("bayeslr")


def test_stale_reason_reporting():
    policy = FreshnessPolicy(max_staleness_s=10.0, min_draws=4)
    pool = _tiny_pool(min_draws=4)
    resident = pool.resident("bayeslr")
    assert policy.stale_reason(resident.snapshot()) == "no draws yet"
    resident.refresh()
    assert policy.stale_reason(resident.snapshot()) is None


# ---------------------------------------------------------------------------
# Checkpoint round-trip restores a warm pool
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_restores_warm_pool(tmp_path):
    pool = _tiny_pool()
    pool.warm()
    r1 = pool.resident("bayeslr")
    pool.save(str(tmp_path))

    pool2 = _tiny_pool()
    step = pool2.restore(str(tmp_path))
    r2 = pool2.resident("bayeslr")
    assert step == r1.steps_done == r2.steps_done
    np.testing.assert_array_equal(np.asarray(r1.state.theta),
                                  np.asarray(r2.state.theta))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        r1.state.sampler_state, r2.state.sampler_state,
    )
    np.testing.assert_array_equal(np.asarray(r1.snapshot().draws),
                                  np.asarray(r2.snapshot().draws))
    # restored pool is *warm*: its next refresh continues the original
    # key schedule bit for bit
    r1.refresh(4)
    r2.refresh(4)
    np.testing.assert_array_equal(np.asarray(r1.state.theta),
                                  np.asarray(r2.state.theta))
    np.testing.assert_array_equal(np.asarray(r1.snapshot().draws),
                                  np.asarray(r2.snapshot().draws))


def test_restore_rejects_missing_resident(tmp_path):
    pool = _tiny_pool()
    pool.warm()
    pool.save(str(tmp_path))
    other = EnsemblePool(ServingConfig(num_chains=2, refresh_steps=4, window=8))
    other.add_workload("ppl", smoke=True, n=100)
    with pytest.raises(KeyError, match="no state for resident"):
        other.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# Workload registry + the other paper workloads
# ---------------------------------------------------------------------------


def test_registry_lists_all_workloads():
    assert {"bayeslr", "stochvol", "jointdpm", "ppl"} <= set(serving_workloads())
    with pytest.raises(KeyError, match="unknown serving workload"):
        build_serving_workload("nope")


def test_ppl_workload_serves_predictives():
    wl = build_serving_workload("ppl", smoke=True, num_chains=2, n=120)
    resident = ResidentEnsemble(wl.ensemble, wl.theta0, key=jax.random.key(0),
                                window=8, refresh_steps=8, micro_batch=4)
    resident.refresh()
    xs = wl.query_specs["predictive"].make_queries(jax.random.key(1), 6)
    values, snap = resident.query(wl.query_specs["predictive"], xs)
    assert values.shape == (6,)
    assert np.all((values > 0.0) & (values < 1.0))
    assert snap.num_draws == 16


@pytest.mark.slow
def test_stochvol_workload_quantile_queries():
    wl = build_serving_workload("stochvol", smoke=True, num_chains=2,
                                num_series=20, length=4, num_particles=5)
    resident = ResidentEnsemble(wl.ensemble, wl.theta0, key=jax.random.key(0),
                                window=8, refresh_steps=8, micro_batch=4)
    resident.refresh()
    levels = np.asarray([0.25, 0.5, 0.75])
    values, _ = resident.query(wl.query_specs["vol_quantile"], levels)
    assert values.shape == (3,)
    assert values[0] <= values[1] <= values[2]  # quantiles are monotone
    assert np.all(values > 0)


@pytest.mark.slow
def test_jointdpm_workload_cluster_predictives():
    wl = build_serving_workload("jointdpm", smoke=True, num_chains=2, n=200)
    resident = ResidentEnsemble(wl.ensemble, wl.theta0, key=jax.random.key(0),
                                window=4, refresh_steps=4, micro_batch=4)
    resident.refresh()
    xs = wl.query_specs["cluster_predictive"].make_queries(jax.random.key(1), 5)
    values, _ = resident.query(wl.query_specs["cluster_predictive"], xs)
    assert values.shape == (5,)
    assert np.all((values >= 0.0) & (values <= 1.0))
    k_active, _ = resident.query(wl.query_specs["k_active"], xs)
    assert np.all(k_active >= 1.0)


# ---------------------------------------------------------------------------
# Resident background refresh + micro-batching
# ---------------------------------------------------------------------------


def test_background_refresh_advances_and_stops(warm_pool):
    # dedicated pool: don't mutate the shared fixture's refresh cadence
    pool = _tiny_pool(refresh_steps=4, window=8, min_draws=4)
    resident = pool.resident("bayeslr")
    resident.start_background(interval_s=0.001)
    deadline = time.monotonic() + 30.0
    while resident.steps_done < 8 and time.monotonic() < deadline:
        time.sleep(0.01)
    resident.stop_background()
    assert resident.steps_done >= 8
    after = resident.steps_done
    time.sleep(0.05)
    assert resident.steps_done == after  # actually stopped


def test_micro_batching_is_invisible_to_results(warm_pool):
    wl = warm_pool.workload("bayeslr")
    spec = wl.query_specs["predictive"]
    xs = spec.make_queries(jax.random.key(5), 13)  # not a micro_batch multiple
    snap = warm_pool.resident("bayeslr").snapshot()
    whole, _ = warm_pool.query("bayeslr", "predictive", xs, snapshot=snap)
    parts = [
        warm_pool.query("bayeslr", "predictive", xs[i:i + 4], snapshot=snap)[0]
        for i in range(0, 13, 4)
    ]
    np.testing.assert_allclose(whole, np.concatenate(parts), rtol=0, atol=0)


def test_zero_row_request_is_harmless_in_a_batch(warm_pool):
    """An empty request must return an empty result without failing the
    healthy requests coalesced into the same batch."""
    wl = warm_pool.workload("bayeslr")
    spec = wl.query_specs["predictive"]
    queue = RequestQueue(warm_pool, max_batch=3)
    healthy1 = queue.submit("bayeslr", "predictive",
                            spec.make_queries(jax.random.key(0), 3))
    empty = queue.submit("bayeslr", "predictive", np.empty((0, 3)))
    healthy2 = queue.submit("bayeslr", "predictive",
                            spec.make_queries(jax.random.key(1), 2))
    queue.drain()
    assert empty.error is None and empty.values.shape == (0,)
    assert healthy1.error is None and healthy1.values.shape == (3,)
    assert healthy2.error is None and healthy2.values.shape == (2,)


def test_malformed_request_fails_its_batch_not_the_server(warm_pool):
    queue = RequestQueue(warm_pool, max_batch=4)
    bad = queue.submit("bayeslr", "predictive", np.zeros((2, 99)))  # wrong width
    queue.drain()  # must not raise out of the serve loop
    assert bad.error is not None and bad.deadline_met is False
    report = queue.slo_report()
    entry = report["classes"]["bayeslr.predictive"]
    assert entry["errors"] == 1 and entry["deadline_hit_rate"] == 0.0
    assert entry["p50_ms"] is None  # failures don't fabricate latency stats


def test_query_before_refresh_raises():
    wl = build_serving_workload("bayeslr", smoke=True, n_train=200, d=3,
                                num_chains=2)
    resident = ResidentEnsemble(wl.ensemble, wl.theta0, key=jax.random.key(0))
    with pytest.raises(RuntimeError, match="no draws yet"):
        resident.query(wl.query_specs["predictive"], np.zeros((2, 3)))


# ---------------------------------------------------------------------------
# Online freshness from rolling R-hat (FreshnessPolicy.max_rhat)
# ---------------------------------------------------------------------------


def test_max_rhat_gate_admits_mixed_window():
    """A generous R-hat ceiling on a well-mixed conjugate posterior admits
    the snapshot after normal warm-up, and snapshot_rhat reports a finite
    value computed from the rolling window."""
    from repro.serving import snapshot_rhat

    pool = _tiny_pool(min_draws=8, max_rhat=5.0)
    snap = pool.ensure_fresh("bayeslr")
    rhat = snapshot_rhat(snap)
    assert rhat is not None and np.isfinite(rhat)
    assert pool.config.freshness.stale_reason(snap) is None


def test_max_rhat_gate_refuses_short_window():
    """Fewer than 4 draws per chain cannot be split into half-chains; the
    gate must read that as stale (and say why)."""
    from repro.serving import FreshnessPolicy

    pool = _tiny_pool(min_draws=2, max_rhat=1.5)
    resident = pool.resident("bayeslr")
    resident.refresh(2)  # window depth 2 < 4
    reason = pool.config.freshness.stale_reason(resident.snapshot())
    assert reason is not None and "split-R-hat" in reason


def test_max_rhat_gate_forces_refresh_until_mixed():
    """ensure_fresh keeps refreshing while the window's R-hat sits above the
    ceiling; the admitted snapshot satisfies it."""
    from repro.serving import snapshot_rhat

    pool = _tiny_pool(min_draws=8, max_rhat=1.8)
    snap = pool.ensure_fresh("bayeslr")
    assert snapshot_rhat(snap) <= 1.8


def test_max_rhat_gate_rejects_unmixed_window():
    """Disjoint per-chain windows (hand-built) must be refused by the gate."""
    from repro.serving import FreshnessPolicy
    from repro.serving.resident import Snapshot

    k, w = 2, 8
    draws = np.concatenate(
        [np.zeros((1, w, 3)), 10.0 + np.zeros((1, w, 3))], axis=0
    ) + 0.01 * np.random.default_rng(0).standard_normal((k, w, 3))
    snap = Snapshot(draws=draws, num_draws=k * w, steps_done=w,
                    staleness_s=0.0, summary={}, created_at=0.0)
    policy = FreshnessPolicy(max_staleness_s=1e9, min_draws=1, max_rhat=1.1)
    reason = policy.stale_reason(snap)
    assert reason is not None and "R-hat" in reason
