"""End-to-end request tracing: span lifecycle, the Tracer ring/stream,
Chrome/Perfetto export, queue-path propagation, and (slow tier) the
cross-process ReplicaProcess round-trip — one trace_id spanning two OS
processes on the shared monotonic timeline.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.obs import Recorder, Tracer, chrome_trace_events
from repro.obs.trace import (
    STAGES,
    load_spans,
    main as trace_main,
    span_close,
    span_open,
)
from repro.serving import FreshnessPolicy, RequestQueue, ServingConfig
from repro.serving.pool import EnsemblePool

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Span + Tracer primitives
# ---------------------------------------------------------------------------


def test_span_open_close_contract():
    span = span_open("t1", "request:w.q", "request", workload="w")
    assert span["trace_id"] == "t1" and span["parent_id"] is None
    assert span["stage"] in STAGES and span["pid"] == os.getpid()
    assert "dur_s" not in span  # open
    child = span_open("t1", "queue_wait", "queue_wait",
                      parent_id=span["span_id"])
    assert child["parent_id"] == span["span_id"]
    span_close(child, rows=4)
    span_close(span)
    assert child["dur_s"] >= 0 and child["rows"] == 4
    # raw spans (no tracer available where they're produced) carry no ids
    raw = span_open(None, "device_eval", "device_eval")
    assert raw["trace_id"] is None


def test_tracer_ring_bounds_and_counts_drops():
    tracer = Tracer(max_spans=3)
    roots = []
    for i in range(5):
        roots.append(tracer.finish(tracer.new_trace(f"r{i}", idx=i)))
    kept = tracer.spans()
    assert len(kept) == 3 and tracer.dropped == 2
    assert [s["idx"] for s in kept] == [2, 3, 4]  # newest survive
    assert tracer.trace(roots[-1]["trace_id"]) == [kept[-1]]
    tracer.close()


def test_tracer_adopt_grafts_raw_spans_onto_trace():
    tracer = Tracer()
    root = tracer.new_trace("request:w.q")
    inner_parent = span_close(span_open(None, "replica_serve", "replica_serve"))
    inner_child = span_close(span_open(
        None, "device_eval", "device_eval", parent_id=inner_parent["span_id"]))
    wire = dict(inner_child)
    wire["span_id"] = None  # e.g. assigned on the far side of a pipe
    adopted = tracer.adopt([inner_parent, wire], root["trace_id"],
                           parent_id=root["span_id"])
    assert all(s["trace_id"] == root["trace_id"] for s in adopted)
    assert adopted[0]["parent_id"] == root["span_id"]  # unparented -> grafted
    assert adopted[1]["parent_id"] == inner_parent["span_id"]  # kept
    assert adopted[1]["span_id"] is not None
    tracer.finish(root)
    assert len(tracer.spans()) == 3
    tracer.close()


def test_tracer_tees_to_recorder_stream_and_jsonl(tmp_path):
    rec = Recorder()
    path = str(tmp_path / "t" / "spans.jsonl")
    tracer = Tracer(recorder=rec, jsonl_path=path)
    tracer.finish(tracer.new_trace("request:a.b"))
    tracer.finish(tracer.new_trace("request:a.b"))
    spans_stream = rec.rollup()["streams"]["spans"]
    assert spans_stream["count"] == 2
    assert spans_stream["fields"]["dur_s"]["count"] == 2
    tracer.close()
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == 2 and all(l["dur_s"] is not None for l in lines)
    assert load_spans(str(tmp_path / "t")) == lines  # dir resolution
    rec.close()


# ---------------------------------------------------------------------------
# Chrome/Perfetto export
# ---------------------------------------------------------------------------


def test_chrome_trace_events_shape():
    tracer = Tracer()
    root = tracer.new_trace("request:w.q", workload="w")
    child = tracer.start(root["trace_id"], "assembly", "assembly",
                         parent_id=root["span_id"])
    tracer.finish(child)
    tracer.finish(root)
    open_span = tracer.new_trace("dangling")  # never closed
    payload = chrome_trace_events(tracer.spans() + [open_span])
    events = payload["traceEvents"]
    assert len(events) == 2  # open spans are excluded, not fabricated
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in events)
    assert min(e["ts"] for e in events) == 0.0  # rebased to earliest span
    assert events[0]["cat"] == "request" and events[1]["cat"] == "assembly"
    assert events[0]["args"]["workload"] == "w"  # tags ride in args
    json.dumps(payload)  # JSON-serializable as-is
    tracer.close()


def test_export_cli_roundtrip(tmp_path, capsys):
    path = str(tmp_path / "spans.jsonl")
    tracer = Tracer(jsonl_path=path)
    keep = tracer.finish(tracer.new_trace("request:w.q"))
    tracer.finish(tracer.new_trace("request:w.q"))
    tracer.close()
    assert trace_main(["--export", str(tmp_path)]) == 0
    line = capsys.readouterr().out.strip()
    assert line.startswith("TRACE_EXPORT spans=2 traces=2")
    out = json.loads((tmp_path / "trace.json").read_text())
    assert len(out["traceEvents"]) == 2
    # --trace-id narrows the export to one request
    assert trace_main(["--export", path, "--trace-id", keep["trace_id"],
                       "--out", str(tmp_path / "one.json")]) == 0
    one = json.loads((tmp_path / "one.json").read_text())
    assert len(one["traceEvents"]) == 1
    assert one["traceEvents"][0]["args"]["trace_id"] == keep["trace_id"]


# ---------------------------------------------------------------------------
# Queue-path propagation: submit -> batch assembly -> device eval
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def traced_pool():
    cfg = ServingConfig(
        num_chains=2, refresh_steps=8, window=16, micro_batch=8, max_batch=4,
        freshness=FreshnessPolicy(max_staleness_s=60.0, min_draws=16), seed=0,
    )
    pool = EnsemblePool(cfg)
    pool.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    pool.warm()
    return pool


def _contains(outer, inner, slack=1e-6):
    return (outer["start_s"] - slack <= inner["start_s"] and
            inner["start_s"] + inner["dur_s"]
            <= outer["start_s"] + outer["dur_s"] + slack)


def test_queue_serving_emits_nested_trace(traced_pool):
    tracer = Tracer()
    queue = RequestQueue(traced_pool, max_batch=4, tracer=tracer)
    spec = traced_pool.workload("bayeslr").query_specs["predictive"]
    reqs = [queue.submit("bayeslr", "predictive",
                         spec.make_queries(jax.random.key(i), 3))
            for i in range(3)]
    queue.drain()
    for req in reqs:
        assert req.trace_id is not None
        spans = tracer.trace(req.trace_id)
        stages = {s["stage"] for s in spans}
        # every request's journey carries its own root + queue_wait
        assert {"request", "queue_wait"} <= stages
        root = next(s for s in spans if s["stage"] == "request")
        assert root["parent_id"] is None
        assert root.get("deadline_met") is not None
        for s in spans:
            assert s.get("dur_s") is not None  # drain closed everything
            if s is not root:
                assert _contains(root, s)  # nesting-consistent timestamps
    # batch-level work (assembly + device eval) is attributed to the batch
    # head's trace — the full queue -> assembly -> device journey
    head_stages = {s["stage"] for s in tracer.trace(reqs[0].trace_id)}
    assert {"request", "queue_wait", "assembly", "device_eval"} <= head_stages
    # the batch-level spans are shared: 3 requests, one assembly span each
    # batch — with max_batch=4 all three rode together
    asm = [s for s in tracer.spans() if s["stage"] == "assembly"]
    assert len(asm) == 1 and asm[0]["batch_size"] == 3
    tracer.close()


def test_queue_error_path_still_closes_trace(traced_pool):
    tracer = Tracer()
    queue = RequestQueue(traced_pool, max_batch=2, tracer=tracer)
    req = queue.submit("bayeslr", "no_such_class", np.zeros((2, 3)))
    queue.drain()
    with pytest.raises(RuntimeError):
        req.result(timeout_s=5.0)
    spans = tracer.trace(req.trace_id)
    root = next(s for s in spans if s["stage"] == "request")
    assert root["dur_s"] is not None and root.get("error")
    assert all(s.get("dur_s") is not None for s in spans)
    tracer.close()


def test_untraced_queue_requests_carry_no_trace(traced_pool):
    queue = RequestQueue(traced_pool, max_batch=2)  # tracer off
    spec = traced_pool.workload("bayeslr").query_specs["predictive"]
    req = queue.submit("bayeslr", "predictive",
                       spec.make_queries(jax.random.key(0), 2))
    queue.drain()
    assert req.trace_id is None and req.trace is None
    assert req.values is not None


# ---------------------------------------------------------------------------
# Cross-process propagation (slow tier): ReplicaProcess round-trip
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_trace_crosses_replica_process_boundary():
    """One trace_id spans two OS processes: the root + serve spans come
    back from the replica worker with ITS pid, nest inside the parent's
    request span on the shared monotonic clock, and export as valid
    Perfetto X events across both pid tracks."""
    script = r"""
import json, os
import jax, numpy as np
from repro.fleet import Fleet, FleetConfig
from repro.obs import Tracer, chrome_trace_events
from repro.serving import FreshnessPolicy, ServingConfig

def main():
    cfg = FleetConfig(
        replicas=1, shards=1, transport="proc",
        serving=ServingConfig(num_chains=2, refresh_steps=8, window=16,
                              micro_batch=8,
                              freshness=FreshnessPolicy(max_staleness_s=1e9,
                                                        min_draws=8),
                              seed=0),
    )
    fleet = Fleet(cfg)
    fleet.add_workload("bayeslr", smoke=True, n_train=400, d=3, batch_size=50)
    fleet.warm(); fleet.pump()
    shard = fleet.shards("bayeslr")[0]
    spec = fleet.spec("bayeslr", "predictive")
    xs = spec.make_queries(jax.random.key(9), 4)

    tracer = Tracer()
    root = tracer.new_trace("request:bayeslr.predictive")
    values, staleness, spans = shard.replicas[0].serve(
        spec, "predictive", xs, trace=(root["trace_id"], root["span_id"]))
    tracer.adopt(spans, root["trace_id"], parent_id=root["span_id"])
    tracer.finish(root)
    all_spans = tracer.trace(root["trace_id"])
    rootc = next(s for s in all_spans if s["stage"] == "request")
    nested = all(
        rootc["start_s"] <= s["start_s"]
        and s["start_s"] + s["dur_s"] <= rootc["start_s"] + rootc["dur_s"]
        for s in all_spans if s is not rootc)
    events = chrome_trace_events(all_spans)["traceEvents"]
    fleet.close()
    print(json.dumps({
        "values_ok": bool(np.isfinite(np.asarray(values)).all()),
        "trace_ids": sorted({s["trace_id"] for s in all_spans}),
        "stages": sorted({s["stage"] for s in all_spans}),
        "pids": sorted({s["pid"] for s in all_spans}),
        "parent_pid": os.getpid(),
        "nested": nested,
        "events_ok": all(e["ph"] == "X" and e["dur"] >= 0 for e in events),
        "n_events": len(events),
    }))

if __name__ == "__main__":
    main()
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, cwd=_REPO, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["values_ok"] is True
    assert len(res["trace_ids"]) == 1  # ONE trace_id end to end
    assert {"request", "replica_serve", "device_eval"} <= set(res["stages"])
    assert len(res["pids"]) == 2  # parent + replica worker process
    assert res["parent_pid"] in res["pids"]
    assert res["nested"] is True  # monotone clock shared across processes
    assert res["events_ok"] is True and res["n_events"] >= 3
