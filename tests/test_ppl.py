"""PET trace / scaffold tests (paper Defs. 1–8 + Fig. 1 example)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ppl import (
    Trace,
    border_node,
    compile_partitioned_target,
    dists,
    scaffold,
)


def _fig1_trace():
    """[assume b (bernoulli 0.5)] [assume mu (if b 1 (gamma 1 1))]
    [assume y (normal mu 0.1)] [observe y 10.0]  with b = True."""
    tr = Trace()
    b = tr.sample("b", dists.bernoulli, tr.constant("p", 0.5), value=jnp.asarray(1.0))
    mu = tr.det("mu", lambda bb: jnp.where(bb > 0, 1.0, 0.0), b)
    sig = tr.constant("sig", 0.1)
    y = tr.sample("y", dists.normal, mu, sig, value=jnp.asarray(10.0))
    tr.observe(y, jnp.asarray(10.0))
    return tr, b, mu, y


def test_fig1_scaffold_sets():
    tr, b, mu, y = _fig1_trace()
    sc = scaffold(tr, b)
    assert {n.name for n in sc.D} == {"b", "mu"}
    assert sc.T == set()
    assert {n.name for n in sc.A} == {"y"}


def test_fig1_scaffold_for_downstream_variable():
    tr, b, mu, y = _fig1_trace()
    sc = scaffold(tr, y)
    assert {n.name for n in sc.D} == {"y"}
    assert sc.A == set() and sc.T == set()


def test_existential_edge_creates_transient_set():
    tr = Trace()
    b = tr.sample("b", dists.bernoulli, tr.constant("p", 0.5), value=jnp.asarray(0.0))
    g = tr.sample("g", dists.gamma, tr.constant("a", 1.0), tr.constant("r", 1.0),
                  value=jnp.asarray(0.7), exist_parent=b)
    sc = scaffold(tr, b)
    assert g in sc.T, "node whose existence depends on D must be transient"
    with pytest.raises(ValueError):
        # T != empty: subsampled MH must refuse (Sec 3.1 restriction)
        from repro.ppl.trace import partition

        partition(tr, sc)


def _bayeslr_trace(n=200, d=3, seed=0):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (n, d))
    w_true = jnp.linspace(-1, 1, d)
    yv = jnp.where(
        jax.random.bernoulli(jax.random.key(seed + 1), jax.nn.sigmoid(x @ w_true)), 1.0, -1.0
    )
    tr = Trace()
    w = tr.sample(
        "w", dists.mvnormal_diag,
        tr.constant("mu_w", jnp.zeros(d)),
        tr.constant("sig_w", jnp.sqrt(0.1) * jnp.ones(d)),
        value=jnp.zeros(d),
    )
    with tr.plate("data", n):
        xn = tr.constant("x", x)
        z = tr.det("z", lambda xx, ww: xx @ ww, xn, w)
        yn = tr.sample("y", dists.bernoulli_logits, z, value=yv)
        tr.observe(yn, yv)
    return tr, w, x, yv


def test_border_node_is_w_for_bayeslr():
    tr, w, _, _ = _bayeslr_trace()
    sc = scaffold(tr, w)
    assert border_node(tr, sc) is w
    # D contains w and the deterministic z inside the plate
    assert {n.name for n in sc.D} == {"w", "z"}
    assert {n.name for n in sc.A} == {"y"}


def test_compiled_target_matches_hand_derivation():
    tr, w, x, yv = _bayeslr_trace()
    target = compile_partitioned_target(tr, w)
    assert target.num_sections == x.shape[0]

    t1 = jnp.full((3,), 0.3)
    t2 = jnp.full((3,), -0.2)
    idx = jnp.arange(40, dtype=jnp.int32)

    hand_global = (-0.5 / 0.1) * (jnp.sum(t2**2) - jnp.sum(t1**2))
    hand_local = -jnp.logaddexp(0, -yv[idx] * (x[idx] @ t2)) + jnp.logaddexp(
        0, -yv[idx] * (x[idx] @ t1)
    )
    np.testing.assert_allclose(float(target.log_global(t1, t2)), float(hand_global), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(target.log_local(t1, t2, idx)), np.asarray(hand_local), rtol=1e-4, atol=1e-5
    )


def test_compiled_target_log_density_is_consistent():
    tr, w, x, yv = _bayeslr_trace(n=50)
    target = compile_partitioned_target(tr, w)
    t1 = jnp.zeros(3)
    t2 = jnp.full((3,), 0.1)
    idx = jnp.arange(50, dtype=jnp.int32)
    delta_via_density = float(target.log_density(t2) - target.log_density(t1))
    delta_via_parts = float(target.log_global(t1, t2) + target.log_local(t1, t2, idx).sum())
    np.testing.assert_allclose(delta_via_density, delta_via_parts, rtol=1e-4, atol=1e-4)


def test_compiled_logit_program_gets_fused_family():
    """ppl/compile.py emits through the target builder: a program whose local
    score matches the logit family carries the fused ensemble evaluation."""
    tr, w, x, yv = _bayeslr_trace(n=250)
    target = compile_partitioned_target(tr, w)
    assert target.family == "logit"
    assert target.log_local_ensemble is not None


def test_compiled_logit_fused_path_matches_unfused_bit_for_bit():
    """The compiled program's log_local_ensemble (ref dispatch on CPU) must
    agree bit for bit with its unfused log_local under vmap."""
    tr, w, x, yv = _bayeslr_trace(n=250)
    target = compile_partitioned_target(tr, w)
    K, m = 4, 40
    ks = jax.random.split(jax.random.key(2), 3)
    wc = jax.random.normal(ks[0], (K, 3))
    wp = jax.random.normal(ks[1], (K, 3))
    idx = jax.random.randint(ks[2], (K, m), 0, 250)
    vmapped = jax.jit(lambda a, b, i: jax.vmap(target.log_local)(a, b, i))(wc, wp, idx)
    fused = jax.jit(target.log_local_ensemble)(wc, wp, idx)
    np.testing.assert_array_equal(np.asarray(vmapped), np.asarray(fused))


def test_compiled_clipped_logit_program_is_not_misclassified():
    """A saturating variant of the inner product (clip(x@w, -c, c)) must
    fail the numeric family gate — attaching the pure logit kernel would
    silently change the model on the fused path."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (100, 3))
    yv = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (100,)), 1.0, -1.0)
    tr = Trace()
    w = tr.sample(
        "w", dists.mvnormal_diag,
        tr.constant("mu_w", jnp.zeros(3)),
        tr.constant("sig_w", jnp.ones(3)),
        value=jnp.zeros(3),
    )
    with tr.plate("data", 100):
        xn = tr.constant("x", x)
        z = tr.det("z", lambda xx, ww: jnp.clip(xx @ ww, -15.0, 15.0), xn, w)
        yn = tr.sample("y", dists.bernoulli_logits, z, value=yv)
        tr.observe(yn, yv)
    target = compile_partitioned_target(tr, w)
    assert target.family is None
    assert target.log_local_ensemble is None


def test_compiled_non_logit_program_has_no_family():
    """A conjugate-normal plate matches no registered family: the compiler
    must emit the generic graph-evaluated target, not a wrong fused route."""
    n = 50
    x = 0.5 + jax.random.normal(jax.random.key(0), (n,))
    tr = Trace()
    mu = tr.sample("mu", dists.normal, tr.constant("m0", 0.0),
                   tr.constant("s0", 1.0), value=jnp.asarray(0.2))
    sig = tr.constant("sig", 1.0)
    with tr.plate("data", n):
        yn = tr.sample("y", dists.normal, mu, sig, value=x)
        tr.observe(yn, x)
    target = compile_partitioned_target(tr, mu)
    assert target.family is None
    assert target.log_local_ensemble is None
    # and it still scores correctly
    idx = jnp.arange(n, dtype=jnp.int32)
    want = (-0.5 * (x - 0.3) ** 2) - (-0.5 * (x - 0.2) ** 2)
    np.testing.assert_allclose(
        np.asarray(target.log_local(jnp.asarray(0.2), jnp.asarray(0.3), idx)),
        np.asarray(want), rtol=1e-4, atol=1e-5)


def test_compiled_family_target_rides_fused_ensemble():
    """End to end: a compiled program on the fused lock-step ensemble agrees
    with the unfused engine."""
    from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig

    tr, w, x, yv = _bayeslr_trace(n=300)
    target = compile_partitioned_target(tr, w)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05, sampler="stream")
    K, T = 2, 30
    keys = jax.random.split(jax.random.key(4), K)
    plain = ChainEnsemble(target, RandomWalk(0.1), K, config=cfg, fused_kernels="never")
    fused = ChainEnsemble(target, RandomWalk(0.1), K, config=cfg, fused_kernels="always")
    _, s_p, _ = plain.run(keys, plain.init(jnp.zeros(3)), T)
    _, s_f, _ = fused.run(keys, fused.init(jnp.zeros(3)), T)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_f), rtol=2e-4, atol=2e-5)


def test_compiled_target_runs_subsampled_chain():
    from repro.core import RandomWalk, SubsampledMHConfig, run_chain

    tr, w, x, yv = _bayeslr_trace(n=300)
    target = compile_partitioned_target(tr, w)
    _, samples, infos = run_chain(
        jax.random.key(1), jnp.zeros(3), target, RandomWalk(0.1), 200,
        kernel="subsampled", config=SubsampledMHConfig(batch_size=50, epsilon=0.05),
    )
    assert np.asarray(samples).shape == (200, 3)
    assert np.isfinite(np.asarray(samples)).all()
    assert 0.0 < np.mean(np.asarray(infos.accepted)) < 1.0


# ---------------------------------------------------------------------------
# gaussian_ar1 state-space plate detection
# ---------------------------------------------------------------------------


def _ar1_trace(n=200, phi0=0.5, sig=0.3, det_fn=None):
    rng = np.random.default_rng(0)
    x = np.zeros(n + 1, np.float32)
    for t in range(1, n + 1):
        x[t] = 0.8 * x[t - 1] + sig * rng.standard_normal()
    x = jnp.asarray(x)
    tr = Trace()
    phi = tr.sample("phi", dists.normal, tr.constant("m0", 0.0),
                    tr.constant("s0", 1.0), value=jnp.asarray(phi0))
    sig_node = tr.constant("sigma", sig)
    with tr.plate("steps", n):
        xprev = tr.constant("x_prev", x[:-1])
        fn = det_fn or (lambda xp, ph: ph * xp)
        mu = tr.det("mu", fn, xprev, phi)
        xt = tr.sample("x", dists.normal, mu, sig_node, value=x[1:])
        tr.observe(xt, x[1:])
    return tr, phi, x


def test_compiled_ar1_program_gets_gaussian_ar1_family():
    """A state-space plate x_t ~ N(phi x_{t-1}, sigma) compiles onto the
    gaussian_ar1 kernel family with the fused ensemble route attached."""
    tr, phi, _ = _ar1_trace()
    target = compile_partitioned_target(tr, phi)
    assert target.family == "gaussian_ar1"
    assert target.log_local_ensemble is not None


def test_compiled_ar1_ensemble_matches_graph_log_local():
    """The family-built (K, m) evaluation must agree with the compiled
    graph-evaluated log_local under vmap (f32 tolerance: the reference
    kernel reassociates the quadratic)."""
    n = 200
    tr, phi, _ = _ar1_trace(n=n)
    target = compile_partitioned_target(tr, phi)
    K, m = 4, 32
    th = jnp.linspace(0.3, 0.9, K)
    thp = th + 0.05
    idx = jax.random.randint(jax.random.key(1), (K, m), 0, n)
    ens = np.asarray(target.log_local_ensemble(th, thp, idx))
    ref = np.asarray(jax.vmap(target.log_local)(th, thp, idx))
    np.testing.assert_allclose(ens, ref, rtol=1e-5, atol=1e-6)


def test_compiled_ar1_runs_subsampled_ensemble():
    """End-to-end: the compiled state-space program rides ChainEnsemble,
    and the family path agrees with fused_kernels='never'."""
    from repro.core import ChainEnsemble, RandomWalk, SubsampledMHConfig

    n = 300
    tr, phi, _ = _ar1_trace(n=n)
    target = compile_partitioned_target(tr, phi)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
    keys = jax.random.split(jax.random.key(4), 3)
    runs = {}
    for mode in ("always", "never"):
        ens = ChainEnsemble(target, RandomWalk(0.05), 3, config=cfg,
                            fused_kernels=mode)
        _, s, i = ens.run(keys, ens.init(jnp.asarray(0.5)), 25)
        runs[mode] = np.asarray(s)
    np.testing.assert_allclose(runs["always"], runs["never"],
                               rtol=2e-4, atol=2e-5)


def test_compiled_saturating_ar1_is_not_misclassified():
    """A saturating AR mean (tanh(phi x_{t-1})) must fail the numeric gate
    and compile to the generic graph-evaluated target."""
    tr, phi, _ = _ar1_trace(det_fn=lambda xp, ph: jnp.tanh(ph * xp))
    target = compile_partitioned_target(tr, phi)
    assert target.family is None
    assert target.log_local_ensemble is None


def test_ar1_with_plate_varying_scale_is_not_matched():
    """Heteroscedastic noise (a per-step scale series) is outside the
    gaussian_ar1 family; the gate must refuse it."""
    n = 100
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n + 1).astype(np.float32))
    tr = Trace()
    phi = tr.sample("phi", dists.normal, tr.constant("m0", 0.0),
                    tr.constant("s0", 1.0), value=jnp.asarray(0.5))
    with tr.plate("steps", n):
        xprev = tr.constant("x_prev", x[:-1])
        mu = tr.det("mu", lambda xp, ph: ph * xp, xprev, phi)
        sig_series = tr.constant("sigma_t", jnp.linspace(0.1, 0.5, n))
        xt = tr.sample("x", dists.normal, mu, sig_series, value=x[1:])
        tr.observe(xt, x[1:])
    target = compile_partitioned_target(tr, phi)
    assert target.family is None
