"""Adaptive scheduling layer: controller edge cases, bounded draws, and the
masked-continuation stepping mode's equivalence/throughput properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChainEnsemble,
    RandomWalk,
    ScheduleConfig,
    SubsampledMHConfig,
    SubsampledMHInfo,
    controller_init,
    controller_params,
    controller_update,
    fy_draw_bounded,
    fy_init,
    fy_reset,
    run_chain,
    split_rhat,
    stream_draw_bounded,
    stream_init,
    tail_latency_summary,
)

CFG = SubsampledMHConfig(batch_size=50, epsilon=0.05)


def _info(rounds=1, n_evaluated=50, accepted=True):
    z = lambda v, dt: jnp.asarray(v, dt)
    return SubsampledMHInfo(
        accepted=z(accepted, bool), n_evaluated=z(n_evaluated, jnp.int32),
        rounds=z(rounds, jnp.int32), mu_hat=z(0.0, jnp.float32),
        mu0=z(0.0, jnp.float32), pvalue=z(0.5, jnp.float32),
        log_u=z(-1.0, jnp.float32), epsilon=z(0.05, jnp.float32),
        batch_eff=z(50, jnp.int32),
    )


def _drive(sched, info, steps, n=1000, cfg=CFG):
    buckets = sched.buckets_for(cfg, n)
    floor = sched.epsilon_floor(cfg)
    st = controller_init(sched, cfg, n)
    for _ in range(steps):
        st = controller_update(st, info, sched, buckets, n, floor)
    return st, buckets


# ---------------------------------------------------------------------------
# Controller unit behavior
# ---------------------------------------------------------------------------


def test_epsilon_clamped_at_floor_on_easy_chains():
    """A stream of easy one-round decisions decays epsilon to the floor —
    the base config epsilon — and never below it."""
    sched = ScheduleConfig(epsilon_max=0.2)
    st, _ = _drive(sched, _info(rounds=1, n_evaluated=50), steps=400)
    assert float(st.epsilon) == pytest.approx(CFG.epsilon)
    # one more easy transition cannot go under the floor
    buckets = sched.buckets_for(CFG, 1000)
    st2 = controller_update(st, _info(), sched, buckets, 1000, sched.epsilon_floor(CFG))
    assert float(st2.epsilon) >= CFG.epsilon


def test_epsilon_clamped_at_ceiling_on_hard_chains():
    sched = ScheduleConfig(epsilon_max=0.2)
    st, _ = _drive(sched, _info(rounds=20, n_evaluated=1000), steps=400)
    assert float(st.epsilon) == pytest.approx(0.2)


def test_bucket_saturates_at_boundaries():
    sched = ScheduleConfig(batch_buckets=(25, 50, 100))
    # hard chains climb to the top bucket and stay there
    hi, buckets = _drive(sched, _info(rounds=10, n_evaluated=500), steps=50)
    assert int(hi.bucket) == len(buckets) - 1
    _, meff = controller_params(hi, buckets)
    assert int(meff) == 100
    # easy chains descend to the bottom bucket and stay there
    lo, _ = _drive(sched, _info(rounds=1, n_evaluated=25), steps=50)
    assert int(lo.bucket) == 0
    eps, meff = controller_params(lo, buckets)
    assert int(meff) == 25 and float(eps) >= CFG.epsilon


def test_adaptation_toggles_freeze_knobs():
    sched = ScheduleConfig(adapt_batch_size=False, adapt_epsilon=False)
    st, buckets = _drive(sched, _info(rounds=50, n_evaluated=1000), steps=30)
    init = controller_init(sched, CFG, 1000)
    assert int(st.bucket) == int(init.bucket)
    assert float(st.epsilon) == float(init.epsilon)
    # EMAs still track even with frozen knobs
    assert float(st.ema_rounds) > 10


def test_schedule_config_validation():
    with pytest.raises(ValueError):
        ScheduleConfig(batch_buckets=(0, 10))
    with pytest.raises(ValueError):
        ScheduleConfig(epsilon_grow=0.5)
    # buckets are sorted, deduped, and clipped to the pool
    sched = ScheduleConfig(batch_buckets=(100, 25, 100, 50))
    assert sched.batch_buckets == (25, 50, 100)
    assert sched.buckets_for(CFG, num_sections=60) == (25, 50, 60)


def test_controller_init_batched_and_jittable():
    sched = ScheduleConfig()
    st = controller_init(sched, CFG, 1000, num_chains=8)
    assert st.bucket.shape == (8,)
    buckets = sched.buckets_for(CFG, 1000)
    upd = jax.jit(jax.vmap(
        lambda s, i: controller_update(s, i, sched, buckets, 1000, CFG.epsilon)
    ))
    infos = jax.tree.map(lambda l: jnp.broadcast_to(l, (8,) + l.shape), _info(rounds=9))
    st2 = upd(st, infos)
    assert st2.t.shape == (8,) and int(st2.t[0]) == 1


# ---------------------------------------------------------------------------
# Bounded without-replacement draws (the bucket mechanism)
# ---------------------------------------------------------------------------


def test_fy_draw_bounded_consumes_pool_at_effective_rate():
    n, m_max = 40, 16
    state = fy_reset(fy_init(n))
    key = jax.random.key(0)
    seen = []
    for r in range(10):
        key, sub = jax.random.split(key)
        m_eff = jnp.int32(5)
        state, idx, valid = fy_draw_bounded(sub, state, m_max, m_eff)
        assert valid.shape == (m_max,)
        got = np.asarray(idx)[np.asarray(valid)]
        assert len(got) == min(5, n - 5 * r)
        seen.extend(got.tolist())
        if int(state.pos) >= n:
            break
    assert int(state.pos) == n
    assert sorted(seen) == list(range(n)), "bounded draws must still be a permutation"


def test_stream_draw_bounded_advances_by_m_eff():
    state = stream_init(100)
    state, idx, valid = stream_draw_bounded(jax.random.key(0), state, 32, jnp.int32(10))
    assert int(state.pos) == 10
    assert int(valid.sum()) == 10
    np.testing.assert_array_equal(np.asarray(idx[:10]), np.arange(10))
    # clamp: m_eff beyond m_max is capped
    state, _, valid = stream_draw_bounded(jax.random.key(0), state, 32, jnp.int32(99))
    assert int(valid.sum()) == 32 and int(state.pos) == 42


# ---------------------------------------------------------------------------
# Masked-continuation stepping: equivalence and correctness
# ---------------------------------------------------------------------------


def test_masked_matches_lockstep_bit_for_bit_when_adaptation_disabled(
    gaussian_target_factory,
):
    """Acceptance criterion: with no schedule, stepping="masked" reproduces
    the lock-step engine's samples/infos exactly (pvalue is compared to f32
    tolerance only: XLA fuses the betainc tail differently in the two
    programs, which moves the last ulp without touching any decision)."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    K, T = 3, 80
    keys = jax.random.split(jax.random.key(7), K)
    lock = ChainEnsemble(target, RandomWalk(0.05), K, config=CFG)
    mask = ChainEnsemble(target, RandomWalk(0.05), K, config=CFG, stepping="masked")
    st_l, s_l, i_l = lock.run(keys, lock.init(jnp.zeros(())), T)
    st_m, s_m, i_m = mask.run(keys, mask.init(jnp.zeros(())), T)
    np.testing.assert_array_equal(np.asarray(s_l), np.asarray(s_m))
    np.testing.assert_array_equal(np.asarray(st_l.theta), np.asarray(st_m.theta))
    for field in ("accepted", "n_evaluated", "rounds", "mu_hat", "mu0", "log_u",
                  "epsilon", "batch_eff"):
        np.testing.assert_array_equal(
            np.asarray(getattr(i_l, field)), np.asarray(getattr(i_m, field)), err_msg=field
        )
    np.testing.assert_allclose(
        np.asarray(i_l.pvalue), np.asarray(i_m.pvalue), rtol=1e-5, atol=1e-30
    )


def test_masked_single_chain_matches_run_chain(gaussian_target_factory):
    """K=1 edge case: the superstep degenerates to a single chain and must
    still reproduce the sequential driver."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    keys = jax.random.split(jax.random.key(3), 1)
    ens = ChainEnsemble(target, RandomWalk(0.05), 1, config=CFG, stepping="masked")
    _, samples, infos = ens.run(keys, ens.init(jnp.zeros(())), 60)
    _, s_seq, i_seq = run_chain(keys[0], jnp.zeros(()), target, RandomWalk(0.05), 60,
                                config=CFG)
    np.testing.assert_array_equal(np.asarray(samples[0]), np.asarray(s_seq))
    np.testing.assert_array_equal(np.asarray(infos.accepted[0]), np.asarray(i_seq.accepted))


def test_masked_adaptive_stays_within_knob_bounds(gaussian_target_factory):
    target, pm, ps = gaussian_target_factory(n=600, seed=1)
    sched = ScheduleConfig(epsilon_max=0.2)
    K, T = 4, 300
    ens = ChainEnsemble(target, RandomWalk(0.08), K, config=CFG, stepping="masked",
                        schedule=sched)
    state = ens.init(jnp.zeros(()) + pm)
    state, samples, infos = ens.run(jax.random.key(2), state, T)
    eps = np.asarray(infos.epsilon)
    meff = np.asarray(infos.batch_eff)
    buckets = set(sched.buckets_for(CFG, 600))
    assert eps.min() >= CFG.epsilon - 1e-7 and eps.max() <= 0.2 + 1e-7
    assert set(np.unique(meff).tolist()) <= buckets
    assert np.asarray(state.controller.t).tolist() == [T] * K
    # chains stay distinct and near the posterior
    s = np.asarray(samples)
    assert not np.array_equal(s[0], s[1])
    assert abs(s[:, T // 2:].mean() - pm) < 6 * ps
    rhat = split_rhat(s[:, T // 2:])
    assert rhat < 1.2, f"adaptive chains did not mix: rhat={rhat}"


def test_adaptive_lockstep_threads_controller(gaussian_target_factory):
    """The controller also rides the lock-step scan (per-chain traced knobs
    through the vmapped subsampled_mh_step)."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    ens = ChainEnsemble(target, RandomWalk(0.05), 3, config=CFG,
                        schedule=ScheduleConfig())
    state, samples, infos = ens.run(jax.random.key(0), ens.init(jnp.zeros(())), 50)
    assert samples.shape == (3, 50)
    assert np.asarray(state.controller.t).tolist() == [50, 50, 50]
    assert np.asarray(infos.batch_eff).min() >= 1


def test_lockstep_schedule_realizes_buckets_above_base_batch(
    gaussian_target_factory,
):
    """Buckets larger than config.batch_size must actually be drawn in the
    lock-step scheduled path (the static draw shape is max(buckets), not
    the base batch size)."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    sched = ScheduleConfig(batch_buckets=(200,))
    ens = ChainEnsemble(target, RandomWalk(0.05), 2, config=CFG, schedule=sched)
    _, _, infos = ens.run(jax.random.key(0), ens.init(jnp.zeros(())), 20)
    assert np.asarray(infos.batch_eff).min() == 200
    # every transition's first round already merges a full 200-section batch
    assert np.asarray(infos.n_evaluated).min() >= 200


def test_masked_state_carries_across_runs(gaussian_target_factory):
    """Continuation purity holds in masked mode exactly as in lock-step."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    ens = ChainEnsemble(target, RandomWalk(0.05), 2, config=CFG, stepping="masked",
                        schedule=ScheduleConfig())
    keys = jax.random.split(jax.random.key(11), 2)
    st_a, s_a, _ = ens.run(keys, ens.init(jnp.zeros(())), 40)
    _, s_c1, _ = ens.run(jax.random.key(12), st_a, 10)
    _, s_c2, _ = ens.run(jax.random.key(12), st_a, 10)
    np.testing.assert_array_equal(np.asarray(s_c1), np.asarray(s_c2))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(st_a.theta)[0]), np.asarray(s_a[:, -1])
    )


def test_ensemble_config_validation(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, kernel="exact", stepping="masked")
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, kernel="exact",
                      schedule=ScheduleConfig())
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, stepping="masked", shard=True)
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, stepping="nope")
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, fused_kernels="maybe")
    with pytest.raises(ValueError):
        # forcing the fused route needs a target that actually carries the
        # ensemble evaluation (build it via repro.core.build_target)
        ChainEnsemble(target, RandomWalk(0.05), 2, fused_kernels="always")


def test_masked_fused_kernel_path_matches_vmap(gaussian_target_factory):
    """Forcing the fused (K, m) kernel route (interpret/ref off-TPU) agrees
    with the vmapped log_local path to float tolerance."""
    import jax.numpy as jnp

    from repro.experiments import bayeslr

    data = bayeslr.synth_2d(jax.random.key(0), n=800)
    target = bayeslr.make_target(data.x_train, data.y_train)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05, sampler="stream")
    K, T = 3, 40
    keys = jax.random.split(jax.random.key(5), K)
    plain = ChainEnsemble(target, RandomWalk(0.1), K, config=cfg, stepping="masked",
                          fused_kernels="never")
    fused = ChainEnsemble(target, RandomWalk(0.1), K, config=cfg, stepping="masked",
                          fused_kernels="always")
    _, s_p, i_p = plain.run(keys, plain.init(jnp.zeros(2)), T)
    _, s_f, i_f = fused.run(keys, fused.init(jnp.zeros(2)), T)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_f), rtol=2e-4, atol=2e-5)
    # the decision stream should agree everywhere at this tolerance
    assert (np.asarray(i_p.accepted) == np.asarray(i_f.accepted)).mean() > 0.95


def test_tail_latency_summary_shapes():
    rounds = np.array([[1, 1, 2, 8], [1, 3, 1, 1]])
    t = tail_latency_summary(rounds)
    assert t["max"] == 8.0 and t["p50"] == 1.0
    assert t["hist"].sum() == rounds.size
    assert t["edges"][0] == 1 and len(t["edges"]) == len(t["hist"])
    with pytest.raises(ValueError):
        tail_latency_summary(np.empty((0,)))


# ---------------------------------------------------------------------------
# Adaptive proposals (ScheduleConfig.adapt_proposal)
# ---------------------------------------------------------------------------


def test_adapt_proposal_flag_off_is_inert_and_bit_for_bit(gaussian_target_factory):
    """Regression for the satellite contract: with adapt_proposal=False
    (default) the new proposal knobs must not leak into the run — samples,
    infos, and controller trajectories are bitwise identical whatever the
    proposal-adaptation hyperparameters are set to."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    K, T = 3, 60
    keys = jax.random.split(jax.random.key(11), K)
    base = ScheduleConfig()
    weird = ScheduleConfig(accept_target=0.9, proposal_gain=7.0, scale_max=5.0)
    assert not base.adapt_proposal and not weird.adapt_proposal
    runs = []
    for sched in (base, weird):
        for stepping in ("lockstep", "masked"):
            ens = ChainEnsemble(target, RandomWalk(0.05), K, config=CFG,
                                stepping=stepping, schedule=sched)
            st, s, i = ens.run(keys, ens.init(jnp.zeros(())), T)
            runs.append((stepping, st, s, i))
    by_step = {}
    for stepping, st, s, i in runs:
        if stepping in by_step:
            st0, s0, i0 = by_step[stepping]
            np.testing.assert_array_equal(np.asarray(s0), np.asarray(s))
            np.testing.assert_array_equal(np.asarray(i0.accepted),
                                          np.asarray(i.accepted))
            np.testing.assert_array_equal(np.asarray(st0.controller.sigma_scale),
                                          np.asarray(st.controller.sigma_scale))
        else:
            by_step[stepping] = (st, s, i)
    # and the scale itself never moves off 1.0 with the flag off
    for stepping, st, _, _ in runs:
        np.testing.assert_array_equal(
            np.asarray(st.controller.sigma_scale), np.ones(K, np.float32)
        )


def test_adapt_proposal_gain_zero_matches_flag_off(gaussian_target_factory):
    """gain=0 keeps sigma_scale pinned at 1.0; threading a unit scale through
    the proposal must reproduce the unscaled run (allclose: the extra
    multiply can change XLA fusion, so last-ulp only)."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    K, T = 3, 50
    keys = jax.random.split(jax.random.key(5), K)
    off = ChainEnsemble(target, RandomWalk(0.05), K, config=CFG,
                        stepping="masked", schedule=ScheduleConfig())
    on0 = ChainEnsemble(target, RandomWalk(0.05), K, config=CFG,
                        stepping="masked",
                        schedule=ScheduleConfig(adapt_proposal=True,
                                                proposal_gain=0.0))
    _, s_off, i_off = off.run(keys, off.init(jnp.zeros(())), T)
    st_on, s_on, i_on = on0.run(keys, on0.init(jnp.zeros(())), T)
    np.testing.assert_allclose(np.asarray(s_off), np.asarray(s_on),
                               rtol=2e-6, atol=1e-7)
    np.testing.assert_array_equal(
        np.asarray(st_on.controller.sigma_scale), np.ones(K, np.float32)
    )


def test_adapt_proposal_grows_scale_under_high_acceptance(gaussian_target_factory):
    """A too-small sigma accepts nearly everything; the controller must push
    sigma_scale up (and clamp it at scale_max)."""
    target, pm, _ = gaussian_target_factory(n=600, seed=1)
    sched = ScheduleConfig(adapt_proposal=True, proposal_gain=1.0, scale_max=4.0)
    ens = ChainEnsemble(target, RandomWalk(1e-4), 3, config=CFG,
                        stepping="masked", schedule=sched)
    state, _, infos = ens.run(jax.random.key(9), ens.init(jnp.zeros(()) + pm), 120)
    scale = np.asarray(state.controller.sigma_scale)
    assert np.all(scale > 1.5), scale
    assert np.all(scale <= 4.0 + 1e-6), scale
    assert np.asarray(infos.accepted, np.float64).mean() > 0.5


def test_adapt_proposal_shrinks_scale_under_rejection(gaussian_target_factory):
    """A huge sigma rejects nearly everything; the scale must decay toward
    scale_min in every stepping mode that threads the controller."""
    target, pm, _ = gaussian_target_factory(n=600, seed=1)
    sched = ScheduleConfig(adapt_proposal=True, proposal_gain=1.0, scale_min=0.25)
    for stepping in ("lockstep", "masked"):
        ens = ChainEnsemble(target, RandomWalk(50.0), 2, config=CFG,
                            stepping=stepping, schedule=sched)
        state, _, _ = ens.run(jax.random.key(4), ens.init(jnp.zeros(()) + pm), 150)
        scale = np.asarray(state.controller.sigma_scale)
        assert np.all(scale < 0.9), (stepping, scale)
        assert np.all(scale >= 0.25 - 1e-6), (stepping, scale)


def test_adapt_proposal_requires_scale_aware_proposal(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=600, seed=1)

    def rigid_proposal(key, theta):
        return theta, jnp.zeros((), jnp.float32)

    with pytest.raises(ValueError, match="scale"):
        ChainEnsemble(target, rigid_proposal, 2, config=CFG,
                      schedule=ScheduleConfig(adapt_proposal=True))


def test_adapt_proposal_schedule_config_validation():
    with pytest.raises(ValueError, match="scale_min"):
        ScheduleConfig(scale_min=0.0)
    with pytest.raises(ValueError, match="accept_target"):
        ScheduleConfig(accept_target=1.5)


def test_adapt_gain_decay_inert_without_proposal_adaptation():
    """The Robbins–Monro knob must not leak when proposal adaptation is off:
    whatever decay is set, the controller (including sigma_scale) is
    bit-for-bit the default controller."""
    base = ScheduleConfig()
    decayed = ScheduleConfig(adapt_gain_decay=0.7)
    assert not base.adapt_proposal and not decayed.adapt_proposal
    info = _info(accepted=True, rounds=4, n_evaluated=950)
    st_a, _ = _drive(base, info, 40, n=1000)
    st_b, _ = _drive(decayed, info, 40, n=1000)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        st_a, st_b,
    )


def test_adapt_gain_decay_shrinks_late_updates():
    """With decay on, per-transition log-scale moves must shrink as t grows
    (Robbins–Monro: the t-th gain is proposal_gain * (1+t)^-decay)."""
    sched = ScheduleConfig(adapt_proposal=True, proposal_gain=0.5,
                           adapt_gain_decay=1.0, scale_max=1e6)
    cfg = CFG
    n = 1000
    buckets = sched.buckets_for(cfg, n)
    floor = sched.epsilon_floor(cfg)
    info = _info(accepted=True)  # constant acceptance pressure upward
    st = controller_init(sched, cfg, n)
    moves = []
    for _ in range(30):
        prev = float(st.sigma_scale)
        st = controller_update(st, info, sched, buckets, n, floor)
        moves.append(abs(np.log(float(st.sigma_scale)) - np.log(prev)))
    # early moves strictly dominate late moves once the acceptance EMA has
    # saturated (first few steps mix EMA warm-up with the decay)
    assert np.mean(moves[5:10]) > np.mean(moves[25:30]) > 0.0
    # and the t-th gain itself matches the Robbins–Monro schedule
    sched_fast = ScheduleConfig(adapt_proposal=True, proposal_gain=0.5,
                                adapt_gain_decay=0.0, scale_max=1e6)
    st_const, _ = _drive(sched_fast, info, 30)
    assert float(st.sigma_scale) < float(st_const.sigma_scale)


def test_adapt_gain_decay_run_stops_adapting(gaussian_target_factory):
    """Flag-on end-to-end: with decay=1 the sigma_scale trajectory converges
    (late-window drift well below early-window drift)."""
    target, pm, _ = gaussian_target_factory(n=600, seed=1)
    # scale_max far above where the run lands: the clamp must not mask the
    # decay (a clamped scale has zero drift whatever the gain does)
    sched = ScheduleConfig(adapt_proposal=True, proposal_gain=0.5,
                           adapt_gain_decay=1.0, scale_max=50.0)
    ens = ChainEnsemble(target, RandomWalk(1e-3), 2, config=CFG,
                        stepping="masked", schedule=sched)
    state = ens.init(jnp.zeros(()) + pm)
    scales = []
    key = jax.random.key(13)
    for i in range(6):
        key, sub = jax.random.split(key)
        state, _, _ = ens.run(sub, state, 30)
        scales.append(np.asarray(state.controller.sigma_scale).copy())
    early_drift = np.abs(np.log(scales[1]) - np.log(scales[0])).max()
    late_drift = np.abs(np.log(scales[-1]) - np.log(scales[-2])).max()
    assert late_drift < early_drift


def test_adapt_gain_decay_validation():
    with pytest.raises(ValueError, match="adapt_gain_decay"):
        ScheduleConfig(adapt_gain_decay=1.5)
    with pytest.raises(ValueError, match="adapt_gain_decay"):
        ScheduleConfig(adapt_gain_decay=-0.1)
