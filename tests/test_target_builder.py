"""Kernel-family registry / target builder and the composite cycle engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChainEnsemble,
    RandomWalk,
    ScheduleConfig,
    SubsampledMHConfig,
    SubsampledMHOp,
    SweepOp,
    build_target,
    cycle,
    get_family,
    registered_families,
    run_cycle_sequential,
)

CFG = SubsampledMHConfig(batch_size=50, epsilon=0.05)


def _logit_data(n=300, d=3, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(k1, (n, d))
    y = jnp.where(jax.random.bernoulli(k2, 0.5, (n,)), 1.0, -1.0)
    return x, y


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_builtin_families_registered():
    assert set(registered_families()) >= {"logit", "gaussian_ar1", "ce"}
    assert get_family("logit").name == "logit"
    with pytest.raises(KeyError):
        get_family("nope")


def test_build_target_validation():
    x, y = _logit_data()
    with pytest.raises(ValueError):
        build_target("logit", (x, y), None, prior_logpdf=lambda w: 0.0)
    with pytest.raises(ValueError):
        build_target("logit", (x, y), 300)  # no prior_logpdf / log_global
    with pytest.raises(ValueError):
        build_target(None, num_sections=300, log_global=lambda a, b: 0.0)
    with pytest.raises(KeyError):
        build_target("nope", (x, y), 300, prior_logpdf=lambda w: 0.0)


# ---------------------------------------------------------------------------
# logit family
# ---------------------------------------------------------------------------


def test_logit_family_matches_hand_target():
    x, y = _logit_data()
    prior_var = 0.1
    t = build_target("logit", (x, y), x.shape[0],
                     prior_logpdf=lambda w: (-0.5 / prior_var) * jnp.sum(w**2))
    assert t.family == "logit" and t.num_sections == 300
    w0, w1 = jnp.zeros(3), jnp.asarray([0.4, -0.2, 0.1])
    idx = jnp.arange(60, dtype=jnp.int32)
    hand_local = (-jnp.logaddexp(0, -y[idx] * (x[idx] @ w1))
                  + jnp.logaddexp(0, -y[idx] * (x[idx] @ w0)))
    np.testing.assert_allclose(np.asarray(t.log_local(w0, w1, idx)),
                               np.asarray(hand_local), rtol=1e-5, atol=1e-6)
    hand_global = (-0.5 / prior_var) * (jnp.sum(w1**2) - jnp.sum(w0**2))
    np.testing.assert_allclose(float(t.log_global(w0, w1)), float(hand_global), rtol=1e-5)
    hand_density = ((-0.5 / prior_var) * jnp.sum(w1**2)
                    - jnp.logaddexp(0, -y * (x @ w1)).sum())
    np.testing.assert_allclose(float(t.log_density(w1)), float(hand_density), rtol=1e-5)


def test_logit_family_ensemble_matches_vmapped_local_bit_for_bit():
    """Acceptance criterion: the fused-path (ref dispatch on CPU) ensemble
    round equals the vmapped unfused evaluation bit for bit."""
    x, y = _logit_data()
    t = build_target("logit", (x, y), x.shape[0], prior_logpdf=lambda w: 0.0)
    K, m = 5, 32
    ks = jax.random.split(jax.random.key(1), 3)
    wc = jax.random.normal(ks[0], (K, 3))
    wp = jax.random.normal(ks[1], (K, 3))
    idx = jax.random.randint(ks[2], (K, m), 0, 300)
    vmapped = jax.jit(lambda a, b, i: jax.vmap(t.log_local)(a, b, i))(wc, wp, idx)
    fused = jax.jit(t.log_local_ensemble)(wc, wp, idx)
    np.testing.assert_array_equal(np.asarray(vmapped), np.asarray(fused))


# ---------------------------------------------------------------------------
# gaussian_ar1 family
# ---------------------------------------------------------------------------


def test_gaussian_ar1_family_matches_transition_logpdf_delta():
    from repro.experiments.stochvol import _trans_logpdf

    n = 200
    k1, k2 = jax.random.split(jax.random.key(3))
    xt = jax.random.normal(k1, (n,))
    xp = jax.random.normal(k2, (n,))
    t = build_target(
        "gaussian_ar1", (xt, xp), n,
        prior_logpdf=lambda th: jnp.zeros(()),
        params_fn=lambda th: (th["phi"], th["sigma2"]),
    )
    th0 = {"phi": jnp.asarray(0.9), "sigma2": jnp.asarray(0.05)}
    th1 = {"phi": jnp.asarray(0.8), "sigma2": jnp.asarray(0.07)}
    idx = jnp.arange(80, dtype=jnp.int32)
    want = (_trans_logpdf(xt[idx], xp[idx], th1["phi"], th1["sigma2"])
            - _trans_logpdf(xt[idx], xp[idx], th0["phi"], th0["sigma2"]))
    np.testing.assert_allclose(np.asarray(t.log_local(th0, th1, idx)),
                               np.asarray(want), rtol=1e-4, atol=1e-5)


def test_gaussian_ar1_latent_dependent_data_fn():
    """Callable data: sections derived from theta (the stochvol ensemble
    form) must agree with the closure-based target on the same h."""
    from repro.experiments import stochvol

    data = stochvol.synth(jax.random.key(4), num_series=20, length=5)
    closure = stochvol.make_param_target(data.h_true, "phi")
    joint = stochvol.make_joint_param_target(20, 5)
    th0 = {"phi": jnp.asarray(0.9), "sigma2": jnp.asarray(0.02), "h": data.h_true}
    th1 = {"phi": jnp.asarray(0.85), "sigma2": jnp.asarray(0.03), "h": data.h_true}
    idx = jnp.arange(100, dtype=jnp.int32)
    np.testing.assert_allclose(
        np.asarray(joint.log_local(th0, th1, idx)),
        np.asarray(closure.log_local(th0, th1, idx)), rtol=1e-6, atol=1e-7,
    )
    # ensemble form: a (K, 2) chain axis over theta, per-chain h
    K = 2
    b = lambda v: jnp.broadcast_to(jnp.asarray(v)[None], (K,) + jnp.shape(jnp.asarray(v)))
    thb0 = {k: b(v) for k, v in th0.items()}
    thb1 = {k: b(v) for k, v in th1.items()}
    idxb = jnp.stack([idx, idx + 1])
    fused = joint.log_local_ensemble(thb0, thb1, idxb)
    for c in range(K):
        np.testing.assert_allclose(
            np.asarray(fused[c]),
            np.asarray(joint.log_local(th0, th1, idxb[c])), rtol=1e-5, atol=1e-6,
        )


# ---------------------------------------------------------------------------
# ce family
# ---------------------------------------------------------------------------


def test_ce_family_delta_and_ensemble():
    from repro.kernels.ref import fused_ce_ref

    n, d, v = 60, 8, 30
    ks = jax.random.split(jax.random.key(5), 4)
    h = 0.3 * jax.random.normal(ks[0], (n, d))
    targets = jax.random.randint(ks[1], (n,), 0, v)
    t = build_target("ce", (h, targets), n, prior_logpdf=lambda tab: jnp.zeros(()))
    tab0 = 0.3 * jax.random.normal(ks[2], (v, d))
    tab1 = 0.3 * jax.random.normal(ks[3], (v, d))
    idx = jnp.arange(40, dtype=jnp.int32)
    want = (fused_ce_ref(h[idx], tab1, targets[idx])
            - fused_ce_ref(h[idx], tab0, targets[idx]))
    np.testing.assert_allclose(np.asarray(t.log_local(tab0, tab1, idx)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)
    K, m = 3, 16
    idxb = jax.random.randint(jax.random.key(6), (K, m), 0, n)
    tabs0 = jnp.stack([tab0] * K)
    tabs1 = jnp.stack([tab1] * K)
    fused = t.log_local_ensemble(tabs0, tabs1, idxb)
    vmapped = jax.vmap(t.log_local, in_axes=(0, 0, 0))(tabs0, tabs1, idxb)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(vmapped),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Composite cycle engine
# ---------------------------------------------------------------------------


def test_cycle_validation():
    x, y = _logit_data()
    t = build_target("logit", (x, y), 300, prior_logpdf=lambda w: 0.0)
    with pytest.raises(ValueError):
        cycle([])
    with pytest.raises(TypeError):
        cycle([lambda k, th: th])
    with pytest.raises(ValueError):
        cycle([SubsampledMHOp(t, RandomWalk(0.1), name="a"),
               SweepOp(lambda k, th: th, name="a")])


def test_ensemble_composite_validation(gaussian_target_factory):
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    cyc = cycle([SubsampledMHOp(target, RandomWalk(0.05), CFG)])
    with pytest.raises(ValueError):
        ChainEnsemble(target, RandomWalk(0.05), 2, transition=cyc)
    with pytest.raises(ValueError):
        ChainEnsemble(num_chains=2, transition=cyc, stepping="masked")
    with pytest.raises(ValueError):
        ChainEnsemble(num_chains=2, transition=cyc, schedule=ScheduleConfig())
    with pytest.raises(ValueError):
        ChainEnsemble(num_chains=2, transition=cyc, shard=True)
    with pytest.raises(ValueError):
        ChainEnsemble(num_chains=2)  # neither target nor transition
    with pytest.raises(ValueError):
        # forcing the fused route on a composite whose MH target has no
        # ensemble evaluation must fail loudly, not silently run unfused
        ChainEnsemble(num_chains=2, transition=cyc, fused_kernels="always")
    with pytest.raises(ValueError):
        # kernel/config are per-component knobs in a composite; the
        # ensemble-level ones would be silently ignored
        ChainEnsemble(num_chains=2, transition=cyc, kernel="exact")
    with pytest.raises(ValueError):
        ChainEnsemble(num_chains=2, transition=cyc, config=CFG)
    x, y = _logit_data()
    fam_t = build_target("logit", (x, y), 300, prior_logpdf=lambda w: 0.0)
    with pytest.raises(ValueError):
        # shard=True demands the sharded vmapped scan; "always" demands the
        # unsharded fused scan — contradictory, rejected at construction
        ChainEnsemble(fam_t, RandomWalk(0.1), 2, fused_kernels="always", shard=True)


def test_cycle_of_one_kernel_equals_bare_kernel(gaussian_target_factory):
    """Determinism: cycle([op]) == the bare kernel ensemble, bit for bit."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)
    K, T = 3, 60
    keys = jax.random.split(jax.random.key(7), K)
    bare = ChainEnsemble(target, RandomWalk(0.05), K, config=CFG)
    comp = ChainEnsemble(num_chains=K, transition=cycle(
        [SubsampledMHOp(target, RandomWalk(0.05), CFG, name="theta")]))
    _, s_b, i_b = bare.run(keys, bare.init(jnp.zeros(())), T)
    _, s_c, i_c = comp.run(keys, comp.init(jnp.zeros(())), T)
    np.testing.assert_array_equal(np.asarray(s_b), np.asarray(s_c))
    for field in ("accepted", "n_evaluated", "rounds", "mu_hat", "mu0", "log_u"):
        np.testing.assert_array_equal(
            np.asarray(getattr(i_b, field)),
            np.asarray(getattr(i_c["theta"], field)), err_msg=field)


def test_composite_k1_matches_sequential_cycle(gaussian_target_factory):
    """A K=1 composite ensemble (MH op + opaque sweep with info) reproduces
    run_cycle_sequential bit for bit."""
    target, _, _ = gaussian_target_factory(n=600, seed=1)

    def sweep(key, th):
        return th + 0.01 * jax.random.normal(key, ()), {"noise": th}

    cyc = cycle([SubsampledMHOp(target, RandomWalk(0.05), CFG, name="mh"),
                 SweepOp(sweep, name="jitter", has_info=True)])
    ens = ChainEnsemble(num_chains=1, transition=cyc)
    keys = jax.random.split(jax.random.key(11), 1)
    _, s_e, i_e = ens.run(keys, ens.init(jnp.zeros(())), 40)
    _, s_q, i_q = run_cycle_sequential(keys[0], jnp.zeros(()), cyc, 40)
    np.testing.assert_array_equal(np.asarray(s_e[0]), np.asarray(s_q))
    np.testing.assert_array_equal(np.asarray(i_e["mh"].accepted[0]),
                                  np.asarray(i_q["mh"].accepted))
    np.testing.assert_array_equal(np.asarray(i_e["jitter"]["noise"][0]),
                                  np.asarray(i_q["jitter"]["noise"]))


# ---------------------------------------------------------------------------
# Fused lock-step scan
# ---------------------------------------------------------------------------


def test_lockstep_fused_path_matches_vmap():
    """Acceptance criterion: the lock-step scan routes rounds through
    log_local_ensemble when dispatch selects the fused path, in parity with
    the unfused scan."""
    x, y = _logit_data(n=800, d=2, seed=9)
    t = build_target("logit", (x, y), 800,
                     prior_logpdf=lambda w: -5.0 * jnp.sum(w**2))
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05, sampler="stream")
    K, T = 3, 40
    keys = jax.random.split(jax.random.key(5), K)
    plain = ChainEnsemble(t, RandomWalk(0.1), K, config=cfg, fused_kernels="never")
    fused = ChainEnsemble(t, RandomWalk(0.1), K, config=cfg, fused_kernels="always")
    _, s_p, i_p = plain.run(keys, plain.init(jnp.zeros(2)), T)
    _, s_f, i_f = fused.run(keys, fused.init(jnp.zeros(2)), T)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_f), rtol=2e-4, atol=2e-5)
    assert (np.asarray(i_p.accepted) == np.asarray(i_f.accepted)).mean() > 0.95


def test_lockstep_fused_with_schedule_stays_in_bounds():
    """The fused lock-step scan composes with the adaptive controller."""
    x, y = _logit_data(n=600, d=2, seed=13)
    t = build_target("logit", (x, y), 600,
                     prior_logpdf=lambda w: -5.0 * jnp.sum(w**2))
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05, sampler="stream")
    sched = ScheduleConfig(epsilon_max=0.2)
    ens = ChainEnsemble(t, RandomWalk(0.1), 3, config=cfg,
                        fused_kernels="always", schedule=sched)
    state, samples, infos = ens.run(jax.random.key(0), ens.init(jnp.zeros(2)), 50)
    eps = np.asarray(infos.epsilon)
    assert samples.shape == (3, 50, 2)
    assert eps.min() >= cfg.epsilon - 1e-7 and eps.max() <= 0.2 + 1e-7
    assert set(np.unique(np.asarray(infos.batch_eff)).tolist()) <= set(
        sched.buckets_for(cfg, 600))
    assert np.asarray(state.controller.t).tolist() == [50, 50, 50]