"""Paper experiment validation: BayesLR, JointDPM, stochastic volatility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RandomWalk,
    SubsampledMHConfig,
    make_sampler,
    run_chain,
    subsampled_mh_step,
)
from repro.experiments import bayeslr, jointdpm, stochvol
from repro.inference import NIWPrior, csmc, particle_filter, posterior_predictive_logpdf


# ---------------------------------------------------------------------------
# Bayesian logistic regression
# ---------------------------------------------------------------------------


def test_bayeslr_subsampled_recovers_weights():
    data = bayeslr.synth_2d(jax.random.key(0), n=1500)
    target = bayeslr.make_target(data.x_train, data.y_train)
    _, samples, infos = run_chain(
        jax.random.key(1), jnp.zeros(2), target, RandomWalk(0.08), 600,
        kernel="subsampled", config=SubsampledMHConfig(batch_size=200, epsilon=0.05),
    )
    w = np.asarray(samples)[200:].mean(0)
    # direction of the true weight vector is recovered
    cos = w @ np.asarray(data.w_true) / (np.linalg.norm(w) * np.linalg.norm(data.w_true))
    assert cos > 0.95
    assert np.mean(np.asarray(infos.n_evaluated)) < 1500


def test_bayeslr_exact_and_subsampled_agree_on_posterior():
    data = bayeslr.synth_2d(jax.random.key(2), n=1000)
    target = bayeslr.make_target(data.x_train, data.y_train)
    _, s_ex, _ = run_chain(jax.random.key(3), jnp.zeros(2), target, RandomWalk(0.1), 800, kernel="exact")
    _, s_sub, _ = run_chain(
        jax.random.key(3), jnp.zeros(2), target, RandomWalk(0.1), 800,
        kernel="subsampled", config=SubsampledMHConfig(batch_size=200, epsilon=0.01),
    )
    m_ex = np.asarray(s_ex)[300:].mean(0)
    m_sub = np.asarray(s_sub)[300:].mean(0)
    assert np.linalg.norm(m_ex - m_sub) < 0.25 * max(np.linalg.norm(m_ex), 1e-6) + 0.1


def test_bayeslr_mala_proposal_runs():
    data = bayeslr.synth_2d(jax.random.key(4), n=500)
    target = bayeslr.make_target(data.x_train, data.y_train)
    from repro.core import MALA

    grad_fn = bayeslr.make_grad_fn(data.x_train, data.y_train, subsample=100)
    _, samples, infos = run_chain(
        jax.random.key(5), jnp.zeros(2), target, MALA(step=1e-4, grad_fn=grad_fn), 100,
        kernel="subsampled", config=SubsampledMHConfig(batch_size=100, epsilon=0.05),
    )
    assert np.isfinite(np.asarray(samples)).all()


# ---------------------------------------------------------------------------
# NIW collapsed component
# ---------------------------------------------------------------------------


def test_niw_predictive_matches_monte_carlo():
    """Empty-cluster predictive == prior predictive; checked against MC."""
    d = 2
    prior = NIWPrior(m0=jnp.zeros(d), k0=2.0, v0=6.0, s0=2.0 * jnp.eye(d))
    x = jnp.asarray([0.3, -0.4])
    lp = float(
        posterior_predictive_logpdf(x, jnp.asarray(0.0), jnp.zeros(d), jnp.zeros((d, d)), prior)
    )
    # Monte-Carlo prior predictive
    rng = np.random.default_rng(0)
    m = 24_000
    # draw Sigma ~ IW(v0, S0) via inverse of Wishart(v0, S0^{-1}), mu ~ N(m0, Sigma/k0)
    s0inv = np.linalg.inv(np.asarray(prior.s0))
    chol = np.linalg.cholesky(s0inv)
    dens = []
    for _ in range(m // 200):
        a = rng.standard_normal((200, int(prior.v0), d)) @ chol.T
        wish = np.einsum("mij,mik->mjk", a, a)
        sigma = np.linalg.inv(wish)
        mu = np.asarray(prior.m0) + np.einsum(
            "mjk,mk->mj", np.linalg.cholesky(sigma / prior.k0), rng.standard_normal((200, d))
        )
        diff = np.asarray(x) - mu
        prec = np.linalg.inv(sigma)
        quad = np.einsum("mi,mij,mj->m", diff, prec, diff)
        logdet = np.linalg.slogdet(sigma)[1]
        dens.append(np.exp(-0.5 * (quad + logdet + d * np.log(2 * np.pi))))
    mc = np.log(np.mean(np.concatenate(dens)))
    np.testing.assert_allclose(lp, mc, atol=0.1)


def test_niw_stats_add_remove_roundtrip():
    from repro.inference import ClusterStats

    stats = ClusterStats.empty(4, 2)
    xs = [jnp.asarray([1.0, 2.0]), jnp.asarray([-0.5, 0.3])]
    for x in xs:
        stats = stats.add(1, x)
    for x in xs:
        stats = stats.remove(1, x)
    assert float(jnp.abs(stats.n).max()) == 0.0
    assert float(jnp.abs(stats.sum_x).max()) < 1e-6
    assert float(jnp.abs(stats.sum_xxt).max()) < 1e-6


# ---------------------------------------------------------------------------
# JointDPM
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def jdpm_setup():
    cfg = jointdpm.JDPMConfig()
    data = jointdpm.synth(jax.random.key(0), n=600, n_test=200)
    state = jointdpm.init_state(jax.random.key(1), data, cfg)
    return cfg, data, state


def test_jdpm_gibbs_preserves_counts(jdpm_setup):
    cfg, data, state = jdpm_setup
    n = data.x.shape[0]
    pts = jax.random.permutation(jax.random.key(2), n)[:300]
    new = jointdpm.gibbs_z_steps(jax.random.key(3), state, data, cfg, pts)
    assert float(new.stats.n.sum()) == n
    # stats consistent with z
    for k in range(cfg.k_max):
        assert int((np.asarray(new.z) == k).sum()) == int(new.stats.n[k])


def test_jdpm_subsampled_w_move_uses_dynamic_pool(jdpm_setup):
    cfg, data, state = jdpm_setup
    state2, info = jointdpm.subsampled_mh_w(
        jax.random.key(4), state, data, cfg, batch_size=50, epsilon=0.1
    )
    assert int(info.n_evaluated) <= int(info.n_k)
    assert state2.w.shape == state.w.shape


@pytest.mark.slow
def test_jdpm_short_run_improves_accuracy(jdpm_setup):
    cfg, data, state = jdpm_setup
    gz = jax.jit(lambda k, s, p: jointdpm.gibbs_z_steps(k, s, data, cfg, p))
    mw = jax.jit(
        lambda k, s: jointdpm.subsampled_mh_w(
            k, s, data, cfg, batch_size=50, epsilon=0.1, sigma_prop=0.3
        )
    )
    prob0 = jointdpm.predict_proba(state, data.x_test, cfg)
    acc0 = jointdpm.accuracy(np.asarray(prob0), np.asarray(data.y_test))
    n = data.x.shape[0]
    for it in range(20):
        kk = jax.random.key(100 + it)
        pts = jax.random.permutation(kk, n)[: n // 2]
        state = gz(kk, state, pts)
        state = jointdpm.mh_alpha(jax.random.key(200 + it), state, cfg)
        for j in range(10):
            state, _ = mw(jax.random.key(300 + 31 * it + j), state)
    prob = jointdpm.predict_proba(state, data.x_test, cfg)
    acc = jointdpm.accuracy(np.asarray(prob), np.asarray(data.y_test))
    assert acc > max(acc0 + 0.05, 0.58), f"accuracy did not improve: {acc0} -> {acc}"


# ---------------------------------------------------------------------------
# Stochastic volatility + particle Gibbs
# ---------------------------------------------------------------------------


def test_csmc_tracks_latent_path():
    data = stochvol.synth(jax.random.key(0), num_series=30, length=5)
    params = stochvol.SVParams(jnp.asarray(0.95), jnp.asarray(0.01))
    h = jnp.zeros_like(data.obs)
    sweep = jax.jit(lambda k, h: stochvol.pgibbs_sweep(k, data.obs, h, params, num_particles=40))
    for i in range(10):
        h = sweep(jax.random.key(i), h)
    # sampled paths should correlate with the truth in aggregate scale
    assert np.isfinite(np.asarray(h)).all()
    assert float(jnp.abs(h).mean()) < 5.0


def test_sv_param_target_sections_are_transitions():
    data = stochvol.synth(jax.random.key(1), num_series=20, length=5)
    target = stochvol.make_param_target(data.h_true, "phi")
    assert target.num_sections == 20 * 5
    theta = {"phi": jnp.asarray(0.9), "sigma2": jnp.asarray(0.01)}
    theta_p = {"phi": jnp.asarray(0.8), "sigma2": jnp.asarray(0.01)}
    l = target.log_local(theta, theta_p, jnp.arange(100, dtype=jnp.int32))
    assert l.shape == (100,)
    assert np.isfinite(np.asarray(l)).all()


def test_sv_invalid_proposals_are_rejected():
    data = stochvol.synth(jax.random.key(2), num_series=10, length=5)
    target = stochvol.make_param_target(data.h_true, "phi")
    theta = {"phi": jnp.asarray(0.9), "sigma2": jnp.asarray(0.01)}
    theta_bad = {"phi": jnp.asarray(1.7), "sigma2": jnp.asarray(0.01)}
    g = float(target.log_global(theta, theta_bad))
    assert g == -np.inf  # prior excludes phi > 1 => reject


@pytest.mark.slow
def test_sv_subsampled_mh_recovers_parameters_given_states():
    """Sec 4.3 parameter move validation with h fixed at the true paths:
    the subsampled MH chain over (phi, sigma2) must land near the
    generating parameters (the pgibbs+MH joint loop is exercised separately
    and in benchmarks, where it gets the iterations it needs to mix)."""
    data = stochvol.synth(jax.random.key(3), num_series=150, length=5, phi=0.95, sigma=0.1)
    target = stochvol.make_param_target(data.h_true, "phi")
    cfg = SubsampledMHConfig(batch_size=100, epsilon=0.05)
    s0, reset, draw = make_sampler("fy", target.num_sections)
    phi_step = jax.jit(
        lambda k, th, ss: subsampled_mh_step(
            k, th, ss, target, stochvol.SingleLeafRW("phi", 0.05), cfg, reset, draw
        )
    )
    sig_step = jax.jit(
        lambda k, th, ss: subsampled_mh_step(
            k, th, ss, target, stochvol.SingleLeafRW("sigma2", 0.004), cfg, reset, draw
        )
    )
    theta = {"phi": jnp.asarray(0.8), "sigma2": jnp.asarray(0.02)}
    key = jax.random.key(4)
    phis, sig2s = [], []
    for _ in range(400):
        key, k1, k2 = jax.random.split(key, 3)
        theta, _, _ = phi_step(k1, theta, s0)
        theta, _, _ = sig_step(k2, theta, s0)
        phis.append(float(theta["phi"]))
        sig2s.append(float(theta["sigma2"]))
    phi_hat = np.mean(phis[100:])
    sig_hat = np.sqrt(np.mean(sig2s[100:]))
    assert 0.8 < phi_hat <= 1.0, phi_hat
    assert 0.06 < sig_hat < 0.16, sig_hat


def test_sv_ensemble_k1_matches_sequential_bit_for_bit():
    """Acceptance criterion: the stochvol ensemble driver at K=1 (adaptation
    off) reproduces its sequential single-chain run exactly — particle Gibbs
    sweep, phi move, sigma2 move, every transition."""
    data = stochvol.synth(jax.random.key(7), num_series=30, length=5)
    kw = dict(batch_size=50, epsilon=0.05, num_particles=12)
    keys = jax.random.split(jax.random.key(8), 1)
    _, samples, infos, _ = stochvol.run_posterior_ensemble(
        keys, data, num_chains=1, num_steps=25, **kw)
    _, s_seq, i_seq = stochvol.run_posterior_sequential(keys[0], data, 25, **kw)
    for leaf in ("phi", "sigma2"):
        np.testing.assert_array_equal(np.asarray(samples[leaf][0]), np.asarray(s_seq[leaf]))
    for name in ("phi", "sigma2"):
        for f in ("accepted", "n_evaluated", "rounds", "mu_hat", "mu0", "log_u"):
            np.testing.assert_array_equal(
                np.asarray(getattr(infos[name], f)[0]),
                np.asarray(getattr(i_seq[name], f)), err_msg=f"{name}.{f}")


def test_sv_ensemble_chains_distinct_and_fused_parity():
    """K>1 stochvol chains differ per key; forcing the fused gaussian_ar1
    route agrees with the unfused composite engine."""
    data = stochvol.synth(jax.random.key(9), num_series=25, length=4)
    kw = dict(batch_size=40, epsilon=0.05, num_particles=10)
    keys = jax.random.split(jax.random.key(10), 3)
    _, s_n, i_n, _ = stochvol.run_posterior_ensemble(
        keys, data, num_chains=3, num_steps=15, fused_kernels="never", **kw)
    _, s_f, i_f, _ = stochvol.run_posterior_ensemble(
        keys, data, num_chains=3, num_steps=15, fused_kernels="always", **kw)
    phi = np.asarray(s_n["phi"])
    assert not np.array_equal(phi[0], phi[1])
    np.testing.assert_allclose(phi, np.asarray(s_f["phi"]), rtol=1e-4, atol=1e-5)
    agree = (np.asarray(i_n["phi"].accepted) == np.asarray(i_f["phi"].accepted)).mean()
    assert agree > 0.9


def test_jdpm_ensemble_k1_matches_sequential_bit_for_bit(jdpm_setup):
    """Acceptance criterion: the jointdpm replica driver at K=1 reproduces
    the sequential cycle (alpha MH, Gibbs z, w-moves) exactly."""
    cfg, data, state = jdpm_setup
    kw = dict(batch_size=50, epsilon=0.1, w_moves=4, gibbs_frac=0.25)
    keys = jax.random.split(jax.random.key(21), 1)
    _, samples, infos, _ = jointdpm.run_posterior_ensemble(
        keys, data, cfg, num_chains=1, num_cycles=5, state0=state, **kw)
    _, s_seq, i_seq = jointdpm.run_posterior_sequential(
        keys[0], data, cfg, 5, state0=state, **kw)
    for leaf in ("alpha", "k_active", "w"):
        np.testing.assert_array_equal(np.asarray(samples[leaf][0]), np.asarray(s_seq[leaf]))
    for f in ("cluster", "accepted", "n_evaluated", "n_k", "rounds"):
        np.testing.assert_array_equal(
            np.asarray(getattr(infos["w"], f)[0]),
            np.asarray(getattr(i_seq["w"], f)), err_msg=f"w.{f}")


def test_jdpm_ensemble_replicas_distinct(jdpm_setup):
    cfg, data, state = jdpm_setup
    keys = jax.random.split(jax.random.key(22), 2)
    # per-chain key array with the default state0 (seeded from keys[0])
    _, samples, _, diag = jointdpm.run_posterior_ensemble(
        keys, data, cfg, num_chains=2, num_cycles=4,
        batch_size=50, w_moves=3, gibbs_frac=0.25)
    assert samples["alpha"].shape == (2, 4)
    assert not np.array_equal(np.asarray(samples["w"][0]), np.asarray(samples["w"][1]))
    assert diag["w_accept_rate"].shape == (2,)
    assert 0.0 <= diag["w_frac_evaluated"] <= 1.0


@pytest.mark.slow
def test_sv_joint_pgibbs_mh_loop_runs():
    """Short joint loop (states + parameters) stays finite and in-support."""
    data = stochvol.synth(jax.random.key(5), num_series=40, length=5)
    theta = {"phi": jnp.asarray(0.7), "sigma2": jnp.asarray(0.02)}
    h = jnp.zeros_like(data.obs)
    cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
    pg = jax.jit(
        lambda k, h, t: stochvol.pgibbs_sweep(
            k, data.obs, h, stochvol.SVParams(t["phi"], t["sigma2"]), 20
        )
    )
    key = jax.random.key(6)
    for _ in range(10):
        key, k1, k2, k3 = jax.random.split(key, 4)
        h = pg(k1, h, theta)
        target = stochvol.make_param_target(h, "phi")
        s0, reset, draw = make_sampler("fy", target.num_sections)
        theta, _, _ = subsampled_mh_step(
            k2, theta, s0, target, stochvol.SingleLeafRW("phi", 0.05), cfg, reset, draw
        )
        theta, _, _ = subsampled_mh_step(
            k3, theta, s0, target, stochvol.SingleLeafRW("sigma2", 0.005), cfg, reset, draw
        )
    assert np.isfinite(np.asarray(h)).all()
    assert 0.0 < float(theta["phi"]) < 1.0
    assert float(theta["sigma2"]) > 0.0
