"""A miniature inference programming language (the paper's `[infer ...]`).

Inference programs are composable transition kernels over a shared state
dict. This mirrors the paper's Venture inference expressions, e.g.

    [infer (cycle ((mh alpha all 1)
                   (gibbs z one step_z)
                   (subsampled_mh w one {Nbatch} {eps} 'drift {sigma} 1)) 1)]

becomes

    Cycle([MHKernel("alpha", ...),
           GibbsKernel("z", sweeps=step_z),
           SubsampledMHKernel("w", batch=Nbatch, eps=eps,
                              proposal=RandomWalk(sigma))])

Kernels are callables ``(key, state) -> state`` where ``state`` is a dict of
named values (latents, sufficient statistics, sampler state, diagnostics).
They may be arbitrary Python driving jitted inner steps, so host-side
structure moves (CRP cluster bookkeeping) coexist with fully-jitted MH.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax

State = dict[str, Any]
Kernel = Callable[[jax.Array, State], State]


@dataclasses.dataclass
class Cycle:
    """Apply each kernel once, in order, ``repeats`` times per call."""

    kernels: Sequence[Kernel]
    repeats: int = 1

    def __call__(self, key: jax.Array, state: State) -> State:
        for _ in range(self.repeats):
            for k in self.kernels:
                key, sub = jax.random.split(key)
                state = k(sub, state)
        return state


@dataclasses.dataclass
class Repeat:
    kernel: Kernel
    times: int

    def __call__(self, key: jax.Array, state: State) -> State:
        for _ in range(self.times):
            key, sub = jax.random.split(key)
            state = self.kernel(sub, state)
        return state


@dataclasses.dataclass
class Mixture:
    """Randomly pick one kernel per call (optionally weighted)."""

    kernels: Sequence[Kernel]
    weights: Sequence[float] | None = None

    def __call__(self, key: jax.Array, state: State) -> State:
        import numpy as np

        key, pick, sub = jax.random.split(key, 3)
        w = None
        if self.weights is not None:
            w = np.asarray(self.weights, float)
            w = w / w.sum()
        i = int(np.random.default_rng(int(jax.random.randint(pick, (), 0, 2**31 - 1))).choice(
            len(self.kernels), p=w))
        return self.kernels[i](sub, state)


def run_inference(
    key: jax.Array,
    state: State,
    program: Kernel,
    num_iterations: int,
    callback: Callable[[int, State], None] | None = None,
) -> State:
    """Drive an inference program; the paper's outer `[infer ... 1]` loop."""
    for it in range(num_iterations):
        key, sub = jax.random.split(key)
        state = program(sub, state)
        if callback is not None:
            callback(it, state)
    return state
