"""Collapsed Normal-inverse-Wishart component model.

The JointDPM experiment collapses each Gaussian component's (mu_k, Sigma_k)
under a conjugate NIW prior; cluster membership moves only need the posterior
predictive density — a multivariate Student-t — computed from O(1)-updatable
sufficient statistics (the PET property the paper leans on for constant-time
z transitions, Sec. 4.2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_LOG_PI = 1.1447298858494002


class NIWPrior(NamedTuple):
    m0: jax.Array  # (D,)
    k0: float
    v0: float
    s0: jax.Array  # (D, D) prior scatter


class ClusterStats(NamedTuple):
    """Sufficient statistics per cluster, shape-stable for K_max clusters."""

    n: jax.Array  # (K,)
    sum_x: jax.Array  # (K, D)
    sum_xxt: jax.Array  # (K, D, D)

    @staticmethod
    def empty(k_max: int, d: int) -> "ClusterStats":
        return ClusterStats(
            jnp.zeros((k_max,), jnp.float32),
            jnp.zeros((k_max, d), jnp.float32),
            jnp.zeros((k_max, d, d), jnp.float32),
        )

    def add(self, k: jax.Array, x: jax.Array) -> "ClusterStats":
        return ClusterStats(
            self.n.at[k].add(1.0),
            self.sum_x.at[k].add(x),
            self.sum_xxt.at[k].add(jnp.outer(x, x)),
        )

    def remove(self, k: jax.Array, x: jax.Array) -> "ClusterStats":
        return ClusterStats(
            self.n.at[k].add(-1.0),
            self.sum_x.at[k].add(-x),
            self.sum_xxt.at[k].add(-jnp.outer(x, x)),
        )


def _mvt_logpdf(x: jax.Array, df: jax.Array, loc: jax.Array, scale: jax.Array) -> jax.Array:
    """Multivariate Student-t log density; scale is the (D,D) shape matrix."""
    d = x.shape[-1]
    chol = jnp.linalg.cholesky(scale)
    diff = jax.scipy.linalg.solve_triangular(chol, x - loc, lower=True)
    quad = jnp.sum(diff * diff)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    return (
        jax.lax.lgamma((df + d) / 2.0)
        - jax.lax.lgamma(df / 2.0)
        - 0.5 * d * (jnp.log(df) + _LOG_PI)
        - 0.5 * logdet
        - 0.5 * (df + d) * jnp.log1p(quad / df)
    )


def posterior_predictive_logpdf(
    x: jax.Array, stats_n: jax.Array, stats_sum: jax.Array, stats_xxt: jax.Array, prior: NIWPrior
) -> jax.Array:
    """log p(x | cluster stats) under the collapsed NIW model (one cluster).

    Standard conjugate updates (Murphy 2007):
      kn = k0 + n, vn = v0 + n, mn = (k0 m0 + sum_x) / kn
      Sn = S0 + sum_xxt + k0 m0 m0' - kn mn mn'
      x | stats ~ t_{vn - D + 1}(mn, Sn (kn+1) / (kn (vn - D + 1)))
    """
    d = x.shape[-1]
    n = stats_n
    kn = prior.k0 + n
    vn = prior.v0 + n
    mn = (prior.k0 * prior.m0 + stats_sum) / kn
    sn = (
        prior.s0
        + stats_xxt
        + prior.k0 * jnp.outer(prior.m0, prior.m0)
        - kn * jnp.outer(mn, mn)
    )
    df = vn - d + 1.0
    scale = sn * (kn + 1.0) / (kn * df)
    # guard: keep scale SPD even for nearly-empty clusters
    scale = scale + 1e-6 * jnp.eye(d, dtype=scale.dtype)
    return _mvt_logpdf(x, df, mn, scale)


def predictive_all_clusters(
    x: jax.Array, stats: ClusterStats, prior: NIWPrior
) -> jax.Array:
    """Vectorized posterior predictive over all K_max clusters -> (K,)."""
    return jax.vmap(
        lambda n, s, ss: posterior_predictive_logpdf(x, n, s, ss, prior)
    )(stats.n, stats.sum_x, stats.sum_xxt)
