"""Sequential Monte Carlo: bootstrap particle filter + conditional SMC
(particle Gibbs) for state-space models.

Used by the stochastic-volatility experiment (paper Sec. 4.3): latent states
are sampled with particle Gibbs while parameters get (subsampled) MH moves —
the paper's `[infer (pgibbs h ...)]` line.

The model interface is a pair of callables:
  transition_sample(key, h_prev, t, params) -> h_t     (proposal = prior)
  obs_logpdf(x_t, h_t, t, params)           -> logp    (weights)
with h scalar per time step (vmap over batched series).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class SMCResult(NamedTuple):
    trajectory: jax.Array  # (T,) sampled path
    log_evidence: jax.Array  # scalar SMC marginal-likelihood estimate


def _systematic_resample(key: jax.Array, logw: jax.Array) -> jax.Array:
    """Systematic resampling; returns ancestor indices (P,)."""
    p = logw.shape[0]
    w = jax.nn.softmax(logw)
    cum = jnp.cumsum(w)
    u = (jax.random.uniform(key) + jnp.arange(p)) / p
    return jnp.searchsorted(cum, u).astype(jnp.int32)


def csmc(
    key: jax.Array,
    obs: jax.Array,  # (T,)
    ref_path: jax.Array,  # (T,) retained trajectory (particle Gibbs)
    params,
    transition_sample: Callable,
    obs_logpdf: Callable,
    num_particles: int,
    h0: float = 0.0,
) -> SMCResult:
    """One conditional-SMC sweep with the reference path retained at slot 0.

    Multinomial conditional resampling (slot 0's ancestor pinned to 0) keeps
    the invariance property of particle Gibbs (Andrieu et al. 2010).
    """
    t_len = obs.shape[0]
    p = num_particles

    def step(carry, inp):
        h_prev, key = carry
        t, x_t, h_ref_t = inp
        key, k_prop, k_res = jax.random.split(key, 3)
        prop_keys = jax.random.split(k_prop, p)
        h_t = jax.vmap(lambda k, hp: transition_sample(k, hp, t, params))(prop_keys, h_prev)
        h_t = h_t.at[0].set(h_ref_t)  # retained particle
        logw = jax.vmap(lambda h: obs_logpdf(x_t, h, t, params))(h_t)
        # conditional multinomial resampling for the NEXT step's ancestors
        anc = jax.random.categorical(k_res, logw, shape=(p,))
        anc = anc.at[0].set(0)
        h_next_prev = h_t[anc]
        log_z_t = jax.nn.logsumexp(logw) - jnp.log(p)
        return (h_next_prev, key), (h_t, anc, logw, log_z_t)

    h_init = jnp.full((p,), h0, obs.dtype)
    ts = jnp.arange(t_len)
    (_, key), (hs, ancs, logws, log_zs) = jax.lax.scan(
        step, (h_init, key), (ts, obs, ref_path)
    )

    # Sample one trajectory: pick final particle by weight, trace ancestry.
    key, k_pick = jax.random.split(key)
    b_last = jax.random.categorical(k_pick, logws[-1])

    def back(b, t):
        # ancestor array at time t maps slot->parent slot chosen for time t+1
        return ancs[t][b], hs[t][b]

    def back_step(b, t):
        h_t = hs[t][b]
        b_prev = jnp.where(t > 0, ancs[t - 1][b], 0)
        return b_prev, h_t

    # scan backwards over time
    def scan_back(carry, t):
        b = carry
        b_prev, h_t = back_step(b, t)
        return b_prev, h_t

    _, traj_rev = jax.lax.scan(scan_back, b_last, jnp.arange(t_len - 1, -1, -1))
    trajectory = traj_rev[::-1]
    return SMCResult(trajectory=trajectory, log_evidence=log_zs.sum())


def particle_filter(
    key: jax.Array,
    obs: jax.Array,
    params,
    transition_sample: Callable,
    obs_logpdf: Callable,
    num_particles: int,
    h0: float = 0.0,
) -> SMCResult:
    """Bootstrap PF (unconditional): used to initialize particle Gibbs."""
    ref = jnp.zeros_like(obs)

    # Reuse csmc machinery but overwrite the retained slot with a fresh draw
    # by never pinning: simplest correct approach is csmc with a random ref
    # drawn from the prior; for initialization quality this suffices.
    def trans_with_ref(k, hp, t, p):
        return transition_sample(k, hp, t, p)

    t_len = obs.shape[0]
    p = num_particles

    def step(carry, inp):
        h_prev, key = carry
        t, x_t = inp
        key, k_prop, k_res = jax.random.split(key, 3)
        prop_keys = jax.random.split(k_prop, p)
        h_t = jax.vmap(lambda k, hp: trans_with_ref(k, hp, t, params))(prop_keys, h_prev)
        logw = jax.vmap(lambda h: obs_logpdf(x_t, h, t, params))(h_t)
        anc = _systematic_resample(k_res, logw)
        log_z_t = jax.nn.logsumexp(logw) - jnp.log(p)
        return (h_t[anc], key), (h_t, anc, logw, log_z_t)

    h_init = jnp.full((p,), h0, obs.dtype)
    ts = jnp.arange(t_len)
    (_, key), (hs, ancs, logws, log_zs) = jax.lax.scan(step, (h_init, key), (ts, obs))
    key, k_pick = jax.random.split(key)
    b_last = jax.random.categorical(k_pick, logws[-1])

    def scan_back(b, t):
        h_t = hs[t][b]
        b_prev = jnp.where(t > 0, ancs[t - 1][b], 0)
        return b_prev, h_t

    _, traj_rev = jax.lax.scan(scan_back, b_last, jnp.arange(t_len - 1, -1, -1))
    return SMCResult(trajectory=traj_rev[::-1], log_evidence=log_zs.sum())
