"""Inference substrate: SMC/particle Gibbs, collapsed NIW, kernel combinators."""
from .kernels import Cycle, Mixture, Repeat, run_inference
from .niw import ClusterStats, NIWPrior, posterior_predictive_logpdf, predictive_all_clusters
from .smc import SMCResult, csmc, particle_filter

__all__ = [
    "ClusterStats",
    "Cycle",
    "Mixture",
    "NIWPrior",
    "Repeat",
    "SMCResult",
    "csmc",
    "particle_filter",
    "posterior_predictive_logpdf",
    "predictive_all_clusters",
    "run_inference",
]
