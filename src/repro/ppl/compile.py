"""Lower a (trace, variable) pair to the tensorized ``core.PartitionedTarget``.

This is the bridge between the faithful PET graph (Defs. 1–8) and the
TPU-friendly interface consumed by the MH kernels: the scaffold is computed
symbolically on the graph, partitioned at the border node, and the local
sections — stored structure-of-arrays inside a ``Plate`` — are scored by one
vectorized log-density evaluation per mini-batch (DESIGN.md §3).

Emission goes through :func:`repro.core.target_builder.build_target`: when
the plate's local score matches a registered kernel family — the ``logit``
observation factor (a ``BernoulliLogits`` node fed by an inner product of a
plate-constant feature matrix with the target variable) or the
``gaussian_ar1`` state-space plate (Normal transition factors
``x_t ~ N(phi * x_{t-1}, sigma)`` with the target variable as the AR
coefficient) — the compiled target carries the family's fused
``log_local_ensemble``, so the program gets the multi-chain Pallas path for
free; otherwise the generic graph-evaluated target is emitted unchanged.
Every match is double-gated: a structural check on the scaffold plus a
numeric probe of the opaque deterministic node, so a near-miss (e.g. a
clipped inner product or saturating AR mean) compiles to the generic path
instead of silently changing the model.

Restrictions enforced here mirror the paper's Sec. 3.1 assumptions:
T(rho, v) = ∅ and all local sections attach through a single border node.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.target import PartitionedTarget
from ..core.target_builder import build_target
from . import dists
from .trace import Node, Plate, Trace, border_node, partition, scaffold


def _topo(nodes) -> list[Node]:
    return sorted(nodes, key=lambda n: n.nid)  # eager build ⇒ nid order is topological


class _Evaluator:
    """Re-evaluates scaffold nodes under a substituted value for v.

    env maps nid -> overridden value. Plate-member values carry a leading
    section axis; evaluating with ``idx`` gathers rows of stacked values, so
    deterministic recomputation and scoring are vectorized over the batch.
    """

    def __init__(self, trace: Trace, v: Node, plate: Plate | None, sc):
        self.trace, self.v, self.plate = trace, v, plate
        self.det_global = _topo(
            n for n in sc.D if n.kind == "deterministic" and n.plate is None
        )
        self.det_local = _topo(
            n for n in sc.nodes if n.kind == "deterministic" and n.plate is not None
        )
        # scoring nodes: stochastic members of the scaffold (v's prior + absorbers)
        self.score_global = _topo(
            n
            for n in sc.nodes
            if n.kind == "stochastic" and n.plate is None and n is not v
        )
        self.score_local = _topo(
            n for n in sc.nodes if n.kind == "stochastic" and n.plate is not None
        )

    def _val(self, node: Node, env: dict, idx):
        val = env.get(node.nid, node.value)
        if idx is not None and node.plate is not None and node.nid not in env:
            val = jnp.asarray(val)[idx]
        return val

    def global_score(self, theta) -> Any:
        env = {self.v.nid: theta}
        for n in self.det_global:
            env[n.nid] = n.fn(*[self._val(p, env, None) for p in n.parents])
        v = self.v
        out = jnp.sum(v.dist.logpdf(theta, *[self._val(p, env, None) for p in v.parents]))
        for n in self.score_global:
            params = [self._val(p, env, None) for p in n.parents]
            out = out + jnp.sum(n.dist.logpdf(self._val(n, env, None), *params))
        return out

    def local_score(self, theta, idx) -> Any:
        env = {self.v.nid: theta}
        for n in self.det_global:
            env[n.nid] = n.fn(*[self._val(p, env, None) for p in n.parents])
        for n in self.det_local:
            env[n.nid] = n.fn(*[self._val(p, env, idx) for p in n.parents])
        out = jnp.zeros(idx.shape, jnp.float32)
        for n in self.score_local:
            params = [self._val(p, env, idx) for p in n.parents]
            out = out + n.dist.logpdf(self._val(n, env, idx), *params)
        return out


def _match_logit_family(ev: _Evaluator, v: Node):
    """Does the plate's local score match the ``logit`` kernel family?

    Structural check: exactly one local scoring node with a
    ``BernoulliLogits`` distribution over {-1, +1} labels, fed by exactly one
    plate-local deterministic node whose parents are a plate-constant feature
    matrix and the target variable v. The deterministic function itself is
    opaque (an arbitrary Python callable), so its inner-product form is
    verified *numerically* on random probe weights — a wrong match here would
    silently change the model, so both gates must pass.

    Returns the family data ``(x, y)`` or None.
    """
    if len(ev.score_local) != 1 or len(ev.det_local) != 1 or ev.det_global:
        return None
    y_node = ev.score_local[0]
    if not isinstance(y_node.dist, dists.BernoulliLogits):
        return None
    if len(y_node.parents) != 1 or y_node.parents[0] is not ev.det_local[0]:
        return None
    z = ev.det_local[0]
    if len(z.parents) != 2:
        return None
    pa, pb = z.parents
    candidates = []
    if pa.kind == "constant" and pa.plate is not None and pb is v:
        candidates.append((pa, lambda xx, ww: z.fn(xx, ww)))
    if pb.kind == "constant" and pb.plate is not None and pa is v:
        candidates.append((pb, lambda xx, ww: z.fn(ww, xx)))
    for x_node, apply_fn in candidates:
        x = jnp.asarray(x_node.value)
        y = jnp.asarray(y_node.value)
        w0 = jnp.asarray(v.value)
        if x.ndim != 2 or y.ndim != 1 or w0.shape != (x.shape[1],):
            continue
        if not bool(jnp.all((y == 1.0) | (y == -1.0))):
            continue
        probe_rows = x[: min(32, x.shape[0])]
        ok = True
        # Two unit-scale probes plus a large-magnitude one: the latter pushes
        # the logits far outside typical ranges, so saturating/clipped
        # variants of the inner product (e.g. clip(x @ w, -c, c)) fail the
        # gate instead of being misclassified as the pure logit family.
        for seed, scale in ((0, 1.0), (1, 1.0), (2, 1e3)):
            w_probe = scale * jax.random.normal(jax.random.key(seed), w0.shape, w0.dtype)
            got = np.asarray(apply_fn(probe_rows, w_probe))
            want = np.asarray(probe_rows @ w_probe)
            if got.shape != want.shape or not np.allclose(got, want, rtol=1e-5,
                                                          atol=1e-6 * max(scale, 1.0)):
                ok = False
                break
        if ok:
            return x, y
    return None


def _match_gaussian_ar1_family(ev: _Evaluator, v: Node):
    """Does the plate's local score match the ``gaussian_ar1`` state-space
    family?  The target shape is an AR(1) transition plate

        x_t ~ Normal(phi * x_{t-1}, sigma),   t in plate,

    with v the (scalar) AR coefficient phi: exactly one local scoring node
    with a ``Normal`` distribution whose scale is a plate-less positive
    constant, fed by exactly one plate-local deterministic node whose parents
    are a plate-constant lag series and v. As with the logit gate, the
    deterministic function is opaque, so its ``phi * x_prev`` form is
    verified numerically on random probe coefficients (including a
    large-magnitude probe that rules out saturating/clipped means).

    Returns ``(data, params_fn)`` for
    :func:`repro.core.target_builder.build_target` — ``data = (x_t, x_prev)``
    and ``params_fn`` mapping theta to the family's ``(phi, sigma^2)`` — or
    None.
    """
    if len(ev.score_local) != 1 or len(ev.det_local) != 1 or ev.det_global:
        return None
    x_node = ev.score_local[0]
    if not isinstance(x_node.dist, dists.Normal):
        return None
    if len(x_node.parents) != 2 or x_node.parents[0] is not ev.det_local[0]:
        return None
    scale_node = x_node.parents[1]
    if scale_node.kind != "constant" or scale_node.plate is not None:
        return None
    sigma = np.asarray(scale_node.value)
    if sigma.ndim != 0 or not sigma > 0:
        return None
    z = ev.det_local[0]
    if len(z.parents) != 2:
        return None
    pa, pb = z.parents
    candidates = []
    if pa.kind == "constant" and pa.plate is not None and pb is v:
        candidates.append((pa, lambda xx, ph: z.fn(xx, ph)))
    if pb.kind == "constant" and pb.plate is not None and pa is v:
        candidates.append((pb, lambda xx, ph: z.fn(ph, xx)))
    for xp_node, apply_fn in candidates:
        xp = jnp.asarray(xp_node.value)
        xt = jnp.asarray(x_node.value)
        phi0 = jnp.asarray(v.value)
        if xp.ndim != 1 or xt.shape != xp.shape or phi0.shape != ():
            continue
        probe_rows = xp[: min(32, xp.shape[0])]
        ok = True
        for seed, scale in ((0, 1.0), (1, 1.0), (2, 1e3)):
            phi_probe = scale * jax.random.normal(jax.random.key(seed), (), phi0.dtype)
            got = np.asarray(apply_fn(probe_rows, phi_probe))
            want = np.asarray(probe_rows * phi_probe)
            if got.shape != want.shape or not np.allclose(got, want, rtol=1e-5,
                                                          atol=1e-6 * max(scale, 1.0)):
                ok = False
                break
        if ok:
            s2 = jnp.asarray(float(sigma) ** 2, jnp.float32)

            def params_fn(theta):
                # The fused kernels take per-chain (phi, s2) of matching
                # shape: broadcast the constant variance to theta's (possibly
                # (K,)-batched) shape.
                return theta, jnp.broadcast_to(s2, jnp.shape(theta))

            return (xt, xp), params_fn
    return None


def compile_partitioned_target(trace: Trace, v: Node) -> PartitionedTarget:
    """Scaffold → border-node partition → kernel-family detection →
    :func:`repro.core.target_builder.build_target`."""
    sc = scaffold(trace, v)
    global_nodes, plate = partition(trace, sc)
    del global_nodes  # evaluator re-derives roles from the scaffold
    if plate is None:
        raise ValueError(
            f"scaffold of {v} has no plate-shaped local sections; use exact MH"
        )
    b = border_node(trace, sc)
    del b
    ev = _Evaluator(trace, v, plate, sc)
    n_sections = plate.size

    def log_global(theta, theta_p):
        return ev.global_score(theta_p) - ev.global_score(theta)

    def log_local(theta, theta_p, idx):
        return ev.local_score(theta_p, idx) - ev.local_score(theta, idx)

    def log_density(theta):
        idx = jnp.arange(n_sections, dtype=jnp.int32)
        return ev.global_score(theta) + ev.local_score(theta, idx).sum()

    family, family_data, params_fn = None, None, None
    logit_data = _match_logit_family(ev, v)
    if logit_data is not None:
        family, family_data = "logit", logit_data
    else:
        ar1 = _match_gaussian_ar1_family(ev, v)
        if ar1 is not None:
            family, (family_data, params_fn) = "gaussian_ar1", ar1
    return build_target(
        family,
        family_data,
        n_sections,
        log_global=log_global,
        # The graph-evaluated log_local is kept even on a family match (it is
        # numerically identical and exercises the scaffold machinery); the
        # family contributes the fused (K, m) log_local_ensemble route.
        log_local=log_local,
        log_density=log_density,
        params_fn=params_fn,
    )
