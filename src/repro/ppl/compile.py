"""Lower a (trace, variable) pair to the tensorized ``core.PartitionedTarget``.

This is the bridge between the faithful PET graph (Defs. 1–8) and the
TPU-friendly interface consumed by the MH kernels: the scaffold is computed
symbolically on the graph, partitioned at the border node, and the local
sections — stored structure-of-arrays inside a ``Plate`` — are scored by one
vectorized log-density evaluation per mini-batch (DESIGN.md §3).

Restrictions enforced here mirror the paper's Sec. 3.1 assumptions:
T(rho, v) = ∅ and all local sections attach through a single border node.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from ..core.target import PartitionedTarget
from .trace import Node, Plate, Trace, border_node, partition, scaffold


def _topo(nodes) -> list[Node]:
    return sorted(nodes, key=lambda n: n.nid)  # eager build ⇒ nid order is topological


class _Evaluator:
    """Re-evaluates scaffold nodes under a substituted value for v.

    env maps nid -> overridden value. Plate-member values carry a leading
    section axis; evaluating with ``idx`` gathers rows of stacked values, so
    deterministic recomputation and scoring are vectorized over the batch.
    """

    def __init__(self, trace: Trace, v: Node, plate: Plate | None, sc):
        self.trace, self.v, self.plate = trace, v, plate
        self.det_global = _topo(
            n for n in sc.D if n.kind == "deterministic" and n.plate is None
        )
        self.det_local = _topo(
            n for n in sc.nodes if n.kind == "deterministic" and n.plate is not None
        )
        # scoring nodes: stochastic members of the scaffold (v's prior + absorbers)
        self.score_global = _topo(
            n
            for n in sc.nodes
            if n.kind == "stochastic" and n.plate is None and n is not v
        )
        self.score_local = _topo(
            n for n in sc.nodes if n.kind == "stochastic" and n.plate is not None
        )

    def _val(self, node: Node, env: dict, idx):
        val = env.get(node.nid, node.value)
        if idx is not None and node.plate is not None and node.nid not in env:
            val = jnp.asarray(val)[idx]
        return val

    def global_score(self, theta) -> Any:
        env = {self.v.nid: theta}
        for n in self.det_global:
            env[n.nid] = n.fn(*[self._val(p, env, None) for p in n.parents])
        v = self.v
        out = jnp.sum(v.dist.logpdf(theta, *[self._val(p, env, None) for p in v.parents]))
        for n in self.score_global:
            params = [self._val(p, env, None) for p in n.parents]
            out = out + jnp.sum(n.dist.logpdf(self._val(n, env, None), *params))
        return out

    def local_score(self, theta, idx) -> Any:
        env = {self.v.nid: theta}
        for n in self.det_global:
            env[n.nid] = n.fn(*[self._val(p, env, None) for p in n.parents])
        for n in self.det_local:
            env[n.nid] = n.fn(*[self._val(p, env, idx) for p in n.parents])
        out = jnp.zeros(idx.shape, jnp.float32)
        for n in self.score_local:
            params = [self._val(p, env, idx) for p in n.parents]
            out = out + n.dist.logpdf(self._val(n, env, idx), *params)
        return out


def compile_partitioned_target(trace: Trace, v: Node) -> PartitionedTarget:
    """Scaffold → border-node partition → PartitionedTarget."""
    sc = scaffold(trace, v)
    global_nodes, plate = partition(trace, sc)
    del global_nodes  # evaluator re-derives roles from the scaffold
    if plate is None:
        raise ValueError(
            f"scaffold of {v} has no plate-shaped local sections; use exact MH"
        )
    b = border_node(trace, sc)
    del b
    ev = _Evaluator(trace, v, plate, sc)
    n_sections = plate.size

    def log_global(theta, theta_p):
        return ev.global_score(theta_p) - ev.global_score(theta)

    def log_local(theta, theta_p, idx):
        return ev.local_score(theta_p, idx) - ev.local_score(theta, idx)

    def log_density(theta):
        idx = jnp.arange(n_sections, dtype=jnp.int32)
        return ev.global_score(theta) + ev.local_score(theta, idx).sum()

    return PartitionedTarget(
        num_sections=n_sections,
        log_global=log_global,
        log_local=log_local,
        log_density=log_density,
    )
