"""Primitive distributions for the PET layer (log-pdfs + forward samplers).

Shapes broadcast; logpdf returns elementwise log densities (callers sum).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = Any
_LOG2PI = 1.8378770664093453


@dataclasses.dataclass(frozen=True)
class Distribution:
    def logpdf(self, x, *params):  # pragma: no cover - interface
        raise NotImplementedError

    def sample(self, key, *params, shape=()):  # pragma: no cover - interface
        raise NotImplementedError


class Normal(Distribution):
    def logpdf(self, x, loc, scale):
        z = (x - loc) / scale
        return -0.5 * (z * z + _LOG2PI) - jnp.log(scale)

    def sample(self, key, loc, scale, shape=()):
        return loc + scale * jax.random.normal(key, shape)


class Bernoulli(Distribution):
    """Support {0., 1.}; parameterized by probability p."""

    def logpdf(self, x, p):
        p = jnp.clip(p, 1e-7, 1 - 1e-7)
        return x * jnp.log(p) + (1 - x) * jnp.log1p(-p)

    def sample(self, key, p, shape=()):
        return jax.random.bernoulli(key, p, shape).astype(jnp.float32)


class BernoulliLogits(Distribution):
    """Support {-1., +1.} with logits z: log p(y|z) = -log(1 + exp(-y z)).

    This is the Logit(y|x, w) factor of the paper's regression models.
    """

    def logpdf(self, y, z):
        return -jnp.logaddexp(0.0, -y * z)

    def sample(self, key, z, shape=()):
        p = jax.nn.sigmoid(z)
        return jnp.where(jax.random.bernoulli(key, p, shape), 1.0, -1.0)


class Gamma(Distribution):
    def logpdf(self, x, a, rate):
        return a * jnp.log(rate) - jax.lax.lgamma(a) + (a - 1) * jnp.log(x) - rate * x

    def sample(self, key, a, rate, shape=()):
        return jax.random.gamma(key, a, shape) / rate


class InvGamma(Distribution):
    def logpdf(self, x, a, scale):
        return a * jnp.log(scale) - jax.lax.lgamma(a) - (a + 1) * jnp.log(x) - scale / x

    def sample(self, key, a, scale, shape=()):
        return scale / jax.random.gamma(key, a, shape)


class Beta(Distribution):
    def logpdf(self, x, a, b):
        lbeta = jax.lax.lgamma(a) + jax.lax.lgamma(b) - jax.lax.lgamma(a + b)
        return (a - 1) * jnp.log(x) + (b - 1) * jnp.log1p(-x) - lbeta

    def sample(self, key, a, b, shape=()):
        return jax.random.beta(key, a, b, shape)


class MVNormalDiag(Distribution):
    def logpdf(self, x, loc, scale):
        z = (x - loc) / scale
        return jnp.sum(-0.5 * (z * z + _LOG2PI) - jnp.log(scale), axis=-1)

    def sample(self, key, loc, scale, shape=()):
        return loc + scale * jax.random.normal(key, shape + jnp.shape(loc))


class Uniform(Distribution):
    def logpdf(self, x, lo, hi):
        inside = (x >= lo) & (x <= hi)
        return jnp.where(inside, -jnp.log(hi - lo), -jnp.inf)

    def sample(self, key, lo, hi, shape=()):
        return jax.random.uniform(key, shape, minval=lo, maxval=hi)


normal = Normal()
bernoulli = Bernoulli()
bernoulli_logits = BernoulliLogits()
gamma = Gamma()
inv_gamma = InvGamma()
beta = Beta()
mvnormal_diag = MVNormalDiag()
uniform = Uniform()
