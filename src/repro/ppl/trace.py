"""Probabilistic execution traces (PETs) and scaffolds — paper Defs. 1–8.

A ``Trace`` records one execution of a generative program as a directed graph
with *statistical* edges E_s (value dependence) and *existential* edges E_e
(control-flow dependence, Def. 1). Scaffold machinery implements:

  Def 2  target set D(rho, v)      — v + deterministic-descendant closure
  Def 3  transient set T(rho, v)   — existence depends on values in D
  Def 4  absorbing set A(rho, v)   — outside nodes with a parent in D∪T
  Def 5  scaffold s = D ∪ T ∪ A
  Def 6  border node b(s, v)       — first descendant of v with >1 branch in s
  Def 7  global section            — s minus descendants(b)
  Def 8  local sections            — s ∩ ({c_i} ∪ descendants(c_i))

``Plate`` nodes hold N structurally-identical sub-traces in structure-of-array
form; they are how the TPU adaptation keeps Def. 8's local sections vectorized
(DESIGN.md §3). ``compile.py`` lowers a (trace, v) pair with a plate-shaped
scaffold to the ``core.PartitionedTarget`` tensor interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from .dists import Distribution


@dataclasses.dataclass
class Node:
    nid: int
    name: str
    kind: str  # "stochastic" | "deterministic" | "constant"
    dist: Distribution | None = None
    fn: Callable | None = None
    parents: tuple = ()  # E_s in-edges (Node refs)
    exist_parent: "Node | None" = None  # E_e in-edge
    value: Any = None
    observed: bool = False
    # plate support
    plate: "Plate | None" = None  # owning plate (None = global graph)

    def __hash__(self):
        return self.nid

    def __eq__(self, other):
        return isinstance(other, Node) and other.nid == self.nid

    def __repr__(self):  # pragma: no cover
        flags = ("obs" if self.observed else self.kind[:3]) + (
            f"@{self.plate.name}" if self.plate else ""
        )
        return f"<{self.name}#{self.nid}:{flags}>"


@dataclasses.dataclass(eq=False)
class Plate:
    """N structurally-identical local sub-traces, stored SoA.

    ``index_node`` is the symbolic section index available to member nodes;
    member node values carry a leading axis of size ``size``.
    """

    name: str
    size: int
    index_node: "Node" = None
    members: list = dataclasses.field(default_factory=list)


class Trace:
    """One probabilistic execution trace. Build eagerly with concrete values."""

    def __init__(self):
        self.nodes: list[Node] = []
        self.plates: list[Plate] = []
        self._plate_stack: list[Plate] = []

    # -- construction -------------------------------------------------------
    def _add(self, node: Node) -> Node:
        self.nodes.append(node)
        if self._plate_stack:
            node.plate = self._plate_stack[-1]
            node.plate.members.append(node)
        return node

    def constant(self, name: str, value) -> Node:
        return self._add(Node(len(self.nodes), name, "constant", value=value))

    def sample(self, name: str, dist: Distribution, *parents: Node, value=None,
               exist_parent: Node | None = None) -> Node:
        """`assume` with a stochastic right-hand side."""
        n = Node(len(self.nodes), name, "stochastic", dist=dist,
                 parents=tuple(parents), exist_parent=exist_parent, value=value)
        return self._add(n)

    def det(self, name: str, fn: Callable, *parents: Node,
            exist_parent: Node | None = None) -> Node:
        """`assume` with a deterministic right-hand side; value computed now."""
        vals = [p.value for p in parents]
        n = Node(len(self.nodes), name, "deterministic", fn=fn,
                 parents=tuple(parents), exist_parent=exist_parent,
                 value=fn(*vals))
        return self._add(n)

    def observe(self, node: Node, value) -> Node:
        assert node.kind == "stochastic", "only stochastic nodes can be observed"
        node.observed = True
        node.value = value
        return node

    def plate(self, name: str, size: int):
        """Context manager: nodes created inside belong to one plate (the N
        local sections of Def. 8, stored stacked)."""
        plate = Plate(name, size)
        plate.index_node = Node(len(self.nodes), f"{name}.idx", "constant",
                                value=jnp.arange(size))
        self.nodes.append(plate.index_node)
        plate.index_node.plate = plate
        plate.members.append(plate.index_node)
        self.plates.append(plate)
        trace = self

        class _Ctx:
            def __enter__(self):
                trace._plate_stack.append(plate)
                return plate

            def __exit__(self, *exc):
                trace._plate_stack.pop()
                return False

        return _Ctx()

    # -- graph queries ------------------------------------------------------
    def children(self, node: Node) -> list[Node]:
        return [n for n in self.nodes if node in n.parents]

    def exist_children(self, node: Node) -> list[Node]:
        return [n for n in self.nodes if n.exist_parent is node]

    def descendants(self, node: Node) -> set[Node]:
        out, frontier = set(), [node]
        while frontier:
            n = frontier.pop()
            for c in self.children(n) + self.exist_children(n):
                if c not in out:
                    out.add(c)
                    frontier.append(c)
        return out


# ---------------------------------------------------------------------------
# Scaffold construction (Defs. 2–8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Scaffold:
    v: Node
    D: set  # target set
    T: set  # transient set
    A: set  # absorbing set

    @property
    def nodes(self) -> set:
        return self.D | self.T | self.A


def target_set(trace: Trace, v: Node) -> set:
    """Def. 2: v plus descendants reached through deterministic nodes."""
    D = {v}
    frontier = [v]
    while frontier:
        n = frontier.pop()
        for c in trace.children(n):
            if c.kind == "deterministic" and c not in D:
                D.add(c)
                frontier.append(c)
    return D


def transient_set(trace: Trace, D: set) -> set:
    """Def. 3 (+ descendants closure: removed nodes take their subtrees)."""
    T = set()
    frontier = []
    for d in D:
        for c in trace.exist_children(d):
            if c not in D and c not in T:
                T.add(c)
                frontier.append(c)
    while frontier:
        n = frontier.pop()
        for c in trace.children(n) + trace.exist_children(n):
            if c not in T and c not in D:
                T.add(c)
                frontier.append(c)
    return T


def absorbing_set(trace: Trace, D: set, T: set) -> set:
    """Def. 4: outside nodes with a parent in D ∪ T (they re-score, not resample)."""
    DT = D | T
    A = set()
    for n in trace.nodes:
        if n in DT:
            continue
        if any(p in DT for p in n.parents):
            assert n.kind == "stochastic", (
                f"deterministic node {n} with a parent in D∪T must itself be in D∪T"
            )
            A.add(n)
    return A


def scaffold(trace: Trace, v: Node) -> Scaffold:
    D = target_set(trace, v)
    T = transient_set(trace, D)
    A = absorbing_set(trace, D, T)
    return Scaffold(v=v, D=D, T=T, A=A)


def border_node(trace: Trace, sc: Scaffold) -> Node:
    """Def. 6: first descendant of v (walking inside the scaffold through D)
    with multiple scaffold branches. A plate child counts as N branches."""
    n = sc.v
    seen = {n}
    while True:
        in_scaffold = [c for c in trace.children(n) if c in sc.nodes and c not in seen]
        plate_children = [c for c in in_scaffold if c.plate is not None]
        if plate_children:
            return n  # children live in a plate → N branches meet here
        if len(in_scaffold) != 1:
            return n
        n = in_scaffold[0]
        seen.add(n)


def partition(trace: Trace, sc: Scaffold) -> tuple[set, Plate | None]:
    """Defs. 7–8: (global section nodes, plate holding the local sections).

    Requires T = ∅ (paper Sec. 3.1: approximate transitions must not change
    trace structure) and all N local branches mediated by one border node.
    """
    if sc.T:
        raise ValueError(
            "subsampled MH requires T(rho, v) = ∅ — proposals must not change "
            "the trace structure (paper Sec. 3.1)"
        )
    b = border_node(trace, sc)
    local_nodes = {n for n in sc.nodes if n.plate is not None}
    global_nodes = sc.nodes - local_nodes
    plates = {n.plate for n in local_nodes}
    if len(plates) > 1:
        raise ValueError("scaffold spans multiple plates; sample one variable at a time")
    plate = plates.pop() if plates else None
    if plate is not None:
        # all local sections must hang off the border node with a single link
        for c in trace.children(b):
            if c in sc.nodes and c.plate is None and c is not b:
                pass  # global-side children are fine
    return global_nodes, plate
