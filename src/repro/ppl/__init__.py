"""PET layer: probabilistic execution traces, scaffolds, and lowering.

Faithful implementations of the paper's Defs. 1–8 plus the `plate`
vectorization bridge to the core MH kernels.
"""
from . import dists
from .compile import compile_partitioned_target
from .trace import (
    Node,
    Plate,
    Scaffold,
    Trace,
    absorbing_set,
    border_node,
    partition,
    scaffold,
    target_set,
    transient_set,
)

__all__ = [
    "Node",
    "Plate",
    "Scaffold",
    "Trace",
    "absorbing_set",
    "border_node",
    "compile_partitioned_target",
    "dists",
    "partition",
    "scaffold",
    "target_set",
    "transient_set",
]
