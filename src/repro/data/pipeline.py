"""Deterministic, shardable synthetic data pipelines.

Two generators:
  - ``TokenStream``: iid uniform tokens keyed by (seed, step) — counter-based,
    so any host can materialize exactly its shard of any step's batch with no
    coordination (the property that makes resume and elastic rescale trivial).
  - ``MarkovStream``: order-1 Markov chains with a random-but-fixed transition
    matrix, giving models a learnable signal for the end-to-end examples.

Batches double as the subsampled-MH *pool*: the stream order is random by
construction, so contiguous per-round slices are without-replacement draws
(DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.key(c.seed), step)
        tokens = jax.random.randint(key, (c.global_batch, c.seq_len), 0, c.vocab, jnp.int32)
        return {"tokens": tokens, "mask": jnp.ones_like(tokens)}


class MarkovStream:
    """Sequences from a fixed random Markov chain (peaked transitions)."""

    def __init__(self, cfg: DataConfig, concentration: float = 0.3):
        self.cfg = cfg
        key = jax.random.key(cfg.seed + 7_777)
        logits = jax.random.normal(key, (cfg.vocab, cfg.vocab)) / concentration
        self.trans_logits = logits

    def batch(self, step: int) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.key(c.seed), step)
        k0, kseq = jax.random.split(key)
        first = jax.random.randint(k0, (c.global_batch,), 0, c.vocab, jnp.int32)
        keys = jax.random.split(kseq, c.seq_len - 1)

        def step_fn(prev, k):
            nxt = jax.random.categorical(k, self.trans_logits[prev], axis=-1).astype(jnp.int32)
            return nxt, nxt

        _, rest = jax.lax.scan(step_fn, first, keys)
        tokens = jnp.concatenate([first[None], rest], axis=0).T
        return {"tokens": tokens, "mask": jnp.ones_like(tokens)}


def shard_batch(batch: dict, mesh, logical=("batch", None)) -> dict:
    """Place a host-global batch onto the mesh with batch-axis sharding."""
    from ..distributed.sharding import named_sharding

    def put(x):
        return jax.device_put(x, named_sharding(mesh, x.shape, logical[: x.ndim]))

    return {k: put(v) for k, v in batch.items()}
