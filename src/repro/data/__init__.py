"""Data pipelines: deterministic shardable synthetic streams."""
from .pipeline import DataConfig, MarkovStream, TokenStream, shard_batch

__all__ = ["DataConfig", "MarkovStream", "TokenStream", "shard_batch"]
