"""chameleon-34b [vlm] — early-fusion mixed-modal token stream; VQ image
tokens live in the shared vocab; modality frontend stubbed (tokens arrive
pre-quantized). qk-norm as in the paper. [arXiv:2405.09818; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_ff=22016, vocab=65536,
    qk_norm=True, rope_base=10_000.0, max_seq=32768,
)
