"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]  SWA window 4096 bounds the decode KV cache, making
the 500k-token decode shape sub-quadratic (see DESIGN.md)."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, window=4096, rope_base=1_000_000.0, max_seq=65536,
    sub_quadratic=True,
)
