"""chatglm3-6b [dense] — 2d (partial) RoPE, GQA kv=2. [arXiv:2406.12793; hf]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, d_ff=13696, vocab=65024,
    rotary_frac=0.5, rope_base=10_000.0, max_seq=32768,
)
