"""gemma3-4b [dense] — 5:1 local:global attention, 128k context, GQA kv=4.
[hf:google/gemma-3-1b-pt; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4, d_ff=10240, vocab=262144,
    head_dim=256, qk_norm=True,
    local_window=1024, global_every=6, rope_base=10_000.0,
    global_rope_base=1_000_000.0, max_seq=131072,
)
