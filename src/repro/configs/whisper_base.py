"""whisper-base [audio] — enc-dec, conv frontend stubbed: input_specs()
provides precomputed (B, 1500, D) frame embeddings. [arXiv:2212.04356;
unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, enc_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048,
    vocab=51865, n_audio_frames=1500, max_seq=32768,
)
