"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887; hf]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_every=2, attn_period=8, attn_index=4,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_base=10_000.0, max_seq=262144, sub_quadratic=True,
)
