"""Architecture registry: the 10 assigned configs + input-shape sets +
reduced smoke variants."""
from __future__ import annotations

import dataclasses

from ..models.transformer import ModelConfig
from . import (
    chameleon_34b,
    chatglm3_6b,
    gemma3_4b,
    internlm2_20b,
    jamba_v0_1_52b,
    mixtral_8x22b,
    phi3_5_moe_42b,
    qwen1_5_32b,
    whisper_base,
    xlstm_350m,
)

ARCHS: dict[str, ModelConfig] = {
    "qwen1.5-32b": qwen1_5_32b.CONFIG,
    "gemma3-4b": gemma3_4b.CONFIG,
    "internlm2-20b": internlm2_20b.CONFIG,
    "chatglm3-6b": chatglm3_6b.CONFIG,
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b.CONFIG,
    "xlstm-350m": xlstm_350m.CONFIG,
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
    "whisper-base": whisper_base.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic decode (DESIGN.md
    §Arch-applicability); every assigned arch has a decoder."""
    cfg = ARCHS[arch]
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full attention: a 524288-token KV cache at full attention is "
            "the quadratic regime this shape excludes (skip noted in DESIGN.md)"
        )
    return True, ""


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: preserves structure
    (window pattern, MoE cadence, hybrid period, enc-dec) at toy width."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=128,
        head_dim=16 if cfg.head_dim else None,
        max_seq=256,
    )
    if cfg.family in ("dense", "vlm"):
        kw["n_layers"] = 6 if cfg.global_every else 3
    elif cfg.family == "moe":
        kw["n_layers"] = 2
        kw["n_experts"] = 4
        kw["top_k"] = min(cfg.top_k, 2)
    elif cfg.family == "ssm":
        kw["n_layers"] = 4
    elif cfg.family == "hybrid":
        kw["n_layers"] = cfg.attn_period  # one period
        kw["n_experts"] = 4
        kw["top_k"] = 2
    elif cfg.family == "audio":
        kw["n_layers"] = 2
        kw["enc_layers"] = 2
        kw["n_audio_frames"] = 16
    if cfg.window:
        kw["window"] = 32
    if cfg.local_window:
        kw["local_window"] = 16
    if cfg.mamba_expand:
        kw["mamba_d_state"] = 8
        kw["dt_rank"] = 8
    return dataclasses.replace(cfg, **kw)


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "reduce_config", "shape_applicable"]
