"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks, d_ff=0 (the blocks
carry their own projections). [arXiv:2405.04517; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    max_seq=1 << 20, sub_quadratic=True,
)
