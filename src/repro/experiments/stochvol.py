"""Stochastic volatility model (paper Sec. 4.3).

    x_t = exp(h_t / 2) eps_t,   h_t ~ N(phi h_{t-1}, sigma^2),  h_0 = 0
    phi ~ Beta(5, 1),           sigma^2 ~ InvGamma(5, 0.05)

Joint parameter + state estimation: particle Gibbs (conditional SMC) samples
the latent paths h while subsampled MH samples phi and sigma^2. The local
sections for both parameters are the T transition factors
N(h_t | phi h_{t-1}, sigma^2) — *statistically dependent* sections, the case
that distinguishes this paper from iid-austerity (Sec. 3.2 Remark).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.target import PartitionedTarget
from ..inference.smc import csmc

_LOG2PI = 1.8378770664093453


class SVParams(NamedTuple):
    phi: jax.Array  # scalar in (0, 1)
    sigma2: jax.Array  # scalar > 0


class SVData(NamedTuple):
    obs: jax.Array  # (S, T) observations
    h_true: jax.Array  # (S, T) latent paths


def synth(key: jax.Array, num_series: int = 200, length: int = 5,
          phi: float = 0.95, sigma: float = 0.1) -> SVData:
    k1, k2 = jax.random.split(key)
    eps_h = jax.random.normal(k1, (num_series, length)) * sigma
    eps_x = jax.random.normal(k2, (num_series, length))

    def one_series(eh):
        def step(h_prev, e):
            h = phi * h_prev + e
            return h, h

        _, hs = jax.lax.scan(step, 0.0, eh)
        return hs

    h = jax.vmap(one_series)(eps_h)
    x = jnp.exp(h / 2.0) * eps_x
    return SVData(obs=x, h_true=h)


# -- densities ---------------------------------------------------------------


def log_prior_phi(phi):
    """Beta(5, 1) on phi."""
    inside = (phi > 0) & (phi < 1)
    lp = 4.0 * jnp.log(jnp.clip(phi, 1e-12, 1.0)) + jnp.log(5.0)
    return jnp.where(inside, lp, -jnp.inf)


def log_prior_sigma2(s2):
    """InvGamma(5, 0.05) on sigma^2."""
    a, b = 5.0, 0.05
    inside = s2 > 0
    s2c = jnp.clip(s2, 1e-12, None)
    lp = a * jnp.log(b) - jax.lax.lgamma(jnp.asarray(a)) - (a + 1) * jnp.log(s2c) - b / s2c
    return jnp.where(inside, lp, -jnp.inf)


def _trans_logpdf(h_t, h_prev, phi, sigma2):
    s2 = jnp.clip(sigma2, 1e-12, None)
    z2 = (h_t - phi * h_prev) ** 2 / s2
    return -0.5 * (z2 + jnp.log(s2) + _LOG2PI)


def _obs_logpdf(x_t, h_t):
    # x_t ~ N(0, exp(h_t)) i.e. std = exp(h_t/2)
    return -0.5 * (x_t * x_t * jnp.exp(-h_t) + h_t + _LOG2PI)


# -- partitioned targets ------------------------------------------------------


def make_param_target(h: jax.Array, which: str,
                      permute_key: jax.Array | None = None) -> PartitionedTarget:
    """Target over ``params = {phi, sigma2}`` for one parameter's move, with
    local sections = all (series, t) transition factors given current h.

    ``which`` selects the moving parameter; the other is held in the closure
    of the proposal (core kernels treat theta as the full dict — symmetric RW
    on a single leaf keeps the other fixed).

    ``permute_key``: pre-permute the section order once (O(N) at target
    construction, amortized over all transitions) so the O(1) ``stream``
    sampler's contiguous slices are valid without-replacement draws even
    though SV sections are serially correlated in natural order.
    """
    s, t_len = h.shape
    h_prev = jnp.concatenate([jnp.zeros((s, 1), h.dtype), h[:, :-1]], axis=1)
    ht_flat = h.reshape(-1)
    hp_flat = h_prev.reshape(-1)
    n = ht_flat.shape[0]
    if permute_key is not None:
        perm = jax.random.permutation(permute_key, n)
        ht_flat = ht_flat[perm]
        hp_flat = hp_flat[perm]

    def log_prior(theta):
        return log_prior_phi(theta["phi"]) + log_prior_sigma2(theta["sigma2"])

    def log_global(theta, theta_p):
        return log_prior(theta_p) - log_prior(theta)

    def log_local(theta, theta_p, idx):
        ht, hp = ht_flat[idx], hp_flat[idx]
        lp = _trans_logpdf(ht, hp, theta_p["phi"], theta_p["sigma2"])
        lc = _trans_logpdf(ht, hp, theta["phi"], theta["sigma2"])
        return lp - lc

    def log_density(theta):
        lp = _trans_logpdf(ht_flat, hp_flat, theta["phi"], theta["sigma2"]).sum()
        return log_prior(theta) + lp

    del which  # both parameters share the same section structure
    return PartitionedTarget(n, log_global, log_local, log_density)


class SingleLeafRW:
    """Symmetric RW on one dict leaf, others untouched (paper's per-variable
    `subsampled_mh sig/phi` kernels)."""

    def __init__(self, leaf: str, sigma: float):
        self.leaf, self.sigma = leaf, sigma

    def __call__(self, key, theta):
        noise = jax.random.normal(key, ())
        theta_p = dict(theta)
        theta_p[self.leaf] = theta[self.leaf] + self.sigma * noise
        return theta_p, jnp.zeros((), jnp.float32)


# -- particle Gibbs over latent paths -----------------------------------------


def pgibbs_sweep(key: jax.Array, obs: jax.Array, h: jax.Array, params: SVParams,
                 num_particles: int = 30) -> jax.Array:
    """One conditional-SMC sweep per series (vmapped): returns new h (S, T)."""

    def transition_sample(k, h_prev, t, p):
        del t
        return p.phi * h_prev + jnp.sqrt(jnp.clip(p.sigma2, 1e-12, None)) * jax.random.normal(k, ())

    def obs_logpdf(x_t, h_t, t, p):
        del t, p
        return _obs_logpdf(x_t, h_t)

    keys = jax.random.split(key, obs.shape[0])

    def one(k, x_s, h_s):
        return csmc(k, x_s, h_s, params, transition_sample, obs_logpdf, num_particles).trajectory

    return jax.vmap(one)(keys, obs, h)


def exact_state_loglik(obs: jax.Array, h: jax.Array, params: SVParams) -> jax.Array:
    """Full joint log p(x, h | params): used in tests against brute force."""
    s, t_len = h.shape
    h_prev = jnp.concatenate([jnp.zeros((s, 1), h.dtype), h[:, :-1]], axis=1)
    lt = _trans_logpdf(h, h_prev, params.phi, params.sigma2).sum()
    lo = _obs_logpdf(obs, h).sum()
    return lt + lo
