"""Stochastic volatility model (paper Sec. 4.3).

    x_t = exp(h_t / 2) eps_t,   h_t ~ N(phi h_{t-1}, sigma^2),  h_0 = 0
    phi ~ Beta(5, 1),           sigma^2 ~ InvGamma(5, 0.05)

Joint parameter + state estimation: particle Gibbs (conditional SMC) samples
the latent paths h while subsampled MH samples phi and sigma^2. The local
sections for both parameters are the T transition factors
N(h_t | phi h_{t-1}, sigma^2) — *statistically dependent* sections, the case
that distinguishes this paper from iid-austerity (Sec. 3.2 Remark).
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.composite import CycleOp, SubsampledMHOp, SweepOp, cycle
from ..core.subsampled_mh import SubsampledMHConfig
from ..core.target import PartitionedTarget
from ..core.target_builder import build_target
from ..inference.smc import csmc
from ..kernels.pgibbs import batched_pgibbs_sweep, pgibbs_sweep_fused

_LOG2PI = 1.8378770664093453

#: Sweep implementations for :func:`make_inference_cycle`.
#: "fused"  — the time-major fused scan (repro.kernels.pgibbs), fast RNG
#:            stream (statistically validated, not bitwise vs opaque);
#: "compat" — the fused scan reproducing the opaque path bit for bit;
#: "opaque" — the legacy per-series vmapped csmc.
SWEEP_MODES = ("fused", "compat", "opaque")
SWEEP_ENV_VAR = "REPRO_SWEEP"


def resolve_sweep(sweep: str = "auto") -> str:
    """``auto`` defers to ``$REPRO_SWEEP`` and defaults to ``fused``."""
    if sweep == "auto":
        sweep = os.environ.get(SWEEP_ENV_VAR, "fused")
    if sweep not in SWEEP_MODES:
        raise ValueError(
            f"unknown sweep mode {sweep!r}; expected 'auto' or one of {SWEEP_MODES}"
        )
    return sweep


class SVParams(NamedTuple):
    phi: jax.Array  # scalar in (0, 1)
    sigma2: jax.Array  # scalar > 0


class SVData(NamedTuple):
    obs: jax.Array  # (S, T) observations
    h_true: jax.Array  # (S, T) latent paths


def synth(key: jax.Array, num_series: int = 200, length: int = 5,
          phi: float = 0.95, sigma: float = 0.1) -> SVData:
    k1, k2 = jax.random.split(key)
    eps_h = jax.random.normal(k1, (num_series, length)) * sigma
    eps_x = jax.random.normal(k2, (num_series, length))

    def one_series(eh):
        def step(h_prev, e):
            h = phi * h_prev + e
            return h, h

        _, hs = jax.lax.scan(step, 0.0, eh)
        return hs

    h = jax.vmap(one_series)(eps_h)
    x = jnp.exp(h / 2.0) * eps_x
    return SVData(obs=x, h_true=h)


# -- densities ---------------------------------------------------------------


def log_prior_phi(phi):
    """Beta(5, 1) on phi."""
    inside = (phi > 0) & (phi < 1)
    lp = 4.0 * jnp.log(jnp.clip(phi, 1e-12, 1.0)) + jnp.log(5.0)
    return jnp.where(inside, lp, -jnp.inf)


def log_prior_sigma2(s2):
    """InvGamma(5, 0.05) on sigma^2."""
    a, b = 5.0, 0.05
    inside = s2 > 0
    s2c = jnp.clip(s2, 1e-12, None)
    lp = a * jnp.log(b) - jax.lax.lgamma(jnp.asarray(a)) - (a + 1) * jnp.log(s2c) - b / s2c
    return jnp.where(inside, lp, -jnp.inf)


def _trans_logpdf(h_t, h_prev, phi, sigma2):
    s2 = jnp.clip(sigma2, 1e-12, None)
    z2 = (h_t - phi * h_prev) ** 2 / s2
    return -0.5 * (z2 + jnp.log(s2) + _LOG2PI)


def _obs_logpdf(x_t, h_t):
    # x_t ~ N(0, exp(h_t)) i.e. std = exp(h_t/2); single definition shared
    # with the fused pgibbs sweep's particle weights
    from ..kernels.ref import sv_obs_loglik

    return sv_obs_loglik(x_t, h_t)


# -- partitioned targets ------------------------------------------------------


def _sv_prior(theta):
    return log_prior_phi(theta["phi"]) + log_prior_sigma2(theta["sigma2"])


def _sv_params(theta):
    return theta["phi"], theta["sigma2"]


def make_param_target(h: jax.Array, which: str,
                      permute_key: jax.Array | None = None) -> PartitionedTarget:
    """Target over ``params = {phi, sigma2}`` for one parameter's move, with
    local sections = all (series, t) transition factors given current h —
    built through the ``gaussian_ar1`` kernel family, which also attaches the
    fused (K, m) ``log_local_ensemble`` round.

    ``which`` selects the moving parameter; the other is held in the closure
    of the proposal (core kernels treat theta as the full dict — symmetric RW
    on a single leaf keeps the other fixed).

    ``permute_key``: pre-permute the section order once (O(N) at target
    construction, amortized over all transitions) so the O(1) ``stream``
    sampler's contiguous slices are valid without-replacement draws even
    though SV sections are serially correlated in natural order.
    """
    s, t_len = h.shape
    h_prev = jnp.concatenate([jnp.zeros((s, 1), h.dtype), h[:, :-1]], axis=1)
    ht_flat = h.reshape(-1)
    hp_flat = h_prev.reshape(-1)
    n = ht_flat.shape[0]
    if permute_key is not None:
        perm = jax.random.permutation(permute_key, n)
        ht_flat = ht_flat[perm]
        hp_flat = hp_flat[perm]

    del which  # both parameters share the same section structure
    return build_target(
        "gaussian_ar1", (ht_flat, hp_flat), n,
        prior_logpdf=_sv_prior, params_fn=_sv_params,
    )


def make_joint_param_target(num_series: int, length: int,
                            permute_key: jax.Array | None = None) -> PartitionedTarget:
    """The ensemble-ready form of :func:`make_param_target`: the latent paths
    live in ``theta["h"]`` instead of a construction-time closure, so one
    target serves every chain of a :class:`~repro.core.ensemble.ChainEnsemble`
    (each chain's sections derive from its own paths) and the particle-Gibbs
    sweep can update ``h`` between MH moves inside the same compiled program.

    The family ``data`` is a callable reading ``theta["h"]`` — valid because
    the phi/sigma2 proposals never move the ``h`` leaf.
    """
    n = num_series * length
    perm = None if permute_key is None else jax.random.permutation(permute_key, n)

    def data_fn(theta):
        h = theta["h"]  # (S, T) — or (K, S, T) inside the ensemble round
        zeros = jnp.zeros(h.shape[:-1] + (1,), h.dtype)
        h_prev = jnp.concatenate([zeros, h[..., :-1]], axis=-1)
        ht = h.reshape(h.shape[:-2] + (n,))
        hp = h_prev.reshape(h_prev.shape[:-2] + (n,))
        if perm is not None:
            ht, hp = ht[..., perm], hp[..., perm]
        return ht, hp

    return build_target(
        "gaussian_ar1", data_fn, n, prior_logpdf=_sv_prior, params_fn=_sv_params,
    )


class SingleLeafRW:
    """Symmetric RW on one dict leaf, others untouched (paper's per-variable
    `subsampled_mh sig/phi` kernels)."""

    def __init__(self, leaf: str, sigma: float):
        self.leaf, self.sigma = leaf, sigma

    def __call__(self, key, theta):
        noise = jax.random.normal(key, ())
        theta_p = dict(theta)
        theta_p[self.leaf] = theta[self.leaf] + self.sigma * noise
        return theta_p, jnp.zeros((), jnp.float32)


# -- particle Gibbs over latent paths -----------------------------------------


def pgibbs_sweep(key: jax.Array, obs: jax.Array, h: jax.Array, params: SVParams,
                 num_particles: int = 30) -> jax.Array:
    """One conditional-SMC sweep per series (vmapped): returns new h (S, T)."""

    def transition_sample(k, h_prev, t, p):
        del t
        return p.phi * h_prev + jnp.sqrt(jnp.clip(p.sigma2, 1e-12, None)) * jax.random.normal(k, ())

    def obs_logpdf(x_t, h_t, t, p):
        del t, p
        return _obs_logpdf(x_t, h_t)

    keys = jax.random.split(key, obs.shape[0])

    def one(k, x_s, h_s):
        return csmc(k, x_s, h_s, params, transition_sample, obs_logpdf, num_particles).trajectory

    return jax.vmap(one)(keys, obs, h)


# -- the paper's inference program on the ensemble engine ---------------------


def make_inference_cycle(
    obs: jax.Array,
    *,
    batch_size: int = 100,
    epsilon: float = 0.05,
    sigma_phi: float = 0.02,
    sigma_sig: float = 0.003,
    num_particles: int = 25,
    sampler: str = "fy",
    permute_key: jax.Array | None = None,
    sweep: str = "auto",
) -> CycleOp:
    """The paper's Sec-4.3 program as a composite cycle:

        [infer (cycle ((pgibbs h ...) (subsampled_mh phi ...)
                       (subsampled_mh sig ...)) 1)]

    — one particle-Gibbs sweep over the latent paths, then per-variable
    subsampled-MH moves on phi and sigma^2 whose local sections are the
    transition factors of the *current* paths (``theta["h"]``). The same
    cycle object drives :func:`run_posterior_sequential` and the K-chain
    :func:`run_posterior_ensemble`, which is what makes them bit-for-bit
    comparable.

    ``sweep`` picks the sweep implementation (see :data:`SWEEP_MODES`): the
    default resolves to the fused time-major scan of
    :mod:`repro.kernels.pgibbs`, which shares the AR(1) propagate/clip
    arithmetic with the ``gaussian_ar1`` delta kernel of the adjacent MH
    rounds and advances all chains' series in one scan. ``"compat"`` is the
    fused layout with the legacy RNG stream (bit-for-bit vs ``"opaque"``).
    """
    s, t_len = obs.shape
    target = make_joint_param_target(s, t_len, permute_key)
    cfg = SubsampledMHConfig(batch_size=batch_size, epsilon=epsilon, sampler=sampler)
    sweep = resolve_sweep(sweep)

    if sweep == "opaque":
        def pg_sweep(key, theta):
            h = pgibbs_sweep(key, obs, theta["h"],
                             SVParams(theta["phi"], theta["sigma2"]), num_particles)
            return {**theta, "h": h}

        sweep_op = SweepOp(pg_sweep, name="pgibbs")
    else:
        rng_mode = "fast" if sweep == "fused" else "compat"

        def pg_single(key, theta):
            h = pgibbs_sweep_fused(
                key, obs, theta["h"], theta["phi"], theta["sigma2"],
                num_particles=num_particles, mode=rng_mode,
            )
            return {**theta, "h": h}

        def pg_batched(keys, theta):
            h = batched_pgibbs_sweep(
                keys, obs, theta["h"], theta["phi"], theta["sigma2"],
                num_particles=num_particles, mode=rng_mode,
            )
            return {**theta, "h": h}

        sweep_op = SweepOp(pg_single, name="pgibbs", batched_fn=pg_batched)

    return cycle([
        sweep_op,
        SubsampledMHOp(target, SingleLeafRW("phi", sigma_phi), cfg, name="phi"),
        SubsampledMHOp(target, SingleLeafRW("sigma2", sigma_sig), cfg, name="sigma2"),
    ])


def init_theta(obs: jax.Array, phi: float = 0.7, sigma2: float = 0.03) -> dict:
    return {
        "phi": jnp.asarray(phi, jnp.float32),
        "sigma2": jnp.asarray(sigma2, jnp.float32),
        "h": jnp.zeros_like(obs),
    }


def _collect_params(theta):
    return {"phi": theta["phi"], "sigma2": theta["sigma2"]}


def run_posterior_sequential(
    key: jax.Array,
    data: SVData,
    num_steps: int = 400,
    *,
    theta0: dict | None = None,
    collect=None,
    **cycle_kw,
):
    """Single-chain reference run of the joint pgibbs + subsampled-MH program
    (one jitted scan). Returns (theta_final, samples, infos) with ``samples``
    the collected (phi, sigma2) trace and ``infos`` keyed by component."""
    from ..core.composite import run_cycle_sequential

    cyc = make_inference_cycle(data.obs, **cycle_kw)
    theta0 = theta0 if theta0 is not None else init_theta(data.obs)
    return run_cycle_sequential(key, theta0, cyc, num_steps,
                                collect or _collect_params)


def run_posterior_ensemble(
    key: jax.Array,
    data: SVData,
    num_chains: int = 4,
    num_steps: int = 400,
    *,
    theta0: dict | None = None,
    collect=None,
    fused_kernels: str = "auto",
    **cycle_kw,
):
    """K-chain stochastic-volatility posterior on the ensemble engine.

    The composite cycle advances every chain's (h, phi, sigma2) inside one
    jitted program; the phi/sigma2 sequential-test rounds evaluate (K, m)
    blocks (through the fused ``gaussian_ar1`` kernel when dispatch selects
    it). Chain k seeded with per-chain key k reproduces
    :func:`run_posterior_sequential` bit for bit.

    Returns ``(state, samples, infos, diagnostics)``: ``samples`` maps
    "phi"/"sigma2" to (K, T) traces; ``diagnostics`` has split-R-hat over
    chains and the evaluated-section fractions per MH variable.
    """
    from ..core import ChainEnsemble
    from ..core.stats import split_rhat

    cyc = make_inference_cycle(data.obs, **cycle_kw)
    ens = ChainEnsemble(num_chains=num_chains, transition=cyc,
                        collect=collect or _collect_params,
                        fused_kernels=fused_kernels)
    theta0 = theta0 if theta0 is not None else init_theta(data.obs)
    state, samples, infos = ens.run(key, ens.init(theta0), num_steps)
    n = data.obs.size
    half = num_steps // 2
    diagnostics = {
        "rhat_phi": split_rhat(np.asarray(samples["phi"])[:, half:]),
        "rhat_sigma2": split_rhat(np.asarray(samples["sigma2"])[:, half:]),
        "frac_evaluated": {
            name: float(np.asarray(infos[name].n_evaluated, np.float64).mean() / n)
            for name in ("phi", "sigma2")
        },
        "accept_rate": {
            name: np.asarray(infos[name].accepted, np.float64).mean(axis=1)
            for name in ("phi", "sigma2")
        },
    }
    return state, samples, infos, diagnostics


def make_serving_workload(
    *,
    smoke: bool = False,
    num_chains: int = 4,
    num_series: int | None = None,
    length: int | None = None,
    num_particles: int | None = None,
    batch_size: int = 100,
    epsilon: float = 0.05,
    seed: int = 0,
):
    """The stochastic-volatility posterior as a servable workload: the full
    Sec-4.3 composite cycle (particle Gibbs over paths + subsampled-MH
    phi/sigma2 moves) kept resident, with request classes

      * ``vol_quantile``: posterior quantiles of the stationary log-vol
        scale ``sigma / sqrt(1 - phi^2)`` — request rows are quantile
        levels in (0, 1),
      * ``phi_mean``: the posterior-mean persistence (rows are dummy
        levels; every row returns the same scalar functional).
    """
    from ..core import ChainEnsemble
    from ..serving.resident import QuerySpec
    from ..serving.workloads import ServingWorkload

    num_series = num_series if num_series is not None else (40 if smoke else 200)
    length = length if length is not None else (6 if smoke else 10)
    num_particles = num_particles if num_particles is not None else (10 if smoke else 25)
    data = synth(jax.random.key(seed), num_series=num_series, length=length)
    cyc = make_inference_cycle(
        data.obs, batch_size=min(batch_size, num_series * length),
        epsilon=epsilon, num_particles=num_particles,
    )
    ens = ChainEnsemble(num_chains=num_chains, transition=cyc,
                        collect=_collect_params)

    def stationary_vol(theta):
        s2 = jnp.clip(theta["sigma2"], 1e-12, None)
        one_minus = jnp.clip(1.0 - theta["phi"] ** 2, 1e-6, None)
        return jnp.sqrt(s2 / one_minus)

    def make_levels(qkey, rows: int) -> np.ndarray:
        return np.asarray(jax.random.uniform(qkey, (rows,), minval=0.05, maxval=0.95))

    specs = {
        "vol_quantile": QuerySpec(
            fn=lambda theta, xs: jnp.full(xs.shape, stationary_vol(theta)),
            aggregate="quantile",
            make_queries=make_levels,
            name="vol_quantile",
        ),
        "phi_mean": QuerySpec(
            fn=lambda theta, xs: jnp.full(xs.shape, theta["phi"]),
            aggregate="mean",
            make_queries=make_levels,
            name="phi_mean",
        ),
    }
    return ServingWorkload(
        name="stochvol",
        ensemble=ens,
        theta0=init_theta(data.obs),
        query_specs=specs,
        default_class="vol_quantile",
        description=f"stochastic volatility, {num_series} series x {length}",
    )


def exact_state_loglik(obs: jax.Array, h: jax.Array, params: SVParams) -> jax.Array:
    """Full joint log p(x, h | params): used in tests against brute force."""
    s, t_len = h.shape
    h_prev = jnp.concatenate([jnp.zeros((s, 1), h.dtype), h[:, :-1]], axis=1)
    lt = _trans_logpdf(h, h_prev, params.phi, params.sigma2).sum()
    lo = _obs_logpdf(obs, h).sum()
    return lt + lo
