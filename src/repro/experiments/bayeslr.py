"""Bayesian logistic regression (paper Sec. 4.1).

    w ~ N(0, 0.1 I_D),   y_i ~ Logit(y | x_i, w),  y ∈ {−1, +1}

Scaffold: D = {w, z_i}, A = {y_i}; the border node is w itself and the N
local sections are the (z_i → y_i) chains — Table 1 row 1, scaling N.

Provides the MNIST-like feature set used for the Fig-4 risk experiment
(12214 train / 2037 test, 50-dim PCA features — synthesized here with the
same shape/scale since the container is offline) and the 2-feature synthetic
of Fig. 5 used for the sublinearity study.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.target import PartitionedTarget
from ..core.target_builder import build_target
from ..kernels.ref import logit_loglik

PRIOR_VAR = 0.1


class LRData(NamedTuple):
    x_train: jax.Array  # (N, D)
    y_train: jax.Array  # (N,) in {-1, +1}
    x_test: jax.Array
    y_test: jax.Array
    w_true: jax.Array


def synth_mnist_like(
    key: jax.Array, n_train: int = 12214, n_test: int = 2037, d: int = 50
) -> LRData:
    """Two-class feature clouds with PCA-like decaying variance per dim,
    matching the scale of the paper's 7-vs-9 MNIST PCA features."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scales = 1.0 / jnp.sqrt(1.0 + jnp.arange(d, dtype=jnp.float32))
    w_true = jax.random.normal(k1, (d,)) * scales * 2.0
    x_train = jax.random.normal(k2, (n_train, d)) * scales
    x_test = jax.random.normal(k3, (n_test, d)) * scales
    p_train = jax.nn.sigmoid(x_train @ w_true)
    p_test = jax.nn.sigmoid(x_test @ w_true)
    u = jax.random.uniform(k4, (n_train + n_test,))
    y_train = jnp.where(u[:n_train] < p_train, 1.0, -1.0)
    y_test = jnp.where(u[n_train:] < p_test, 1.0, -1.0)
    return LRData(x_train, y_train, x_test, y_test, w_true)


def synth_2d(key: jax.Array, n: int) -> LRData:
    """Fig. 5a style data: two 2-d blobs separated along a diagonal."""
    k1, k2 = jax.random.split(key)
    w_true = jnp.asarray([2.0, -2.0])
    x = jax.random.normal(k1, (n, 2))
    p = jax.nn.sigmoid(x @ w_true)
    y = jnp.where(jax.random.uniform(k2, (n,)) < p, 1.0, -1.0)
    return LRData(x, y, x[: max(n // 10, 1)], y[: max(n // 10, 1)], w_true)


# The shared reference logistic factor lives in repro.kernels.ref; re-exported
# here because the experiments historically imported it from this module.
loglik = logit_loglik


def make_target(x: jax.Array, y: jax.Array, prior_var: float = PRIOR_VAR) -> PartitionedTarget:
    """BayesLR partitioned target via the ``logit`` kernel family: the
    builder attaches ``log_local`` and the fused (K, m) ``log_local_ensemble``
    (one pallas_call per multi-chain sequential-test round on TPU, pure-jnp
    ref elsewhere) — no hand-wired kernel hookup."""
    return build_target(
        "logit",
        (x, y),
        x.shape[0],
        prior_logpdf=lambda w: (-0.5 / prior_var) * jnp.sum(w**2),
    )


def make_grad_fn(x: jax.Array, y: jax.Array, prior_var: float = PRIOR_VAR, subsample: int | None = None):
    """Gradient of the log posterior (optionally on a fixed subsample with
    N/|S| rescaling) — powers the MALA proposal."""
    n = x.shape[0]

    def full_logpost(w):
        return (-0.5 / prior_var) * jnp.sum(w**2) + loglik(w, x, y).sum()

    if subsample is None:
        return jax.grad(full_logpost)

    sub = min(subsample, n)

    def sub_grad(w):
        xi, yi = x[:sub], y[:sub]

        def f(wv):
            return (-0.5 / prior_var) * jnp.sum(wv**2) + (n / sub) * loglik(wv, xi, yi).sum()

        return jax.grad(f)(w)

    return sub_grad


def run_posterior_ensemble(
    key: jax.Array,
    data: LRData,
    num_chains: int = 8,
    num_steps: int = 1000,
    kernel: str = "subsampled",
    batch_size: int = 100,
    epsilon: float = 0.05,
    sampler: str = "stream",
    sigma: float = 0.05,
    overdisperse: float = 0.5,
    stepping: str = "lockstep",
    schedule=None,
):
    """K-chain posterior sampling with cross-chain diagnostics.

    Runs a :class:`repro.core.ensemble.ChainEnsemble` from overdispersed
    starting points and returns (samples (K, T, D), diagnostics dict with
    per-dimension split-R-hat, total ESS of w[0], and the per-chain
    acceptance / evaluated-section summaries). ``stepping="masked"`` plus a
    :class:`repro.core.schedule.ScheduleConfig` turns on the adaptive
    masked-continuation engine.
    """
    from ..core import (
        ChainEnsemble,
        RandomWalk,
        SubsampledMHConfig,
        ensemble_summary,
        multichain_ess,
        split_rhat,
    )

    target = make_target(data.x_train, data.y_train)
    d = data.x_train.shape[1]
    cfg = SubsampledMHConfig(batch_size=batch_size, epsilon=epsilon, sampler=sampler)
    ens = ChainEnsemble(target, RandomWalk(sigma), num_chains, kernel=kernel, config=cfg,
                        stepping=stepping, schedule=schedule)
    k_init, k_run = jax.random.split(key)
    theta0 = overdisperse * jax.random.normal(k_init, (num_chains, d))
    state = ens.init(theta0, batched=True)
    state, samples, infos = ens.run(k_run, state, num_steps)
    w = np.asarray(samples)[:, num_steps // 2:]  # (K, T/2, D) post burn-in
    diagnostics = {
        "rhat": split_rhat(w),
        "ess_w0": multichain_ess(w[..., 0]),
        **ensemble_summary(infos),
    }
    return np.asarray(samples), diagnostics


def make_serving_workload(
    *,
    smoke: bool = False,
    num_chains: int = 8,
    n_train: int | None = None,
    d: int | None = None,
    batch_size: int | None = None,
    epsilon: float = 0.05,
    sigma: float = 0.05,
    stepping: str = "lockstep",
    schedule=None,
    seed: int = 0,
):
    """The BayesLR posterior as a servable workload (see
    :mod:`repro.serving.workloads`): the ``logit``-family target behind a
    :class:`~repro.core.ensemble.ChainEnsemble`, with two request classes —

      * ``predictive``: posterior-predictive P(y=+1 | x) for test rows,
      * ``vote``: the posterior fraction of draws classifying x as +1
        (a calibration-style uncertainty signal on the same inputs).

    Query inputs are rows of the held-out test set.
    """
    from ..core import ChainEnsemble, RandomWalk, SubsampledMHConfig
    from ..serving.resident import QuerySpec
    from ..serving.workloads import ServingWorkload, row_sampler

    n_train = n_train if n_train is not None else (2_000 if smoke else 12_000)
    d = d if d is not None else (4 if smoke else 20)
    batch_size = batch_size if batch_size is not None else (100 if smoke else 500)
    data = synth_mnist_like(
        jax.random.key(seed), n_train=n_train, n_test=max(512, d * 16), d=d
    )
    target = make_target(data.x_train, data.y_train)
    cfg = SubsampledMHConfig(batch_size=batch_size, epsilon=epsilon, sampler="stream")
    ens = ChainEnsemble(target, RandomWalk(sigma), num_chains, config=cfg,
                        stepping=stepping, schedule=schedule)
    make_queries = row_sampler(np.asarray(data.x_test))
    specs = {
        "predictive": QuerySpec(
            fn=lambda w, xs: jax.nn.sigmoid(xs @ w),
            aggregate="mean",
            make_queries=make_queries,
            name="predictive",
        ),
        "vote": QuerySpec(
            fn=lambda w, xs: (xs @ w > 0).astype(jnp.float32),
            aggregate="mean",
            make_queries=make_queries,
            name="vote",
        ),
    }
    return ServingWorkload(
        name="bayeslr",
        ensemble=ens,
        theta0=jnp.zeros(d),
        query_specs=specs,
        default_class="predictive",
        description=f"Bayesian logistic regression, N={n_train}, D={d}",
    )


def predictive_mean_prob(w_samples: np.ndarray, x_test: np.ndarray) -> np.ndarray:
    """Running posterior-predictive mean P(y=+1|x) per test point: (T, Ntest)."""
    w_samples = np.asarray(w_samples)
    logits = w_samples @ np.asarray(x_test).T  # (T, Ntest)
    probs = 1.0 / (1.0 + np.exp(-logits))
    return np.cumsum(probs, axis=0) / np.arange(1, len(probs) + 1)[:, None]


def risk_vs_reference(pred_running: np.ndarray, reference: np.ndarray) -> np.ndarray:
    """Risk of the predictive mean (Korattikara et al. 2014): mean squared
    error of the running predictive mean vs a long-run reference, per step."""
    return np.mean((pred_running - reference[None, :]) ** 2, axis=1)


def test_error(w: np.ndarray, x_test: np.ndarray, y_test: np.ndarray) -> float:
    pred = np.sign(np.asarray(x_test) @ np.asarray(w))
    return float(np.mean(pred != np.asarray(y_test)))
