"""Joint Dirichlet-process mixture of logistic experts (paper Sec. 4.2).

    (x_i, y_i) | P ~ f(x, y | P),   P ~ DP(alpha P0)
    f(x, y | P) = sum_k pi_k N(x | mu_k, Sigma_k) Logit(y | x, w_k)

(mu_k, Sigma_k) are collapsed under a conjugate NIW prior; the DP is
collapsed to a CRP. Inference mirrors the paper's program:

    [infer (cycle ((mh alpha all 1)
                   (gibbs z one step_z)
                   (subsampled_mh w one {Nbatch} {eps} 'drift {sigma} 1)) 1)]

 - z: single-site Gibbs via Neal's Algorithm 8 (one auxiliary component),
   O(1)-updatable NIW sufficient statistics (constant-time PET transitions),
 - alpha: random-walk MH on log(alpha) against the CRP partition likelihood,
 - w_k: **subsampled MH** over a randomly chosen expert's weights — local
   sections are the N_k member points, so the number of concurrently active
   austerity instances is itself inferred (Table 1 row 2: scaling N_k).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.composite import CycleOp, SweepOp, cycle
from ..core.samplers import fy_draw, fy_from_buffer, fy_reset
from ..core.sequential_test import sequential_test
from ..inference.niw import ClusterStats, NIWPrior, predictive_all_clusters
from ..kernels.ref import logit_loglik


@dataclasses.dataclass(frozen=True)
class JDPMConfig:
    k_max: int = 20
    d: int = 2
    prior_var_w: float = 1.0
    alpha_a: float = 1.0  # Gamma(a, rate) prior on alpha
    alpha_rate: float = 1.0
    niw_k0: float = 0.1
    niw_v0: float = 4.0
    niw_s0_scale: float = 1.0

    def niw_prior(self) -> NIWPrior:
        return NIWPrior(
            m0=jnp.zeros((self.d,), jnp.float32),
            k0=self.niw_k0,
            v0=self.niw_v0,
            s0=self.niw_s0_scale * jnp.eye(self.d, dtype=jnp.float32),
        )


class JDPMState(NamedTuple):
    z: jax.Array  # (N,) int32 assignments
    w: jax.Array  # (K_max, D+1) expert weights (last column = bias)
    alpha: jax.Array  # scalar CRP concentration
    stats: ClusterStats  # NIW sufficient statistics per cluster


class JDPMData(NamedTuple):
    x: jax.Array  # (N, D)
    y: jax.Array  # (N,) in {-1, +1}
    x_test: jax.Array
    y_test: jax.Array


def synth(key: jax.Array, n: int = 10_000, n_test: int = 1_000) -> JDPMData:
    """Paper-Fig-6b-style synthetic: several anisotropic blobs, each with its
    own linear label boundary (so no single global logistic fits)."""
    centers = jnp.asarray([[-2.5, 0.0], [2.5, 0.0], [0.0, 2.5], [0.0, -2.5]])
    w_per = jnp.asarray([[2.0, 1.0], [-2.0, 1.0], [1.0, -2.0], [-1.0, -2.0]])
    k1, k2, k3 = jax.random.split(key, 3)
    total = n + n_test
    comp = jax.random.randint(k1, (total,), 0, 4)
    xs = centers[comp] + 0.7 * jax.random.normal(k2, (total, 2))
    logits = jnp.sum((xs - centers[comp]) * w_per[comp], axis=-1)
    ys = jnp.where(jax.random.uniform(k3, (total,)) < jax.nn.sigmoid(2.0 * logits), 1.0, -1.0)
    return JDPMData(xs[:n], ys[:n], xs[n:], ys[n:])


def init_state(key: jax.Array, data: JDPMData, cfg: JDPMConfig) -> JDPMState:
    n = data.x.shape[0]
    k1, k2 = jax.random.split(key)
    z = jax.random.randint(k1, (n,), 0, 3).astype(jnp.int32)  # start with 3 clusters
    w = jnp.sqrt(cfg.prior_var_w) * jax.random.normal(k2, (cfg.k_max, cfg.d + 1))
    stats = ClusterStats.empty(cfg.k_max, cfg.d)

    def add(i, s):
        return s.add(z[i], data.x[i])

    stats = jax.lax.fori_loop(0, n, add, stats)
    return JDPMState(z=z, w=w, alpha=jnp.asarray(1.0), stats=stats)


# ---------------------------------------------------------------------------
# Gibbs over assignments (Neal Algorithm 8, one auxiliary component)
# ---------------------------------------------------------------------------


def gibbs_z_steps(
    key: jax.Array, state: JDPMState, data: JDPMData, cfg: JDPMConfig, points: jax.Array
) -> JDPMState:
    """Single-site Gibbs transitions for the given point indices (jitted)."""
    prior = cfg.niw_prior()
    x, y = data.x, data.y
    keys = jax.random.split(key, points.shape[0])

    def one_point(t, carry):
        z, w, stats = carry
        i = points[t]
        xi, yi = x[i], y[i]
        stats = stats.remove(z[i], xi)
        counts = stats.n
        # auxiliary slot: first empty cluster gets a fresh prior draw of w
        empty = counts < 0.5
        aux = jnp.argmax(empty)  # first empty slot (there is always one: K_max > K)
        k_aux, k_pick = jax.random.split(keys[t])
        w_aux = jnp.sqrt(cfg.prior_var_w) * jax.random.normal(k_aux, (cfg.d + 1,))
        w_eff = w.at[aux].set(w_aux)
        feat = predictive_all_clusters(xi, stats, prior)  # (K,)
        xi_aug = jnp.concatenate([xi, jnp.ones((1,), xi.dtype)])
        lab = -jnp.logaddexp(0.0, -yi * (w_eff @ xi_aug))  # (K,)
        crp = jnp.where(
            counts > 0.5,
            jnp.log(jnp.maximum(counts, 1e-12)),
            jnp.where(jnp.arange(cfg.k_max) == aux, jnp.log(state.alpha), -jnp.inf),
        )
        logp = crp + feat + lab
        k_new = jax.random.categorical(k_pick, logp).astype(jnp.int32)
        z = z.at[i].set(k_new)
        w = jnp.where(k_new == aux, w_eff, w)  # keep the fresh draw if chosen
        stats = stats.add(k_new, xi)
        return z, w, stats

    z, w, stats = jax.lax.fori_loop(0, points.shape[0], one_point, (state.z, state.w, state.stats))
    return JDPMState(z=z, w=w, alpha=state.alpha, stats=stats)


# ---------------------------------------------------------------------------
# MH over alpha (CRP partition likelihood)
# ---------------------------------------------------------------------------


def _crp_log_partition(alpha, counts):
    k_active = jnp.sum(counts > 0.5)
    n = jnp.sum(counts)
    return (
        k_active * jnp.log(alpha)
        + jax.lax.lgamma(alpha)
        - jax.lax.lgamma(alpha + n)
    )


def mh_alpha(key: jax.Array, state: JDPMState, cfg: JDPMConfig, step: float = 0.3) -> JDPMState:
    k1, k2 = jax.random.split(key)
    log_a = jnp.log(state.alpha)
    log_a_p = log_a + step * jax.random.normal(k1, ())
    a, a_p = state.alpha, jnp.exp(log_a_p)

    def post(alpha, log_alpha):
        prior = cfg.alpha_a * jnp.log(cfg.alpha_rate) + (cfg.alpha_a - 1) * log_alpha - cfg.alpha_rate * alpha
        return prior + _crp_log_partition(alpha, state.stats.n) + log_alpha  # + Jacobian

    log_ratio = post(a_p, log_a_p) - post(a, log_a)
    accept = jnp.log(jax.random.uniform(k2, (), minval=1e-20)) < log_ratio
    return state._replace(alpha=jnp.where(accept, a_p, a))


# ---------------------------------------------------------------------------
# Subsampled MH over a randomly chosen expert's weights
# ---------------------------------------------------------------------------


class WMoveInfo(NamedTuple):
    cluster: jax.Array
    accepted: jax.Array
    n_evaluated: jax.Array
    n_k: jax.Array
    rounds: jax.Array


def subsampled_mh_w(
    key: jax.Array,
    state: JDPMState,
    data: JDPMData,
    cfg: JDPMConfig,
    batch_size: int = 100,
    epsilon: float = 0.1,
    sigma_prop: float = 0.1,
    exact: bool = False,
) -> tuple[JDPMState, WMoveInfo]:
    """One (subsampled) MH transition on w_k for a random non-empty cluster.

    The local-section pool is the cluster's padded member buffer with logical
    size N_k — a *dynamic* pool (the paper's point that the number of
    austerity instances is an object of inference). Fully jitted.
    """
    n = data.x.shape[0]
    k_pick, k_u, k_prop, k_test = jax.random.split(key, 4)
    counts = state.stats.n
    pick_logits = jnp.where(counts > 0.5, 0.0, -jnp.inf)
    k_sel = jax.random.categorical(k_pick, pick_logits).astype(jnp.int32)
    n_k = counts[k_sel].astype(jnp.int32)

    members = jnp.argsort(jnp.where(state.z == k_sel, 0, 1), stable=True).astype(jnp.int32)
    # members[:N_k] are the cluster's points (stable sort keeps data order)

    w_cur = state.w[k_sel]
    w_prop = w_cur + sigma_prop * jax.random.normal(k_prop, w_cur.shape)
    log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))
    g = (-0.5 / cfg.prior_var_w) * (jnp.sum(w_prop**2) - jnp.sum(w_cur**2))
    mu0 = (log_u - g) / jnp.maximum(n_k, 1)

    def eval_fn(pos_idx):
        i = members[pos_idx]
        xi = jnp.concatenate([data.x[i], jnp.ones((pos_idx.shape[0], 1), data.x.dtype)], axis=-1)
        yi = data.y[i]
        return logit_loglik(w_prop, xi, yi) - logit_loglik(w_cur, xi, yi)

    res = sequential_test(
        key=k_test,
        mu0=mu0,
        draw_fn=fy_draw,
        eval_fn=eval_fn,
        sampler_state=fy_reset(fy_from_buffer(jnp.arange(n, dtype=jnp.int32), n_k)),
        num_sections=n_k,
        batch_size=batch_size,
        epsilon=epsilon if not exact else 0.0,  # eps=0 -> never stop early (exact)
        max_rounds=-(-n // batch_size),
    )
    accept = res.decision
    w_new = state.w.at[k_sel].set(jnp.where(accept, w_prop, w_cur))
    info = WMoveInfo(
        cluster=k_sel,
        accepted=accept,
        n_evaluated=res.n_evaluated,
        n_k=n_k,
        rounds=res.rounds,
    )
    return state._replace(w=w_new), info


# ---------------------------------------------------------------------------
# The paper's inference program on the ensemble engine
# ---------------------------------------------------------------------------


def make_inference_cycle(
    data: JDPMData,
    cfg: JDPMConfig,
    *,
    batch_size: int = 100,
    epsilon: float = 0.1,
    sigma_prop: float = 0.3,
    gibbs_frac: float = 0.5,
    w_moves: int = 10,
) -> CycleOp:
    """The paper's Fig-7 program as a composite cycle:

        [infer (cycle ((mh alpha all 1) (gibbs z one step_z)
                       (subsampled_mh w one {Nbatch} {eps} 'drift {sigma} 1)) 1)]

    ``alpha`` and ``z`` are opaque sweeps; the ``w`` component applies
    ``w_moves`` :func:`subsampled_mh_w` transitions (each picking a random
    non-empty expert, its dynamic member pool the local sections) and records
    the stacked :class:`WMoveInfo` trace. One cycle object serves the
    sequential reference and the K-replica ensemble.
    """
    n = data.x.shape[0]
    n_gibbs = max(1, int(n * gibbs_frac))

    def alpha_op(key, state):
        return mh_alpha(key, state, cfg)

    def z_op(key, state):
        k_pts, k_gibbs = jax.random.split(key)
        pts = jax.random.permutation(k_pts, n)[:n_gibbs]
        return gibbs_z_steps(k_gibbs, state, data, cfg, pts)

    def w_op(key, state):
        infos = []
        for j in range(w_moves):
            state, info = subsampled_mh_w(
                jax.random.fold_in(key, j), state, data, cfg,
                batch_size=batch_size, epsilon=epsilon, sigma_prop=sigma_prop,
            )
            infos.append(info)
        return state, jax.tree.map(lambda *ls: jnp.stack(ls), *infos)

    return cycle([
        SweepOp(alpha_op, name="alpha"),
        SweepOp(z_op, name="z"),
        SweepOp(w_op, name="w", has_info=True),
    ])


def _collect_summary(state: JDPMState):
    return {
        "alpha": state.alpha,
        "k_active": jnp.sum(state.stats.n > 0.5).astype(jnp.int32),
        "w": state.w,
    }


def run_posterior_sequential(
    key: jax.Array,
    data: JDPMData,
    cfg: JDPMConfig,
    num_cycles: int = 30,
    *,
    state0: JDPMState | None = None,
    collect=None,
    **cycle_kw,
):
    """Single-replica reference run of the full JDPM program in one jitted
    scan. Returns (state_final, samples, infos)."""
    from ..core.composite import run_cycle_sequential

    cyc = make_inference_cycle(data, cfg, **cycle_kw)
    if state0 is None:
        state0 = init_state(jax.random.fold_in(key, 0), data, cfg)
    return run_cycle_sequential(key, state0, cyc, num_cycles,
                                collect or _collect_summary)


def run_posterior_ensemble(
    key: jax.Array,
    data: JDPMData,
    cfg: JDPMConfig,
    num_chains: int = 4,
    num_cycles: int = 30,
    *,
    state0: JDPMState | None = None,
    collect=None,
    **cycle_kw,
):
    """K independent replicas of the JDPM program on the ensemble engine —
    ``subsampled_mh_w`` (and the alpha/z sweeps) advance all replicas inside
    one jitted program, so the dynamic-pool austerity moves of paper Table 1
    row 2 amortize exactly like the BayesLR chains do.

    Replica k seeded with per-chain key k reproduces
    :func:`run_posterior_sequential` bit for bit (given the same ``state0``).
    Returns ``(state, samples, infos, diagnostics)``; ``diagnostics`` carries
    the per-replica w-move acceptance and evaluated-fraction summaries.
    """
    from ..core import ChainEnsemble

    cyc = make_inference_cycle(data, cfg, **cycle_kw)
    ens = ChainEnsemble(num_chains=num_chains, transition=cyc,
                        collect=collect or _collect_summary)
    if state0 is None:
        # ``key`` may be a (K,) per-chain key array (the form the K=1
        # equivalence contract uses); seed the shared init from its first key.
        karr = jnp.asarray(key)
        typed = jnp.issubdtype(karr.dtype, jax.dtypes.prng_key)
        init_key = karr[0] if (karr.ndim >= 1 if typed else karr.ndim >= 2) else key
        state0 = init_state(jax.random.fold_in(init_key, 0), data, cfg)
    state, samples, infos = ens.run(key, ens.init(state0), num_cycles)
    w_info = infos["w"]
    n_k = np.maximum(np.asarray(w_info.n_k, np.float64), 1.0)
    diagnostics = {
        "w_accept_rate": np.asarray(w_info.accepted, np.float64).mean(axis=(1, 2)),
        "w_frac_evaluated": (np.asarray(w_info.n_evaluated, np.float64) / n_k).mean(),
        "k_active_final": np.asarray(samples["k_active"])[:, -1],
    }
    return state, samples, infos, diagnostics


def make_serving_workload(
    *,
    smoke: bool = False,
    num_chains: int = 4,
    n: int | None = None,
    cfg: JDPMConfig | None = None,
    batch_size: int = 100,
    epsilon: float = 0.2,
    w_moves: int | None = None,
    gibbs_frac: float = 0.25,
    seed: int = 0,
):
    """The joint DP mixture as a servable workload: the full Sec-4.2 cycle
    (alpha-MH + Gibbs-z + dynamic-pool subsampled-MH w-moves) kept resident.
    The collected draws are the *predictive sufficient state* — expert
    weights, NIW cluster statistics, and alpha — not the O(N) assignment
    vector, so the posterior window stays small. Request classes:

      * ``cluster_predictive``: p(y=+1 | x*) under the mixture-of-experts
        posterior predictive — rows are feature points,
      * ``k_active``: posterior mean number of active clusters (rows are
        dummies; a scalar functional per draw).
    """
    from ..core import ChainEnsemble
    from ..inference.niw import predictive_all_clusters
    from ..serving.resident import QuerySpec
    from ..serving.workloads import ServingWorkload, row_sampler

    n = n if n is not None else (600 if smoke else 5_000)
    cfg = cfg or JDPMConfig()
    w_moves = w_moves if w_moves is not None else (2 if smoke else 8)
    data = synth(jax.random.key(seed), n=n, n_test=max(256, n // 8))
    cyc = make_inference_cycle(
        data, cfg, batch_size=min(batch_size, n), epsilon=epsilon,
        w_moves=w_moves, gibbs_frac=gibbs_frac,
    )

    def collect_predictive(state: JDPMState):
        return {"w": state.w, "alpha": state.alpha, "stats": state.stats}

    ens = ChainEnsemble(num_chains=num_chains, transition=cyc,
                        collect=collect_predictive)
    prior = cfg.niw_prior()
    make_points = row_sampler(np.asarray(data.x_test))

    def cluster_predictive(draw, xs):
        stats, w = draw["stats"], draw["w"]
        counts = stats.n

        def one(x):
            feat = predictive_all_clusters(x, stats, prior)
            logw = jnp.where(
                counts > 0.5, jnp.log(jnp.maximum(counts, 1e-12)) + feat, -jnp.inf
            )
            resp = jax.nn.softmax(logw)
            x_aug = jnp.concatenate([x, jnp.ones((1,), x.dtype)])
            return jnp.sum(resp * jax.nn.sigmoid(w @ x_aug))

        return jax.vmap(one)(xs)

    specs = {
        "cluster_predictive": QuerySpec(
            fn=cluster_predictive,
            aggregate="mean",
            make_queries=make_points,
            name="cluster_predictive",
        ),
        "k_active": QuerySpec(
            fn=lambda draw, xs: jnp.full(
                (xs.shape[0],), jnp.sum(draw["stats"].n > 0.5).astype(jnp.float32)
            ),
            aggregate="mean",
            make_queries=make_points,
            name="k_active",
        ),
    }
    return ServingWorkload(
        name="jointdpm",
        ensemble=ens,
        theta0=init_state(jax.random.fold_in(jax.random.key(seed), 0), data, cfg),
        query_specs=specs,
        default_class="cluster_predictive",
        description=f"joint DP mixture of logistic experts, N={n}",
    )


# ---------------------------------------------------------------------------
# Posterior predictive classification
# ---------------------------------------------------------------------------


def predict_proba(state: JDPMState, x_test: jax.Array, cfg: JDPMConfig) -> jax.Array:
    """p(y=+1 | x*) under one posterior sample: mixture-weighted experts."""
    prior = cfg.niw_prior()
    counts = state.stats.n

    def one(xs):
        feat = predictive_all_clusters(xs, state.stats, prior)
        logw = jnp.where(counts > 0.5, jnp.log(jnp.maximum(counts, 1e-12)) + feat, -jnp.inf)
        resp = jax.nn.softmax(logw)
        xs_aug = jnp.concatenate([xs, jnp.ones((1,), xs.dtype)])
        p_k = jax.nn.sigmoid(state.w @ xs_aug)
        return jnp.sum(resp * p_k)

    return jax.vmap(one)(x_test)


def accuracy(prob: np.ndarray, y_test: np.ndarray) -> float:
    pred = np.where(np.asarray(prob) > 0.5, 1.0, -1.0)
    return float(np.mean(pred == np.asarray(y_test)))
