"""The paper's three applications (Sec. 4) as reusable modules."""
from . import bayeslr, jointdpm, stochvol

__all__ = ["bayeslr", "jointdpm", "stochvol"]
