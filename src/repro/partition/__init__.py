"""Data-parallel subposterior MCMC: partition observations, combine draws.

The scaling axis for datasets beyond one host (ROADMAP): split the N
observations into P disjoint shards (:mod:`repro.partition.partitioner`),
run an unmodified subsampled-MH writer fleet per shard against its local
slice under the tempered prior ``p(theta)^(1/P)``, and recombine the
per-shard posterior windows at query time in the fleet router
(:mod:`repro.partition.combine`: consensus weighted averaging or Gaussian
density-product). Statistical correctness is pinned by the conjugate
ground-truth harness in ``tests/test_subposterior.py``.
"""
from .combine import (
    METHODS,
    combine_draws,
    combine_snapshots,
    consensus_combine,
    flatten_draws,
    product_combine,
    product_moments,
    trim_windows,
    unflatten_draws,
)
from .partitioner import (
    SCHEMES,
    partition_append_indices,
    partition_indices,
    partition_spec,
    partition_target,
    take_sections,
)

__all__ = [
    "METHODS",
    "SCHEMES",
    "combine_draws",
    "combine_snapshots",
    "consensus_combine",
    "flatten_draws",
    "partition_append_indices",
    "partition_indices",
    "partition_spec",
    "partition_target",
    "product_combine",
    "product_moments",
    "take_sections",
    "trim_windows",
    "unflatten_draws",
]
