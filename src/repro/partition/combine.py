"""Draw combination: subposterior windows -> one full-posterior window.

Two combination rules over the P per-partition posterior windows the
fleet's read replicas hold (each window a pytree of (K, W, ...) draws):

  * **consensus** (Scott et al., consensus Monte Carlo): weighted
    averaging of aligned draws, ``theta_s = (sum_p W_p)^-1 sum_p W_p
    theta_{p,s}`` with matrix weights ``W_p = Sigma_hat_p^-1`` (the inverse
    subposterior sample covariance). Exact when the subposteriors are
    Gaussian — which the prior-tempered construction makes true for the
    conjugate ground-truth model, and asymptotically true in general.
  * **product** (Gaussian density-product): fit ``N(mu_p, Sigma_p)`` to
    each subposterior, form the product density
    ``Sigma = (sum_p Sigma_p^-1)^-1``, ``mu = Sigma sum_p Sigma_p^-1
    mu_p``, and draw a fresh window from it with a seeded generator
    (deterministic per version, so repeated queries against one combined
    generation are identical).

All moment math runs host-side in float64 with a deterministic reduction
order over sorted partition position — combination is invariant (to float
tolerance) under permuting the partition list, a tested contract. Flatten/
unflatten round-trips the draws pytree so combined windows keep the
(K, W, ...) shape the :class:`repro.serving.resident.SnapshotEvaluator`
consumes — the router serves combined draws through the *same* evaluator
as every other window.
"""
from __future__ import annotations

import time
import zlib
from typing import Any, Sequence

import jax
import numpy as np

from ..serving.resident import Snapshot

METHODS = ("consensus", "product")


# ---------------------------------------------------------------------------
# Flatten / unflatten
# ---------------------------------------------------------------------------


def flatten_draws(draws: Any) -> np.ndarray:
    """(K, W, ...) pytree -> (S, D) float64 matrix, S = K*W, leaves
    concatenated along the feature axis in tree order."""
    leaves = jax.tree.leaves(draws)
    if not leaves:
        raise ValueError("empty draws pytree")
    flats = []
    for leaf in leaves:
        a = np.asarray(leaf, np.float64)
        flats.append(a.reshape(a.shape[0] * a.shape[1], -1))
    return np.concatenate(flats, axis=1)


def unflatten_draws(flat: np.ndarray, template: Any) -> Any:
    """Inverse of :func:`flatten_draws`: reshape a (S, D) matrix back onto
    ``template``'s pytree structure and (K, W, ...) leaf shapes."""
    leaves, treedef = jax.tree.flatten(template)
    k, w = leaves[0].shape[:2]
    if flat.shape[0] != k * w:
        raise ValueError(
            f"flat draws rows {flat.shape[0]} != template K*W {k * w}"
        )
    out, col = [], 0
    for leaf in leaves:
        width = int(np.prod(leaf.shape[2:], dtype=np.int64)) if leaf.ndim > 2 else 1
        block = flat[:, col:col + width]
        col += width
        out.append(
            block.reshape((k, w) + tuple(leaf.shape[2:])).astype(leaf.dtype)
        )
    if col != flat.shape[1]:
        raise ValueError(f"flat draws have {flat.shape[1]} columns, used {col}")
    return jax.tree.unflatten(treedef, out)


def trim_windows(draws_list: Sequence[Any]) -> list[Any]:
    """Equalize window depth across partitions: keep each window's trailing
    (freshest) ``W_min`` draws per chain so aligned-draw combination has a
    common S. Chain counts must already agree (one fleet config)."""
    ks = {jax.tree.leaves(d)[0].shape[0] for d in draws_list}
    if len(ks) != 1:
        raise ValueError(f"partitions disagree on chain count: {sorted(ks)}")
    w_min = min(jax.tree.leaves(d)[0].shape[1] for d in draws_list)
    return [
        jax.tree.map(lambda a: a[:, -w_min:], d) for d in draws_list
    ]


# ---------------------------------------------------------------------------
# Moments and combination rules (float64, deterministic reduction order)
# ---------------------------------------------------------------------------


def _moments(flat: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    mean = flat.mean(axis=0)
    centered = flat - mean
    cov = (centered.T @ centered) / max(flat.shape[0] - 1, 1)
    return mean, np.atleast_2d(cov)


def _weight(cov: np.ndarray, ridge: float) -> np.ndarray:
    d = cov.shape[0]
    lam = ridge * max(np.trace(cov) / d, 1e-300)
    return np.linalg.inv(cov + lam * np.eye(d))


def consensus_combine(
    flats: Sequence[np.ndarray], ridge: float = 1e-9
) -> np.ndarray:
    """Weighted-average aligned draws: ``(sum W_p)^-1 sum W_p theta_{p,s}``
    with ``W_p`` the (ridge-regularized) inverse subposterior covariance.
    All inputs must share (S, D); returns the combined (S, D) draws."""
    if len({f.shape for f in flats}) != 1:
        raise ValueError(
            f"consensus needs aligned draw matrices, got {[f.shape for f in flats]}"
        )
    weights = [_weight(_moments(f)[1], ridge) for f in flats]
    w_sum = np.sum(weights, axis=0)
    weighted = np.sum([w @ f.T for w, f in zip(weights, flats)], axis=0)
    return np.linalg.solve(w_sum, weighted).T


def product_moments(
    flats: Sequence[np.ndarray], ridge: float = 1e-9
) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian density-product mean/cov from per-partition moments:
    ``Sigma = (sum_p Sigma_p^-1)^-1``, ``mu = Sigma sum_p Sigma_p^-1 mu_p``."""
    precisions, weighted_means = [], []
    for f in flats:
        mean, cov = _moments(f)
        w = _weight(cov, ridge)
        precisions.append(w)
        weighted_means.append(w @ mean)
    precision = np.sum(precisions, axis=0)
    cov = np.linalg.inv(precision)
    mean = cov @ np.sum(weighted_means, axis=0)
    return mean, cov


def product_combine(
    flats: Sequence[np.ndarray],
    num_samples: int,
    seed: int = 0,
    ridge: float = 1e-9,
) -> np.ndarray:
    """Draw ``num_samples`` iid samples from the density-product Gaussian
    with a seeded generator (deterministic for a given seed)."""
    mean, cov = product_moments(flats, ridge)
    chol = np.linalg.cholesky(cov + 1e-300 * np.eye(cov.shape[0]))
    z = np.random.default_rng(int(seed) & 0xFFFFFFFF).standard_normal(
        (num_samples, mean.shape[0])
    )
    return mean[None, :] + z @ chol.T


# ---------------------------------------------------------------------------
# Window-level entry points (what the fleet router calls)
# ---------------------------------------------------------------------------


def combine_draws(
    draws_list: Sequence[Any],
    method: str = "consensus",
    *,
    seed: int = 0,
    ridge: float = 1e-9,
) -> Any:
    """Combine P per-partition windows into one full-posterior window with
    the same pytree structure and (K, W_min, ...) leaf shapes."""
    if method not in METHODS:
        raise ValueError(f"unknown combine method {method!r}; known: {METHODS}")
    draws_list = list(draws_list)
    if not draws_list:
        raise ValueError("no partition windows to combine")
    if len(draws_list) == 1:
        return draws_list[0]
    trimmed = trim_windows(draws_list)
    flats = [flatten_draws(d) for d in trimmed]
    if method == "consensus":
        combined = consensus_combine(flats, ridge)
    else:
        combined = product_combine(flats, flats[0].shape[0], seed, ridge)
    return unflatten_draws(combined, trimmed[0])


def combine_snapshots(
    snaps: Sequence[Snapshot], method: str = "consensus", *, ridge: float = 1e-9
) -> Snapshot:
    """One servable :class:`Snapshot` from P per-partition snapshots.

    ``steps_done`` is the sum of partition versions (strictly increasing
    whenever any partition advances — the combined generation key), and
    ``staleness_s`` is the *max* over partitions: a combined window is only
    as fresh as its stalest input. The product rule's sampling seed derives
    from the version tuple, so a combined generation is deterministic.
    """
    snaps = list(snaps)
    if any(s.draws is None for s in snaps):
        missing = [i for i, s in enumerate(snaps) if s.draws is None]
        raise RuntimeError(f"partition(s) {missing} have no window yet")
    seed = zlib.crc32(
        np.asarray([s.steps_done for s in snaps], np.int64).tobytes()
    )
    combined = combine_draws(
        [s.draws for s in snaps], method, seed=seed, ridge=ridge
    )
    lead = jax.tree.leaves(combined)[0].shape
    return Snapshot(
        draws=combined,
        num_draws=int(lead[0] * lead[1]),
        steps_done=int(sum(s.steps_done for s in snaps)),
        staleness_s=max(s.staleness_s for s in snaps),
        summary={"combine": {"method": method, "partitions": len(snaps)}},
        created_at=time.monotonic(),
    )
