"""Dataset partitioner: observation shards for subposterior writer fleets.

The embarrassingly-parallel regime from "Patterns of Scalable Bayesian
Inference" (Angelino et al.; Scott et al. consensus Monte Carlo): split the
N observations into P disjoint shards, give each shard to an *unmodified*
subsampled-MH worker whose target is the local data slice under the
tempered prior ``p(theta)^(1/P)``, and recombine draws at query time
(:mod:`repro.partition.combine`). The product of the P subposteriors

    p_p(theta) ∝ p(theta)^(1/P) · prod_{i in shard p} p(x_i | theta)

is exactly the full posterior, which is what makes recombination sound.

Partitioning is *structural*: it operates on the
:class:`repro.core.target_builder.TargetSpec` recipe a builder-constructed
target carries, slices the section-pool arrays along axis 0, and re-runs
the builder — so every registered kernel family (logit, gaussian_ar1, ce,
gaussian_mean) partitions without any per-workload code, and the per-shard
targets keep their fused ensemble kernels.

``partition_target(target, 1)`` returns ``[target]`` — the *same object*,
no tempering wrapper, no index round-trip — so the P=1 fleet configuration
stays bit-for-bit identical to the unpartitioned path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from ..core.target import PartitionedTarget
from ..core.target_builder import TargetSpec, build_from_spec, spec_of

SCHEMES = ("stride", "block")


def partition_indices(
    n: int, num_partitions: int, scheme: str = "stride"
) -> list[np.ndarray]:
    """Disjoint index shards covering ``range(n)`` exactly.

    ``stride``: observation i goes to shard ``i % P`` — balanced to within
    one row, and stable under streaming growth (appending rows N..N+k-1
    *appends* to each shard's slice instead of reshuffling it — the
    property the fleet's streaming fold-in rides on).
    ``block``: contiguous ``ceil(n/P)``-row blocks (locality-preserving for
    time-ordered pools).
    """
    if num_partitions < 1:
        raise ValueError(f"num_partitions must be >= 1, got {num_partitions}")
    if n < num_partitions:
        raise ValueError(
            f"cannot split {n} sections into {num_partitions} non-empty shards"
        )
    if scheme == "stride":
        return [
            np.arange(p, n, num_partitions, dtype=np.int64)
            for p in range(num_partitions)
        ]
    if scheme == "block":
        return [
            np.asarray(block, dtype=np.int64)
            for block in np.array_split(np.arange(n, dtype=np.int64), num_partitions)
        ]
    raise ValueError(f"unknown partition scheme {scheme!r}; known: {SCHEMES}")


def partition_append_indices(
    n_before: int, n_new: int, num_partitions: int, scheme: str = "stride"
) -> list[np.ndarray]:
    """Which rows of a freshly appended chunk land on which shard.

    Returns P index arrays *into the new chunk* (0..n_new-1) such that
    appending chunk[idx_p] to shard p reproduces ``partition_indices``
    applied to the concatenated pool — the invariant that lets a running
    partitioned fleet fold streamed observations in without repartitioning
    (stride only; block partitions are not append-stable).
    """
    if scheme != "stride":
        raise ValueError(
            f"streaming append requires the 'stride' scheme, got {scheme!r}"
        )
    if num_partitions < 1:
        raise ValueError(f"num_partitions must be >= 1, got {num_partitions}")
    offsets = np.arange(n_new, dtype=np.int64) + int(n_before)
    return [
        np.nonzero(offsets % num_partitions == p)[0].astype(np.int64)
        for p in range(num_partitions)
    ]


def take_sections(data: Any, idx: np.ndarray) -> Any:
    """Slice every leaf of a section pool along axis 0."""
    return jax.tree.map(lambda a: a[np.asarray(idx)], data)


def partition_spec(
    spec: TargetSpec, num_partitions: int, scheme: str = "stride"
) -> list[TargetSpec]:
    """P per-shard specs: sliced data + prior tempered by a further 1/P."""
    parts = partition_indices(spec.num_sections, num_partitions, scheme)
    return [
        dataclasses.replace(
            spec,
            data=take_sections(spec.data, idx),
            num_sections=int(idx.shape[0]),
            prior_scale=spec.prior_scale / num_partitions,
        )
        for idx in parts
    ]


def partition_target(
    target: PartitionedTarget, num_partitions: int, scheme: str = "stride"
) -> list[PartitionedTarget]:
    """P independent subposterior targets for one builder-constructed
    target (see module docstring). P=1 returns ``[target]`` unchanged."""
    if num_partitions == 1:
        return [target]
    return [
        build_from_spec(s)
        for s in partition_spec(spec_of(target), num_partitions, scheme)
    ]
