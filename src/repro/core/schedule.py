"""Adaptive per-chain scheduling for the subsampled-MH ensemble.

The sequential test (Alg. 2) makes each transition's cost data dependent:
an easy accept/reject decision stops after one mini-batch, a hard one burns
the whole pool. Two static knobs govern that trade — ``batch_size`` (sections
per round) and ``epsilon`` (the test's p-value tolerance) — and the right
setting differs per chain and drifts as chains move through the posterior.

This module closes the ROADMAP's "async / adaptive chain scheduling" item
with a jittable per-chain controller in the spirit of the adaptive
subsampling patterns surveyed by Angelino et al. (*Patterns of Scalable
Bayesian Inference*): after every completed transition it folds that
transition's ``rounds`` / ``n_evaluated`` / ``accepted`` into trailing EMAs
and re-tunes

  * ``batch_size`` within a **compile-time bucket set**: chains whose tests
    run long (rounds EMA above ``rounds_high``) step up to a bigger bucket so
    they finish in fewer rounds and stop stalling the vmapped row; chains
    that decide in ~one round step back down, touching less data per
    transition (the paper's measured sublinearity metric). Buckets are
    static, so the program is compiled once; the *effective* batch is a
    traced per-chain value applied through the bounded draws in
    :mod:`repro.core.samplers`.
  * ``epsilon`` within ``[epsilon floor, epsilon_max]``: a chain that keeps
    exhausting its pool (the decision is statistically hard, so the exact
    fallback is doing O(N) work anyway) relaxes its tolerance multiplicatively
    to stop earlier; easy chains decay back to the floor — the configured
    ``SubsampledMHConfig.epsilon`` — restoring the user's accuracy target.
  * optionally (``adapt_proposal=True``, default off) the **proposal
    sigma**: ``sigma_scale`` moves multiplicatively toward the target
    acceptance rate from the trailing acceptance EMA, clamped to
    ``[scale_min, scale_max]``, and is threaded into the proposal's
    ``scale`` argument by the ensemble. With the flag off nothing is
    threaded and runs are bit-for-bit the unscaled engine.

Everything is a scalar-per-chain pytree (:class:`ControllerState`) threaded
through :func:`repro.core.subsampled_mh.subsampled_mh_step` by
:class:`repro.core.ensemble.ChainEnsemble`, in both the lock-step and the
masked-continuation stepping modes. The controller is pure and jittable, so
it composes with ``vmap``/``scan``/``while_loop`` like every other kernel in
this package (the composable-kernel discipline of Handa et al.).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ControllerState(NamedTuple):
    """Per-chain adaptation state; every field is a scalar (or, in ensemble
    use, a (K,)-leaved pytree). ``bucket`` indexes the static bucket tuple,
    ``epsilon`` is the chain's current tolerance, the ``ema_*`` fields are
    trailing averages of the last transitions' test statistics."""

    bucket: jax.Array  # int32 index into the static batch-bucket tuple
    epsilon: jax.Array  # f32 current per-chain tolerance
    ema_rounds: jax.Array  # f32 trailing mean of rounds per transition
    ema_frac: jax.Array  # f32 trailing mean of n_evaluated / N
    ema_accept: jax.Array  # f32 trailing acceptance rate
    t: jax.Array  # int32 transitions folded in so far
    sigma_scale: jax.Array = None  # f32 proposal-sigma multiplier (1.0 = base)


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Static controller configuration (hashable; safe to close over in jit).

    ``batch_buckets``: the compile-time set of candidate batch sizes. When
    ``None`` it is derived from the kernel's base ``batch_size`` as
    ``{m//2, m, 2m, 4m}`` clipped to ``[1, N]`` (see :func:`buckets_for`).

    ``epsilon_min``: the tolerance floor. ``None`` means "the base
    ``SubsampledMHConfig.epsilon``" — adaptation may temporarily *relax* the
    test on hard chains but never makes it stricter than requested, and easy
    chains always decay back to the floor.

    Example::

        >>> sched = ScheduleConfig(epsilon_max=0.2)
        >>> from repro.core import SubsampledMHConfig
        >>> sched.buckets_for(SubsampledMHConfig(batch_size=100), num_sections=5000)
        (50, 100, 200, 400)
    """

    batch_buckets: tuple[int, ...] | None = None
    epsilon_max: float = 0.2
    epsilon_min: float | None = None  # None -> base config epsilon (the floor)
    adapt_batch_size: bool = True
    adapt_epsilon: bool = True
    ema_halflife: float = 8.0  # transitions until a stat's weight halves
    rounds_high: float = 3.0  # rounds EMA above this -> bigger bucket
    rounds_low: float = 1.25  # rounds EMA below this -> smaller bucket
    exhaust_frac: float = 0.9  # n_evaluated/N above this -> relax epsilon
    epsilon_grow: float = 1.25
    epsilon_decay: float = 0.97
    # -- adaptive proposals (ROADMAP item): drive per-chain proposal sigma
    # from the trailing acceptance rate toward ``accept_target``. Off by
    # default; with the flag off the controller is bit-for-bit the
    # pre-adaptive-proposal controller and no scale is threaded into the
    # proposal (regression-tested in tests/test_schedule.py).
    adapt_proposal: bool = False
    accept_target: float = 0.234  # classic RW-MH optimal acceptance
    proposal_gain: float = 0.33  # log-scale gain per transition
    scale_min: float = 0.1  # sigma_scale clamp (multiples of base sigma)
    scale_max: float = 10.0
    # ``adapt_gain_decay`` puts the sigma adaptation on a Robbins–Monro
    # diminishing-gain schedule: transition t uses an effective gain of
    # ``proposal_gain * (1 + t) ** -adapt_gain_decay``. At the default 0.0
    # the gain is constant and the update is bit-for-bit the constant-gain
    # controller (adaptation then never stops, so the flag-on chain targets
    # the posterior only approximately). Any value in (0.5, 1.0] satisfies
    # the Robbins–Monro conditions (sum of gains diverges, sum of squared
    # gains converges), so adaptation vanishes asymptotically and the
    # flag-on chain recovers the correct stationary target.
    adapt_gain_decay: float = 0.0

    def __post_init__(self):
        if self.batch_buckets is not None:
            b = tuple(sorted(set(int(x) for x in self.batch_buckets)))
            if not b or b[0] < 1:
                raise ValueError(f"batch_buckets must be positive ints, got {self.batch_buckets}")
            object.__setattr__(self, "batch_buckets", b)
        if not 0.0 < self.epsilon_decay <= 1.0 or self.epsilon_grow < 1.0:
            raise ValueError("need 0 < epsilon_decay <= 1 <= epsilon_grow")
        if not 0.0 < self.scale_min <= 1.0 <= self.scale_max:
            raise ValueError("need 0 < scale_min <= 1 <= scale_max")
        if not 0.0 < self.accept_target < 1.0:
            raise ValueError(f"accept_target must be in (0, 1), got {self.accept_target}")
        if not 0.0 <= self.adapt_gain_decay <= 1.0:
            raise ValueError(
                f"adapt_gain_decay must be in [0, 1], got {self.adapt_gain_decay}"
            )

    def buckets_for(self, config, num_sections: int | None = None) -> tuple[int, ...]:
        """The sorted static bucket tuple for a given kernel config."""
        if self.batch_buckets is not None:
            buckets = self.batch_buckets
        else:
            m = config.batch_size
            buckets = tuple(sorted({max(1, m // 2), m, 2 * m, 4 * m}))
        if num_sections is not None:
            buckets = tuple(sorted({min(b, num_sections) for b in buckets}))
        return buckets

    def epsilon_floor(self, config) -> float:
        eps = config.epsilon if self.epsilon_min is None else self.epsilon_min
        return float(min(eps, self.epsilon_max))


def controller_init(
    sched: ScheduleConfig,
    config,
    num_sections: int,
    num_chains: int | None = None,
) -> ControllerState:
    """Initial controller state: base bucket, floor epsilon, neutral EMAs.

    With ``num_chains`` given, every field carries a leading (K,) axis so the
    state vmaps/shards exactly like the sampler state.
    """
    buckets = sched.buckets_for(config, num_sections)
    base = min(range(len(buckets)), key=lambda i: abs(buckets[i] - config.batch_size))
    st = ControllerState(
        bucket=jnp.asarray(base, jnp.int32),
        epsilon=jnp.asarray(sched.epsilon_floor(config), jnp.float32),
        ema_rounds=jnp.ones((), jnp.float32),
        ema_frac=jnp.asarray(min(config.batch_size / max(num_sections, 1), 1.0), jnp.float32),
        ema_accept=jnp.asarray(0.5, jnp.float32),
        t=jnp.zeros((), jnp.int32),
        sigma_scale=jnp.ones((), jnp.float32),
    )
    if num_chains is None:
        return st
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (num_chains,) + l.shape), st)


def controller_params(
    state: ControllerState, buckets: tuple[int, ...]
) -> tuple[jax.Array, jax.Array]:
    """The knobs a transition should run with: (epsilon f32, batch_eff i32).

    ``buckets`` is the static tuple the ``bucket`` index points into; the
    returned effective batch size is a traced value <= max(buckets).
    """
    arr = jnp.asarray(buckets, jnp.int32)
    return state.epsilon, arr[jnp.clip(state.bucket, 0, len(buckets) - 1)]


def controller_update(
    state: ControllerState,
    info,
    sched: ScheduleConfig,
    buckets: tuple[int, ...],
    num_sections: int,
    epsilon_floor: float,
) -> ControllerState:
    """Fold one completed transition's info into the controller (jittable).

    ``info`` needs ``rounds``, ``n_evaluated`` and ``accepted`` fields —
    scalar entries of :class:`repro.core.subsampled_mh.SubsampledMHInfo`.
    Bucket moves are hysteretic (one step per transition, driven by the
    rounds EMA); epsilon moves multiplicatively, clamped to
    ``[epsilon_floor, epsilon_max]``.
    """
    decay = jnp.float32(2.0 ** (-1.0 / max(sched.ema_halflife, 1e-6)))
    mix = lambda old, new: decay * old + (1.0 - decay) * jnp.asarray(new, jnp.float32)
    ema_rounds = mix(state.ema_rounds, info.rounds)
    ema_frac = mix(state.ema_frac, info.n_evaluated / jnp.float32(max(num_sections, 1)))
    ema_accept = mix(state.ema_accept, info.accepted)

    up = ema_rounds > sched.rounds_high
    down = (ema_rounds < sched.rounds_low) & ~up
    bucket = jnp.clip(
        state.bucket + up.astype(jnp.int32) - down.astype(jnp.int32), 0, len(buckets) - 1
    )
    if not sched.adapt_batch_size:
        bucket = state.bucket

    hard = info.n_evaluated >= sched.exhaust_frac * num_sections
    eps = jnp.where(
        hard, state.epsilon * sched.epsilon_grow, state.epsilon * sched.epsilon_decay
    )
    eps = jnp.clip(eps, jnp.float32(epsilon_floor), jnp.float32(sched.epsilon_max))
    if not sched.adapt_epsilon:
        eps = state.epsilon

    sigma_scale = state.sigma_scale
    if sched.adapt_proposal:
        # Multiplicative move of log(sigma) toward the target acceptance
        # rate, driven by the trailing acceptance EMA. The Python branch on
        # adapt_gain_decay keeps the default bit-for-bit the constant-gain
        # controller; with decay > 0 the gain follows the Robbins–Monro
        # schedule gain * (1 + t)^-decay, so adaptation dies out and the
        # chain's stationary target is asymptotically exact.
        gain = jnp.float32(sched.proposal_gain)
        if sched.adapt_gain_decay:
            gain = gain * (1.0 + state.t.astype(jnp.float32)) ** jnp.float32(
                -sched.adapt_gain_decay
            )
        sigma_scale = sigma_scale * jnp.exp(
            gain * (ema_accept - sched.accept_target)
        )
        sigma_scale = jnp.clip(
            sigma_scale, jnp.float32(sched.scale_min), jnp.float32(sched.scale_max)
        )

    return ControllerState(
        bucket=bucket,
        epsilon=eps,
        ema_rounds=ema_rounds,
        ema_frac=ema_frac,
        ema_accept=ema_accept,
        t=state.t + 1,
        sigma_scale=sigma_scale,
    )
