"""Partitioned targets: the TPU-tensor form of a partitioned scaffold.

A ``PartitionedTarget`` is what the ppl/ layer emits after computing the
scaffold s(rho, v) for a global variable v and partitioning it into the
*global* section plus N structurally-identical *local* sections (paper
Defs. 6–8). The MH kernels in this package consume only this interface:

  log_global(theta, theta_prime) -> scalar
      sum over the global section of log w_n, i.e.
      log p_global(theta') - log p_global(theta). Proposal corrections are
      handled by the Proposal object, not here.

  log_local(theta, theta_prime, idx) -> (m,)
      l_i for the requested local sections: the per-section log-weight
      products sum_{n in local_i} log w_n. For symmetric proposals over a
      Bayesian-network-shaped scaffold this is
      log p(x_{local_i} | theta') - log p(x_{local_i} | theta).

  num_sections
      N, the number of children of the border node b(s, v).

The callables must be jit-traceable. ``theta`` is an arbitrary pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

Params = Any


@dataclasses.dataclass(frozen=True)
class PartitionedTarget:
    num_sections: int
    log_global: Callable[[Params, Params], jax.Array]
    log_local: Callable[[Params, Params, jax.Array], jax.Array]
    # Optional: full-posterior log density (global part + all sections), used
    # by diagnostics and by gradient-informed proposals. May be None.
    log_density: Callable[[Params], jax.Array] | None = None
    # Optional ensemble-fused local evaluation: (theta, theta', idx) with a
    # leading (K,) chain axis on every argument -> (K, m) deltas, backed by a
    # fused kernel (e.g. repro.kernels.ops.batched_logit_delta). When set and
    # the ops dispatch selects Pallas, ChainEnsemble routes each sequential-
    # test round through it instead of vmapping ``log_local``.
    log_local_ensemble: Callable[[Params, Params, jax.Array], jax.Array] | None = None
    # Name of the registered kernel family (repro.core.target_builder) that
    # built log_local / log_local_ensemble, or None for hand-wired targets.
    family: str | None = None
    # Optional construction recipe (repro.core.target_builder.TargetSpec):
    # the family name, the section-pool data arrays, and the (possibly
    # tempered) prior the builder assembled this target from. Carrying the
    # recipe is what makes targets *re-buildable* — the dataset partitioner
    # (repro.partition) slices the pool per subposterior worker, and the
    # streaming append path concatenates new observations — without any
    # per-workload code. None for hand-wired or latent-dependent targets.
    spec: Any | None = None


def from_iid_loglik(
    prior_logpdf: Callable[[Params], jax.Array],
    loglik_fn: Callable[[Params, jax.Array], jax.Array],
    data: Any,
    num_sections: int,
) -> PartitionedTarget:
    """Convenience constructor for the BayesLR-shaped scaffold (Table 1 row 1):
    theta ~ prior, sections are iid observations.

    ``loglik_fn(theta, idx) -> (m,)`` per-observation log-likelihoods; ``data``
    is closed over by loglik_fn's caller — kept here only for documentation.
    """
    del data

    def log_global(theta, theta_p):
        return prior_logpdf(theta_p) - prior_logpdf(theta)

    def log_local(theta, theta_p, idx):
        return loglik_fn(theta_p, idx) - loglik_fn(theta, idx)

    def log_density(theta):
        import jax.numpy as jnp

        idx = jnp.arange(num_sections, dtype=jnp.int32)
        return prior_logpdf(theta) + loglik_fn(theta, idx).sum()

    return PartitionedTarget(num_sections, log_global, log_local, log_density)
