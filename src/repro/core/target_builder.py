"""Target construction layer: one kernel-family registry for every workload.

The paper's central claim is that *one* implementation of edge-subsampled MH
serves all three applications (Sec. 4). This module is where that claim
lives at the tensor level: a target declares its local-likelihood *family*
— the shape of its per-section factor — and the builder attaches

  * ``log_local``          the (m,) pair-delta used by single chains,
  * ``log_local_ensemble`` the (K, m) multi-chain round, backed by the
                           matching fused kernel in :mod:`repro.kernels.ops`
                           (Pallas on TPU, interpret/ref twin elsewhere),
  * ``log_density``        prior + full local sum, for diagnostics,

so BayesLR, the joint DP mixture's expert weights, the stochastic-volatility
parameter moves, and PPL-compiled programs all ride the same construction
path instead of hand-wiring their kernel hookups.

Registered families:

  ``logit``         Logit(y | x·w) observation factors (BayesLR, DPM experts)
                    data = (x (N, D), y (N,)), params = w
  ``gaussian_ar1``  N(x_t | phi x_{t-1}, sigma^2) transition factors
                    (stochastic volatility), data = (x_t, x_prev) each (N,),
                    params = (phi, sigma2)
  ``ce``            softmax cross-entropy token factors (the LM likelihood),
                    data = (h (N, D), targets (N,)), params = table (V, D)

``data`` may also be a callable ``theta -> data`` for sections that are
functions of latent state (stochvol's transition factors depend on the
current particle-Gibbs paths ``theta["h"]``); it must only read leaves the
MH proposal does not move, since both sides of the delta share it. In the
ensemble forms every params leaf carries a leading (K,) chain axis and the
data pools may be shared ``(N, ...)`` or per-chain ``(K, N, ...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..distributed.sharding import lc
from .target import PartitionedTarget

Params = Any

_LOG2PI = 1.8378770664093453


def _gather(arr: jax.Array, idx: jax.Array, section_ndim: int) -> jax.Array:
    """Gather sections: shared pool (N, ...) with any idx shape, or per-chain
    pool (K, N, ...) with (K, m) idx."""
    if arr.ndim == section_ndim + 1:
        return arr[idx]
    return jax.vmap(lambda a, i: a[i])(arr, idx)


def _gather_sharded(arr: jax.Array, idx: jax.Array, section_ndim: int) -> jax.Array:
    """Ensemble-round gather with the chains x data sharding constraint: the
    (K, m, ...) block is split over the mesh data axis (when a 2-d ensemble
    mesh is active — see :mod:`repro.distributed.sharding`; a no-op
    otherwise), so each device materializes and scores only its slice of the
    drawn sections."""
    out = _gather(arr, idx, section_ndim)
    logical = ("ensemble_chains", "subsample") + (None,) * (out.ndim - 2)
    return lc(out, logical)


def _shard_round_idx(idx: jax.Array) -> jax.Array:
    return lc(idx, ("ensemble_chains", "subsample"))


def _replicate_round(out: jax.Array) -> jax.Array:
    # Re-replicate the (K, m) deltas along m before they reach the Welford
    # reduction — keeps sharded and unsharded reduction order identical
    # (the bit-for-bit contract of the 2-d ensemble mesh).
    return lc(out, ("ensemble_chains", None))


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    """The re-buildable recipe behind a builder-constructed target.

    Everything :func:`build_target` needs to assemble the target again:
    the family name, the section-pool arrays (axis 0 = sections), the
    prior, and ``prior_scale`` — the tempering exponent on the prior,
    ``p(theta)^(1/P)`` for a P-way subposterior partition (Scott et al.
    consensus Monte Carlo; "Patterns of Scalable Bayesian Inference"), so
    the product of the P subposteriors is the full posterior.

    A spec is only attached when ``data`` is concrete arrays (latent-
    dependent callable pools cannot be sliced/appended structurally).
    """

    family: str
    data: Any  # pytree of (N, ...) arrays, sections along axis 0
    num_sections: int
    prior_logpdf: Callable[[Params], jax.Array]
    params_fn: Callable[[Params], Any] | None = None
    prior_scale: float = 1.0


def spec_of(target: PartitionedTarget) -> TargetSpec:
    """The target's construction recipe, or a clear error for targets that
    were hand-wired (no family / callable data / explicit log_global)."""
    if target.spec is None:
        raise ValueError(
            "target carries no TargetSpec (hand-wired log_global/log_local, "
            "callable data, or family=None) — partitioning and streaming "
            "append need a build_target(...) construction with concrete "
            "data arrays and prior_logpdf"
        )
    return target.spec


def build_from_spec(spec: TargetSpec) -> PartitionedTarget:
    """Re-run the builder on a (possibly sliced/appended/tempered) spec."""
    return build_target(
        spec.family,
        spec.data,
        spec.num_sections,
        prior_logpdf=spec.prior_logpdf,
        params_fn=spec.params_fn,
        prior_scale=spec.prior_scale,
    )


def _section_count(data: Any) -> int:
    leaves = jax.tree.leaves(data)
    if not leaves:
        return 0
    counts = {int(leaf.shape[0]) for leaf in leaves}
    if len(counts) != 1:
        raise ValueError(
            f"data leaves disagree on the section axis: {sorted(counts)}"
        )
    return counts.pop()


def append_observations(target: PartitionedTarget, new_data: Any) -> PartitionedTarget:
    """A new target whose section pool is ``concat([old, new], axis=0)``.

    The streaming append-only primitive: scoring functions are rebuilt by
    the same builder path, so the result is *identical* to building the
    target on the concatenated data from scratch (regression-tested
    property). An empty append (zero new sections) returns ``target``
    itself — a bit-for-bit no-op.
    """
    spec = spec_of(target)
    n_new = _section_count(new_data)
    if n_new == 0:
        return target
    old_leaves = jax.tree.structure(spec.data)
    new_leaves = jax.tree.structure(new_data)
    if old_leaves != new_leaves:
        raise ValueError(
            f"appended data structure {new_leaves} != target data "
            f"structure {old_leaves}"
        )
    def cat(a, b):
        a, b = jnp.asarray(a), jnp.asarray(b)
        if a.shape[1:] != b.shape[1:]:
            raise ValueError(
                f"appended section shape {b.shape[1:]} != existing "
                f"{a.shape[1:]}"
            )
        return jnp.concatenate([a, b.astype(a.dtype)], axis=0)

    merged = jax.tree.map(cat, spec.data, new_data)
    return build_from_spec(
        dataclasses.replace(
            spec, data=merged, num_sections=spec.num_sections + n_new
        )
    )


@dataclasses.dataclass(frozen=True)
class KernelFamily:
    """A local-likelihood family: reference scoring + fused ensemble delta.

    ``loglik(data, params, idx) -> (m,)`` per-section log-likelihoods,
    ``delta(data, params, params_p, idx) -> (m,)`` the pair-delta a single
    chain's sequential-test round evaluates, and
    ``ensemble_delta(data, params, params_p, idx) -> (K, m)`` the multi-chain
    round routed through the :mod:`repro.kernels.ops` dispatch.
    """

    name: str
    loglik: Callable[[Any, Any, jax.Array], jax.Array]
    delta: Callable[[Any, Any, Any, jax.Array], jax.Array]
    ensemble_delta: Callable[[Any, Any, Any, jax.Array], jax.Array]


_FAMILIES: dict[str, KernelFamily] = {}


def register_family(family: KernelFamily) -> KernelFamily:
    """Add a family to the registry (overwrites an existing name)."""
    _FAMILIES[family.name] = family
    return family


def get_family(name: str) -> KernelFamily:
    if name not in _FAMILIES:
        raise KeyError(
            f"unknown kernel family {name!r}; registered: {sorted(_FAMILIES)}"
        )
    return _FAMILIES[name]


def registered_families() -> tuple[str, ...]:
    return tuple(sorted(_FAMILIES))


# ---------------------------------------------------------------------------
# Built-in families
# ---------------------------------------------------------------------------


def _logit_loglik(data, w, idx):
    from ..kernels import ref

    x, y = data
    return ref.logit_loglik(w, _gather(x, idx, 1), _gather(y, idx, 0))


def _logit_delta(data, w, w_p, idx):
    from ..kernels import ref

    x, y = data
    return ref.logit_delta_ref(_gather(x, idx, 1), _gather(y, idx, 0), w, w_p)


def _logit_ensemble_delta(data, w, w_p, idx):
    from ..kernels import ops

    x, y = data
    idx = _shard_round_idx(idx)
    return _replicate_round(ops.batched_logit_delta(
        _gather_sharded(x, idx, 1), _gather_sharded(y, idx, 0), w, w_p
    ))


def _ar1_loglik(data, params, idx):
    phi, s2 = params
    xt, xp = (_gather(a, idx, 0) for a in data)
    s2c = jnp.clip(s2, 1e-12, None)
    z2 = (xt - phi * xp) ** 2 / s2c
    return -0.5 * (z2 + jnp.log(s2c) + _LOG2PI)


def _ar1_delta(data, params, params_p, idx):
    from ..kernels import ref

    xt, xp = (_gather(a, idx, 0) for a in data)
    return ref.gaussian_ar1_delta_ref(xt, xp, *params, *params_p)


def _ar1_ensemble_delta(data, params, params_p, idx):
    from ..kernels import ops

    idx = _shard_round_idx(idx)
    xt, xp = (_gather_sharded(a, idx, 0) for a in data)
    return _replicate_round(ops.batched_gaussian_ar1_delta(xt, xp, *params, *params_p))


def _ce_loglik(data, table, idx):
    from ..kernels import ops

    h, targets = data
    return ops.fused_ce(_gather(h, idx, 1), table, _gather(targets, idx, 0))


def _ce_delta(data, table, table_p, idx):
    return _ce_loglik(data, table_p, idx) - _ce_loglik(data, table, idx)


def _ce_ensemble_delta(data, table, table_p, idx):
    # Two kernel passes, not a pair-fused one: unlike the logit pair (one
    # matmul against a stacked (D, 2) weight pair), the CE sides score
    # against two *different* vocab tables, so both table streams are
    # irreducible — pair fusion would only share the (m, D) activation reads
    # and one launch, a second-order saving at V >> D. The gathers are hoisted
    # so they happen once for both sides.
    from ..kernels import ops

    h, targets = data
    idx = _shard_round_idx(idx)
    hg, tg = _gather_sharded(h, idx, 1), _gather_sharded(targets, idx, 0)
    return _replicate_round(
        ops.batched_fused_ce(hg, table_p, tg) - ops.batched_fused_ce(hg, table, tg)
    )


def _gm_loglik(data, theta, idx):
    xg = _gather(data, idx, 1)  # (m, D) or (K, m, D)
    return -0.5 * jnp.sum((xg - theta[..., None, :]) ** 2, axis=-1)


def _gm_delta(data, theta, theta_p, idx):
    xg = _gather(data, idx, 1)
    return 0.5 * (
        jnp.sum((xg - theta[..., None, :]) ** 2, axis=-1)
        - jnp.sum((xg - theta_p[..., None, :]) ** 2, axis=-1)
    )


def _gm_ensemble_delta(data, theta, theta_p, idx):
    idx = _shard_round_idx(idx)
    xg = _gather_sharded(data, idx, 1)  # (K, m, D)
    out = 0.5 * (
        jnp.sum((xg - theta[:, None, :]) ** 2, axis=-1)
        - jnp.sum((xg - theta_p[:, None, :]) ** 2, axis=-1)
    )
    return _replicate_round(out)


register_family(KernelFamily("logit", _logit_loglik, _logit_delta, _logit_ensemble_delta))
register_family(KernelFamily("gaussian_ar1", _ar1_loglik, _ar1_delta, _ar1_ensemble_delta))
register_family(KernelFamily("ce", _ce_loglik, _ce_delta, _ce_ensemble_delta))
# Unit-variance Gaussian mean model: data = x (N, D), params = theta (D,),
# per-section factor N(x_i | theta, I) up to the additive constant (only
# deltas and relative densities matter to MH and the diagnostics). This is
# the conjugate family the subposterior ground-truth harness runs on: prior
# N(0, I) gives the closed-form posterior N(n xbar / (n+1), I / (n+1)).
register_family(KernelFamily("gaussian_mean", _gm_loglik, _gm_delta, _gm_ensemble_delta))


# ---------------------------------------------------------------------------
# The builder
# ---------------------------------------------------------------------------


def build_target(
    family: str | None,
    data: Any = None,
    num_sections: int | None = None,
    *,
    prior_logpdf: Callable[[Params], jax.Array] | None = None,
    log_global: Callable[[Params, Params], jax.Array] | None = None,
    log_local: Callable[[Params, Params, jax.Array], jax.Array] | None = None,
    log_density: Callable[[Params], jax.Array] | None = None,
    params_fn: Callable[[Params], Any] | None = None,
    prior_scale: float = 1.0,
) -> PartitionedTarget:
    """Construct a :class:`~repro.core.target.PartitionedTarget` from a
    registered kernel family.

    ``data`` is the family's section pool (arrays, or ``theta -> arrays`` for
    latent-dependent sections); ``params_fn`` maps the chain's ``theta`` to
    the family's canonical parameters (default: identity). The global section
    comes from ``prior_logpdf`` (pairs are differenced) or an explicit
    ``log_global``. With ``family=None`` an explicit ``log_local`` is
    required and no ensemble evaluation is attached — the pass-through for
    targets whose local score matches no registered family.

    ``prior_scale`` tempers the prior to ``prior_scale * log p(theta)`` —
    the ``p(theta)^(1/P)`` subposterior construction, where each of P
    data-partition workers carries 1/P of the prior mass so the product of
    the P subposteriors is the full posterior (:mod:`repro.partition`).
    With ``prior_scale == 1.0`` (the default) the built closures are
    *exactly* the untempered ones — no wrapper — which is what keeps the
    P=1 fleet configuration bit-for-bit identical to the unpartitioned
    path.

    When ``data`` is concrete arrays and the prior is given, the target
    carries a :class:`TargetSpec` recipe (``target.spec``) so it can be
    re-built on a data slice (:func:`repro.partition.partition_target`) or
    on appended observations (:func:`append_observations`).

    Example — the BayesLR target in one call::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core import build_target
        >>> x = jax.random.normal(jax.random.key(0), (100, 3))
        >>> y = jnp.where(jax.random.bernoulli(jax.random.key(1), 0.5, (100,)), 1.0, -1.0)
        >>> t = build_target("logit", (x, y), 100,
        ...                  prior_logpdf=lambda w: -5.0 * jnp.sum(w**2))
        >>> t.family, t.num_sections, t.log_local_ensemble is not None
        ('logit', 100, True)
        >>> w0, w1 = jnp.zeros(3), jnp.full((3,), 0.1)
        >>> t.log_local(w0, w1, jnp.arange(8, dtype=jnp.int32)).shape
        (8,)
    """
    if num_sections is None:
        raise ValueError("num_sections is required")
    user_prior = prior_logpdf
    if prior_logpdf is not None and prior_scale != 1.0:
        scale = float(prior_scale)
        base_prior = prior_logpdf
        prior_logpdf = lambda theta: scale * base_prior(theta)
    elif prior_scale != 1.0:
        raise ValueError("prior_scale tempering requires prior_logpdf")
    if log_global is None:
        if prior_logpdf is None:
            raise ValueError("pass prior_logpdf or an explicit log_global")

        def log_global(theta, theta_p):
            return prior_logpdf(theta_p) - prior_logpdf(theta)

    if family is None:
        if log_local is None:
            raise ValueError("family=None requires an explicit log_local")
        return PartitionedTarget(
            num_sections=num_sections,
            log_global=log_global,
            log_local=log_local,
            log_density=log_density,
        )

    fam = get_family(family)
    spec = None
    if not callable(data) and data is not None and user_prior is not None:
        # Recipe for partitioning / streaming append: the *untempered* prior
        # plus the exponent, so re-tempering composes instead of stacking.
        spec = TargetSpec(
            family=family,
            data=data,
            num_sections=num_sections,
            prior_logpdf=user_prior,
            params_fn=params_fn,
            prior_scale=float(prior_scale),
        )
    data_fn = data if callable(data) else (lambda theta: data)
    params_fn = params_fn or (lambda theta: theta)

    if log_local is None:

        def log_local(theta, theta_p, idx):
            return fam.delta(data_fn(theta), params_fn(theta), params_fn(theta_p), idx)

    def log_local_ensemble(theta, theta_p, idx):
        return fam.ensemble_delta(
            data_fn(theta), params_fn(theta), params_fn(theta_p), idx
        )

    if log_density is None and prior_logpdf is not None:

        def log_density(theta):
            idx = jnp.arange(num_sections, dtype=jnp.int32)
            local = fam.loglik(data_fn(theta), params_fn(theta), idx)
            return prior_logpdf(theta) + local.sum()

    return PartitionedTarget(
        num_sections=num_sections,
        log_global=log_global,
        log_local=log_local,
        log_density=log_density,
        log_local_ensemble=log_local_ensemble,
        family=fam.name,
        spec=spec,
    )
