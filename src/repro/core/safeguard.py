"""Sec. 3.3 safeguard: normality diagnostics + auto exact-vs-subsampled report.

"Our software can provide a normality test for the distribution of the
estimated mean in trial runs and produce an auto-generated comparison between
the performance of the approximate MH and regular inference."

The t-test in Alg. 2 assumes mini-batch means of {l_i} are near-normal; heavy
tails (the Bardenet et al. counterexample) break the CLT on small subsets.
``trial_run_report`` runs a few transitions, collects the population {l_i} at
each proposal, tests normality of mini-batch means (Jarque–Bera), and replays
the SAME (u, theta, theta') decisions through both the exact rule and the
sequential test to report the empirical decision-error rate.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .samplers import fy_draw, fy_init, fy_reset
from .sequential_test import sequential_test
from .stats import jarque_bera
from .target import PartitionedTarget


@dataclasses.dataclass
class TrialReport:
    num_trials: int
    jb_stat_mean: float
    jb_pvalue_min: float
    normal_ok: bool
    decision_error_rate: float
    mean_fraction_evaluated: float
    recommendation: str

    def __str__(self) -> str:  # pragma: no cover - formatting
        lines = [
            "Sec 3.3 safeguard report",
            f"  trials                      : {self.num_trials}",
            f"  Jarque-Bera stat (mean)     : {self.jb_stat_mean:.3f}",
            f"  Jarque-Bera p-value (min)   : {self.jb_pvalue_min:.4f}",
            f"  batch-mean normality OK     : {self.normal_ok}",
            f"  exact-vs-subsampled errors  : {self.decision_error_rate:.3%}",
            f"  mean fraction of N evaluated: {self.mean_fraction_evaluated:.3%}",
            f"  recommendation              : {self.recommendation}",
        ]
        return "\n".join(lines)


def trial_run_report(
    key: jax.Array,
    theta0,
    target: PartitionedTarget,
    proposal,
    batch_size: int = 100,
    epsilon: float = 0.01,
    num_trials: int = 20,
) -> TrialReport:
    n = target.num_sections
    idx_all = jnp.arange(n, dtype=jnp.int32)
    theta = theta0
    jb_stats, jb_ps, errors, fractions = [], [], [], []

    # One compile for all trials: (theta, theta', mu0) stay traced instead of
    # being closed over per trial, which retraced the while_loop every time.
    @jax.jit
    def _seq(k, th, th_p, mu0):
        return sequential_test(
            key=k,
            mu0=mu0,
            draw_fn=fy_draw,
            eval_fn=lambda i: target.log_local(th, th_p, i),
            sampler_state=fy_reset(fy_init(n)),
            num_sections=n,
            batch_size=batch_size,
            epsilon=epsilon,
        )

    for _ in range(num_trials):
        key, k_u, k_prop, k_test = jax.random.split(key, 4)
        log_u = float(jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0)))
        theta_p, corr = proposal(k_prop, theta)
        g = float(target.log_global(theta, theta_p) + corr)
        l = np.asarray(target.log_local(theta, theta_p, idx_all))
        mu0 = (log_u - g) / n
        exact_accept = l.mean() > mu0

        # normality of mini-batch means
        nb = max(len(l) // batch_size, 1)
        means = np.array([c.mean() for c in np.array_split(l, nb)]) if nb > 1 else l
        jb, p = jarque_bera(means)
        jb_stats.append(jb)
        jb_ps.append(p)

        res = _seq(k_test, theta, theta_p, jnp.asarray(mu0, jnp.float32))
        errors.append(bool(res.decision) != bool(exact_accept))
        fractions.append(float(res.n_evaluated) / n)

        if exact_accept:  # advance chain with the exact decision (trial run)
            theta = theta_p

    normal_ok = min(jb_ps) > 0.01
    err = float(np.mean(errors))
    rec = (
        "subsampled MH looks safe at this epsilon/batch size"
        if normal_ok and err <= max(2.0 * epsilon, 0.1)
        else "heavy-tailed l_i or high decision-error rate: increase batch size, "
        "lower epsilon, or fall back to exact MH for this variable"
    )
    return TrialReport(
        num_trials=num_trials,
        jb_stat_mean=float(np.mean(jb_stats)),
        jb_pvalue_min=float(min(jb_ps)),
        normal_ok=normal_ok,
        decision_error_rate=err,
        mean_fraction_evaluated=float(np.mean(fractions)),
        recommendation=rec,
    )
