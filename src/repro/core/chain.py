"""Single-chain drivers: jitted scan loops and timed host loops for benchmarks.

For K chains at once (batched keys/theta/sampler states, one jitted program,
optional multi-device fan-out) use :class:`repro.core.ensemble.ChainEnsemble`
— chain k of an ensemble seeded with per-chain key k reproduces
:func:`run_chain` with that key step for step.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .mh import mh_step
from .subsampled_mh import SubsampledMHConfig, make_kernel
from .target import PartitionedTarget

Params = Any


def run_chain(
    key: jax.Array,
    theta0: Params,
    target: PartitionedTarget,
    proposal,
    num_steps: int,
    kernel: str = "subsampled",
    config: SubsampledMHConfig | None = None,
    collect: Callable[[Params], Any] | None = None,
    chunk_size: int | None = None,
):
    """Run ``num_steps`` transitions inside one jitted lax.scan.

    Returns (theta_final, collected_samples, infos) with leaves stacked on a
    leading time axis. ``collect`` maps theta -> whatever should be recorded
    per step (defaults to theta itself — fine for small parameter trees).

    See :class:`repro.core.ensemble.ChainEnsemble` for the vmapped K-chain
    version of this loop (same per-chain key-splitting discipline).
    """
    collect = collect or (lambda t: t)
    config = config or SubsampledMHConfig()

    if kernel == "subsampled":
        sampler0, step = make_kernel(target, proposal, config)

        def scan_body(carry, k):
            theta, sstate = carry
            theta, sstate, info = step(k, theta, sstate)
            return (theta, sstate), (collect(theta), info)

        keys = jax.random.split(key, num_steps)
        (theta, _), (samples, infos) = jax.lax.scan(scan_body, (theta0, sampler0), keys)
        return theta, samples, infos

    if kernel == "exact":

        def scan_body(theta, k):
            theta, info = mh_step(k, theta, target, proposal, chunk_size=chunk_size)
            return theta, (collect(theta), info)

        keys = jax.random.split(key, num_steps)
        theta, (samples, infos) = jax.lax.scan(scan_body, theta0, keys)
        return theta, samples, infos

    raise ValueError(f"unknown kernel {kernel!r}")


def run_chain_timed(
    key: jax.Array,
    theta0: Params,
    target: PartitionedTarget,
    proposal,
    num_steps: int,
    kernel: str = "subsampled",
    config: SubsampledMHConfig | None = None,
    collect: Callable[[Params], Any] | None = None,
    callback: Callable[[int, float, Any, Any], None] | None = None,
    chunk_size: int | None = None,
):
    """Host-driven loop recording wall-clock per transition (for the
    risk-vs-time figures). One jitted step function, python loop around it.

    Returns dict with samples (list), infos (list of dicts), times (np array
    of cumulative seconds).

    For aggregate-throughput timing across many chains use
    :meth:`repro.core.ensemble.ChainEnsemble.run_timed`, which amortizes the
    per-step host dispatch this loop pays deliberately (it wants per-
    transition timestamps).
    """
    collect = collect or (lambda t: t)
    config = config or SubsampledMHConfig()

    if kernel == "subsampled":
        sampler0, raw_step = make_kernel(target, proposal, config)
        step = jax.jit(raw_step)
        state = sampler0
    else:
        step = jax.jit(
            lambda k, t: mh_step(k, t, target, proposal, chunk_size=chunk_size)
        )
        state = None

    theta = theta0
    samples, infos, times = [], [], []
    t_start = None
    for i in range(num_steps):
        key, sub = jax.random.split(key)
        if kernel == "subsampled":
            theta, state, info = step(sub, theta, state)
        else:
            theta, info = step(sub, theta)
        jax.block_until_ready(theta)
        if t_start is None:  # exclude compile time from the clock
            t_start = time.perf_counter()
            times.append(0.0)
        else:
            times.append(time.perf_counter() - t_start)
        samples.append(jax.device_get(collect(theta)))
        infos.append({k: np.asarray(v) for k, v in info._asdict().items()})
        if callback is not None:
            callback(i, times[-1], samples[-1], infos[-1])
    return {"samples": samples, "infos": infos, "times": np.asarray(times)}


def acceptance_rate(infos) -> float:
    acc = np.asarray(infos.accepted if hasattr(infos, "accepted") else [i["accepted"] for i in infos])
    return float(np.mean(acc))
