"""Statistical primitives for the sequential MH test and chain diagnostics.

Everything here is jit-safe (pure jnp) unless noted. The Student-t survival
function is computed exactly through the regularized incomplete beta function,
matching ``scipy.stats.t.sf`` to f32 precision.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def student_t_sf(t: jax.Array, df: jax.Array) -> jax.Array:
    """P(T > t) for T ~ Student-t(df), t >= 0.

    Uses sf(t) = 0.5 * I_{df/(df+t^2)}(df/2, 1/2) for t >= 0.
    """
    t = jnp.asarray(t, jnp.float32)
    df = jnp.asarray(df, jnp.float32)
    x = df / (df + t * t)
    return 0.5 * jax.scipy.special.betainc(df / 2.0, 0.5, x)


def two_sided_t_pvalue(tstat: jax.Array, df: jax.Array) -> jax.Array:
    """Two-sided p-value of |tstat| under Student-t(df)."""
    return 2.0 * student_t_sf(jnp.abs(tstat), df)


class Welford(NamedTuple):
    """Streaming mean/variance accumulator (Chan's parallel merge form).

    ``count`` is carried as f32 so the whole state lives on device; all
    experiments keep n <= 2**24 where f32 counting is exact.
    """

    count: jax.Array  # n
    mean: jax.Array  # running mean
    m2: jax.Array  # sum of squared deviations

    @staticmethod
    def empty(dtype=jnp.float32) -> "Welford":
        z = jnp.zeros((), dtype)
        return Welford(z, z, z)

    def merge_batch(self, values: jax.Array, mask: jax.Array | None = None) -> "Welford":
        """Merge a batch of observations. ``mask`` selects valid entries."""
        values = values.astype(self.mean.dtype)
        if mask is None:
            nb = jnp.asarray(values.size, self.count.dtype)
            mb = jnp.mean(values)
            m2b = jnp.sum((values - mb) ** 2)
        else:
            mask = mask.astype(values.dtype)
            nb = jnp.sum(mask)
            safe_nb = jnp.maximum(nb, 1.0)
            mb = jnp.sum(values * mask) / safe_nb
            m2b = jnp.sum(mask * (values - mb) ** 2)
        na = self.count
        n = na + nb
        delta = mb - self.mean
        safe_n = jnp.maximum(n, 1.0)
        mean = self.mean + delta * nb / safe_n
        m2 = self.m2 + m2b + delta * delta * na * nb / safe_n
        # If the batch was empty, keep previous stats untouched.
        keep = nb > 0
        return Welford(
            jnp.where(keep, n, na),
            jnp.where(keep, mean, self.mean),
            jnp.where(keep, m2, self.m2),
        )

    @property
    def std(self) -> jax.Array:
        """Sample standard deviation (ddof=1)."""
        return jnp.sqrt(self.m2 / jnp.maximum(self.count - 1.0, 1.0))


def finite_population_std_err(welford: Welford, population: jax.Array) -> jax.Array:
    """Std of the running mean with the without-replacement correction.

    s = s_l / sqrt(n) * sqrt(1 - (n-1)/(N-1))   (Alg. 2, step 7)
    """
    n = welford.count
    big_n = jnp.asarray(population, jnp.float32)
    corr = jnp.clip(1.0 - (n - 1.0) / jnp.maximum(big_n - 1.0, 1.0), 0.0, 1.0)
    return welford.std / jnp.sqrt(jnp.maximum(n, 1.0)) * jnp.sqrt(corr)


# ---------------------------------------------------------------------------
# Chain diagnostics (host-side numpy; not jitted).
# ---------------------------------------------------------------------------


def autocorrelation(x: np.ndarray, max_lag: int | None = None) -> np.ndarray:
    """Normalized autocorrelation of a 1-d chain via FFT."""
    x = np.asarray(x, np.float64)
    n = len(x)
    if max_lag is None:
        max_lag = n - 1
    x = x - x.mean()
    nfft = int(2 ** np.ceil(np.log2(2 * n)))
    f = np.fft.rfft(x, nfft)
    acov = np.fft.irfft(f * np.conj(f), nfft)[: max_lag + 1].real / n
    if acov[0] <= 0:
        return np.zeros(max_lag + 1)
    return acov / acov[0]


def effective_sample_size(x: np.ndarray) -> float:
    """ESS via Geyer's initial positive sequence estimator."""
    n = len(x)
    if n < 4:
        return float(n)
    rho = autocorrelation(x)
    # Sum consecutive pairs; truncate at first negative pair (Geyer 1992).
    tau = 1.0
    for k in range(1, (len(rho) - 1) // 2):
        pair = rho[2 * k - 1] + rho[2 * k]
        if pair < 0:
            break
        tau += 2.0 * pair
    return float(n / max(tau, 1e-12))


def predictive_risk(estimates: np.ndarray, truth: float) -> float:
    """Risk of the running predictive mean, as in Korattikara et al. (2014):
    E[(f_bar_T - truth)^2] estimated from one (or more) chains."""
    estimates = np.atleast_2d(np.asarray(estimates, np.float64))
    return float(np.mean((estimates - truth) ** 2))


# ---------------------------------------------------------------------------
# Cross-chain diagnostics for ChainEnsemble outputs (leaves shaped (K, T, ...)).
# ---------------------------------------------------------------------------


def split_rhat(chains: np.ndarray) -> np.ndarray | float:
    """Split-R̂ (Gelman et al. 2013) of an ensemble of chains.

    ``chains``: (K, T) or (K, T, *param_dims). Each chain is split in half
    (2K half-chains of length T//2), then R̂ = sqrt(((L-1)/L · W + B/L) / W)
    with W the mean within-chain variance and B the between-chain variance
    of the half-chain means. Scalar input rank returns a float; trailing
    parameter dims are vectorized over.
    """
    x = np.asarray(chains, np.float64)
    if x.ndim < 2:
        raise ValueError("split_rhat expects (K, T, ...) stacked chains")
    k, t = x.shape[:2]
    half = t // 2
    if half < 2:
        raise ValueError(f"chains too short for split-R-hat: T={t}")
    # (2K, half, *param): drop the middle sample when T is odd
    halves = np.concatenate([x[:, :half], x[:, t - half:]], axis=0)
    means = halves.mean(axis=1)  # (2K, *param)
    variances = halves.var(axis=1, ddof=1)  # (2K, *param)
    w = variances.mean(axis=0)
    b = half * means.var(axis=0, ddof=1)
    var_hat = (half - 1) / half * w + b / half
    rhat = np.sqrt(var_hat / np.maximum(w, 1e-300))
    return float(rhat) if rhat.ndim == 0 else rhat


def multichain_ess(chains: np.ndarray) -> float:
    """Total effective sample size of an ensemble: sum of per-chain Geyer
    ESS values for a (K, T) scalar-functional trace."""
    x = np.asarray(chains, np.float64)
    if x.ndim != 2:
        raise ValueError("multichain_ess expects (K, T)")
    return float(sum(effective_sample_size(row) for row in x))


def ensemble_summary(infos) -> dict:
    """Per-chain and aggregate transition statistics from stacked ensemble
    infos (SubsampledMHInfo / MHInfo leaves shaped (K, T)).

    Returns per-chain acceptance rates and mean evaluated-section counts
    plus their ensemble aggregates — the Sec-4 "fraction of data touched"
    numbers, now across chains. When the infos carry the adaptation trace
    (``epsilon`` / ``batch_eff`` from :mod:`repro.core.schedule`), their
    per-chain means and final values are summarized too.
    """
    acc = np.asarray(infos.accepted, np.float64)
    n_eval = np.asarray(infos.n_evaluated, np.float64)
    out = {
        "accept_rate": acc.mean(axis=1),
        "mean_n_evaluated": n_eval.mean(axis=1),
        "accept_rate_overall": float(acc.mean()),
        "mean_n_evaluated_overall": float(n_eval.mean()),
    }
    if hasattr(infos, "rounds"):
        rounds = np.asarray(infos.rounds, np.float64)
        out["mean_rounds"] = rounds.mean(axis=1)
        out["mean_rounds_overall"] = float(rounds.mean())
        out["rounds_tail"] = tail_latency_summary(rounds)
    if hasattr(infos, "epsilon"):
        eps = np.asarray(infos.epsilon, np.float64)
        out["mean_epsilon"] = eps.mean(axis=1)
        out["final_epsilon"] = eps[:, -1]
    if hasattr(infos, "batch_eff"):
        be = np.asarray(infos.batch_eff, np.float64)
        out["mean_batch_eff"] = be.mean(axis=1)
        out["final_batch_eff"] = be[:, -1]
    return out


def tail_latency_summary(rounds, percentiles=(50, 90, 99)) -> dict:
    """Tail statistics of per-transition sequential-test rounds.

    In the lock-step ensemble the whole vmapped row pays every transition's
    *max* round count, so the tail of this distribution — not its mean — is
    what throughput is made of; the masked-continuation mode exists to make
    the tail per-chain instead of per-row. Returns percentiles, mean/max,
    and a histogram over integer round counts (``hist[i]`` = transitions
    that took ``edges[i]`` rounds).
    """
    r = np.asarray(rounds, np.float64).ravel()
    if r.size == 0:
        raise ValueError("tail_latency_summary needs at least one transition")
    out = {f"p{p}": float(np.percentile(r, p)) for p in percentiles}
    out["mean"] = float(r.mean())
    out["max"] = float(r.max())
    edges = np.arange(1, max(int(r.max()), 1) + 1)
    hist, _ = np.histogram(r, bins=np.concatenate([edges - 0.5, [edges[-1] + 0.5]]))
    out["edges"] = edges
    out["hist"] = hist
    return out


def stage_latency_breakdown(spans) -> dict:
    """Per-stage latency tables from closed trace spans.

    The request-path counterpart of :func:`tail_latency_summary`: spans
    (plain dicts from :mod:`repro.obs.trace` carrying ``stage``/``dur_s``)
    are grouped by stage tag — queue wait vs batch assembly vs device eval
    vs combine — and each stage gets count/mean/p50/p95/max/total in
    milliseconds. This is what the stats endpoint's ``/stages`` view
    returns, answering "where did the latency go" without re-reading the
    raw spans stream.
    """
    by_stage: dict[str, list[float]] = {}
    traces = set()
    for span in spans:
        dur = span.get("dur_s")
        stage = span.get("stage")
        if not isinstance(dur, (int, float)) or stage is None:
            continue
        by_stage.setdefault(str(stage), []).append(float(dur) * 1e3)
        if span.get("trace_id") is not None:
            traces.add(span["trace_id"])
    stages = {}
    for stage, ms in sorted(by_stage.items()):
        arr = np.asarray(ms, np.float64)
        stages[stage] = {
            "count": int(arr.size),
            "mean_ms": float(arr.mean()),
            "p50_ms": float(np.percentile(arr, 50)),
            "p95_ms": float(np.percentile(arr, 95)),
            "max_ms": float(arr.max()),
            "total_ms": float(arr.sum()),
        }
    return {
        "span_count": int(sum(len(v) for v in by_stage.values())),
        "trace_count": len(traces),
        "stages": stages,
    }


def slo_summary(latencies_s, deadlines_s=None, percentiles=(50, 95, 99)) -> dict:
    """Service-level summary of per-request latencies (seconds).

    The serving-layer counterpart of :func:`tail_latency_summary`: request
    latencies instead of sequential-test rounds. Returns millisecond
    percentiles (``p50_ms`` etc.), mean/max, the request count, and — when
    per-request ``deadlines_s`` are given — the fraction of requests that
    met their deadline (``deadline_hit_rate``), the SLO number
    ``launch/serve.py`` reports per request class.

    Example::

        >>> s = slo_summary([0.010, 0.020, 0.030], deadlines_s=[0.025] * 3)
        >>> round(s["p50_ms"], 1), round(s["deadline_hit_rate"], 2)
        (20.0, 0.67)
    """
    lat = np.asarray(latencies_s, np.float64).ravel()
    if lat.size == 0:
        raise ValueError("slo_summary needs at least one request")
    out = {f"p{p}_ms": float(np.percentile(lat, p) * 1e3) for p in percentiles}
    out["mean_ms"] = float(lat.mean() * 1e3)
    out["max_ms"] = float(lat.max() * 1e3)
    out["count"] = int(lat.size)
    if deadlines_s is not None:
        dl = np.broadcast_to(np.asarray(deadlines_s, np.float64).ravel(), lat.shape)
        out["deadline_hit_rate"] = float(np.mean(lat <= dl))
    return out


# ---------------------------------------------------------------------------
# Unified serving SLO schema.
#
# RequestQueue.slo_report() and FleetRouter.slo_report() used to return
# differently-shaped dicts for the same concepts. Both now build the one
# schema below, which is what the observability recorder (repro.obs) and the
# CI perf gate (benchmarks/gate.py) consume. Every field is always present
# (latency percentiles are None when a class has no successful completions),
# so consumers never need per-producer key probing.
# ---------------------------------------------------------------------------


_SLO_DEPRECATED_KEYS = {"total_requests": "count"}


class SLOReportDict(dict):
    """A canonical slo_report dict that still answers the pre-unification
    key spellings (``total_requests``), with a :class:`DeprecationWarning`.
    The aliases are not real keys — iteration, ``in``, and serialization see
    only the canonical schema — and they are removed next release."""

    def __missing__(self, key):
        canon = _SLO_DEPRECATED_KEYS.get(key)
        if canon is not None and dict.__contains__(self, canon):
            warnings.warn(
                f"slo_report key {key!r} is deprecated; use {canon!r}",
                DeprecationWarning,
                stacklevel=2,
            )
            return self[canon]
        raise KeyError(key)

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default


@dataclasses.dataclass
class ClassSLO:
    """Per-(workload, request-class) serving statistics.

    ``count``/``errors`` cover *attempted* (non-shed) completions;
    ``admitted``/``shed`` are admission-control counters (for the plain
    queue, which never sheds, ``admitted`` equals the attempted count).
    Latency percentiles summarize successful requests only — a batch that
    failed fast must not read as low latency — while ``deadline_hit_rate``
    covers every attempted request (failures count as misses).
    """

    count: int = 0
    errors: int = 0
    admitted: int = 0
    shed: int = 0
    priority: int = 0
    deadline_hit_rate: float = 0.0
    mean_batch_size: float = 0.0
    p50_ms: float | None = None
    p95_ms: float | None = None
    p99_ms: float | None = None
    mean_ms: float | None = None
    max_ms: float | None = None
    staleness_mean_s: float | None = None
    staleness_max_s: float | None = None

    @classmethod
    def from_requests(
        cls, requests, *, priority: int = 0,
        admitted: int | None = None, shed: int | None = None,
    ) -> "ClassSLO":
        """Aggregate completed request records (anything with ``latency_s``
        / ``error`` / ``deadline_met`` / ``staleness_s`` / ``batch_size``
        attributes; shed requests carry ``error="shed: ..."``)."""
        attempted, shed_local = [], 0
        for r in requests:
            if (r.error or "").startswith("shed"):
                shed_local += 1
            else:
                attempted.append(r)
        ok = [r for r in attempted if r.error is None]
        out = cls(
            count=len(ok),
            errors=len(attempted) - len(ok),
            admitted=len(attempted) if admitted is None else int(admitted),
            shed=shed_local if shed is None else int(shed),
            priority=int(priority),
        )
        if attempted:
            out.deadline_hit_rate = float(
                np.mean([bool(r.deadline_met) for r in attempted])
            )
        if ok:
            s = slo_summary([r.latency_s for r in ok])
            out.p50_ms, out.p95_ms, out.p99_ms = s["p50_ms"], s["p95_ms"], s["p99_ms"]
            out.mean_ms, out.max_ms = s["mean_ms"], s["max_ms"]
            out.mean_batch_size = float(np.mean([r.batch_size or 1 for r in ok]))
            staleness = [r.staleness_s for r in ok if r.staleness_s is not None]
            if staleness:
                out.staleness_mean_s = float(np.mean(staleness))
                out.staleness_max_s = float(np.max(staleness))
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SLOReport:
    """One serving report: totals, admission/recovery state, per-class
    tables. ``count`` spans every completion including shed requests (they
    completed, just not with an answer); ``errors`` excludes shed.
    """

    count: int = 0
    errors: int = 0
    shed: int = 0
    admission: dict | None = None
    recovery: dict | None = None
    classes: dict[str, ClassSLO] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> SLOReportDict:
        return SLOReportDict(
            count=self.count,
            errors=self.errors,
            shed=self.shed,
            admission=self.admission,
            recovery=self.recovery,
            classes={k: v.to_dict() for k, v in self.classes.items()},
        )


def build_slo_report(
    requests,
    *,
    priorities: dict[str, int] | None = None,
    class_counters: dict[tuple[str, str], dict] | None = None,
    admission: dict | None = None,
    recovery: dict | None = None,
) -> SLOReport:
    """Aggregate completed requests into the unified :class:`SLOReport`.

    ``class_counters`` (keyed ``(workload, query_class)``, entries holding
    ``admitted``/``shed``) lets the router report its submit-time admission
    counters instead of the completion-derived defaults; classes that only
    appear in the counters (everything they admitted still pending) still
    get a row.
    """
    done = [r for r in requests if r.latency_s is not None]
    by_class: dict[tuple[str, str], list] = {}
    for r in done:
        by_class.setdefault((r.workload, r.query_class), []).append(r)
    counters = class_counters or {}
    classes: dict[str, ClassSLO] = {}
    errors_total = shed_total = 0
    for wl, qc in sorted(set(by_class) | set(counters)):
        cnt = counters.get((wl, qc))
        entry = ClassSLO.from_requests(
            by_class.get((wl, qc), []),
            priority=(priorities or {}).get(qc, 0),
            admitted=cnt["admitted"] if cnt else None,
            shed=cnt["shed"] if cnt else None,
        )
        classes[f"{wl}.{qc}"] = entry
        errors_total += entry.errors
        shed_total += entry.shed
    return SLOReport(
        count=len(done),
        errors=errors_total,
        shed=shed_total,
        admission=admission,
        recovery=recovery,
        classes=classes,
    )


def jarque_bera(x: np.ndarray) -> tuple[float, float]:
    """Jarque–Bera normality statistic and asymptotic chi2(2) p-value.

    Used by the Sec. 3.3 safeguard: the sequential t-test assumes the
    mini-batch means are approximately normal; heavy-tailed {l_i} break it.
    """
    x = np.asarray(x, np.float64)
    n = len(x)
    mu = x.mean()
    s = x.std()
    if s == 0 or n < 8:
        return 0.0, 1.0
    z = (x - mu) / s
    skew = np.mean(z**3)
    kurt = np.mean(z**4) - 3.0
    jb = n / 6.0 * (skew**2 + kurt**2 / 4.0)
    # chi2(2) survival = exp(-jb/2)
    return float(jb), float(np.exp(-jb / 2.0))


# ---------------------------------------------------------------------------
# Streaming anomaly / SLO-burn math (shared by repro.obs.alerts)
# ---------------------------------------------------------------------------


class EwmaState(NamedTuple):
    """Exponentially weighted mean/variance for streaming z-scores.

    ``count`` is the number of observations folded in; ``mean``/``var`` are
    the EWMA first and second central moments (West's recurrence). A fresh
    state is ``EwmaState(0, 0.0, 0.0)``.
    """

    count: int
    mean: float
    var: float


def ewma_update(state: EwmaState, x: float, alpha: float = 0.3) -> EwmaState:
    """Fold one observation into an :class:`EwmaState`.

    The first observation initializes the mean exactly (no bias toward
    zero); variance starts at 0 and inflates as spread is observed.
    """
    if state.count == 0:
        return EwmaState(1, float(x), 0.0)
    diff = float(x) - state.mean
    incr = alpha * diff
    mean = state.mean + incr
    var = (1.0 - alpha) * (state.var + diff * incr)
    return EwmaState(state.count + 1, mean, var)


def ewma_zscore(state: EwmaState, x: float, min_sigma: float = 1e-9) -> float:
    """The z-score of ``x`` against an EWMA state's mean/sigma (0.0 until
    the state has seen at least two observations)."""
    if state.count < 2:
        return 0.0
    sigma = max(state.var, 0.0) ** 0.5
    return (float(x) - state.mean) / max(sigma, min_sigma)


def burn_rate(bad_fraction: float, budget: float) -> float:
    """SLO error-budget burn rate: observed bad fraction over the allowed
    bad fraction. 1.0 burns the budget exactly at the sustainable pace;
    >1 exhausts it early (e.g. 14.4 = a 30-day budget gone in ~2 days)."""
    return float(bad_fraction) / max(float(budget), 1e-12)
