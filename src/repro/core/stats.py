"""Statistical primitives for the sequential MH test and chain diagnostics.

Everything here is jit-safe (pure jnp) unless noted. The Student-t survival
function is computed exactly through the regularized incomplete beta function,
matching ``scipy.stats.t.sf`` to f32 precision.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def student_t_sf(t: jax.Array, df: jax.Array) -> jax.Array:
    """P(T > t) for T ~ Student-t(df), t >= 0.

    Uses sf(t) = 0.5 * I_{df/(df+t^2)}(df/2, 1/2) for t >= 0.
    """
    t = jnp.asarray(t, jnp.float32)
    df = jnp.asarray(df, jnp.float32)
    x = df / (df + t * t)
    return 0.5 * jax.scipy.special.betainc(df / 2.0, 0.5, x)


def two_sided_t_pvalue(tstat: jax.Array, df: jax.Array) -> jax.Array:
    """Two-sided p-value of |tstat| under Student-t(df)."""
    return 2.0 * student_t_sf(jnp.abs(tstat), df)


class Welford(NamedTuple):
    """Streaming mean/variance accumulator (Chan's parallel merge form).

    ``count`` is carried as f32 so the whole state lives on device; all
    experiments keep n <= 2**24 where f32 counting is exact.
    """

    count: jax.Array  # n
    mean: jax.Array  # running mean
    m2: jax.Array  # sum of squared deviations

    @staticmethod
    def empty(dtype=jnp.float32) -> "Welford":
        z = jnp.zeros((), dtype)
        return Welford(z, z, z)

    def merge_batch(self, values: jax.Array, mask: jax.Array | None = None) -> "Welford":
        """Merge a batch of observations. ``mask`` selects valid entries."""
        values = values.astype(self.mean.dtype)
        if mask is None:
            nb = jnp.asarray(values.size, self.count.dtype)
            mb = jnp.mean(values)
            m2b = jnp.sum((values - mb) ** 2)
        else:
            mask = mask.astype(values.dtype)
            nb = jnp.sum(mask)
            safe_nb = jnp.maximum(nb, 1.0)
            mb = jnp.sum(values * mask) / safe_nb
            m2b = jnp.sum(mask * (values - mb) ** 2)
        na = self.count
        n = na + nb
        delta = mb - self.mean
        safe_n = jnp.maximum(n, 1.0)
        mean = self.mean + delta * nb / safe_n
        m2 = self.m2 + m2b + delta * delta * na * nb / safe_n
        # If the batch was empty, keep previous stats untouched.
        keep = nb > 0
        return Welford(
            jnp.where(keep, n, na),
            jnp.where(keep, mean, self.mean),
            jnp.where(keep, m2, self.m2),
        )

    @property
    def std(self) -> jax.Array:
        """Sample standard deviation (ddof=1)."""
        return jnp.sqrt(self.m2 / jnp.maximum(self.count - 1.0, 1.0))


def finite_population_std_err(welford: Welford, population: jax.Array) -> jax.Array:
    """Std of the running mean with the without-replacement correction.

    s = s_l / sqrt(n) * sqrt(1 - (n-1)/(N-1))   (Alg. 2, step 7)
    """
    n = welford.count
    big_n = jnp.asarray(population, jnp.float32)
    corr = jnp.clip(1.0 - (n - 1.0) / jnp.maximum(big_n - 1.0, 1.0), 0.0, 1.0)
    return welford.std / jnp.sqrt(jnp.maximum(n, 1.0)) * jnp.sqrt(corr)


# ---------------------------------------------------------------------------
# Chain diagnostics (host-side numpy; not jitted).
# ---------------------------------------------------------------------------


def autocorrelation(x: np.ndarray, max_lag: int | None = None) -> np.ndarray:
    """Normalized autocorrelation of a 1-d chain via FFT."""
    x = np.asarray(x, np.float64)
    n = len(x)
    if max_lag is None:
        max_lag = n - 1
    x = x - x.mean()
    nfft = int(2 ** np.ceil(np.log2(2 * n)))
    f = np.fft.rfft(x, nfft)
    acov = np.fft.irfft(f * np.conj(f), nfft)[: max_lag + 1].real / n
    if acov[0] <= 0:
        return np.zeros(max_lag + 1)
    return acov / acov[0]


def effective_sample_size(x: np.ndarray) -> float:
    """ESS via Geyer's initial positive sequence estimator."""
    n = len(x)
    if n < 4:
        return float(n)
    rho = autocorrelation(x)
    # Sum consecutive pairs; truncate at first negative pair (Geyer 1992).
    tau = 1.0
    for k in range(1, (len(rho) - 1) // 2):
        pair = rho[2 * k - 1] + rho[2 * k]
        if pair < 0:
            break
        tau += 2.0 * pair
    return float(n / max(tau, 1e-12))


def predictive_risk(estimates: np.ndarray, truth: float) -> float:
    """Risk of the running predictive mean, as in Korattikara et al. (2014):
    E[(f_bar_T - truth)^2] estimated from one (or more) chains."""
    estimates = np.atleast_2d(np.asarray(estimates, np.float64))
    return float(np.mean((estimates - truth) ** 2))


# ---------------------------------------------------------------------------
# Cross-chain diagnostics for ChainEnsemble outputs (leaves shaped (K, T, ...)).
# ---------------------------------------------------------------------------


def split_rhat(chains: np.ndarray) -> np.ndarray | float:
    """Split-R̂ (Gelman et al. 2013) of an ensemble of chains.

    ``chains``: (K, T) or (K, T, *param_dims). Each chain is split in half
    (2K half-chains of length T//2), then R̂ = sqrt(((L-1)/L · W + B/L) / W)
    with W the mean within-chain variance and B the between-chain variance
    of the half-chain means. Scalar input rank returns a float; trailing
    parameter dims are vectorized over.
    """
    x = np.asarray(chains, np.float64)
    if x.ndim < 2:
        raise ValueError("split_rhat expects (K, T, ...) stacked chains")
    k, t = x.shape[:2]
    half = t // 2
    if half < 2:
        raise ValueError(f"chains too short for split-R-hat: T={t}")
    # (2K, half, *param): drop the middle sample when T is odd
    halves = np.concatenate([x[:, :half], x[:, t - half:]], axis=0)
    means = halves.mean(axis=1)  # (2K, *param)
    variances = halves.var(axis=1, ddof=1)  # (2K, *param)
    w = variances.mean(axis=0)
    b = half * means.var(axis=0, ddof=1)
    var_hat = (half - 1) / half * w + b / half
    rhat = np.sqrt(var_hat / np.maximum(w, 1e-300))
    return float(rhat) if rhat.ndim == 0 else rhat


def multichain_ess(chains: np.ndarray) -> float:
    """Total effective sample size of an ensemble: sum of per-chain Geyer
    ESS values for a (K, T) scalar-functional trace."""
    x = np.asarray(chains, np.float64)
    if x.ndim != 2:
        raise ValueError("multichain_ess expects (K, T)")
    return float(sum(effective_sample_size(row) for row in x))


def ensemble_summary(infos) -> dict:
    """Per-chain and aggregate transition statistics from stacked ensemble
    infos (SubsampledMHInfo / MHInfo leaves shaped (K, T)).

    Returns per-chain acceptance rates and mean evaluated-section counts
    plus their ensemble aggregates — the Sec-4 "fraction of data touched"
    numbers, now across chains. When the infos carry the adaptation trace
    (``epsilon`` / ``batch_eff`` from :mod:`repro.core.schedule`), their
    per-chain means and final values are summarized too.
    """
    acc = np.asarray(infos.accepted, np.float64)
    n_eval = np.asarray(infos.n_evaluated, np.float64)
    out = {
        "accept_rate": acc.mean(axis=1),
        "mean_n_evaluated": n_eval.mean(axis=1),
        "accept_rate_overall": float(acc.mean()),
        "mean_n_evaluated_overall": float(n_eval.mean()),
    }
    if hasattr(infos, "rounds"):
        rounds = np.asarray(infos.rounds, np.float64)
        out["mean_rounds"] = rounds.mean(axis=1)
        out["mean_rounds_overall"] = float(rounds.mean())
        out["rounds_tail"] = tail_latency_summary(rounds)
    if hasattr(infos, "epsilon"):
        eps = np.asarray(infos.epsilon, np.float64)
        out["mean_epsilon"] = eps.mean(axis=1)
        out["final_epsilon"] = eps[:, -1]
    if hasattr(infos, "batch_eff"):
        be = np.asarray(infos.batch_eff, np.float64)
        out["mean_batch_eff"] = be.mean(axis=1)
        out["final_batch_eff"] = be[:, -1]
    return out


def tail_latency_summary(rounds, percentiles=(50, 90, 99)) -> dict:
    """Tail statistics of per-transition sequential-test rounds.

    In the lock-step ensemble the whole vmapped row pays every transition's
    *max* round count, so the tail of this distribution — not its mean — is
    what throughput is made of; the masked-continuation mode exists to make
    the tail per-chain instead of per-row. Returns percentiles, mean/max,
    and a histogram over integer round counts (``hist[i]`` = transitions
    that took ``edges[i]`` rounds).
    """
    r = np.asarray(rounds, np.float64).ravel()
    if r.size == 0:
        raise ValueError("tail_latency_summary needs at least one transition")
    out = {f"p{p}": float(np.percentile(r, p)) for p in percentiles}
    out["mean"] = float(r.mean())
    out["max"] = float(r.max())
    edges = np.arange(1, max(int(r.max()), 1) + 1)
    hist, _ = np.histogram(r, bins=np.concatenate([edges - 0.5, [edges[-1] + 0.5]]))
    out["edges"] = edges
    out["hist"] = hist
    return out


def slo_summary(latencies_s, deadlines_s=None, percentiles=(50, 95, 99)) -> dict:
    """Service-level summary of per-request latencies (seconds).

    The serving-layer counterpart of :func:`tail_latency_summary`: request
    latencies instead of sequential-test rounds. Returns millisecond
    percentiles (``p50_ms`` etc.), mean/max, the request count, and — when
    per-request ``deadlines_s`` are given — the fraction of requests that
    met their deadline (``deadline_hit_rate``), the SLO number
    ``launch/serve.py`` reports per request class.

    Example::

        >>> s = slo_summary([0.010, 0.020, 0.030], deadlines_s=[0.025] * 3)
        >>> round(s["p50_ms"], 1), round(s["deadline_hit_rate"], 2)
        (20.0, 0.67)
    """
    lat = np.asarray(latencies_s, np.float64).ravel()
    if lat.size == 0:
        raise ValueError("slo_summary needs at least one request")
    out = {f"p{p}_ms": float(np.percentile(lat, p) * 1e3) for p in percentiles}
    out["mean_ms"] = float(lat.mean() * 1e3)
    out["max_ms"] = float(lat.max() * 1e3)
    out["count"] = int(lat.size)
    if deadlines_s is not None:
        dl = np.broadcast_to(np.asarray(deadlines_s, np.float64).ravel(), lat.shape)
        out["deadline_hit_rate"] = float(np.mean(lat <= dl))
    return out


def jarque_bera(x: np.ndarray) -> tuple[float, float]:
    """Jarque–Bera normality statistic and asymptotic chi2(2) p-value.

    Used by the Sec. 3.3 safeguard: the sequential t-test assumes the
    mini-batch means are approximately normal; heavy-tailed {l_i} break it.
    """
    x = np.asarray(x, np.float64)
    n = len(x)
    mu = x.mean()
    s = x.std()
    if s == 0 or n < 8:
        return 0.0, 1.0
    z = (x - mu) / s
    skew = np.mean(z**3)
    kurt = np.mean(z**4) - 3.0
    jb = n / 6.0 * (skew**2 + kurt**2 / 4.0)
    # chi2(2) survival = exp(-jb/2)
    return float(jb), float(np.exp(-jb / 2.0))
