"""Alg. 2: the sequential Student-t test for the MH accept decision.

Reformulation (Eq. 6): given u ~ U[0,1], accept iff mu > mu0 where

    mu0 = (log u - sum_{n in global} log w_n) / N
    mu  = (1/N) sum_i l_i,   l_i = sum_{n in local_i} log w_n.

The test consumes mini-batches of l_i drawn WITHOUT replacement, keeps a
Welford accumulator, applies the finite-population correction, and stops when
the two-sided t p-value of (mu_hat - mu0)/s drops below epsilon — or when the
pool is exhausted (n = N), at which point the decision is exact.

Guard (paper Sec. 2, Alg. 2 step 8): when s_l = 0 the t-test is skipped and
another batch is drawn, preventing false early decisions when a small subset
happens to contain all-equal values.

This module is deliberately independent of MH: it tests H1: mu > mu0 against
H2: mu < mu0 for ANY batched supplier of l_i values, so it can be unit-tested
and reused (e.g. model-based alternatives, Sec. 5 of the paper).
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .stats import Welford, finite_population_std_err, two_sided_t_pvalue


def test_round_decision(welford: Welford, mu0, n_total, epsilon):
    """One round's stopping logic on the running accumulator (Alg. 2 steps
    7–14). Returns ``(decision, pvalue, test_ok, exhausted)``; shared by
    :func:`sequential_test` and the masked-continuation superstep of
    :class:`repro.core.ensemble.ChainEnsemble` so the two stepping modes are
    float-for-float identical. ``epsilon`` may be a traced per-chain scalar
    (the adaptive scheduler's knob)."""
    n = welford.count
    exhausted = n >= n_total
    s = finite_population_std_err(welford, n_total)
    df = jnp.maximum(n - 1.0, 1.0)
    tstat = jnp.where(s > 0, jnp.abs(welford.mean - mu0) / jnp.maximum(s, 1e-30), jnp.inf)
    pval = jnp.where(s > 0, two_sided_t_pvalue(tstat, df), jnp.zeros((), jnp.float32))
    # s_l == 0 guard: no test unless the sample std is positive — except
    # when the pool is exhausted, where the comparison is exact anyway.
    test_ok = (welford.std > 0) & (pval < epsilon)
    decision = welford.mean > mu0
    return decision, pval, test_ok, exhausted


class SeqTestResult(NamedTuple):
    decision: jax.Array  # bool: True = H1 (mu > mu0) = accept
    n_evaluated: jax.Array  # int32: local sections actually evaluated
    rounds: jax.Array  # int32: mini-batches drawn
    mu_hat: jax.Array  # f32
    pvalue: jax.Array  # f32 (final)
    sampler_state: tuple  # threaded sampler state
    aux: tuple = ()  # threaded eval auxiliary state (e.g. loglik caches)


def sequential_test(
    key: jax.Array,
    mu0: jax.Array,
    draw_fn: Callable,
    eval_fn: Callable[[jax.Array], jax.Array],
    sampler_state,
    num_sections: int,
    batch_size: int,
    epsilon: float,
    max_rounds: int | None = None,
    aux=None,
    batch_eff=None,
    draw_bounded_fn: Callable | None = None,
) -> SeqTestResult:
    """Run the sequential test inside a single jittable while_loop.

    draw_fn(key, sampler_state, m) -> (sampler_state, idx[m], valid[m])
    eval_fn(idx[m]) -> l[m]   (per-section log-weight sums)

    ``epsilon`` may be a traced scalar (per-chain adaptive tolerance). With
    ``batch_eff`` (a traced effective batch size <= ``batch_size``) and a
    matching ``draw_bounded_fn(key, state, m_max, m_eff)``, each round's
    shapes stay at the static ``batch_size`` but only ``batch_eff`` sections
    are drawn, evaluated into the statistics, and consumed from the pool —
    the adaptive scheduler's bucket mechanism (see
    :mod:`repro.core.schedule`). Pass an explicit ``max_rounds`` that covers
    pool exhaustion at the smallest bucket in that case.

    When ``aux`` is given, eval_fn is stateful: eval_fn(idx, aux) -> (l, aux).
    This lets evaluators carry caches across rounds (the Sec-3.5 lazy
    stale-value mechanism at tensor scale).

    Doctest — an easy decision (all l_i far above mu0) stops after one round::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core import make_sampler, sequential_test
        >>> state0, reset, draw = make_sampler("stream", 1000)
        >>> res = sequential_test(
        ...     key=jax.random.key(0), mu0=jnp.float32(-1.0), draw_fn=draw,
        ...     eval_fn=lambda idx: idx.astype(jnp.float32),
        ...     sampler_state=reset(state0), num_sections=1000,
        ...     batch_size=50, epsilon=0.05)
        >>> bool(res.decision), int(res.rounds), int(res.n_evaluated)
        (True, 1, 50)
    """
    n_total = num_sections
    if batch_eff is not None and draw_bounded_fn is None:
        raise ValueError("batch_eff requires a matching draw_bounded_fn")
    if max_rounds is None:
        try:
            max_rounds = int(math.ceil(int(n_total) / batch_size))
        except TypeError as e:  # traced pool size (e.g. random cluster count)
            raise ValueError(
                "num_sections is traced; pass an explicit static max_rounds"
            ) from e

    class _St(NamedTuple):
        key: jax.Array
        sampler: tuple
        welford: Welford
        rounds: jax.Array
        done: jax.Array
        decision: jax.Array
        pvalue: jax.Array
        aux: tuple

    st0 = _St(
        key=key,
        sampler=sampler_state,
        welford=Welford.empty(),
        rounds=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), bool),
        decision=jnp.zeros((), bool),
        pvalue=jnp.ones((), jnp.float32),
        aux=() if aux is None else aux,
    )
    stateful = aux is not None

    def cond(st: _St):
        return ~st.done

    def body(st: _St):
        key, sub = jax.random.split(st.key)
        if batch_eff is None:
            sampler, idx, valid = draw_fn(sub, st.sampler, batch_size)
        else:
            sampler, idx, valid = draw_bounded_fn(sub, st.sampler, batch_size, batch_eff)
        if stateful:
            l, new_aux = eval_fn(idx, st.aux)
        else:
            l, new_aux = eval_fn(idx), st.aux
        w = st.welford.merge_batch(l, valid)
        rounds = st.rounds + 1
        decision, pval, test_ok, exhausted = test_round_decision(w, mu0, n_total, epsilon)
        done = test_ok | exhausted | (rounds >= max_rounds)
        return _St(key, sampler, w, rounds, done, decision, pval, new_aux)

    st = jax.lax.while_loop(cond, body, st0)
    return SeqTestResult(
        decision=st.decision,
        n_evaluated=st.welford.count.astype(jnp.int32),
        rounds=st.rounds,
        mu_hat=st.welford.mean,
        pvalue=st.pvalue,
        sampler_state=st.sampler,
        aux=st.aux,
    )


def expected_batches_theoretical(l_values, mu0: float, batch_size: int, epsilon: float) -> float:
    """Host-side expectation of evaluated sections for a FIXED (theta, theta')
    pair, following Korattikara et al. (2014) Eq. 19: walk the test forward on
    the population moments (mean/std of {l_i}) instead of Monte Carlo draws.

    Used by benchmarks/fig5 to draw the theoretical sublinearity curve.
    """
    import numpy as np
    from scipy import stats as sstats

    l = np.asarray(l_values, np.float64)
    n_total = len(l)
    mu = l.mean()
    sl = l.std(ddof=1)
    if sl == 0:
        return float(n_total)
    p_not_stopped = 1.0
    expected = 0.0
    n = 0
    while n < n_total and p_not_stopped > 1e-12:
        m = min(batch_size, n_total - n)
        n += m
        expected += m * p_not_stopped
        corr = max(1.0 - (n - 1) / max(n_total - 1, 1), 0.0)
        s = sl / math.sqrt(n) * math.sqrt(corr)
        if s == 0:
            break
        t = abs(mu - mu0) / s
        pval = 2.0 * sstats.t.sf(t, df=max(n - 1, 1))
        p_stop = 1.0 if pval < epsilon else 0.0
        # Eq.19-style deterministic walk on population moments: the test stat
        # concentrates fast, so the stop event is ~deterministic per n.
        p_not_stopped *= 1.0 - p_stop
    return float(expected)
