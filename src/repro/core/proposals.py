"""Proposal distributions for MH transitions over pytree parameters.

A proposal returns (theta_prime, log_correction) where

    log_correction = log q(theta | theta') - log q(theta' | theta)

which is added to the global-section term of the acceptance ratio (Eq. 3's
q-factors for D; T = T' = empty under the paper's Sec. 3.1 restriction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


def _tree_randn_like(key: jax.Array, tree: Params) -> Params:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noise = [
        jax.random.normal(k, l.shape, l.dtype if jnp.issubdtype(l.dtype, jnp.floating) else jnp.float32)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, noise)


@dataclasses.dataclass(frozen=True)
class RandomWalk:
    """Symmetric Gaussian random walk: theta' = theta + sigma * xi.

    ``sigma`` may be a scalar or a pytree matching theta (per-block scales).
    Symmetric => log_correction = 0. At multi-chip scale the noise is
    regenerated per-shard from the same counter-based key, so proposing
    requires zero communication (DESIGN.md §4).
    """

    sigma: Any = 0.1

    def __call__(self, key: jax.Array, theta: Params, scale=None):
        """``scale`` (an optional traced scalar) multiplies ``sigma`` — the
        hook the adaptive-proposal controller of :mod:`repro.core.schedule`
        uses to drive per-chain step sizes. ``scale=None`` is the static
        path and is bit-for-bit identical to the pre-scale kernel."""
        xi = _tree_randn_like(key, theta)
        sigma = self.sigma
        scalar_sigma = isinstance(sigma, (int, float)) or (
            hasattr(sigma, "ndim") and getattr(sigma, "ndim", 1) == 0
        )
        if scale is not None:
            if scalar_sigma:
                sigma = sigma * scale
            else:
                sigma = jax.tree.map(lambda s: s * scale, sigma)
        if scalar_sigma:
            theta_p = jax.tree.map(lambda t, n: t + sigma * n, theta, xi)
        else:
            theta_p = jax.tree.map(lambda t, n, s: t + s * n, theta, xi, sigma)
        return theta_p, jnp.zeros((), jnp.float32)


@dataclasses.dataclass(frozen=True)
class MALA:
    """Metropolis-adjusted Langevin proposal using a (possibly stochastic)
    gradient estimate of the log target.

    theta' = theta + (step/2) * grad(theta) + sqrt(step) * xi

    ``grad_fn(theta) -> pytree`` supplies the gradient; when it is a
    subsampled estimate the q-correction below is itself approximate — the
    sequential test still targets the exact ratio of p's, so the residual bias
    is the proposal's, not the test's. Used to study the collective-bound
    roofline regime (gradients require an all-reduce; RW does not).
    """

    step: float
    grad_fn: Callable[[Params], Params]

    def __call__(self, key: jax.Array, theta: Params):
        g = self.grad_fn(theta)
        xi = _tree_randn_like(key, theta)
        half = 0.5 * self.step
        root = jnp.sqrt(jnp.asarray(self.step, jnp.float32))
        theta_p = jax.tree.map(lambda t, gg, n: t + half * gg + root * n, theta, g, xi)
        g_p = self.grad_fn(theta_p)

        def _logq(dst, src, gsrc):
            # log N(dst; src + half*gsrc, step I) up to shared constants
            diff = jax.tree.map(lambda d, s, gg: d - s - half * gg, dst, src, gsrc)
            sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(diff))
            return -sq / (2.0 * self.step)

        corr = _logq(theta, theta_p, g_p) - _logq(theta_p, theta, g)
        return theta_p, corr


@dataclasses.dataclass(frozen=True)
class IndependentGaussian:
    """Independence proposal q(theta') = N(mu, sigma^2 I); correction is the
    full ratio. Useful as the `prior` proposal for conjugate smoke tests."""

    mu: Any
    sigma: float = 1.0

    def __call__(self, key: jax.Array, theta: Params):
        xi = _tree_randn_like(key, theta)
        theta_p = jax.tree.map(lambda m, n: m + self.sigma * n, self.mu, xi)

        def _logq(x):
            diff = jax.tree.map(lambda a, m: a - m, x, self.mu)
            sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(diff))
            return -sq / (2.0 * self.sigma**2)

        return theta_p, _logq(theta) - _logq(theta_p)
