"""Exact single-site MH on a partitioned scaffold (Alg. 1 baseline).

This is the O(N)-per-transition baseline the paper compares against: every
local section's l_i is evaluated. Evaluation is chunked through ``lax.map``
so peak memory stays bounded for large N.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .target import PartitionedTarget

Params = Any


class MHInfo(NamedTuple):
    accepted: jax.Array  # bool
    n_evaluated: jax.Array  # int32 — always N here
    rounds: jax.Array  # int32
    mu_hat: jax.Array  # f32: mean of l_i
    mu0: jax.Array  # f32
    log_u: jax.Array  # f32


def _tree_select(pred: jax.Array, on_true: Params, on_false: Params) -> Params:
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def mh_step(
    key: jax.Array,
    theta: Params,
    target: PartitionedTarget,
    proposal,
    chunk_size: int | None = None,
) -> tuple[Params, MHInfo]:
    """One exact MH transition. Returns (theta_new, info)."""
    k_u, k_prop = jax.random.split(key)
    log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))
    theta_p, corr = proposal(k_prop, theta)
    n = target.num_sections
    g = target.log_global(theta, theta_p) + corr
    mu0 = (log_u - g) / n

    if chunk_size is None or chunk_size >= n:
        idx = jnp.arange(n, dtype=jnp.int32)
        total = target.log_local(theta, theta_p, idx).sum()
    else:
        pad = (-n) % chunk_size
        idx = jnp.arange(n + pad, dtype=jnp.int32)
        mask = (idx < n).astype(jnp.float32)
        chunks = idx.reshape(-1, chunk_size)
        mchunks = mask.reshape(-1, chunk_size)

        def one(args):
            c, mk = args
            return (target.log_local(theta, theta_p, jnp.minimum(c, n - 1)) * mk).sum()

        total = jax.lax.map(one, (chunks, mchunks)).sum()

    accept = log_u < g + total
    theta_new = _tree_select(accept, theta_p, theta)
    info = MHInfo(
        accepted=accept,
        n_evaluated=jnp.asarray(n, jnp.int32),
        rounds=jnp.asarray(max(1, -(-n // (chunk_size or n))), jnp.int32),
        mu_hat=total / n,
        mu0=mu0,
        log_u=log_u,
    )
    return theta_new, info
