"""Vectorized multi-chain execution: K independent MH chains in one program.

The paper's sublinear bound is *per transition*; aggregate throughput comes
from running many chains at once (the ensemble / parallel-chain pattern of
Angelino et al., *Patterns of Scalable Bayesian Inference*). ``ChainEnsemble``
lifts the single-chain kernels in this package over a leading chain axis:

  * ``jax.vmap`` over :func:`repro.core.subsampled_mh.subsampled_mh_step`
    (or the exact :func:`repro.core.mh.mh_step`) — batched PRNG keys,
    batched theta pytrees, batched Fisher–Yates sampler states — so K
    transitions compile to ONE jitted program and every mini-batch
    evaluation is a (K, m) block instead of K separate (m,) calls,
  * per-chain semantics are preserved exactly: chain k of the ensemble,
    seeded with key k, produces the same trajectory as a sequential
    :func:`repro.core.chain.run_chain` call with that key (the batched
    while_loop masks finished lanes, it never perturbs them),
  * an optional ``shard_map`` fan-out over a chain mesh axis spreads the
    ensemble across devices (see :mod:`repro.distributed.sharding` for the
    data-axis counterpart); on one device it is skipped entirely.

Downstream, :func:`repro.core.stats.split_rhat` /
:func:`repro.core.stats.ensemble_summary` consume the (K, T) outputs for
cross-chain convergence diagnostics, and the fused (K, m) likelihood block
has a Pallas twin in :mod:`repro.kernels.batched_loglik`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .mh import mh_step
from .subsampled_mh import SubsampledMHConfig, make_kernel
from .target import PartitionedTarget

Params = Any


class EnsembleState(NamedTuple):
    """Per-chain carried state; every leaf has a leading (K,) chain axis."""

    theta: Params
    sampler_state: Any  # batched sampler pytree ("exact" kernel: dummy zeros)

    @property
    def num_chains(self) -> int:
        return jax.tree.leaves(self.theta)[0].shape[0]


def _broadcast_chain_axis(tree: Params, num_chains: int) -> Params:
    """Tile every leaf with a leading chain axis (identical initial chains)."""

    def tile(leaf):
        leaf = jnp.asarray(leaf)
        return jnp.broadcast_to(leaf[None], (num_chains,) + leaf.shape)

    return jax.tree.map(tile, tree)


@dataclasses.dataclass(frozen=True)
class ChainEnsemble:
    """K independent MH chains advanced in lock-step inside one jitted scan.

    Usage::

        ens = ChainEnsemble(target, RandomWalk(0.05), num_chains=16)
        state = ens.init(theta0)                      # broadcast K chains
        state, samples, infos = ens.run(key, state, num_steps=1000)
        # samples: (K, num_steps, ...); infos leaves: (K, num_steps)

    ``run`` splits ``key`` into one key per chain and, per chain, into one
    key per step exactly like :func:`repro.core.chain.run_chain` does — so
    passing per-chain keys (a ``(K,)`` key array) reproduces K sequential
    ``run_chain`` calls bit-for-bit on elementwise targets.

    With multiple devices visible (and ``shard="auto"`` or ``True``), the
    vmapped step is wrapped in ``shard_map`` over a 1-d chain mesh, so each
    device advances ``K / n_devices`` chains with zero cross-device traffic.
    """

    target: PartitionedTarget
    proposal: Any
    num_chains: int
    kernel: str = "subsampled"  # "subsampled" | "exact"
    config: SubsampledMHConfig | None = None
    chunk_size: int | None = None  # exact kernel: lax.map chunking
    collect: Callable[[Params], Any] | None = None
    shard: Any = "auto"  # "auto" | True | False — shard_map over chains
    chain_axis: str = "chains"

    def __post_init__(self):
        if self.kernel not in ("subsampled", "exact"):
            raise ValueError(f"unknown kernel {self.kernel!r}")

    # -- state ------------------------------------------------------------

    def init(self, theta0: Params, *, batched: bool = False) -> EnsembleState:
        """Build the batched initial state.

        ``theta0`` is a single pytree broadcast to all chains, or (with
        ``batched=True``) a pytree whose leaves already carry a leading
        (num_chains,) axis — e.g. overdispersed starting points for R-hat.
        """
        theta = theta0 if batched else _broadcast_chain_axis(theta0, self.num_chains)
        lead = jax.tree.leaves(theta)[0].shape[0]
        if lead != self.num_chains:
            raise ValueError(f"theta leading axis {lead} != num_chains {self.num_chains}")
        if self.kernel == "subsampled":
            state0, _ = make_kernel(self.target, self.proposal, self.config or SubsampledMHConfig())
            sampler = _broadcast_chain_axis(state0, self.num_chains)
        else:
            sampler = jnp.zeros((self.num_chains,), jnp.int32)
        return EnsembleState(theta, sampler)

    # -- single-chain step with a uniform (key, theta, state) signature ---

    def _make_step(self):
        if self.kernel == "subsampled":
            _, step = make_kernel(self.target, self.proposal, self.config or SubsampledMHConfig())
            return step

        def exact_step(key, theta, state):
            theta, info = mh_step(key, theta, self.target, self.proposal, chunk_size=self.chunk_size)
            return theta, state, info

        return exact_step

    def _per_chain_keys(self, key: jax.Array) -> jax.Array:
        key = jnp.asarray(key)
        typed = jnp.issubdtype(key.dtype, jax.dtypes.prng_key)
        # Per-chain keys are a (K,) typed-key array or (K, 2) legacy uint32
        # array; a bare legacy key of shape (2,) must NOT be mistaken for two
        # per-chain keys when num_chains == 2.
        batched = (key.ndim == 1 and typed) or (key.ndim == 2 and not typed)
        if batched and key.shape[0] == self.num_chains:
            return key
        return jax.random.split(key, self.num_chains)

    @functools.cached_property
    def _run_jit(self):
        step = self._make_step()
        collect = self.collect or (lambda t: t)

        def one_chain(key, theta0, sampler0, num_steps):
            keys = jax.random.split(key, num_steps)

            def body(carry, k):
                theta, sstate = carry
                theta, sstate, info = step(k, theta, sstate)
                return (theta, sstate), (collect(theta), info)

            (theta, sstate), (samples, infos) = jax.lax.scan(body, (theta0, sampler0), keys)
            return theta, sstate, samples, infos

        def run_all(keys, theta, sampler, num_steps):
            fn = jax.vmap(lambda k, t, s: one_chain(k, t, s, num_steps))
            mesh = self._chain_mesh()
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P

                spec = P(self.chain_axis)
                fn = shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                               out_specs=(spec, spec, spec, spec), check_rep=False)
            return fn(keys, theta, sampler)

        return jax.jit(run_all, static_argnames=("num_steps",))

    def _chain_mesh(self):
        if self.shard is False:
            return None
        devices = jax.devices()
        if len(devices) <= 1:
            return None  # single device: the plain vmap path is identical
        if self.num_chains % len(devices) != 0:
            if self.shard is True:
                raise ValueError(
                    f"shard=True needs num_chains ({self.num_chains}) divisible "
                    f"by the device count ({len(devices)})"
                )
            return None
        from jax.sharding import Mesh

        import numpy as np

        return Mesh(np.asarray(devices), (self.chain_axis,))

    # -- drivers ----------------------------------------------------------

    def run(self, key: jax.Array, state: EnsembleState, num_steps: int):
        """Advance every chain ``num_steps`` transitions in one XLA program.

        Returns ``(state, samples, infos)`` with ``samples`` leaves shaped
        (K, num_steps, ...) and ``infos`` leaves (K, num_steps).
        """
        keys = self._per_chain_keys(key)
        theta, sampler, samples, infos = self._run_jit(
            keys, state.theta, state.sampler_state, num_steps=num_steps
        )
        return EnsembleState(theta, sampler), samples, infos

    def run_timed(self, key: jax.Array, state: EnsembleState, num_steps: int,
                  block_every: int = 1):
        """Host-chunked loop recording wall clock, the multi-chain analog of
        :func:`repro.core.chain.run_chain_timed`. Compile time is excluded.

        Returns (state, dict) with ``transitions_per_sec`` aggregated over
        chains — the number ``benchmarks/multichain_bench.py`` reports.
        """
        import time

        import numpy as np

        keys = self._per_chain_keys(key)
        # Warm up every program the timed loop dispatches: each block size the
        # loop will request (num_steps is a static jit argument, so a ragged
        # final block would otherwise compile inside the timed region) and the
        # per-chain key-advance splitter.
        split_all = jax.jit(jax.vmap(lambda k: jax.random.split(k)))
        jax.block_until_ready(split_all(keys))
        block_sizes = {min(block_every, num_steps)}
        if num_steps % block_every:
            block_sizes.add(num_steps % block_every)
        for n in block_sizes:
            warm, _, _ = self.run(keys, state, n)
            jax.block_until_ready(warm.theta)
        samples_blocks, infos_blocks = [], []
        t0 = time.perf_counter()
        done = 0
        while done < num_steps:
            n = min(block_every, num_steps - done)
            pairs = split_all(keys)
            keys, subs = pairs[:, 0], pairs[:, 1]
            state, samples, infos = self.run(subs, state, n)
            jax.block_until_ready(state.theta)
            samples_blocks.append(samples)
            infos_blocks.append(infos)
            done += n
        wall = time.perf_counter() - t0
        cat = lambda xs: jax.tree.map(lambda *ls: np.concatenate([np.asarray(l) for l in ls], axis=1), *xs)
        return state, {
            "samples": cat(samples_blocks),
            "infos": cat(infos_blocks),
            "wall": wall,
            "transitions_per_sec": self.num_chains * num_steps / max(wall, 1e-12),
        }


def run_ensemble(
    key: jax.Array,
    theta0: Params,
    target: PartitionedTarget,
    proposal,
    num_chains: int,
    num_steps: int,
    kernel: str = "subsampled",
    config: SubsampledMHConfig | None = None,
    **kw,
):
    """One-shot convenience wrapper: init + run. Returns (state, samples, infos)."""
    ens = ChainEnsemble(target, proposal, num_chains, kernel=kernel, config=config, **kw)
    state = ens.init(theta0)
    return ens.run(key, state, num_steps)
