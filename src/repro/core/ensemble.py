"""Vectorized multi-chain execution: K independent MH chains in one program.

The paper's sublinear bound is *per transition*; aggregate throughput comes
from running many chains at once (the ensemble / parallel-chain pattern of
Angelino et al., *Patterns of Scalable Bayesian Inference*). ``ChainEnsemble``
lifts the single-chain kernels in this package over a leading chain axis:

  * ``jax.vmap`` over :func:`repro.core.subsampled_mh.subsampled_mh_step`
    (or the exact :func:`repro.core.mh.mh_step`) — batched PRNG keys,
    batched theta pytrees, batched Fisher–Yates sampler states — so K
    transitions compile to ONE jitted program and every mini-batch
    evaluation is a (K, m) block instead of K separate (m,) calls,
  * per-chain semantics are preserved exactly: chain k of the ensemble,
    seeded with key k, produces the same trajectory as a sequential
    :func:`repro.core.chain.run_chain` call with that key,
  * an optional ``shard_map`` fan-out over a chain mesh axis spreads the
    ensemble across devices; a 2-d ``shard=("chains", "data")`` mesh
    additionally shards each sequential-test round's (K, m) mini-batch over
    the data axis through the logical-axis rules of
    :mod:`repro.distributed.sharding` (the per-round deltas are computed on
    device slices, then re-replicated before the test statistics reduce, so
    sharded runs stay bit-for-bit). On one device both are skipped entirely.

Two stepping modes control how the K sequential tests share the vmapped row:

  ``lockstep``
    transitions advance in sync; within a transition the batched while_loop
    runs every round until the *slowest* chain's test stops, so one hard
    accept/reject decision stalls the whole row (its per-row cost is
    ``max_k rounds_k`` per transition).

  ``masked``
    the masked-continuation superstep: one while_loop over *rounds*, where a
    chain whose test finishes immediately commits its transition and begins
    the next proposal inside the same compiled loop — per-chain progress
    counters instead of lock-step rounds. Total row count drops from
    ``sum_t max_k rounds_{k,t}`` to ``max_k sum_t rounds_{k,t}``, which is
    what restores the amortized speedup at large K. With adaptation
    disabled the mode reproduces ``lockstep`` results bit for bit (the
    stepping order of every chain's draws/merges/splits is identical).

An optional :class:`repro.core.schedule.ScheduleConfig` attaches the
adaptive per-chain controller: each chain's trailing ``rounds`` /
``n_evaluated`` / acceptance statistics tune its ``batch_size`` (within a
compile-time bucket set) and ``epsilon`` between transitions, in either
stepping mode.

Downstream, :func:`repro.core.stats.split_rhat` /
:func:`repro.core.stats.ensemble_summary` consume the (K, T) outputs for
cross-chain convergence diagnostics; when the target carries a fused
``log_local_ensemble`` (attached by :mod:`repro.core.target_builder`, e.g.
:func:`repro.kernels.ops.batched_logit_delta`) and the dispatch selects
Pallas, BOTH stepping modes route each (K, m) round through it instead of
vmapping ``log_local`` — the masked superstep natively, the lock-step scan
via the batched-transition form of the same round loop.

The serving layer (:mod:`repro.serving`) keeps ensembles *resident*: the
:meth:`ChainEnsemble.step_keys` schedule (``fold_in(chain_key, t)``) makes
chunked ``run``/``run_timed(start_step=)`` calls resume one logical run bit
for bit, which is what lets a background refresh loop — and a checkpoint
restore — continue exactly the trajectory an offline ``run`` would produce.

Composite programs — the paper's ``(cycle (...))`` inference expressions —
run through ``transition=cycle([...])``: per-variable
:class:`repro.core.composite.SubsampledMHOp` kernels (each with its own
target/proposal/config, fused rounds when available) interleaved with
opaque vmapped :class:`repro.core.composite.SweepOp` sweeps (Gibbs scans,
particle Gibbs). That is how stochvol and jointdpm ride this engine; see
:mod:`repro.experiments.stochvol` / :mod:`repro.experiments.jointdpm`.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import lc, logical_axis_rules
from .composite import CycleOp, SubsampledMHOp, SweepOp, init_cycle_samplers
from .mh import mh_step
from .schedule import ScheduleConfig, controller_init, controller_params, controller_update
from .sequential_test import test_round_decision
from .stats import Welford
from .subsampled_mh import (
    SubsampledMHConfig,
    SubsampledMHInfo,
    adaptive_max_rounds,
    make_kernel,
    propose_and_mu0,
)
from .samplers import make_bounded_draw, make_sampler
from .target import PartitionedTarget

Params = Any


class EnsembleState(NamedTuple):
    """Per-chain carried state; every leaf has a leading (K,) chain axis.

    ``controller`` is ``None`` without a schedule, otherwise the batched
    :class:`repro.core.schedule.ControllerState` pytree."""

    theta: Params
    sampler_state: Any  # batched sampler pytree ("exact" kernel: dummy zeros)
    controller: Any = None

    @property
    def num_chains(self) -> int:
        return jax.tree.leaves(self.theta)[0].shape[0]


def _broadcast_chain_axis(tree: Params, num_chains: int) -> Params:
    """Tile every leaf with a leading chain axis (identical initial chains)."""

    def tile(leaf):
        leaf = jnp.asarray(leaf)
        return jnp.broadcast_to(leaf[None], (num_chains,) + leaf.shape)

    return jax.tree.map(tile, tree)


def _bselect(pred: jax.Array, on_true: Params, on_false: Params) -> Params:
    """Tree-select with a (K,) predicate broadcast over trailing leaf dims."""

    def sel(a, b):
        p = pred.reshape(pred.shape + (1,) * (a.ndim - pred.ndim))
        return jnp.where(p, a, b)

    return jax.tree.map(sel, on_true, on_false)


def _scatter_at(buf: jax.Array, pos: jax.Array, val: jax.Array, do: jax.Array) -> jax.Array:
    """Per-chain write ``buf[pos] = val where do`` (buf: (T, ...), scalars pos/do)."""
    cur = jax.lax.dynamic_index_in_dim(buf, pos, axis=0, keepdims=False)
    new = jnp.where(do, val, cur)
    return jax.lax.dynamic_update_index_in_dim(buf, new, pos, 0)


def _lc_chains(tree: Params) -> Params:
    """Constrain every (K, ...) leaf to the mesh chain axis (no-op without an
    active :func:`repro.distributed.sharding.logical_axis_rules` context)."""
    return jax.tree.map(
        lambda l: lc(l, ("ensemble_chains",) + (None,) * (l.ndim - 1)), tree
    )


def _lc_round(idx: jax.Array) -> jax.Array:
    """Shard a round's (K, m) index block chains x data."""
    return lc(idx, ("ensemble_chains", "subsample"))


def _lc_replicate_round(l: jax.Array) -> jax.Array:
    """Re-replicate a round's (K, m) deltas along m. The sharded gather +
    delta evaluation is elementwise per section, so each element's bits match
    the unsharded run; all-gathering *before* the Welford merge keeps the
    test-statistic reduction order identical too — the bit-for-bit contract
    of the 2-d mesh."""
    return lc(l, ("ensemble_chains", None))


def _make_batched_transition(
    target: PartitionedTarget,
    proposal,
    config: SubsampledMHConfig,
    num_chains: int,
    use_fused: bool,
    *,
    adaptive: bool = False,
    batch_max: int | None = None,
    max_rounds: int,
):
    """One *batched* subsampled-MH transition for K chains: vmapped
    propose/reset, then a single while_loop over sequential-test rounds where
    each round evaluates one (K, m) block — through
    ``target.log_local_ensemble`` when ``use_fused`` (the fused lock-step
    route), through ``vmap(target.log_local)`` otherwise.

    Round-for-round this reproduces ``vmap(subsampled_mh_step)`` (the same
    key-splitting, draw, Welford-merge, and ``test_round_decision`` order;
    finished lanes keep their whole state, exactly as XLA's batched
    while_loop does) — it exists so the lock-step scan and composite cycles
    can route rounds through the fused kernels, which a vmapped scalar step
    cannot express.

    Returns ``transition(keys (K,), theta, sampler, epsilon (K,),
    batch_eff (K,), prop_scale=None) -> (theta', sampler', info)`` where the
    optional ``prop_scale`` is a (K,) per-chain proposal-sigma multiplier
    (the adaptive-proposal knob; ``None`` keeps the static proposal call).
    """
    _, reset_fn, draw_fn = make_sampler(config.sampler, target.num_sections)
    draw_bounded = make_bounded_draw(config.sampler) if adaptive else None
    m_max = batch_max if batch_max is not None else config.batch_size
    n_total = target.num_sections
    K = num_chains

    def transition(keys, theta, sampler, epsilon, batch_eff, prop_scale=None):
        if prop_scale is None:
            th_p, mu0, log_u, ktest = jax.vmap(
                lambda k, t: propose_and_mu0(k, t, target, proposal)
            )(keys, theta)
        else:
            th_p, mu0, log_u, ktest = jax.vmap(
                lambda k, t, s: propose_and_mu0(k, t, target, proposal, s)
            )(keys, theta, prop_scale)
        init = (
            ktest,
            jax.vmap(reset_fn)(sampler),
            Welford(*(jnp.zeros((K,), jnp.float32) for _ in range(3))),
            jnp.zeros((K,), jnp.int32),  # rounds
            jnp.zeros((K,), bool),  # done
            jnp.zeros((K,), bool),  # decision
            jnp.ones((K,), jnp.float32),  # pvalue
        )

        def cond(c):
            return jnp.any(~c[4])

        def body(c):
            tk, smp, w, rounds, done, decision, pval = c
            active = ~done
            pairs = jax.vmap(jax.random.split)(tk)
            tkey, sub = pairs[:, 0], pairs[:, 1]
            if adaptive:
                smp2, idx, valid = jax.vmap(
                    lambda k, s, m: draw_bounded(k, s, m_max, m)
                )(sub, smp, batch_eff)
            else:
                smp2, idx, valid = jax.vmap(lambda k, s: draw_fn(k, s, m_max))(sub, smp)
            idx = _lc_round(idx)
            if use_fused:
                l = target.log_local_ensemble(theta, th_p, idx)
            else:
                l = jax.vmap(target.log_local)(theta, th_p, idx)
            l = _lc_replicate_round(l)
            w2 = jax.vmap(Welford.merge_batch)(w, l, valid)
            dec, pv, test_ok, exhausted = jax.vmap(
                lambda w_, m_, e: test_round_decision(w_, m_, n_total, e)
            )(w2, mu0, epsilon)
            rounds2 = rounds + 1
            fin = test_ok | exhausted | (rounds2 >= max_rounds)
            return (
                jnp.where(active, tkey, tk),
                _bselect(active, smp2, smp),
                _bselect(active, w2, w),
                jnp.where(active, rounds2, rounds),
                done | fin,
                jnp.where(active, dec, decision),
                jnp.where(active, pv, pval),
            )

        _, sampler2, w, rounds, _, decision, pval = jax.lax.while_loop(cond, body, init)
        theta_new = _bselect(decision, th_p, theta)
        info = SubsampledMHInfo(
            accepted=decision,
            n_evaluated=w.count.astype(jnp.int32),
            rounds=rounds,
            mu_hat=w.mean,
            mu0=mu0,
            pvalue=pval,
            log_u=log_u,
            epsilon=jnp.asarray(epsilon, jnp.float32),
            batch_eff=jnp.asarray(batch_eff, jnp.int32),
        )
        return theta_new, sampler2, info

    return transition


class _MaskedCarry(NamedTuple):
    """Superstep state of the masked-continuation loop (all leaves (K, ...))."""

    test_key: jax.Array  # per-chain sequential-test key
    theta: Params  # current sample
    theta_prop: Params  # proposal under test
    log_u: jax.Array
    mu0: jax.Array
    welford: Welford
    sampler: Any
    controller: Any
    epsilon: jax.Array  # knobs frozen at each transition's start
    batch_eff: jax.Array
    steps_done: jax.Array  # i32: transitions committed per chain
    rounds: jax.Array  # i32: rounds inside the current transition
    fresh: jax.Array  # bool: chain must start a new proposal this superstep
    samples: Params  # (K, T, ...) output buffers
    infos: SubsampledMHInfo  # (K, T) leaves
    supersteps: jax.Array  # scalar i32 safety counter


@dataclasses.dataclass(frozen=True)
class ChainEnsemble:
    """K independent MH chains advanced inside one jitted program.

    Usage::

        ens = ChainEnsemble(target, RandomWalk(0.05), num_chains=16)
        state = ens.init(theta0)                      # broadcast K chains
        state, samples, infos = ens.run(key, state, num_steps=1000)
        # samples: (K, num_steps, ...); infos leaves: (K, num_steps)

    ``run`` splits ``key`` into one key per chain and, per chain, into one
    key per step exactly like :func:`repro.core.chain.run_chain` does — so
    passing per-chain keys (a ``(K,)`` key array) reproduces K sequential
    ``run_chain`` calls bit-for-bit on elementwise targets.

    ``stepping="masked"`` (subsampled kernel only) switches to the
    masked-continuation superstep — chains that finish their sequential test
    early begin their next transition inside the same compiled loop instead
    of waiting for the row's slowest test. ``schedule=ScheduleConfig(...)``
    attaches the per-chain adaptive controller (works in both modes).

    With multiple devices visible (and ``shard="auto"`` or ``True``), the
    lock-step vmapped step is wrapped in ``shard_map`` over a 1-d chain
    mesh, so each device advances ``K / n_devices`` chains with zero
    cross-device traffic. ``shard=("chains", "data")`` (or
    ``{"chains": c, "data": d}`` with explicit sizes) instead builds a 2-d
    mesh: chains spread over the first axis while each sequential-test
    round's (K, m) mini-batch — the gather plus the per-section delta
    evaluation, fused or vmapped — shards its m rows over the second, via
    the logical-axis rules in :mod:`repro.distributed.sharding`. The deltas
    are re-replicated before the test statistics reduce, so a 2-d-sharded
    run is bit-for-bit the unsharded run (regression-tested at 4 forced
    host devices); the 2-d form also covers the masked superstep.

    Doctest — four subsampled chains, then the masked + adaptive form::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core import (ChainEnsemble, RandomWalk, ScheduleConfig,
        ...                         SubsampledMHConfig, from_iid_loglik)
        >>> x = 0.5 + jax.random.normal(jax.random.key(0), (300,))
        >>> target = from_iid_loglik(lambda th: -0.5 * th**2,
        ...                          lambda th, idx: -0.5 * (x[idx] - th) ** 2,
        ...                          None, 300)
        >>> cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
        >>> ens = ChainEnsemble(target, RandomWalk(0.1), num_chains=4, config=cfg)
        >>> state, samples, infos = ens.run(jax.random.key(1),
        ...                                 ens.init(jnp.zeros(())), 20)
        >>> samples.shape, infos.n_evaluated.shape
        ((4, 20), (4, 20))
        >>> fast = ChainEnsemble(target, RandomWalk(0.1), num_chains=4, config=cfg,
        ...                      stepping="masked", schedule=ScheduleConfig())
        >>> state, samples, infos = fast.run(jax.random.key(1),
        ...                                  fast.init(jnp.zeros(())), 20)
        >>> samples.shape, bool(jnp.all(infos.epsilon >= cfg.epsilon))
        ((4, 20), True)
    """

    target: PartitionedTarget | None = None
    proposal: Any = None
    num_chains: int = 1
    kernel: str = "subsampled"  # "subsampled" | "exact"
    config: SubsampledMHConfig | None = None
    chunk_size: int | None = None  # exact kernel: lax.map chunking
    collect: Callable[[Params], Any] | None = None
    # "auto" | True | False — shard_map over a 1-d chain mesh; or a 2-d
    # chains x data request: ("chains", "data") / {"chains": c, "data": d}
    shard: Any = "auto"
    chain_axis: str = "chains"
    data_axis: str = "data"
    stepping: str = "lockstep"  # "lockstep" | "masked" (subsampled only)
    schedule: ScheduleConfig | None = None  # adaptive per-chain controller
    fused_kernels: str = "auto"  # "auto" | "always" | "never" — (K, m) Pallas path
    transition: CycleOp | None = None  # composite cycle (replaces target+proposal)

    def __post_init__(self):
        if self.kernel not in ("subsampled", "exact"):
            raise ValueError(f"unknown kernel {self.kernel!r}")
        if self.stepping not in ("lockstep", "masked"):
            raise ValueError(f"unknown stepping {self.stepping!r}")
        if self.fused_kernels not in ("auto", "always", "never"):
            raise ValueError(f"unknown fused_kernels {self.fused_kernels!r}")
        if self.num_chains < 1:
            raise ValueError(f"num_chains must be >= 1, got {self.num_chains}")
        if self._shard_2d_request is not None:
            if self.transition is not None:
                raise ValueError(
                    "composite transitions run unsharded; the 2-d "
                    "shard=(chains, data) mesh supports single-kernel "
                    "ensembles only"
                )
            if self.kernel != "subsampled":
                raise ValueError(
                    "the 2-d shard=(chains, data) mesh requires the "
                    "subsampled kernel — only its sequential-test rounds "
                    "have a data axis to shard"
                )
        if self.transition is not None:
            if self.target is not None or self.proposal is not None:
                raise ValueError(
                    "pass either (target, proposal) or transition=cycle(...), not both"
                )
            if self.kernel != "subsampled" or self.config is not None or \
                    self.chunk_size is not None:
                raise ValueError(
                    "composite transitions take kernel/config per component "
                    "(SubsampledMHOp(..., config=)); the ensemble-level "
                    "kernel=, config=, and chunk_size= knobs do not apply"
                )
            if self.stepping != "lockstep":
                raise ValueError(
                    "composite transitions run on the lock-step scan; the masked "
                    "superstep supports single-kernel ensembles only"
                )
            if self.schedule is not None:
                raise ValueError(
                    "adaptive scheduling is not supported with composite "
                    "transitions yet (the controller assumes one target)"
                )
            if self.shard is True:
                raise ValueError(
                    "composite transitions run unsharded; use shard='auto' or False"
                )
            if self.fused_kernels == "always":
                names = self.transition.names
                missing = [names[i] for i, op in self.transition.mh_ops
                           if op.target.log_local_ensemble is None]
                if missing:
                    raise ValueError(
                        f"fused_kernels='always' but composite MH components "
                        f"{missing} carry no log_local_ensemble (build their "
                        "targets via repro.core.build_target)"
                    )
            return
        if self.target is None or self.proposal is None:
            raise ValueError("target and proposal are required without transition=")
        if self.kernel == "exact" and (self.stepping == "masked" or self.schedule):
            raise ValueError(
                "masked stepping / adaptive scheduling require the subsampled "
                "kernel (the exact kernel has no sequential test to overlap)"
            )
        if self.schedule is not None and self.schedule.adapt_proposal:
            import inspect

            try:
                params = inspect.signature(self.proposal).parameters
                takes_scale = len(params) >= 3 or any(
                    p.kind is inspect.Parameter.VAR_POSITIONAL
                    or p.kind is inspect.Parameter.VAR_KEYWORD
                    for p in params.values()
                )
            except (TypeError, ValueError):  # builtins etc: trust the caller
                takes_scale = True
            if not takes_scale:
                raise ValueError(
                    "schedule.adapt_proposal=True needs a proposal accepting "
                    "a third `scale` argument (e.g. repro.core.RandomWalk)"
                )
        if self.stepping == "masked" and self.shard is True:
            raise ValueError("masked stepping runs unsharded; use shard='auto' or False")
        if self.fused_kernels == "always" and self.kernel == "exact":
            raise ValueError(
                "fused_kernels='always' requires the subsampled kernel — only "
                "its sequential-test rounds route through log_local_ensemble"
            )
        if self.fused_kernels == "always" and self.target.log_local_ensemble is None:
            raise ValueError(
                "fused_kernels='always' but the target carries no "
                "log_local_ensemble (build it via repro.core.build_target)"
            )
        if self.fused_kernels == "always" and self.shard is True:
            raise ValueError(
                "fused_kernels='always' runs the (K, m) rounds unsharded; "
                "use shard='auto' or False"
            )

    # -- derived static config -------------------------------------------

    @functools.cached_property
    def _shard_2d_request(self):
        """Normalized 2-d mesh request: ``(chains_size | None, data_size |
        None)`` when ``shard`` asks for a chains x data mesh, else None."""
        s = self.shard
        if isinstance(s, (tuple, list)):
            if tuple(s) != (self.chain_axis, self.data_axis):
                raise ValueError(
                    f"tuple shard= must name the mesh axes "
                    f"({self.chain_axis!r}, {self.data_axis!r}), got {tuple(s)!r}"
                )
            return (None, None)
        if isinstance(s, dict):
            extra = set(s) - {self.chain_axis, self.data_axis}
            if extra:
                raise ValueError(
                    f"dict shard= keys must be a subset of "
                    f"{{{self.chain_axis!r}, {self.data_axis!r}}}, got extra {sorted(extra)}"
                )
            return (s.get(self.chain_axis), s.get(self.data_axis))
        if s not in ("auto", True, False):
            raise ValueError(
                f"shard must be 'auto', True, False, a "
                f"({self.chain_axis!r}, {self.data_axis!r}) tuple, or a dict "
                f"of axis sizes; got {s!r}"
            )
        return None

    @functools.cached_property
    def _mesh_2d(self):
        """The chains x data mesh for a 2-d ``shard=`` request (None on a
        single device — the unsharded program is identical there)."""
        req = self._shard_2d_request
        if req is None:
            return None
        devices = jax.devices()
        n = len(devices)
        if n <= 1:
            return None
        c, d = req
        if c is None and d is not None:
            if n % d:
                raise ValueError(f"data axis size {d} must divide device count {n}")
            c = n // d
        if c is not None:
            d = d if d is not None else n // c
            if c * d != n:
                raise ValueError(
                    f"mesh {self.chain_axis}={c} x {self.data_axis}={d} != "
                    f"device count {n}"
                )
        else:
            # Balanced default: the divisor of n nearest sqrt(n) that also
            # divides num_chains (c=1, a pure data mesh, always qualifies).
            cands = [k for k in range(1, n + 1)
                     if n % k == 0 and self.num_chains % k == 0]
            c = min(cands, key=lambda k: (abs(k - math.sqrt(n)), -k))
            d = n // c
        if self.num_chains % c:
            raise ValueError(
                f"num_chains ({self.num_chains}) must be divisible by the "
                f"{self.chain_axis!r} mesh axis size ({c})"
            )
        from jax.sharding import Mesh

        import numpy as np

        return Mesh(np.asarray(devices).reshape(c, d),
                    (self.chain_axis, self.data_axis))

    @property
    def _config(self) -> SubsampledMHConfig:
        return self.config or SubsampledMHConfig()

    @functools.cached_property
    def _buckets(self) -> tuple[int, ...]:
        if self.schedule is None:
            return (self._config.batch_size,)
        return self.schedule.buckets_for(self._config, self.target.num_sections)

    @functools.cached_property
    def _max_rounds(self) -> int:
        return adaptive_max_rounds(self._config, self.target.num_sections, self._buckets)

    def _fused_for(self, target: PartitionedTarget) -> bool:
        """Does the fused (K, m) route apply to ``target`` under this
        ensemble's ``fused_kernels`` setting? One decision for the masked
        superstep, the fused lock-step scan, and composite MH components —
        delegating the "auto" case to :func:`repro.kernels.ops.use_kernel`
        (TPU, or the ``REPRO_FUSED`` environment default)."""
        if self.fused_kernels == "never" or target.log_local_ensemble is None:
            return False
        from ..kernels import ops

        return ops.use_kernel(self.fused_kernels)

    def _use_fused(self) -> bool:
        return self.target is not None and self._fused_for(self.target)

    # -- state ------------------------------------------------------------

    def init(self, theta0: Params, *, batched: bool = False) -> EnsembleState:
        """Build the batched initial state.

        ``theta0`` is a single pytree broadcast to all chains, or (with
        ``batched=True``) a pytree whose leaves already carry a leading
        (num_chains,) axis — e.g. overdispersed starting points for R-hat.

        Example::

            >>> import jax.numpy as jnp
            >>> from repro.core import ChainEnsemble, RandomWalk, from_iid_loglik
            >>> t = from_iid_loglik(lambda th: -0.5 * th**2,
            ...                     lambda th, idx: jnp.zeros(idx.shape), None, 10)
            >>> ens = ChainEnsemble(t, RandomWalk(0.1), num_chains=3)
            >>> ens.init(jnp.zeros(2)).theta.shape
            (3, 2)
        """
        theta = theta0 if batched else _broadcast_chain_axis(theta0, self.num_chains)
        lead = jax.tree.leaves(theta)[0].shape[0]
        if lead != self.num_chains:
            raise ValueError(f"theta leading axis {lead} != num_chains {self.num_chains}")
        if self.transition is not None:
            sampler = _broadcast_chain_axis(init_cycle_samplers(self.transition),
                                            self.num_chains)
            return EnsembleState(theta, sampler, None)
        if self.kernel == "subsampled":
            state0, _, _ = make_sampler(self._config.sampler, self.target.num_sections)
            sampler = _broadcast_chain_axis(state0, self.num_chains)
        else:
            sampler = jnp.zeros((self.num_chains,), jnp.int32)
        ctrl = None
        if self.schedule is not None:
            ctrl = controller_init(
                self.schedule, self._config, self.target.num_sections, self.num_chains
            )
        return EnsembleState(theta, sampler, ctrl)

    # -- single-chain step with a uniform (key, theta, state) signature ---

    def _make_step(self):
        if self.kernel == "subsampled":
            scheduled = self.schedule is not None
            _, step = make_kernel(
                self.target, self.proposal, self._config, scheduled=scheduled,
                batch_max=max(self._buckets) if scheduled else None,
            )
            return step

        def exact_step(key, theta, state):
            theta, info = mh_step(key, theta, self.target, self.proposal, chunk_size=self.chunk_size)
            return theta, state, info

        return exact_step

    def _per_chain_keys(self, key: jax.Array) -> jax.Array:
        key = jnp.asarray(key)
        typed = jnp.issubdtype(key.dtype, jax.dtypes.prng_key)
        # Per-chain keys are a (K,) typed-key array or (K, 2) legacy uint32
        # array; a bare legacy key of shape (2,) must NOT be mistaken for two
        # per-chain keys when num_chains == 2.
        batched = (key.ndim == 1 and typed) or (key.ndim == 2 and not typed)
        if batched and key.shape[0] == self.num_chains:
            return key
        return jax.random.split(key, self.num_chains)

    @functools.cached_property
    def _run_jit(self):
        step = self._make_step()
        collect = self.collect or (lambda t: t)
        sched = self.schedule
        buckets = self._buckets
        max_rounds = self._max_rounds
        n_total = self.target.num_sections
        eps_floor = sched.epsilon_floor(self._config) if sched else 0.0
        adapt_prop = sched is not None and sched.adapt_proposal

        def one_chain(keys, theta0, sampler0, ctrl0):
            # ``keys``: this chain's (num_steps,) per-step key row.
            if sched is None:

                def body(carry, k):
                    theta, sstate, ctrl = carry
                    theta, sstate, info = step(k, theta, sstate)
                    return (theta, sstate, ctrl), (collect(theta), info)

            else:

                def body(carry, k):
                    theta, sstate, ctrl = carry
                    eps, meff = controller_params(ctrl, buckets)
                    theta, sstate, info = step(
                        k, theta, sstate, eps, meff, max_rounds,
                        prop_scale=ctrl.sigma_scale if adapt_prop else None,
                    )
                    ctrl = controller_update(ctrl, info, sched, buckets, n_total, eps_floor)
                    return (theta, sstate, ctrl), (collect(theta), info)

            (theta, sstate, ctrl), (samples, infos) = jax.lax.scan(
                body, (theta0, sampler0, ctrl0), keys
            )
            return theta, sstate, ctrl, samples, infos

        def run_all(step_keys, theta, sampler, ctrl, num_steps):
            del num_steps  # static; implied by step_keys' trailing axis
            fn = jax.vmap(one_chain)
            mesh = self._chain_mesh()
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P

                spec = P(self.chain_axis)
                fn = shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec, spec),
                               out_specs=(spec,) * 5, check_rep=False)
            return fn(step_keys, theta, sampler, ctrl)

        return jax.jit(run_all, static_argnames=("num_steps",))

    # -- batched-transition lock-step scan --------------------------------

    def _make_run_batched(self, use_fused: bool):
        """Lock-step scan whose sequential-test rounds are (K, m) blocks —
        through ``target.log_local_ensemble`` when ``use_fused`` (the
        fused-kernel route; only the block evaluation's float order differs,
        parity-tested against ``fused_kernels="never"``), through
        ``vmap(target.log_local)`` otherwise (round-for-round AND bit-for-bit
        the vmapped scan — the route the 2-d chains x data mesh runs on).
        Chain semantics match the vmapped scan round for round."""
        config = self._config
        sched = self.schedule
        buckets = self._buckets
        collect = self.collect or (lambda t: t)
        K = self.num_chains
        n_total = self.target.num_sections
        eps_floor = sched.epsilon_floor(config) if sched else 0.0
        transition = _make_batched_transition(
            self.target, self.proposal, config, K, use_fused,
            adaptive=sched is not None,
            batch_max=max(buckets) if sched else None,
            max_rounds=self._max_rounds,
        )
        adapt_prop = sched is not None and sched.adapt_proposal

        def run_all(step_keys, theta, sampler, ctrl, num_steps):
            del num_steps
            step_keys = jnp.swapaxes(step_keys, 0, 1)  # (num_steps, K)

            def body(carry, keys_t):
                theta, sampler, ctrl = carry
                theta = _lc_chains(theta)
                sampler = _lc_chains(sampler)
                if sched is None:
                    eps = jnp.full((K,), config.epsilon, jnp.float32)
                    meff = jnp.full((K,), config.batch_size, jnp.int32)
                else:
                    eps, meff = jax.vmap(lambda c: controller_params(c, buckets))(ctrl)
                theta, sampler, info = transition(
                    keys_t, theta, sampler, eps, meff,
                    ctrl.sigma_scale if adapt_prop else None,
                )
                if sched is not None:
                    ctrl = jax.vmap(
                        lambda c, i: controller_update(c, i, sched, buckets, n_total, eps_floor)
                    )(ctrl, info)
                return (theta, sampler, ctrl), (jax.vmap(collect)(theta), info)

            (theta, sampler, ctrl), (samples, infos) = jax.lax.scan(
                body, (theta, sampler, ctrl), step_keys
            )
            swap = lambda t: jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), t)
            return theta, sampler, ctrl, swap(samples), swap(infos)

        return jax.jit(run_all, static_argnames=("num_steps",))

    @functools.cached_property
    def _run_lockstep_fused_jit(self):
        return self._make_run_batched(True)

    @functools.cached_property
    def _run_lockstep_batched_jit(self):
        return self._make_run_batched(False)

    # -- composite cycle --------------------------------------------------

    @functools.cached_property
    def _run_composite_jit(self):
        """Lock-step scan over a composite cycle: per engine transition each
        component applies once, in order — batched subsampled-MH transitions
        (fused (K, m) rounds when dispatch selects them) interleaved with
        vmapped opaque sweeps. Key discipline matches
        :func:`repro.core.composite.run_cycle_sequential` per chain."""
        cyc = self.transition
        names = cyc.names
        K = self.num_chains
        collect = self.collect or (lambda t: t)
        n_ops = len(cyc.ops)
        comps = []
        for op in cyc.ops:
            if isinstance(op, SubsampledMHOp):
                trans = _make_batched_transition(
                    op.target, op.proposal, op.cfg, K,
                    self._fused_for(op.target), max_rounds=op.max_rounds,
                )
                comps.append(("mh", trans, op.cfg))
            else:
                comps.append(("sweep", op.fn, op.has_info, op.batched_fn))

        def run_all(step_keys, theta, samplers, ctrl, num_steps):
            del ctrl, num_steps  # composite cycles run unscheduled
            step_keys = jnp.swapaxes(step_keys, 0, 1)  # (num_steps, K)

            def body(carry, keys_t):
                theta, samplers = carry
                # single-component cycles consume the step key directly
                # (mirrors run_cycle_sequential: cycle([op]) == bare kernel)
                if n_ops > 1:
                    subkeys = jax.vmap(lambda k: jax.random.split(k, n_ops))(keys_t)
                else:
                    subkeys = keys_t[:, None]
                infos = {}
                new_s = list(samplers)
                for i, comp in enumerate(comps):
                    k_i = subkeys[:, i]
                    if comp[0] == "mh":
                        _, trans, cfg = comp
                        eps = jnp.full((K,), cfg.epsilon, jnp.float32)
                        meff = jnp.full((K,), cfg.batch_size, jnp.int32)
                        theta, new_s[i], info = trans(k_i, theta, samplers[i], eps, meff)
                        infos[names[i]] = info
                    else:
                        _, fn, has_info, batched_fn = comp
                        # a natively chain-batched sweep (fused pgibbs scan)
                        # replaces the opaque per-chain vmap when provided
                        if batched_fn is not None:
                            out = batched_fn(k_i, theta)
                        else:
                            out = jax.vmap(fn)(k_i, theta)
                        if has_info:
                            theta, infos[names[i]] = out
                        else:
                            theta = out
                return (theta, tuple(new_s)), (jax.vmap(collect)(theta), infos)

            (theta, samplers), (samples, infos) = jax.lax.scan(
                body, (theta, samplers), step_keys
            )
            swap = lambda t: jax.tree.map(lambda l: jnp.swapaxes(l, 0, 1), t)
            return theta, samplers, None, swap(samples), swap(infos)

        return jax.jit(run_all, static_argnames=("num_steps",))

    # -- masked-continuation superstep ------------------------------------

    @functools.cached_property
    def _run_masked_jit(self):
        target = self.target
        proposal = self.proposal
        config = self._config
        sched = self.schedule
        collect = self.collect or (lambda t: t)
        buckets = self._buckets
        m_max = max(buckets)
        max_rounds = self._max_rounds
        n_total = target.num_sections
        eps_floor = sched.epsilon_floor(config) if sched else 0.0
        _, reset_fn, draw_fn = make_sampler(config.sampler, n_total)
        draw_bounded = make_bounded_draw(config.sampler)
        adaptive = sched is not None
        adapt_prop = adaptive and sched.adapt_proposal
        use_fused = self._use_fused()
        K = self.num_chains

        def knobs(ctrl):
            if not adaptive:
                return (jnp.full((K,), config.epsilon, jnp.float32),
                        jnp.full((K,), config.batch_size, jnp.int32))
            return jax.vmap(lambda c: controller_params(c, buckets))(ctrl)

        def run_masked(step_keys, theta, sampler, ctrl, num_steps):
            keys = step_keys[:, 0]  # placeholder only; replaced at first start
            eps0, meff0 = knobs(ctrl)
            zero = jnp.zeros((K,), jnp.int32)
            sample_sd = jax.eval_shape(jax.vmap(collect), theta)
            samples0 = jax.tree.map(
                lambda s: jnp.zeros((K, num_steps) + s.shape[1:], s.dtype), sample_sd
            )
            infos0 = SubsampledMHInfo(
                accepted=jnp.zeros((K, num_steps), bool),
                n_evaluated=jnp.zeros((K, num_steps), jnp.int32),
                rounds=jnp.zeros((K, num_steps), jnp.int32),
                mu_hat=jnp.zeros((K, num_steps), jnp.float32),
                mu0=jnp.zeros((K, num_steps), jnp.float32),
                pvalue=jnp.zeros((K, num_steps), jnp.float32),
                log_u=jnp.zeros((K, num_steps), jnp.float32),
                epsilon=jnp.zeros((K, num_steps), jnp.float32),
                batch_eff=jnp.zeros((K, num_steps), jnp.int32),
            )
            carry0 = _MaskedCarry(
                test_key=keys,  # placeholder; replaced at each chain's first start
                theta=theta,
                theta_prop=theta,
                log_u=jnp.zeros((K,), jnp.float32),
                mu0=jnp.zeros((K,), jnp.float32),
                welford=Welford(*(jnp.zeros((K,), jnp.float32) for _ in range(3))),
                sampler=sampler,
                controller=ctrl,
                epsilon=eps0,
                batch_eff=meff0,
                steps_done=zero,
                rounds=zero,
                fresh=jnp.ones((K,), bool),
                samples=samples0,
                infos=infos0,
                supersteps=jnp.zeros((), jnp.int32),
            )
            cap = jnp.int32(num_steps * max_rounds + num_steps + 1)

            def cond(c: _MaskedCarry):
                return jnp.any(c.steps_done < num_steps) & (c.supersteps < cap)

            def body(c: _MaskedCarry):
                active = c.steps_done < num_steps
                start = c.fresh & active
                pos = jnp.minimum(c.steps_done, num_steps - 1)

                # --- transition start: propose, reset test state (Alg.3 2-6).
                # Guarded by a scalar cond: mid-test supersteps (no chain
                # starting) skip the proposal / log_global / reset work
                # entirely instead of computing and discarding it.
                def start_block(_):
                    k_step = jax.vmap(lambda ks, i: ks[i])(step_keys, pos)
                    if adapt_prop:
                        th_p, mu0_n, log_u_n, ktest_n = jax.vmap(
                            lambda k, t, s: propose_and_mu0(k, t, target, proposal, s)
                        )(k_step, c.theta, c.controller.sigma_scale)
                    else:
                        th_p, mu0_n, log_u_n, ktest_n = jax.vmap(
                            lambda k, t: propose_and_mu0(k, t, target, proposal)
                        )(k_step, c.theta)
                    eps_n, meff_n = knobs(c.controller)
                    return (
                        jnp.where(start, ktest_n, c.test_key),
                        _bselect(start, th_p, c.theta_prop),
                        jnp.where(start, mu0_n, c.mu0),
                        jnp.where(start, log_u_n, c.log_u),
                        jnp.where(start, eps_n, c.epsilon),
                        jnp.where(start, meff_n, c.batch_eff),
                        _bselect(
                            start,
                            Welford(*(jnp.zeros((K,), jnp.float32) for _ in range(3))),
                            c.welford,
                        ),
                        _bselect(start, jax.vmap(reset_fn)(c.sampler), c.sampler),
                        jnp.where(start, 0, c.rounds),
                    )

                def no_start(_):
                    return (c.test_key, c.theta_prop, c.mu0, c.log_u, c.epsilon,
                            c.batch_eff, c.welford, c.sampler, c.rounds)

                (test_key, theta_prop, mu0, log_u, epsilon, batch_eff, welford,
                 sampler, rounds) = jax.lax.cond(jnp.any(start), start_block, no_start, None)

                # --- one sequential-test round for every active chain
                theta_cur = _lc_chains(c.theta)
                theta_prop = _lc_chains(theta_prop)
                pairs = jax.vmap(jax.random.split)(test_key)
                tkey, sub = pairs[:, 0], pairs[:, 1]
                if adaptive:
                    sampler2, idx, valid = jax.vmap(
                        lambda k, s, m: draw_bounded(k, s, m_max, m)
                    )(sub, sampler, batch_eff)
                else:
                    sampler2, idx, valid = jax.vmap(
                        lambda k, s: draw_fn(k, s, m_max)
                    )(sub, sampler)
                idx = _lc_round(idx)
                if use_fused:
                    l = target.log_local_ensemble(theta_cur, theta_prop, idx)
                else:
                    l = jax.vmap(target.log_local)(theta_cur, theta_prop, idx)
                l = _lc_replicate_round(l)
                w2 = jax.vmap(Welford.merge_batch)(welford, l, valid)
                decision, pval, test_ok, exhausted = jax.vmap(
                    lambda w, m, e: test_round_decision(w, m, n_total, e)
                )(w2, mu0, epsilon)
                rounds2 = rounds + 1
                done = active & (test_ok | exhausted | (rounds2 >= max_rounds))

                # --- commit finished transitions (Alg.3 15-19)
                theta_new = _bselect(done & decision, theta_prop, c.theta)
                info_now = SubsampledMHInfo(
                    accepted=decision,
                    n_evaluated=w2.count.astype(jnp.int32),
                    rounds=rounds2,
                    mu_hat=w2.mean,
                    mu0=mu0,
                    pvalue=pval,
                    log_u=log_u,
                    epsilon=epsilon,
                    batch_eff=batch_eff,
                )
                scatter = jax.vmap(_scatter_at)
                samples = jax.tree.map(
                    lambda buf, val: scatter(buf, pos, val, done),
                    c.samples, jax.vmap(collect)(theta_new),
                )
                infos = jax.tree.map(
                    lambda buf, val: scatter(buf, pos, val, done), c.infos, info_now
                )
                ctrl = c.controller
                if adaptive:
                    ctrl2 = jax.vmap(
                        lambda cs, i: controller_update(cs, i, sched, buckets, n_total, eps_floor)
                    )(ctrl, info_now)
                    ctrl = _bselect(done, ctrl2, ctrl)

                return _MaskedCarry(
                    test_key=jnp.where(active, tkey, test_key),
                    theta=theta_new,
                    theta_prop=theta_prop,
                    log_u=log_u,
                    mu0=mu0,
                    welford=_bselect(active, w2, welford),
                    sampler=_bselect(active, sampler2, sampler),
                    controller=ctrl,
                    epsilon=epsilon,
                    batch_eff=batch_eff,
                    steps_done=c.steps_done + done.astype(jnp.int32),
                    rounds=jnp.where(active, rounds2, rounds),
                    fresh=jnp.where(active, done, c.fresh),
                    samples=samples,
                    infos=infos,
                    supersteps=c.supersteps + 1,
                )

            end = jax.lax.while_loop(cond, body, carry0)
            return end.theta, end.sampler, end.controller, end.samples, end.infos

        return jax.jit(run_masked, static_argnames=("num_steps",))

    def _chain_mesh(self):
        if self.shard is False or self.stepping == "masked" or self.transition is not None:
            return None
        if self._shard_2d_request is not None:
            return None  # 2-d requests route through the batched runners
        devices = jax.devices()
        if len(devices) <= 1:
            return None  # single device: the plain vmap path is identical
        if self.num_chains % len(devices) != 0:
            if self.shard is True:
                raise ValueError(
                    f"shard=True needs num_chains ({self.num_chains}) divisible "
                    f"by the device count ({len(devices)})"
                )
            return None
        from jax.sharding import Mesh

        import numpy as np

        return Mesh(np.asarray(devices), (self.chain_axis,))

    # -- drivers ----------------------------------------------------------

    @functools.cached_property
    def _split_keys_jit(self):
        """(K,) per-chain keys -> (K, num_steps) step keys, exactly the split
        the scanned runners historically performed internally."""
        return jax.jit(
            lambda keys, num_steps: jax.vmap(
                lambda k: jax.random.split(k, num_steps)
            )(keys),
            static_argnames=("num_steps",),
        )

    @functools.cached_property
    def _fold_keys_jit(self):
        return jax.jit(
            lambda keys, start, num_steps: jax.vmap(
                lambda k: jax.vmap(
                    lambda t: jax.random.fold_in(k, t)
                )(start + jnp.arange(num_steps, dtype=jnp.uint32))
            )(keys),
            static_argnames=("num_steps",),
        )

    def step_keys(self, key: jax.Array, start: int, num_steps: int) -> jax.Array:
        """The canonical *resumable* step-key schedule: step ``t`` of chain
        ``c`` gets ``fold_in(chain_key_c, t)``, independent of how the run is
        chunked. ``ens.run(None, state, n, step_keys=ens.step_keys(key, o, n))``
        advanced in any block sizes reproduces one offline
        ``ens.run(None, state0, total, step_keys=ens.step_keys(key, 0, total))``
        bit for bit — the contract :class:`repro.serving.ResidentEnsemble`
        and :meth:`run_timed`'s ``start_step=`` resumption are built on.
        (The default :meth:`run` schedule splits ``key`` per step instead and
        is *not* resumable across chunk boundaries.)
        """
        keys = self._per_chain_keys(key)
        return self._fold_keys_jit(keys, jnp.uint32(start), num_steps=num_steps)

    def run(self, key: jax.Array | None, state: EnsembleState, num_steps: int,
            *, step_keys: jax.Array | None = None):
        """Advance every chain ``num_steps`` transitions in one XLA program.

        Returns ``(state, samples, infos)`` with ``samples`` leaves shaped
        (K, num_steps, ...) and ``infos`` leaves (K, num_steps). ``key`` may
        be one key (split per chain) or a (K,) per-chain key array.

        ``step_keys`` (a (K, num_steps) key array, e.g. from
        :meth:`step_keys`) bypasses the internal per-chain splitting — the
        hook for resumable serving runs; ``key`` is then ignored and may be
        ``None``.
        """
        if step_keys is None:
            keys = self._per_chain_keys(key)
            step_keys = self._split_keys_jit(keys, num_steps=num_steps)
        else:
            lead = jnp.asarray(step_keys).shape[:2] if hasattr(step_keys, "shape") else None
            if lead != (self.num_chains, num_steps):
                raise ValueError(
                    f"step_keys must be a ({self.num_chains}, {num_steps}) key "
                    f"array, got leading shape {lead}"
                )
        mesh2 = self._mesh_2d
        if self.transition is not None:
            runner = self._run_composite_jit
        elif self.stepping == "masked":
            runner = self._run_masked_jit
        elif self._shard_2d_request is not None:
            # 2-d chains x data requests run the batched-transition scan (the
            # only lock-step form whose rounds expose a shardable data axis);
            # on a single device the same runner executes unsharded —
            # bit-for-bit the vmapped scan when unfused.
            runner = (self._run_lockstep_fused_jit if self._use_fused()
                      else self._run_lockstep_batched_jit)
        elif (self.kernel == "subsampled" and self._use_fused()
              and (self.fused_kernels == "always" or self._chain_mesh() is None)):
            # The fused lock-step scan runs unsharded. An explicit "always"
            # wins over the chain mesh (shard=True + "always" is rejected at
            # construction); under "auto" with a mesh present, the vmapped
            # scan keeps the multi-device fan-out instead.
            runner = self._run_lockstep_fused_jit
        else:
            runner = self._run_jit
        if mesh2 is not None:
            # Activate the logical-axis rules while tracing/running so the
            # lc constraints in the round loop (and in the kernel-family
            # registry's gathers) bind to this mesh.
            with logical_axis_rules(mesh2):
                theta, sampler, ctrl, samples, infos = runner(
                    step_keys, state.theta, state.sampler_state, state.controller,
                    num_steps=num_steps
                )
        else:
            theta, sampler, ctrl, samples, infos = runner(
                step_keys, state.theta, state.sampler_state, state.controller,
                num_steps=num_steps
            )
        return EnsembleState(theta, sampler, ctrl), samples, infos

    def run_timed(self, key: jax.Array, state: EnsembleState, num_steps: int,
                  block_every: int = 1, *, start_step: int = 0, on_block=None):
        """Host-chunked loop recording wall clock, the multi-chain analog of
        :func:`repro.core.chain.run_chain_timed`. Compile time is excluded.

        Steps run on the **resumable** :meth:`step_keys` schedule: global
        step ``start_step + i`` of chain ``c`` is keyed by
        ``fold_in(chain_key_c, start_step + i)``, so consecutive calls with
        advancing ``start_step`` (and the returned state) continue one
        logical run bit for bit — the incremental-refresh contract of
        :class:`repro.serving.ResidentEnsemble`. ``on_block(state, samples,
        infos, steps_done)`` (optional) is invoked after every block inside
        the timed window — the collect hook a serving loop uses to stream
        draws out while the next block runs.

        Returns (state, dict) with ``transitions_per_sec`` aggregated over
        chains — the number ``benchmarks/multichain_bench.py`` reports —
        plus ``next_step`` (pass it back as ``start_step`` to resume).

        Example::

            >>> import jax, jax.numpy as jnp
            >>> from repro.core import ChainEnsemble, RandomWalk, from_iid_loglik
            >>> x = jax.random.normal(jax.random.key(0), (50,))
            >>> t = from_iid_loglik(lambda th: -0.5 * th**2,
            ...                     lambda th, idx: -0.5 * (x[idx] - th) ** 2,
            ...                     None, 50)
            >>> ens = ChainEnsemble(t, RandomWalk(0.1), num_chains=2)
            >>> state, out = ens.run_timed(jax.random.key(1),
            ...                            ens.init(jnp.zeros(())), 4, block_every=2)
            >>> out["samples"].shape, out["wall"] > 0, out["next_step"]
            ((2, 4), True, 4)
        """
        import time

        import numpy as np

        # All step keys for this window, computed (and warmed) up front so
        # neither key generation nor a ragged final block compiles inside
        # the timed region (num_steps is a static jit argument).
        all_keys = self.step_keys(key, start_step, num_steps)
        jax.block_until_ready(all_keys)
        block_sizes = {min(block_every, num_steps)}
        if num_steps % block_every:
            block_sizes.add(num_steps % block_every)
        for n in block_sizes:
            warm, _, _ = self.run(None, state, n, step_keys=all_keys[:, :n])
            jax.block_until_ready(warm.theta)
        samples_blocks, infos_blocks = [], []
        t0 = time.perf_counter()
        done = 0
        while done < num_steps:
            n = min(block_every, num_steps - done)
            state, samples, infos = self.run(
                None, state, n, step_keys=all_keys[:, done:done + n]
            )
            jax.block_until_ready(state.theta)
            samples_blocks.append(samples)
            infos_blocks.append(infos)
            done += n
            if on_block is not None:
                on_block(state, samples, infos, start_step + done)
        wall = time.perf_counter() - t0
        cat = lambda xs: jax.tree.map(lambda *ls: np.concatenate([np.asarray(l) for l in ls], axis=1), *xs)
        return state, {
            "samples": cat(samples_blocks),
            "infos": cat(infos_blocks),
            "wall": wall,
            "transitions_per_sec": self.num_chains * num_steps / max(wall, 1e-12),
            "next_step": start_step + num_steps,
        }


def run_ensemble(
    key: jax.Array,
    theta0: Params,
    target: PartitionedTarget,
    proposal,
    num_chains: int,
    num_steps: int,
    kernel: str = "subsampled",
    config: SubsampledMHConfig | None = None,
    **kw,
):
    """One-shot convenience wrapper: init + run. Returns (state, samples, infos).

    Extra keyword arguments reach :class:`ChainEnsemble` — e.g.
    ``stepping="masked"``, ``schedule=ScheduleConfig()`` for the adaptive
    masked-continuation engine.

    Example::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core import RandomWalk, from_iid_loglik, run_ensemble
        >>> x = jax.random.normal(jax.random.key(0), (100,))
        >>> t = from_iid_loglik(lambda th: -0.5 * th**2,
        ...                     lambda th, idx: -0.5 * (x[idx] - th) ** 2, None, 100)
        >>> _, samples, infos = run_ensemble(jax.random.key(1), jnp.zeros(()),
        ...                                  t, RandomWalk(0.1), num_chains=2,
        ...                                  num_steps=10)
        >>> samples.shape
        (2, 10)
    """
    ens = ChainEnsemble(target, proposal, num_chains, kernel=kernel, config=config, **kw)
    state = ens.init(theta0)
    return ens.run(key, state, num_steps)
