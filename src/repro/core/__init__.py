"""Core: sublinear-time approximate MH transitions on partitioned scaffolds.

The paper's primary contribution as a composable JAX module:

  - ``PartitionedTarget``: the tensorized global/local scaffold partition,
  - ``sequential_test``: Alg. 2 (sequential Student-t accept test),
  - ``subsampled_mh_step`` / ``make_kernel``: Alg. 3,
  - ``mh_step``: the exact O(N) baseline (Alg. 1),
  - samplers: O(m)-per-round without-replacement draws,
  - ``run_chain`` drivers and Sec-3.3 safeguard diagnostics.
"""
from .chain import acceptance_rate, run_chain, run_chain_timed
from .composite import (
    CycleOp,
    SubsampledMHOp,
    SweepOp,
    cycle,
    run_cycle_sequential,
)
from .ensemble import ChainEnsemble, EnsembleState, run_ensemble
from .mh import MHInfo, mh_step
from .proposals import MALA, IndependentGaussian, RandomWalk
from .samplers import (
    FisherYatesState,
    StreamSliceState,
    fy_draw,
    fy_draw_bounded,
    fy_from_buffer,
    fy_init,
    fy_reset,
    make_bounded_draw,
    make_sampler,
    stream_draw,
    stream_draw_bounded,
    stream_init,
    stream_reset,
)
from .safeguard import TrialReport, trial_run_report
from .schedule import (
    ControllerState,
    ScheduleConfig,
    controller_init,
    controller_params,
    controller_update,
)
from .sequential_test import (
    SeqTestResult,
    expected_batches_theoretical,
    sequential_test,
    test_round_decision,
)
from .stats import (
    Welford,
    autocorrelation,
    effective_sample_size,
    ensemble_summary,
    finite_population_std_err,
    jarque_bera,
    multichain_ess,
    predictive_risk,
    split_rhat,
    student_t_sf,
    tail_latency_summary,
    two_sided_t_pvalue,
)
from .subsampled_mh import (
    SubsampledMHConfig,
    SubsampledMHInfo,
    adaptive_max_rounds,
    make_kernel,
    propose_and_mu0,
    subsampled_mh_step,
)
from .target import PartitionedTarget, from_iid_loglik
from .target_builder import (
    KernelFamily,
    build_target,
    get_family,
    register_family,
    registered_families,
)

__all__ = [
    "MALA",
    "ChainEnsemble",
    "ControllerState",
    "CycleOp",
    "EnsembleState",
    "FisherYatesState",
    "IndependentGaussian",
    "KernelFamily",
    "MHInfo",
    "PartitionedTarget",
    "RandomWalk",
    "ScheduleConfig",
    "SeqTestResult",
    "StreamSliceState",
    "SubsampledMHConfig",
    "SubsampledMHInfo",
    "SubsampledMHOp",
    "SweepOp",
    "TrialReport",
    "Welford",
    "acceptance_rate",
    "adaptive_max_rounds",
    "autocorrelation",
    "build_target",
    "controller_init",
    "controller_params",
    "controller_update",
    "cycle",
    "effective_sample_size",
    "ensemble_summary",
    "expected_batches_theoretical",
    "finite_population_std_err",
    "from_iid_loglik",
    "fy_draw",
    "fy_draw_bounded",
    "fy_from_buffer",
    "fy_init",
    "fy_reset",
    "get_family",
    "jarque_bera",
    "make_bounded_draw",
    "make_kernel",
    "make_sampler",
    "mh_step",
    "multichain_ess",
    "predictive_risk",
    "propose_and_mu0",
    "register_family",
    "registered_families",
    "run_chain",
    "run_chain_timed",
    "run_cycle_sequential",
    "run_ensemble",
    "sequential_test",
    "split_rhat",
    "stream_draw",
    "stream_draw_bounded",
    "stream_init",
    "stream_reset",
    "student_t_sf",
    "subsampled_mh_step",
    "tail_latency_summary",
    "test_round_decision",
    "trial_run_report",
    "two_sided_t_pvalue",
]
