"""Composite transition operators: the paper's ``[infer (cycle (...))]`` at
ensemble scale.

All three applications of the paper run *programs* of kernels, not a single
kernel: BayesLR is one subsampled-MH move, but stochastic volatility cycles
``subsampled_mh sig/phi`` with a particle-Gibbs sweep over the latent paths,
and the joint DP mixture cycles MH over alpha, Gibbs over assignments, and
subsampled MH over expert weights. This module gives
:class:`repro.core.ensemble.ChainEnsemble` that same compositional shape:

  :func:`cycle`          — an ordered cycle of component operators,
  :class:`SubsampledMHOp` — a per-variable subsampled-MH kernel (its target
                           may read latent state from ``theta``; when the
                           target carries ``log_local_ensemble`` and dispatch
                           selects the fused path, its rounds run as (K, m)
                           fused-kernel blocks),
  :class:`SweepOp`        — an opaque inner kernel ``fn(key, theta) -> theta``
                           (or ``-> (theta, info)``) vmapped over chains:
                           Gibbs scans, particle-Gibbs sweeps, or any jittable
                           transition the engine should not introspect.

:func:`run_cycle_sequential` is the single-chain reference driver with the
identical key-splitting discipline — chain k of a composite ensemble seeded
with key k reproduces it bit for bit (regression-tested), which is what
makes the ensemble port of stochvol/jointdpm a pure engine swap.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .samplers import make_sampler
from .subsampled_mh import SubsampledMHConfig, adaptive_max_rounds, subsampled_mh_step
from .target import PartitionedTarget

Params = Any


@dataclasses.dataclass(frozen=True)
class SubsampledMHOp:
    """One per-variable subsampled-MH component of a composite cycle.

    ``target.num_sections`` must be static; the target's ``log_local`` /
    ``log_local_ensemble`` may read latent state (e.g. particle-Gibbs paths)
    from ``theta`` as long as ``proposal`` does not move those leaves.
    """

    target: PartitionedTarget
    proposal: Any
    config: SubsampledMHConfig | None = None
    name: str | None = None

    @property
    def cfg(self) -> SubsampledMHConfig:
        return self.config or SubsampledMHConfig()

    @property
    def max_rounds(self) -> int:
        cfg = self.cfg
        return adaptive_max_rounds(cfg, self.target.num_sections, (cfg.batch_size,))


@dataclasses.dataclass(frozen=True)
class SweepOp:
    """An opaque inner kernel cycled between MH moves.

    ``fn(key, theta) -> theta``, or ``fn(key, theta) -> (theta, info)`` with
    ``has_info=True`` (the info pytree is recorded per step under this op's
    name, like the MH ops' :class:`~repro.core.subsampled_mh.SubsampledMHInfo`).

    ``batched_fn(keys, theta) -> theta`` (optional) is the natively
    chain-batched form: ``keys`` carries a leading chain axis and every
    ``theta`` leaf a matching one. When set, the ensemble's composite runner
    calls it instead of ``jax.vmap(fn)`` — for sweeps that restructure the
    chain axis themselves (e.g. the fused particle-Gibbs scan in
    :mod:`repro.kernels.pgibbs`, which advances the whole K x S x P slab per
    time step). It must be semantically ``jax.vmap(fn)``; the single-chain
    ``fn`` remains the sequential twin the bit-for-bit contracts anchor on.
    """

    fn: Callable
    name: str | None = None
    has_info: bool = False
    batched_fn: Callable | None = None


@dataclasses.dataclass(frozen=True)
class CycleOp:
    """An ordered cycle of component operators — one engine transition applies
    each component once, in order (the paper's ``(cycle (...) 1)``)."""

    ops: tuple

    def __post_init__(self):
        if not self.ops:
            raise ValueError("cycle() needs at least one component operator")
        for op in self.ops:
            if not isinstance(op, (SubsampledMHOp, SweepOp)):
                raise TypeError(
                    f"cycle components must be SubsampledMHOp or SweepOp, got {op!r}"
                )
        names = self.names
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate component names: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(
            op.name if op.name is not None else f"op{i}"
            for i, op in enumerate(self.ops)
        )

    @property
    def mh_ops(self) -> tuple[tuple[int, SubsampledMHOp], ...]:
        return tuple(
            (i, op) for i, op in enumerate(self.ops) if isinstance(op, SubsampledMHOp)
        )


def cycle(ops) -> CycleOp:
    """Build a composite cycle operator from a sequence of components.

    Example — one MH variable cycled with an opaque sweep::

        >>> import jax.numpy as jnp
        >>> from repro.core import RandomWalk, SweepOp, SubsampledMHOp, cycle
        >>> from repro.core import from_iid_loglik
        >>> t = from_iid_loglik(lambda th: -0.5 * th**2,
        ...                     lambda th, idx: jnp.zeros(idx.shape), None, 10)
        >>> c = cycle([SubsampledMHOp(t, RandomWalk(0.1), name="theta"),
        ...            SweepOp(lambda k, th: th, name="noop")])
        >>> c.names
        ('theta', 'noop')
    """
    return CycleOp(tuple(ops))


def init_cycle_samplers(op_cycle: CycleOp):
    """Initial sampler state per component (a zeros placeholder for sweeps)."""
    states = []
    for op in op_cycle.ops:
        if isinstance(op, SubsampledMHOp):
            s0, _, _ = make_sampler(op.cfg.sampler, op.target.num_sections)
            states.append(s0)
        else:
            states.append(jnp.zeros((), jnp.int32))
    return tuple(states)


def run_cycle_sequential(
    key: jax.Array,
    theta0: Params,
    op_cycle: CycleOp,
    num_steps: int,
    collect: Callable[[Params], Any] | None = None,
):
    """Single-chain reference driver for a composite cycle, one jitted scan.

    Per step the key splits into one subkey per component, consumed in cycle
    order — exactly the discipline of the ensemble's composite runner, so a
    K=1 :class:`~repro.core.ensemble.ChainEnsemble` with ``transition=cycle``
    reproduces this bit for bit. Returns ``(theta, samples, infos)`` with
    ``infos`` a dict keyed by component name (MH ops always; sweeps when
    ``has_info``).
    """
    collect = collect or (lambda t: t)
    ops = op_cycle.ops
    names = op_cycle.names
    machinery = []
    for op in ops:
        if isinstance(op, SubsampledMHOp):
            _, reset_fn, draw_fn = make_sampler(op.cfg.sampler, op.target.num_sections)
            machinery.append((reset_fn, draw_fn))
        else:
            machinery.append(None)
    samplers0 = init_cycle_samplers(op_cycle)

    def body(carry, k):
        theta, samplers = carry
        # A single-component cycle consumes the step key directly, so
        # cycle([op]) reproduces the bare kernel bit for bit.
        subkeys = jax.random.split(k, len(ops)) if len(ops) > 1 else jnp.asarray(k)[None]
        infos = {}
        new_samplers = list(samplers)
        for i, op in enumerate(ops):
            if isinstance(op, SubsampledMHOp):
                reset_fn, draw_fn = machinery[i]
                theta, new_samplers[i], info = subsampled_mh_step(
                    subkeys[i], theta, samplers[i], op.target, op.proposal,
                    op.cfg, reset_fn, draw_fn, max_rounds=op.max_rounds,
                )
                infos[names[i]] = info
            else:
                out = op.fn(subkeys[i], theta)
                if op.has_info:
                    theta, info = out
                    infos[names[i]] = info
                else:
                    theta = out
        return (theta, tuple(new_samplers)), (collect(theta), infos)

    keys = jax.random.split(key, num_steps)
    (theta, _), (samples, infos) = jax.lax.scan(body, (theta0, samplers0), keys)
    return theta, samples, infos
