"""Without-replacement mini-batch samplers with sublinear per-round cost.

The paper (Alg. 2/3) draws mini-batches of local sections *without
replacement*. Regenerating a full random permutation per transition costs
O(N) and would break the sublinear bound, so the default sampler is a
**partial Fisher–Yates shuffle** over a persistent index array:

  * state: (idx: int32[N], pos: scalar) — idx persists across transitions,
  * a round draws m indices with m in-place random swaps → O(m) work,
  * ``reset`` (per transition) just rewinds ``pos`` to 0; restarting a
    Fisher–Yates walk from position 0 with fresh randomness yields an exactly
    uniform without-replacement sample regardless of the array's current
    permutation state.

This is the faithful CPU-algorithm analog. At LM scale the bayes/ layer
instead slices a pre-permuted stream (distributionally equivalent, zero
gather cost on a sharded pool) — see DESIGN.md §3.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FisherYatesState(NamedTuple):
    idx: jax.Array  # int32[capacity], a permutation buffer
    pos: jax.Array  # int32 scalar, number of indices consumed this transition
    size: jax.Array  # int32 scalar, logical pool size (≤ capacity, may be traced)

    @property
    def capacity(self) -> int:
        return self.idx.shape[0]


def fy_init(n: int, size=None) -> FisherYatesState:
    """Pool over [0, n). ``size`` (possibly traced) restricts to a logical
    prefix — used when the pool is a padded member buffer (e.g. the points of
    one DP-mixture cluster, whose count N_k is itself random)."""
    if size is None:
        size = n
    return FisherYatesState(
        jnp.arange(n, dtype=jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.asarray(size, jnp.int32),
    )


def fy_from_buffer(idx_buffer: jax.Array, size) -> FisherYatesState:
    """Pool drawing from an explicit (padded) index buffer of logical ``size``."""
    return FisherYatesState(
        idx_buffer.astype(jnp.int32), jnp.zeros((), jnp.int32), jnp.asarray(size, jnp.int32)
    )


def fy_reset(state: FisherYatesState) -> FisherYatesState:
    """Rewind for a new transition (O(1)); the array itself persists."""
    return FisherYatesState(state.idx, jnp.zeros((), jnp.int32), state.size)


def fy_draw(
    key: jax.Array, state: FisherYatesState, m: int
) -> tuple[FisherYatesState, jax.Array, jax.Array]:
    """Draw ``m`` indices without replacement from the logical pool.

    Returns (new_state, indices int32[m], valid bool[m]). When fewer than m
    indices remain, the tail entries are repeats of valid draws but flagged
    invalid; callers mask them out of the test statistics.
    """
    cap = state.idx.shape[0]
    n = state.size
    keys = jax.random.split(key, m)

    def body(k, carry):
        idx, pos = carry
        p = jnp.minimum(pos + k, cap - 1)
        # swap target uniform in [p, n)
        span = jnp.maximum(n - p, 1)
        j = jnp.minimum(p + jax.random.randint(keys[k], (), 0, span, dtype=jnp.int32), cap - 1)
        vi, vj = idx[p], idx[j]
        idx = idx.at[p].set(vj).at[j].set(vi)
        return idx, pos

    idx, _ = jax.lax.fori_loop(0, m, body, (state.idx, state.pos))
    offs = state.pos + jnp.arange(m, dtype=jnp.int32)
    valid = offs < n
    out = idx[jnp.minimum(offs, cap - 1)]
    new_pos = jnp.minimum(state.pos + m, n)
    return FisherYatesState(idx, new_pos, state.size), out, valid


def fy_draw_bounded(
    key: jax.Array, state: FisherYatesState, m_max: int, m_eff: jax.Array
) -> tuple[FisherYatesState, jax.Array, jax.Array]:
    """Fisher–Yates draw with a *traced* effective batch size.

    Shapes stay static at ``m_max`` (so one compiled program serves every
    batch-size bucket of the adaptive scheduler); only the first ``m_eff``
    lanes are valid and only those consume pool positions — the next draw
    resumes at ``pos + m_eff``. The extra swaps beyond ``m_eff`` merely
    re-permute the tail, which leaves future without-replacement draws
    exactly uniform (any permutation is a valid Fisher–Yates start state).
    """
    m_eff = jnp.clip(jnp.asarray(m_eff, jnp.int32), 0, m_max)
    new_state, idx, valid = fy_draw(key, state, m_max)
    valid = valid & (jnp.arange(m_max, dtype=jnp.int32) < m_eff)
    new_pos = jnp.minimum(state.pos + m_eff, state.size)
    return FisherYatesState(new_state.idx, new_pos, state.size), idx, valid


class StreamSliceState(NamedTuple):
    """TPU-native without-replacement sampler over a pre-permuted pool.

    The pool (e.g. the resident global batch of sequences) is assumed already
    randomly ordered by the data pipeline; a round consumes the next
    contiguous slice. Equivalent in distribution to Fisher–Yates draws while
    keeping every gather local to its shard.
    """

    pos: jax.Array  # int32 scalar
    n: int

    @property
    def num_sections(self) -> int:
        return self.n


def stream_init(n: int) -> StreamSliceState:
    return StreamSliceState(jnp.zeros((), jnp.int32), n)


def stream_reset(state: StreamSliceState) -> StreamSliceState:
    return StreamSliceState(jnp.zeros((), jnp.int32), state.n)


def stream_draw(
    key: jax.Array, state: StreamSliceState, m: int
) -> tuple[StreamSliceState, jax.Array, jax.Array]:
    del key  # randomness lives in the stream order
    offs = state.pos + jnp.arange(m, dtype=jnp.int32)
    valid = offs < state.n
    out = jnp.minimum(offs, state.n - 1).astype(jnp.int32)
    return StreamSliceState(jnp.minimum(state.pos + m, state.n), state.n), out, valid


def stream_draw_bounded(
    key: jax.Array, state: StreamSliceState, m_max: int, m_eff: jax.Array
) -> tuple[StreamSliceState, jax.Array, jax.Array]:
    """Stream-slice draw with a traced effective batch size <= ``m_max``.

    Lanes past ``m_eff`` are flagged invalid and do NOT advance the stream
    position, so the pool is consumed at exactly the adaptive rate.
    """
    del key
    m_eff = jnp.clip(jnp.asarray(m_eff, jnp.int32), 0, m_max)
    offs = state.pos + jnp.arange(m_max, dtype=jnp.int32)
    valid = (offs < state.n) & (jnp.arange(m_max, dtype=jnp.int32) < m_eff)
    out = jnp.minimum(offs, state.n - 1).astype(jnp.int32)
    return StreamSliceState(jnp.minimum(state.pos + m_eff, state.n), state.n), out, valid


def make_sampler(kind: str, n: int):
    """Returns (init_state, reset_fn, draw_fn) for ``kind`` in {fy, stream}."""
    if kind == "fy":
        return fy_init(n), fy_reset, fy_draw
    if kind == "stream":
        return stream_init(n), stream_reset, stream_draw
    raise ValueError(f"unknown sampler kind: {kind!r}")


def make_bounded_draw(kind: str):
    """The bounded twin of ``make_sampler``'s draw_fn:
    draw(key, state, m_max static, m_eff traced) -> (state, idx[m_max], valid).
    """
    if kind == "fy":
        return fy_draw_bounded
    if kind == "stream":
        return stream_draw_bounded
    raise ValueError(f"unknown sampler kind: {kind!r}")
