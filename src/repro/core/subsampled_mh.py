"""Alg. 3: the sublinear-time subsampled MH transition.

Interleaves scaffold-section materialization with the sequential test: local
sections are only evaluated when the test asks for another mini-batch, so the
per-transition cost is O(m * rounds) with rounds determined adaptively by the
test — sublinear in N whenever the decision is statistically easy.

The kernel is fully jittable (while_loop + cond) and SPMD-friendly: with
sections sharded over the data mesh axes, each round's evaluation is data
parallel and the test statistics reduce with a scalar psum (see bayes/).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .samplers import make_sampler
from .sequential_test import sequential_test
from .target import PartitionedTarget

Params = Any


class SubsampledMHInfo(NamedTuple):
    accepted: jax.Array  # bool
    n_evaluated: jax.Array  # int32: sections actually evaluated
    rounds: jax.Array  # int32: mini-batches drawn
    mu_hat: jax.Array  # f32
    mu0: jax.Array  # f32
    pvalue: jax.Array  # f32
    log_u: jax.Array  # f32


@dataclasses.dataclass(frozen=True)
class SubsampledMHConfig:
    batch_size: int = 100  # m: mini-batch of local sections per round
    epsilon: float = 0.01  # tolerance of the sequential test
    max_rounds: int | None = None  # default ceil(N/m): exhaust the pool
    sampler: str = "fy"  # "fy" (Fisher–Yates) | "stream" (pre-permuted pool)


def _tree_select(pred: jax.Array, on_true: Params, on_false: Params) -> Params:
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def subsampled_mh_step(
    key: jax.Array,
    theta: Params,
    sampler_state,
    target: PartitionedTarget,
    proposal,
    config: SubsampledMHConfig,
    reset_fn,
    draw_fn,
) -> tuple[Params, Any, SubsampledMHInfo]:
    """One approximate MH transition (Alg. 3). Returns (theta', sampler', info).

    Steps map to the paper: 2 sample u; 3–4 construct+evaluate the global
    section; 6 compute mu0; 7–14 sequential test with lazily-materialized
    local sections; 15–19 accept or restore.
    """
    k_u, k_prop, k_test = jax.random.split(key, 3)
    log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))
    theta_p, corr = proposal(k_prop, theta)
    n = target.num_sections
    g = target.log_global(theta, theta_p) + corr  # Detach&Regen(global)
    mu0 = (log_u - g) / n

    res = sequential_test(
        key=k_test,
        mu0=mu0,
        draw_fn=draw_fn,
        eval_fn=lambda idx: target.log_local(theta, theta_p, idx),
        sampler_state=reset_fn(sampler_state),
        num_sections=n,
        batch_size=config.batch_size,
        epsilon=config.epsilon,
        max_rounds=config.max_rounds,
    )
    accept = res.decision
    theta_new = _tree_select(accept, theta_p, theta)
    info = SubsampledMHInfo(
        accepted=accept,
        n_evaluated=res.n_evaluated,
        rounds=res.rounds,
        mu_hat=res.mu_hat,
        mu0=mu0,
        pvalue=res.pvalue,
        log_u=log_u,
    )
    return theta_new, res.sampler_state, info


def make_kernel(
    target: PartitionedTarget,
    proposal,
    config: SubsampledMHConfig | None = None,
):
    """Bundle a jit-ready (init_state, step) pair.

    step(key, theta, sampler_state) -> (theta', sampler_state', info)
    """
    config = config or SubsampledMHConfig()
    state0, reset_fn, draw_fn = make_sampler(config.sampler, target.num_sections)

    def step(key, theta, sampler_state):
        return subsampled_mh_step(
            key, theta, sampler_state, target, proposal, config, reset_fn, draw_fn
        )

    return state0, step
