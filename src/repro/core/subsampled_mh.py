"""Alg. 3: the sublinear-time subsampled MH transition.

Interleaves scaffold-section materialization with the sequential test: local
sections are only evaluated when the test asks for another mini-batch, so the
per-transition cost is O(m * rounds) with rounds determined adaptively by the
test — sublinear in N whenever the decision is statistically easy.

The kernel is fully jittable (while_loop + cond) and SPMD-friendly: with
sections sharded over the data mesh axes, each round's evaluation is data
parallel and the test statistics reduce with a scalar psum (see bayes/).

The per-transition knobs (``epsilon``, effective batch size) may be traced
per-chain values supplied by the adaptive scheduler
(:mod:`repro.core.schedule`) instead of the static config scalars — the
ensemble threads its controller state through the keyword overrides of
:func:`subsampled_mh_step`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .samplers import make_bounded_draw, make_sampler
from .sequential_test import sequential_test
from .target import PartitionedTarget

Params = Any


class SubsampledMHInfo(NamedTuple):
    accepted: jax.Array  # bool
    n_evaluated: jax.Array  # int32: sections actually evaluated
    rounds: jax.Array  # int32: mini-batches drawn
    mu_hat: jax.Array  # f32
    mu0: jax.Array  # f32
    pvalue: jax.Array  # f32
    log_u: jax.Array  # f32
    epsilon: jax.Array  # f32: tolerance this transition ran with
    batch_eff: jax.Array  # int32: effective mini-batch size this transition

    # The last two fields are the adaptation trace: constant copies of the
    # config under static scheduling, the controller's per-transition knob
    # settings under repro.core.schedule.


@dataclasses.dataclass(frozen=True)
class SubsampledMHConfig:
    """Static kernel configuration for one subsampled-MH chain.

    ``batch_size`` (m) sections are drawn per sequential-test round;
    ``epsilon`` is the test's p-value tolerance (smaller = closer to exact
    MH, more sections evaluated); ``max_rounds`` caps the test (default:
    enough rounds to exhaust the pool, at which point the decision is
    exact); ``sampler`` picks the without-replacement scheme.

    Example::

        >>> cfg = SubsampledMHConfig(batch_size=50, epsilon=0.05)
        >>> cfg.batch_size, cfg.sampler
        (50, 'fy')
    """

    batch_size: int = 100  # m: mini-batch of local sections per round
    epsilon: float = 0.01  # tolerance of the sequential test
    max_rounds: int | None = None  # default ceil(N/m): exhaust the pool
    sampler: str = "fy"  # "fy" (Fisher–Yates) | "stream" (pre-permuted pool)


def _tree_select(pred: jax.Array, on_true: Params, on_false: Params) -> Params:
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b), on_true, on_false)


def propose_and_mu0(
    key: jax.Array, theta: Params, target: PartitionedTarget, proposal,
    prop_scale=None,
) -> tuple[Params, jax.Array, jax.Array, jax.Array]:
    """Steps 2–6 of Alg. 3: draw u, propose, evaluate the global section.

    Returns ``(theta_prime, mu0, log_u, key_test)`` where ``key_test`` seeds
    the sequential test. Factored out so the masked-continuation ensemble
    stepping reproduces the scanned single-chain kernel bit for bit.

    ``prop_scale`` (a traced scalar, or None) is forwarded to the proposal's
    ``scale`` argument — the adaptive-proposal hook; ``None`` keeps the
    two-argument call and is bit-for-bit the pre-scale behavior.
    """
    k_u, k_prop, k_test = jax.random.split(key, 3)
    log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))
    if prop_scale is None:
        theta_p, corr = proposal(k_prop, theta)
    else:
        theta_p, corr = proposal(k_prop, theta, prop_scale)
    g = target.log_global(theta, theta_p) + corr  # Detach&Regen(global)
    mu0 = (log_u - g) / target.num_sections
    return theta_p, mu0, log_u, k_test


def subsampled_mh_step(
    key: jax.Array,
    theta: Params,
    sampler_state,
    target: PartitionedTarget,
    proposal,
    config: SubsampledMHConfig,
    reset_fn,
    draw_fn,
    *,
    epsilon=None,
    batch_eff=None,
    draw_bounded_fn=None,
    max_rounds: int | None = None,
    batch_max: int | None = None,
    prop_scale=None,
) -> tuple[Params, Any, SubsampledMHInfo]:
    """One approximate MH transition (Alg. 3). Returns (theta', sampler', info).

    Steps map to the paper: 2 sample u; 3–4 construct+evaluate the global
    section; 6 compute mu0; 7–14 sequential test with lazily-materialized
    local sections; 15–19 accept or restore.

    The keyword overrides accept *traced* per-chain values from the adaptive
    scheduler: ``epsilon`` replaces ``config.epsilon``, ``batch_eff`` (with
    its ``draw_bounded_fn``, see :func:`repro.core.samplers.make_bounded_draw`)
    caps each round at an effective batch while shapes stay static at
    ``batch_max`` (the scheduler's largest bucket; defaults to
    ``config.batch_size``), and ``max_rounds`` must then cover exhaustion at
    the smallest batch bucket.

    Example — one transition on a 200-section conjugate target::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core import (RandomWalk, SubsampledMHConfig,
        ...                         from_iid_loglik, make_kernel)
        >>> x = 0.5 + jax.random.normal(jax.random.key(0), (200,))
        >>> target = from_iid_loglik(lambda th: -0.5 * th**2,
        ...                          lambda th, idx: -0.5 * (x[idx] - th) ** 2,
        ...                          None, 200)
        >>> state0, step = make_kernel(target, RandomWalk(0.1),
        ...                            SubsampledMHConfig(batch_size=50, epsilon=0.05))
        >>> theta, state, info = step(jax.random.key(1), jnp.zeros(()), state0)
        >>> theta.shape, int(info.n_evaluated) <= 200
        ((), True)
    """
    theta_p, mu0, log_u, k_test = propose_and_mu0(key, theta, target, proposal, prop_scale)
    eps = config.epsilon if epsilon is None else epsilon

    res = sequential_test(
        key=k_test,
        mu0=mu0,
        draw_fn=draw_fn,
        eval_fn=lambda idx: target.log_local(theta, theta_p, idx),
        sampler_state=reset_fn(sampler_state),
        num_sections=target.num_sections,
        batch_size=config.batch_size if batch_max is None else batch_max,
        epsilon=eps,
        max_rounds=config.max_rounds if max_rounds is None else max_rounds,
        batch_eff=batch_eff,
        draw_bounded_fn=draw_bounded_fn,
    )
    accept = res.decision
    theta_new = _tree_select(accept, theta_p, theta)
    info = SubsampledMHInfo(
        accepted=accept,
        n_evaluated=res.n_evaluated,
        rounds=res.rounds,
        mu_hat=res.mu_hat,
        mu0=mu0,
        pvalue=res.pvalue,
        log_u=log_u,
        epsilon=jnp.asarray(eps, jnp.float32),
        batch_eff=jnp.asarray(
            config.batch_size if batch_eff is None else batch_eff, jnp.int32
        ),
    )
    return theta_new, res.sampler_state, info


def adaptive_max_rounds(config: SubsampledMHConfig, num_sections: int, buckets) -> int:
    """Static round cap covering pool exhaustion at the smallest bucket."""
    if config.max_rounds is not None:
        return config.max_rounds
    m_min = max(1, min(int(b) for b in buckets))
    return int(math.ceil(num_sections / m_min))


def make_kernel(
    target: PartitionedTarget,
    proposal,
    config: SubsampledMHConfig | None = None,
    *,
    scheduled: bool = False,
    batch_max: int | None = None,
):
    """Bundle a jit-ready (init_state, step) pair.

    step(key, theta, sampler_state) -> (theta', sampler_state', info)

    With ``scheduled=True`` the step instead has signature
    ``step(key, theta, sampler_state, epsilon, batch_eff, max_rounds=None,
    prop_scale=None)`` and accepts the adaptive controller's traced per-chain knobs
    (:func:`repro.core.schedule.controller_params`); ``batch_max`` sets the
    static per-round draw shape (the scheduler's largest bucket — without it
    buckets above ``config.batch_size`` could never actually be drawn).
    """
    config = config or SubsampledMHConfig()
    state0, reset_fn, draw_fn = make_sampler(config.sampler, target.num_sections)

    if scheduled:
        draw_bounded = make_bounded_draw(config.sampler)

        def step(key, theta, sampler_state, epsilon, batch_eff, max_rounds=None,
                 prop_scale=None):
            return subsampled_mh_step(
                key, theta, sampler_state, target, proposal, config, reset_fn, draw_fn,
                epsilon=epsilon, batch_eff=batch_eff, draw_bounded_fn=draw_bounded,
                max_rounds=max_rounds, batch_max=batch_max, prop_scale=prop_scale,
            )

        return state0, step

    def step(key, theta, sampler_state):
        return subsampled_mh_step(
            key, theta, sampler_state, target, proposal, config, reset_fn, draw_fn
        )

    return state0, step
