"""Step builders + abstract input specs for every (arch × shape) cell.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStruct
stand-ins for every input of the lowered step — no device allocation — and
the matching step callable:

  train_*   : (seed, params, batch)        -> (params', LMTrainInfo)
  prefill_* : (params, tokens[, frames])   -> (cache, last logits)
  decode_*  : (params, cache, tokens)      -> (cache', logits)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..bayes import LogLikCache, TrainConfig, make_cached_train_step, make_train_step
from ..configs import ARCHS, SHAPES, ShapeSpec
from ..distributed.sharding import DEFAULT_RULES, named_sharding, resolve_spec
from ..models.transformer import (
    ModelConfig,
    ParamSpec,
    abstract_cache,
    cache_template,
    decode_step,
    param_specs,
    prefill,
)

_IS_SPEC = lambda x: isinstance(x, ParamSpec)  # noqa: E731


def _sds(shape, dtype, mesh=None, logical=None, rules=None):
    sharding = None
    if mesh is not None and logical is not None:
        sharding = named_sharding(mesh, shape, logical, rules)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def spec_tree_to_abstract(specs, mesh=None, rules=None):
    """ParamSpec tree -> ShapeDtypeStruct tree (with shardings if mesh)."""
    return jax.tree.map(
        lambda s: _sds(s.shape, s.dtype, mesh, s.logical, rules), specs, is_leaf=_IS_SPEC
    )


def spec_tree_to_shardings(specs, mesh, rules=None):
    return jax.tree.map(
        lambda s: named_sharding(mesh, s.shape, s.logical, rules), specs, is_leaf=_IS_SPEC
    )


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    spec: ShapeSpec
    step: Callable  # jit-able python callable
    in_specs: tuple  # ShapeDtypeStructs (with shardings when mesh given)
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    train_cfg: TrainConfig | None = None
    rules: dict | None = None  # logical-axis rule overrides for this cell


def default_train_config(cfg: ModelConfig, spec: ShapeSpec) -> TrainConfig:
    rb = max(spec.global_batch // 4, 1)
    return TrainConfig(round_batch=rb, epsilon=0.05, sigma=1e-4, ce_chunk=256)


# Rule presets for sharding experiments (§Perf). "infer_tp": weights prefer
# the model axis over data-axis FSDP — right for decode, where activations
# are tiny and FSDP all-gathers dominate. "infer_replicate": drop the data
# axis from weights entirely (small models / collective-bound prefill).
RULE_PRESETS: dict[str, dict | None] = {
    "default": None,
    "infer_tp": {"embed": (("model",), ("data",))},
    "infer_replicate": {"embed": ()},
    # HC2: replicate mamba inner projections over the model axis, removing
    # the per-layer activation all-reduce of the down-projection partials
    "mamba_dp": {"mamba_inner": ()},
    # HC2 iter C: mamba replication + no-FSDP weights combined
    "jamba_prefill": {"mamba_inner": (), "embed": ()},
}


def build_cell(arch: str, shape: str, mesh=None, train_cfg: TrainConfig | None = None,
               rules: dict | None = None, kv_dtype: str | None = None) -> Cell:
    import dataclasses as _dc

    cfg = ARCHS[arch]
    if kv_dtype is not None:
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dtype)
    spec = SHAPES[shape]
    gb, s = spec.global_batch, spec.seq_len
    pspecs = param_specs(cfg)
    params_abs = spec_tree_to_abstract(pspecs, mesh, rules)
    params_sh = spec_tree_to_shardings(pspecs, mesh, rules) if mesh else None
    repl = named_sharding(mesh, (), ()) if mesh else None

    def sh(shape_, logical):
        return named_sharding(mesh, shape_, logical, rules) if mesh else None

    if spec.kind == "train":
        tc = train_cfg or default_train_config(cfg, spec)
        batch_abs = {
            "tokens": _sds((gb, s), jnp.int32, mesh, ("batch", None)),
            "mask": _sds((gb, s), jnp.int32, mesh, ("batch", None)),
        }
        if cfg.family == "audio":
            batch_abs["frames"] = _sds(
                (gb, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16,
                mesh, ("batch", None, None),
            )
        batch_sh = jax.tree.map(lambda x: x.sharding, batch_abs) if mesh else None
        if tc.cached:
            raw_step = make_cached_train_step(cfg, tc)

            def step(seed, params, batch, cache):
                return raw_step(jax.random.key(seed), params, batch, cache)

            cache_abs = LogLikCache(
                _sds((gb,), jnp.float32, mesh, ("batch",)),
                _sds((gb,), jnp.bool_, mesh, ("batch",)),
            )
            cache_sh = jax.tree.map(lambda x: x.sharding, tuple(cache_abs)) if mesh else None
            cache_sh = LogLikCache(*cache_sh) if mesh else None
            in_specs = (_sds((), jnp.uint32), params_abs, batch_abs, cache_abs)
            in_sh = (repl, params_sh, batch_sh, cache_sh) if mesh else None
            out_sh = (params_sh, cache_sh, None) if mesh else None
            return Cell(arch, shape, cfg, spec, step, in_specs, in_sh, out_sh,
                        donate_argnums=(1, 3), train_cfg=tc, rules=rules)

        raw_step = make_train_step(cfg, tc)

        def step(seed, params, batch):
            return raw_step(jax.random.key(seed), params, batch)

        in_specs = (_sds((), jnp.uint32), params_abs, batch_abs)
        in_sh = (repl, params_sh, batch_sh) if mesh else None
        out_sh = (params_sh, None) if mesh else None
        return Cell(arch, shape, cfg, spec, step, in_specs, in_sh, out_sh,
                    donate_argnums=(1,), train_cfg=tc, rules=rules)

    if spec.kind == "prefill":
        def step(params, tokens, *extra):
            ex = {"frames": extra[0]} if extra else None
            return prefill(params, tokens, cfg, max_len=s, extra=ex)

        tokens_abs = _sds((gb, s), jnp.int32, mesh, ("batch", None))
        extras = ()
        if cfg.family == "audio":
            extras = (_sds((gb, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16,
                           mesh, ("batch", None, None)),)
        in_specs = (params_abs, tokens_abs) + extras
        cache_sh = spec_tree_to_shardings(cache_template(cfg, gb, s), mesh, rules) if mesh else None
        in_sh = (params_sh, tokens_abs.sharding) + tuple(e.sharding for e in extras) if mesh else None
        out_sh = ((cache_sh, sh((gb, cfg.vocab), ("batch", "vocab"))) if mesh else None)
        return Cell(arch, shape, cfg, spec, step, in_specs, in_sh, out_sh, rules=rules)

    # decode: one new token against a seq_len-deep cache
    def step(params, cache, tokens):
        return decode_step(params, cache, tokens, cfg)

    cache_specs = cache_template(cfg, gb, s)
    cache_abs = spec_tree_to_abstract(cache_specs, mesh, rules)
    if cfg.family == "audio":
        pass  # enc_out is part of the cache template already
    tokens_abs = _sds((gb, 1), jnp.int32, mesh, ("batch", None))
    in_specs = (params_abs, cache_abs, tokens_abs)
    cache_sh = spec_tree_to_shardings(cache_specs, mesh, rules) if mesh else None
    in_sh = (params_sh, cache_sh, tokens_abs.sharding) if mesh else None
    out_sh = ((cache_sh, sh((gb, cfg.vocab), ("batch", "vocab"))) if mesh else None)
    return Cell(arch, shape, cfg, spec, step, in_specs, in_sh, out_sh,
                donate_argnums=(1,), rules=rules)


def input_specs(arch: str, shape: str, mesh=None):
    """The assignment's entry point: ShapeDtypeStruct stand-ins for every
    model input of the given cell."""
    return build_cell(arch, shape, mesh).in_specs
