"""Posterior query serving front-end.

Serves posterior-functional queries from a pool of **resident ensembles**
(warm multi-chain sampler state, background refresh, request batching,
SLO-aware freshness — see :mod:`repro.serving` and docs/ARCHITECTURE.md):

    PYTHONPATH=src python -m repro.launch.serve --workload bayeslr --smoke
    PYTHONPATH=src python -m repro.launch.serve --workload stochvol \
        --queries 500 --max-batch 32 --deadline-ms 100
    PYTHONPATH=src python -m repro.launch.serve --workload bayeslr \
        --ckpt-dir /tmp/pool  # save on exit; restarts warm from the same dir

Per request class it reports p50/p95/p99 latency, deadline hit rate, and
snapshot staleness, then (always) cross-checks one served predictive
against the same functional computed offline from the identical snapshot
draws. ``--workload lm`` keeps the legacy LM decoding demo (batched
posterior-sample decoding with ``--arch`` / ``--prompt-len`` /
``--gen-len``; params restored from ``--ckpt-dir``).

``--fleet`` serves through the sharded fleet instead (:mod:`repro.fleet`):
writer resident ensembles per workload shard stream snapshot deltas to
``--replicas`` read replicas, and a priority-aware router with admission
control spreads requests across the replica lanes:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python -m repro.launch.serve --fleet --workload bayeslr --smoke --mesh 2d
    python -m repro.launch.serve --fleet --devices 4 --replicas 3 \
        --replica-transport proc --workload bayeslr

(``--devices N`` forces N virtual host devices before JAX initializes —
one process group hosting the writer mesh and the replicas.)

``--subposterior P`` turns the fleet data-parallel: the observation pool
is stride-partitioned into P shards, each with its own writer group
sampling the local slice under the ``p(theta)^(1/P)`` tempered prior, and
the router recombines the per-partition windows at query time
(``--combine consensus|product``). ``--stream`` demos the append-only
target mode: a fresh observation chunk is folded into the *running*
writers mid-serve (no restart) and the freshness gate refuses the
pre-append windows:

    python -m repro.launch.serve --subposterior 2 --smoke
    python -m repro.launch.serve --subposterior 4 --stream --workload bayeslr
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS

POSTERIOR_WORKLOADS = ("bayeslr", "stochvol", "jointdpm", "ppl")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--workload", default="bayeslr",
                    choices=POSTERIOR_WORKLOADS + ("lm",),
                    help="posterior workload to serve (or 'lm' for the "
                         "legacy decoding demo)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small model, >=100 queries, parity check")
    ap.add_argument("--queries", type=int, default=None,
                    help="number of requests to serve (default: 120 smoke, 400 full)")
    ap.add_argument("--rows-per-query", type=int, default=8,
                    help="request rows (test points / quantile levels) per query")
    ap.add_argument("--chains", type=int, default=None,
                    help="resident chains K (default: 4 smoke, 8 full)")
    ap.add_argument("--refresh-steps", type=int, default=None,
                    help="transitions per refresh block (default: 16 smoke, 64 full)")
    ap.add_argument("--window", type=int, default=None,
                    help="posterior draws retained per chain (default: 32 smoke, 128 full)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="requests coalesced into one evaluation")
    ap.add_argument("--micro-batch", type=int, default=64,
                    help="request rows per compiled evaluation chunk")
    ap.add_argument("--deadline-ms", type=float, default=250.0,
                    help="per-request latency SLO")
    ap.add_argument("--max-staleness-s", type=float, default=30.0,
                    help="freshness: oldest admissible snapshot age")
    ap.add_argument("--min-draws", type=int, default=None,
                    help="freshness: min cross-chain draws before serving "
                         "(default: chains * window / 2)")
    ap.add_argument("--background", action="store_true",
                    help="refresh on a background thread while serving "
                         "(default: refresh synchronously when stale)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="posterior pool: restore-if-present + save-on-exit; "
                         "lm: restore params (a posterior sample)")
    ap.add_argument("--seed", type=int, default=0)
    # -- sharded serving fleet (--fleet) -----------------------------------
    fl = ap.add_argument_group("sharded serving fleet (--fleet)")
    fl.add_argument("--fleet", action="store_true",
                    help="serve through the writer/replica fleet "
                         "(repro.fleet) instead of the single pool")
    fl.add_argument("--replicas", type=int, default=2,
                    help="read replicas per workload shard")
    fl.add_argument("--fleet-shards", type=int, default=1,
                    help="independent writer shards per workload")
    fl.add_argument("--replica-transport", default="inproc",
                    choices=("inproc", "proc"),
                    help="replica hosting: in-process objects or one OS "
                         "process per replica (the scaling configuration)")
    fl.add_argument("--mesh", default="auto", choices=("auto", "2d", "off"),
                    help="writer ensemble sharding: 'auto' (1-d chain mesh "
                         "when devices allow), '2d' (chains x data), 'off'")
    fl.add_argument("--devices", type=int, default=None,
                    help="force N virtual host devices (XLA_FLAGS) before "
                         "JAX initializes — the fleet's process group size")
    fl.add_argument("--max-depth", type=int, default=256,
                    help="admission: queue depth before shedding starts")
    fl.add_argument("--max-miss-rate", type=float, default=0.5,
                    help="admission: predicted deadline-miss rate threshold")
    fl.add_argument("--subposterior", type=int, default=1, metavar="P",
                    help="data-parallel subposterior MCMC: partition the "
                         "observations into P shards, run a writer group "
                         "per shard under the p(theta)^(1/P) tempered "
                         "prior, recombine draws at query time (implies "
                         "--fleet; P=1 is the unpartitioned fleet)")
    fl.add_argument("--combine", default="consensus",
                    choices=("consensus", "product"),
                    help="subposterior draw-combination rule: consensus "
                         "weighted averaging or Gaussian density-product")
    fl.add_argument("--autoscale", action="store_true",
                    help="closed-loop replica autoscaling: a control loop "
                         "over the recorded admission/SLO signals adds "
                         "replicas under overload and retires them after "
                         "quiesce (fleet/soak modes; implies --fleet)")
    fl.add_argument("--autoscale-max", type=int, default=None,
                    help="autoscaler replica ceiling per workload "
                         "(default: launch replicas + 2)")
    fl.add_argument("--autoscale-cooldown", type=float, default=2.0,
                    help="seconds between autoscaler actuations")
    fl.add_argument("--stream", action="store_true",
                    help="streaming append-only target demo: mid-serve, "
                         "append a fresh observation chunk into the running "
                         "writers (no restart) and prove the staleness "
                         "gate refuses pre-append windows (implies --fleet)")
    # -- observability (repro.obs) ------------------------------------------
    ob = ap.add_argument_group("observability")
    ob.add_argument("--stats-addr", default=None, metavar="HOST:PORT",
                    help="expose the live metric rollup as JSON over HTTP "
                         "(port 0 = ephemeral); prints a STATS_OK self-check")
    ob.add_argument("--obs-dir", default=os.environ.get("REPRO_OBS_DIR"),
                    help="write per-run JSONL metric streams + summary.json "
                         "under this directory (default: $REPRO_OBS_DIR, "
                         "else in-memory only)")
    ob.add_argument("--alerts", action="store_true",
                    help="evaluate the standard alert ruleset (threshold / "
                         "SLO burn-rate / anomaly rules with a pending-"
                         "firing-resolved state machine) over the live "
                         "rollup; transitions land on the 'alerts' stream, "
                         "/alerts + /health appear on --stats-addr, and an "
                         "ALERTS_OK self-check prints on exit")
    ob.add_argument("--soak", action="store_true",
                    help="chaos soak: sustained mixed-class load on the "
                         "fleet while one replica is killed and restarted "
                         "mid-load; prints SOAK_OK with recovery counters")
    ob.add_argument("--soak-seconds", type=float, default=None,
                    help="soak load duration (default: 6 smoke, 30 full)")
    ob.add_argument("--trace-dir", default=None,
                    help="end-to-end request tracing: tee every span to "
                         "<dir>/spans.jsonl and export a Chrome/Perfetto "
                         "<dir>/trace.json on exit (prints TRACE_OK)")
    ob.add_argument("--profile-dir", default=None,
                    help="capture one jax.profiler trace of the first "
                         "writer refresh into this directory (no-op when "
                         "the profiler is unavailable)")
    # -- legacy LM decoding flags (only read under --workload lm) ----------
    lm = ap.add_argument_group("lm decoding demo (--workload lm)")
    lm.add_argument("--arch", default="xlstm-350m", choices=list(ARCHS))
    lm.add_argument("--reduced", action="store_true")
    lm.add_argument("--batch", type=int, default=8)
    lm.add_argument("--prompt-len", type=int, default=64)
    lm.add_argument("--gen-len", type=int, default=64)
    lm.add_argument("--model-parallel", type=int, default=1)
    return ap


# ---------------------------------------------------------------------------
# Observability wiring (repro.obs)
# ---------------------------------------------------------------------------


def _setup_obs(args, source=None):
    """Recorder + optional HTTP stats endpoint + SLO sampler + tracer for a
    serve run, or (None, None, None, None) when no observability flag is
    set."""
    if not (args.stats_addr is not None or args.obs_dir or args.soak
            or args.trace_dir or args.profile_dir or args.alerts
            or args.autoscale):
        return None, None, None, None
    from repro.obs import Recorder, SLOSampler, StatsServer, Tracer

    recorder = Recorder(
        args.obs_dir,
        meta={"workload": args.workload, "argv": sys.argv[1:]},
    )
    tracer = None
    if args.trace_dir:
        tracer = Tracer(
            recorder=recorder,
            jsonl_path=os.path.join(args.trace_dir, "spans.jsonl"),
        )
        print(f"trace: spans tee to {args.trace_dir}/spans.jsonl")
    server = None
    if args.stats_addr is not None:
        server = StatsServer(recorder, args.stats_addr, tracer=tracer)
        print(f"stats: live rollup at {server.url}")
    sampler = SLOSampler(recorder, source) if source is not None else None
    return recorder, server, sampler, tracer


def _setup_alerts(args, recorder, stats_server, workload, fleet=None):
    """AlertEngine over the run's recorder, wired into the stats endpoint
    (``/alerts`` and a component-health ``/health``), or None with
    ``--alerts`` off — the request path then never sees any of this."""
    if not args.alerts or recorder is None:
        return None
    from repro.obs import default_rules, health_report
    from repro.obs.alerts import AlertEngine

    rules = default_rules(
        args.workload, workload.default_class,
        deadline_ms=args.deadline_ms, max_depth=args.max_depth,
    )
    engine = AlertEngine(recorder, rules)
    if stats_server is not None:
        stats_server.alerts = engine
        stats_server.health = lambda: health_report(
            recorder.rollup(),
            fleet_report=fleet.report() if fleet is not None else None,
            alert_status=engine.status(),
            max_depth=args.max_depth if fleet is not None else None,
        )
        print(f"alerts: {len(rules)} rules over the live rollup; "
              f"/alerts and /health at {stats_server.url}")
    else:
        print(f"alerts: {len(rules)} rules over the live rollup")
    return engine


def _setup_autoscaler(args, fleet, router, recorder, engine):
    """The closed-loop actuator (``--autoscale``): scale between the launch
    replica count and ``--autoscale-max`` on the admission/SLO signals (and
    the overload alerts, when ``--alerts`` is also on)."""
    if not args.autoscale:
        return None
    from repro.fleet import AutoScaleConfig, AutoScaler

    launch = fleet.replica_count(args.workload)
    ceiling = args.autoscale_max
    if ceiling is None:
        ceiling = launch + 2
    config = AutoScaleConfig(
        min_replicas=launch,
        max_replicas=max(ceiling, launch),
        scale_up_depth=args.max_depth,
        scale_down_depth=max(args.max_depth // 16, 2),
        quiesce_ticks=2,
        cooldown_s=args.autoscale_cooldown,
    )
    scaler = AutoScaler(fleet, router, args.workload, config,
                        recorder=recorder, engine=engine)
    print(f"autoscale: replicas {launch}..{config.max_replicas}, "
          f"scale_up_depth={config.scale_up_depth} "
          f"scale_down_depth={config.scale_down_depth} "
          f"cooldown={config.cooldown_s}s")
    return scaler


def _alerts_selfcheck(engine, server) -> bool:
    """The ALERTS_OK line CI greps: the engine evaluated at least once and,
    when an endpoint is up, ``/alerts`` serves its live status."""
    ok = engine.evaluations >= 1
    if server is not None:
        import urllib.request

        import json as _json

        try:
            with urllib.request.urlopen(server.url.rstrip("/") + "/alerts",
                                        timeout=10) as resp:
                ok = ok and bool(_json.loads(resp.read()).get("available"))
        except Exception:  # noqa: BLE001 — an unreachable endpoint is a fail
            ok = False
    firing = ",".join(engine.firing()) or "-"
    line = "ALERTS_OK" if ok else "ALERTS_FAIL"
    print(f"{line} rules={len(engine.rules)} "
          f"evaluations={engine.evaluations} "
          f"transitions={engine.transitions} fired={engine.fired_total} "
          f"resolved={engine.resolved_total} firing={firing}")
    return ok


def _obs_num_sections(ensemble):
    """``num_sections`` of a serving ensemble's target(s), in the shape
    :func:`repro.obs.record_transition_cost` wants: an int for a
    builder-constructed single target, a per-op dict for a composite
    ``cycle()`` transition, None when nothing is subsampled."""
    if ensemble.target is not None:
        return int(ensemble.target.num_sections)
    transition = getattr(ensemble, "transition", None)
    if transition is not None and hasattr(transition, "mh_ops"):
        names = transition.names
        return {
            names[i]: int(op.target.num_sections)
            for i, op in transition.mh_ops
        }
    return None


def _record_transition_cost(recorder, workload_name, snap, num_sections):
    from repro.obs import record_transition_cost

    record_transition_cost(
        recorder, workload_name, snap.summary, num_sections=num_sections
    )


def _record_profile(recorder, args, resident) -> None:
    """Note a completed ``--profile-dir`` capture on the ``profile`` stream
    (no record when the one-shot capture never fired)."""
    if recorder is None or resident is None:
        return
    captured = getattr(resident, "last_profile_dir", None)
    if captured:
        recorder.record("profile", {
            "workload": args.workload,
            "capture_dir": captured,
            "tool": "jax.profiler",
        })
        print(f"profile: jax.profiler capture in {captured}")


def _stats_selfcheck(server) -> bool:
    """Fetch our own endpoint and print STATS_OK/STATS_FAIL — the CI-style
    proof that the rollup is reachable and carries the headline fields."""
    import urllib.request

    import json as _json

    with urllib.request.urlopen(server.url, timeout=10) as resp:
        roll = _json.loads(resp.read())
    streams = roll.get("streams", {})
    slo_last = streams.get("slo", {}).get("last", {})
    snap_last = streams.get("snapshot", {}).get("last", {})
    ok = (
        "req_per_s" in slo_last and "p95_ms" in slo_last
        and "shed" in slo_last and "staleness_s" in snap_last
    )
    sublinear = ""
    try:
        with urllib.request.urlopen(server.url.rstrip("/") + "/sublinear",
                                    timeout=10) as resp:
            sub = _json.loads(resp.read())
        frac = sub.get("frac_data_touched", {}).get("mean") \
            if isinstance(sub.get("frac_data_touched"), dict) else None
        if frac is not None:
            sublinear = f" frac_data_touched={frac:.4f}"
    except Exception:  # noqa: BLE001 — the sublinear view is informational
        pass
    line = "STATS_OK" if ok else "STATS_FAIL"
    print(f"{line} url={server.url} streams={sorted(streams)} "
          f"req_per_s={slo_last.get('req_per_s', float('nan')):.0f} "
          f"p95_ms={slo_last.get('p95_ms', float('nan')):.2f} "
          f"shed={slo_last.get('shed', 'n/a')} "
          f"staleness_s={snap_last.get('staleness_s', float('nan')):.3f}"
          f"{sublinear}")
    return ok


def _teardown_obs(recorder, server, tracer=None, trace_dir=None) -> None:
    if server is not None:
        server.close()
    if tracer is not None:
        if trace_dir:
            _export_trace(tracer, trace_dir)
        tracer.close()
    if recorder is not None:
        path = recorder.close()
        if path:
            print(f"obs: metric streams + summary in {recorder.dir}")


def _export_trace(tracer, trace_dir) -> None:
    """Write the Chrome/Perfetto export next to the spans tee and print the
    TRACE_OK line CI greps (and uploads as an artifact)."""
    from repro.obs.trace import export_chrome_trace

    spans = tracer.spans()
    out = export_chrome_trace(spans, os.path.join(trace_dir, "trace.json"))
    n_traces = len({s.get("trace_id") for s in spans if s.get("trace_id")})
    print(f"TRACE_OK spans={len(spans)} traces={n_traces} "
          f"dropped={tracer.dropped} export={out}")


# ---------------------------------------------------------------------------
# Posterior serving path
# ---------------------------------------------------------------------------


def _offline_reference(workload, spec, snap, xs) -> np.ndarray | None:
    """Recompute the served functional offline (numpy / per-draw loop) from
    the *same* snapshot draws — the acceptance cross-check. Returns None when
    the workload has no independent closed form wired up."""
    if workload.name in ("bayeslr", "ppl") and spec.name == "predictive":
        from repro.experiments import bayeslr

        w = np.asarray(jax.tree.leaves(snap.draws)[0])
        w = w.reshape(-1, w.shape[-1])  # (S, D)
        return bayeslr.predictive_mean_prob(w, np.asarray(xs))[-1]
    return None


def serve_posterior(args) -> int:
    from repro.serving import (
        EnsemblePool,
        FreshnessPolicy,
        RequestQueue,
        ServingConfig,
    )

    smoke = args.smoke
    dflt = lambda v, d: d if v is None else v
    chains = dflt(args.chains, 4 if smoke else 8)
    refresh_steps = dflt(args.refresh_steps, 16 if smoke else 64)
    window = dflt(args.window, 32 if smoke else 128)
    num_queries = dflt(args.queries, 120 if smoke else 400)
    # --min-draws 0 is meaningful (disable the draw-count freshness floor)
    min_draws = dflt(args.min_draws, max(chains * window // 2, chains))
    config = ServingConfig(
        num_chains=chains,
        refresh_steps=refresh_steps,
        window=window,
        micro_batch=args.micro_batch,
        max_batch=args.max_batch,
        freshness=FreshnessPolicy(
            max_staleness_s=args.max_staleness_s, min_draws=min_draws
        ),
        default_deadline_s=args.deadline_ms / 1e3,
        seed=args.seed,
    )
    print(f"pool: workload={args.workload} K={chains} refresh={refresh_steps} "
          f"window={window} min_draws={min_draws} "
          f"max_staleness={args.max_staleness_s}s")
    pool = EnsemblePool(config)
    pool.add_workload(args.workload, smoke=smoke, seed=args.seed)
    if args.profile_dir:
        # One-shot: the first refresh (inside warm()) lands the capture.
        pool.resident(args.workload).arm_profile(args.profile_dir)
    workload = pool.workload(args.workload)
    print(f"target: {workload.description}; request classes: "
          f"{sorted(workload.query_specs)}")

    restored = None
    if args.ckpt_dir:
        from repro.checkpoint.manager import latest_step

        if latest_step(args.ckpt_dir) is not None:
            restored = pool.restore(args.ckpt_dir)
            print(f"restored warm pool from {args.ckpt_dir} (step {restored})")

    t0 = time.perf_counter()
    pool.warm()
    warm_s = time.perf_counter() - t0
    resident = pool.resident(args.workload)
    print(f"warm in {warm_s:.1f}s: {resident.steps_done} transitions/chain "
          f"resident ({chains * resident.steps_done} total)")
    # Compile each request class's evaluator outside the measured window
    # (a cold query would otherwise charge XLA compile time to its batch).
    wkey = jax.random.key(args.seed + 2)
    for cls in sorted(workload.query_specs):
        wkey, sub = jax.random.split(wkey)
        pool.query(args.workload, cls,
                   workload.query_specs[cls].make_queries(sub, args.rows_per_query))
    if args.background:
        pool.start()

    queue = RequestQueue(pool, max_batch=args.max_batch,
                         default_deadline_s=args.deadline_ms / 1e3)
    recorder, stats_server, sampler, tracer = _setup_obs(args, source=queue)
    queue.tracer = tracer
    engine = _setup_alerts(args, recorder, stats_server, workload)
    num_sections = _obs_num_sections(resident.ensemble)
    classes = sorted(workload.query_specs)
    qkey = jax.random.key(args.seed + 1)
    t0 = time.perf_counter()
    served = 0
    # Submit in bursts (1..max_batch) so the batcher actually coalesces.
    burst = max(2, args.max_batch // 2)
    for i in range(0, num_queries, burst):
        take = min(burst, num_queries - i)
        for j in range(take):
            cls = classes[(i + j) % len(classes)]
            qkey, sub = jax.random.split(qkey)
            xs = workload.query_specs[cls].make_queries(sub, args.rows_per_query)
            queue.submit(args.workload, cls, xs)
        served += len(queue.drain())
        if sampler is not None:
            sampler.sample()
            from repro.obs import record_snapshot

            snap_now = pool.resident(args.workload).snapshot()
            record_snapshot(recorder, args.workload, snap_now)
            _record_transition_cost(recorder, args.workload, snap_now,
                                    num_sections)
            if engine is not None:
                engine.evaluate()
    wall = time.perf_counter() - t0
    report = queue.slo_report()

    print(f"\nserved {served} requests "
          f"({args.rows_per_query} rows each) in {wall:.2f}s "
          f"({served / max(wall, 1e-9):.0f} req/s)")
    for cls, entry in report["classes"].items():
        if not entry.get("count"):
            print(f"  {cls:28s} ALL {entry['errors']} requests FAILED")
            continue
        print(f"  {cls:28s} p50={entry['p50_ms']:7.2f}ms "
              f"p95={entry['p95_ms']:7.2f}ms p99={entry['p99_ms']:7.2f}ms "
              f"deadline_hit={entry['deadline_hit_rate']:.1%} "
              f"batch~{entry['mean_batch_size']:.1f} "
              f"staleness~{entry.get('staleness_mean_s', float('nan')):.3f}s")
    if report["errors"]:
        print(f"  WARNING: {report['errors']} request(s) failed")
    snap_report = pool.slo_snapshot_report()[args.workload]
    print(f"  snapshot: staleness={snap_report['staleness_s']:.3f}s "
          f"draws={snap_report['num_draws']} "
          f"steps_done={snap_report['steps_done']} fresh={snap_report['fresh']}")

    # -- parity: a served predictive vs the same functional offline --------
    spec = workload.query_specs[workload.default_class]
    qkey, sub = jax.random.split(qkey)
    xs = spec.make_queries(sub, 16)
    snap = pool.ensure_fresh(args.workload)
    served_vals, snap = pool.query(
        args.workload, workload.default_class, xs, snapshot=snap
    )
    ref = _offline_reference(workload, spec, snap, xs)
    parity = "n/a"
    if ref is not None:
        err = float(np.max(np.abs(served_vals - ref)))
        if not np.allclose(served_vals, ref, rtol=1e-4, atol=1e-5):
            print(f"PARITY FAIL: served vs offline max|delta|={err:.3g}")
            return 1
        parity = f"ok(max|delta|={err:.2g})"
        print(f"  parity: served {workload.default_class} == offline "
              f"predictive from the same draws ({parity})")

    if args.ckpt_dir:
        path = pool.save(args.ckpt_dir)
        print(f"saved warm pool to {path}")
    if args.background:
        pool.stop()

    stats_ok = alerts_ok = True
    if recorder is not None:
        from repro.obs import record_adaptation

        snap = pool.resident(args.workload).snapshot()
        record_adaptation(recorder, args.workload, snap.summary)
        _record_transition_cost(recorder, args.workload, snap, num_sections)
        _record_profile(recorder, args, pool.resident(args.workload))
        if engine is not None:
            engine.evaluate()
            alerts_ok = _alerts_selfcheck(engine, stats_server)
        if stats_server is not None:
            stats_ok = _stats_selfcheck(stats_server)
        _teardown_obs(recorder, stats_server, tracer, args.trace_dir)

    first = next(
        (e for e in report["classes"].values() if e.get("count")), None
    )
    if first is None or report["errors"] or not stats_ok or not alerts_ok:
        print(f"SERVE_FAIL workload={args.workload} errors={report['errors']}")
        return 1
    # New fields go AFTER parity= so existing CI greps keep matching.
    print(f"SERVE_OK workload={args.workload} queries={served} "
          f"p50_ms={first['p50_ms']:.2f} p95_ms={first['p95_ms']:.2f} "
          f"deadline_hit={first['deadline_hit_rate']:.3f} "
          f"staleness_s={snap_report['staleness_s']:.3f} parity={parity}"
          + (f" alerts_fired={engine.fired_total}"
             if engine is not None else ""))
    if smoke:
        assert served >= 100, f"smoke must serve >=100 queries, served {served}"
    return 0


# ---------------------------------------------------------------------------
# Sharded serving fleet (--fleet)
# ---------------------------------------------------------------------------


def _build_fleet(args):
    """Config + fleet + workload registration shared by the fleet and soak
    paths; returns (fleet, workload, classes)."""
    from repro.fleet import Fleet, FleetConfig
    from repro.serving import FreshnessPolicy, ServingConfig

    smoke = args.smoke
    dflt = lambda v, d: d if v is None else v
    chains = dflt(args.chains, 4 if smoke else 8)
    refresh_steps = dflt(args.refresh_steps, 16 if smoke else 64)
    window = dflt(args.window, 32 if smoke else 128)
    min_draws = dflt(args.min_draws, max(chains * window // 2, chains))
    mesh = {"auto": "auto", "2d": ("chains", "data"), "off": False}[args.mesh]
    config = FleetConfig(
        replicas=args.replicas,
        shards=args.fleet_shards,
        transport=args.replica_transport,
        mesh=mesh,
        subposterior=args.subposterior,
        combine=args.combine,
        serving=ServingConfig(
            num_chains=chains,
            refresh_steps=refresh_steps,
            window=window,
            micro_batch=args.micro_batch,
            max_batch=args.max_batch,
            freshness=FreshnessPolicy(
                max_staleness_s=args.max_staleness_s, min_draws=min_draws
            ),
            default_deadline_s=args.deadline_ms / 1e3,
            seed=args.seed,
        ),
    )
    print(f"fleet: workload={args.workload} shards={args.fleet_shards} "
          f"replicas={args.replicas}/shard transport={args.replica_transport} "
          f"mesh={args.mesh} devices={len(jax.devices())} K={chains} "
          f"refresh={refresh_steps} window={window} "
          f"subposterior={args.subposterior} combine={args.combine}")
    fleet = Fleet(config)
    fleet.add_workload(args.workload, smoke=smoke, seed=args.seed)
    workload = fleet.workload(args.workload)
    classes = sorted(workload.query_specs)
    print(f"target: {workload.description}; request classes: {classes}")
    return fleet, workload, classes


def _build_router(args, fleet, workload):
    """Priority/admission router over a fleet: the default class outranks
    the rest, so under overload the low classes are shed first."""
    from repro.fleet import AdmissionConfig, FleetRouter

    priorities = {cls: 0 for cls in sorted(workload.query_specs)}
    priorities[workload.default_class] = 1
    return FleetRouter(
        fleet,
        priorities=priorities,
        admission=AdmissionConfig(
            max_depth=args.max_depth, max_miss_rate=args.max_miss_rate
        ),
        max_batch=args.max_batch,
        default_deadline_s=args.deadline_ms / 1e3,
    )


def _compile_lanes(args, fleet, workload, router=None):
    """Compile every replica lane's evaluators outside the measured window."""
    wkey = jax.random.key(args.seed + 2)
    for shard in fleet.shards(args.workload):
        for replica in shard.replicas:
            for cls in sorted(workload.query_specs):
                wkey, sub = jax.random.split(wkey)
                spec = workload.query_specs[cls]
                replica.serve(spec, cls, spec.make_queries(sub, args.rows_per_query))
    if router is not None and args.subposterior > 1:
        # Partitioned workloads serve through the router's combined window,
        # whose evaluator is distinct from the lanes' — warm it too so the
        # first measured query doesn't pay XLA compile + first combination.
        for cls in sorted(workload.query_specs):
            wkey, sub = jax.random.split(wkey)
            spec = workload.query_specs[cls]
            router._serve_combined(
                args.workload, cls, spec.make_queries(sub, args.rows_per_query)
            )


def _stream_append(args, fleet) -> int:
    """The --stream demo: append a bootstrap-resampled observation chunk
    into the running writers mid-serve, prove the staleness gate flipped
    (pre-append windows read as infinitely stale), then pump one
    refresh+broadcast round so serving continues against the grown
    posterior. Returns the number of appended rows."""
    from repro.core import spec_of

    base = fleet.workload(args.workload)
    if base.ensemble.target is None:
        raise RuntimeError(
            f"--stream needs a builder-constructed target; workload "
            f"{args.workload!r} runs a composite transition"
        )
    spec = spec_of(base.ensemble.target)
    rng = np.random.default_rng(args.seed + 7)
    n = int(spec.num_sections)
    k = max(8, n // 16)
    idx = rng.integers(0, n, size=k)
    chunk = jax.tree.map(lambda a: np.asarray(a)[idx], spec.data)
    added = fleet.append_observations(args.workload, chunk)
    stale = [
        s.writer.snapshot().staleness_s for s in fleet.shards(args.workload)
    ]
    grew = [s for s in stale if not np.isfinite(s)]
    fleet.pump(args.workload)  # fold the grown targets into fresh windows
    print(f"STREAM_OK appended={added} rows mid-serve; "
          f"{len(grew)}/{len(stale)} writer(s) marked stale by the append, "
          f"refreshed without restart")
    return added


def serve_fleet(args) -> int:
    smoke = args.smoke
    dflt = lambda v, d: d if v is None else v
    num_queries = dflt(args.queries, 120 if smoke else 400)
    fleet, workload, classes = _build_fleet(args)

    restored = None
    if args.ckpt_dir:
        from repro.checkpoint.manager import latest_step

        if latest_step(args.ckpt_dir) is not None:
            restored = fleet.restore(args.ckpt_dir)
            print(f"restored warm fleet from {args.ckpt_dir} (step {restored})")

    if args.profile_dir:
        fleet.shards(args.workload)[0].writer.arm_profile(args.profile_dir)
    t0 = time.perf_counter()
    fleet.warm()
    warm_s = time.perf_counter() - t0
    shard0 = fleet.shards(args.workload)[0]
    print(f"warm in {warm_s:.1f}s: writers at "
          f"{[s.writer.steps_done for s in fleet.shards(args.workload)]} "
          f"transitions/chain, replicas synced to "
          f"{[r.version for r in shard0.replicas]}")

    router = _build_router(args, fleet, workload)
    recorder, stats_server, sampler, tracer = _setup_obs(args, source=router)
    router.tracer = tracer
    engine = _setup_alerts(args, recorder, stats_server, workload, fleet)
    scaler = _setup_autoscaler(args, fleet, router, recorder, engine)
    num_sections = _obs_num_sections(shard0.writer.ensemble)
    _compile_lanes(args, fleet, workload, router)
    if args.background:
        fleet.start()
        router.start_workers()

    qkey = jax.random.key(args.seed + 1)
    burst = max(2, args.max_batch // 2)
    t0 = time.perf_counter()
    served = 0
    stream_rows = 0
    streamed = False
    pending = []
    for i in range(0, num_queries, burst):
        take = min(burst, num_queries - i)
        for j in range(take):
            cls = classes[(i + j) % len(classes)]
            qkey, sub = jax.random.split(qkey)
            xs = workload.query_specs[cls].make_queries(sub, args.rows_per_query)
            pending.append(router.submit(args.workload, cls, xs))
        if args.background:
            # done.wait, not result(): a shed/errored request must pace the
            # burst loop, not crash it (shedding is the feature under test).
            pending[-1].done.wait(timeout=60.0)
        else:
            served += len(router.drain())
            if (i // burst) % 8 == 7:
                fleet.pump(args.workload)  # stream fresh deltas mid-serve
        if args.stream and not streamed and i + burst >= num_queries // 2:
            stream_rows = _stream_append(args, fleet)
            streamed = True
        if sampler is not None and (i // burst) % 4 == 3:
            from repro.obs import record_fleet_sync

            sampler.sample()
            record_fleet_sync(recorder, fleet)
            _record_transition_cost(recorder, args.workload,
                                    shard0.writer.snapshot(), num_sections)
            if engine is not None:
                engine.evaluate()
            if scaler is not None:
                scaler.tick()
    if args.background:
        for req in pending:
            req.done.wait(timeout=60.0)
        # Shed requests complete instantly with error="shed: ..." — they
        # must not inflate the served count (the sync path's drain() never
        # sees them, so both modes now agree).
        served = len([
            r for r in pending
            if r.done.is_set() and not (r.error or "").startswith("shed")
        ])
    wall = time.perf_counter() - t0
    stats_ok = alerts_ok = True
    if sampler is not None:
        from repro.obs import record_adaptation, record_fleet_sync, record_snapshot

        sampler.sample()
        record_fleet_sync(recorder, fleet)
        snap = shard0.writer.snapshot()
        record_snapshot(recorder, args.workload, snap)
        record_adaptation(recorder, args.workload, snap.summary)
        _record_transition_cost(recorder, args.workload, snap, num_sections)
        _record_profile(recorder, args, shard0.writer)
        if engine is not None:
            engine.evaluate()
            alerts_ok = _alerts_selfcheck(engine, stats_server)
        if stats_server is not None:
            stats_ok = _stats_selfcheck(stats_server)
    report = router.slo_report()

    print(f"\nserved {served} requests ({args.rows_per_query} rows each) in "
          f"{wall:.2f}s ({served / max(wall, 1e-9):.0f} req/s) across "
          f"{args.fleet_shards * args.replicas} replica lane(s)")
    for cls, entry in report["classes"].items():
        if not entry.get("count"):
            print(f"  {cls:28s} admitted={entry.get('admitted', 0)} "
                  f"shed={entry.get('shed', 0)} (nothing served)")
            continue
        print(f"  {cls:28s} p50={entry['p50_ms']:7.2f}ms "
              f"p95={entry['p95_ms']:7.2f}ms p99={entry['p99_ms']:7.2f}ms "
              f"deadline_hit={entry['deadline_hit_rate']:.1%} "
              f"prio={entry['priority']} admitted={entry['admitted']} "
              f"shed={entry['shed']} "
              f"staleness~{entry.get('staleness_mean_s', float('nan')):.3f}s")
    adm = report["admission"]
    print(f"  admission: depth={adm['depth']} "
          f"predicted_miss={adm['predicted_miss_rate']:.3f} "
          f"shed_floor={adm['shed_floor']} total_shed={report['shed']}")
    sync = fleet.sync_stats
    ratio = sync["delta_wire_bytes"] / max(sync["full_wire_bytes"], 1)
    print(f"  delta stream: {sync['syncs']} syncs, "
          f"{sync['delta_wire_bytes']} delta bytes vs "
          f"{sync['full_wire_bytes']} full-snapshot bytes "
          f"({ratio:.2f}x)")

    if args.background:
        router.stop_workers()
        fleet.stop()

    # -- parity: a replica's answer vs the writer's from the same version --
    fleet.sync_all()  # replicas now mirror the writers exactly
    spec = workload.query_specs[workload.default_class]
    qkey, sub = jax.random.split(qkey)
    xs = spec.make_queries(sub, 16)
    w_vals, w_snap = shard0.writer.query(spec, xs)
    r_vals, _ = shard0.replicas[0].serve(spec, workload.default_class, xs)
    err = float(np.max(np.abs(np.asarray(w_vals) - np.asarray(r_vals)))) if len(xs) else 0.0
    if not np.array_equal(np.asarray(w_vals), np.asarray(r_vals)):
        print(f"PARITY FAIL: replica vs writer max|delta|={err:.3g} "
              f"(writer v{w_snap.steps_done}, replica v{shard0.replicas[0].version})")
        _teardown_obs(recorder, stats_server, tracer, args.trace_dir)
        fleet.close()
        return 1
    parity = "ok(bitexact)"
    print(f"  parity: replica {workload.default_class} == writer from the "
          f"same delta-streamed window ({parity})")

    if args.ckpt_dir:
        path = fleet.save(args.ckpt_dir)
        print(f"saved warm fleet to {path}")
    _teardown_obs(recorder, stats_server, tracer, args.trace_dir)
    fleet.close()

    first = next((e for e in report["classes"].values() if e.get("count")), None)
    if (first is None or report["errors"] or (smoke and served < 100)
            or not stats_ok or not alerts_ok):
        # The smoke floor gates BEFORE SERVE_OK: CI greps the log, so a
        # failed smoke must never have printed the success line.
        print(f"SERVE_FAIL workload={args.workload} fleet=1 "
              f"errors={report['errors']} served={served}")
        return 1
    # New fields go AFTER parity= so existing CI greps keep matching.
    print(f"SERVE_OK workload={args.workload} fleet=1 "
          f"shards={args.fleet_shards} replicas={args.replicas} "
          f"queries={served} p50_ms={first['p50_ms']:.2f} "
          f"p95_ms={first['p95_ms']:.2f} "
          f"deadline_hit={first['deadline_hit_rate']:.3f} "
          f"shed={report['shed']} delta_ratio={ratio:.2f} parity={parity} "
          f"subposterior={args.subposterior} combine={args.combine}"
          + (f" stream_rows={stream_rows}" if args.stream else "")
          + (f" alerts_fired={engine.fired_total}"
             if engine is not None else "")
          + (f" scale_up={scaler.events['scale_up']} "
             f"scale_down={scaler.events['scale_down']}"
             if scaler is not None else ""))
    return 0


# ---------------------------------------------------------------------------
# Chaos soak (--soak)
# ---------------------------------------------------------------------------


def serve_soak(args) -> int:
    """Sustained mixed-class load against the multi-replica fleet while one
    replica is SIGKILLed mid-load and later restarted: proves the router
    reroutes around the dead lane without dropping top-class requests and
    that the revived replica full-resyncs to bit-exact parity with the warm
    writer. Prints ``SOAK_OK``/``SOAK_FAIL`` with the recovery counters."""
    from repro.obs import record_fleet_sync, record_snapshot

    smoke = args.smoke
    soak_s = args.soak_seconds or (6.0 if smoke else 30.0)
    # Killing a replica must leave a live lane in its shard.
    args.replicas = max(args.replicas, 2)
    fleet, workload, classes = _build_fleet(args)
    if args.profile_dir:
        fleet.shards(args.workload)[0].writer.arm_profile(args.profile_dir)
    fleet.warm()
    shard0 = fleet.shards(args.workload)[0]
    victim = shard0.replicas[-1]
    router = _build_router(args, fleet, workload)
    recorder, stats_server, sampler, tracer = _setup_obs(args, source=router)
    router.tracer = tracer
    engine = _setup_alerts(args, recorder, stats_server, workload, fleet)
    scaler = _setup_autoscaler(args, fleet, router, recorder, engine)
    num_sections = _obs_num_sections(shard0.writer.ensemble)
    _compile_lanes(args, fleet, workload)
    top = workload.default_class
    print(f"soak: {soak_s:.0f}s mixed-class load "
          f"({', '.join(classes)}; top class {top!r}), "
          f"kill {victim.name} at ~35%, restart at ~65%")

    fleet.start()          # background refresh + delta sync
    router.start_workers()  # one worker thread per replica lane

    t0 = time.perf_counter()
    end = t0 + soak_s
    kill_at = t0 + 0.35 * soak_s
    recover_at = t0 + 0.65 * soak_s
    killed = recovered = False
    full_before = 0
    pending: list = []
    qkey = jax.random.key(args.seed + 1)
    i = 0
    last_sample = t0
    while True:
        now = time.perf_counter()
        if now >= end and recovered:
            break
        if not killed and now >= kill_at:
            recorder.record("chaos", {"event": "kill", "replica": victim.name})
            victim.kill()
            killed = True
            print(f"chaos: killed {victim.name} at t+{now - t0:.1f}s "
                  f"(pending={router.pending_count})")
        if killed and not recovered and now >= recover_at and (
                router.dead_lanes >= 1 or now >= end):
            full_before = fleet.sync_stats["full_deltas"]
            victim.restart()
            fleet.sync_shard(shard0)  # version 0 -> full snapshot resync
            revived = router.revive()
            recovered = True
            recorder.record("chaos", {
                "event": "restart", "replica": victim.name,
                "revived_lanes": revived,
                "replica_version": victim.version,
            })
            print(f"chaos: restarted {victim.name} at t+{now - t0:.1f}s "
                  f"(revived {revived} lane(s), replica v{victim.version})")
        if router.pending_count > 4 * args.max_depth:
            time.sleep(0.01)  # backpressure: let the lane workers catch up
        else:
            cls = classes[i % len(classes)]
            qkey, sub = jax.random.split(qkey)
            xs = workload.query_specs[cls].make_queries(sub, args.rows_per_query)
            pending.append(router.submit(args.workload, cls, xs))
            i += 1
            if i % 8 == 0:
                time.sleep(0.002)  # yield to the worker threads
        if sampler is not None and now - last_sample >= max(soak_s / 12, 0.25):
            sampler.sample()
            record_fleet_sync(recorder, fleet)
            snap_now = shard0.writer.snapshot()
            record_snapshot(recorder, args.workload, snap_now)
            _record_transition_cost(recorder, args.workload, snap_now,
                                    num_sections)
            if engine is not None:
                engine.evaluate()
            # The scaler deliberately does NOT tick during the kill/restart
            # window: the choreography below is the deterministic
            # scale-up-under-pressure / scale-down-after-quiesce proof, and
            # a mid-chaos actuation would spend the replica headroom first.
            last_sample = now

    # -- closed-loop overload burst (--autoscale) --------------------------
    # Drive submissions past the admission shed point and hold them there
    # until the loop closes: the sampler records the active shed floor, the
    # admission_overload rule fires, and the scaler actuates a scale-up.
    burst_submitted = burst_shed = 0
    if scaler is not None:
        low = next((c for c in classes if c != top), top)
        up_before = scaler.events["scale_up"]
        fired_before = engine.fired_total if engine is not None else 0
        burst_done = lambda: (
            scaler.events["scale_up"] > up_before
            and (engine is None or engine.fired_total > fired_before)
        )
        burst_deadline = time.perf_counter() + 60.0
        while not burst_done() and time.perf_counter() < burst_deadline:
            while router.pending_count < args.max_depth + 8:
                qkey, sub = jax.random.split(qkey)
                xs = workload.query_specs[top].make_queries(
                    sub, args.rows_per_query)
                pending.append(router.submit(args.workload, top, xs))
                burst_submitted += 1
            # With the floor up, a low-class submission is refused — the
            # shed that proves the overload point was actually crossed.
            qkey, sub = jax.random.split(qkey)
            shed_probe = router.submit(
                args.workload, low,
                workload.query_specs[low].make_queries(sub, args.rows_per_query))
            burst_shed += int((shed_probe.error or "").startswith("shed"))
            if sampler is not None:
                sampler.sample()
            if engine is not None:
                engine.evaluate()
            scaler.tick()
            time.sleep(0.05)
        print(f"chaos: overload burst submitted {burst_submitted} top-class "
              f"requests (depth {router.pending_count}), "
              f"{burst_shed} low-class shed, "
              f"scale_up={scaler.events['scale_up']}")

    for req in pending:
        req.done.wait(timeout=120.0)

    # -- quiesce: the backlog is drained; tick the scaler until it has
    # retired every replica it added (calm depth -> scale-down events).
    if scaler is not None:
        scaler.observe()  # absorb the burst's shed counters: not fresh pressure
        quiesce_deadline = time.perf_counter() + 60.0
        while scaler.outstanding and time.perf_counter() < quiesce_deadline:
            if sampler is not None:
                sampler.sample()
            if engine is not None:
                engine.evaluate()
            scaler.tick()
            time.sleep(max(args.autoscale_cooldown / 4, 0.05))
        print(f"chaos: quiesce done, scale_down={scaler.events['scale_down']} "
              f"replicas={fleet.replica_count(args.workload)}")
    wall = time.perf_counter() - t0
    stats_ok = alerts_ok = True
    if sampler is not None:
        sampler.sample()
        record_fleet_sync(recorder, fleet)
        snap_final = shard0.writer.snapshot()
        record_snapshot(recorder, args.workload, snap_final)
        _record_transition_cost(recorder, args.workload, snap_final,
                                num_sections)
        _record_profile(recorder, args, shard0.writer)
        if engine is not None:
            engine.evaluate()
            alerts_ok = _alerts_selfcheck(engine, stats_server)
        if stats_server is not None:
            stats_ok = _stats_selfcheck(stats_server)
    report = router.slo_report()
    router.stop_workers()
    fleet.stop()

    # -- post-chaos parity: EVERY current replica (the revived victim and
    # any autoscaler survivors) vs the warm writer, bit-exact ---------------
    fleet.sync_all()
    resyncs = fleet.sync_stats["full_deltas"] - full_before
    spec = workload.query_specs[top]
    qkey, sub = jax.random.split(qkey)
    xs = spec.make_queries(sub, 16)
    # Re-read shard0: runtime add/remove_replica swapped the shard entry,
    # so the launch-time NamedTuple's replica tuple is stale.
    shard0 = fleet.shards(args.workload)[0]
    w_vals, w_snap = shard0.writer.query(spec, xs)
    parity_bad = []
    for replica in shard0.replicas:
        r_vals, _ = replica.serve(spec, top, xs)
        if not np.array_equal(np.asarray(w_vals), np.asarray(r_vals)):
            err = float(np.max(np.abs(np.asarray(w_vals) - np.asarray(r_vals))))
            parity_bad.append(f"{replica.name} max|delta|={err:.3g} "
                              f"v{replica.version}")
    parity_ok = not parity_bad

    served = len([
        r for r in pending
        if r.done.is_set() and not (r.error or "").startswith("shed")
    ])
    recovery = report["recovery"]
    top_entry = report["classes"].get(f"{args.workload}.{top}", {})
    top_reqs = [r for r in pending if r.query_class == top]
    dropped = [r for r in top_reqs if not r.done.is_set()]
    print(f"\nsoak: {served} served / {len(pending)} submitted in {wall:.1f}s "
          f"({served / max(wall, 1e-9):.0f} req/s), shed={report['shed']}, "
          f"lane_deaths={recovery['lane_deaths']}, "
          f"rerouted={recovery['rerouted']}, "
          f"dead_lanes={recovery['dead_lanes']}, resyncs={resyncs}")

    failures = []
    if not top_entry.get("count"):
        failures.append(f"no completed top-class ({top!r}) requests in report")
    if not killed or not recovered:
        failures.append("kill/restart never fired (soak too short?)")
    if recovery["lane_deaths"] < 1:
        failures.append("victim lane never died under load")
    if recovery["dead_lanes"]:
        failures.append(f"{recovery['dead_lanes']} lane(s) still dead after revive")
    if dropped:
        failures.append(f"{len(dropped)} top-class request(s) never completed")
    if top_entry.get("errors", 0):
        failures.append(f"top-class errors={top_entry['errors']}")
    if top_entry.get("shed", 0):
        failures.append(f"top-class shed={top_entry['shed']}")
    if resyncs < 1:
        failures.append("restarted replica never full-resynced")
    if not parity_ok:
        failures.append(
            f"parity vs writer v{w_snap.steps_done}: " + "; ".join(parity_bad))
    if not stats_ok:
        failures.append("stats endpoint self-check failed")
    if not alerts_ok:
        failures.append("alert engine self-check failed")
    if scaler is not None:
        if scaler.events["scale_up"] < 1:
            failures.append("autoscaler never scaled up under overload")
        if scaler.events["scale_down"] < 1:
            failures.append("autoscaler never scaled down after quiesce")
        if burst_shed < 1:
            failures.append("overload burst never crossed the shed point")
        if engine is not None and engine.fired_total < 1:
            failures.append("no alert fired during the overload burst")

    _teardown_obs(recorder, stats_server, tracer, args.trace_dir)
    fleet.close()
    if failures:
        print(f"SOAK_FAIL workload={args.workload} " + "; ".join(failures))
        return 1
    # New fields go AFTER parity= so existing CI greps keep matching.
    print(f"SOAK_OK workload={args.workload} soak_s={wall:.1f} "
          f"served={served} kills=1 recovered=1 resyncs={resyncs} "
          f"reroutes={recovery['rerouted']} "
          f"lane_deaths={recovery['lane_deaths']} shed={report['shed']} "
          f"top_class_errors=0 "
          f"p95_ms={top_entry.get('p95_ms') or float('nan'):.2f} "
          f"parity=ok(bitexact)"
          + (f" alerts_fired={engine.fired_total}"
             if engine is not None else "")
          + (f" scale_up={scaler.events['scale_up']} "
             f"scale_down={scaler.events['scale_down']}"
             if scaler is not None else ""))
    return 0


# ---------------------------------------------------------------------------
# Legacy LM decoding demo (--workload lm)
# ---------------------------------------------------------------------------


def serve_lm(args) -> int:
    from repro.checkpoint import manager as ckpt
    from repro.configs import reduce_config
    from repro.distributed.sharding import logical_axis_rules
    from repro.models import decode_step, init_params, prefill

    from .mesh import make_mesh_for_devices

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = make_mesh_for_devices(model_parallel=args.model_parallel)
    with logical_axis_rules(mesh), mesh:
        params = init_params(jax.random.key(0), cfg)
        if args.ckpt_dir:
            _, params = ckpt.restore(args.ckpt_dir, target=params)
            print(f"restored posterior sample from {args.ckpt_dir}")
        prompts = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
        )
        extra = None
        if cfg.family == "audio":
            extra = {"frames": 0.1 * jax.random.normal(
                jax.random.key(2), (args.batch, cfg.n_audio_frames, cfg.d_model),
                jnp.bfloat16)}
        max_len = args.prompt_len + args.gen_len + 8
        jprefill = jax.jit(lambda p, t: prefill(p, t, cfg, max_len, extra))
        jdecode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

        t0 = time.perf_counter()
        cache, logits = jprefill(params, prompts)
        jax.block_until_ready(logits)
        t_pre = time.perf_counter() - t0
        tok = jnp.argmax(logits, -1)[:, None]
        key = jax.random.key(3)
        t0 = time.perf_counter()
        for _ in range(args.gen_len):
            key, sub = jax.random.split(key)
            cache, logits = jdecode(params, cache, tok)
            tok = jax.random.categorical(sub, logits, axis=-1)[:, None]
        jax.block_until_ready(logits)
        t_dec = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_pre:.2f}s "
          f"({args.batch * args.prompt_len / t_pre:.0f} tok/s)")
    print(f"decode {args.gen_len} steps: {t_dec:.2f}s "
          f"({args.batch * args.gen_len / t_dec:.0f} tok/s)")
    return 0


_LM_ONLY_FLAGS = ("arch", "reduced", "batch", "prompt_len", "gen_len",
                  "model_parallel")


def main(argv=None) -> None:
    parser = build_parser()
    args = parser.parse_args(argv)
    if args.fleet and args.workload == "lm":
        parser.error("--fleet serves posterior workloads, not the lm demo")
    if args.subposterior > 1 or args.stream:
        if args.workload == "lm":
            parser.error("--subposterior/--stream serve posterior "
                         "workloads through the fleet, not the lm demo")
        args.fleet = True  # both modes live in the fleet serve path
    if args.autoscale:
        if args.workload == "lm":
            parser.error("--autoscale scales the replica fleet, not the "
                         "lm demo")
        args.fleet = True  # the actuator needs replica lanes to scale
    if args.alerts and args.workload == "lm":
        parser.error("--alerts applies to posterior serving, not the lm demo")
    if args.fleet and args.devices:
        # Must land before JAX initializes its backends (importing jax is
        # fine; creating the first array is not) — hence a fresh
        # `python -m repro.launch.serve` process, not a long-lived session.
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={args.devices}".strip()
            )
    if args.workload != "lm":
        # Guard legacy invocations: the pre-serving CLI was LM-only and had
        # no --workload flag, so `serve --arch ... --batch 8` must not be
        # silently rewired onto the bayeslr posterior service.
        drifted = [f"--{name.replace('_', '-')}" for name in _LM_ONLY_FLAGS
                   if getattr(args, name) != parser.get_default(name)]
        if drifted:
            parser.error(
                f"{', '.join(drifted)} only apply to the LM decoding demo; "
                "add --workload lm (posterior serving ignores them)"
            )
    if args.soak and (args.workload == "lm" or not args.fleet):
        parser.error("--soak drives the replica fleet: add --fleet "
                     "(and a posterior --workload)")
    if args.workload == "lm":
        code = serve_lm(args)
    elif args.soak:
        code = serve_soak(args)
    elif args.fleet:
        code = serve_fleet(args)
    else:
        code = serve_posterior(args)
    if code:
        sys.exit(code)


if __name__ == "__main__":
    main()
