"""Production serve launcher: batched posterior-predictive decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --reduced \
        --batch 8 --prompt-len 64 --gen-len 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs import ARCHS, reduce_config
from repro.distributed.sharding import logical_axis_rules
from repro.models import decode_step, init_params, prefill
from .mesh import make_mesh_for_devices


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params (a posterior sample) from here")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = make_mesh_for_devices(model_parallel=args.model_parallel)
    with logical_axis_rules(mesh), mesh:
        params = init_params(jax.random.key(0), cfg)
        if args.ckpt_dir:
            _, params = ckpt.restore(args.ckpt_dir, target=params)
            print(f"restored posterior sample from {args.ckpt_dir}")
        prompts = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
        )
        extra = None
        if cfg.family == "audio":
            extra = {"frames": 0.1 * jax.random.normal(
                jax.random.key(2), (args.batch, cfg.n_audio_frames, cfg.d_model),
                jnp.bfloat16)}
        max_len = args.prompt_len + args.gen_len + 8
        jprefill = jax.jit(lambda p, t: prefill(p, t, cfg, max_len, extra))
        jdecode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

        t0 = time.perf_counter()
        cache, logits = jprefill(params, prompts)
        jax.block_until_ready(logits)
        t_pre = time.perf_counter() - t0
        tok = jnp.argmax(logits, -1)[:, None]
        key = jax.random.key(3)
        t0 = time.perf_counter()
        for _ in range(args.gen_len):
            key, sub = jax.random.split(key)
            cache, logits = jdecode(params, cache, tok)
            tok = jax.random.categorical(sub, logits, axis=-1)[:, None]
        jax.block_until_ready(logits)
        t_dec = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_pre:.2f}s "
          f"({args.batch * args.prompt_len / t_pre:.0f} tok/s)")
    print(f"decode {args.gen_len} steps: {t_dec:.2f}s "
          f"({args.batch * args.gen_len / t_dec:.0f} tok/s)")


if __name__ == "__main__":
    main()
