"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run forces 512 host devices BEFORE calling this).
"""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    # jax < 0.5 has no AxisType / axis_types kwarg; Auto is its only behavior.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh_for_devices(n_devices: int | None = None, model_parallel: int | None = None):
    """Smaller meshes for tests/examples: (data, model) factorization of the
    available device count."""
    n = n_devices or len(jax.devices())
    mp = model_parallel or 1
    assert n % mp == 0
    return jax.make_mesh((n // mp, mp), ("data", "model"), **_axis_types_kw(2))
