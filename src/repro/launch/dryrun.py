import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below may import jax.

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, shape_applicable  # noqa: E402
from repro.distributed.sharding import logical_axis_rules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<ty>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def scan_trip_count(cfg) -> int:
    """Trip count of the model's layer scan: collectives inside the scanned
    body appear ONCE in HLO text but execute once per layer/period."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_period
    if cfg.family == "ssm":
        return cfg.n_layers // 2
    return cfg.n_layers


def parse_collectives(hlo_text: str, loop_scale: int = 1) -> dict:
    """Sum per-device result bytes of every collective in post-SPMD HLO.

    all-reduce wire volume is counted 2x (ring reduce-scatter + all-gather);
    -done ops are skipped (their -start carries the shape). Collectives in
    non-ENTRY computations (loop bodies / called computations) are scaled by
    ``loop_scale`` — the layer-scan trip count — since the HLO text shows the
    body once. This over-scales collectives in non-layer subcomputations and
    under-scales doubly-nested ones; it is the consistent first-order
    correction (documented in DESIGN.md §8).
    """
    out: dict[str, dict] = {}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
        elif line.startswith("}"):
            in_entry = in_entry and not line.startswith("}")
        elif line.startswith("%") and line.rstrip().endswith("{"):
            in_entry = False
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group("ty")):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        scale = 1 if in_entry else loop_scale
        rec = out.setdefault(op, {"count": 0, "result_bytes": 0, "wire_bytes": 0})
        rec["count"] += 1
        rec["result_bytes"] += nbytes * scale
        rec["wire_bytes"] += nbytes * scale * (2 if op == "all-reduce" else 1)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             rules_name: str = "default", kv_dtype: str | None = None,
             tag: str = "", cached: bool = False) -> dict:
    from repro.launch.steps import RULE_PRESETS

    spec = SHAPES[shape]
    mesh_name = "multi" if multi_pod else "single"
    record: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                    "rules": rules_name, "kv_dtype": kv_dtype, "tag": tag}
    ok, reason = shape_applicable(arch, shape)
    if not ok:
        record.update(status="skipped", reason=reason)
        return record
    try:
        rules = RULE_PRESETS[rules_name]
        mesh = make_production_mesh(multi_pod=multi_pod)
        with logical_axis_rules(mesh, rules):
            train_cfg = None
            if cached and SHAPES[shape].kind == "train":
                from repro.launch.steps import default_train_config

                train_cfg = __import__("dataclasses").replace(
                    default_train_config(ARCHS[arch], SHAPES[shape]), cached=True
                )
            cell = build_cell(arch, shape, mesh, train_cfg=train_cfg,
                              rules=rules, kv_dtype=kv_dtype)
            jitted = jax.jit(
                cell.step,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            )
            t0 = time.time()
            with mesh:
                lowered = jitted.lower(*cell.in_specs)
                t_lower = time.time() - t0
                t0 = time.time()
                compiled = lowered.compile()
                t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        colls = parse_collectives(compiled.as_text(), scan_trip_count(cell.cfg))
        colls_raw = parse_collectives(compiled.as_text(), 1)
        cfg = cell.cfg
        n_chips = 512 if multi_pod else 256
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=float(ca.get("flops", 0.0)),
            bytes_per_device=float(ca.get("bytes accessed", 0.0)),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            collectives=colls,
            collective_wire_bytes_per_device=sum(
                c["wire_bytes"] for c in colls.values()
            ),
            collective_wire_bytes_unscaled=sum(
                c["wire_bytes"] for c in colls_raw.values()
            ),
            loop_scale=scan_trip_count(cell.cfg),
            n_chips=n_chips,
            params_total=cfg.param_count(),
            params_active=cfg.active_param_count(),
            tokens=spec.global_batch * spec.seq_len,
            step_kind=spec.kind,
            train_round_batch=(cell.train_cfg.round_batch if cell.train_cfg else None),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug to record
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    finally:
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            suffix = f"__{tag}" if tag else ""
            fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}{suffix}.json")
            with open(fn, "w") as f:
                json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default=None, choices=list(ARCHS), help="one architecture")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--rules", default="default",
                    choices=["default", "infer_tp", "infer_replicate", "mamba_dp", "jamba_prefill"])
    ap.add_argument("--kv-dtype", default=None, choices=[None, "bf16", "fp8"])
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--cached", action="store_true", help="lazy loglik cache train step")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all and not args.arch and not args.shape:
        ap.error("pass --all or select --arch/--shape")

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name == "multi", args.out,
                               rules_name=args.rules, kv_dtype=args.kv_dtype,
                               tag=args.tag, cached=args.cached)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f" flops/dev={rec['flops_per_device']:.3e}"
                        f" coll={rec['collective_wire_bytes_per_device']:.3e}B"
                        f" temp={rec['memory']['temp_bytes']/2**30:.2f}GiB"
                        f" compile={rec['compile_s']}s"
                    )
                elif status == "error":
                    failures += 1
                    extra = " " + rec["error"][:160]
                print(f"[{status:7s}] {arch} x {shape} x {mesh_name}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
