"""Production train launcher: subsampled-MH chain over an architecture's
parameters with checkpoint/restart, preemption handling, and deterministic
resume.

On real hardware this runs under the production mesh; on this CPU container
use ``--reduced`` for a structurally-identical smoke run:

    PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --reduced \
        --steps 20 --ckpt-dir /tmp/chain
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.bayes import TrainConfig, make_exact_step, make_train_step
from repro.configs import ARCHS, reduce_config
from repro.data import DataConfig, MarkovStream
from repro.distributed.sharding import logical_axis_rules
from repro.models import init_params
from repro.runtime import LoopConfig, run_loop
from .mesh import make_mesh_for_devices


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--round-batch", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--sigma", type=float, default=1e-4)
    ap.add_argument("--kernel", default="subsampled", choices=["subsampled", "exact"])
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--preempt-flag", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_config(cfg)
    tc = TrainConfig(round_batch=args.round_batch, epsilon=args.epsilon,
                     sigma=args.sigma)
    maker = make_train_step if args.kernel == "subsampled" else make_exact_step
    mesh = make_mesh_for_devices(model_parallel=args.model_parallel)

    with logical_axis_rules(mesh), mesh:
        params = init_params(jax.random.key(0), cfg)
        step_fn = jax.jit(maker(cfg, tc))
        stream = MarkovStream(
            DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=0)
        )
        out = run_loop(
            step_fn, params, stream.batch,
            LoopConfig(num_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, preempt_flag=args.preempt_flag),
        )
    infos = out["infos"]
    acc = np.mean([i["accepted"] for i in infos]) if infos else float("nan")
    n_eval = np.mean([i["n_evaluated"] for i in infos]) if infos else float("nan")
    print(f"done: step={out['step']} acceptance={acc:.2f} "
          f"mean_sections={n_eval:.1f}/{args.batch}")


if __name__ == "__main__":
    main()
