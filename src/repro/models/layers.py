"""Transformer building blocks with logical-axis sharding annotations.

Everything is written against plain dict parameter trees (leaves are arrays;
the parallel "axes" tree holds logical-axis name tuples consumed by
``distributed.sharding``). Layers are shape-polymorphic over a leading
stacked-layer dimension so the model loops with ``lax.scan``.

Conventions:
  B batch, S sequence, D d_model, H q-heads, K kv-heads, h head_dim,
  F d_ff, E experts, V vocab, T = B*S flattened tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.sharding import lc  # logical constraint (no-op without mesh)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple  # logical axis name (or None) per dim
    dtype: Any = jnp.bfloat16
    init_scale: str = "fan_in"  # "fan_in" | "one" | "zero" | "normal"


def init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init_scale == "one":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init_scale == "zero":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init_scale == "embed":
        scale = 0.02  # keeps tied-unembedding logits O(1) at init
    elif spec.init_scale == "normal":
        scale = 1.0
    else:
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        scale = fan_in**-0.5
    return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_table(positions: jax.Array, head_dim: int, base: float, rotary_frac: float = 1.0):
    """cos/sin tables (S, rot/2). ``rotary_frac`` < 1 rotates only the first
    rot = head_dim*frac dims (ChatGLM's 2d/partial RoPE)."""
    rot = int(head_dim * rotary_frac)
    rot -= rot % 2
    freqs = base ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (S, rot/2)
    return jnp.cos(angles), jnp.sin(angles), rot


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, rot: int) -> jax.Array:
    """x: (B, S, N, h); cos/sin: (S, rot/2) or (B, S, rot/2)."""
    dt = x.dtype
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :].astype(jnp.float32)
    sin = sin[:, :, None, :].astype(jnp.float32)
    xr = x[..., :rot].astype(jnp.float32)
    xp = x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape).astype(dt)
    return jnp.concatenate([yr, xp], axis=-1) if rot < x.shape[-1] else yr


# ---------------------------------------------------------------------------
# Attention (GQA + sliding window as data + optional qk-norm / bias)
#
# Three execution paths share one mask rule:
#   - dense:   materialize (S, T) logits (short sequences),
#   - flash:   lax.scan over q- and kv-chunks with online softmax (long
#              sequences; (B,S,T) never materializes — pure-JAX flash attn),
#   - cached:  decode/prefill against a ring-buffer KV cache whose slot
#              positions are explicit, so sliding-window archs keep an
#              O(window) cache even at 500k-token contexts.
# ---------------------------------------------------------------------------

FLASH_THRESHOLD = 2048  # use chunked attention above this many query rows
_NEG = -1e30


def _mask(q_pos, k_pos, window, causal):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    m &= (q_pos[:, None] - k_pos[None, :]) < window
    m &= k_pos[None, :] >= 0  # ring-buffer slots still empty carry pos = -1
    return m


def _attend_dense(qg, k_all, v_all, q_pos, k_pos, window, causal, scale):
    b, s, n_kv, group, hd = qg.shape
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_all).astype(jnp.float32) * scale
    mask = _mask(q_pos, k_pos, window, causal)[None, None, None]
    logits = jnp.where(mask, logits, _NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(qg.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", probs, v_all)


def _attend_flash(qg, k_all, v_all, q_pos, k_pos, window, causal, scale,
                  chunk_q: int = 256, chunk_kv: int = 512):
    """Online-softmax chunked attention: scan over q chunks, inner scan over
    kv chunks. Memory is O(chunk_q * chunk_kv) per head instead of O(S*T)."""
    b, s, n_kv, group, hd = qg.shape
    t = k_all.shape[1]
    cq = min(chunk_q, s)
    ckv = min(chunk_kv, t)
    nq = -(-s // cq)
    nkv = -(-t // ckv)
    pad_q = nq * cq - s
    pad_kv = nkv * ckv - t

    qg_p = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(q_pos, (0, pad_q), constant_values=-(1 << 29))
    k_p = jnp.pad(k_all, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    v_p = jnp.pad(v_all, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    kpos_p = jnp.pad(k_pos, (0, pad_kv), constant_values=-1)

    q_chunks = qg_p.reshape(b, nq, cq, n_kv, group, hd).swapaxes(0, 1)
    qpos_chunks = qpos_p.reshape(nq, cq)
    k_chunks = k_p.reshape(b, nkv, ckv, n_kv, hd).swapaxes(0, 1)
    v_chunks = v_p.reshape(b, nkv, ckv, n_kv, hd).swapaxes(0, 1)
    kpos_chunks = kpos_p.reshape(nkv, ckv)

    def q_step(_, q_in):
        q_c, qp = q_in  # (B, cq, K, g, h), (cq,)

        def kv_step(carry, kv_in):
            m_run, l_run, acc = carry
            k_c, v_c, kp = kv_in
            logits = jnp.einsum("bskgh,btkh->bkgst", q_c, k_c).astype(jnp.float32) * scale
            mask = _mask(qp, kp, window, causal)[None, None, None]
            logits = jnp.where(mask, logits, _NEG)
            m_new = jnp.maximum(m_run, logits.max(-1))
            corr = jnp.exp(m_run - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p.astype(q_c.dtype), v_c
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, n_kv, group, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, n_kv, group, cq), jnp.float32)
        a0 = jnp.zeros((b, n_kv, group, cq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k_chunks, v_chunks, kpos_chunks))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(q_c.dtype)  # (B, K, g, cq, h)

    _, outs = jax.lax.scan(q_step, None, (q_chunks, qpos_chunks))
    # outs: (nq, B, K, g, cq, h) -> (B, S, K, g, h)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * cq, n_kv, group, hd)
    return out[:, :s]


def attention(
    x: jax.Array,  # (B, S, D)
    p: Params,  # wq (D, H, h), wk/wv (D, K, h), wo (H, h, D), optional bq/bk/bv, qnorm/knorm
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions: jax.Array,  # (S,) or (B, S)
    window: jax.Array | int,  # sliding-window size (>= S means full); traced OK
    rope_base: float,
    rotary_frac: float = 1.0,
    causal: bool = True,
    kv_cache: tuple | None = None,  # (k_buf (B,C,K,h), v_buf, length, slot_pos (C,))
    q_scale: float | None = None,
    use_rope: bool = True,
) -> tuple[jax.Array, tuple | None]:
    b, s, d = x.shape
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "qnorm" in p:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    pos = positions if positions.ndim == 1 else positions[0]
    if use_rope:
        cos, sin, rot = rope_table(pos, head_dim, rope_base, rotary_frac)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)
    q = lc(q, ("batch", None, "q_heads", None))
    k = lc(k, ("batch", None, "kv_heads", None))

    if kv_cache is not None:
        # slot_pos has already been advanced for this step by the caller
        k_buf, v_buf, length, slot_pos = kv_cache
        cache_len = k_buf.shape[1]
        if s >= cache_len:
            # prefilling a window-sized ring: attend in-sequence, store the
            # tail at its ring slots (slot of absolute position p = p % C, so
            # later decode inserts at length % C overwrite the oldest entry)
            shift = (s - cache_len) % cache_len
            k_buf = jnp.roll(k[:, -cache_len:].astype(k_buf.dtype), shift, axis=1)
            v_buf = jnp.roll(v[:, -cache_len:].astype(v_buf.dtype), shift, axis=1)
            k_all, v_all = k, v
            k_pos = pos
        else:
            ins = length % cache_len  # ring buffer (SWA: cache_len = window)
            k_buf = jax.lax.dynamic_update_slice_in_dim(
                k_buf, k.astype(k_buf.dtype), ins, axis=1
            )
            v_buf = jax.lax.dynamic_update_slice_in_dim(
                v_buf, v.astype(v_buf.dtype), ins, axis=1
            )
            k_all, v_all = k_buf, v_buf
            k_pos = slot_pos
        q_pos = pos
        new_cache = (k_buf, v_buf)
        if k_all.dtype != q.dtype:  # quantized (fp8) cache: dequant on read
            k_all = k_all.astype(q.dtype)
            v_all = v_all.astype(q.dtype)
    else:
        k_all, v_all = k, v
        k_pos = pos
        q_pos = pos
        new_cache = None

    group = n_heads // n_kv
    qg = q.reshape(b, s, n_kv, group, head_dim)
    scale = q_scale if q_scale is not None else head_dim**-0.5
    if s > FLASH_THRESHOLD or (k_all.shape[1] > 4 * FLASH_THRESHOLD and s > 1):
        out5 = _attend_flash(qg, k_all, v_all, q_pos, k_pos, window, causal, scale)
    else:
        out5 = _attend_dense(qg, k_all, v_all, q_pos, k_pos, window, causal, scale)
    out = out5.reshape(b, s, n_heads, head_dim)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return lc(y, ("batch", None, None)), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_mlp(x: jax.Array, p: Params) -> jax.Array:
    """p: wi_gate (D, F), wi_up (D, F), wo (F, D)."""
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = lc(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def gelu_mlp(x: jax.Array, p: Params) -> jax.Array:
    """p: wi (D, F), bi (F,), wo (F, D), bo (D,). (Whisper-style.)"""
    h = jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = lc(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"]) + p["bo"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-bounded sort-free dispatch)
# ---------------------------------------------------------------------------


def moe_mlp(
    x: jax.Array,  # (B, S, D)
    p: Params,  # router (D, E), wi_gate/wi_up (E, D, F), wo (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    n_groups: int | None = None,
) -> jax.Array:
    """Top-k MoE with GShard-style *grouped* dispatch: tokens are split into
    data-sharded groups, each dispatched to capacity-bounded expert buffers
    locally. Grouping keeps the scatter/gather shard-local (a global scatter
    over a sharded token axis made XLA replicate the (T, D) updates — 100+ GiB
    per device at 1M-token prefill in the dry-run); cross-shard traffic is
    then only the expert-sharded einsum's all-to-all, as in GShard/Switch.
    """
    b, s, d = x.shape
    e = p["router"].shape[-1]
    t = b * s
    if n_groups is None:
        n_groups = b if (s > 1 and b >= 16) else 1
    gn = n_groups
    g_sz = t // gn
    xt = lc(x.reshape(gn, g_sz, d), ("batch", None, None))
    logits = jnp.einsum("gtd,de->gte", xt, p["router"]).astype(jnp.float32)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(gate_all, top_k)  # (G, T/G, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Sequential chunking over long groups bounds live expert-buffer memory
    # (the dispatch buffers are ~4x token bytes; at 65k tokens/device the
    # un-chunked version held ~16 GiB of transients in the dry-run).
    chunk = min(g_sz, 8192)
    n_c = g_sz // chunk

    # floor keeps tiny decode batches drop-free (capacity-1 buckets would
    # silently drop second experts and skew the decode distribution)
    capacity = max(int(capacity_factor * chunk * top_k / e), min(chunk * top_k, 32))
    token_id = jnp.repeat(jnp.arange(chunk), top_k)  # shared across groups

    def one_chunk(_, inp):
        xc, gate_c, sel_c = inp  # (G, chunk, D), (G, chunk, k), (G, chunk, k)
        sel_flat = sel_c.reshape(gn, chunk * top_k)
        onehot = jax.nn.one_hot(sel_flat, e, dtype=jnp.int32)
        pos = ((jnp.cumsum(onehot, axis=1) - 1) * onehot).sum(-1)
        keep = pos < capacity
        slot = jnp.where(keep, sel_flat * capacity + pos, e * capacity)

        def scatter_group(xg, sl):
            return jnp.zeros((e * capacity + 1, d), xg.dtype).at[sl].set(xg[token_id])

        buf = jax.vmap(scatter_group)(xc, slot)[:, :-1].reshape(gn, e, capacity, d)
        # experts -> model when divisible; otherwise the capacity dim picks up
        # the model axis (mixtral's 8 experts on a 16-way axis)
        buf = lc(buf, ("batch", "experts", "capacity", None))

        g = jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"])
        u = jnp.einsum("gecd,edf->gecf", buf, p["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        h = lc(h, ("batch", "experts", "capacity", None))
        out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])
        out_buf = lc(out_buf, ("batch", "experts", "capacity", None)).reshape(
            gn, e * capacity, d
        )
        out_buf = jnp.concatenate([out_buf, jnp.zeros((gn, 1, d), x.dtype)], axis=1)
        wgt = (gate_c.reshape(gn, -1, 1) * keep[..., None]).astype(x.dtype)

        def combine_group(ob, sl, wg):
            per_assign = ob[sl] * wg
            return jnp.zeros((chunk, d), x.dtype).at[token_id].add(per_assign)

        return None, jax.vmap(combine_group)(out_buf, slot, wgt)

    if n_c == 1:
        _, y = one_chunk(None, (xt, gate, sel))
    else:
        xs = (
            xt.reshape(gn, n_c, chunk, d).swapaxes(0, 1),
            gate.reshape(gn, n_c, chunk, top_k).swapaxes(0, 1),
            sel.reshape(gn, n_c, chunk, top_k).swapaxes(0, 1),
        )
        _, ys = jax.lax.scan(one_chunk, None, xs)  # (n_c, G, chunk, D)
        y = ys.swapaxes(0, 1).reshape(gn, g_sz, d)
    return lc(y.reshape(b, s, d), ("batch", None, None))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed(tokens: jax.Array, table: jax.Array, scale: bool = False) -> jax.Array:
    h = jnp.take(table, tokens, axis=0)
    if scale:
        h = h * jnp.asarray(table.shape[-1] ** 0.5, h.dtype)
    return lc(h, ("batch", None, None))


def unembed_loglik(
    h: jax.Array,  # (B, S, D)
    table: jax.Array,  # (V, D) (tied) — logits = h @ table.T
    targets: jax.Array,  # (B, S)
    mask: jax.Array,  # (B, S)
    chunk: int = 512,
) -> jax.Array:
    """Per-sequence log-likelihood, seq-chunked so (B,S,V) never materializes.

    This is the pure-jnp reference path; kernels/fused_ce is the TPU kernel
    with identical semantics (vocab-blocked online logsumexp).
    """
    b, s, d = h.shape

    def one_chunk(carry, inp):
        hc, tc, mc = inp  # (B, c, D), (B, c), (B, c)
        logits = jnp.einsum("bcd,vd->bcv", hc, table).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return carry + ((tgt - logz) * mc).sum(-1), None

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, pad)))
    mp = jnp.pad(mask.astype(h.dtype), ((0, 0), (0, pad)))
    hs = hp.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    ts = tp.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    ms = mp.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(one_chunk, jnp.zeros((b,), jnp.float32), (hs, ts, ms))
    return total
