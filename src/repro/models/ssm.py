"""Recurrent sequence blocks: Mamba (selective SSM), xLSTM (mLSTM + sLSTM).

All blocks share the calling convention

    y, new_state = block(x, params, state=None)

with ``x: (B, S, D)``; ``state`` carries the recurrent summary for decode
(one-token steps with S=1 continue from ``state``). Training uses
``lax.scan`` over time — the recurrences are the sub-quadratic reason these
architectures run the 500k-token decode shape.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Mamba (S6) block
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    conv: jax.Array  # (B, kernel-1, di) trailing inputs for the causal conv
    ssm: jax.Array  # (B, di, ds)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, prefix: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, di); w: (k, di); prefix: (B, k-1, di)."""
    k = w.shape[0]
    xp = jnp.concatenate([prefix, x], axis=1)
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + w[j] * jax.lax.dynamic_slice_in_dim(xp, j, x.shape[1], axis=1)
    return out + b


def mamba_block(
    x: jax.Array, p: Params, state: MambaState | None = None
) -> tuple[jax.Array, MambaState]:
    b, s, d = x.shape
    di = p["a_log"].shape[0]
    ds = p["a_log"].shape[1]
    kernel = p["conv_w"].shape[0]

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each

    prefix = (
        state.conv if state is not None else jnp.zeros((b, kernel - 1, di), x.dtype)
    )
    x_c = _causal_conv(x_in, p["conv_w"], p["conv_b"], prefix)
    new_conv = jnp.concatenate([prefix, x_in], axis=1)[:, -(kernel - 1):, :]
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bse,ef->bsf", x_c, p["x_proj"])
    dt_rank = p["dt_proj"].shape[0]
    dt_r = proj[..., :dt_rank]
    b_mat = proj[..., dt_rank : dt_rank + ds].astype(jnp.float32)
    c_mat = proj[..., dt_rank + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]).astype(jnp.float32) + p["dt_bias"]
    )  # (B, S, di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, ds)

    h0 = (
        state.ssm.astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, di, ds), jnp.float32)
    )
    xcf = x_c.astype(jnp.float32)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp  # (B,di), (B,ds), (B,ds), (B,di)
        decay = jnp.exp(dt_t[..., None] * a)  # (B, di, ds)
        h = h * decay + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bes,bs->be", h, c_t)
        return h, y_t

    h_final, ys = jax.lax.scan(
        step,
        h0,
        (
            dt.swapaxes(0, 1),
            b_mat.swapaxes(0, 1),
            c_mat.swapaxes(0, 1),
            xcf.swapaxes(0, 1),
        ),
    )
    y = ys.swapaxes(0, 1) + p["d_skip"].astype(jnp.float32) * xcf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, MambaState(conv=new_conv, ssm=h_final.astype(jnp.float32))


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) block
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dh, dh)
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H)


def mlstm_block(
    x: jax.Array, p: Params, state: MLSTMState | None = None
) -> tuple[jax.Array, MLSTMState]:
    b, s, d = x.shape
    n_heads, dh = p["wq"].shape[1], p["wq"].shape[2]
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"]).astype(jnp.float32) * dh**-0.5
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"]).astype(jnp.float32)
    i_log = jnp.einsum("bsd,dn->bsn", x, p["wi"]).astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(jnp.einsum("bsd,dn->bsn", x, p["wf"]).astype(jnp.float32))
    o_gate = jax.nn.sigmoid(jnp.einsum("bsd,dn->bsn", x, p["wo_gate"]).astype(jnp.float32))

    if state is None:
        c0 = jnp.zeros((b, n_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, n_heads, dh), jnp.float32)
        m0 = jnp.full((b, n_heads), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, i_t, f_t, o_t = inp
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c = f_p[..., None, None] * c + i_p[..., None, None] * jnp.einsum(
            "bnh,bng->bnhg", v_t, k_t
        )
        n = f_p[..., None] * n + i_p[..., None] * k_t
        num = jnp.einsum("bnhg,bng->bnh", c, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bng,bng->bn", n, q_t)), 1.0)
        h_t = o_t[..., None] * num / den[..., None]
        return (c, n, m_new), h_t

    (c_f, n_f, m_f), hs = jax.lax.scan(
        step,
        (c0, n0, m0),
        (
            q.swapaxes(0, 1),
            k.swapaxes(0, 1),
            v.swapaxes(0, 1),
            i_log.swapaxes(0, 1),
            f_log.swapaxes(0, 1),
            o_gate.swapaxes(0, 1),
        ),
    )
    h = hs.swapaxes(0, 1).reshape(b, s, n_heads * dh).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", h, p["out_proj"])
    return out, MLSTMState(c=c_f, n=n_f, m=m_f)


# ---------------------------------------------------------------------------
# xLSTM: sLSTM (scalar memory, recurrent gates) block
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    h: jax.Array  # (B, H, dh)
    c: jax.Array  # (B, H, dh)
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H, dh)


def slstm_block(
    x: jax.Array, p: Params, state: SLSTMState | None = None
) -> tuple[jax.Array, SLSTMState]:
    b, s, d = x.shape
    n_heads, dh = p["r"].shape[0], p["r"].shape[1]
    wx = jnp.einsum("bsd,dnf->bsnf", x, p["w"]).astype(jnp.float32)  # (B,S,H,4dh)

    if state is None:
        zeros = jnp.zeros((b, n_heads, dh), jnp.float32)
        st = SLSTMState(zeros, zeros, zeros, jnp.full((b, n_heads, dh), -1e30))
    else:
        st = state

    r = p["r"].astype(jnp.float32)  # (H, dh, 4dh) block-diagonal recurrence
    bias = p["b"].astype(jnp.float32)  # (H, 4dh)

    def step(carry, wx_t):
        h, c, n, m = carry
        pre = wx_t + jnp.einsum("bnh,nhf->bnf", h, r) + bias  # (B,H,4dh)
        z_t, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
        z_t = jnp.tanh(z_t)
        o_t = jax.nn.sigmoid(o_t)
        f_log = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(f_log + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_log + m - m_new)
        c = f_p * c + i_p * z_t
        n = f_p * n + i_p
        h_new = o_t * c / jnp.maximum(n, 1.0)
        return (h_new, c, n, m_new), h_new

    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(step, tuple(st), wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(b, s, n_heads * dh).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", h, p["out_proj"])
    return out, SLSTMState(h_f, c_f, n_f, m_f)
