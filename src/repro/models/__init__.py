"""LM architecture stack (dense / MoE / SSM / hybrid / audio / VLM)."""
from .transformer import (
    ModelConfig,
    abstract_cache,
    abstract_params,
    decode_step,
    forward_hidden,
    forward_loglik,
    init_cache,
    init_params,
    param_specs,
    prefill,
)

__all__ = [
    "ModelConfig",
    "abstract_cache",
    "abstract_params",
    "decode_step",
    "forward_hidden",
    "forward_loglik",
    "init_cache",
    "init_params",
    "param_specs",
    "prefill",
]
