"""Architecture zoo: decoder-only dense/MoE/SSM/hybrid LMs, enc-dec audio,
early-fusion VLM — one config-driven implementation.

Param trees are nested dicts whose leaves are arrays; ``param_specs`` returns
the same tree with ``ParamSpec`` leaves (shape + logical axes) so the
launcher can build shardings and abstract values without allocating.

Entry points:
  param_specs(cfg) / init_params(key, cfg) / abstract_params(cfg)
  forward_loglik(params, batch, cfg)      -> per-sequence loglik (B,)
  prefill(params, tokens, cfg, max_len)   -> (cache, last-position logits)
  decode_step(params, cache, tokens, cfg) -> (cache, logits)
  init_cache / abstract_cache
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..distributed.sharding import lc
from .layers import (
    ParamSpec,
    attention,
    embed,
    gelu_mlp,
    init_leaf,
    moe_mlp,
    rms_norm,
    swiglu_mlp,
    unembed_loglik,
)
from .ssm import (
    MambaState,
    MLSTMState,
    SLSTMState,
    mamba_block,
    mlstm_block,
    slstm_block,
)

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_base: float = 10_000.0
    rotary_frac: float = 1.0
    window: int | None = None  # uniform sliding window (mixtral)
    local_window: int | None = None  # gemma3 local layers
    global_every: int | None = None  # gemma3: every k-th layer is global
    global_rope_base: float | None = None
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # jamba/phi: MoE layer cadence
    attn_period: int = 0  # jamba: one attention layer per this many
    attn_index: int = 4
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    dt_rank: int | None = None
    enc_layers: int = 0  # whisper encoder depth
    n_audio_frames: int = 1500
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    max_seq: int = 8192
    sub_quadratic: bool = False  # eligible for long_500k decode
    kv_cache_dtype: str = "bf16"  # "bf16" | "fp8" (float8_e4m3fn; §Perf HC3)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def param_count(self) -> int:
        import numpy as np

        specs = param_specs(self)
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        return int(sum(np.prod(s.shape) for s in leaves))

    def active_param_count(self) -> int:
        """MoE-aware: experts count at top_k/n_experts utilization."""
        import numpy as np

        specs = param_specs(self)
        total = 0
        flat = _flatten(specs)
        for path, s in flat.items():
            n = int(np.prod(s.shape))
            if "experts" in s.logical and self.n_experts > 0:
                n = int(n * self.top_k / self.n_experts)
            total += n
        return total


def _flatten(tree: dict, prefix: str = "") -> dict[str, ParamSpec]:
    out = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        else:
            out[path] = v
    return out


# ---------------------------------------------------------------------------
# Param specs per family
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, h, nh, nk = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    sl = ("layers",) * len(stack)
    s = {
        "wq": ParamSpec(stack + (d, nh, h), sl + ("embed", "q_heads", None)),
        "wk": ParamSpec(stack + (d, nk, h), sl + ("embed", "kv_heads", None)),
        "wv": ParamSpec(stack + (d, nk, h), sl + ("embed", "kv_heads", None)),
        "wo": ParamSpec(stack + (nh, h, d), sl + ("q_heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec(stack + (nh, h), sl + ("q_heads", None), init_scale="zero")
        s["bk"] = ParamSpec(stack + (nk, h), sl + ("kv_heads", None), init_scale="zero")
        s["bv"] = ParamSpec(stack + (nk, h), sl + ("kv_heads", None), init_scale="zero")
    if cfg.qk_norm:
        s["qnorm"] = ParamSpec(stack + (h,), sl + (None,), init_scale="zero")
        s["knorm"] = ParamSpec(stack + (h,), sl + (None,), init_scale="zero")
    return s


def _mlp_specs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    sl = ("layers",) * len(stack)
    return {
        "wi_gate": ParamSpec(stack + (d, f), sl + ("embed", "mlp")),
        "wi_up": ParamSpec(stack + (d, f), sl + ("embed", "mlp")),
        "wo": ParamSpec(stack + (f, d), sl + ("mlp", "embed")),
    }


def _moe_specs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sl = ("layers",) * len(stack)
    return {
        "router": ParamSpec(stack + (d, e), sl + ("embed", None)),
        "wi_gate": ParamSpec(stack + (e, d, f), sl + ("experts", "embed", "expert_mlp")),
        "wi_up": ParamSpec(stack + (e, d, f), sl + ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec(stack + (e, f, d), sl + ("experts", "expert_mlp", "embed")),
    }


def _mamba_specs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, di, ds, dtr, k = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank_eff, cfg.mamba_d_conv
    sl = ("layers",) * len(stack)
    m = "mamba_inner"  # own logical axis: TP-vs-replicate is a perf knob (HC2)
    return {
        "in_proj": ParamSpec(stack + (d, 2 * di), sl + ("embed", m)),
        "conv_w": ParamSpec(stack + (k, di), sl + ("conv", m), init_scale="normal"),
        "conv_b": ParamSpec(stack + (di,), sl + (m,), init_scale="zero"),
        "x_proj": ParamSpec(stack + (di, dtr + 2 * ds), sl + (m, None)),
        "dt_proj": ParamSpec(stack + (dtr, di), sl + (None, m)),
        "dt_bias": ParamSpec(stack + (di,), sl + (m,), init_scale="zero"),
        "a_log": ParamSpec(stack + (di, ds), sl + (m, "state"), init_scale="zero"),
        "d_skip": ParamSpec(stack + (di,), sl + (m,), init_scale="one"),
        "out_proj": ParamSpec(stack + (di, d), sl + (m, "embed")),
    }


def _norm_spec(cfg: ModelConfig, stack: tuple = ()) -> ParamSpec:
    return ParamSpec(
        stack + (cfg.d_model,), ("layers",) * len(stack) + (None,), init_scale="zero"
    )


def param_specs(cfg: ModelConfig) -> dict:
    d, v, n = cfg.d_model, cfg.vocab, cfg.n_layers
    specs: dict = {
        # vocab-sharded only: 2D-sharding the table trips XLA's gather
        # partitioner into involuntary full rematerialization (observed in the
        # dry-run); the model-axis shard already bounds per-chip bytes.
        "embed": {"table": ParamSpec((v, d), ("vocab", None), init_scale="embed")},
        "final_norm": _norm_spec(cfg),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        specs["layers"] = {
            "ln1": _norm_spec(cfg, (n,)),
            "ln2": _norm_spec(cfg, (n,)),
            "attn": _attn_specs(cfg, (n,)),
            "mlp": _mlp_specs(cfg, (n,)),
        }
    elif fam == "moe":
        specs["layers"] = {
            "ln1": _norm_spec(cfg, (n,)),
            "ln2": _norm_spec(cfg, (n,)),
            "attn": _attn_specs(cfg, (n,)),
            "moe": _moe_specs(cfg, (n,)),
        }
    elif fam == "ssm":  # xLSTM: alternating mLSTM / sLSTM pairs
        pairs = n // 2
        nh, dh = cfg.n_heads, d // cfg.n_heads
        specs["layers"] = {
            "ln_m": _norm_spec(cfg, (pairs,)),
            "ln_s": _norm_spec(cfg, (pairs,)),
            "mlstm": {
                "wq": ParamSpec((pairs, d, nh, dh), ("layers", "embed", "q_heads", None)),
                "wk": ParamSpec((pairs, d, nh, dh), ("layers", "embed", "q_heads", None)),
                "wv": ParamSpec((pairs, d, nh, dh), ("layers", "embed", "q_heads", None)),
                "wi": ParamSpec((pairs, d, nh), ("layers", "embed", None)),
                "wf": ParamSpec((pairs, d, nh), ("layers", "embed", None)),
                "wo_gate": ParamSpec((pairs, d, nh), ("layers", "embed", None)),
                "out_proj": ParamSpec((pairs, d, d), ("layers", None, "embed")),
            },
            "slstm": {
                "w": ParamSpec((pairs, d, nh, 4 * dh), ("layers", "embed", "q_heads", None)),
                "r": ParamSpec((pairs, nh, dh, 4 * dh), ("layers", "q_heads", None, None)),
                "b": ParamSpec((pairs, nh, 4 * dh), ("layers", "q_heads", None), init_scale="zero"),
                "out_proj": ParamSpec((pairs, d, d), ("layers", None, "embed")),
            },
        }
    elif fam == "hybrid":  # jamba: periods of attn_period layers, 1 attention
        p = n // cfg.attn_period
        n_m = cfg.attn_period - 1
        n_moe = cfg.attn_period // cfg.moe_every
        n_mlp = cfg.attn_period - n_moe
        specs["layers"] = {
            "ln_mix": _norm_spec(cfg, (p, cfg.attn_period)),
            "ln_mlp": _norm_spec(cfg, (p, cfg.attn_period)),
            "attn": _attn_specs(cfg, (p,)),
            "mamba": _mamba_specs(cfg, (p, n_m)),
            "moe": _moe_specs(cfg, (p, n_moe)),
            "mlp": _mlp_specs(cfg, (p, n_mlp)),
        }
    elif fam == "audio":  # whisper: encoder + decoder with cross-attention
        ne = cfg.enc_layers
        specs["enc"] = {
            "pos": ParamSpec((cfg.n_audio_frames, d), (None, "embed"), init_scale="normal"),
            "layers": {
                "ln1": _norm_spec(cfg, (ne,)),
                "ln2": _norm_spec(cfg, (ne,)),
                "attn": _attn_specs(cfg, (ne,)),
                "mlp": {
                    "wi": ParamSpec((ne, d, cfg.d_ff), ("layers", "embed", "mlp")),
                    "bi": ParamSpec((ne, cfg.d_ff), ("layers", "mlp"), init_scale="zero"),
                    "wo": ParamSpec((ne, cfg.d_ff, d), ("layers", "mlp", "embed")),
                    "bo": ParamSpec((ne, d), ("layers", "embed"), init_scale="zero"),
                },
            },
            "final_norm": _norm_spec(cfg),
        }
        specs["dec_pos"] = ParamSpec((cfg.max_seq, d), (None, "embed"), init_scale="normal")
        specs["layers"] = {
            "ln1": _norm_spec(cfg, (n,)),
            "ln_x": _norm_spec(cfg, (n,)),
            "ln2": _norm_spec(cfg, (n,)),
            "attn": _attn_specs(cfg, (n,)),
            "xattn": _attn_specs(cfg, (n,)),
            "mlp": {
                "wi": ParamSpec((n, d, cfg.d_ff), ("layers", "embed", "mlp")),
                "bi": ParamSpec((n, cfg.d_ff), ("layers", "mlp"), init_scale="zero"),
                "wo": ParamSpec((n, cfg.d_ff, d), ("layers", "mlp", "embed")),
                "bo": ParamSpec((n, d), ("layers", "embed"), init_scale="zero"),
            },
        }
    else:
        raise ValueError(f"unknown family {fam!r}")
    return specs


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    specs = param_specs(cfg)
    flat = _flatten(specs)
    keys = jax.random.split(key, len(flat))
    flat_vals = {p: init_leaf(k, s) for (p, s), k in zip(sorted(flat.items()), keys)}

    def rebuild(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            path = f"{prefix}/{k}" if prefix else k
            out[k] = rebuild(v, path) if isinstance(v, dict) else flat_vals[path]
        return out

    return rebuild(specs)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree (no allocation) for dry-run lowering."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        param_specs(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Per-layer window / rope schedules (data, not control flow)
# ---------------------------------------------------------------------------

_FULL_WINDOW = 1 << 30


def layer_schedules(cfg: ModelConfig, n: int | None = None):
    """Per-layer (window, rope_base) arrays — sliding windows and dual rope
    bases become *data* consumed by one attention code path."""
    n = n or cfg.n_layers
    windows = jnp.full((n,), cfg.window or _FULL_WINDOW, jnp.int32)
    bases = jnp.full((n,), cfg.rope_base, jnp.float32)
    if cfg.global_every:
        idx = jnp.arange(n)
        is_global = (idx + 1) % cfg.global_every == 0
        windows = jnp.where(is_global, _FULL_WINDOW, cfg.local_window or _FULL_WINDOW)
        bases = jnp.where(is_global, cfg.global_rope_base or cfg.rope_base, cfg.rope_base)
    return windows, bases


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _attn_kwargs(cfg: ModelConfig):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                rotary_frac=cfg.rotary_frac)


def _decoder_stack(params: Params, h: jax.Array, cfg: ModelConfig, positions,
                   caches=None):
    """Uniform scan for dense / moe / vlm families. caches: None or dict of
    stacked buffers (L, B, Smax, K, hd) plus scalar length."""
    windows, bases = layer_schedules(cfg)
    lp = params["layers"]
    is_moe = cfg.family == "moe"
    slot_pos = _advance_slot_pos(caches, positions) if caches is not None else None

    def body(carry, xs):
        h = carry
        p, window, base, cache_kv = xs
        a_in = rms_norm(h, p["ln1"], cfg.norm_eps)
        kv = None
        if cache_kv is not None:
            kv = (cache_kv["k"], cache_kv["v"], caches["len"], slot_pos)
        a_out, new_kv = attention(
            a_in, p["attn"], positions=positions, window=window, rope_base=base,
            kv_cache=kv, **_attn_kwargs(cfg),
        )
        h = h + a_out
        m_in = rms_norm(h, p["ln2"], cfg.norm_eps)
        if is_moe:
            m_out = moe_mlp(m_in, p["moe"], top_k=cfg.top_k)
        else:
            m_out = swiglu_mlp(m_in, p["mlp"])
        h = h + m_out
        out_kv = None
        if new_kv is not None:
            out_kv = {"k": new_kv[0], "v": new_kv[1]}
        return h, out_kv

    cache_xs = None
    if caches is not None:
        cache_xs = {"k": caches["k"], "v": caches["v"]}
    h, new_cache = jax.lax.scan(body, h, (lp, windows, bases, cache_xs))
    if caches is not None:
        s = positions.shape[-1]
        new_cache = {"k": new_cache["k"], "v": new_cache["v"], "pos": slot_pos,
                     "len": caches["len"] + s}
    return h, new_cache


def _xlstm_stack(params, h, cfg, states=None):
    lp = params["layers"]

    def body(carry, xs):
        h = carry
        p, st = xs
        m_st = MLSTMState(*st["m"]) if st is not None else None
        s_st = SLSTMState(*st["s"]) if st is not None else None
        y, m_new = mlstm_block(rms_norm(h, p["ln_m"], cfg.norm_eps), p["mlstm"], m_st)
        h = h + y
        y, s_new = slstm_block(rms_norm(h, p["ln_s"], cfg.norm_eps), p["slstm"], s_st)
        h = h + y
        return h, {"m": tuple(m_new), "s": tuple(s_new)}

    h, new_states = jax.lax.scan(body, h, (lp, states))
    return h, new_states


def _jamba_stack(params, h, cfg, positions, caches=None):
    lp = params["layers"]
    ap = cfg.attn_period
    window = cfg.window or _FULL_WINDOW
    slot_pos = _advance_slot_pos(caches, positions) if caches is not None else None

    def period(carry, xs):
        h = carry
        p, cache_p = xs
        m_i = 0
        moe_i = 0
        mlp_i = 0
        new_cache = {} if cache_p is not None else None
        mamba_states = []
        for li in range(ap):
            mix_in = rms_norm(h, p["ln_mix"][li], cfg.norm_eps)
            if li == cfg.attn_index:
                kv = None
                if cache_p is not None:
                    kv = (cache_p["k"], cache_p["v"], caches["len"], slot_pos)
                y, new_kv = attention(
                    mix_in, p["attn"], positions=positions, window=window,
                    rope_base=cfg.rope_base, kv_cache=kv, **_attn_kwargs(cfg),
                )
                if new_cache is not None:
                    new_cache["k"], new_cache["v"] = new_kv[0], new_kv[1]
            else:
                mp = jax.tree.map(lambda a: a[m_i], p["mamba"])
                st = None
                if cache_p is not None:
                    st = MambaState(cache_p["conv"][m_i], cache_p["ssm"][m_i])
                y, st_new = mamba_block(mix_in, mp, st)
                mamba_states.append(st_new)
                m_i += 1
            h = h + y
            mlp_in = rms_norm(h, p["ln_mlp"][li], cfg.norm_eps)
            if li % cfg.moe_every == 0:
                mo = jax.tree.map(lambda a: a[moe_i], p["moe"])
                y = moe_mlp(mlp_in, mo, top_k=cfg.top_k)
                moe_i += 1
            else:
                ml = jax.tree.map(lambda a: a[mlp_i], p["mlp"])
                y = swiglu_mlp(mlp_in, ml)
                mlp_i += 1
            h = h + y
        outs = None
        if new_cache is not None:
            outs = {
                "k": new_cache["k"],
                "v": new_cache["v"],
                "conv": jnp.stack([s.conv for s in mamba_states]),
                "ssm": jnp.stack([s.ssm for s in mamba_states]),
            }
        elif cache_p is None and caches is None:
            # training path still returns final mamba states for API parity
            outs = {
                "conv": jnp.stack([s.conv for s in mamba_states]),
                "ssm": jnp.stack([s.ssm for s in mamba_states]),
            }
        return h, outs

    cache_xs = None
    if caches is not None:
        cache_xs = {k: caches[k] for k in ("k", "v", "conv", "ssm")}
    h, new_cache = jax.lax.scan(period, h, (lp, cache_xs))
    if caches is not None:
        s = positions.shape[-1]
        new_cache = dict(new_cache, pos=slot_pos, len=caches["len"] + s)
    return h, new_cache


def _whisper_encode(params, frames, cfg):
    """frames: (B, T_audio, D) precomputed frame embeddings (stub frontend)."""
    ep = params["enc"]
    h = frames + ep["pos"][None, : frames.shape[1]].astype(frames.dtype)
    pos = jnp.arange(frames.shape[1])

    def body(carry, p):
        h = carry
        a_in = rms_norm(h, p["ln1"], cfg.norm_eps)
        a, _ = attention(
            a_in, p["attn"], positions=pos, window=_FULL_WINDOW, rope_base=cfg.rope_base,
            causal=False, use_rope=False, **_attn_kwargs(cfg),
        )
        h = h + a
        h = h + gelu_mlp(rms_norm(h, p["ln2"], cfg.norm_eps), p["mlp"])
        return h, None

    h, _ = jax.lax.scan(body, h, ep["layers"])
    return rms_norm(h, ep["final_norm"], cfg.norm_eps)


def _cross_attention(x, enc_out, p, cfg):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("btd,dkh->btkh", enc_out, p["wk"])
    v = jnp.einsum("btd,dkh->btkh", enc_out, p["wv"])
    group = cfg.n_heads // cfg.n_kv
    qg = q.reshape(b, s, cfg.n_kv, group, cfg.hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * cfg.hd**-0.5
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v).reshape(b, s, cfg.n_heads, cfg.hd)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"])


def _whisper_decode_stack(params, h, enc_out, cfg, positions, caches=None):
    lp = params["layers"]
    pos_emb = jnp.take(params["dec_pos"], jnp.minimum(positions, cfg.max_seq - 1), axis=0)
    h = h + pos_emb[None].astype(h.dtype)
    slot_pos = _advance_slot_pos(caches, positions) if caches is not None else None

    def body(carry, xs):
        h = carry
        p, cache_kv = xs
        kv = None
        if cache_kv is not None:
            kv = (cache_kv["k"], cache_kv["v"], caches["len"], slot_pos)
        a, new_kv = attention(
            rms_norm(h, p["ln1"], cfg.norm_eps), p["attn"], positions=positions,
            window=_FULL_WINDOW, rope_base=cfg.rope_base, kv_cache=kv,
            use_rope=False, **_attn_kwargs(cfg),
        )
        h = h + a
        h = h + _cross_attention(rms_norm(h, p["ln_x"], cfg.norm_eps), enc_out, p["xattn"], cfg)
        h = h + gelu_mlp(rms_norm(h, p["ln2"], cfg.norm_eps), p["mlp"])
        out_kv = {"k": new_kv[0], "v": new_kv[1]} if new_kv is not None else None
        return h, out_kv

    cache_xs = None
    if caches is not None:
        cache_xs = {"k": caches["k"], "v": caches["v"]}
    h, new_cache = jax.lax.scan(body, h, (lp, cache_xs))
    if caches is not None:
        new_cache = {**new_cache, "pos": slot_pos, "len": caches["len"] + positions.shape[-1]}
    return h, new_cache


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def forward_hidden(params: Params, tokens: jax.Array, cfg: ModelConfig,
                   extra: dict | None = None) -> jax.Array:
    """Token ids -> final hidden states (pre final-norm applied)."""
    h = embed(tokens, params["embed"]["table"])
    s = tokens.shape[1]
    positions = jnp.arange(s)
    if cfg.family in ("dense", "moe", "vlm"):
        h, _ = _decoder_stack(params, h, cfg, positions)
    elif cfg.family == "ssm":
        h, _ = _xlstm_stack(params, h, cfg)
    elif cfg.family == "hybrid":
        h, _ = _jamba_stack(params, h, cfg, positions)
    elif cfg.family == "audio":
        enc_out = _whisper_encode(params, extra["frames"], cfg)
        h, _ = _whisper_decode_stack(params, h, enc_out, cfg, positions)
    else:
        raise ValueError(cfg.family)
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def forward_loglik(params: Params, batch: dict, cfg: ModelConfig,
                   ce_chunk: int = 512) -> jax.Array:
    """Per-sequence log p(tokens | params): the MH local sections l_i.

    batch: tokens (B, S) int32, mask (B, S) — next-token factorization;
    audio adds frames (B, T_audio, D).
    """
    tokens = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "mask")}
    h = forward_hidden(params, tokens[:, :-1], cfg, extra or None)
    targets = tokens[:, 1:]
    mask = batch.get("mask")
    mask = jnp.ones_like(targets) if mask is None else mask[:, 1:]
    return unembed_loglik(h, params["embed"]["table"], targets, mask, chunk=ce_chunk)


# -- serving ------------------------------------------------------------------


def effective_cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Uniform-SWA archs (mixtral) keep an O(window) ring buffer even for
    500k contexts; everything else caches the full context."""
    if cfg.window:
        return min(max_len, cfg.window)
    return max_len


def cache_template(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """{name: ParamSpec} tree for the decode cache (shape + logical axes)."""
    if dtype is None:
        dtype = jnp.float8_e4m3fn if cfg.kv_cache_dtype == "fp8" else jnp.bfloat16
    c = effective_cache_len(cfg, max_len)
    fam = cfg.family
    kv_log = ("layers", "batch", "kv_seq", "kv_heads", None)

    def kv(n):
        shape = (n, batch, c, cfg.n_kv, cfg.hd)
        return {
            "k": ParamSpec(shape, kv_log, dtype),
            "v": ParamSpec(shape, kv_log, dtype),
        }

    scalar = ParamSpec((), (), jnp.int32)
    posspec = ParamSpec((c,), (None,), jnp.int32)
    if fam in ("dense", "moe", "vlm"):
        return {**kv(cfg.n_layers), "pos": posspec, "len": scalar}
    if fam == "ssm":
        pairs = cfg.n_layers // 2
        nh, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
        f32 = jnp.float32
        return {
            "m": (
                ParamSpec((pairs, batch, nh, dh, dh), ("layers", "batch", "q_heads", None, None), f32),
                ParamSpec((pairs, batch, nh, dh), ("layers", "batch", "q_heads", None), f32),
                ParamSpec((pairs, batch, nh), ("layers", "batch", "q_heads"), f32),
            ),
            "s": tuple(
                ParamSpec((pairs, batch, nh, dh), ("layers", "batch", "q_heads", None), f32)
                for _ in range(4)
            ),
        }
    if fam == "hybrid":
        p = cfg.n_layers // cfg.attn_period
        n_m = cfg.attn_period - 1
        return {
            **kv(p),
            "conv": ParamSpec((p, n_m, batch, cfg.mamba_d_conv - 1, cfg.d_inner),
                              ("layers", None, "batch", None, "mlp"), dtype),
            "ssm": ParamSpec((p, n_m, batch, cfg.d_inner, cfg.mamba_d_state),
                             ("layers", None, "batch", "mlp", None), jnp.float32),
            "pos": posspec,
            "len": scalar,
        }
    if fam == "audio":
        return {
            **kv(cfg.n_layers),
            "pos": posspec,
            "len": scalar,
            "enc_out": ParamSpec((batch, cfg.n_audio_frames, cfg.d_model),
                                 ("batch", None, "embed_tp"), dtype),
        }
    raise ValueError(fam)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the decode cache (dry-run serving input)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        cache_template(cfg, batch, max_len, dtype),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_out: jax.Array | None = None):
    tree = abstract_cache(cfg, batch, max_len, dtype)

    def zero(x):
        if isinstance(x, jax.ShapeDtypeStruct):
            return jnp.zeros(x.shape, x.dtype)
        return x

    cache = jax.tree.map(zero, tree)
    if cfg.family == "ssm":
        # mLSTM max-stabilizer starts at -inf-ish
        m = list(cache["m"])
        m[2] = jnp.full(m[2].shape, -1e30, m[2].dtype)
        cache["m"] = tuple(m)
    else:
        cache["pos"] = jnp.full(cache["pos"].shape, -1, jnp.int32)
    if enc_out is not None:
        cache["enc_out"] = enc_out
    return cache


def _advance_slot_pos(cache: dict, positions: jax.Array):
    """Advance the ring-buffer slot->absolute-position map once per step."""
    slot_pos, length = cache["pos"], cache["len"]
    c = slot_pos.shape[0]
    s = positions.shape[-1]
    if s >= c:  # (re)filling the whole ring: tail at slots p % C
        shift = (s - c) % c
        return jnp.roll(positions[-c:].astype(jnp.int32), shift)
    ins = length % c
    return jax.lax.dynamic_update_slice_in_dim(
        slot_pos, positions.astype(jnp.int32), ins, axis=0
    )


def decode_step(params: Params, cache, tokens: jax.Array, cfg: ModelConfig):
    """One-token decode: tokens (B, 1) -> (new_cache, logits (B, V))."""
    h = embed(tokens, params["embed"]["table"])
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "audio", "hybrid"):
        length = cache["len"]
        positions = length + jnp.arange(tokens.shape[1])
    if fam in ("dense", "moe", "vlm"):
        h, cache = _decoder_stack(params, h, cfg, positions, caches=cache)
    elif fam == "ssm":
        h, cache = _xlstm_stack(params, h, cfg, states=cache)
    elif fam == "hybrid":
        h, cache = _jamba_stack(params, h, cfg, positions, caches=cache)
    elif fam == "audio":
        enc_out = cache["enc_out"]
        sub = {k: cache[k] for k in ("k", "v", "pos", "len")}
        h, sub = _whisper_decode_stack(params, h, enc_out, cfg, positions, caches=sub)
        cache = {**cache, **sub}
    else:
        raise ValueError(fam)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"])
    return cache, lc(logits[:, -1].astype(jnp.float32), ("batch", "vocab"))


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, max_len: int,
            extra: dict | None = None):
    """Process a full prompt, building the cache; returns (cache, last logits)."""
    b, s = tokens.shape
    fam = cfg.family
    enc_out = None
    if fam == "audio":
        enc_out = _whisper_encode(params, extra["frames"], cfg)
    cache = init_cache(cfg, b, max_len, enc_out=enc_out)
    h = embed(tokens, params["embed"]["table"])
    positions = jnp.arange(s)
    if fam in ("dense", "moe", "vlm"):
        h, cache = _decoder_stack(params, h, cfg, positions, caches=cache)
    elif fam == "ssm":
        h, cache = _xlstm_stack(params, h, cfg, states=cache)
    elif fam == "hybrid":
        h, cache = _jamba_stack(params, h, cfg, positions, caches=cache)
    elif fam == "audio":
        sub = {k: cache[k] for k in ("k", "v", "pos", "len")}
        h, sub = _whisper_decode_stack(params, h, enc_out, cfg, positions, caches=sub)
        cache = {**cache, **sub}
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], params["embed"]["table"])
    return cache, logits.astype(jnp.float32)
