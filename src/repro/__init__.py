"""repro: sublinear-time approximate MCMC transitions for probabilistic
programs — faithful reproduction + multi-pod JAX framework.

Subpackages: core (the paper's algorithm), ppl (PET scaffolds), experiments
(the paper's three applications), inference (particle Gibbs, NIW, kernel
combinators), models (10-arch LM zoo), bayes (LM-scale transition operator),
kernels (Pallas), distributed / data / optim / checkpoint / runtime
(substrates), configs, launch.
"""
__version__ = "1.0.0"
