"""Logical-axis sharding: rules with divisibility fallback.

JAX rejects uneven shardings (verified in the de-risk prototype), and the
assigned architectures have head/expert counts that don't divide the 16-way
model axis (gemma3: 8 q-heads, mixtral: 8 experts, xlstm: 4 heads). So each
parameter/activation dim carries a *logical* name and the mesh mapping is a
prioritized rule list; a rule is skipped when the dim isn't divisible by the
target mesh axes, falling through to the next rule (MaxText-style).

``lc(x, names)`` applies a sharding constraint inside jitted code when a mesh
context is active; it is a no-op on a single device so model code runs
unchanged in CPU tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Priority-ordered candidate mesh axes per logical axis name. The first
# candidate whose size divides the dim (and isn't already used by another dim
# of the same tensor) wins; otherwise the dim is replicated.
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "vocab": (("model",),),
    "embed": (("data",),),  # FSDP-style weight sharding over the data axis
    "embed_tp": (("model",),),
    "mlp": (("model",),),
    "q_heads": (("model",),),
    "kv_heads": (("model",),),
    "heads_flat": (("model",),),
    "experts": (("model",),),
    "mamba_inner": (("model",),),
    "expert_mlp": (("model",),),
    "capacity": (("model",),),  # MoE buffer fallback when experts % model != 0
    "kv_seq": (("model", "data"), ("model",)),  # decode-cache sequence sharding
    "seq": (),  # sequence dim: replicated by default (SP is a perf knob)
    "layers": (),
    "conv": (),
    "state": (),
    # -- MCMC-ensemble axes (repro.core.ensemble 2-d chains x data meshes).
    # The (K,) chain axis spreads whole chains; "subsample" is the m axis of
    # a sequential-test round's (K, m) mini-batch, sharded over the data
    # axis so each device gathers+scores its slice of the drawn sections.
    # Both are no-ops on model-training meshes (no "chains" axis there) and
    # fall through to replicated when the dim isn't divisible.
    "ensemble_chains": (("chains",),),
    "subsample": (("data",),),
}


class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: dict[str, tuple[tuple[str, ...], ...]] | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: dict | None = None):
    """Activate a mesh + rule set; model code's ``lc`` calls start applying
    real sharding constraints."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def _mesh_axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def resolve_spec(
    shape: Sequence[int], logical: Sequence[str | None], mesh: Mesh, rules: dict
) -> P:
    """Map logical axis names to a PartitionSpec honoring divisibility and
    one-mesh-axis-per-tensor uniqueness."""
    used: set[str] = set()
    parts: list = []
    for dim, name in zip(shape, logical):
        assigned = None
        if name is not None:
            for cand in rules.get(name, ()):
                cand_eff = tuple(a for a in cand if a in mesh.shape and a not in used)
                if not cand_eff:
                    continue
                if dim % _mesh_axis_size(mesh, cand_eff) == 0:
                    assigned = cand_eff if len(cand_eff) > 1 else cand_eff[0]
                    used.update(cand_eff)
                    break
        parts.append(assigned)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def lc(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """Logical sharding constraint; no-op without an active mesh context."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or len(mesh.devices.reshape(-1)) <= 1:
        return x
    spec = resolve_spec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, shape: Sequence[int], logical: Sequence[str | None],
                   rules: dict | None = None) -> NamedSharding:
    rules = dict(DEFAULT_RULES, **(rules or {}))
    return NamedSharding(mesh, resolve_spec(shape, logical, mesh, rules))


def tree_shardings(mesh: Mesh, specs: dict, rules: dict | None = None):
    """Map a {path: ParamSpec} dict to {path: NamedSharding}."""
    return {
        k: named_sharding(mesh, v.shape, v.logical, rules) for k, v in specs.items()
    }


def count_bytes(specs: dict) -> int:
    total = 0
    for v in specs.values():
        total += int(np.prod(v.shape)) * jax.dtypes.canonicalize_dtype(v.dtype).itemsize
    return total
