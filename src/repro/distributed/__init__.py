"""Distribution: mesh construction, logical-axis sharding, collective accounting."""
from .sharding import (
    DEFAULT_RULES,
    count_bytes,
    lc,
    logical_axis_rules,
    named_sharding,
    resolve_spec,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "count_bytes",
    "lc",
    "logical_axis_rules",
    "named_sharding",
    "resolve_spec",
    "tree_shardings",
]
