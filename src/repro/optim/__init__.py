"""Optimizer substrate: Adam / SGD / SGLD for the hybrid-inference examples
(SGD on bulk weights interoperating with MH on selected blocks — the paper's
"interleave with other general-purpose inference" property)."""
from .optimizers import (
    AdamState,
    adam_init,
    adam_step,
    lm_loss_fn,
    sgd_step,
    sgld_step,
)

__all__ = ["AdamState", "adam_init", "adam_step", "lm_loss_fn", "sgd_step", "sgld_step"]
