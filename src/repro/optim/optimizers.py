"""Minimal functional optimizers (Adam, SGD, SGLD) over pytree params."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class AdamState(NamedTuple):
    mu: Params
    nu: Params
    count: jax.Array


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros), count=jnp.zeros((), jnp.int32))


def adam_step(
    grads: Params, state: AdamState, params: Params,
    lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
) -> tuple[Params, AdamState]:
    count = state.count + 1
    cf = count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**cf)
        vh = v / (1 - b2**cf)
        new_p = p.astype(jnp.float32) - lr * mh / (jnp.sqrt(vh) + eps)
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(new_mu, new_nu, count)


def sgd_step(grads: Params, params: Params, lr: float = 1e-2) -> Params:
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )


def sgld_step(
    key: jax.Array, grads: Params, params: Params, lr: float, temperature: float = 1.0
) -> Params:
    """Stochastic gradient Langevin dynamics: the classic scalable-Bayes
    comparator to subsampled MH."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    gleaves = treedef.flatten_up_to(grads)
    noise_scale = (2.0 * lr * temperature) ** 0.5
    new = [
        (
            p.astype(jnp.float32)
            + lr * g.astype(jnp.float32)
            + noise_scale * jax.random.normal(k, p.shape, jnp.float32)
        ).astype(p.dtype)
        for p, g, k in zip(leaves, gleaves, keys)
    ]
    return jax.tree.unflatten(treedef, new)


def lm_loss_fn(cfg):
    """Mean negative log-likelihood per token (for the Adam/SGD substrate)."""
    from ..models.transformer import forward_loglik

    def loss(params, batch):
        ll = forward_loglik(params, batch, cfg)
        denom = jnp.maximum(batch["mask"][:, 1:].sum(), 1)
        return -ll.sum() / denom

    return loss
