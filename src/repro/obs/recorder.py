"""Append-only per-run metric recording with live rollups.

A :class:`Recorder` owns one run directory (``<root>/<run_id>/``) holding
one JSONL file per metric *stream* — ``slo.jsonl``, ``snapshot.jsonl``,
``fleet.jsonl``, ``refresh.jsonl``, ``adaptation.jsonl``, ``chaos.jsonl``
in the serving front-end — plus ``meta.json`` at start and ``summary.json``
(the final rollup) at close. Every record is one JSON object per line with
a wall-clock ``t`` and a run-relative ``rel_s`` stamp, so streams from one
run can be joined on time.

The rollup is maintained incrementally (count / mean / min / max / last
plus streaming P² p50/p95 per numeric field per stream) and is cheap to
read at any moment — it is what
the ``serve --stats-addr`` HTTP endpoint returns while the run is live, and
what ``summary.json`` freezes at the end.

``root_dir=None`` records in memory only (rollup works, nothing touches
disk) — what tests and ephemeral smoke runs use.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np


def json_default(obj):
    """JSON encoder fallback for the numpy scalars/arrays metric dicts
    naturally carry."""
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return str(obj)


def _as_scalar(value) -> float | None:
    """The aggregatable float behind a metric value, or None for
    non-numeric values (bool counts as numeric: rates of flags are useful)."""
    if isinstance(value, bool):
        return float(value)
    if isinstance(value, (int, float, np.integer, np.floating)):
        v = float(value)
        return v if np.isfinite(v) else None
    return None


class _P2Quantile:
    """Jain & Chlamtac's P² streaming quantile estimator (5 markers).

    O(1) memory per field: below 5 observations the exact sorted-buffer
    quantile is returned; from the 5th on, the marker heights track the
    target quantile with piecewise-parabolic adjustment. This is what lets
    the rollup report latency tails without re-reading the raw JSONL."""

    __slots__ = ("p", "q", "n", "np_", "dn", "_buf")

    def __init__(self, p: float):
        self.p = float(p)
        self._buf: list[float] = []
        self.q: list[float] | None = None

    def add(self, x: float) -> None:
        if self.q is None:
            self._buf.append(x)
            if len(self._buf) == 5:
                self._buf.sort()
                p = self.p
                self.q = list(self._buf)
                self.n = [0.0, 1.0, 2.0, 3.0, 4.0]
                self.np_ = [0.0, 2 * p, 4 * p, 2 + 2 * p, 4.0]
                self.dn = [0.0, p / 2, p, (1 + p) / 2, 1.0]
            return
        q, n = self.q, self.n
        if x < q[0]:
            q[0] = x
            k = 0
        elif x < q[1]:
            k = 0
        elif x < q[2]:
            k = 1
        elif x < q[3]:
            k = 2
        elif x <= q[4]:
            k = 3
        else:
            q[4] = x
            k = 3
        for i in range(k + 1, 5):
            n[i] += 1.0
        for i in range(5):
            self.np_[i] += self.dn[i]
        for i in (1, 2, 3):
            d = self.np_[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1.0) or \
                    (d <= -1.0 and n[i - 1] - n[i] < -1.0):
                d = 1.0 if d > 0 else -1.0
                qi = self._parabolic(i, d)
                if not q[i - 1] < qi < q[i + 1]:
                    qi = self._linear(i, d)
                q[i] = qi
                n[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self.q, self.n
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1])
        )

    def _linear(self, i: int, d: float) -> float:
        d = int(d)
        q, n = self.q, self.n
        return q[i] + d * (q[i + d] - q[i]) / (n[i + d] - n[i])

    def value(self) -> float:
        if self.q is not None:
            return self.q[2]
        s = sorted(self._buf)
        if not s:
            return 0.0
        idx = self.p * (len(s) - 1)
        lo = int(idx)
        frac = idx - lo
        if lo + 1 >= len(s):
            return s[-1]
        return s[lo] + (s[lo + 1] - s[lo]) * frac


class _FieldAgg:
    """Streaming count/sum/min/max/last + P² tail quantiles for one
    numeric field."""

    __slots__ = ("count", "total", "min", "max", "last", "q50", "q95")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = 0.0
        self.q50 = _P2Quantile(0.5)
        self.q95 = _P2Quantile(0.95)

    def add(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.last = v
        self.q50.add(v)
        self.q95.add(v)

    def summary(self) -> dict:
        # count/mean/min/max/last are byte-identical to the pre-quantile
        # rollup; p50/p95 are additive keys (dashboards keying on the
        # original five fields are unaffected).
        return {
            "count": self.count,
            "mean": self.total / max(self.count, 1),
            "min": self.min,
            "max": self.max,
            "last": self.last,
            "p50": self.q50.value(),
            "p95": self.q95.value(),
        }


class Recorder:
    """Thread-safe append-only metric streams + incremental rollup."""

    def __init__(self, root_dir: str | None = None, *,
                 run_id: str | None = None, meta: dict | None = None):
        self.run_id = run_id or (
            f"run-{time.strftime('%Y%m%d-%H%M%S')}-{os.getpid()}"
        )
        self.meta = dict(meta or {})
        self.dir: str | None = None
        self._files: dict[str, object] = {}
        self._streams: dict[str, dict] = {}
        self._lock = threading.Lock()
        self._t0_wall = time.time()
        self._t0_mono = time.monotonic()
        self._closed = False
        if root_dir:
            self.dir = os.path.join(root_dir, self.run_id)
            os.makedirs(self.dir, exist_ok=True)
            with open(os.path.join(self.dir, "meta.json"), "w") as f:
                json.dump({"run_id": self.run_id, "started_at": self._t0_wall,
                           **self.meta}, f, default=json_default, indent=2)

    # -- writing -----------------------------------------------------------

    def record(self, stream: str, metrics: dict | None = None, **kw) -> dict:
        """Append one record to ``stream``; returns the record (with its
        time stamps) as written."""
        rec = {"t": time.time(),
               "rel_s": time.monotonic() - self._t0_mono}
        rec.update(metrics or {})
        rec.update(kw)
        line = json.dumps(rec, default=json_default)
        with self._lock:
            if self._closed:
                raise RuntimeError(f"recorder {self.run_id!r} is closed")
            agg = self._streams.setdefault(
                stream, {"count": 0, "fields": {}, "last": {}}
            )
            agg["count"] += 1
            agg["last"] = rec
            for field, value in rec.items():
                v = _as_scalar(value)
                if v is None:
                    continue
                agg["fields"].setdefault(field, _FieldAgg()).add(v)
            if self.dir is not None:
                f = self._files.get(stream)
                if f is None:
                    safe = stream.replace(os.sep, "_")
                    f = open(os.path.join(self.dir, f"{safe}.jsonl"), "a",
                             buffering=1)
                    self._files[stream] = f
                f.write(line + "\n")
        return rec

    # -- reading -----------------------------------------------------------

    def rollup(self) -> dict:
        """The current end-of-run summary, computable at any moment: per
        stream the record count, the last record, and count/mean/min/max/last
        per numeric field."""
        with self._lock:
            return {
                "run_id": self.run_id,
                "uptime_s": time.monotonic() - self._t0_mono,
                "meta": dict(self.meta),
                "streams": {
                    name: {
                        "count": agg["count"],
                        "last": dict(agg["last"]),
                        "fields": {
                            f: a.summary() for f, a in agg["fields"].items()
                        },
                    }
                    for name, agg in self._streams.items()
                },
            }

    def stream_path(self, stream: str) -> str | None:
        if self.dir is None:
            return None
        return os.path.join(self.dir, f"{stream.replace(os.sep, '_')}.jsonl")

    def read_stream(self, stream: str) -> list[dict]:
        """Parse a stream's JSONL back into records (empty when the stream
        was never written or the recorder is memory-only)."""
        path = self.stream_path(stream)
        if path is None or not os.path.exists(path):
            return []
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]

    # -- lifecycle ---------------------------------------------------------

    def write_summary(self) -> str | None:
        """Freeze the rollup to ``summary.json``; returns its path (None
        for a memory-only recorder)."""
        if self.dir is None:
            return None
        path = os.path.join(self.dir, "summary.json")
        with open(path, "w") as f:
            json.dump(self.rollup(), f, default=json_default, indent=2)
        return path

    def close(self) -> str | None:
        """Write the summary and close every stream file (idempotent)."""
        with self._lock:
            if self._closed:
                return None
            files, self._files = self._files, {}
        path = self.write_summary()
        for f in files.values():
            f.close()
        with self._lock:
            self._closed = True
        return path

    def __enter__(self) -> "Recorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
