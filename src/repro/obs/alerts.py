"""Declarative alerting over live :class:`~repro.obs.Recorder` rollups.

The active half of the observability layer: a set of :class:`AlertRule`\\ s
is evaluated against the recorder's incremental rollup (no stream is ever
re-read), each rule runs a pending → firing → resolved state machine with
per-rule hysteresis and cooldown, and every state *transition* is recorded
on the ``alerts`` stream — so the alert history is itself a stream, and
the autoscaler (:mod:`repro.fleet.autoscale`) can trace an action back to
the alert that triggered it.

Three rule kinds:

``threshold``
    Compare one rollup aggregate (``last``/``mean``/``p50``/``p95``/...)
    of ``<stream>.<field>`` against a fixed bound, e.g.
    ``slo.p95_ms > deadline budget``.
``burn_rate``
    Multi-window SLO error-budget burn (:func:`repro.core.stats.burn_rate`):
    the rule keeps short and long sliding windows of the observed bad
    fraction (``1 - field`` for good-rate metrics like
    ``deadline_hit_rate``); it breaches only when *both* windows burn the
    budget faster than ``max_burn`` — the short window catches the spike,
    the long window keeps a single bad sample from paging.
``anomaly``
    Streaming EWMA z-score (:func:`repro.core.stats.ewma_zscore`) on the
    field's latest value — req/s collapses, accept-rate shifts,
    ``frac_data_touched`` drifting toward full passes, ESS regressions.
    The baseline only absorbs non-breaching observations, so a sustained
    regression keeps firing instead of teaching the baseline to accept it.

State machine per rule::

    ok ──breach──▶ pending ──for_samples breaches──▶ firing
    ▲                 │ clear                           │ clear_samples clears
    │                 ▼                                 ▼
    └───────────── resolved ◀───────────────────────────┘
        (next evaluation; re-entry within cooldown_s is suppressed)

Evaluation is pull-based — callers decide the cadence (the serve loop
ticks it alongside the :class:`~repro.obs.SLOSampler`), nothing here
spawns threads or touches the request path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

from ..core.stats import EwmaState, burn_rate, ewma_update, ewma_zscore
from .recorder import Recorder, _as_scalar

_KINDS = ("threshold", "burn_rate", "anomaly")
_OPS = {
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
}
_SOURCES = ("last", "mean", "min", "max", "p50", "p95")
STATES = ("ok", "pending", "firing", "resolved")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative rule over ``<stream>.<field>`` of the rollup.

    Only the parameters of the rule's ``kind`` are read; the rest keep
    their defaults. ``for_samples``/``clear_samples`` are the entry/exit
    hysteresis (consecutive breaching/clear evaluations), ``cooldown_s``
    suppresses re-entry into ``pending`` after a resolve.
    """

    name: str
    stream: str
    field: str
    kind: str = "threshold"
    # threshold:
    op: str = ">"
    threshold: float = 0.0
    source: str = "last"  # which rollup aggregate to compare
    # burn_rate:
    objective: float = 0.99  # target good fraction (error budget = 1 - this)
    max_burn: float = 2.0
    short_window: int = 6  # samples, not seconds — cadence is the caller's
    long_window: int = 24
    good_metric: bool = True  # field measures goodness (bad = 1 - value)
    # anomaly:
    alpha: float = 0.3
    z_threshold: float = 4.0
    min_samples: int = 8
    direction: str = "both"  # "above" | "below" | "both"
    # state machine:
    for_samples: int = 2
    clear_samples: int = 2
    cooldown_s: float = 0.0
    severity: str = "warning"  # "info" | "warning" | "page"
    description: str = ""

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown rule kind {self.kind!r}; known: {_KINDS}")
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}; known: {sorted(_OPS)}")
        if self.source not in _SOURCES:
            raise ValueError(
                f"unknown source {self.source!r}; known: {_SOURCES}"
            )
        if self.direction not in ("above", "below", "both"):
            raise ValueError(f"unknown direction {self.direction!r}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        if self.short_window < 1 or self.long_window < self.short_window:
            raise ValueError("need 1 <= short_window <= long_window")
        if self.for_samples < 1 or self.clear_samples < 1:
            raise ValueError("for_samples and clear_samples must be >= 1")


class _RuleState:
    """Mutable evaluation state for one rule."""

    __slots__ = ("state", "breaches", "clears", "fired_count", "since_s",
                 "resolved_at", "ewma", "window", "value", "measure")

    def __init__(self):
        self.state = "ok"
        self.breaches = 0  # consecutive breaching evaluations
        self.clears = 0  # consecutive clear evaluations while firing
        self.fired_count = 0
        self.since_s: float | None = None  # clock() of the last transition
        self.resolved_at: float | None = None
        self.ewma = EwmaState(0, 0.0, 0.0)
        self.window: deque[float] = deque()
        self.value: float | None = None  # last observed field value
        self.measure: float | None = None  # z-score / burn rate / value


class AlertEngine:
    """Evaluate a ruleset against rollups; record transitions to a stream."""

    def __init__(self, recorder: Recorder | None, rules, *,
                 stream: str = "alerts", clock=time.monotonic):
        rules = tuple(rules)
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names in {names}")
        self.recorder = recorder
        self.rules = rules
        self.stream = stream
        self.clock = clock
        self.evaluations = 0
        self.transitions = 0
        self.fired_total = 0
        self.resolved_total = 0
        self._states = {r.name: _RuleState() for r in rules}

    # -- signal extraction ---------------------------------------------------

    @staticmethod
    def _field_value(rule: AlertRule, rollup: dict) -> float | None:
        stream = rollup.get("streams", {}).get(rule.stream)
        if not stream:
            return None
        agg = stream.get("fields", {}).get(rule.field)
        if not agg:
            return None
        return _as_scalar(agg.get(rule.source))

    def _breach(self, rule: AlertRule, st: _RuleState, value: float
                ) -> tuple[bool, float]:
        """(is the signal breaching, the measured statistic)."""
        if rule.kind == "threshold":
            return _OPS[rule.op](value, rule.threshold), value
        if rule.kind == "burn_rate":
            bad = (1.0 - value) if rule.good_metric else value
            st.window.append(float(bad))
            while len(st.window) > rule.long_window:
                st.window.popleft()
            if len(st.window) < rule.short_window:
                return False, 0.0
            budget = 1.0 - rule.objective
            short = list(st.window)[-rule.short_window:]
            fast = burn_rate(sum(short) / len(short), budget)
            slow = burn_rate(sum(st.window) / len(st.window), budget)
            return (fast > rule.max_burn and slow > rule.max_burn), fast
        # anomaly
        z = ewma_zscore(st.ewma, value)
        breach = st.ewma.count >= rule.min_samples and (
            (rule.direction in ("above", "both") and z > rule.z_threshold)
            or (rule.direction in ("below", "both") and z < -rule.z_threshold)
        )
        if not breach:
            # Only a non-anomalous observation teaches the baseline, so a
            # sustained regression keeps firing instead of being absorbed.
            st.ewma = ewma_update(st.ewma, value, rule.alpha)
        return breach, z

    # -- state machine -------------------------------------------------------

    def _transition(self, rule: AlertRule, st: _RuleState, to: str,
                    now: float) -> dict:
        event = {
            "rule": rule.name,
            "from": st.state,
            "to": to,
            "kind": rule.kind,
            "severity": rule.severity,
            "stream": rule.stream,
            "field": rule.field,
            "value": st.value,
            "measure": st.measure,
        }
        st.state = to
        st.since_s = now
        self.transitions += 1
        if to == "firing":
            st.fired_count += 1
            self.fired_total += 1
        if to == "resolved":
            st.resolved_at = now
            self.resolved_total += 1
        if self.recorder is not None:
            self.recorder.record(self.stream, event)
        return event

    def evaluate(self, rollup: dict | None = None) -> list[dict]:
        """One evaluation pass; returns the state transitions it caused
        (each already recorded on the ``alerts`` stream)."""
        if rollup is None:
            if self.recorder is None:
                raise ValueError("no rollup given and no recorder attached")
            rollup = self.recorder.rollup()
        now = self.clock()
        self.evaluations += 1
        events: list[dict] = []
        for rule in self.rules:
            st = self._states[rule.name]
            if st.state == "resolved":
                # "resolved" is held for exactly one evaluation so readers
                # of /alerts see it; then the rule returns to ok.
                events.append(self._transition(rule, st, "ok", now))
            value = self._field_value(rule, rollup)
            if value is None:
                continue  # stream/field not recorded yet: state untouched
            st.value = value
            breach, st.measure = self._breach(rule, st, value)
            if breach:
                st.clears = 0
                st.breaches += 1
                if st.state == "ok":
                    if st.resolved_at is not None and rule.cooldown_s > 0 \
                            and now - st.resolved_at < rule.cooldown_s:
                        continue  # re-entry suppressed by cooldown
                    st.breaches = 1
                    events.append(self._transition(rule, st, "pending", now))
                if st.state == "pending" and st.breaches >= rule.for_samples:
                    events.append(self._transition(rule, st, "firing", now))
            else:
                st.breaches = 0
                if st.state == "pending":
                    events.append(self._transition(rule, st, "ok", now))
                elif st.state == "firing":
                    st.clears += 1
                    if st.clears >= rule.clear_samples:
                        st.clears = 0
                        events.append(
                            self._transition(rule, st, "resolved", now)
                        )
        return events

    # -- views ---------------------------------------------------------------

    def firing(self) -> list[str]:
        """Names of the rules currently firing."""
        return [n for n, st in self._states.items() if st.state == "firing"]

    def state(self, rule_name: str) -> str:
        return self._states[rule_name].state

    def status(self) -> dict:
        """The ``/alerts`` endpoint payload: per-rule state + engine
        counters."""
        now = self.clock()
        rules = {}
        for rule in self.rules:
            st = self._states[rule.name]
            rules[rule.name] = {
                "state": st.state,
                "kind": rule.kind,
                "severity": rule.severity,
                "stream": rule.stream,
                "field": rule.field,
                "value": st.value,
                "measure": st.measure,
                "fired_count": st.fired_count,
                "since_s": None if st.since_s is None else now - st.since_s,
                "description": rule.description,
            }
        return {
            "available": True,
            "rules": rules,
            "firing": self.firing(),
            "evaluations": self.evaluations,
            "transitions": self.transitions,
            "fired_total": self.fired_total,
            "resolved_total": self.resolved_total,
        }


def default_rules(workload: str, default_class: str, *,
                  deadline_ms: float = 250.0,
                  max_depth: int = 256) -> tuple[AlertRule, ...]:
    """The serve front-end's standard ruleset over the streams the
    :mod:`repro.obs.sources` adapters already record.

    ``admission_overload`` / ``queue_depth_high`` are the overload pair the
    autoscaler treats as scale-up triggers (see
    :class:`repro.fleet.autoscale.AutoScaleConfig.overload_alerts`);
    ``sublinear_regression`` / ``rhat_regression`` watch the paper's
    accuracy-vs-cost contract itself.
    """
    cls = f"{workload}.{default_class}"
    return (
        AlertRule(
            name="p95_over_budget", stream="slo", field="p95_ms",
            kind="threshold", op=">", threshold=float(deadline_ms),
            for_samples=2, clear_samples=2, severity="page",
            description="worst-class p95 above the deadline budget",
        ),
        AlertRule(
            name="admission_overload", stream="slo",
            field="admission_shed_floor", kind="threshold", op=">=",
            threshold=0.0, for_samples=1, clear_samples=1, severity="page",
            description="the admission shed floor is active (load is being "
                        "refused)",
        ),
        AlertRule(
            name="queue_depth_high", stream="slo", field="admission_depth",
            kind="threshold", op=">=", threshold=float(max_depth),
            for_samples=1, clear_samples=1, severity="warning",
            description="router backlog at/above the admission depth bound",
        ),
        AlertRule(
            name="deadline_burn", stream="slo",
            field=f"{cls}.deadline_hit_rate", kind="burn_rate",
            objective=0.9, max_burn=1.5, short_window=3, long_window=12,
            for_samples=1, clear_samples=2, severity="page",
            description="top-class deadline error budget burning >1.5x "
                        "over both windows",
        ),
        AlertRule(
            name="req_rate_anomaly", stream="slo", field="req_per_s",
            kind="anomaly", z_threshold=4.0, min_samples=8,
            direction="below", for_samples=2, clear_samples=2,
            description="request throughput collapsed vs its EWMA baseline",
        ),
        AlertRule(
            name="accept_rate_anomaly", stream="refresh",
            field="accept_rate", kind="anomaly", z_threshold=4.0,
            min_samples=8, direction="both", for_samples=2, clear_samples=2,
            description="MH acceptance rate shifted vs its EWMA baseline",
        ),
        AlertRule(
            name="sublinear_regression", stream="transition_cost",
            field="frac_data_touched", kind="threshold", op=">=",
            threshold=0.999, for_samples=2, clear_samples=2,
            severity="warning",
            description="transitions degraded to full data passes "
                        "(sublinearity lost)",
        ),
        AlertRule(
            name="rhat_regression", stream="snapshot", field="rhat",
            kind="threshold", op=">", threshold=1.2, for_samples=2,
            clear_samples=2, severity="warning",
            description="window split R-hat above 1.2: chains diverging",
        ),
        AlertRule(
            name="ess_anomaly", stream="snapshot", field="ess",
            kind="anomaly", z_threshold=4.0, min_samples=8,
            direction="below", for_samples=2, clear_samples=2,
            description="window ESS collapsed vs its EWMA baseline",
        ),
    )
