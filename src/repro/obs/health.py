"""Component health scoring over the recorder's rollup.

One number per subsystem in [0, 1] plus an overall grade — what the
``/health`` endpoint serves and what a fleet operator (or the chaos soak)
reads to decide whether the service is actually OK. Nothing here samples
anything: every score is derived from the **rollup** the
:mod:`repro.obs.sources` adapters already maintain, optionally joined with
a live ``Fleet.report()`` for per-replica liveness.

Components (each scored independently, missing signals score as healthy —
absence of a stream means the subsystem isn't in play, not that it is
broken):

``queue``      admission state: active shed floor / backlog vs ``max_depth``
``router``     lane recovery state: dead lanes now, deaths observed
``replicas``   per-replica liveness + version lag vs the writer (needs a
               ``Fleet.report()``)
``writer``     window convergence: split R-hat and draw depth
``sublinear``  the paper's contract: mean ``frac_data_touched`` < 1.0

Grades: ``ok`` >= 0.8, ``degraded`` >= 0.5, else ``critical``. The overall
score is the *minimum* component score — health is a conjunction; averaging
would let a dead replica pool hide behind a healthy queue.
"""
from __future__ import annotations


def _grade(score: float) -> str:
    if score >= 0.8:
        return "ok"
    if score >= 0.5:
        return "degraded"
    return "critical"


def _last(rollup: dict, stream: str) -> dict:
    return rollup.get("streams", {}).get(stream, {}).get("last", {})


def _fields(rollup: dict, stream: str) -> dict:
    return rollup.get("streams", {}).get(stream, {}).get("fields", {})


def _component(score: float, **detail) -> dict:
    score = max(0.0, min(1.0, float(score)))
    return {"score": score, "status": _grade(score), **detail}


def _queue_health(rollup: dict, max_depth: int | None) -> dict:
    slo = _last(rollup, "slo")
    floor = slo.get("admission_shed_floor", -1)
    depth = slo.get("admission_depth", 0) or 0
    score = 1.0
    if isinstance(floor, (int, float)) and floor >= 0:
        score = 0.4  # actively shedding: degraded by definition
    elif max_depth:
        # Linear pressure penalty as the backlog approaches the shed point.
        score = 1.0 - 0.5 * min(float(depth) / float(max_depth), 1.0)
    return _component(score, depth=depth, shed_floor=floor,
                      shed_total=slo.get("shed", 0))


def _router_health(rollup: dict) -> dict:
    slo = _last(rollup, "slo")
    dead = slo.get("dead_lanes", 0) or 0
    deaths = slo.get("lane_deaths", 0) or 0
    score = 1.0
    if dead:
        score = 0.3  # a lane is down *right now*
    elif deaths:
        score = 0.9  # recovered from deaths: slightly scarred, serving
    return _component(score, dead_lanes=dead, lane_deaths=deaths,
                      rerouted=slo.get("rerouted", 0))


def _replica_health(fleet_report: dict | None) -> dict:
    if not fleet_report:
        return _component(1.0, available=False)
    shards = fleet_report.get("shards", {})
    total = alive = 0
    max_lag = 0
    for shard in shards.values():
        steps = shard.get("writer_steps", 0)
        for stats in shard.get("replicas", []):
            total += 1
            ok = stats.get("alive", True)
            alive += int(bool(ok))
        for version in shard.get("replica_versions", []):
            max_lag = max(max_lag, int(steps) - int(version))
    if not total:
        return _component(1.0, available=False)
    score = alive / total
    if max_lag > 0 and score > 0.0:
        # Replicas alive but trailing the writer: mild staleness penalty,
        # saturating — a stuck delta stream reads as degraded, not critical.
        score *= max(0.6, 1.0 - 0.001 * max_lag)
    sync_errors = len(fleet_report.get("errors", {}))
    if sync_errors:
        score = min(score, 0.7)
    return _component(score, replicas=total, alive=alive, max_version_lag=max_lag,
                      sync_errors=sync_errors)


def _writer_health(rollup: dict) -> dict:
    snap = _last(rollup, "snapshot")
    rhat = snap.get("rhat")
    draws = snap.get("num_draws", 0)
    score = 1.0
    if isinstance(rhat, (int, float)):
        if rhat > 1.5:
            score = 0.3
        elif rhat > 1.2:
            score = 0.6
        elif rhat > 1.1:
            score = 0.9
    return _component(score, rhat=rhat, num_draws=draws,
                      ess=snap.get("ess"))


def _sublinear_health(rollup: dict) -> dict:
    agg = _fields(rollup, "transition_cost").get("frac_data_touched")
    if not agg:
        return _component(1.0, available=False)
    mean = float(agg.get("mean", 0.0))
    # frac == 1.0 means every transition touched all the data — the
    # sublinearity contract is gone, not merely degraded.
    score = 1.0 if mean < 0.9 else (0.6 if mean < 0.999 else 0.2)
    return _component(score, frac_data_touched_mean=mean,
                      samples=int(agg.get("count", 0)))


def health_report(rollup: dict, *, fleet_report: dict | None = None,
                  alert_status: dict | None = None,
                  max_depth: int | None = None) -> dict:
    """The ``/health`` payload: per-component scores, the min-score
    overall grade, and (when an alert engine is attached) the firing
    alerts dragging the grade down — a page-severity alert caps the
    overall score at ``degraded``."""
    components = {
        "queue": _queue_health(rollup, max_depth),
        "router": _router_health(rollup),
        "replicas": _replica_health(fleet_report),
        "writer": _writer_health(rollup),
        "sublinear": _sublinear_health(rollup),
    }
    score = min(c["score"] for c in components.values())
    firing: list[str] = []
    if alert_status and alert_status.get("firing"):
        firing = list(alert_status["firing"])
        severities = {
            name: alert_status.get("rules", {}).get(name, {}).get("severity")
            for name in firing
        }
        cap = 0.4 if "page" in severities.values() else 0.7
        score = min(score, cap)
    return {
        "score": score,
        "status": _grade(score),
        "components": components,
        "firing": firing,
        "run_id": rollup.get("run_id"),
        "uptime_s": rollup.get("uptime_s"),
    }
