"""Live stats endpoint: the recorder's rollup (and trace views) over HTTP.

A :class:`StatsServer` binds a tiny :class:`ThreadingHTTPServer` on a
daemon thread and answers GETs with JSON — what ``serve --stats-addr
host:port`` exposes so a dashboard (or ``curl``) can watch the service
while it is under load. Port 0 binds an ephemeral port (tests); the bound
address is in :attr:`url`. Paths:

====================  =====================================================
path                  payload
====================  =====================================================
``/``                 the owning :meth:`repro.obs.Recorder.rollup` —
                      req/s, latency tails (incl. streaming p50/p95),
                      shed counts, snapshot staleness
``/spans``            the attached :class:`repro.obs.trace.Tracer`'s
                      in-memory span ring (newest ``max_spans``)
``/stages``           per-stage latency breakdown of those spans (queue
                      wait vs batch assembly vs device eval vs combine;
                      :func:`repro.core.stats.stage_latency_breakdown`)
``/sublinear``        the live "fraction of data touched per transition"
                      rollup from the ``transition_cost`` stream, with the
                      per-op breakdown for ``cycle()`` transitions
====================  =====================================================

Any other path falls back to the full rollup, so pre-tracing dashboards
keep working unchanged.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .recorder import Recorder, json_default


def _sublinear_view(rollup: dict) -> dict:
    """The ``/sublinear`` payload from a rollup: overall and per-op
    ``frac_data_touched`` aggregates of the ``transition_cost`` stream."""
    stream = rollup.get("streams", {}).get("transition_cost")
    if not stream:
        return {"available": False, "samples": 0}
    fields = stream.get("fields", {})
    suffix = ".frac_data_touched"
    per_op = {
        key[: -len(suffix)]: agg
        for key, agg in fields.items()
        if key.endswith(suffix)
    }
    return {
        "available": True,
        "samples": stream.get("count", 0),
        "frac_data_touched": fields.get("frac_data_touched"),
        "per_op": per_op,
        "last": stream.get("last", {}),
    }


class StatsServer:
    """Serve ``recorder.rollup()`` (plus trace views) as JSON over GET."""

    def __init__(self, recorder: Recorder, addr: str = "127.0.0.1:0",
                 tracer=None):
        host, _, port = addr.partition(":")
        recorder_ref = recorder
        tracer_ref = tracer

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0].rstrip("/") or "/"
                if path == "/spans":
                    spans = tracer_ref.spans() if tracer_ref else []
                    payload = {
                        "spans": spans,
                        "count": len(spans),
                        "dropped": tracer_ref.dropped if tracer_ref else 0,
                    }
                elif path == "/stages":
                    from ..core.stats import stage_latency_breakdown

                    payload = stage_latency_breakdown(
                        tracer_ref.spans() if tracer_ref else []
                    )
                elif path == "/sublinear":
                    payload = _sublinear_view(recorder_ref.rollup())
                else:
                    payload = recorder_ref.rollup()
                body = json.dumps(payload, default=json_default).encode()
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silence per-request stderr spam
                pass

        self._server = ThreadingHTTPServer(
            (host or "127.0.0.1", int(port or 0)), _Handler
        )
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="stats-http", daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=10.0)
