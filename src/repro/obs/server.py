"""Live stats endpoint: the recorder's rollup over HTTP.

A :class:`StatsServer` binds a tiny :class:`ThreadingHTTPServer` on a
daemon thread and answers every GET with the owning
:class:`repro.obs.Recorder`'s current :meth:`rollup` as JSON — what
``serve --stats-addr host:port`` exposes so a dashboard (or ``curl``) can
watch req/s, latency tails, shed counts, and snapshot staleness while the
service is under load. Port 0 binds an ephemeral port (tests); the bound
address is in :attr:`url`.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .recorder import Recorder, json_default


class StatsServer:
    """Serve ``recorder.rollup()`` as JSON on every GET."""

    def __init__(self, recorder: Recorder, addr: str = "127.0.0.1:0"):
        host, _, port = addr.partition(":")
        recorder_ref = recorder

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — http.server API
                body = json.dumps(
                    recorder_ref.rollup(), default=json_default
                ).encode()
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silence per-request stderr spam
                pass

        self._server = ThreadingHTTPServer(
            (host or "127.0.0.1", int(port or 0)), _Handler
        )
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="stats-http", daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=10.0)
