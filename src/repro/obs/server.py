"""Live stats endpoint: the recorder's rollup (and trace views) over HTTP.

A :class:`StatsServer` binds a tiny :class:`ThreadingHTTPServer` on a
daemon thread and answers GETs with JSON — what ``serve --stats-addr
host:port`` exposes so a dashboard (or ``curl``) can watch the service
while it is under load. Port 0 binds an ephemeral port (tests); the bound
address is in :attr:`url`. Paths:

====================  =====================================================
path                  payload
====================  =====================================================
``/``                 the owning :meth:`repro.obs.Recorder.rollup` —
                      req/s, latency tails (incl. streaming p50/p95),
                      shed counts, snapshot staleness
``/healthz``          cheap liveness probe: ``{"ok": true, "run_id": ...}``
                      (no rollup computed — safe for tight probe loops)
``/health``           the component health model
                      (:func:`repro.obs.health.health_report`): per-
                      component scores + the min-score overall grade
``/alerts``           the attached :class:`repro.obs.alerts.AlertEngine`'s
                      per-rule state (``{"available": false}`` without one)
``/spans``            the attached :class:`repro.obs.trace.Tracer`'s
                      in-memory span ring (newest ``max_spans``)
``/stages``           per-stage latency breakdown of those spans (queue
                      wait vs batch assembly vs device eval vs combine;
                      :func:`repro.core.stats.stage_latency_breakdown`)
``/sublinear``        the live "fraction of data touched per transition"
                      rollup from the ``transition_cost`` stream, with the
                      per-op breakdown for ``cycle()`` transitions
====================  =====================================================

Any other path is a **404** with a JSON body listing the valid routes (a
typo'd dashboard URL used to silently get the full rollup with a 200 —
indistinguishable from the intended answer).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .recorder import Recorder, json_default

ROUTES = ("/", "/alerts", "/health", "/healthz", "/spans", "/stages",
          "/sublinear")


def _sublinear_view(rollup: dict) -> dict:
    """The ``/sublinear`` payload from a rollup: overall and per-op
    ``frac_data_touched`` aggregates of the ``transition_cost`` stream."""
    stream = rollup.get("streams", {}).get("transition_cost")
    if not stream:
        return {"available": False, "samples": 0}
    fields = stream.get("fields", {})
    suffix = ".frac_data_touched"
    per_op = {
        key[: -len(suffix)]: agg
        for key, agg in fields.items()
        if key.endswith(suffix)
    }
    return {
        "available": True,
        "samples": stream.get("count", 0),
        "frac_data_touched": fields.get("frac_data_touched"),
        "per_op": per_op,
        "last": stream.get("last", {}),
    }


class StatsServer:
    """Serve ``recorder.rollup()`` (plus alert/health/trace views) as JSON.

    ``alerts`` (an :class:`~repro.obs.alerts.AlertEngine`), ``health`` (a
    zero-arg callable returning the ``/health`` payload), and ``tracer``
    are all optional and may also be attached after construction by
    assigning the public attributes — the serve front-end builds the
    engine after the server is already listening.
    """

    def __init__(self, recorder: Recorder, addr: str = "127.0.0.1:0",
                 tracer=None, alerts=None, health=None):
        host, _, port = addr.partition(":")
        self.recorder = recorder
        self.tracer = tracer
        self.alerts = alerts
        self.health = health
        server_ref = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0].rstrip("/") or "/"
                status = 200
                if path == "/":
                    payload = server_ref.recorder.rollup()
                elif path == "/healthz":
                    payload = {"ok": True,
                               "run_id": server_ref.recorder.run_id}
                elif path == "/health":
                    payload = server_ref._health_view()
                elif path == "/alerts":
                    engine = server_ref.alerts
                    payload = engine.status() if engine is not None \
                        else {"available": False}
                elif path == "/spans":
                    tracer = server_ref.tracer
                    spans = tracer.spans() if tracer else []
                    payload = {
                        "spans": spans,
                        "count": len(spans),
                        "dropped": tracer.dropped if tracer else 0,
                    }
                elif path == "/stages":
                    from ..core.stats import stage_latency_breakdown

                    tracer = server_ref.tracer
                    payload = stage_latency_breakdown(
                        tracer.spans() if tracer else []
                    )
                elif path == "/sublinear":
                    payload = _sublinear_view(server_ref.recorder.rollup())
                else:
                    status = 404
                    payload = {"error": f"unknown path {path!r}",
                               "routes": list(ROUTES)}
                body = json.dumps(payload, default=json_default).encode()
                self.send_response(status)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silence per-request stderr spam
                pass

        self._server = ThreadingHTTPServer(
            (host or "127.0.0.1", int(port or 0)), _Handler
        )
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="stats-http", daemon=True
        )
        self._thread.start()

    def _health_view(self) -> dict:
        if self.health is not None:
            return self.health()
        from .health import health_report

        engine = self.alerts
        return health_report(
            self.recorder.rollup(),
            alert_status=engine.status() if engine is not None else None,
        )

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=10.0)
