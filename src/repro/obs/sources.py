"""Adapters from the repo's existing signal sources into a Recorder.

Nothing here computes new statistics — each adapter samples a surface that
already exists (``slo_report()``, ``Snapshot``, ``Fleet.sync_stats``,
``ensemble_summary`` adaptation traces, ``run_timed(on_block=)``) and
flattens it into one record on a named stream, so a run's whole signal set
lands in one place instead of vanishing with the process:

====================  =====================================================
stream                source
====================  =====================================================
``slo``               :class:`SLOSampler` over a RequestQueue/FleetRouter
``admission``         shed-floor *transitions* (same sampler)
``snapshot``          :func:`record_snapshot` — staleness, R-hat, window ESS
``adaptation``        :func:`record_adaptation` — epsilon/batch/sigma traces
``fleet``             :func:`record_fleet_sync` — delta-vs-full byte accounting
``refresh``           :func:`make_on_block` — per-block transition throughput
``transition_cost``   :func:`record_transition_cost` — fraction of data
                      touched per transition (the live sublinear evidence)
====================  =====================================================
"""
from __future__ import annotations

import time

import numpy as np

from .recorder import Recorder, _as_scalar

# Per-class fields lifted into the flattened slo record.
_CLASS_FIELDS = (
    "count", "errors", "admitted", "shed", "priority",
    "p50_ms", "p95_ms", "p99_ms", "deadline_hit_rate",
    "mean_batch_size", "staleness_mean_s",
)


class SLOSampler:
    """Periodically flatten a queue's / router's unified ``slo_report()``
    into the ``slo`` stream.

    Derives interval request throughput (``req_per_s``) from the completion
    count delta between consecutive samples, lifts the worst per-class tail
    into top-level ``p95_ms``/``staleness_mean_s`` (the single numbers the
    stats endpoint check and the soak harness read), and records admission
    *state transitions* (shed-floor changes) on the ``admission`` stream.
    """

    def __init__(self, recorder: Recorder, source, stream: str = "slo"):
        self.recorder = recorder
        self.source = source  # anything with .slo_report()
        self.stream = stream
        self._prev: tuple[float, int] | None = None
        self._last_floor: object = "__unset__"

    def sample(self) -> dict:
        report = self.source.slo_report()
        now = time.monotonic()
        rec: dict = {
            "count": report["count"],
            "errors": report["errors"],
            "shed": report.get("shed", 0),
        }
        if self._prev is not None:
            dt = now - self._prev[0]
            delta = report["count"] - self._prev[1]
            if delta < 0:
                # The source's completion counters went backwards — a
                # router rebuild or pool restart reset them. A negative
                # delta would poison the req_per_s aggregates, so clamp to
                # zero and leave an explicit marker record instead.
                self.recorder.record(self.stream, {
                    "counter_reset": True,
                    "count_before": self._prev[1],
                    "count_after": report["count"],
                })
                delta = 0
            rec["req_per_s"] = delta / dt if dt > 0 else 0.0
        self._prev = (now, report["count"])
        admission = report.get("admission")
        if admission:
            rec["admission_depth"] = admission["depth"]
            rec["admission_miss_rate"] = admission["predicted_miss_rate"]
            floor = admission["shed_floor"]
            rec["admission_shed_floor"] = -1 if floor is None else floor
            if floor != self._last_floor:
                if self._last_floor != "__unset__":
                    self.recorder.record("admission", {
                        "shed_floor": -1 if floor is None else floor,
                        "depth": admission["depth"],
                        "predicted_miss_rate": admission["predicted_miss_rate"],
                    })
                self._last_floor = floor
        recovery = report.get("recovery")
        if recovery:
            rec["lane_deaths"] = recovery["lane_deaths"]
            rec["rerouted"] = recovery["rerouted"]
            rec["dead_lanes"] = recovery["dead_lanes"]
        p95s, stales = [], []
        for cls, entry in report["classes"].items():
            for field in _CLASS_FIELDS:
                value = entry.get(field)
                if value is not None:
                    rec[f"{cls}.{field}"] = value
            if entry.get("p95_ms") is not None:
                p95s.append(entry["p95_ms"])
            if entry.get("staleness_mean_s") is not None:
                stales.append(entry["staleness_mean_s"])
        if p95s:
            rec["p95_ms"] = float(max(p95s))  # worst class tail
        if stales:
            rec["staleness_mean_s"] = float(max(stales))
        self.recorder.record(self.stream, rec)
        return rec


def record_snapshot(recorder: Recorder, name: str, snap,
                    stream: str = "snapshot") -> dict:
    """One ``snapshot`` record from a Snapshot (resident, pool, or replica
    view): staleness, window size, and — when the window is deep enough —
    the split-R-hat and cross-chain window ESS freshness diagnostics."""
    from ..serving.pool import snapshot_ess, snapshot_rhat

    rec: dict = {
        "workload": name,
        "staleness_s": snap.staleness_s,
        "num_draws": snap.num_draws,
        "steps_done": snap.steps_done,
    }
    if snap.draws is not None:
        rhat = snapshot_rhat(snap)
        if rhat is not None:
            rec["rhat"] = rhat
        rec["ess"] = snapshot_ess(snap)
    return recorder.record(stream, rec)


def record_adaptation(recorder: Recorder, name: str, summary: dict,
                      stream: str = "adaptation") -> dict | None:
    """One ``adaptation`` record from an ``ensemble_summary`` dict (a
    snapshot's ``summary``): the schedule controller's epsilon / effective
    batch / acceptance traces, flattened to scalars (per-chain arrays are
    recorded as their ensemble mean; nested dicts get dotted keys)."""
    if not summary:
        return None
    rec: dict = {"workload": name}

    def put(prefix: str, mapping: dict) -> None:
        for key, value in mapping.items():
            if isinstance(value, dict):
                put(f"{prefix}{key}.", value)
            elif _as_scalar(value) is not None:
                rec[f"{prefix}{key}"] = float(value)
            elif isinstance(value, np.ndarray) and value.dtype.kind in "fiub" \
                    and value.size and not prefix:
                # Per-chain top-level traces (accept_rate, final_epsilon, ...);
                # nested arrays (histogram edges etc.) are not metrics.
                rec[f"{key}_mean"] = float(np.mean(value))

    put("", summary)
    if len(rec) == 1:  # nothing numeric — don't write an empty record
        return None
    return recorder.record(stream, rec)


def record_fleet_sync(recorder: Recorder, fleet, stream: str = "fleet") -> dict:
    """One ``fleet`` record: the cumulative delta-vs-full byte accounting
    (``Fleet.sync_stats``) plus per-shard writer/replica progress."""
    sync = dict(fleet.sync_stats)
    rec: dict = dict(sync)
    rec["delta_ratio"] = (
        sync["delta_wire_bytes"] / max(sync["full_wire_bytes"], 1)
    )
    report = fleet.report()
    for shard_name, shard in report["shards"].items():
        rec[f"{shard_name}.writer_steps"] = shard["writer_steps"]
        rec[f"{shard_name}.min_replica_version"] = (
            min(shard["replica_versions"]) if shard["replica_versions"] else 0
        )
    rec["sync_errors"] = len(report["errors"])
    return recorder.record(stream, rec)


def record_transition_cost(recorder: Recorder, name: str, summary: dict,
                           num_sections=None,
                           stream: str = "transition_cost") -> dict | None:
    """One ``transition_cost`` record from a snapshot's ``summary``: the
    live sublinear-cost evidence, per refresh block.

    ``summary`` is what :func:`repro.core.stats.ensemble_summary` returns
    (already on every :class:`~repro.serving.resident.Snapshot`), either a
    single-op dict carrying ``mean_n_evaluated_overall`` or — for
    ``cycle()`` transitions — a dict of such summaries keyed by component
    op name. ``num_sections`` is the partitioned target's section count
    (an ``{op_name: count}`` dict for composites); when known, each op's
    ``frac_data_touched`` = sections evaluated / sections total is the
    paper's headline ratio — strictly below 1.0 means the transition is
    genuinely sublinear. The top-level ``frac_data_touched`` of a
    composite record is the mean across its subsampled ops."""
    def one(prefix: str, s: dict, ns) -> float | None:
        ne = s.get("mean_n_evaluated_overall")
        if not isinstance(ne, (int, float)):
            return None
        rec[f"{prefix}mean_n_evaluated"] = float(ne)
        if isinstance(s.get("mean_rounds_overall"), (int, float)):
            rec[f"{prefix}mean_rounds"] = float(s["mean_rounds_overall"])
        if ns:
            rec[f"{prefix}num_sections"] = int(ns)
            frac = float(ne) / float(ns)
            rec[f"{prefix}frac_data_touched"] = frac
            return frac
        return None

    rec: dict = {"workload": name}
    if "mean_n_evaluated_overall" in summary:
        one("", summary, num_sections)
    else:  # composite: {op_name: ensemble_summary}
        fracs = []
        for op, s in summary.items():
            if not isinstance(s, dict):
                continue
            ns = num_sections.get(op) if isinstance(num_sections, dict) \
                else num_sections
            frac = one(f"{op}.", s, ns)
            if frac is not None:
                fracs.append(frac)
        if fracs:
            rec["frac_data_touched"] = float(np.mean(fracs))
    if len(rec) == 1:  # no subsampled op anywhere — nothing to record
        return None
    return recorder.record(stream, rec)


def make_on_block(recorder: Recorder, name: str = "",
                  stream: str = "refresh"):
    """An ``on_block`` hook for :meth:`ChainEnsemble.run_timed`: records
    each block's transition throughput and acceptance/adaptation state on
    the ``refresh`` stream. The hook keeps its own clock, so throughput is
    per block, not cumulative."""
    state = {"t": None, "step": None}

    def on_block(_state, samples, infos, steps_done) -> None:
        import jax

        now = time.monotonic()
        leaves = jax.tree.leaves(samples)
        k = int(np.asarray(leaves[0]).shape[0]) if leaves else 1
        rec: dict = {"steps_done": int(steps_done)}
        if name:
            rec["workload"] = name
        if state["t"] is not None and steps_done > state["step"]:
            dt = now - state["t"]
            if dt > 0:
                rec["transitions_per_sec"] = (
                    (steps_done - state["step"]) * k / dt
                )
        state["t"], state["step"] = now, steps_done
        if hasattr(infos, "accepted"):
            rec["accept_rate"] = float(np.mean(np.asarray(infos.accepted)))
        if hasattr(infos, "n_evaluated"):
            rec["mean_n_evaluated"] = float(
                np.mean(np.asarray(infos.n_evaluated))
            )
        for field in ("epsilon", "batch_eff"):
            if hasattr(infos, field):
                trace = np.asarray(getattr(infos, field))
                rec[f"mean_{field}"] = float(np.mean(trace))
        recorder.record(stream, rec)

    return on_block
