"""One-shot terminal summary of a recorded run directory.

``python -m repro.obs.dash <obs-dir>/<run-id>`` renders the headlines of a
finished (or crashed) run from its on-disk artifacts alone — no live
endpoint required — so a soak/CI artifact is inspectable straight from the
download:

* rollup headlines (uptime, per-stream counts, req/s, worst p95, shed)
* the sublinear fraction (``transition_cost``), the paper's live evidence
* the per-stage latency table when ``spans.jsonl`` was recorded
* alert history: rules that fired, and anything still firing at exit

The rollup comes from ``summary.json`` when the recorder closed cleanly;
otherwise it is rebuilt by folding the raw ``*.jsonl`` streams through the
same per-field aggregation the live rollup uses — a crashed run still
renders.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .recorder import _as_scalar, _FieldAgg


def load_rollup(run_dir: str) -> dict:
    """``summary.json`` if the run closed cleanly, else a rollup rebuilt
    from the stream files."""
    summary = os.path.join(run_dir, "summary.json")
    if os.path.exists(summary):
        with open(summary) as f:
            return json.load(f)
    streams: dict = {}
    for fname in sorted(os.listdir(run_dir)):
        if not fname.endswith(".jsonl"):
            continue
        name = fname[: -len(".jsonl")]
        agg: dict = {"count": 0, "fields": {}, "last": {}}
        for rec in read_stream(run_dir, name):
            agg["count"] += 1
            agg["last"] = rec
            for field, value in rec.items():
                v = _as_scalar(value)
                if v is not None:
                    agg["fields"].setdefault(field, _FieldAgg()).add(v)
        if agg["count"]:
            streams[name] = {
                "count": agg["count"],
                "last": agg["last"],
                "fields": {f: a.summary()
                           for f, a in agg["fields"].items()},
            }
    meta: dict = {}
    meta_path = os.path.join(run_dir, "meta.json")
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    last_rel = max(
        (s["last"].get("rel_s", 0.0) for s in streams.values()), default=0.0
    )
    return {"run_id": meta.get("run_id", os.path.basename(run_dir)),
            "uptime_s": last_rel, "meta": meta, "streams": streams}


def read_stream(run_dir: str, stream: str) -> list[dict]:
    path = os.path.join(run_dir, f"{stream}.jsonl")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _fmt(value, spec: str = ".2f") -> str:
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        return "n/a" if value is None else str(value)
    return format(value, spec)


def _headlines(rollup: dict, out) -> None:
    streams = rollup.get("streams", {})
    print(f"run {rollup.get('run_id')}  "
          f"uptime={_fmt(rollup.get('uptime_s'), '.1f')}s  "
          f"streams={len(streams)}", file=out)
    counts = "  ".join(f"{n}={s.get('count', 0)}"
                       for n, s in sorted(streams.items()))
    if counts:
        print(f"  records: {counts}", file=out)
    slo = streams.get("slo", {})
    if slo:
        f = slo.get("fields", {})
        last = slo.get("last", {})
        print(f"  slo: req_per_s~{_fmt(f.get('req_per_s', {}).get('mean'), '.0f')} "
              f"p95_ms(worst)={_fmt(f.get('p95_ms', {}).get('max'))} "
              f"shed={_fmt(last.get('shed'), 'd')} "
              f"errors={_fmt(last.get('errors'), 'd')} "
              f"dead_lanes={_fmt(last.get('dead_lanes'), 'd')}", file=out)


def _sublinear(rollup: dict, out) -> None:
    from .server import _sublinear_view

    view = _sublinear_view(rollup)
    if not view.get("available"):
        print("  sublinear: no transition_cost records", file=out)
        return
    agg = view.get("frac_data_touched") or {}
    print(f"  sublinear: frac_data_touched mean={_fmt(agg.get('mean'), '.4f')} "
          f"last={_fmt(agg.get('last'), '.4f')} "
          f"over {view['samples']} refreshes"
          + (f"; per-op: " + ", ".join(
              f"{op}={_fmt(a.get('mean'), '.3f')}"
              for op, a in sorted(view["per_op"].items()))
             if view.get("per_op") else ""), file=out)


def _stages(run_dir: str, out) -> None:
    spans = read_stream(run_dir, "spans")
    if not spans:
        return
    from ..core.stats import stage_latency_breakdown

    table = stage_latency_breakdown(spans).get("stages", {})
    if not table:
        return
    print("  stage latency (ms):", file=out)
    print(f"    {'stage':14s} {'count':>6s} {'mean':>8s} {'p50':>8s} "
          f"{'p95':>8s} {'max':>8s}", file=out)
    for stage, row in table.items():
        print(f"    {stage:14s} {row.get('count', 0):6d} "
              f"{_fmt(row.get('mean_ms')):>8s} {_fmt(row.get('p50_ms')):>8s} "
              f"{_fmt(row.get('p95_ms')):>8s} {_fmt(row.get('max_ms')):>8s}",
              file=out)


def _alerts(run_dir: str, out) -> None:
    events = read_stream(run_dir, "alerts")
    if not events:
        print("  alerts: none recorded", file=out)
        return
    state: dict[str, dict] = {}
    fired: dict[str, int] = {}
    for ev in events:
        rule = ev.get("rule", "?")
        state[rule] = ev
        if ev.get("to") == "firing":
            fired[rule] = fired.get(rule, 0) + 1
    firing = sorted(r for r, ev in state.items() if ev.get("to") == "firing")
    print(f"  alerts: {len(events)} transitions, "
          f"{sum(fired.values())} fire(s) across {len(fired)} rule(s)",
          file=out)
    for rule, n in sorted(fired.items()):
        ev = state[rule]
        print(f"    {rule:24s} fired x{n}  last={ev.get('to')} "
              f"severity={ev.get('severity')} "
              f"value={_fmt(ev.get('value'), '.4g')}", file=out)
    if firing:
        print(f"    STILL FIRING at exit: {', '.join(firing)}", file=out)


def _autoscale(run_dir: str, out) -> None:
    events = read_stream(run_dir, "autoscale")
    if not events:
        return
    ups = sum(1 for e in events if e.get("action") == "scale_up")
    downs = sum(1 for e in events if e.get("action") == "scale_down")
    print(f"  autoscale: {len(events)} decisions "
          f"(scale_up={ups} scale_down={downs})", file=out)
    for ev in events:
        if ev.get("action") in ("scale_up", "scale_down"):
            print(f"    t+{_fmt(ev.get('rel_s'), '.1f')}s {ev['action']} "
                  f"{ev.get('replica', '')} replicas "
                  f"{ev.get('replicas_before')}->{ev.get('replicas_after')} "
                  f"({ev.get('reason', '')})", file=out)


def main(argv=None, out=None) -> int:
    out = out or sys.stdout
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dash", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("run_dir", help="a recorder run directory "
                                    "(<obs-dir>/<run-id>)")
    args = ap.parse_args(argv)
    run_dir = args.run_dir
    if not os.path.isdir(run_dir):
        print(f"dash: no such run directory: {run_dir}", file=sys.stderr)
        return 2
    rollup = load_rollup(run_dir)
    if not rollup.get("streams"):
        print(f"dash: {run_dir} holds no metric streams", file=sys.stderr)
        return 2
    _headlines(rollup, out)
    _sublinear(rollup, out)
    _stages(run_dir, out)
    _alerts(run_dir, out)
    _autoscale(run_dir, out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
