"""End-to-end request tracing across the serving/fleet stack.

A *trace* follows one request from :meth:`RequestQueue.submit` (or
:meth:`FleetRouter.submit`) through batch assembly, across the pickled-pipe
:class:`~repro.fleet.replica.ReplicaProcess` transport, into
:class:`~repro.serving.resident.SnapshotEvaluator` device evaluation and the
subposterior combine path. Each hop is a *span*: a plain dict with

====================  =====================================================
field                 meaning
====================  =====================================================
``trace_id``          the request this span belongs to (shared end-to-end)
``span_id``           this span
``parent_id``         the enclosing span (None for the request root)
``name``              human label (``request:bayeslr.predictive``, ...)
``stage``             one of the stage tags below (the latency-breakdown key)
``start_s``           ``time.monotonic()`` at open — on Linux this clock is
                      CLOCK_MONOTONIC, shared across processes, so writer-
                      and replica-process spans nest on one timeline
``dur_s``             open-to-close duration (present only on closed spans)
``pid``               OS process that produced the span
====================  =====================================================

plus free-form tags. Stage tags used by the serving stack: ``request``
(root), ``queue_wait``, ``assembly``, ``replica_serve``, ``device_eval``,
``combine``.

Spans are plain dicts on purpose: replica worker processes build them with
:func:`span_open`/:func:`span_close` and ship them back over the pipe
inside the query reply — no Tracer, Recorder, or lock crosses the process
boundary. The parent-side :class:`Tracer` then :meth:`~Tracer.emit`\\ s them:
every closed span lands in a bounded in-memory ring (what ``/spans`` and
the Chrome export read) and on the ``spans`` stream of the owning
:class:`~repro.obs.Recorder` (so ``spans.jsonl`` persists with the other
metric streams when ``--obs-dir``/``--trace-dir`` is set).

Export (Chrome/Perfetto ``trace_event`` JSON — load in ``ui.perfetto.dev``
or ``chrome://tracing``)::

    python -m repro.obs.trace --export /tmp/trace/spans.jsonl --out trace.json
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
import uuid
from collections import deque

STAGES = ("request", "queue_wait", "assembly", "replica_serve",
          "device_eval", "combine")


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


def new_span_id() -> str:
    return uuid.uuid4().hex[:16]


def span_open(trace_id: str | None, name: str, stage: str,
              parent_id: str | None = None, **tags) -> dict:
    """An open span (no ``dur_s`` yet). ``trace_id=None`` makes a *raw*
    span a later :meth:`Tracer.adopt` grafts onto a trace — what components
    that must not depend on a Tracer (evaluator, replica workers) produce."""
    span = {
        "trace_id": trace_id,
        "span_id": new_span_id(),
        "parent_id": parent_id,
        "name": name,
        "stage": stage,
        "start_s": time.monotonic(),
        "pid": os.getpid(),
    }
    span.update(tags)
    return span


def span_close(span: dict, **tags) -> dict:
    """Close an open span in place (sets ``dur_s``); returns it."""
    span["dur_s"] = time.monotonic() - span["start_s"]
    span.update(tags)
    return span


class Tracer:
    """Span collection point for one serving process.

    Thread-safe. Closed spans go two places: a bounded in-memory ring
    (``max_spans`` newest; ``dropped`` counts evictions) that the stats
    endpoint and the exit-time export read, and — when a recorder is
    attached — the ``spans`` stream, whose rollup then carries ``dur_s``
    count/mean/tails per the normal field aggregation. ``jsonl_path``
    additionally tees every span to a standalone JSONL file (what
    ``serve --trace-dir`` points the ``--export`` CLI at).
    """

    def __init__(self, recorder=None, *, stream: str = "spans",
                 max_spans: int = 100_000, jsonl_path: str | None = None):
        self.recorder = recorder
        self.stream = stream
        self.dropped = 0
        self._ring: deque[dict] = deque(maxlen=int(max_spans))
        self._lock = threading.Lock()
        self._file = None
        if jsonl_path:
            os.makedirs(os.path.dirname(jsonl_path) or ".", exist_ok=True)
            self._file = open(jsonl_path, "a", buffering=1)

    # -- span lifecycle ----------------------------------------------------

    def new_trace(self, name: str, stage: str = "request", **tags) -> dict:
        """Open a root span under a fresh trace_id."""
        return span_open(new_trace_id(), name, stage, parent_id=None, **tags)

    def start(self, trace_id: str, name: str, stage: str,
              parent_id: str | None = None, **tags) -> dict:
        return span_open(trace_id, name, stage, parent_id=parent_id, **tags)

    def finish(self, span: dict, **tags) -> dict:
        """Close and emit an open span."""
        return self.emit(span_close(span, **tags))

    def emit(self, span: dict) -> dict:
        """Collect an already-closed span (ring + recorder + JSONL tee)."""
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(span)
            if self._file is not None:
                self._file.write(json.dumps(span) + "\n")
        if self.recorder is not None:
            self.recorder.record(self.stream, span)
        return span

    def adopt(self, spans, trace_id: str, parent_id: str | None = None) -> list:
        """Graft raw spans (``trace_id=None``, e.g. produced inside the
        evaluator or shipped back from a replica worker) onto ``trace_id``
        and emit them. Spans without a parent are parented to
        ``parent_id``; internal parent links between the raw spans are
        preserved."""
        out = []
        for span in spans:
            span = dict(span)
            span["trace_id"] = trace_id
            if span.get("span_id") is None:
                span["span_id"] = new_span_id()
            if span.get("parent_id") is None:
                span["parent_id"] = parent_id
            out.append(self.emit(span))
        return out

    # -- reading -----------------------------------------------------------

    def spans(self) -> list[dict]:
        with self._lock:
            return list(self._ring)

    def trace(self, trace_id: str) -> list[dict]:
        return [s for s in self.spans() if s.get("trace_id") == trace_id]

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


# ---------------------------------------------------------------------------
# Chrome/Perfetto trace_event export
# ---------------------------------------------------------------------------

_META_FIELDS = ("trace_id", "span_id", "parent_id", "name", "stage",
                "start_s", "dur_s", "pid", "t", "rel_s")


def chrome_trace_events(spans) -> dict:
    """Closed spans -> Chrome ``trace_event`` JSON (complete "X" events,
    microsecond timestamps relative to the earliest span; one track per
    originating pid, so replica-process spans sit on their own row while
    still nesting on the shared monotonic timeline)."""
    closed = [s for s in spans if s.get("dur_s") is not None]
    t0 = min((s["start_s"] for s in closed), default=0.0)
    events = []
    for s in sorted(closed, key=lambda s: s["start_s"]):
        args = {k: v for k, v in s.items() if k not in _META_FIELDS}
        args["trace_id"] = s.get("trace_id")
        events.append({
            "name": s.get("name", "?"),
            "cat": s.get("stage", "span"),
            "ph": "X",
            "ts": round((s["start_s"] - t0) * 1e6, 3),
            "dur": round(s["dur_s"] * 1e6, 3),
            "pid": s.get("pid", 0),
            "tid": s.get("pid", 0),
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def load_spans(path: str) -> list[dict]:
    """Spans from a ``spans.jsonl`` file, or from a directory holding one
    (a Recorder run dir or a ``--trace-dir``)."""
    if os.path.isdir(path):
        path = os.path.join(path, "spans.jsonl")
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def export_chrome_trace(spans, out_path: str) -> str:
    payload = chrome_trace_events(spans)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f)
    return out_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--export", required=True, metavar="SPANS",
                    help="spans.jsonl file, or a directory containing one")
    ap.add_argument("--out", default=None,
                    help="output trace JSON (default <dir>/trace.json)")
    ap.add_argument("--trace-id", default=None,
                    help="export only this trace's spans")
    args = ap.parse_args(argv)
    spans = load_spans(args.export)
    if args.trace_id:
        spans = [s for s in spans if s.get("trace_id") == args.trace_id]
    src_dir = args.export if os.path.isdir(args.export) \
        else os.path.dirname(args.export)
    out = args.out or os.path.join(src_dir or ".", "trace.json")
    export_chrome_trace(spans, out)
    n_traces = len({s.get("trace_id") for s in spans if s.get("dur_s") is not None})
    print(f"TRACE_EXPORT spans={len(spans)} traces={n_traces} out={out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
