"""Historical perf trend store: a ring buffer of bench artifact sets.

The CI perf gate used to diff against exactly one previous run's
``BENCH_*.json`` set — one noisy baseline, no memory. A
:class:`HistoryStore` keeps the last ``capacity`` runs' artifacts
(``BENCH_*.json`` plus the run's ``GATE_verdict.json``) in an append-only
ring under one root directory::

    <root>/index.json              {"next_seq": 7, "runs": [...]}  oldest first
    <root>/000004-20260807-.../    BENCH_serving.json, ..., GATE_verdict.json
    <root>/000005-.../
    <root>/000006-.../

``benchmarks/gate.py --trend --history <root>`` reads the last K runs for
a median-of-last-K baseline plus monotone-drift detection, then appends
the current run — so the store itself is what CI persists run-over-run
(an ``actions/cache``-backed directory; see ``.github/workflows/ci.yml``).

The store is deliberately dumb: it copies files and prunes the oldest
entries past ``capacity``. All metric math (record matching, direction,
thresholds) stays in ``benchmarks/gate.py``. A missing or corrupt
``index.json`` is rebuilt from the run directories on disk, so an
expired/partial CI cache degrades to "shorter history", never to a crash.
"""
from __future__ import annotations

import json
import os
import shutil
import time

_ARTIFACT_PREFIX = "BENCH_"
_VERDICT = "GATE_verdict.json"


class HistoryStore:
    """Append-only ring buffer of the last N runs' bench artifacts."""

    def __init__(self, root: str, capacity: int = 20):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.root = root
        self.capacity = int(capacity)
        os.makedirs(self.root, exist_ok=True)
        self._index = self._load_index()

    # -- index -------------------------------------------------------------

    @property
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.json")

    def _load_index(self) -> dict:
        try:
            with open(self._index_path) as f:
                index = json.load(f)
            runs = [r for r in index.get("runs", [])
                    if os.path.isdir(os.path.join(self.root, r["id"]))]
            return {"next_seq": int(index.get("next_seq", len(runs))),
                    "runs": runs}
        except (OSError, ValueError, KeyError, TypeError):
            # No/corrupt index: rebuild from the run dirs on disk (their
            # zero-padded seq prefix keeps them sortable oldest-first).
            runs = [
                {"id": d, "saved_at": None}
                for d in sorted(os.listdir(self.root))
                if os.path.isdir(os.path.join(self.root, d))
            ]
            next_seq = 0
            for r in runs:
                try:
                    next_seq = max(next_seq, int(r["id"].split("-", 1)[0]) + 1)
                except ValueError:
                    pass
            return {"next_seq": next_seq, "runs": runs}

    def _write_index(self) -> None:
        with open(self._index_path, "w") as f:
            json.dump(self._index, f, indent=1, default=str)

    # -- reading -----------------------------------------------------------

    def runs(self) -> list[dict]:
        """Run entries, oldest first: ``{"id", "saved_at", ...}``."""
        return [dict(r) for r in self._index["runs"]]

    def run_dir(self, run_id: str) -> str:
        return os.path.join(self.root, run_id)

    def last(self, n: int) -> list[dict]:
        """The newest ``n`` run entries, oldest first."""
        return self.runs()[-n:] if n > 0 else []

    def __len__(self) -> int:
        return len(self._index["runs"])

    # -- appending ---------------------------------------------------------

    def append(self, artifact_dir: str, run_id: str | None = None,
               meta: dict | None = None) -> str:
        """Copy one run's ``BENCH_*.json`` (+ ``GATE_verdict.json`` when
        present) into the ring; prunes past ``capacity``. Returns the run
        id. A run with no bench artifacts at all is refused — an empty
        entry would silently shorten every later trend window."""
        files = sorted(
            f for f in os.listdir(artifact_dir)
            if (f.startswith(_ARTIFACT_PREFIX) and f.endswith(".json"))
            or f == _VERDICT
        )
        if not any(f.startswith(_ARTIFACT_PREFIX) for f in files):
            raise FileNotFoundError(
                f"no {_ARTIFACT_PREFIX}*.json artifacts in {artifact_dir!r}"
            )
        seq = self._index["next_seq"]
        self._index["next_seq"] = seq + 1
        if run_id is None:
            run_id = f"{seq:06d}-{time.strftime('%Y%m%d-%H%M%S')}"
        else:
            run_id = f"{seq:06d}-{run_id}"
        dst = self.run_dir(run_id)
        os.makedirs(dst, exist_ok=True)
        for f in files:
            shutil.copy2(os.path.join(artifact_dir, f), os.path.join(dst, f))
        self._index["runs"].append({
            "id": run_id,
            "saved_at": time.time(),
            "artifacts": files,
            **(meta or {}),
        })
        while len(self._index["runs"]) > self.capacity:
            oldest = self._index["runs"].pop(0)
            shutil.rmtree(self.run_dir(oldest["id"]), ignore_errors=True)
        self._write_index()
        return run_id
