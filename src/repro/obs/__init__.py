"""Observability: per-run metric streams, rollups, and the live stats endpoint.

The recording layer over the serving/fleet stack (see docs/OBSERVABILITY.md):

    signal sources ──▶ sources.py adapters ──▶ Recorder ──▶ <run>/<stream>.jsonl
     slo_report()        SLOSampler              │             summary.json
     Snapshot            record_snapshot         └─▶ rollup() ──▶ StatsServer
     sync_stats          record_fleet_sync                        (HTTP JSON)
     run_timed           make_on_block
     adaptation trace    record_adaptation

Front-end: ``python -m repro.launch.serve --stats-addr 127.0.0.1:8787
--obs-dir /tmp/obs``; regression gating over the recorded benchmark
artifacts lives in ``benchmarks/gate.py``.
"""
from .recorder import Recorder, json_default
from .server import StatsServer
from .sources import (
    SLOSampler,
    make_on_block,
    record_adaptation,
    record_fleet_sync,
    record_snapshot,
)

__all__ = [
    "Recorder",
    "SLOSampler",
    "StatsServer",
    "json_default",
    "make_on_block",
    "record_adaptation",
    "record_fleet_sync",
    "record_snapshot",
]
