"""Observability: metric streams, request traces, rollups, live endpoint.

The recording layer over the serving/fleet stack (see docs/OBSERVABILITY.md):

    signal sources ──▶ sources.py adapters ──▶ Recorder ──▶ <run>/<stream>.jsonl
     slo_report()        SLOSampler              │             summary.json
     Snapshot            record_snapshot         └─▶ rollup() ──▶ StatsServer
     sync_stats          record_fleet_sync                        (HTTP JSON:
     run_timed           make_on_block                             /  /spans
     adaptation trace    record_adaptation                         /stages
     SubsampledMHInfo    record_transition_cost                    /sublinear)
    request path     ──▶ trace.Tracer spans  ──▶ spans stream + ring
     (queue/router/replica/evaluator)            └─▶ Chrome trace export
    bench artifacts  ──▶ history.HistoryStore (ring of last N runs,
                          read by benchmarks/gate.py --trend)

Front-end: ``python -m repro.launch.serve --stats-addr 127.0.0.1:8787
--obs-dir /tmp/obs --trace-dir /tmp/trace``; trace export via
``python -m repro.obs.trace --export ...``; trend gating over the recorded
benchmark artifacts lives in ``benchmarks/gate.py``.
"""
from .history import HistoryStore
from .recorder import Recorder, json_default
from .server import StatsServer
from .sources import (
    SLOSampler,
    make_on_block,
    record_adaptation,
    record_fleet_sync,
    record_snapshot,
    record_transition_cost,
)
from .trace import Tracer, chrome_trace_events, span_close, span_open

# The alerting/health layer loads lazily: every serve path imports this
# package (via .trace / .recorder), and a flags-off run must not pay for —
# or even load — the alert engine.
_LAZY = {
    "AlertEngine": "alerts",
    "AlertRule": "alerts",
    "default_rules": "alerts",
    "health_report": "health",
}


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{module}", __name__), name)


__all__ = [
    "AlertEngine",
    "AlertRule",
    "HistoryStore",
    "Recorder",
    "SLOSampler",
    "StatsServer",
    "Tracer",
    "chrome_trace_events",
    "default_rules",
    "health_report",
    "json_default",
    "make_on_block",
    "record_adaptation",
    "record_fleet_sync",
    "record_snapshot",
    "record_transition_cost",
    "span_close",
    "span_open",
]
