"""Bayesian LM bridge: the paper's transition operator at architecture scale."""
from .train import (
    LMTrainInfo,
    LogLikCache,
    TrainConfig,
    make_cached_train_step,
    make_exact_step,
    make_train_step,
)

__all__ = [
    "LMTrainInfo",
    "LogLikCache",
    "TrainConfig",
    "make_cached_train_step",
    "make_exact_step",
    "make_train_step",
]
