"""LM-scale subsampled MH: ``train_step`` is one approximate MH transition
over the model parameters theta under p(theta) prod_i p(seq_i | theta).

Mapping onto the paper (DESIGN.md §5):
  - local section i  = one training sequence; l_i = log p(seq_i|theta') -
    log p(seq_i|theta) (two forward passes, NO backward),
  - global section   = Gaussian prior ratio (+ proposal correction; zero for
    the symmetric random walk),
  - without-replacement draws = contiguous slices of the pre-permuted
    resident pool (stream sampler — DESIGN.md §3),
  - accept/reject    = Alg. 2 sequential t-test with finite-population
    correction, inside one lax.while_loop.

Distribution properties (the 1000-node story): the proposal is regenerated
per-shard from counter-based PRNG keys (zero-communication), and the only
cross-chip traffic per round is the scalar psum of the Welford statistics —
O(1) bytes versus O(P) for an SGD all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.sequential_test import sequential_test
from ..core.samplers import StreamSliceState, stream_draw, stream_reset
from ..models.transformer import ModelConfig, forward_loglik

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    round_batch: int = 64  # sequences per test round (global, across the mesh)
    max_rounds: int | None = None  # default: pool // round_batch
    epsilon: float = 0.05
    sigma: float = 1e-4  # RW proposal std
    prior_var: float = 1.0
    ce_chunk: int = 256
    dataset_size: int | None = None  # N; defaults to the resident pool size
    proposal: str = "rw"  # "rw" | "mala"
    mala_step: float = 1e-6
    # restrict proposals to leaves whose '/'-joined path contains one of these
    # substrings (e.g. ("final_norm",) for Bayesian-last-layer); None = all
    propose_paths: tuple | None = None
    cached: bool = False  # lazy loglik cache (Sec 3.5 analog; §Perf HC1)


class LMTrainInfo(NamedTuple):
    accepted: jax.Array
    rounds: jax.Array
    n_evaluated: jax.Array
    mu_hat: jax.Array
    mu0: jax.Array
    pvalue: jax.Array
    log_u: jax.Array


_SCAN_NOISE_THRESHOLD = 1 << 22  # elements; larger leaves get per-row RNG


def _perturb_leaf(key: jax.Array, leaf: jax.Array, sigma: float) -> jax.Array:
    """leaf + sigma * N(0, I), generating noise per leading-axis row inside a
    scan for big (stacked-layer) leaves: Threefry temporaries are ~8x the
    output size, which at full stacked shape dominated per-device memory in
    the dry-run (0.8 GiB x dozens of u64 buffers for qwen's 64-layer stack)."""
    if leaf.size <= _SCAN_NOISE_THRESHOLD or leaf.ndim < 2:
        n = jax.random.normal(key, leaf.shape, jnp.float32)
        return (leaf.astype(jnp.float32) + sigma * n).astype(leaf.dtype)

    keys = jax.random.split(key, leaf.shape[0])

    def body(_, inp):
        row, k = inp
        n = jax.random.normal(k, row.shape, jnp.float32)
        return None, (row.astype(jnp.float32) + sigma * n).astype(row.dtype)

    _, out = jax.lax.scan(body, None, (leaf, keys))
    return out


def _tree_rw_propose(
    key: jax.Array, tree: Params, sigma: float, paths: tuple | None = None
) -> Params:
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree.structure(tree)
    keys = jax.random.split(key, len(leaves_with_path))
    out = []
    for k, (path, leaf) in zip(keys, leaves_with_path):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if paths is not None and not any(s in name for s in paths):
            out.append(leaf)
        else:
            out.append(_perturb_leaf(k, leaf, sigma))
    return jax.tree.unflatten(treedef, out)


def _tree_normal_like(key: jax.Array, tree: Params) -> Params:
    zeros = jax.tree.map(lambda l: jnp.zeros_like(l), tree)
    return _tree_rw_propose(key, zeros, 1.0)


def _prior_delta(theta: Params, theta_p: Params, prior_var: float) -> jax.Array:
    """log p(theta') - log p(theta) under N(0, prior_var I) (f32 accumulate)."""
    def sq(t):
        return sum(
            jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(t)
        )

    return (-0.5 / prior_var) * (sq(theta_p) - sq(theta))


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Build the jittable subsampled-MH train step for one architecture."""

    def loglik_slice(theta, batch, start, rb):
        rows = {
            k: jax.lax.dynamic_slice_in_dim(v, start, rb, axis=0)
            for k, v in batch.items()
        }
        return forward_loglik(theta, rows, cfg, ce_chunk=tc.ce_chunk)

    def train_step(key, params, batch):
        pool = batch["tokens"].shape[0]
        rb = min(tc.round_batch, pool)
        rounds_total = tc.max_rounds or -(-pool // rb)
        n_sections = tc.dataset_size or pool

        k_u, k_prop, k_test = jax.random.split(key, 3)
        log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))

        if tc.proposal == "mala":
            def logpost_est(t):
                ll = loglik_slice(t, batch, 0, rb).sum() * (n_sections / rb)
                pr = sum(
                    jnp.sum(jnp.square(l.astype(jnp.float32)))
                    for l in jax.tree.leaves(t)
                )
                return ll - 0.5 * pr / tc.prior_var

            g_est = jax.grad(logpost_est)(params)
            xi = _tree_normal_like(k_prop, params)
            half = 0.5 * tc.mala_step
            root = tc.mala_step**0.5
            theta_p = jax.tree.map(
                lambda t, gg, n: (
                    t.astype(jnp.float32) + half * gg.astype(jnp.float32)
                    + root * n.astype(jnp.float32)
                ).astype(t.dtype),
                params, g_est, xi,
            )
            corr = jnp.zeros((), jnp.float32)  # symmetric-at-small-step approx
        else:
            theta_p = _tree_rw_propose(k_prop, params, tc.sigma, tc.propose_paths)
            corr = jnp.zeros((), jnp.float32)

        g = _prior_delta(params, theta_p, tc.prior_var) + corr
        mu0 = (log_u - g) / n_sections

        def eval_fn(idx):
            # idx are contiguous stream offsets; evaluate the slice
            start = idx[0]
            lp = loglik_slice(theta_p, batch, start, rb)
            lc_ = loglik_slice(params, batch, start, rb)
            return lp - lc_

        res = sequential_test(
            key=k_test,
            mu0=mu0,
            draw_fn=stream_draw,
            eval_fn=eval_fn,
            sampler_state=stream_reset(StreamSliceState(jnp.zeros((), jnp.int32), pool)),
            num_sections=n_sections,
            batch_size=rb,
            epsilon=tc.epsilon,
            max_rounds=rounds_total,
        )
        accept = res.decision
        new_params = jax.tree.map(
            lambda a, b: jnp.where(accept, b, a), params, theta_p
        )
        info = LMTrainInfo(
            accepted=accept,
            rounds=res.rounds,
            n_evaluated=res.n_evaluated,
            mu_hat=res.mu_hat,
            mu0=mu0,
            pvalue=res.pvalue,
            log_u=log_u,
        )
        return new_params, info

    return train_step


class LogLikCache(NamedTuple):
    """Per-section log p(seq_i | theta) values for the resident pool, with a
    validity mask. This is the paper's Sec-3.5 *lazy stale-node update* at
    tensor scale: an accepted proposal leaves un-evaluated sections' cached
    values stale (valid=False); they are recomputed on first access instead
    of eagerly."""

    ll: jax.Array  # (pool,) f32
    valid: jax.Array  # (pool,) bool

    @staticmethod
    def empty(pool: int) -> "LogLikCache":
        return LogLikCache(jnp.zeros((pool,), jnp.float32), jnp.zeros((pool,), bool))


def make_cached_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Subsampled MH with the lazy loglik cache (§Perf hillclimb 1).

    Per test round the baseline runs TWO forwards (theta and theta'). With the
    cache, the theta forward is skipped whenever the round's slice is entirely
    valid — in steady state (same resident pool across transitions) the
    expected forwards per round drop from 2 to 1 + acceptance_rate.

    step(seed_key, params, batch, cache) -> (params', cache', info)
    """

    def loglik_slice(theta, batch, start, rb):
        rows = {
            k: jax.lax.dynamic_slice_in_dim(v, start, rb, axis=0)
            for k, v in batch.items()
        }
        return forward_loglik(theta, rows, cfg, ce_chunk=tc.ce_chunk)

    def train_step(key, params, batch, cache: LogLikCache):
        pool = batch["tokens"].shape[0]
        rb = min(tc.round_batch, pool)
        rounds_total = tc.max_rounds or -(-pool // rb)
        n_sections = tc.dataset_size or pool

        k_u, k_prop, k_test = jax.random.split(key, 3)
        log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))
        theta_p = _tree_rw_propose(k_prop, params, tc.sigma, tc.propose_paths)
        g = _prior_delta(params, theta_p, tc.prior_var)
        mu0 = (log_u - g) / n_sections

        # aux = (cur-cache, prop values recorded this transition, eval mask)
        aux0 = (cache, jnp.zeros((pool,), jnp.float32), jnp.zeros((pool,), bool))

        def eval_fn(idx, aux):
            cur, prop_ll, evald = aux
            start = idx[0]
            lp = loglik_slice(theta_p, batch, start, rb)
            sl_valid = jax.lax.dynamic_slice_in_dim(cur.valid, start, rb)
            sl_ll = jax.lax.dynamic_slice_in_dim(cur.ll, start, rb)

            def fresh(_):
                lc_ = loglik_slice(params, batch, start, rb)
                return jnp.where(sl_valid, sl_ll, lc_)

            # skip the theta forward when every cached value is fresh
            lcur = jax.lax.cond(sl_valid.all(), lambda _: sl_ll, fresh, None)
            new_cur = LogLikCache(
                jax.lax.dynamic_update_slice_in_dim(cur.ll, lcur, start, axis=0),
                jax.lax.dynamic_update_slice_in_dim(
                    cur.valid, jnp.ones((rb,), bool), start, axis=0
                ),
            )
            prop_ll = jax.lax.dynamic_update_slice_in_dim(prop_ll, lp, start, axis=0)
            evald = jax.lax.dynamic_update_slice_in_dim(
                evald, jnp.ones((rb,), bool), start, axis=0
            )
            return lp - lcur, (new_cur, prop_ll, evald)

        res = sequential_test(
            key=k_test,
            mu0=mu0,
            draw_fn=stream_draw,
            eval_fn=eval_fn,
            sampler_state=stream_reset(StreamSliceState(jnp.zeros((), jnp.int32), pool)),
            num_sections=n_sections,
            batch_size=rb,
            epsilon=tc.epsilon,
            max_rounds=rounds_total,
            aux=aux0,
        )
        accept = res.decision
        cur, prop_ll, evald = res.aux
        new_params = jax.tree.map(lambda a, b: jnp.where(accept, b, a), params, theta_p)
        # accept: evaluated sections carry l(theta'); the rest go stale (lazy)
        new_cache = LogLikCache(
            ll=jnp.where(accept, prop_ll, cur.ll),
            valid=jnp.where(accept, evald, cur.valid),
        )
        info = LMTrainInfo(
            accepted=accept,
            rounds=res.rounds,
            n_evaluated=res.n_evaluated,
            mu_hat=res.mu_hat,
            mu0=mu0,
            pvalue=res.pvalue,
            log_u=log_u,
        )
        return new_params, new_cache, info

    return train_step


def make_exact_step(cfg: ModelConfig, tc: TrainConfig):
    """O(N) baseline: evaluate every local section (the full pool), then the
    exact accept rule — the paper's Alg. 1 comparator at LM scale."""

    def exact_step(key, params, batch):
        pool = batch["tokens"].shape[0]
        rb = min(tc.round_batch, pool)
        rounds = -(-pool // rb)
        k_u, k_prop = jax.random.split(key)
        log_u = jnp.log(jax.random.uniform(k_u, (), jnp.float32, 1e-20, 1.0))
        theta_p = _tree_rw_propose(k_prop, params, tc.sigma, tc.propose_paths)
        g = _prior_delta(params, theta_p, tc.prior_var)

        def body(carry, r):
            start = r * rb
            rows = {
                k: jax.lax.dynamic_slice_in_dim(v, start, rb, axis=0)
                for k, v in batch.items()
            }
            lp = forward_loglik(theta_p, rows, cfg, ce_chunk=tc.ce_chunk)
            lc_ = forward_loglik(params, rows, cfg, ce_chunk=tc.ce_chunk)
            return carry + (lp - lc_).sum(), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(rounds))
        accept = log_u < g + total
        new_params = jax.tree.map(lambda a, b: jnp.where(accept, b, a), params, theta_p)
        info = LMTrainInfo(
            accepted=accept,
            rounds=jnp.asarray(rounds, jnp.int32),
            n_evaluated=jnp.asarray(pool, jnp.int32),
            mu_hat=total / pool,
            mu0=(log_u - g) / pool,
            pvalue=jnp.zeros((), jnp.float32),
            log_u=log_u,
        )
        return new_params, info

    return exact_step
