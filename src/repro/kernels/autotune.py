"""Per-backend Pallas block-size autotuner with a disk-backed winner cache.

The Pallas kernels' tile sizes (``tile_m``, ``tile_t``/``tile_v``, …) were
hard-coded guesses; the right block depends on backend generation and the
shape regime. This module benchmarks a small candidate grid per *kernel
family* the first time a (family, shape-bucket) combination is dispatched,
and caches the winner on disk keyed by ``(backend, family, shape-bucket)``
so every later process start skips straight to the tuned block.

Scope and knobs:

* ``REPRO_AUTOTUNE=1`` forces tuning on, ``REPRO_AUTOTUNE=0`` pins the
  shipped defaults (:data:`DEFAULT_TILES`). Unset/``auto`` tunes only on a
  real TPU backend — interpret-mode timings on CPU say nothing about MXU/
  VMEM behaviour, so CPU runs stay deterministic and fast by default.
* ``REPRO_AUTOTUNE_DIR`` relocates the cache (CI sets it to a workspace
  path and uploads the JSON as a build artifact); the default is
  ``~/.cache/repro/autotune``.
* Shapes are bucketed to powers of two: one measurement covers the whole
  regime, and the compiled-kernel cache can't be flooded by ragged shapes.

Consulted by :mod:`repro.kernels.ops` — explicit ``tile_*`` kwargs always
win over the tuner, so call sites keep full control.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

ENV_VAR = "REPRO_AUTOTUNE"
DIR_ENV_VAR = "REPRO_AUTOTUNE_DIR"

#: The shipped block sizes — what ``REPRO_AUTOTUNE=0`` pins, and the
#: starting candidate of every grid (so tuning can never do worse than the
#: defaults on the measured workload, up to timer noise).
DEFAULT_TILES: dict[str, dict[str, int]] = {
    "logit_delta": {"tile_n": 512},
    "batched_loglik": {"tile_m": 256},
    "gaussian_ar1": {"tile_m": 256},
    "fused_ce": {"tile_t": 256, "tile_v": 512},
    "batched_fused_ce": {"tile_t": 256, "tile_v": 512},
}

CANDIDATES: dict[str, tuple[dict[str, int], ...]] = {
    "logit_delta": tuple({"tile_n": n} for n in (256, 512, 1024, 2048)),
    "batched_loglik": tuple({"tile_m": m} for m in (128, 256, 512, 1024)),
    "gaussian_ar1": tuple({"tile_m": m} for m in (128, 256, 512, 1024)),
    "fused_ce": tuple(
        {"tile_t": t, "tile_v": v} for t in (128, 256) for v in (256, 512, 1024)
    ),
    "batched_fused_ce": tuple(
        {"tile_t": t, "tile_v": v} for t in (128, 256) for v in (256, 512, 1024)
    ),
}

_memory_cache: dict[str, dict[str, Any]] = {}
_loaded_backends: set[str] = set()


def enabled() -> bool:
    """Tune? ``REPRO_AUTOTUNE`` 1/0 forces; unset tunes on TPU only."""
    env = os.environ.get(ENV_VAR, "auto").lower()
    if env in ("0", "false", "off", "never"):
        return False
    if env in ("1", "true", "on", "always"):
        return True
    return jax.default_backend() == "tpu"


def cache_dir() -> str:
    return os.environ.get(DIR_ENV_VAR) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune"
    )


def _cache_path(backend: str) -> str:
    return os.path.join(cache_dir(), f"{backend}.json")


def clear_cache(memory_only: bool = False) -> None:
    """Forget tuned winners (tests; or after a toolchain upgrade)."""
    _memory_cache.clear()
    _loaded_backends.clear()
    if memory_only:
        return
    for backend in ("tpu", "cpu", "gpu"):
        path = _cache_path(backend)
        if os.path.exists(path):
            os.remove(path)


def _load_disk(backend: str) -> None:
    if backend in _loaded_backends:
        return
    _loaded_backends.add(backend)
    path = _cache_path(backend)
    try:
        with open(path) as f:
            _memory_cache.update(json.load(f))
    except (OSError, ValueError):
        pass


def _save_disk(backend: str) -> None:
    path = _cache_path(backend)
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        entries = {k: v for k, v in _memory_cache.items()
                   if k.startswith(f"{backend}|")}
        with open(tmp, "w") as f:
            json.dump(entries, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS: in-memory winner still applies this process


def _bucket(n: int) -> int:
    return 1 if n <= 1 else int(2 ** int(np.ceil(np.log2(n))))


def cache_key(family: str, shape: tuple[int, ...], backend: str) -> str:
    bucket = "x".join(str(_bucket(int(d))) for d in shape)
    return f"{backend}|{family}|{bucket}"


def _time_once(fn: Callable[[], Any]) -> float:
    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _synth_inputs(family: str, shape: tuple[int, ...]):
    """Random concrete inputs at the bucketed shape for offline timing."""
    rng = np.random.default_rng(0)
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    pm1 = lambda *s: jnp.asarray(
        np.where(rng.standard_normal(s) > 0, 1.0, -1.0), jnp.float32
    )
    if family == "logit_delta":
        n, d = shape
        return (f32(n, d), pm1(n), f32(d), f32(d))
    if family == "batched_loglik":
        k, m, d = shape
        return (f32(k, m, d), pm1(k, m), f32(k, d), f32(k, d))
    if family == "gaussian_ar1":
        k, m = shape
        pos = jnp.abs(f32(k)) + 0.01
        return (f32(k, m), f32(k, m), f32(k) * 0.1 + 0.9, pos,
                f32(k) * 0.1 + 0.9, pos)
    if family == "fused_ce":
        t, d, v = shape
        tgt = jnp.asarray(rng.integers(0, v, size=(t,)), jnp.int32)
        return (f32(t, d), f32(v, d), tgt)
    if family == "batched_fused_ce":
        k, t, d, v = shape
        tgt = jnp.asarray(rng.integers(0, v, size=(k, t)), jnp.int32)
        return (f32(k, t, d), f32(v, d), tgt)
    raise KeyError(f"unknown kernel family {family!r}")


def _kernel_fn(family: str) -> Callable:
    # local imports: ops imports this module, kernels are leaf modules
    if family == "logit_delta":
        from .logit_loglik import logit_delta
        return logit_delta
    if family == "batched_loglik":
        from .batched_loglik import batched_logit_delta
        return batched_logit_delta
    if family == "gaussian_ar1":
        from .gaussian_ar1 import batched_gaussian_ar1_delta
        return batched_gaussian_ar1_delta
    if family == "fused_ce":
        from .fused_ce import fused_ce
        return fused_ce
    if family == "batched_fused_ce":
        from .fused_ce import batched_fused_ce
        return batched_fused_ce
    raise KeyError(f"unknown kernel family {family!r}")


def _benchmark(family: str, shape: tuple[int, ...], interpret: bool) -> dict:
    """Race the candidate grid at the bucketed shape; return the entry."""
    bucketed = tuple(_bucket(int(d)) for d in shape)
    args = _synth_inputs(family, bucketed)
    kernel = _kernel_fn(family)
    timings = []
    for cand in CANDIDATES[family]:
        try:
            sec = _time_once(lambda: kernel(*args, interpret=interpret, **cand))
        except Exception:  # candidate invalid on this backend: skip it
            continue
        timings.append((sec, cand))
    if not timings:
        return {"tiles": dict(DEFAULT_TILES[family]), "us": None}
    timings.sort(key=lambda tc: tc[0])
    best_sec, best = timings[0]
    return {
        "tiles": dict(best),
        "us": best_sec * 1e6,
        "candidates": len(timings),
        "default_us": next(
            (s * 1e6 for s, c in timings if c == DEFAULT_TILES[family]), None
        ),
    }


def tiles_for(family: str, shape: tuple[int, ...]) -> dict[str, int]:
    """The block sizes to dispatch ``family`` with at ``shape``.

    Returns the shipped defaults when tuning is disabled; otherwise the
    cached winner, measuring the candidate grid on first use (concrete
    synthesized inputs — safe to call during tracing, shapes are static).
    """
    if family not in DEFAULT_TILES:
        raise KeyError(f"unknown kernel family {family!r}")
    if not enabled():
        return dict(DEFAULT_TILES[family])
    backend = jax.default_backend()
    key = cache_key(family, shape, backend)
    _load_disk(backend)
    entry = _memory_cache.get(key)
    if entry is None:
        entry = _benchmark(family, shape, interpret=backend != "tpu")
        _memory_cache[key] = entry
        _save_disk(backend)
    return dict(entry["tiles"])


def warm(families: tuple[str, ...] | None = None, fast: bool = True) -> dict:
    """Tune representative shape buckets for each family (the CI artifact
    producer: ``python -m repro.kernels.autotune``)."""
    shapes: dict[str, list[tuple[int, ...]]] = {
        "logit_delta": [(4096, 64)],
        "batched_loglik": [(8, 256, 64)],
        "gaussian_ar1": [(8, 1024)],
        "fused_ce": [(256, 256, 8192)],
        "batched_fused_ce": [(4, 256, 256, 8192)],
    }
    if not fast:
        shapes["logit_delta"].append((65536, 64))
        shapes["batched_loglik"].append((64, 512, 64))
        shapes["gaussian_ar1"].append((64, 4096))
    out = {}
    for family in families or tuple(shapes):
        for shape in shapes[family]:
            out[cache_key(family, shape, jax.default_backend())] = tiles_for(
                family, shape
            )
    return out


if __name__ == "__main__":
    os.environ.setdefault(ENV_VAR, "1")
    for k, tiles in warm().items():
        print(f"{k}: {tiles}")
    print(f"cache: {_cache_path(jax.default_backend())}")
