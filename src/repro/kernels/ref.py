"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

This module also owns the shared *reference* likelihoods (e.g.
:func:`logit_loglik`): one definition that the experiments, the kernel-family
registry (:mod:`repro.core.target_builder`), and the parity tests all import,
so the fused kernels always have a single source of truth to agree with.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def logit_loglik(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Per-observation log Logit(y | x, w) = -log(1 + exp(-y x·w)).

    The shared reference implementation of the paper's logistic factor —
    BayesLR and the joint DP mixture both score observations with it; the
    fused kernels in :mod:`repro.kernels.logit_loglik` /
    :mod:`repro.kernels.batched_loglik` compute its pair-delta form.

    w: (D,), x: (..., D), y: (...) in {-1, +1} -> (...) f32.
    """
    return -jnp.logaddexp(0.0, -y * (x @ w))


def fused_ce_ref(h: jax.Array, table: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token log-likelihood: log softmax(h @ table.T)[target].

    h: (T, D), table: (V, D), targets: (T,) int32 -> (T,) f32.
    """
    logits = jnp.einsum("td,vd->tv", h, table).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return tgt - logz


def logit_delta_ref(
    x: jax.Array, y: jax.Array, w_cur: jax.Array, w_prop: jax.Array
) -> jax.Array:
    """BayesLR local-section delta: l_i = log sig(y x.w') - log sig(y x.w).

    x: (N, D), y: (N,) in {-1,+1}, w_*: (D,) -> (N,) f32.
    """
    z_c = (x @ w_cur).astype(jnp.float32)
    z_p = (x @ w_prop).astype(jnp.float32)
    return -jnp.logaddexp(0.0, -y * z_p) + jnp.logaddexp(0.0, -y * z_c)


def batched_logit_delta_ref(
    xg: jax.Array, yg: jax.Array, w_cur: jax.Array, w_prop: jax.Array
) -> jax.Array:
    """Ensemble-batched logit delta: one (m,)-block per chain.

    xg: (K, m, D), yg: (K, m) in {-1,+1}, w_*: (K, D) -> (K, m) f32.
    """
    z_c = jnp.einsum("kmd,kd->km", xg, w_cur).astype(jnp.float32)
    z_p = jnp.einsum("kmd,kd->km", xg, w_prop).astype(jnp.float32)
    return -jnp.logaddexp(0.0, -yg * z_p) + jnp.logaddexp(0.0, -yg * z_c)


def ar1_propagate(h_prev: jax.Array, noise: jax.Array,
                  phi: jax.Array, s2: jax.Array) -> jax.Array:
    """Shared AR(1) transition sample: ``phi * h_prev + sqrt(clip(s2)) * z``.

    The *sampling* twin of :func:`gaussian_ar1_delta_ref`'s density math —
    the particle-Gibbs sweep (:mod:`repro.kernels.pgibbs`) propagates
    particles with exactly the clip/scale arithmetic the MH delta kernel
    scores them with, so sweep and adjacent MH rounds share one definition
    of the transition factor.
    """
    return phi * h_prev + jnp.sqrt(jnp.clip(s2, 1e-12, None)) * noise


_LOG2PI = 1.8378770664093453


def sv_obs_loglik(x: jax.Array, h: jax.Array) -> jax.Array:
    """Stochastic-volatility observation factor log N(x | 0, exp(h)):
    the particle weight of the pgibbs sweep (elementwise over any batch)."""
    return -0.5 * (x * x * jnp.exp(-h) + h + _LOG2PI)


def gaussian_ar1_delta_ref(
    xt: jax.Array, xp: jax.Array,
    phi_cur: jax.Array, s2_cur: jax.Array,
    phi_prop: jax.Array, s2_prop: jax.Array,
) -> jax.Array:
    """AR(1) transition-factor delta (the stochvol local sections):

        l_i = log N(xt_i | phi' xp_i, s2') - log N(xt_i | phi xp_i, s2)

    The 2pi constant cancels in the pair. sigma^2 is clipped at 1e-12 so
    out-of-support proposals (rejected via the -inf prior in the global
    section) still produce finite local evaluations.

    xt, xp: (..., m); phi/s2 scalars broadcast against them -> (..., m) f32.
    """
    s2c = jnp.clip(s2_cur, 1e-12, None).astype(jnp.float32)
    s2p = jnp.clip(s2_prop, 1e-12, None).astype(jnp.float32)
    xt = xt.astype(jnp.float32)
    xp = xp.astype(jnp.float32)
    lc = -0.5 * ((xt - phi_cur * xp) ** 2 / s2c + jnp.log(s2c))
    lp = -0.5 * ((xt - phi_prop * xp) ** 2 / s2p + jnp.log(s2p))
    return lp - lc


def batched_gaussian_ar1_delta_ref(
    xt: jax.Array, xp: jax.Array,
    phi_cur: jax.Array, s2_cur: jax.Array,
    phi_prop: jax.Array, s2_prop: jax.Array,
) -> jax.Array:
    """Ensemble-batched AR(1) delta: xt/xp (K, m), params (K,) -> (K, m)."""
    return gaussian_ar1_delta_ref(
        xt, xp,
        phi_cur[:, None], s2_cur[:, None], phi_prop[:, None], s2_prop[:, None],
    )


def batched_fused_ce_ref(h: jax.Array, table: jax.Array, targets: jax.Array) -> jax.Array:
    """Ensemble-batched per-token log-likelihood.

    h: (K, T, D); table: (V, D) shared across chains or (K, V, D) per-chain;
    targets: (K, T) int32 -> (K, T) f32.
    """
    if table.ndim == 2:
        logits = jnp.einsum("ktd,vd->ktv", h, table).astype(jnp.float32)
    else:
        logits = jnp.einsum("ktd,kvd->ktv", h, table).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return tgt - logz
