"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_ce_ref(h: jax.Array, table: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token log-likelihood: log softmax(h @ table.T)[target].

    h: (T, D), table: (V, D), targets: (T,) int32 -> (T,) f32.
    """
    logits = jnp.einsum("td,vd->tv", h, table).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return tgt - logz


def logit_delta_ref(
    x: jax.Array, y: jax.Array, w_cur: jax.Array, w_prop: jax.Array
) -> jax.Array:
    """BayesLR local-section delta: l_i = log sig(y x.w') - log sig(y x.w).

    x: (N, D), y: (N,) in {-1,+1}, w_*: (D,) -> (N,) f32.
    """
    z_c = (x @ w_cur).astype(jnp.float32)
    z_p = (x @ w_prop).astype(jnp.float32)
    return -jnp.logaddexp(0.0, -y * z_p) + jnp.logaddexp(0.0, -y * z_c)


def batched_logit_delta_ref(
    xg: jax.Array, yg: jax.Array, w_cur: jax.Array, w_prop: jax.Array
) -> jax.Array:
    """Ensemble-batched logit delta: one (m,)-block per chain.

    xg: (K, m, D), yg: (K, m) in {-1,+1}, w_*: (K, D) -> (K, m) f32.
    """
    z_c = jnp.einsum("kmd,kd->km", xg, w_cur).astype(jnp.float32)
    z_p = jnp.einsum("kmd,kd->km", xg, w_prop).astype(jnp.float32)
    return -jnp.logaddexp(0.0, -yg * z_p) + jnp.logaddexp(0.0, -yg * z_c)
