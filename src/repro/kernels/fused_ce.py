"""Pallas TPU kernel: fused large-vocab log-likelihood (blocked online
logsumexp).

The per-transition hot spot of subsampled MH over an LM is the per-sequence
log-likelihood: logits = h @ W_vocab^T with V up to 262k (gemma3). Naively
that materializes a (T, V) tensor in HBM (tens of GB per round). This kernel
streams vocab tiles through VMEM with a flash-style running (max, sum)
accumulator and a one-hot target extraction, so HBM traffic is
O(T*D + V*D + T) instead of O(T*V).

Grid: (T/tile_t, V/tile_v), vocab-major iteration is the accumulation loop;
MXU work per step is a (tile_t x D) @ (D x tile_v) matmul. Tiles are 128-row
aligned for the MXU. Validated in interpret mode on CPU against ref.py
(real-TPU execution is the deployment target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(tgt_ref, h_ref, tab_ref, out_ref, m_ref, s_ref, t_ref, *, tile_v, n_v, v_real):
    vj = pl.program_id(1)

    @pl.when(vj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    h = h_ref[...]
    tab = tab_ref[...]
    logits = jax.lax.dot_general(
        h, tab, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (tile_t, tile_v)
    # mask vocab-padding columns out of the logsumexp
    col_global = vj * tile_v + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col_global < v_real, logits, _NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, logits.max(axis=-1))
    corr = jnp.exp(m_old - m_new)
    s_ref[...] = s_ref[...] * corr + jnp.exp(logits - m_new[:, None]).sum(axis=-1)
    m_ref[...] = m_new

    # target logit if it falls inside this vocab tile
    tgt = tgt_ref[...]
    local = tgt - vj * tile_v
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    hit = cols == local[:, None]
    t_ref[...] = t_ref[...] + jnp.where(hit, logits, 0.0).sum(axis=-1)

    @pl.when(vj == n_v - 1)
    def _finish():
        out_ref[...] = t_ref[...] - (jnp.log(s_ref[...]) + m_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_t", "tile_v", "interpret"))
def fused_ce(
    h: jax.Array,  # (T, D)
    table: jax.Array,  # (V, D)
    targets: jax.Array,  # (T,) int32
    *,
    tile_t: int = 256,
    tile_v: int = 512,
    interpret: bool = False,
) -> jax.Array:
    t, d = h.shape
    v = table.shape[0]
    tile_t = min(tile_t, t)
    tile_v = min(tile_v, v)
    pad_t = (-t) % tile_t
    pad_v = (-v) % tile_v
    if pad_t:
        h = jnp.pad(h, ((0, pad_t), (0, 0)))
        targets = jnp.pad(targets, (0, pad_t))
    if pad_v:
        table = jnp.pad(table, ((0, pad_v), (0, 0)))
    tp, vp = t + pad_t, v + pad_v
    n_t, n_v = tp // tile_t, vp // tile_v

    out = pl.pallas_call(
        functools.partial(_kernel, tile_v=tile_v, n_v=n_v, v_real=v),
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((tile_t,), lambda i, j: (i,)),
            pl.BlockSpec((tile_t, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_v, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((tp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_t,), jnp.float32),
            pltpu.VMEM((tile_t,), jnp.float32),
            pltpu.VMEM((tile_t,), jnp.float32),
        ],
        interpret=interpret,
    )(targets.astype(jnp.int32), h, table)
    return out[:t]


def _batched_kernel(tgt_ref, h_ref, tab_ref, out_ref, m_ref, s_ref, t_ref,
                    *, tile_v, n_v, v_real, shared_table):
    vj = pl.program_id(2)

    @pl.when(vj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    h = h_ref[0]  # (tile_t, D) of this chain
    tab = tab_ref[...] if shared_table else tab_ref[0]  # (tile_v, D)
    logits = jax.lax.dot_general(
        h, tab, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (tile_t, tile_v)
    col_global = vj * tile_v + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col_global < v_real, logits, _NEG)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, logits.max(axis=-1))
    corr = jnp.exp(m_old - m_new)
    s_ref[...] = s_ref[...] * corr + jnp.exp(logits - m_new[:, None]).sum(axis=-1)
    m_ref[...] = m_new

    tgt = tgt_ref[0]
    local = tgt - vj * tile_v
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    hit = cols == local[:, None]
    t_ref[...] = t_ref[...] + jnp.where(hit, logits, 0.0).sum(axis=-1)

    @pl.when(vj == n_v - 1)
    def _finish():
        out_ref[0] = t_ref[...] - (jnp.log(s_ref[...]) + m_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_t", "tile_v", "interpret"))
def batched_fused_ce(
    h: jax.Array,  # (K, T, D) per-chain token activations
    table: jax.Array,  # (V, D) shared vocab table, or (K, V, D) per-chain
    targets: jax.Array,  # (K, T) int32
    *,
    tile_t: int = 256,
    tile_v: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Ensemble-batched per-token log-likelihood: the (K, m) multi-chain
    round of the LM likelihood, one ``pallas_call`` for all K chains.

    The chain axis joins the grid (grid = (K, T/tile_t, V/tile_v), vocab-major
    accumulation per (chain, token-tile) as in :func:`fused_ce`). ``table``
    may be shared (the common case: chains sample activations-producing
    parameters) or carry a per-chain leading axis (chains sample the table
    itself, e.g. an unembedding MH move).
    """
    k, t, d = h.shape
    shared_table = table.ndim == 2
    v = table.shape[0] if shared_table else table.shape[1]
    tile_t = min(tile_t, t)
    tile_v = min(tile_v, v)
    pad_t = (-t) % tile_t
    pad_v = (-v) % tile_v
    if pad_t:
        h = jnp.pad(h, ((0, 0), (0, pad_t), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad_t)))
    if pad_v:
        pad_spec = ((0, pad_v), (0, 0)) if shared_table else ((0, 0), (0, pad_v), (0, 0))
        table = jnp.pad(table, pad_spec)
    tp, vp = t + pad_t, v + pad_v
    n_t, n_v = tp // tile_t, vp // tile_v

    if shared_table:
        tab_spec = pl.BlockSpec((tile_v, d), lambda c, i, j: (j, 0))
    else:
        tab_spec = pl.BlockSpec((1, tile_v, d), lambda c, i, j: (c, j, 0))
    out = pl.pallas_call(
        functools.partial(_batched_kernel, tile_v=tile_v, n_v=n_v, v_real=v,
                          shared_table=shared_table),
        grid=(k, n_t, n_v),
        in_specs=[
            pl.BlockSpec((1, tile_t), lambda c, i, j: (c, i)),
            pl.BlockSpec((1, tile_t, d), lambda c, i, j: (c, i, 0)),
            tab_spec,
        ],
        out_specs=pl.BlockSpec((1, tile_t), lambda c, i, j: (c, i)),
        out_shape=jax.ShapeDtypeStruct((k, tp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_t,), jnp.float32),
            pltpu.VMEM((tile_t,), jnp.float32),
            pltpu.VMEM((tile_t,), jnp.float32),
        ],
        interpret=interpret,
    )(targets.astype(jnp.int32), h, table)
    return out[:, :t]
