"""Pallas TPU kernels for the likelihood hot spots (+ jnp oracles).

fused_ce    — vocab-blocked per-token log-likelihood (online logsumexp)
logit_delta — pair-fused BayesLR MH delta (x read once for theta, theta')
ops         — jit'd dispatch wrappers (kernel on TPU, interpret/ref on CPU)
ref         — pure-jnp oracles (the allclose ground truth)
"""
from . import ops, ref
from .fused_ce import fused_ce
from .logit_loglik import logit_delta

__all__ = ["fused_ce", "logit_delta", "ops", "ref"]
