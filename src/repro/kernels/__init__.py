"""Pallas TPU kernels for the likelihood hot spots (+ jnp oracles).

fused_ce                  — vocab-blocked per-token log-likelihood (online logsumexp)
batched_fused_ce          — the (K, T) ensemble-batched form: one grid over chains
logit_delta               — pair-fused BayesLR MH delta (x read once for theta, theta')
batched_logit_delta       — the (K, m) ensemble-batched form of logit_delta: one
                            fused pallas_call per multi-chain sequential-test round
batched_gaussian_ar1_delta — the (K, m) AR(1) transition-factor delta (stochvol)
batched_pgibbs_sweep      — fused particle-Gibbs sweep: all (K chains, S series,
                            P particles) advanced by ONE time-major scan, sharing
                            the AR(1) propagate math with the delta kernels
ops                       — jit'd dispatch wrappers (mode="auto|always|never":
                            kernel on TPU, interpret/ref on CPU, REPRO_FUSED env
                            overrides the auto default; precision="fp32|bf16|auto"
                            picks the gather/delta data path, fp32 accumulation
                            always)
autotune                  — per-backend Pallas block-size tuner with an on-disk
                            winner cache (REPRO_AUTOTUNE, REPRO_AUTOTUNE_DIR)
ref                       — pure-jnp oracles (the allclose ground truth) and the
                            shared reference likelihoods (logit_loglik,
                            ar1_propagate, sv_obs_loglik)
"""
from . import autotune, ops, ref
from .batched_loglik import batched_logit_delta, gather_and_delta
from .fused_ce import batched_fused_ce, fused_ce
from .gaussian_ar1 import batched_gaussian_ar1_delta
from .logit_loglik import logit_delta
from .pgibbs import batched_pgibbs_sweep, pgibbs_sweep_fused

__all__ = [
    "autotune",
    "batched_fused_ce",
    "batched_gaussian_ar1_delta",
    "batched_logit_delta",
    "batched_pgibbs_sweep",
    "fused_ce",
    "gather_and_delta",
    "logit_delta",
    "ops",
    "pgibbs_sweep_fused",
    "ref",
]
