"""Pallas TPU kernels for the likelihood hot spots (+ jnp oracles).

fused_ce            — vocab-blocked per-token log-likelihood (online logsumexp)
logit_delta         — pair-fused BayesLR MH delta (x read once for theta, theta')
batched_logit_delta — the (K, m) ensemble-batched form of logit_delta: one
                      fused pallas_call per multi-chain sequential-test round
ops                 — jit'd dispatch wrappers (kernel on TPU, interpret/ref on CPU)
ref                 — pure-jnp oracles (the allclose ground truth)
"""
from . import ops, ref
from .batched_loglik import batched_logit_delta, gather_and_delta
from .fused_ce import fused_ce
from .logit_loglik import logit_delta

__all__ = ["batched_logit_delta", "fused_ce", "gather_and_delta", "logit_delta", "ops", "ref"]
