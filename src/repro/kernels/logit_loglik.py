"""Pallas TPU kernel: fused BayesLR delta-log-likelihood.

The paper's own hot spot (Sec. 4.1): every sequential-test round evaluates
l_i = log sig(y_i x_i.w') - log sig(y_i x_i.w) for a mini-batch. Evaluating
theta and theta' separately reads the feature tile x twice; MH always needs
the PAIR, so this kernel computes both dot products per x-tile read — the
data movement is halved versus two passes (a beyond-paper fusion enabled by
the structure of the MH ratio; see DESIGN.md §6).

Grid: (N/tile_n,). Per step: one (tile_n x D) @ (D x 2) MXU matmul, then the
log-sigmoid deltas on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, w2_ref, out_ref):
    x = x_ref[...]
    w2 = w2_ref[...]  # (D, 2): [w_cur, w_prop]
    z = jax.lax.dot_general(
        x, w2, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (tile_n, 2)
    y = y_ref[...].astype(jnp.float32)
    lc = -jnp.logaddexp(0.0, -y * z[:, 0])
    lp = -jnp.logaddexp(0.0, -y * z[:, 1])
    out_ref[...] = lp - lc


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def logit_delta(
    x: jax.Array,  # (N, D)
    y: jax.Array,  # (N,) in {-1, +1}
    w_cur: jax.Array,  # (D,)
    w_prop: jax.Array,  # (D,)
    *,
    tile_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    n, d = x.shape
    tile_n = min(tile_n, n)
    pad = (-n) % tile_n
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad), constant_values=1.0)
    w2 = jnp.stack([w_cur, w_prop], axis=-1)  # (D, 2)
    out = pl.pallas_call(
        _kernel,
        grid=((n + pad) // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((d, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        interpret=interpret,
    )(x, y, w2)
    return out[:n]
