"""Pallas kernel: ensemble-batched BayesLR delta-log-likelihood.

The multi-chain engine (:class:`repro.core.ensemble.ChainEnsemble`) turns
every sequential-test round into a (K, m) block of local-section evaluations
— K chains, each with its own gathered mini-batch and its own (w, w') pair.
This kernel fuses the whole block into one ``pallas_call``: per (chain, tile)
grid step it reads one (tile_m, D) slab of gathered features and the chain's
(D, 2) stacked weight pair, does a single MXU matmul for BOTH sides of the
MH ratio (the same pair-fusion as :mod:`repro.kernels.logit_loglik`, lifted
over the chain axis), and writes the (tile_m,) delta.

Inputs are the *gathered* per-chain mini-batches — the O(m) gather stays
outside the kernel where XLA can fuse it with the sampler's index production.

Grid: (K, ceil(m / tile_m)). ``ref.batched_logit_delta_ref`` is the pure-jnp
twin used for interpret-mode parity tests on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xg_ref, yg_ref, w2_ref, out_ref):
    x = xg_ref[0]  # (tile_m, D) gathered features of this chain's tile
    w2 = w2_ref[0]  # (D, 2): [w_cur, w_prop] of this chain
    z = jax.lax.dot_general(
        x, w2, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (tile_m, 2)
    y = yg_ref[0].astype(jnp.float32)
    lc = -jnp.logaddexp(0.0, -y * z[:, 0])
    lp = -jnp.logaddexp(0.0, -y * z[:, 1])
    out_ref[0] = lp - lc


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def batched_logit_delta(
    xg: jax.Array,  # (K, m, D) gathered features, one mini-batch per chain
    yg: jax.Array,  # (K, m) labels in {-1, +1}
    w_cur: jax.Array,  # (K, D)
    w_prop: jax.Array,  # (K, D)
    *,
    tile_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """l[k, i] = log sig(y x·w'_k) - log sig(y x·w_k) for all K chains at once."""
    k, m, d = xg.shape
    tile_m = min(tile_m, m)
    pad = (-m) % tile_m
    if pad:
        xg = jnp.pad(xg, ((0, 0), (0, pad), (0, 0)))
        yg = jnp.pad(yg, ((0, 0), (0, pad)), constant_values=1.0)
    w2 = jnp.stack([w_cur, w_prop], axis=-1)  # (K, D, 2)
    out = pl.pallas_call(
        _kernel,
        grid=(k, (m + pad) // tile_m),
        in_specs=[
            pl.BlockSpec((1, tile_m, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
            pl.BlockSpec((1, d, 2), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, m + pad), jnp.float32),
        interpret=interpret,
    )(xg, yg, w2)
    return out[:, :m]


def gather_and_delta(
    x: jax.Array,  # (N, D) full feature pool
    y: jax.Array,  # (N,)
    idx: jax.Array,  # (K, m) int32 per-chain mini-batch indices
    w_cur: jax.Array,  # (K, D)
    w_prop: jax.Array,  # (K, D)
    *,
    tile_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Gather each chain's mini-batch then run the fused (K, m) kernel."""
    return batched_logit_delta(
        x[idx], y[idx], w_cur, w_prop, tile_m=tile_m, interpret=interpret
    )
