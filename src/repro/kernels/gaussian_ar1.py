"""Pallas kernel: ensemble-batched AR(1) transition-factor delta.

The stochastic-volatility local sections (paper Sec. 4.3) are the T
transition factors N(h_t | phi h_{t-1}, sigma^2); a multi-chain sequential-
test round over K chains evaluates a (K, m) block of pair-deltas

    l[k, i] = log N(xt[k,i] | phi'_k xp[k,i], s2'_k)
            - log N(xt[k,i] | phi_k  xp[k,i], s2_k)

with per-chain (phi, sigma^2) pairs. Pure VPU work — the fusion win is a
single kernel launch per round with the per-chain parameter broadcast, the
masking, and both sides of the MH ratio in one pass over the gathered
(K, m) slabs (which stay outside the kernel, fused with the sampler's index
production, exactly like :mod:`repro.kernels.batched_loglik`).

Grid: (K, ceil(m / tile_m)). ``ref.batched_gaussian_ar1_delta_ref`` is the
pure-jnp twin used for interpret-mode parity tests on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xt_ref, xp_ref, par_ref, out_ref):
    xt = xt_ref[0].astype(jnp.float32)  # (tile_m,) gathered x_t of this chain
    xp = xp_ref[0].astype(jnp.float32)  # (tile_m,) gathered x_{t-1}
    par = par_ref[0]  # (4,): [phi, s2, phi', s2']
    phi_c, s2_c, phi_p, s2_p = par[0], par[1], par[2], par[3]
    s2_c = jnp.maximum(s2_c, 1e-12)
    s2_p = jnp.maximum(s2_p, 1e-12)
    lc = -0.5 * ((xt - phi_c * xp) ** 2 / s2_c + jnp.log(s2_c))
    lp = -0.5 * ((xt - phi_p * xp) ** 2 / s2_p + jnp.log(s2_p))
    out_ref[0] = lp - lc


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def batched_gaussian_ar1_delta(
    xt: jax.Array,  # (K, m) gathered x_t, one mini-batch per chain
    xp: jax.Array,  # (K, m) gathered x_{t-1}
    phi_cur: jax.Array,  # (K,)
    s2_cur: jax.Array,  # (K,)
    phi_prop: jax.Array,  # (K,)
    s2_prop: jax.Array,  # (K,)
    *,
    tile_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(K, m) AR(1) pair-delta block — one call per multi-chain test round.

    bfloat16 ``xt``/``xp`` slabs are streamed as-is (half the HBM bytes of
    the memory-bound gather path) and upcast to float32 inside the kernel;
    any other dtype is cast to float32 up front as before.
    """
    k, m = xt.shape
    if xt.dtype != jnp.bfloat16:
        xt = xt.astype(jnp.float32)
        xp = xp.astype(jnp.float32)
    tile_m = min(tile_m, m)
    pad = (-m) % tile_m
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, pad)))
        xp = jnp.pad(xp, ((0, 0), (0, pad)))
    par = jnp.stack(
        [phi_cur, s2_cur, phi_prop, s2_prop], axis=-1
    ).astype(jnp.float32)  # (K, 4)
    out = pl.pallas_call(
        _kernel,
        grid=(k, (m + pad) // tile_m),
        in_specs=[
            pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
            pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, m + pad), jnp.float32),
        interpret=interpret,
    )(xt, xp, par)
    return out[:, :m]
